# Tier-1 verification + smoke entry points (mirrors .github/workflows/ci.yml)

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test verify fast smoke bench-smoke all

test verify:
	$(PY) -m pytest -x -q

fast:                        # skip the multi-device subprocess tests
	$(PY) -m pytest -x -q -m "not slow"

smoke:
	$(PY) examples/quickstart.py

bench-smoke:
	$(PY) benchmarks/transformer_comm.py --smoke

all: verify smoke bench-smoke
