# Tier-1 verification + smoke entry points (mirrors .github/workflows/ci.yml)

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test verify fast slow floor smoke bench-smoke wire-smoke \
        ring-smoke quant-smoke ratectl-smoke ratectl-pl-smoke \
        partition-smoke chaos-smoke serve-smoke docs all

test verify:
	$(PY) -m pytest -x -q

fast:                        # skip the multi-device subprocess tests
	$(PY) -m pytest -x -q -m "not slow" --durations=10

slow:                        # subprocess meshes + the parity matrix
	$(PY) -m pytest -x -q -m slow --durations=10

floor:                       # fail if collected tests drop below the floor
	$(PY) scripts/check_collection_floor.py

smoke:
	$(PY) examples/quickstart.py

bench-smoke:
	$(PY) benchmarks/transformer_comm.py --smoke

wire-smoke:                  # packed + p2p halo-exchange acceptance checks
	$(PY) benchmarks/halo_exchange.py --smoke

ring-smoke:                  # p2p ring: transport == analytic at rates {1,4}
	$(PY) benchmarks/halo_exchange.py --smoke-ring

quant-smoke:                 # bit-packed int2/int4 wire; ledger == bytes
	$(PY) benchmarks/halo_exchange.py --smoke-quant   # transport == analytic

ratectl-smoke:               # closed loop: budget within 5%, error >= uniform
	$(PY) benchmarks/ratectl_budget.py --smoke

ratectl-pl-smoke:            # per-layer: err <= uniform, budget 5%, parity
	$(PY) benchmarks/ratectl_budget.py --per-layer --smoke

partition-smoke:             # out-of-core: RSS-bounded 1e6-node stream,
	$(PY) benchmarks/partition_pipeline.py --smoke   # cut + shard parity

chaos-smoke:                 # faults: ledger exact under drops, resume
	$(PY) benchmarks/chaos_soak.py --smoke           # bitwise, elastic Q-1

serve-smoke:                 # serving SLO: warm p99 <= 0.5x cold, warm
	$(PY) benchmarks/serving_bench.py --smoke        # bits < cold, exactness

docs:                        # intra-repo markdown link check (CI docs job)
	$(PY) scripts/check_links.py

all: floor verify smoke bench-smoke wire-smoke ring-smoke quant-smoke \
     ratectl-smoke ratectl-pl-smoke partition-smoke chaos-smoke \
     serve-smoke docs
