"""Chaos soak: seeded link faults + crash/recovery through the trainer.

Runs 30-step ``train_gnn`` soaks under a deterministic
``repro.dist.faults.FaultSchedule`` at per-step link-drop rates
∈ {0, 5%, 20%} (full-communication policy, p2p wire, Q = 4) and replays
the degradation ladder host-side to predict the ledger exactly: a
dropped pair serves its cached hop (zero wire bits), past ``max_stale``
it goes local-only, so the surviving-hop transport of every run must
equal ``Σ_t 2 · 32 · Σ_e f_e · fresh_rows(t)`` computed from nothing
but the schedule and the halo pair table.

``--smoke`` is the CI acceptance check (ISSUE 8):

* every step of every soak completes (finite losses, zero crashes);
* the 20%-drop final loss is within 10% of the fault-free run;
* each run's realised transport equals the host-replayed analytic
  ledger (≤ 1e-6 relative);
* kill-at-step-15 (``stop_after=15`` checkpoint) + ``resume=True``
  reproduces the uninterrupted run's logged losses and cumulative
  transport **bitwise**;
* a worker-crash event at step 15 (shard-backed run) shrinks the run
  elastically to Q − 1 and keeps training finite — and a post-crash
  checkpoint resumes bitwise at the smaller world size.

Output: ``experiments/bench/chaos_soak.csv`` (schema in
benchmarks/README.md).
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

if __package__ in (None, ""):               # `python benchmarks/...py` direct
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import dataset, save_rows

Q = 4
N = 512
HIDDEN = 256
LAYERS = 2
EPOCHS = 30
SEED = 0
FAULT_SEED = 11
MAX_STALE = 3
BACKOFF_CAP = 8
DROPS = (0.0, 0.05, 0.2)
KILL_AT = 15


def _policy():
    from repro.core import CommPolicy
    return CommPolicy.parse("full", EPOCHS)


def _train(g, sched=None, **kw):
    from repro.train import train_gnn
    return train_gnn(g, q=Q, policy=_policy(), epochs=EPOCHS, hidden=HIDDEN,
                     layers=LAYERS, eval_every=5, wire="p2p", seed=SEED,
                     faults=sched, fault_max_stale=MAX_STALE,
                     fault_backoff_cap=BACKOFF_CAP, **kw)


def _schedule(drop: float, crash_at=()):
    from repro.dist.faults import FaultSchedule
    return FaultSchedule(q=Q, seed=FAULT_SEED, drop_rate=drop,
                         crash_at=tuple(crash_at))


def _replay_transport_bits(sched, meta, widths) -> float:
    """Host replay of the ledger: only FRESH off-diagonal pairs ship
    bits — ``2 × 32 × Σ_e f_e × pair_rows`` per step (forward +
    backward cotangent, fp32 wire at rate 1)."""
    import numpy as np

    from repro.dist.faults import FRESH, degrade_plan, init_degrade

    rows = np.asarray(meta.pair_table(), np.float64)
    off = ~np.eye(meta.q, dtype=bool)
    dst = init_degrade(meta.q)
    total = 0.0
    for t in range(EPOCHS):
        serve, dst = degrade_plan(dst, sched.effective_drops(t), t,
                                  max_stale=MAX_STALE,
                                  backoff_cap=BACKOFF_CAP)
        fresh_rows = float((rows * ((serve == FRESH) & off)).sum())
        total += 2.0 * 32.0 * fresh_rows * float(sum(widths))
    return total


def _widths(g):
    from repro.dist.ratectl import exchange_widths
    from repro.nn import GNNConfig
    cfg = GNNConfig(conv="sage", in_dim=g.feat_dim, hidden=HIDDEN,
                    out_dim=g.num_classes, layers=LAYERS)
    return exchange_widths(cfg)


def _shard_dir(g, td: str) -> str:
    from repro.graph.partition import random_partition
    from repro.graph.stream import write_graph_store, write_shards
    owner = random_partition(g, Q, seed=SEED)
    store = write_graph_store(g, os.path.join(td, "store"))
    write_shards(store, owner, os.path.join(td, "shards"))
    return os.path.join(td, "shards")


def sweep(assert_ok: bool) -> list[dict]:
    import numpy as np

    g = dataset("arxiv", n=N)
    widths = _widths(g)
    rows, base_loss = [], None
    for drop in DROPS:
        sched = _schedule(drop)
        t0 = time.time()
        res = _train(g, sched)
        losses = res.history.loss
        finite = bool(np.all(np.isfinite(losses)))
        measured = res.history.total_transport_gfloats * 32e9
        expect = _replay_transport_bits(sched, res.meta, widths)
        ledger_ok = abs(measured - expect) <= 1e-6 * max(expect, 1.0)
        if drop == 0.0:
            base_loss = losses[-1]
        rel = abs(losses[-1] - base_loss) / max(base_loss, 1e-12)
        rows.append({"drop_rate": drop, "final_loss": losses[-1],
                     "loss_vs_clean": rel, "transport_gbits": measured / 1e9,
                     "analytic_gbits": expect / 1e9,
                     "ledger_ok": int(ledger_ok), "finite": int(finite),
                     "wall_s": time.time() - t0})
        print(f"drop={drop:>4}: loss={losses[-1]:.4f} (vs clean "
              f"{rel:.2%}), transport={measured / 1e9:.3f} Gbit, "
              f"ledger {'OK' if ledger_ok else 'MISMATCH'}")
        if assert_ok:
            assert finite, f"non-finite loss at drop={drop}"
            assert ledger_ok, (f"transport {measured} != analytic replay "
                               f"{expect} at drop={drop}")
            assert rel <= 0.10, (f"20%-drop loss {losses[-1]} deviates "
                                 f"{rel:.1%} from fault-free {base_loss}")
    return rows


def kill_resume(assert_ok: bool) -> dict:
    g = dataset("arxiv", n=N)
    sched = _schedule(0.2)
    with tempfile.TemporaryDirectory() as td:
        _train(g, sched, checkpoint_dir=td, stop_after=KILL_AT)
        resumed = _train(g, sched, checkpoint_dir=td, resume=True)
    full = _train(g, sched)
    n_tail = len(resumed.history.loss)
    tail = full.history.loss[-n_tail:]
    bitwise = resumed.history.loss == tail and \
        resumed.history.transport_gfloats[-1] == \
        full.history.transport_gfloats[-1]
    print(f"kill-at-{KILL_AT} resume: "
          f"{'bitwise' if bitwise else 'DIVERGED'}")
    if assert_ok:
        assert bitwise, (resumed.history.loss, tail)
    return {"leg": "kill_resume", "bitwise": int(bitwise)}


def crash_elastic(assert_ok: bool) -> dict:
    import numpy as np

    g = dataset("arxiv", n=N)
    with tempfile.TemporaryDirectory() as td:
        shards = _shard_dir(g, td)
        sched = _schedule(0.05, crash_at=((KILL_AT, 1),))
        res = _train(shards, sched)
        finite = bool(np.all(np.isfinite(res.history.loss)))
        shrunk = res.meta.q == Q - 1
        # post-crash checkpoint + resume replays the shrink bitwise
        ck = os.path.join(td, "ck")
        _train(shards, sched, checkpoint_dir=ck, stop_after=KILL_AT + 5)
        resumed = _train(shards, sched, checkpoint_dir=ck, resume=True)
        n_tail = len(resumed.history.loss)
        bitwise = resumed.history.loss == res.history.loss[-n_tail:]
    print(f"crash leg: finite={finite} q={res.meta.q} "
          f"post-crash resume {'bitwise' if bitwise else 'DIVERGED'}")
    if assert_ok:
        assert finite and shrunk and bitwise
    return {"leg": "crash_elastic", "finite": int(finite),
            "q_final": res.meta.q, "bitwise": int(bitwise)}


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = sweep(assert_ok=smoke)
    kill_resume(assert_ok=smoke)
    crash_elastic(assert_ok=smoke)
    path = save_rows("chaos_soak", rows)
    print(f"wrote {path}")
    if smoke:
        print("CHAOS_SOAK_SMOKE_OK")


if __name__ == "__main__":
    main()
