"""Shared benchmark utilities."""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")

_DATASETS: dict = {}


def dataset(name: str, n: int, seed: int = 0):
    """Cached synthetic dataset (arxiv-/products-analogue)."""
    from repro.graph import citation_graph, copurchase_graph
    key = (name, n, seed)
    if key not in _DATASETS:
        if name == "arxiv":
            _DATASETS[key] = citation_graph(n=n, seed=seed)
        elif name == "products":
            _DATASETS[key] = copurchase_graph(n=n, seed=seed)
        else:
            raise KeyError(name)
    return _DATASETS[key]


def save_rows(name: str, rows: list[dict]) -> str:
    from repro.train.metrics import write_csv
    path = os.path.join(OUT_DIR, f"{name}.csv")
    write_csv(path, rows)
    return path


class StepTimer:
    """Median wall time per call."""

    def __init__(self):
        self.times = []

    def measure(self, fn, *args, warmup: int = 1, iters: int = 3):
        import jax
        for _ in range(warmup):
            out = fn(*args)
        jax.block_until_ready(out)
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            self.times.append(time.perf_counter() - t0)
        return out

    @property
    def us_per_call(self) -> float:
        return 1e6 * sorted(self.times)[len(self.times) // 2]
