"""Paper Fig. 3 (accuracy vs epoch) + Fig. 5 (accuracy vs floats
communicated), 16 workers, random partitioning — one training sweep feeds
both figures.

Policies: full comm, no comm, fixed {2,4,16}, VARCO slopes {3,5,7}.
"""

from __future__ import annotations

import time

from benchmarks.common import dataset, save_rows


def policies(epochs: int):
    from repro.core import FULL_COMM, NO_COMM, fixed, varco
    return [
        ("full", FULL_COMM),
        ("nocomm", NO_COMM),
        ("fixed2", fixed(2.0)),
        ("fixed4", fixed(4.0)),
        ("fixed16", fixed(16.0)),
        ("varco3", varco(epochs, slope=3)),
        ("varco5", varco(epochs, slope=5)),
    ]


def main(quick: bool = True) -> dict:
    from repro.train import train_gnn

    n = 6000 if quick else 20000
    epochs = 120 if quick else 300
    q = 16
    g = dataset("arxiv", n)
    rows = []
    summary = {}
    t0 = time.time()
    for name, pol in policies(epochs):
        res = train_gnn(g, q=q, scheme="random", policy=pol, epochs=epochs,
                        eval_every=10, hidden=64, weight_decay=1e-3, seed=0)
        h = res.history
        for i in range(len(h.epoch)):
            rows.append({"policy": name, **h.row(i)})
        summary[name] = (h.best_test_acc, h.total_halo_gfloats)
    save_rows("fig3_fig5_accuracy", rows)
    best = {k: round(v[0], 4) for k, v in summary.items()}
    return {"name": "fig3_fig5_accuracy",
            "us_per_call": 1e6 * (time.time() - t0) / (epochs *
                                                       len(summary)),
            "derived": "|".join(f"{k}={v}" for k, v in best.items())}


if __name__ == "__main__":
    print(main())
