"""Paper Fig. 4 + Tables II/III: accuracy vs number of servers for random
and METIS-like partitioning; full / no / VARCO communication."""

from __future__ import annotations

import time

from benchmarks.common import dataset, save_rows


def main(quick: bool = True) -> dict:
    from repro.core import FULL_COMM, NO_COMM, varco
    from repro.train import train_gnn

    n = 6000 if quick else 20000
    epochs = 100 if quick else 300
    qs = [2, 4, 8, 16] if not quick else [2, 8]
    rows = []
    t0 = time.time()
    runs = 0
    for scheme in ("random", "metis-like"):
        for q in qs:
            for name, pol in [("full", FULL_COMM), ("nocomm", NO_COMM),
                              ("varco5", varco(epochs, slope=5))]:
                g = dataset("arxiv", n)
                res = train_gnn(g, q=q, scheme=scheme, policy=pol,
                                epochs=epochs, eval_every=epochs // 4,
                                hidden=64, weight_decay=1e-3, seed=0)
                h = res.history
                rows.append({"scheme": scheme, "q": q, "policy": name,
                             "best_test_acc": round(h.best_test_acc, 4),
                             "final_test_acc": round(h.final_test_acc, 4),
                             "gfloats": round(h.total_halo_gfloats, 3)})
                runs += 1
    save_rows("fig4_tables23_accuracy_vs_servers", rows)

    # the paper's key reads: (i) varco ~ full for every q and scheme,
    # (ii) nocomm degrades with q under random partitioning
    def acc(scheme, q, policy):
        return next(r["best_test_acc"] for r in rows
                    if r["scheme"] == scheme and r["q"] == q and
                    r["policy"] == policy)

    gap16 = acc("random", max(qs), "full") - acc("random", max(qs), "varco5")
    nc_drop = acc("random", qs[0], "nocomm") - acc("random", max(qs),
                                                   "nocomm")
    return {"name": "fig4_accuracy_vs_servers",
            "us_per_call": 1e6 * (time.time() - t0) / (runs * epochs),
            "derived": f"varco_gap_q{max(qs)}={gap16:.4f}"
                       f"|nocomm_drop={nc_drop:.4f}"}


if __name__ == "__main__":
    print(main())
