"""Halo-exchange wire sweep: dense vs packed vs neighbor-only p2p.

The packed wire (DESIGN.md §3.3) shrinks the all-gather payload from
``[B, F]`` to ``[B, K·128]``; the p2p wire (§3.5) replaces the all-gather
itself with a ``ppermute`` ring that ships each peer only its per-pair
halo rows, with the local edges on the ``ell_spmm`` kernel path.  This
sweep *measures* both reductions instead of asserting them — per
(Q, F, rate) on a METIS-like-partitioned synthetic citation graph it
records the analytic point-to-point charge, each wire's transport charge,
the buffer-level collective volume each format physically moves
(all-gather: every worker's padded block × (Q-1) peers; p2p ring: the
padded per-pair hop buffers), and the wall time of one emulated forward
exchange per wire.

``--smoke`` checks the packed acceptance bound
``packed ≤ (1/r + 1/(F/128)) × dense`` for r ∈ {2, 4, 16}, rate-1
training parity of the packed vs dense wire on both backends, and the
direction of the p2p win: transport == analytic ≪ all-gather volume.
``--smoke-ring`` is the CI ring target: emulated-backend p2p checks only —
transport ≈ analytic bits at rates {1, 4}, rate-1 p2p vs dense training
parity, and the p2p-under-all-gather volume direction (~1 min).
``--smoke-quant`` is the CI quantised-wire target (DESIGN.md §3.8): the
fused pack+quantise kernel beats the two-stage pack-then-cast pipeline
wall-clock, and transport at widths {2, 4, 8} equals the analytic
``transport_bits_quant`` charge through a real forward pass (~1 min).

Output: ``experiments/bench/halo_exchange.csv`` (schema in
benchmarks/README.md).
"""

from __future__ import annotations

import os
import subprocess
import sys
import time

if __package__ in (None, ""):               # `python benchmarks/...py` direct
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from benchmarks.common import StepTimer, save_rows

RATES = [1.0, 2.0, 4.0, 16.0]


def _setup(n: int, q: int, f: int, scheme: str = "metis-like"):
    from repro.dist.gnn_parallel import DistMeta
    from repro.dist.halo import attach_p2p
    from repro.graph import partition_graph
    from repro.graph.synthetic import citation_graph
    from repro.nn import GNNConfig, init_gnn

    g = citation_graph(n=n, feat_dim=f, seed=0)
    cfg = GNNConfig(conv="sage", in_dim=f, hidden=128,
                    out_dim=g.num_classes, layers=2)
    params = init_gnn(jax.random.key(0), cfg)
    pg = partition_graph(g, q, scheme=scheme)
    graph = attach_p2p(pg.device_arrays(), pg)
    return (cfg, params, pg, graph,
            DistMeta.build(pg, params),
            DistMeta.build(pg, params, wire="packed"),
            DistMeta.build(pg, params, wire="p2p"))


def _time_exchange(graph, meta, policy, compressor, rate, key) -> float:
    """Median us of one jitted forward aggregation (layer-0 exchange)."""
    from repro.dist.gnn_parallel import _make_aggregate_emulated

    @jax.jit
    def once(x):
        agg = _make_aggregate_emulated(graph, meta, policy, compressor,
                                       rate, key)
        out, bits = agg(0, x)
        return out

    t = StepTimer()
    t.measure(once, graph["features"])
    return t.us_per_call


def main(quick: bool = True) -> dict:
    from repro.core import FULL_COMM, fixed

    n = 2000 if quick else 8000
    qs = [4, 8] if quick else [4, 8, 16]
    fs = [256, 512] if quick else [256, 512, 1024]
    rows = []
    t0 = time.time()
    worst_ratio = 0.0
    worst_p2p = 0.0
    for q in qs:
        for f in fs:
            (cfg, params, pg, graph, meta_d, meta_p,
             meta_r) = _setup(n, q, f)
            for rate in RATES:
                pol = FULL_COMM if rate == 1.0 \
                    else fixed(rate, compressor="blockmask")
                comp = pol.compressor() if pol.compresses else None
                width = meta_p.packed_width(f, rate)
                dense_mb = float(meta_d.transport_bits(f)) / 8e6
                packed_mb = float(meta_p.transport_bits(f, rate)) / 8e6
                p2p_mb = float(meta_r.transport_bits(f, rate)) / 8e6
                ag_mb = meta_p.collective_bits(f, rate) / 8e6
                ring_mb = meta_r.collective_bits(f, rate) / 8e6
                bound = 1.0 / rate + 128.0 / f
                us_d = _time_exchange(graph, meta_d, pol, comp,
                                      jnp.asarray(rate), jax.random.key(1))
                us_p = _time_exchange(graph, meta_p, pol, comp, rate,
                                      jax.random.key(1))
                us_r = _time_exchange(graph, meta_r, pol, comp, rate,
                                      jax.random.key(1))
                ratio = packed_mb / dense_mb
                worst_ratio = max(worst_ratio, ratio - bound)
                worst_p2p = max(worst_p2p, p2p_mb / ag_mb)
                # bytes-on-wire at sub-byte storage widths: conservation
                # (tests/parity.py run_wire_conservation) pins buffer
                # nbytes == ceil(transport bits / 8), so the quantised
                # charge IS the transported byte volume; w=8 is the
                # int8-stored baseline the w<8 rows undercut by ~w/8
                wire_w = {w: float(meta_r.transport_bits_quant(
                    f, rate, w)) / 8e6 for w in (2, 4, 8)}
                rows.append({
                    "q": q, "f": f, "rate": rate, "wire_cols": width,
                    "hop_rows": meta_r.p2p_hop_width,
                    "analytic_mb": round(
                        float(meta_d.ledger_bits(f, rate)) / 8e6, 4),
                    "dense_transport_mb": round(dense_mb, 4),
                    "packed_transport_mb": round(packed_mb, 4),
                    "p2p_transport_mb": round(p2p_mb, 4),
                    "allgather_mb": round(ag_mb, 4),
                    "ring_mb": round(ring_mb, 4),
                    "p2p_over_allgather": round(p2p_mb / ag_mb, 4),
                    "packed_over_dense": round(ratio, 4),
                    "bound": round(bound, 4),
                    "p2p_bytes_w2_mb": round(wire_w[2], 4),
                    "p2p_bytes_w4_mb": round(wire_w[4], 4),
                    "p2p_bytes_int8_mb": round(wire_w[8], 4),
                    "w4_over_int8": round(wire_w[4] / wire_w[8], 4),
                    "dense_us": round(us_d, 1),
                    "packed_us": round(us_p, 1),
                    "p2p_us": round(us_r, 1),
                })
    save_rows("halo_exchange", rows)
    return {"name": "halo_exchange",
            "us_per_call": 1e6 * (time.time() - t0) / max(len(rows), 1),
            "derived": f"rows={len(rows)}|worst_ratio_minus_bound="
                       f"{worst_ratio:.4f}|worst_p2p_over_allgather="
                       f"{worst_p2p:.4f}"}


# ---------------------------------------------------------------------------
# --smoke / --smoke-ring acceptance checks
# ---------------------------------------------------------------------------

_SHARD_PARITY = """
import jax, jax.numpy as jnp
from repro.graph import tiny_graph, partition_graph
from repro.nn import GNNConfig, init_gnn
from repro.dist.gnn_parallel import (DistMeta, make_train_step,
                                     make_worker_mesh, shard_graph)
from repro.dist.halo import attach_p2p
from repro.core import FULL_COMM
from repro.train.optim import sgd

g = tiny_graph(n=256, feat_dim=256)
cfg = GNNConfig(conv='sage', in_dim=256, hidden=128,
                out_dim=g.num_classes, layers=2)
params = init_gnn(jax.random.key(0), cfg)
pg = partition_graph(g, 4, scheme='random')
graph = attach_p2p(pg.device_arrays(), pg)
opt = sgd(1e-2)   # proportional to grads; see _train_parity
mesh = make_worker_mesh(4)
gs = shard_graph(graph, mesh)
outs = []
for wire in ('dense', 'packed', 'p2p'):
    meta = DistMeta.build(pg, params, wire=wire)
    p, s = params, opt.init(params)
    step = make_train_step(cfg, FULL_COMM, opt, meta, mesh=mesh)
    for i in range(3):
        p, s, m = step(p, s, gs, jnp.asarray(i), jax.random.key(i))
    outs.append(p)
d = max(float(jnp.abs(a - b).max())
        for o in outs[1:]
        for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(o)))
assert d < 1e-5, d
print('SHARD_PARITY_OK', d)
"""


def _train_parity(wires, graph, pg, params, atol: float) -> float:
    """Max param diff between rate-1 full-comm training on ``wires``.

    Plain SGD so the comparison stays proportional to the gradient diff —
    adaptive optimizers turn summation-order noise on near-zero gradients
    into ±lr sign flips, which would mask a genuine transport bug.
    """
    from repro.core import FULL_COMM
    from repro.dist.gnn_parallel import DistMeta, make_train_step
    from repro.nn import GNNConfig
    from repro.train.optim import sgd

    cfg = GNNConfig(conv="sage", in_dim=pg.feat_dim, hidden=128,
                    out_dim=pg.num_classes, layers=2)
    opt = sgd(1e-2)
    outs = []
    for wire in wires:
        meta = DistMeta.build(pg, params, wire=wire)
        p, s = params, opt.init(params)
        step = make_train_step(cfg, FULL_COMM, opt, meta)
        for i in range(3):
            p, s, _ = step(p, s, graph, jnp.asarray(i), jax.random.key(i))
        outs.append(p)
    d = max(float(jnp.abs(a - b).max())
            for o in outs[1:]
            for a, b in zip(jax.tree_util.tree_leaves(outs[0]),
                            jax.tree_util.tree_leaves(o)))
    assert d < atol, (wires, d)
    return d


def smoke_ring() -> None:
    """Emulated-backend p2p acceptance (the CI ``ring-smoke`` target)."""
    from repro.core import FULL_COMM, fixed
    from repro.dist.gnn_parallel import DistMeta, make_train_step
    from repro.dist.halo import attach_p2p
    from repro.graph import partition_graph, tiny_graph
    from repro.nn import GNNConfig, init_gnn
    from repro.train.optim import adamw

    # F=512 and hidden=512: every exchanged width is 512, so the lane-block
    # quantisation is exact at rates {1, 4} and transport == analytic holds
    # with equality, not just up to rounding
    g = tiny_graph(n=256, feat_dim=512)
    cfg = GNNConfig(conv="sage", in_dim=512, hidden=512,
                    out_dim=g.num_classes, layers=2)
    params = init_gnn(jax.random.key(0), cfg)
    pg = partition_graph(g, 4, scheme="random")
    graph = attach_p2p(pg.device_arrays(), pg)
    meta = DistMeta.build(pg, params, wire="p2p")
    meta_ag = DistMeta.build(pg, params, wire="packed")
    opt = adamw(5e-3)

    for rate in (1.0, 4.0):
        pol = FULL_COMM if rate == 1.0 \
            else fixed(rate, compressor="blockmask")
        step = make_train_step(cfg, pol, opt, meta)
        _, _, m = step(params, opt.init(params), graph, jnp.asarray(0),
                       jax.random.key(0))
        analytic, transport = float(m["halo_bits"]), float(m["transport_bits"])
        assert abs(transport - analytic) <= 1e-6 * analytic, \
            (rate, transport, analytic)
        # both are fwd+bwd (2×) volumes over the step's two exchanges
        ag = 2.0 * sum(meta_ag.collective_bits(f, rate)
                       for f in (cfg.in_dim, cfg.hidden))
        assert transport < ag, (transport, ag)
        print(f"ring transport ok: r={rate:g} transport==analytic="
              f"{analytic:.0f} bits, all-gather volume {ag:.0f}")

    d = _train_parity(("dense", "p2p"), graph, pg, params, atol=1e-5)
    print(f"emulated p2p rate-1 parity ok: max param diff {d:.2e}")
    print("RING_SMOKE_OK")


def smoke_quant() -> None:
    """Quantised-wire acceptance (DESIGN.md §3.8, the CI ``quant-smoke``
    target): the fused pack+quantise+bit-pack launch stays within noise
    of the staged pack → cast → bit-pack pipeline on the oracle path
    (the strict fusion win is the TPU kernel's claim — one VMEM pass vs
    three HBM round trips), the int4 p2p transport charge equals the
    analytic ``transport_bits_quant`` closed form through a real forward
    pass, and the MEASURED sub-byte hop buffers land at ~w/8 of the
    int8-stored baseline (w=8 bitwise-identical to it)."""
    import numpy as np

    from repro.core import fixed
    from repro.dist.gnn_parallel import (DistMeta, _make_aggregate_emulated,
                                         _packed_pair_k_for)
    from repro.dist.halo import attach_p2p
    from repro.graph import partition_graph, tiny_graph
    from repro.kernels import ops
    from repro.kernels.ops import LANE
    from repro.kernels.varco_pack import block_mask_indices
    from repro.nn import GNNConfig, init_gnn
    from repro.nn.gnn import gnn_forward

    # 1. wall clock: ONE fused dispatch (gather + per-block amax + scale +
    #    int round + bit-pack in a single program) vs the staged
    #    pack -> cast -> bit-pack pipeline that materialises the fp32
    #    packed and int8 level intermediates between dispatches — same
    #    payload out, same shape as the kernel_bench row (n=2048, F=512,
    #    K=4, w=4)
    nq, fq, wq = 2048, 512, 4
    x = jax.random.normal(jax.random.key(0), (nq, fq), jnp.float32)
    kept, inv = block_mask_indices(jax.random.key(1), fq // 128, 1.0)
    pack_stage = jax.jit(lambda a: ops.wire_pack(a, kept, inv))

    def _cast(p):
        kq = p.shape[1] // LANE
        pb = p.reshape(p.shape[0], kq, LANE)
        qmax = float(2 ** (wq - 1) - 1)
        amax = jnp.max(jnp.abs(pb), axis=-1)
        scale = jnp.where(amax > 0, amax / qmax, 1.0)
        qv = jnp.clip(jnp.rint(pb / scale[..., None]), -qmax, qmax)
        return qv.astype(jnp.int8).reshape(p.shape), scale

    cast_stage = jax.jit(_cast)
    bitpack_stage = jax.jit(lambda lv: ops.pack_bits(lv, wq))

    def _staged(a):
        lv, scale = cast_stage(pack_stage(a))
        return bitpack_stage(lv), scale

    for _ in range(3):            # best-of-3: absorb transient CI load
        t_f = StepTimer()
        t_f.measure(lambda a: ops.pack_quant(a, kept, width=wq), x, iters=5)
        t_2 = StepTimer()
        t_2.measure(_staged, x, iters=5)
        if t_f.us_per_call < t_2.us_per_call:
            break
    # no-regression bound: the oracle runs the same jnp either way, so
    # the fused program must not LOSE to the staged dispatches by more
    # than scheduler noise; strict superiority is the TPU kernel's claim
    assert t_f.us_per_call <= 1.25 * t_2.us_per_call, \
        (t_f.us_per_call, t_2.us_per_call)
    print(f"fused pack+quant+bitpack ok: {t_f.us_per_call:.0f}us vs "
          f"staged {t_2.us_per_call:.0f}us "
          f"({t_2.us_per_call / t_f.us_per_call:.2f}x)")

    # 2. int4 transport == analytic: F=512 and hidden=512 (as smoke_ring)
    #    so both exchanges ship 512 lanes and the closed form
    #    ``halo_demand · K · (128·w + 32)`` holds with equality
    g = tiny_graph(n=256, feat_dim=512)
    cfg = GNNConfig(conv="sage", in_dim=512, hidden=512,
                    out_dim=g.num_classes, layers=2)
    params = init_gnn(jax.random.key(0), cfg)
    pg = partition_graph(g, 4, scheme="random")
    graph = attach_p2p(pg.device_arrays(), pg)
    meta = DistMeta.build(pg, params, wire="p2p")
    qn, rate = meta.q, 4.0
    rm = np.full((qn, qn), rate, np.float32)
    np.fill_diagonal(rm, 1.0)

    def forward_bits(width):
        wm = None
        if width is not None:
            wm = np.full((qn, qn), width, np.float32)
            np.fill_diagonal(wm, 32.0)
        agg = _make_aggregate_emulated(
            graph, meta, fixed(rate, compressor="blockmask"), None,
            jnp.ones((), jnp.float32), jax.random.key(2),
            packed_k=dict(_packed_pair_k_for(meta, rm)),
            rate_map=jnp.asarray(rm),
            width_map=None if wm is None else jnp.asarray(wm))
        _, bits = gnn_forward(params, cfg, graph["features"], agg)
        return np.asarray(bits)

    for width in (2, 4, 8):
        bits = forward_bits(width)
        transport = float(bits[2:2 + qn * qn].sum())
        analytic = 2.0 * float(meta.transport_bits_quant(512, rate, width))
        assert abs(transport - analytic) <= 1e-6 * analytic, \
            (width, transport, analytic)
        print(f"quant transport ok: w={width} transport==analytic="
              f"{analytic:.0f} bits ({width / 32:.3f}x payload + scales)")
    # a width-32 map reproduces the unquantised ledger bit-for-bit
    np.testing.assert_array_equal(forward_bits(32), forward_bits(None))
    print("fp32 width map == unquantised ledger (bitwise)")

    # 3. true sub-byte storage (the tentpole): the MEASURED hop buffers —
    #    captured off the wire, not the ledger — at w=4 come in under
    #    0.55x the int8-stored baseline (w=2 under 0.30x), and the w=8
    #    payload is bitwise the int8 levels the pre-packing wire stored
    from repro.dist.gnn_parallel import _packed_store_w
    from repro.kernels import ref

    def forward_wire(width):
        wm = np.full((qn, qn), width, np.float32)
        np.fill_diagonal(wm, 32.0)
        wo = []
        agg = _make_aggregate_emulated(
            graph, meta, fixed(rate, compressor="blockmask"), None,
            jnp.ones((), jnp.float32), jax.random.key(2),
            packed_k=dict(_packed_pair_k_for(meta, rm)),
            rate_map=jnp.asarray(rm), width_map=jnp.asarray(wm),
            store_w=_packed_store_w(meta, wm), wire_out=wo)
        gnn_forward(params, cfg, graph["features"], agg)
        return wo

    def wire_bytes(wo):
        return sum(np.asarray(p).nbytes +
                   (0 if s is None else np.asarray(s).nbytes)
                   for p, s in wo)

    int8_stored = wire_bytes(forward_wire(8))  # one byte per lane + scales
    for width, bound in ((4, 0.55), (2, 0.30)):
        got = wire_bytes(forward_wire(width))
        assert got <= bound * int8_stored, (width, got, int8_stored)
        print(f"sub-byte storage ok: w={width} hop buffers "
              f"{got / int8_stored:.3f}x int8-stored (<= {bound}x)")
    payload8, _ = ops.pack_quant(x, kept, width=8)
    levels8, _ = ref.quant_levels_reference(ref.pack_reference(x, kept), 8)
    np.testing.assert_array_equal(
        np.asarray(payload8),
        np.asarray(jax.lax.bitcast_convert_type(levels8, jnp.uint8)))
    print("w=8 payload bitwise == pre-packing int8 storage")
    print("QUANT_SMOKE_OK")


def smoke() -> None:
    from repro.core import FULL_COMM
    from repro.graph import partition_graph, tiny_graph
    from repro.nn import init_gnn
    from repro.nn.gnn import GNNConfig

    # 1. wire-volume bounds at every (f, rate) the criteria name, plus the
    #    p2p direction: transport == analytic point-to-point charge, below
    #    the all-gather collective volume
    for f in (256, 512, 1024):
        (cfg, params, pg, graph, meta_d, meta_p,
         meta_r) = _setup(1000, 4, f)
        dense = float(meta_d.transport_bits(f))
        for rate in (2.0, 4.0, 16.0):
            packed = float(meta_p.transport_bits(f, rate))
            bound = (1.0 / rate + 128.0 / f) * dense
            assert packed <= bound + 1e-6, (f, rate, packed, bound)
            p2p = float(meta_r.transport_bits(f, rate))
            assert p2p <= packed + 1e-6, (f, rate, p2p, packed)
            ag = meta_p.collective_bits(f, rate)
            assert p2p < ag, (f, rate, p2p, ag)
            print(f"wire volume ok: F={f} r={rate:g}  packed/dense="
                  f"{packed / dense:.3f} <= bound {bound / dense:.3f}  "
                  f"p2p/all-gather={p2p / ag:.3f}")

    # 2. wall-clock direction: one emulated forward exchange, p2p+ELL vs
    #    the all-gather+scatter dense wire at Q ∈ {4, 8} (the win is ~2-3×
    #    at F=512 on CPU, far above timing noise)
    from repro.core import fixed
    for q in (4, 8):
        (cfg, params, pg, graph, meta_d, _,
         meta_r) = _setup(2000, q, 512)
        pol = fixed(4.0, compressor="blockmask")
        comp = pol.compressor()

        def measure():
            us_d = _time_exchange(graph, meta_d, pol, comp,
                                  jnp.asarray(4.0), jax.random.key(1))
            us_r = _time_exchange(graph, meta_r, pol, comp, 4.0,
                                  jax.random.key(1))
            return us_d, us_r

        for _ in range(3):        # best-of-3: absorb transient CI load
            us_d, us_r = measure()
            if us_r < us_d:
                break
        assert us_r < us_d, (q, us_r, us_d)
        print(f"wall clock ok: Q={q} F=512 r=4  p2p {us_r:.0f}us < "
              f"all-gather {us_d:.0f}us ({us_d / us_r:.2f}x)")

    # 3. packed + p2p rate-1 training == dense full comm (emulated backend)
    g = tiny_graph(n=256, feat_dim=256)
    params = init_gnn(jax.random.key(0), GNNConfig(
        conv="sage", in_dim=256, hidden=128, out_dim=g.num_classes,
        layers=2))
    pg = partition_graph(g, 4, scheme="random")
    from repro.dist.halo import attach_p2p
    graph = attach_p2p(pg.device_arrays(), pg)
    d = _train_parity(("dense", "packed", "p2p"), graph, pg, params,
                      atol=1e-5)
    print(f"emulated rate-1 parity ok: max param diff {d:.2e}")

    # 4. same on the shard_map backend (subprocess: 4 virtual devices)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", _SHARD_PARITY], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:{out.stderr}"
    print(f"shard_map rate-1 parity ok: {out.stdout.strip()}")
    print("SMOKE_OK")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    grp = ap.add_mutually_exclusive_group()
    grp.add_argument("--smoke", action="store_true",
                     help="acceptance checks: wire-volume bounds + rate-1 "
                          "training parity on both backends (~2 min)")
    grp.add_argument("--smoke-ring", action="store_true",
                     help="p2p ring acceptance on the emulated backend: "
                          "transport == analytic at rates {1, 4} + rate-1 "
                          "parity (~1 min)")
    grp.add_argument("--smoke-quant", action="store_true",
                     help="quantised-wire acceptance: fused pack+quantise "
                          "beats pack-then-cast wall-clock + int4 transport "
                          "== analytic wire bits (~1 min)")
    grp.add_argument("--full", action="store_true",
                     help="paper-scale sweep (bigger graphs, more Q/F)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    elif args.smoke_ring:
        smoke_ring()
    elif args.smoke_quant:
        smoke_quant()
    else:
        print(main(quick=not args.full))
