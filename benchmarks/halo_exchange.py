"""Halo-exchange wire-volume sweep: dense vs packed transport (rate × Q × F).

The packed wire (DESIGN.md §3.3) is the repo's "make a hot path measurably
faster" step: where the dense collective ships the masked ``[B, F]`` block
no matter the rate, the packed wire ships ``[B, K·128]``.  This sweep
*measures* the reduction instead of asserting it — per (Q, F, rate) it
records the analytic point-to-point charge, the dense and packed transport
charges, the raw collective buffer bytes, and the wall time of one emulated
forward exchange on each wire.

``--smoke`` additionally checks the acceptance bound
``packed ≤ (1/r + 1/(F/128)) × dense`` for r ∈ {2, 4, 16} and runs a rate-1
training-parity check of the packed vs dense wire on both backends
(emulated inline, shard_map in a 4-virtual-device subprocess).

Output: ``experiments/bench/halo_exchange.csv`` (schema in
benchmarks/README.md).
"""

from __future__ import annotations

import os
import subprocess
import sys
import time

if __package__ in (None, ""):               # `python benchmarks/...py` direct
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from benchmarks.common import StepTimer, save_rows

RATES = [1.0, 2.0, 4.0, 16.0]


def _setup(n: int, q: int, f: int):
    from repro.dist.gnn_parallel import DistMeta
    from repro.graph import partition_graph
    from repro.graph.synthetic import citation_graph
    from repro.nn import GNNConfig, init_gnn

    g = citation_graph(n=n, feat_dim=f, seed=0)
    cfg = GNNConfig(conv="sage", in_dim=f, hidden=128,
                    out_dim=g.num_classes, layers=2)
    params = init_gnn(jax.random.key(0), cfg)
    pg = partition_graph(g, q, scheme="random")
    graph = pg.device_arrays()
    return (cfg, params, pg, graph,
            DistMeta.build(pg, params),
            DistMeta.build(pg, params, wire="packed"))


def _time_exchange(graph, meta, policy, compressor, rate, key) -> float:
    """Median us of one jitted forward aggregation (layer-0 exchange)."""
    from repro.dist.gnn_parallel import _make_aggregate_emulated

    @jax.jit
    def once(x):
        agg = _make_aggregate_emulated(graph, meta, policy, compressor,
                                       rate, key)
        out, bits = agg(0, x)
        return out

    t = StepTimer()
    t.measure(once, graph["features"])
    return t.us_per_call


def main(quick: bool = True) -> dict:
    from repro.core import FULL_COMM, fixed

    n = 2000 if quick else 8000
    qs = [4] if quick else [4, 8, 16]
    fs = [256, 512] if quick else [256, 512, 1024]
    rows = []
    t0 = time.time()
    worst_ratio = 0.0
    for q in qs:
        for f in fs:
            cfg, params, pg, graph, meta_d, meta_p = _setup(n, q, f)
            for rate in RATES:
                pol = FULL_COMM if rate == 1.0 \
                    else fixed(rate, compressor="blockmask")
                comp = pol.compressor() if pol.compresses else None
                width = meta_p.packed_width(f, rate)
                dense_mb = float(meta_d.transport_bits(f)) / 8e6
                packed_mb = float(meta_p.transport_bits(f, rate)) / 8e6
                bound = 1.0 / rate + 128.0 / f
                us_d = _time_exchange(graph, meta_d, pol, comp,
                                      jnp.asarray(rate), jax.random.key(1))
                us_p = _time_exchange(graph, meta_p, pol, comp, rate,
                                      jax.random.key(1))
                ratio = packed_mb / dense_mb
                worst_ratio = max(worst_ratio, ratio - bound)
                rows.append({
                    "q": q, "f": f, "rate": rate, "wire_cols": width,
                    "analytic_mb": round(
                        float(meta_d.ledger_bits(f, rate)) / 8e6, 4),
                    "dense_transport_mb": round(dense_mb, 4),
                    "packed_transport_mb": round(packed_mb, 4),
                    "dense_buffer_mb": round(
                        graph["send_idx"].size * f * 4 / 1e6, 4),
                    "packed_buffer_mb": round(
                        graph["send_idx"].size * width * 4 / 1e6, 4),
                    "packed_over_dense": round(ratio, 4),
                    "bound": round(bound, 4),
                    "dense_us": round(us_d, 1),
                    "packed_us": round(us_p, 1),
                })
    save_rows("halo_exchange", rows)
    return {"name": "halo_exchange",
            "us_per_call": 1e6 * (time.time() - t0) / max(len(rows), 1),
            "derived": f"rows={len(rows)}|worst_ratio_minus_bound="
                       f"{worst_ratio:.4f}"}


# ---------------------------------------------------------------------------
# --smoke acceptance checks
# ---------------------------------------------------------------------------

_SHARD_PARITY = """
import jax, jax.numpy as jnp
from repro.graph import tiny_graph, partition_graph
from repro.nn import GNNConfig, init_gnn
from repro.dist.gnn_parallel import (DistMeta, make_train_step,
                                     make_worker_mesh, shard_graph)
from repro.core import FULL_COMM
from repro.train.optim import adamw

g = tiny_graph(n=256, feat_dim=256)
cfg = GNNConfig(conv='sage', in_dim=256, hidden=128,
                out_dim=g.num_classes, layers=2)
params = init_gnn(jax.random.key(0), cfg)
pg = partition_graph(g, 4, scheme='random')
graph = pg.device_arrays()
opt = adamw(1e-2)
mesh = make_worker_mesh(4)
gs = shard_graph(graph, mesh)
outs = []
for wire in ('dense', 'packed'):
    meta = DistMeta.build(pg, params, wire=wire)
    p, s = params, opt.init(params)
    step = make_train_step(cfg, FULL_COMM, opt, meta, mesh=mesh)
    for i in range(3):
        p, s, m = step(p, s, gs, jnp.asarray(i), jax.random.key(i))
    outs.append(p)
d = max(float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])))
assert d < 1e-5, d
print('SHARD_PARITY_OK', d)
"""


def smoke() -> None:
    from repro.core import FULL_COMM
    from repro.dist.gnn_parallel import DistMeta, make_train_step
    from repro.graph import partition_graph, tiny_graph
    from repro.nn import GNNConfig, init_gnn
    from repro.train.optim import adamw

    # 1. wire-volume bound at every (f, rate) the criteria name
    for f in (256, 512, 1024):
        cfg, params, pg, graph, meta_d, meta_p = _setup(1000, 4, f)
        dense = float(meta_d.transport_bits(f))
        for rate in (2.0, 4.0, 16.0):
            packed = float(meta_p.transport_bits(f, rate))
            bound = (1.0 / rate + 128.0 / f) * dense
            assert packed <= bound + 1e-6, (f, rate, packed, bound)
            print(f"wire volume ok: F={f} r={rate:g}  packed/dense="
                  f"{packed / dense:.3f} <= bound {bound / dense:.3f}")

    # 2. packed rate-1 training == dense full comm (emulated backend)
    g = tiny_graph(n=256, feat_dim=256)
    cfg = GNNConfig(conv="sage", in_dim=256, hidden=128,
                    out_dim=g.num_classes, layers=2)
    params = init_gnn(jax.random.key(0), cfg)
    pg = partition_graph(g, 4, scheme="random")
    graph = pg.device_arrays()
    opt = adamw(1e-2)
    outs = []
    for wire in ("dense", "packed"):
        meta = DistMeta.build(pg, params, wire=wire)
        p, s = params, opt.init(params)
        step = make_train_step(cfg, FULL_COMM, opt, meta)
        for i in range(3):
            p, s, _ = step(p, s, graph, jnp.asarray(i), jax.random.key(i))
        outs.append(p)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree_util.tree_leaves(outs[0]),
                            jax.tree_util.tree_leaves(outs[1])))
    assert d < 1e-5, d
    print(f"emulated rate-1 parity ok: max param diff {d:.2e}")

    # 3. same on the shard_map backend (subprocess: 4 virtual devices)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", _SHARD_PARITY], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:{out.stderr}"
    print(f"shard_map rate-1 parity ok: {out.stdout.strip()}")
    print("SMOKE_OK")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    grp = ap.add_mutually_exclusive_group()
    grp.add_argument("--smoke", action="store_true",
                     help="acceptance checks: wire-volume bound + rate-1 "
                          "training parity on both backends (~2 min)")
    grp.add_argument("--full", action="store_true",
                     help="paper-scale sweep (bigger graphs, more Q/F)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        print(main(quick=not args.full))
