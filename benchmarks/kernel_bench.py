"""Kernel microbenches: Pallas kernels vs pure-jnp oracles.

On this CPU container the kernels run in interpret mode (Python) — the
*correctness* delta is the meaningful number; wall time is reported for the
jnp reference path, which is what XLA:CPU executes."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import StepTimer, save_rows


def main(quick: bool = True) -> dict:
    from repro.kernels import ops, ref
    from repro.kernels.varco_pack import block_mask_indices

    rng = np.random.default_rng(0)
    rows = []
    t0 = time.time()

    # flash attention
    b, h, kv, s, d = (1, 4, 2, 512, 64) if quick else (2, 8, 4, 2048, 128)
    q = jnp.asarray(rng.normal(0, 1, (b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, kv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, kv, s, d)), jnp.float32)
    t_ref = StepTimer()
    refo = t_ref.measure(jax.jit(lambda a, b_, c: ref.mha_reference(
        a, b_, c, causal=True)), q, k, v)
    kout = ops.mha(q, k, v, causal=True, interpret=True)
    err = float(jnp.abs(kout - refo).max())
    rows.append({"kernel": "flash_attention", "shape": f"{b}x{h}x{s}x{d}",
                 "ref_us": round(t_ref.us_per_call, 1), "fused_us": "",
                 "max_err": err})

    # varco pack/unpack round trip
    n, f = (512, 1024) if quick else (4096, 4096)
    x = jnp.asarray(rng.normal(0, 1, (n, f)), jnp.float32)
    kept, inv = block_mask_indices(jax.random.key(0), f // 128, 4.0)
    t_ref = StepTimer()
    t_ref.measure(jax.jit(lambda a: ref.unpack_reference(
        ref.pack_reference(a, kept), inv)), x)
    xt, _ = ops.compress_roundtrip(jax.random.key(0), x, 4.0, interpret=True)
    expect = ref.unpack_reference(ref.pack_reference(x, kept), inv)
    rows.append({"kernel": "varco_pack", "shape": f"{n}x{f}",
                 "ref_us": round(t_ref.us_per_call, 1), "fused_us": "",
                 "max_err": float(jnp.abs(xt - expect).max())})

    # fused pack+quantise+bit-pack vs staged pipeline (DESIGN.md §3.8):
    # ONE compiled program (the Pallas kernel computes the gather, the
    # per-block amax, the scale, the int round and the sub-byte bit-pack
    # in a single VMEM pass; XLA:CPU fuses the same graph) against three
    # separately-dispatched stages materialising the fp32 packed and the
    # int8 level intermediates in between.  ref_us is the staged
    # pipeline, fused_us the single launch; wire_bytes the payload the
    # exchange actually ships (~w/8 of the int8-per-lane storage).
    nq, fq, wq = (2048, 512, 4)
    xq = jnp.asarray(rng.normal(0, 1, (nq, fq)), jnp.float32)
    keptq, invq = block_mask_indices(jax.random.key(1), fq // 128, 1.0)
    t_fused = StepTimer()
    pk_f, sc_f = t_fused.measure(
        lambda a: ops.pack_quant(a, keptq, width=wq), xq, iters=5)

    pack_stage = jax.jit(lambda a: ops.wire_pack(a, keptq, invq))

    def _cast(p):
        kq = p.shape[1] // 128
        pb = p.reshape(p.shape[0], kq, 128)
        qmax = float(2 ** (wq - 1) - 1)
        amax = jnp.max(jnp.abs(pb), axis=-1)
        scale = jnp.where(amax > 0, amax / qmax, 1.0)
        qv = jnp.clip(jnp.rint(pb / scale[..., None]), -qmax, qmax)
        return qv.astype(jnp.int8).reshape(p.shape), scale

    cast_stage = jax.jit(_cast)
    bitpack_stage = jax.jit(lambda lv: ops.pack_bits(lv, wq))
    t_two = StepTimer()
    pk_2, sc_2 = t_two.measure(
        lambda a: (lambda lv_sc: (bitpack_stage(lv_sc[0]), lv_sc[1]))(
            cast_stage(pack_stage(a))), xq, iters=5)
    # decode the sub-byte payloads before comparing values
    quant_err = float(jnp.abs(
        ref.unpack_quant_reference(pk_f, sc_f, wq) -
        ref.unpack_quant_reference(pk_2, sc_2, wq)).max())
    speedup = t_two.us_per_call / max(t_fused.us_per_call, 1e-9)
    int8_bytes = pk_f.shape[0] * keptq.shape[0] * 128
    rows.append({"kernel": "pack_quant_fused",
                 "shape": f"{nq}x{fq}@w{wq} {speedup:.2f}x "
                          f"wire={pk_f.nbytes}B/int8={int8_bytes}B",
                 "ref_us": round(t_two.us_per_call, 1),
                 "fused_us": round(t_fused.us_per_call, 1),
                 "max_err": quant_err})

    # packed wire path (runtime integration: wire_pack -> wire_unpack with
    # custom VJP; Pallas on TPU, ref oracle here).  max_err compares against
    # the dense blockmask round trip the packed exchange must match bitwise;
    # the shape column records the on-wire width reduction.
    from repro.core.compression import get_compressor
    t_ref = StepTimer()
    wired = t_ref.measure(jax.jit(lambda a: ops.wire_unpack(
        ops.wire_pack(a, kept, inv), kept, inv)), x)
    dense, _ = get_compressor("blockmask")(jax.random.key(0), x, 4.0)
    rows.append({"kernel": "wire_pack+unpack",
                 "shape": f"{n}x{f}->wire {n}x{kept.shape[0] * 128}",
                 "ref_us": round(t_ref.us_per_call, 1), "fused_us": "",
                 "max_err": float(jnp.abs(wired - dense).max())})

    # ell spmm
    ns, nd, kk, ff = (2048, 512, 16, 256) if quick else (16384, 4096, 32, 512)
    xs = jnp.asarray(rng.normal(0, 1, (ns, ff)), jnp.float32)
    nbr = jnp.asarray(rng.integers(0, ns, (nd, kk)), jnp.int32)
    w = jnp.asarray(rng.normal(0, 1, (nd, kk)), jnp.float32)
    t_ref = StepTimer()
    refa = t_ref.measure(jax.jit(ref.ell_spmm_reference), xs, nbr, w)
    agg = ops.aggregate(xs, nbr, w, interpret=True)
    rows.append({"kernel": "ell_spmm", "shape": f"{ns}->{nd}x{kk}x{ff}",
                 "ref_us": round(t_ref.us_per_call, 1), "fused_us": "",
                 "max_err": float(jnp.abs(agg - refa).max())})

    save_rows("kernel_bench", rows)
    worst = max(r["max_err"] for r in rows)
    return {"name": "kernel_bench",
            "us_per_call": 1e6 * (time.time() - t0) / len(rows),
            "derived": f"worst_err={worst:.2e}"}


if __name__ == "__main__":
    print(main())
