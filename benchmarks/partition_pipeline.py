"""Out-of-core streaming partition pipeline (DESIGN.md §3.9).

Sweep mode measures the full ingestion chain — streaming generator →
external-sort spill → multilevel `stream_partition` → per-worker shard
write — per (n, q): wall time per stage, edge cut vs the random
baseline, balance, and the subprocess peak RSS (`VmHWM`), so the
headline "never materialises the graph" claim is a measured number, not
a docstring.

``--smoke`` is the CI ``partition-smoke`` acceptance (~3 min):

1. a fresh numpy-only subprocess streams a 10⁶-node SBM graph to Q=16
   shards under a fixed peak-RSS budget (asserted well below the
   full-graph in-memory footprint), with the multilevel cut at most
   0.75× the expected random cut and balance within slack;
2. on an in-memory-sized citation graph the exact path must equal
   `metis_like_partition` bitwise and the *forced* multilevel path must
   land within 1.1× of its cut;
3. a Q=16 shard-backed forward conformance leg through the shared
   parity harness (emulated ≡ shard_map ≤ 1e-6, mixed rate × width).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

if __package__ in (None, ""):               # `python benchmarks/...py` direct
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import save_rows

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# Fixed acceptance budget for the 10⁶-node smoke probe.  The full-graph
# footprint (features + CSR + shard stacks, reported by the probe) is
# ~0.5 GB; the streaming pipeline must stay comfortably below it even
# counting the python+numpy baseline RSS.
SMOKE_N = 1_000_000
SMOKE_Q = 16
RSS_BUDGET_MB = 520.0

# The probe runs in a fresh interpreter so VmHWM reflects ONLY the
# streaming pipeline (numpy-only imports — `repro.graph` pulls no jax).
_PROBE = r"""
import json, os, sys, tempfile, time
sys.path.insert(0, sys.argv[1])
import numpy as np
from repro.graph.stream import (stream_edge_cut, stream_partition,
                                write_shards)
from repro.graph.synthetic import stream_sbm_graph

n, q, workdir = int(sys.argv[2]), int(sys.argv[3]), sys.argv[4]
t0 = time.time()
store = stream_sbm_graph(os.path.join(workdir, "store"), n=n,
                         feat_dim=64, avg_degree=8.0)
t1 = time.time()
owner = stream_partition(store, q, scheme="metis-like", seed=0)
t2 = time.time()
cut = stream_edge_cut(store, owner)
shard_dir = write_shards(store, owner, os.path.join(workdir, "shards"))
t3 = time.time()
sizes = np.bincount(owner, minlength=q)
# what loading + partitioning this graph in memory would cost: features,
# CSR, labels/masks, plus the [Q, ...] padded shard stacks (f32/i32)
part = int(sizes.max())
full_mb = (n * store.feat_dim * 4 + store.num_edges * (4 + 8)
           + n * (4 + 3) + q * part * (store.feat_dim + 8) * 4) / 2**20
with open("/proc/self/status") as fh:
    hwm = next(int(l.split()[1]) for l in fh if l.startswith("VmHWM"))
print(json.dumps({
    "n": n, "q": q, "edges": store.num_edges,
    "gen_s": round(t1 - t0, 2), "part_s": round(t2 - t1, 2),
    "shard_s": round(t3 - t2, 2), "cross_frac": round(cut["cross_frac"], 4),
    "balance": round(float(sizes.max()) * q / n, 4),
    "vmhwm_mb": round(hwm / 1024.0, 1), "full_mb": round(full_mb, 1)}))
"""


def _probe(n: int, q: int) -> dict:
    """Stream gen→partition→shards in a fresh interpreter; return its
    stage timings, cut, balance, and peak RSS."""
    with tempfile.TemporaryDirectory(prefix="ppipe") as td:
        out = subprocess.run(
            [sys.executable, "-c", _PROBE, SRC, str(n), str(q), td],
            capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(quick: bool = True) -> dict:
    sizes = [100_000, 400_000] if quick else [1_000_000, 4_000_000]
    rows = []
    for n in sizes:
        for q in (4, 16):
            r = _probe(n, q)
            r["random_cross"] = round((q - 1) / q, 4)
            rows.append(r)
    save_rows("partition_pipeline", rows)
    last = rows[-1]
    return {"name": "partition_pipeline",
            "us_per_call": 1e6 * sum(r["gen_s"] + r["part_s"] +
                                     r["shard_s"] for r in rows) / len(rows),
            "derived": f"n={last['n']}|q={last['q']}"
                       f"|cross={last['cross_frac']}"
                       f"|rss={last['vmhwm_mb']}MB"
                       f"|full={last['full_mb']}MB"}


def smoke() -> None:
    import numpy as np

    # 1. bounded-memory scale probe: 10⁶ nodes → Q=16 shards
    t0 = time.time()
    r = _probe(SMOKE_N, SMOKE_Q)
    print(f"scale probe: {r}  ({time.time() - t0:.0f}s)")
    assert r["vmhwm_mb"] <= RSS_BUDGET_MB, \
        f"peak RSS {r['vmhwm_mb']} MB over the {RSS_BUDGET_MB} MB budget"
    assert RSS_BUDGET_MB < 0.85 * r["full_mb"], \
        f"budget no longer below the full-graph footprint {r['full_mb']} MB"
    # SBM class members scatter over the whole id space (affine perm),
    # so the only exploitable locality is the class structure itself;
    # the multilevel cut lands ~0.67x the random expectation there
    exp_random = (SMOKE_Q - 1) / SMOKE_Q
    assert r["cross_frac"] <= 0.75 * exp_random, \
        f"cut {r['cross_frac']} not below 0.75x the random {exp_random}"
    assert r["balance"] <= 1.06, f"imbalance {r['balance']}"

    # 2. cut quality against the in-memory partitioner (fits in core)
    from repro.graph import citation_graph, edge_cut_stats
    from repro.graph.partition import metis_like_partition
    from repro.graph.stream import (stream_edge_cut, stream_partition,
                                    write_graph_store)
    g = citation_graph(n=20000, seed=0)
    ref = edge_cut_stats(g, metis_like_partition(g, 8, seed=0))
    with tempfile.TemporaryDirectory(prefix="ppipe") as td:
        store = write_graph_store(g, os.path.join(td, "s"))
        exact = stream_partition(store, 8, scheme="metis-like", seed=0)
        np.testing.assert_array_equal(
            exact, metis_like_partition(g, 8, seed=0),
            err_msg="exact path diverged from the in-memory partitioner")
        forced = stream_partition(store, 8, scheme="metis-like", seed=0,
                                  in_core_nodes=0, coarsen_target=4000,
                                  refine_max_nodes=25000)
        cut = stream_edge_cut(store, forced)["cross_frac"]
    print(f"cut quality: multilevel={cut:.4f} in-memory="
          f"{ref['cross_frac']:.4f}")
    assert cut <= 1.1 * ref["cross_frac"], (cut, ref["cross_frac"])

    # 3. Q=16 shard-backed conformance: emulated ≡ shard_map ≤ 1e-6
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "tests"))
    from parity import run_forward_parity
    out = run_forward_parity(16, [
        {"wire": "p2p", "policy": "full", "map": None},
        {"wire": "packed", "policy": "fixed:4", "map": "pair",
         "width_map": "pair", "seed": 36},
    ], f=128, n=512, shards=True)
    print(out.strip())
    assert out.count(" OK ") == 2, out
    print("PARTITION_SMOKE_OK")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    grp = ap.add_mutually_exclusive_group()
    grp.add_argument("--smoke", action="store_true",
                     help="CI acceptance: RSS-bounded 1e6-node probe, "
                          "cut quality, shard-backed Q=16 parity")
    grp.add_argument("--full", action="store_true",
                     help="paper-scale sweep sizes")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        print(main(quick=not args.full))
