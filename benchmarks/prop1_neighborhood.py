"""Proposition 1: fixed compression converges to an ε(r)-sized gradient
neighbourhood — measure the stationary full-comm gradient norm vs rate."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import save_rows


def main(quick: bool = True) -> dict:
    from repro.core import FULL_COMM, fixed
    from repro.dist.gnn_parallel import (DistMeta, _local_loss_fn,
                                         _make_aggregate_emulated,
                                         make_train_step)
    from repro.graph import partition_graph, tiny_graph
    from repro.nn import GNNConfig, init_gnn
    from repro.train.optim import adamw, global_norm

    g = tiny_graph(n=512 if quick else 2048, seed=1)
    cfg = GNNConfig(conv="sage", in_dim=g.feat_dim, hidden=32,
                    out_dim=g.num_classes, layers=3)
    pg = partition_graph(g, 8, scheme="random")
    graph = pg.device_arrays()
    epochs = 120 if quick else 400

    rows = []
    t0 = time.time()
    for rate in [1.0, 4.0, 16.0, 64.0, 128.0]:
        params = init_gnn(jax.random.key(0), cfg)
        meta = DistMeta.build(pg, params)
        opt = adamw(5e-3)
        s = opt.init(params)
        pol = FULL_COMM if rate == 1.0 else fixed(rate)
        step = make_train_step(cfg, pol, opt, meta)
        p = params
        for i in range(epochs):
            p, s, m = step(p, s, graph, jnp.asarray(i), jax.random.key(i))
        agg = _make_aggregate_emulated(graph, meta, FULL_COMM, None,
                                       jnp.ones(()), jax.random.key(0))
        grads = jax.grad(lambda q_: _local_loss_fn(
            q_, cfg, graph, agg, meta, psum=False)[0])(p)
        gn = float(global_norm(grads))
        eps2 = float(pol.compressor().eps2(rate)) if rate > 1 else 0.0
        rows.append({"rate": rate, "eps2": round(eps2, 4),
                     "final_loss": round(float(m["loss"]), 5),
                     "grad_norm": gn})
    save_rows("prop1_neighborhood", rows)
    mono = all(a["grad_norm"] <= b["grad_norm"] * 1.5
               for a, b in zip(rows, rows[1:]))
    return {"name": "prop1_neighborhood",
            "us_per_call": 1e6 * (time.time() - t0) / (5 * epochs),
            "derived": f"grad_norms={[round(r['grad_norm'], 4) for r in rows]}"
                       f"|monotone~{mono}"}


if __name__ == "__main__":
    print(main())
