"""Fig.-5 frontier, closed loop: accuracy vs wire budget per controller.

The paper's headline (Fig. 5) plots accuracy against communicated floats
for the *open-loop* eq.-(8) schedule.  This sweep reproduces that
frontier for the closed-loop controllers of ``repro.dist.ratectl``: per
budget fraction ``B = frac × full-comm transport`` it trains the same
partitioned graph under

* ``uniform`` — the fixed-rate baseline whose rate is chosen to land on
  the budget (the paper's Fixed Comp Rate point),
* ``budget``  — the PI controller told ``auto:budget:<B>``,
* ``error``   — the per-pair water-filling controller ``auto:error:<B>``,

plus one open-loop ``varco:linear:5`` run (its own measured transport is
its x-coordinate), all on the p2p wire.  Per row it records the budget,
the transport actually shipped (and its fraction of budget), and
final/best test accuracy.

``--smoke`` is the CI acceptance check (~3 min): the ``budget``
controller's accumulated transport must land within 5% of the requested
bits; the ``error`` controller's accuracy at the uniform baseline's
measured budget must be at least the baseline's; the int4 rate × width
frontier (``auto:error:<B>:w4``, DESIGN.md §3.8) must drop no more
block energy than fp32 subset-dropping at equal budget; the realised
ledger transport must equal the analytic ``transport_bits_quant`` at
every wire width; and emulated ≡ shard_map at mixed ``[L, Q, Q]``
rate × width maps.

``--per-layer`` (DESIGN.md §3.7) adds the per-layer frontier: the same
controllers told ``auto:<controller>:<B>:per-layer`` plan ``[L, Q, Q]``
rate tensors, water-filling each step's allowance across layers by
measured dropped energy.  With ``--smoke`` it asserts the per-layer
acceptance triple: (i) per-layer cumulative compression error ≤ the
uniform-layer controller's at equal bit budget, (ii) budget adherence
within 5%, (iii) emulated ≡ shard_map ≤ 1e-6 at mixed ``[L, Q, Q]``
rates (subprocess, 4 virtual devices).

Output: ``experiments/bench/ratectl_budget.csv`` (schema in
benchmarks/README.md).
"""

from __future__ import annotations

import os
import sys
import time

if __package__ in (None, ""):               # `python benchmarks/...py` direct
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import save_rows

# the budget/error controllers need kept-block headroom: F=512 → nb=4
F = 512
LAYERS = 2
Q = 4
SCHEME = "metis-like"


def _train(g, policy_spec: str, epochs: int, wire: str = "p2p",
           compressor: str | None = None):
    from repro.core import CommPolicy
    from repro.train import train_gnn

    policy = CommPolicy.parse(policy_spec, epochs, compressor=compressor)
    res = train_gnn(g, q=Q, scheme=SCHEME, policy=policy, epochs=epochs,
                    hidden=F, layers=LAYERS, eval_every=10, wire=wire)
    transport_bits = res.history.total_transport_gfloats * 32e9
    return res, transport_bits


def _full_step_bits(g) -> float:
    """Analytic full-communication transport of one train step (the same
    model the controllers pace against: ``exchange_widths``)."""
    import jax

    from repro.dist.gnn_parallel import DistMeta
    from repro.dist.ratectl import exchange_widths
    from repro.graph import partition_graph
    from repro.nn import GNNConfig, init_gnn

    cfg = GNNConfig(conv="sage", in_dim=F, hidden=F,
                    out_dim=g.num_classes, layers=LAYERS)
    pg = partition_graph(g, Q, scheme=SCHEME)
    meta = DistMeta.build(pg, init_gnn(jax.random.key(0), cfg), wire="p2p")
    return 2.0 * 32.0 * meta.halo_demand * sum(exchange_widths(cfg))


def main(quick: bool = True, per_layer: bool = False) -> dict:
    from repro.graph.synthetic import citation_graph

    n = 1200 if quick else 6000
    epochs = 30 if quick else 120
    fracs = [0.3, 0.5] if quick else [0.2, 0.35, 0.5, 0.75]
    g = citation_graph(n=n, feat_dim=F, seed=0)
    d_full = _full_step_bits(g)
    rows = []
    t0 = time.time()
    worst_budget_err = 0.0
    specs = ["budget", "error"]
    if per_layer:
        specs += ["budget:per-layer", "error:per-layer"]
    for frac in fracs:
        budget = frac * d_full * epochs
        # uniform fixed-rate baseline aimed at the budget
        res_u, t_u = _train(g, f"fixed:{1.0 / frac:g}", epochs,
                            compressor="blockmask")
        rows.append({"policy": "uniform", "budget_bits": budget,
                     "transport_bits": t_u, "of_budget": t_u / budget,
                     "final_acc": res_u.history.final_test_acc,
                     "best_acc": res_u.history.best_test_acc,
                     "comp_err": ""})
        for spec in specs:
            ctl, _, suffix = spec.partition(":")
            res, t = _train(g, f"auto:{ctl}:{budget:g}"
                            f"{':' + suffix if suffix else ''}", epochs)
            if ctl == "budget" and not suffix:
                worst_budget_err = max(worst_budget_err,
                                       abs(t - budget) / budget)
            h = res.history
            rows.append({"policy": spec.replace(":", "-"),
                         "budget_bits": budget,
                         "transport_bits": t, "of_budget": t / budget,
                         "final_acc": h.final_test_acc,
                         "best_acc": h.best_test_acc,
                         "comp_err": h.comp_err[-1] if h.comp_err else ""})
    res_o, t_o = _train(g, "varco:linear:5", epochs,
                        compressor="blockmask")
    rows.append({"policy": "open-loop", "budget_bits": t_o,
                 "transport_bits": t_o, "of_budget": 1.0,
                 "final_acc": res_o.history.final_test_acc,
                 "best_acc": res_o.history.best_test_acc,
                 "comp_err": ""})
    save_rows("ratectl_budget", rows)
    return {"name": "ratectl_budget",
            "us_per_call": 1e6 * (time.time() - t0) / max(len(rows), 1),
            "derived": f"rows={len(rows)}|worst_budget_err="
                       f"{worst_budget_err:.4f}"}


def smoke() -> None:
    """Acceptance: budget adherence within 5%, error >= uniform accuracy."""
    from repro.graph.synthetic import citation_graph

    epochs = 40
    g = citation_graph(n=1200, feat_dim=F, seed=0)

    # the uniform fixed-rate baseline's measured transport IS the budget,
    # so the closed-loop runs compete at exactly equal wire spend
    res_u, budget = _train(g, "fixed:2", epochs, compressor="blockmask")
    acc_u = res_u.history.final_test_acc
    print(f"uniform fixed:2  transport={budget:.4g} bits  acc={acc_u:.4f}")

    res_b, t_b = _train(g, f"auto:budget:{budget:g}", epochs)
    err = abs(t_b - budget) / budget
    print(f"budget controller  spent/budget={t_b / budget:.4f}  "
          f"acc={res_b.history.final_test_acc:.4f}")
    assert err <= 0.05, (
        f"budget controller missed the bit budget by {100 * err:.1f}% "
        f"(> 5%): shipped {t_b:.4g} of {budget:.4g}")

    res_e, t_e = _train(g, f"auto:error:{budget:g}", epochs)
    acc_e = res_e.history.final_test_acc
    print(f"error controller   spent/budget={t_e / budget:.4f}  "
          f"acc={acc_e:.4f}")
    assert t_e <= 1.05 * budget, (t_e, budget)
    assert acc_e + 1e-6 >= acc_u, (
        f"error controller accuracy {acc_e:.4f} fell below the uniform "
        f"baseline {acc_u:.4f} at equal budget")

    # int4 rate × width frontier (DESIGN.md §3.8): at the SAME wire-bit
    # budget, spending it on int4 payloads buys ~8× the kept lane-blocks,
    # so the cumulative dropped-block energy must not exceed the fp32
    # subset-dropping controller's
    res_q, t_q = _train(g, f"auto:error:{budget:g}:w4", epochs)
    err_fp32 = res_e.history.comp_err[-1]
    err_int4 = res_q.history.comp_err[-1]
    print(f"error ctl @ w4     spent/budget={t_q / budget:.4f}  "
          f"acc={res_q.history.final_test_acc:.4f}  dropped energy "
          f"{err_int4:.4g} vs fp32 {err_fp32:.4g}")
    assert t_q <= 1.05 * budget, (t_q, budget)
    assert err_int4 <= err_fp32 * (1.0 + 1e-6), (
        f"int4 rate×width dropped MORE energy than fp32 subset-dropping "
        f"at equal budget: {err_int4:.6g} > {err_fp32:.6g}")

    # ledger transport = analytic wire bits at EVERY width: one forward
    # pass per width on the partitioned benchmark graph, realised
    # per-pair ledger charges against the transport_bits_quant closed
    # form (w=32 must reproduce the unquantised ledger exactly)
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import fixed
    from repro.dist.gnn_parallel import (DistMeta, _make_aggregate_emulated,
                                         _packed_pair_k_for)
    from repro.dist.halo import attach_p2p
    from repro.graph import partition_graph
    from repro.nn import GNNConfig, init_gnn
    from repro.nn.gnn import gnn_forward

    cfg = GNNConfig(conv="sage", in_dim=F, hidden=F,
                    out_dim=g.num_classes, layers=LAYERS)
    params = init_gnn(jax.random.key(0), cfg)
    pg = partition_graph(g, Q, scheme=SCHEME)
    graph = attach_p2p(pg.device_arrays(), pg)
    meta = DistMeta.build(pg, params, wire="p2p")
    rate = 2.0
    rm = np.full((Q, Q), rate, np.float32)
    np.fill_diagonal(rm, 1.0)
    for width in (2, 4, 8, 32):
        wm = np.full((Q, Q), float(width), np.float32)
        np.fill_diagonal(wm, 32.0)
        agg = _make_aggregate_emulated(
            graph, meta, fixed(rate, compressor="blockmask"), None,
            jnp.ones((), jnp.float32), jax.random.key(0),
            packed_k=dict(_packed_pair_k_for(meta, rm)),
            rate_map=jnp.asarray(rm), width_map=jnp.asarray(wm))
        _, bits = gnn_forward(params, cfg, graph["features"], agg)
        transport = float(np.asarray(bits)[2:2 + Q * Q].sum())
        analytic = 2.0 * float(meta.transport_bits_quant(F, rate, width))
        assert abs(transport - analytic) <= 1e-6 * analytic, \
            (width, transport, analytic)
        print(f"ledger == analytic ok: w={width} {analytic:.0f} bits")

    # emulated ≡ shard_map at mixed [L, Q, Q] rate × width maps, through
    # the shared conformance harness (≤ 1e-6, asserted per case in the
    # subprocess)
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "tests"))
    from parity import run_forward_parity
    out = run_forward_parity(Q, [
        {"wire": wire, "policy": "fixed:4", "map": "layer",
         "width_map": "layer", "seed": 0}
        for wire in ("p2p", "packed")], layers=LAYERS)
    print(out.strip())
    assert out.count(" OK ") == 2, out
    print("RATECTL_SMOKE_OK")


def smoke_per_layer() -> None:
    """Per-layer acceptance (DESIGN.md §3.7): per-layer controller drops
    no more energy than the uniform-layer controller at equal budget,
    lands the budget within 5%, and the backends agree at mixed
    ``[L, Q, Q]`` rates (the shared conformance harness of
    tests/parity.py, so the benchmark and the test matrix exercise one
    parity protocol)."""
    from repro.graph.synthetic import citation_graph

    epochs = 40
    g = citation_graph(n=1200, feat_dim=F, seed=0)

    # anchor the budget on the uniform fixed-rate baseline's spend, like
    # the scalar smoke — both closed-loop runs then compete at equal bits
    _, budget = _train(g, "fixed:2", epochs, compressor="blockmask")
    print(f"anchor budget = {budget:.4g} bits")

    res_u, t_u = _train(g, f"auto:budget:{budget:g}", epochs)
    err_u = res_u.history.comp_err[-1]
    print(f"uniform-layer budget ctl  spent/budget={t_u / budget:.4f}  "
          f"comp_err={err_u:.4g}  acc={res_u.history.final_test_acc:.4f}")

    res_p, t_p = _train(g, f"auto:budget:{budget:g}:per-layer", epochs)
    err_p = res_p.history.comp_err[-1]
    adherence = abs(t_p - budget) / budget
    split = [round(v, 5) for v in res_p.history.layer_split(Q)]
    print(f"per-layer budget ctl      spent/budget={t_p / budget:.4f}  "
          f"comp_err={err_p:.4g}  acc={res_p.history.final_test_acc:.4f}  "
          f"layer split Gf={split}")

    assert adherence <= 0.05, (
        f"per-layer budget controller missed the bit budget by "
        f"{100 * adherence:.1f}% (> 5%): shipped {t_p:.4g} of {budget:.4g}")
    assert err_p <= err_u * (1.0 + 1e-6), (
        f"per-layer allocation dropped MORE energy than uniform layers at "
        f"equal budget: {err_p:.6g} > {err_u:.6g}")

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "tests"))
    from parity import run_forward_parity
    out = run_forward_parity(Q, [
        {"wire": wire, "policy": "fixed:4", "map": "layer", "seed": 0}
        for wire in ("p2p", "packed")], layers=LAYERS)
    print(out.strip())
    print("RATECTL_PER_LAYER_SMOKE_OK")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    grp = ap.add_mutually_exclusive_group()
    grp.add_argument("--smoke", action="store_true",
                     help="acceptance: budget within 5%, error >= uniform "
                          "accuracy at equal budget (~2 min); with "
                          "--per-layer, the per-layer acceptance triple "
                          "instead")
    grp.add_argument("--full", action="store_true",
                     help="paper-scale frontier sweep")
    ap.add_argument("--per-layer", action="store_true",
                    help="per-layer [L, Q, Q] frontier / smoke "
                         "(DESIGN.md §3.7)")
    args = ap.parse_args()
    if args.smoke and args.per_layer:
        smoke_per_layer()
    elif args.smoke:
        smoke()
    else:
        print(main(quick=not args.full, per_layer=args.per_layer))
