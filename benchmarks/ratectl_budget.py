"""Fig.-5 frontier, closed loop: accuracy vs wire budget per controller.

The paper's headline (Fig. 5) plots accuracy against communicated floats
for the *open-loop* eq.-(8) schedule.  This sweep reproduces that
frontier for the closed-loop controllers of ``repro.dist.ratectl``: per
budget fraction ``B = frac × full-comm transport`` it trains the same
partitioned graph under

* ``uniform`` — the fixed-rate baseline whose rate is chosen to land on
  the budget (the paper's Fixed Comp Rate point),
* ``budget``  — the PI controller told ``auto:budget:<B>``,
* ``error``   — the per-pair water-filling controller ``auto:error:<B>``,

plus one open-loop ``varco:linear:5`` run (its own measured transport is
its x-coordinate), all on the p2p wire.  Per row it records the budget,
the transport actually shipped (and its fraction of budget), and
final/best test accuracy.

``--smoke`` is the CI acceptance check (~2 min): the ``budget``
controller's accumulated transport must land within 5% of the requested
bits, and the ``error`` controller's accuracy at the uniform baseline's
measured budget must be at least the baseline's.

Output: ``experiments/bench/ratectl_budget.csv`` (schema in
benchmarks/README.md).
"""

from __future__ import annotations

import os
import sys
import time

if __package__ in (None, ""):               # `python benchmarks/...py` direct
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import save_rows

# the budget/error controllers need kept-block headroom: F=512 → nb=4
F = 512
LAYERS = 2
Q = 4
SCHEME = "metis-like"


def _train(g, policy_spec: str, epochs: int, wire: str = "p2p",
           compressor: str | None = None):
    from repro.core import CommPolicy
    from repro.train import train_gnn

    policy = CommPolicy.parse(policy_spec, epochs, compressor=compressor)
    res = train_gnn(g, q=Q, scheme=SCHEME, policy=policy, epochs=epochs,
                    hidden=F, layers=LAYERS, eval_every=10, wire=wire)
    transport_bits = res.history.total_transport_gfloats * 32e9
    return res, transport_bits


def _full_step_bits(g) -> float:
    """Analytic full-communication transport of one train step (the same
    model the controllers pace against: ``exchange_widths``)."""
    import jax

    from repro.dist.gnn_parallel import DistMeta
    from repro.dist.ratectl import exchange_widths
    from repro.graph import partition_graph
    from repro.nn import GNNConfig, init_gnn

    cfg = GNNConfig(conv="sage", in_dim=F, hidden=F,
                    out_dim=g.num_classes, layers=LAYERS)
    pg = partition_graph(g, Q, scheme=SCHEME)
    meta = DistMeta.build(pg, init_gnn(jax.random.key(0), cfg), wire="p2p")
    return 2.0 * 32.0 * meta.halo_demand * sum(exchange_widths(cfg))


def main(quick: bool = True) -> dict:
    from repro.graph.synthetic import citation_graph

    n = 1200 if quick else 6000
    epochs = 30 if quick else 120
    fracs = [0.3, 0.5] if quick else [0.2, 0.35, 0.5, 0.75]
    g = citation_graph(n=n, feat_dim=F, seed=0)
    d_full = _full_step_bits(g)
    rows = []
    t0 = time.time()
    worst_budget_err = 0.0
    for frac in fracs:
        budget = frac * d_full * epochs
        # uniform fixed-rate baseline aimed at the budget
        res_u, t_u = _train(g, f"fixed:{1.0 / frac:g}", epochs,
                            compressor="blockmask")
        rows.append({"policy": "uniform", "budget_bits": budget,
                     "transport_bits": t_u, "of_budget": t_u / budget,
                     "final_acc": res_u.history.final_test_acc,
                     "best_acc": res_u.history.best_test_acc})
        for ctl in ("budget", "error"):
            res, t = _train(g, f"auto:{ctl}:{budget:g}", epochs)
            if ctl == "budget":
                worst_budget_err = max(worst_budget_err,
                                       abs(t - budget) / budget)
            rows.append({"policy": ctl, "budget_bits": budget,
                         "transport_bits": t, "of_budget": t / budget,
                         "final_acc": res.history.final_test_acc,
                         "best_acc": res.history.best_test_acc})
    res_o, t_o = _train(g, "varco:linear:5", epochs,
                        compressor="blockmask")
    rows.append({"policy": "open-loop", "budget_bits": t_o,
                 "transport_bits": t_o, "of_budget": 1.0,
                 "final_acc": res_o.history.final_test_acc,
                 "best_acc": res_o.history.best_test_acc})
    save_rows("ratectl_budget", rows)
    return {"name": "ratectl_budget",
            "us_per_call": 1e6 * (time.time() - t0) / max(len(rows), 1),
            "derived": f"rows={len(rows)}|worst_budget_err="
                       f"{worst_budget_err:.4f}"}


def smoke() -> None:
    """Acceptance: budget adherence within 5%, error >= uniform accuracy."""
    from repro.graph.synthetic import citation_graph

    epochs = 40
    g = citation_graph(n=1200, feat_dim=F, seed=0)

    # the uniform fixed-rate baseline's measured transport IS the budget,
    # so the closed-loop runs compete at exactly equal wire spend
    res_u, budget = _train(g, "fixed:2", epochs, compressor="blockmask")
    acc_u = res_u.history.final_test_acc
    print(f"uniform fixed:2  transport={budget:.4g} bits  acc={acc_u:.4f}")

    res_b, t_b = _train(g, f"auto:budget:{budget:g}", epochs)
    err = abs(t_b - budget) / budget
    print(f"budget controller  spent/budget={t_b / budget:.4f}  "
          f"acc={res_b.history.final_test_acc:.4f}")
    assert err <= 0.05, (
        f"budget controller missed the bit budget by {100 * err:.1f}% "
        f"(> 5%): shipped {t_b:.4g} of {budget:.4g}")

    res_e, t_e = _train(g, f"auto:error:{budget:g}", epochs)
    acc_e = res_e.history.final_test_acc
    print(f"error controller   spent/budget={t_e / budget:.4f}  "
          f"acc={acc_e:.4f}")
    assert t_e <= 1.05 * budget, (t_e, budget)
    assert acc_e + 1e-6 >= acc_u, (
        f"error controller accuracy {acc_e:.4f} fell below the uniform "
        f"baseline {acc_u:.4f} at equal budget")
    print("RATECTL_SMOKE_OK")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    grp = ap.add_mutually_exclusive_group()
    grp.add_argument("--smoke", action="store_true",
                     help="acceptance: budget within 5%, error >= uniform "
                          "accuracy at equal budget (~2 min)")
    grp.add_argument("--full", action="store_true",
                     help="paper-scale frontier sweep")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        print(main(quick=not args.full))
