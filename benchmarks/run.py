"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark).
``--full`` switches to paper-scale settings (bigger graphs, 300 epochs).
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback

if __package__ in (None, ""):               # `python benchmarks/run.py` direct
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

MODULES = [
    "benchmarks.table1_partition_stats",
    "benchmarks.fig3_fig5_accuracy",
    "benchmarks.fig4_accuracy_vs_servers",
    "benchmarks.prop1_neighborhood",
    "benchmarks.transformer_comm",
    "benchmarks.kernel_bench",
    "benchmarks.halo_exchange",              # dense/packed/p2p wire sweep
    "benchmarks.ratectl_budget",             # closed-loop budget frontier
    "benchmarks.roofline",
]


def main(argv: list[str] | None = None) -> int:
    """Run the registered benchmarks; returns the number of FAILED modules
    (the process exit code — CI must never pass on a broken benchmark;
    regression: tests/test_bench_run.py)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filter")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    failed = 0
    for modname in MODULES:
        if args.only and not any(s in modname
                                 for s in args.only.split(",")):
            continue
        try:
            mod = __import__(modname, fromlist=["main"])
            rec = mod.main(quick=not args.full)
            print(f"{rec['name']},{rec['us_per_call']:.1f},"
                  f"{rec['derived']}", flush=True)
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{modname},NaN,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    return failed


if __name__ == "__main__":
    sys.exit(main())
