"""Serving latency/QPS sweep + the §3.11 tail-latency SLO acceptance.

Sweeps the :class:`repro.serve.ServingEngine` over batch sizes and two
query paths on the same partitioned graph:

* ``cold`` — every batch pays a full exact distributed recompute
  (``refresh(force=True)``) before answering: the no-cache baseline.
* ``warm`` — batches answer straight from the drift-gated embedding
  cache (zero wire bits between refreshes).

Per row it records p50/p99 latency, QPS, and the ``CommLedger`` wire
bits charged by the path.

``--smoke`` is the CI acceptance leg (DESIGN.md §3.11):

1. warm p99 latency ≤ 0.5 × cold p99 at equal batch size;
2. warm wire bits strictly below cold (per ``CommLedger``);
3. while drift gating reports ``FRESH``, served embeddings match a
   full fresh centralised forward ≤ 1e-5;
4. after an edge-update batch, the incremental k-hop recompute matches
   a full recompute ≤ 1e-5 on the touched frontier.

Output: ``experiments/bench/serving_bench.csv`` (schema in
benchmarks/README.md).
"""

from __future__ import annotations

import os
import sys
import time

if __package__ in (None, ""):               # `python benchmarks/...py` direct
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import save_rows

F = 256
LAYERS = 2
Q = 4
N = 1024


def _engine(n: int = N, seed: int = 0):
    import jax

    from repro.graph.synthetic import citation_graph
    from repro.nn import GNNConfig, init_gnn
    from repro.serve import ServingEngine

    g = citation_graph(n=n, feat_dim=F, seed=seed)
    cfg = GNNConfig(conv="sage", in_dim=F, hidden=F,
                    out_dim=g.num_classes, layers=LAYERS)
    params = init_gnn(jax.random.key(seed), cfg)
    eng = ServingEngine(g, params, cfg, q=Q, seed=seed)
    return g, cfg, params, eng


def _percentiles(samples_s: list[float]) -> tuple[float, float]:
    import numpy as np
    arr = np.asarray(samples_s) * 1e3
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))


def _latency_sweep(eng, batch: int, trials: int, cold: bool,
                   rng) -> tuple[list[float], float]:
    """Per-batch latencies (s) + wire bits charged over the sweep."""
    n = eng.g.num_nodes
    bits0 = float(eng.ledger.transport)
    times = []
    for _ in range(trials):
        nodes = rng.integers(0, n, batch)
        t0 = time.perf_counter()
        if cold:
            eng.refresh(force=True)
        eng.serve(nodes)
        times.append(time.perf_counter() - t0)
    return times, float(eng.ledger.transport) - bits0


def main(quick: bool = True) -> dict:
    import numpy as np

    n = N if quick else 4096
    trials = 20 if quick else 100
    batches = [1, 16, 64] if quick else [1, 8, 32, 128, 512]
    _, _, _, eng = _engine(n=n)
    eng.refresh(force=True)
    rng = np.random.default_rng(0)
    rows = []
    t0 = time.time()
    for batch in batches:
        for mode in ("cold", "warm"):
            times, bits = _latency_sweep(eng, batch, trials,
                                         mode == "cold", rng)
            p50, p99 = _percentiles(times)
            rows.append({"mode": mode, "batch": batch, "trials": trials,
                         "p50_ms": p50, "p99_ms": p99,
                         "qps": batch * trials / max(sum(times), 1e-12),
                         "wire_bits": bits})
    save_rows("serving_bench", rows)
    return {"name": "serving_bench",
            "us_per_call": 1e6 * (time.time() - t0) / max(len(rows), 1),
            "derived": f"rows={len(rows)}"}


def smoke() -> None:
    """The four-assert §3.11 acceptance leg (~2 min)."""
    import numpy as np

    from repro.nn.gnn import centralized_forward

    g, cfg, params, eng = _engine()
    rng = np.random.default_rng(0)
    batch, trials = 64, 30

    # 3. FRESH ⇒ exact: cold-start refresh, then served == centralised
    eng.refresh(force=True)
    nodes = rng.integers(0, g.num_nodes, batch)
    emb, status = eng.serve(nodes)
    ref = np.asarray(centralized_forward(params, cfg, g))
    d = float(np.max(np.abs(emb - ref[nodes])))
    print(f"status={status}  served vs fresh forward max|diff|={d:.3g}")
    assert status == "FRESH", status
    assert d <= 1e-5, d

    # 1./2. warm vs cold at equal batch: tail latency + wire bits
    cold_t, cold_bits = _latency_sweep(eng, batch, trials, True, rng)
    # the cold sweep's forced refreshes re-primed the halo caches; one
    # gated refresh folds the drift measurement in before the warm leg
    eng.refresh()
    warm_t, warm_bits = _latency_sweep(eng, batch, trials, False, rng)
    cold_p50, cold_p99 = _percentiles(cold_t)
    warm_p50, warm_p99 = _percentiles(warm_t)
    print(f"cold p50={cold_p50:.2f}ms p99={cold_p99:.2f}ms "
          f"bits={cold_bits:.3g}")
    print(f"warm p50={warm_p50:.2f}ms p99={warm_p99:.2f}ms "
          f"bits={warm_bits:.3g}")
    assert warm_p99 <= 0.5 * cold_p99, (
        f"warm-cache p99 {warm_p99:.2f}ms missed the SLO: > 0.5x cold "
        f"recompute p99 {cold_p99:.2f}ms at batch {batch}")
    assert warm_bits < cold_bits, (
        f"warm-cache wire bits {warm_bits:.3g} not strictly below cold "
        f"{cold_bits:.3g}")

    # 4. streaming updates: incremental == full recompute on the frontier
    eng.refresh(force=True)
    ins = (rng.integers(0, g.num_nodes, 8), rng.integers(0, g.num_nodes, 8))
    dst0, src0 = g.edge_list()
    pick = rng.integers(0, len(dst0), 6)
    touched, fronts = eng.apply_updates(inserts=ins,
                                        deletes=(dst0[pick], src0[pick]))
    ref2 = np.asarray(centralized_forward(params, cfg, eng.g))
    emb2, _ = eng.serve(np.asarray(touched))
    d2 = float(np.max(np.abs(emb2 - ref2[np.asarray(touched)])))
    print(f"update batch: |touched|={len(touched)} frontier sizes="
          f"{[len(f) for f in fronts]} incremental vs full max|diff|="
          f"{d2:.3g}")
    assert d2 <= 1e-5, d2
    print("SERVING_SMOKE_OK")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="§3.11 acceptance: warm p99 <= 0.5x cold, warm "
                         "wire bits < cold, FRESH exactness <= 1e-5, "
                         "incremental == full recompute <= 1e-5")
    ap.add_argument("--full", action="store_true",
                    help="larger sweep (4096 nodes, more batch sizes)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        print(main(quick=not args.full))
