"""Paper Table I: self/cross edge statistics per (dataset, partitioner, Q)."""

from __future__ import annotations

import time

from benchmarks.common import dataset, save_rows


def main(quick: bool = True) -> dict:
    from repro.graph import edge_cut_stats
    from repro.graph.partition import PARTITIONERS

    sizes = {"arxiv": 20000 if quick else 50000,
             "products": 30000 if quick else 100000}
    qs = [2, 4, 8, 16]
    rows = []
    t0 = time.time()
    for ds, n in sizes.items():
        g = dataset(ds, n)
        for scheme in PARTITIONERS:
            for q in qs:
                from repro.graph import partition_graph
                pg = partition_graph(g, q, scheme=scheme)
                st = edge_cut_stats(g, pg.owner)
                rows.append({
                    "dataset": ds, "scheme": scheme, "q": q,
                    "self_edges": st["self_edges"],
                    "cross_edges": st["cross_edges"],
                    "self_pct": round(100 * st["self_frac"], 2),
                    "cross_pct": round(100 * st["cross_frac"], 2),
                    "halo_demand": pg.halo_demand,
                })
    save_rows("table1_partition_stats", rows)
    # headline check mirroring the paper: METIS-like cuts fewer edges and
    # cross share grows with Q
    r16 = [r for r in rows if r["dataset"] == "arxiv" and r["q"] == 16]
    metis = next(r for r in r16 if r["scheme"] == "metis-like")
    rand = next(r for r in r16 if r["scheme"] == "random")
    return {"name": "table1_partition_stats",
            "us_per_call": 1e6 * (time.time() - t0) / len(rows),
            "derived": f"cross16_random={rand['cross_pct']}%"
                       f"|metis-like={metis['cross_pct']}%"}


if __name__ == "__main__":
    print(main())
