"""Beyond-paper: VARCO on an assigned LLM arch — accuracy(loss)-per-byte of
data-parallel gradient traffic (the Fig. 5 axis transplanted to LM
training). Single-device mesh: numerics identical to multi-device since
the compressor acts per worker before the (here trivial) psum."""

from __future__ import annotations

import os
import sys
import time

if __package__ in (None, ""):               # `python benchmarks/...py` direct
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_rows


def main(quick: bool = True) -> dict:
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.core import FULL_COMM, fixed, varco
    from repro.dist.grad_compress import make_varco_dp_train_step
    from repro.launch.steps import make_optimizer
    from repro.models.transformer import init_lm

    cfg = get_config("granite-3-2b", smoke=True)
    steps = 60 if quick else 200
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    rng = np.random.default_rng(0)
    # tiny synthetic corpus with learnable bigram structure
    trans = rng.dirichlet(np.full(cfg.vocab_size, 0.05), cfg.vocab_size)
    toks = np.zeros((8, 128), np.int32)
    for b in range(8):
        toks[b, 0] = rng.integers(cfg.vocab_size)
        for t in range(1, 128):
            toks[b, t] = rng.choice(cfg.vocab_size, p=trans[toks[b, t - 1]])
    batch = {"tokens": jnp.asarray(toks)}

    rows = []
    summary = {}
    t0 = time.time()
    for name, pol in [("full", FULL_COMM), ("fixed8", fixed(8.0)),
                      ("varco", varco(steps, slope=5, c_max=64.0))]:
        params = init_lm(jax.random.key(0), cfg)
        opt = make_optimizer(cfg, lr=3e-3)
        s = opt.init(params)
        step = make_varco_dp_train_step(cfg, opt, pol, mesh)
        p = params
        bits = 0.0
        for i in range(steps):
            p, s, m = step(p, s, batch, jnp.asarray(i), jax.random.key(i))
            bits += float(m["grad_bits"])
            rows.append({"policy": name, "step": i,
                         "loss": round(float(m["loss"]), 4),
                         "rate": round(float(m["rate"]), 2),
                         "gbits_cum": round(bits / 1e9, 4)})
        summary[name] = round(float(m["loss"]), 4)
    save_rows("transformer_comm", rows)
    return {"name": "transformer_comm",
            "us_per_call": 1e6 * (time.time() - t0) / (3 * steps),
            "derived": "|".join(f"{k}_loss={v}" for k, v in summary.items())}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    grp = ap.add_mutually_exclusive_group()
    grp.add_argument("--smoke", action="store_true",
                     help="reduced settings (CPU, ~1 min); the default")
    grp.add_argument("--full", action="store_true",
                     help="longer run (200 steps instead of 60; still the "
                          "smoke model config on CPU)")
    args = ap.parse_args()
    print(main(quick=not args.full))
