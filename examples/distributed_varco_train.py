"""End-to-end driver (deliverable b): full-batch distributed GNN training
with VARCO on a production-shaped problem.

* synthetic OGBN-Arxiv analogue (20k nodes / ~270k edges by default),
* 16 workers, random partitioning (the paper's hardest setting),
* 300 epochs of Algorithm 1 with the linear slope-5 scheduler,
* periodic evaluation, msgpack checkpointing, CSV history.

Run:  PYTHONPATH=src python examples/distributed_varco_train.py \
          [--workers 16] [--epochs 300] [--comm varco:linear:5]
          [--scheme random|metis-like] [--shard-map] [--wire dense|packed|p2p]

``--policy`` (alias of ``--comm``) also accepts the closed-loop specs of
``repro.dist.ratectl`` (DESIGN.md §3.6) — e.g.

    --policy auto:budget:2e9 --feat-dim 512 --hidden 512

plans per-pair compression rates every epoch so the run's total transport
lands on the named bit budget (the trailing report prints the adherence).
A trailing ``:per-layer`` (e.g. ``auto:budget:2e9:per-layer``) lifts the
plan to per-layer ``[L, Q, Q]`` rate tensors — each layer's exchanges get
their own water-filled share of the step's bit allowance (DESIGN.md §3.7)
— and the report adds the per-layer transport split.
Auto policies need lane-grid widths (feature/hidden multiples of 128) and
run on the p2p wire; widths of 512 give the controller 4 kept-block
levels per pair to allocate — at width 128 every pair is already at the
one-block floor and no budget below full communication is reachable.

``--shard-map`` runs the real collective path and needs
``XLA_FLAGS=--xla_force_host_platform_device_count=<workers>``; the default
emulated path is numerically identical (tests/test_multidevice.py).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=20000)
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--epochs", type=int, default=300)
    ap.add_argument("--comm", "--policy", dest="comm",
                    default="varco:linear:5",
                    help="comm spec: full | none | fixed:<r> | "
                         "varco:<sched> | "
                         "auto:<controller>:<budget-bits>[:per-layer] "
                         "(closed-loop; e.g. auto:budget:2e9 or "
                         "auto:budget:2e9:per-layer for [L, Q, Q] "
                         "per-layer rate tensors)")
    ap.add_argument("--wire", default=None,
                    choices=["dense", "packed", "p2p"],
                    help="halo-exchange transport (auto policies default "
                         "to p2p)")
    ap.add_argument("--scheme", default="random",
                    choices=["random", "metis-like"])
    ap.add_argument("--feat-dim", type=int, default=None,
                    help="synthetic feature width (default: the dataset's "
                         "128; auto policies want >= 256 for compression "
                         "headroom)")
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--dataset", default="arxiv",
                    choices=["arxiv", "products"])
    ap.add_argument("--shard-map", action="store_true")
    ap.add_argument("--out", default="experiments/run")
    args = ap.parse_args()

    from repro.core import CommPolicy
    from repro.graph import citation_graph, copurchase_graph
    from repro.train import train_gnn
    from repro.train.checkpoint import save
    from repro.train.metrics import write_csv

    gen = citation_graph if args.dataset == "arxiv" else copurchase_graph
    graph = gen(n=args.nodes) if args.feat_dim is None \
        else gen(n=args.nodes, feat_dim=args.feat_dim)
    policy = CommPolicy.parse(args.comm, args.epochs)
    auto = policy.mode == "auto"
    if auto and (args.hidden % 128 or graph.feat_dim % 128):
        ap.error(f"auto policies pack 128-lane blocks: --hidden/--feat-dim "
                 f"must be multiples of 128, got {args.hidden}/"
                 f"{graph.feat_dim}")
    wire = args.wire or ("p2p" if auto else "dense")
    print(f"dataset={graph.name} workers={args.workers} "
          f"scheme={args.scheme} comm={policy.describe()} wire={wire}")

    res = train_gnn(
        graph, q=args.workers, scheme=args.scheme, policy=policy,
        epochs=args.epochs, hidden=args.hidden, weight_decay=1e-3,
        eval_every=10, use_shard_map=args.shard_map, wire=wire,
        log_fn=lambda r: print(
            f"epoch {r['epoch']:4d}  loss {r['loss']:.4f}  "
            f"rate {r['rate']:6.1f}  val {r['val_acc']:.3f}  "
            f"test {r['test_acc']:.3f}  comm {r['halo_gfloats']:.2f} Gf",
            flush=True))

    os.makedirs(args.out, exist_ok=True)
    write_csv(os.path.join(args.out, "history.csv"), res.history.rows())
    save(os.path.join(args.out, "model.msgpack"), res.params,
         extra={"policy": res.policy_desc,
                "test_acc": res.history.final_test_acc})
    print(f"\nfinal test acc {res.history.final_test_acc:.3f} "
          f"(best {res.history.best_test_acc:.3f}); "
          f"total comm {res.history.total_halo_gfloats:.2f} Gfloat; "
          f"artifacts in {args.out}/")
    if auto:
        spent = res.history.total_transport_gfloats * 32e9
        print(f"budget adherence: shipped {spent:.4g} of "
              f"{policy.budget_bits:.4g} bits "
              f"({spent / policy.budget_bits:.1%})")
        split = res.history.layer_split(args.workers)
        if split:
            print("per-layer transport split (Gfloat): " +
                  ", ".join(f"L{i}={v:.3f}" for i, v in enumerate(split)))


if __name__ == "__main__":
    main()
