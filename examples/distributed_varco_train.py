"""End-to-end driver (deliverable b): full-batch distributed GNN training
with VARCO on a production-shaped problem.

* synthetic OGBN-Arxiv analogue (20k nodes / ~270k edges by default),
* 16 workers, random partitioning (the paper's hardest setting),
* 300 epochs of Algorithm 1 with the linear slope-5 scheduler,
* periodic evaluation, msgpack checkpointing, CSV history.

Run:  PYTHONPATH=src python examples/distributed_varco_train.py \
          [--workers 16] [--epochs 300] [--comm varco:linear:5]
          [--scheme random|metis-like] [--shard-map]

``--shard-map`` runs the real collective path and needs
``XLA_FLAGS=--xla_force_host_platform_device_count=<workers>``; the default
emulated path is numerically identical (tests/test_multidevice.py).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=20000)
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--epochs", type=int, default=300)
    ap.add_argument("--comm", default="varco:linear:5")
    ap.add_argument("--scheme", default="random",
                    choices=["random", "metis-like"])
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--dataset", default="arxiv",
                    choices=["arxiv", "products"])
    ap.add_argument("--shard-map", action="store_true")
    ap.add_argument("--out", default="experiments/run")
    args = ap.parse_args()

    from repro.core import CommPolicy
    from repro.graph import citation_graph, copurchase_graph
    from repro.train import train_gnn
    from repro.train.checkpoint import save
    from repro.train.metrics import write_csv

    gen = citation_graph if args.dataset == "arxiv" else copurchase_graph
    graph = gen(n=args.nodes)
    policy = CommPolicy.parse(args.comm, args.epochs)
    print(f"dataset={graph.name} workers={args.workers} "
          f"scheme={args.scheme} comm={policy.describe()}")

    res = train_gnn(
        graph, q=args.workers, scheme=args.scheme, policy=policy,
        epochs=args.epochs, hidden=args.hidden, weight_decay=1e-3,
        eval_every=10, use_shard_map=args.shard_map,
        log_fn=lambda r: print(
            f"epoch {r['epoch']:4d}  loss {r['loss']:.4f}  "
            f"rate {r['rate']:6.1f}  val {r['val_acc']:.3f}  "
            f"test {r['test_acc']:.3f}  comm {r['halo_gfloats']:.2f} Gf",
            flush=True))

    os.makedirs(args.out, exist_ok=True)
    write_csv(os.path.join(args.out, "history.csv"), res.history.rows())
    save(os.path.join(args.out, "model.msgpack"), res.params,
         extra={"policy": res.policy_desc,
                "test_acc": res.history.final_test_acc})
    print(f"\nfinal test acc {res.history.final_test_acc:.3f} "
          f"(best {res.history.best_test_acc:.3f}); "
          f"total comm {res.history.total_halo_gfloats:.2f} Gfloat; "
          f"artifacts in {args.out}/")


if __name__ == "__main__":
    main()
