"""Quickstart: VARCO distributed GNN training in ~40 lines.

Trains the paper's 3-layer GraphSAGE on a synthetic citation graph split
across 4 workers, comparing full communication, no communication and VARCO
variable compression (Algorithm 1).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import FULL_COMM, NO_COMM, varco          # noqa: E402
from repro.graph import citation_graph                     # noqa: E402
from repro.train import train_gnn                          # noqa: E402


def main():
    epochs = 100
    graph = citation_graph(n=3000, seed=0)
    print(f"graph: {graph.num_nodes} nodes, {graph.num_edges} edges, "
          f"{graph.num_classes} classes")

    results = {}
    for name, policy in [
        ("full communication", FULL_COMM),
        ("no communication", NO_COMM),
        ("VARCO (linear slope 5)", varco(epochs, slope=5)),
    ]:
        res = train_gnn(graph, q=4, scheme="random", policy=policy,
                        epochs=epochs, eval_every=25, hidden=64)
        results[name] = res
        h = res.history
        print(f"{name:24s} test_acc={h.best_test_acc:.3f} "
              f"comm={h.total_halo_gfloats:.2f} Gfloat")

    full = results["full communication"].history
    var = results["VARCO (linear slope 5)"].history
    saving = 1.0 - var.total_halo_gfloats / max(full.total_halo_gfloats,
                                                1e-9)
    print(f"\nVARCO reached {var.best_test_acc:.3f} "
          f"(full comm: {full.best_test_acc:.3f}) "
          f"while communicating {100 * saving:.0f}% fewer floats.")


if __name__ == "__main__":
    main()
