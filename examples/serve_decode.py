"""Batched serving demo: prefill a batch of prompts, then autoregressive
decode against the KV cache (the serve path the decode_32k / long_500k
dry-run shapes lower).

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch granite-3-2b]
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.steps import make_decode_step, make_prefill_step
    from repro.models.transformer import init_lm

    cfg = get_config(args.arch, smoke=True)
    params = init_lm(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.batch, args.prompt_len)),
                          jnp.int32)

    max_len = args.prompt_len + args.new_tokens
    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(next_tok)
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} tokens "
          f"in {t_prefill:.2f}s")

    out = [next_tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        next_tok, logits, cache = decode(params,
                                         {"tokens": next_tok[:, None]},
                                         cache)
        out.append(next_tok)
    jax.block_until_ready(next_tok)
    dt = time.time() - t0
    total = args.batch * (args.new_tokens - 1)
    print(f"decode: {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on CPU)")
    gen = jnp.stack(out, axis=1)
    print("sample generations (token ids):")
    for b in range(min(args.batch, 3)):
        print(" ", np.asarray(gen[b])[:16].tolist())


if __name__ == "__main__":
    main()
