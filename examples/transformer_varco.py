"""VARCO on an assigned LLM architecture: data-parallel training with
variable-rate compressed gradient all-reduce over 4 virtual devices.

This is the paper's scheme transplanted to the transformer substrate
(DESIGN.md §4): early steps ship ~1/64 of the gradient bits, annealing to
full fidelity — loss matches the uncompressed run at a fraction of the
gradient traffic.

Run:  python examples/transformer_varco.py          (sets its own XLA flag)
"""

import os

# 4 virtual CPU devices for a real shard_map data-parallel mesh — set
# before any jax import (this is a standalone script, not a test).
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def main():
    from repro.configs import get_config
    from repro.core import FULL_COMM, varco
    from repro.dist.grad_compress import make_dp_mesh, \
        make_varco_dp_train_step
    from repro.launch.steps import make_optimizer
    from repro.models.transformer import init_lm
    from repro.nn.modules import param_count

    cfg = get_config("granite-3-2b", smoke=True)
    steps = 40
    mesh = make_dp_mesh(4)
    rng = np.random.default_rng(0)

    # bigram-structured synthetic corpus (so the LM has something to learn)
    trans = rng.dirichlet(np.full(cfg.vocab_size, 0.05), cfg.vocab_size)
    toks = np.zeros((8, 128), np.int32)
    for b in range(8):
        toks[b, 0] = rng.integers(cfg.vocab_size)
        for t in range(1, 128):
            toks[b, t] = rng.choice(cfg.vocab_size, p=trans[toks[b, t - 1]])
    batch = {"tokens": jnp.asarray(toks)}

    for name, pol in [("full", FULL_COMM),
                      ("varco", varco(steps, slope=5, c_max=64.0))]:
        params = init_lm(jax.random.key(0), cfg)
        print(f"\n== {name} ==  ({param_count(params):,} params, "
              f"{mesh.shape['data']} workers)")
        opt = make_optimizer(cfg, lr=3e-3)
        s = opt.init(params)
        step = make_varco_dp_train_step(cfg, opt, pol, mesh)
        p = params
        bits = 0.0
        for i in range(steps):
            p, s, m = step(p, s, batch, jnp.asarray(i), jax.random.key(i))
            bits += float(m["grad_bits"])
            if i % 10 == 0 or i == steps - 1:
                print(f"step {i:3d}  loss {float(m['loss']):.4f}  "
                      f"rate {float(m['rate']):5.1f}  "
                      f"grad-traffic {bits / 8e9:.3f} GB")


if __name__ == "__main__":
    main()
