#!/usr/bin/env python
"""Fail CI when the collected test count drops below the floor.

A refactor that silently de-collects a module (import error swallowed by
a skip, a renamed file pytest no longer matches, a conftest change that
breaks parametrisation) shows up as "fewer tests, all green".  This
guard runs ``pytest --collect-only -q`` and compares the collected count
against the floor recorded here, which each PR bumps to its own count.

Usage::

    PYTHONPATH=src python scripts/check_collection_floor.py [--min N]
"""

from __future__ import annotations

import argparse
import re
import subprocess
import sys

#: tier-1 collected-test floor — raise (never lower) as suites grow.
#: History: PR 1: 155, PR 2: 188, PR 3: 229, PR 4: 281, PR 5: 313,
#: PR 6: 351, PR 7: 372, PR 8: 406, PR 9: 432.
FLOOR = 436


def collected_count(pytest_args: list[str] | None = None) -> int:
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q",
         *(pytest_args or [])],
        capture_output=True, text=True)
    if out.returncode not in (0, 5):     # 5 = no tests collected
        print(out.stdout[-4000:], file=sys.stderr)
        print(out.stderr[-4000:], file=sys.stderr)
        raise SystemExit(f"pytest --collect-only failed "
                         f"(rc={out.returncode})")
    m = re.search(r"(\d+) tests? collected", out.stdout)
    if not m:
        m = re.search(r"collected (\d+) items", out.stdout)
    if not m:
        print(out.stdout[-4000:], file=sys.stderr)
        raise SystemExit("could not parse collected-test count")
    return int(m.group(1))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--min", type=int, default=FLOOR,
                    help=f"minimum collected tests (default {FLOOR})")
    args = ap.parse_args()
    n = collected_count()
    if n < args.min:
        print(f"FAIL: collected {n} tests, floor is {args.min} — a suite "
              f"stopped collecting (or lower the floor ONLY with a PR "
              f"that explains the removal)")
        return 1
    print(f"OK: collected {n} tests (floor {args.min})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
