#!/usr/bin/env python
"""Intra-repo markdown link checker (the CI docs job).

Scans the repo's curated docs for ``[text](target)`` links and fails if a
relative target doesn't exist on disk, or an in-page ``#anchor`` doesn't
match any heading (GitHub slug rules).  External ``http(s)://`` / ``mailto:``
targets are ignored — CI must not depend on the network.

Usage: python scripts/check_links.py [files...]   (defaults to the doc set)
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_FILES = ["README.md", "DESIGN.md", "ROADMAP.md", "CHANGES.md",
                 "benchmarks/README.md"]

# [text](target) — ignore images' leading '!' (still checked) and code spans
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation, spaces→'-'."""
    heading = re.sub(r"`([^`]*)`", r"\1", heading.strip())
    heading = re.sub(r"[^\w\- ]", "", heading.lower())
    return heading.replace(" ", "-")


def anchors_of(path: str) -> set[str]:
    with open(path, encoding="utf-8") as f:
        return {slugify(h) for h in HEADING_RE.findall(f.read())}


def check_file(md_path: str) -> list[str]:
    errors = []
    base = os.path.dirname(md_path)
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    # strip fenced code blocks — links inside them are examples, not refs
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if path_part:
            resolved = os.path.normpath(os.path.join(base, path_part))
            if not os.path.exists(resolved):
                errors.append(f"{md_path}: broken link target {target!r}")
                continue
            anchor_file = resolved
        else:
            anchor_file = md_path
        if anchor and os.path.isfile(anchor_file) \
                and anchor_file.endswith(".md"):
            if slugify(anchor) not in anchors_of(anchor_file):
                errors.append(f"{md_path}: missing anchor {target!r}")
    return errors


def main(argv: list[str]) -> int:
    files = argv or [os.path.join(REPO, f) for f in DEFAULT_FILES]
    errors, checked = [], 0
    for f in files:
        if not os.path.exists(f):
            errors.append(f"missing doc file: {f}")
            continue
        checked += 1
        errors.extend(check_file(f))
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"check_links: {checked} files, "
          f"{'FAILED' if errors else 'ok'} ({len(errors)} errors)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
