#!/usr/bin/env bash
# Tier-1 verify: full test suite + CPU smoke runs.  Usage: scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python examples/quickstart.py
python benchmarks/transformer_comm.py --smoke
