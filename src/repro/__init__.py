"""repro — production-grade JAX reproduction of VARCO (Cerviño et al. 2024:
Distributed Training of Large GNNs with Variable Communication Rates)."""

__version__ = "1.0.0"
