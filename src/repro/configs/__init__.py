from .base import (ARCH_IDS, ArchConfig, MambaConfig, MoEConfig, all_configs,
                   get_config)

__all__ = ["ARCH_IDS", "ArchConfig", "MambaConfig", "MoEConfig",
           "all_configs", "get_config"]
