"""Architecture configuration schema + registry.

Every assigned architecture gets one ``repro/configs/<id>.py`` exporting
``CONFIG`` (the exact assignment) and ``SMOKE`` (a reduced same-family
variant: ≤2 layers, d_model ≤ 512, ≤4 experts) used by CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # shared (always-on) experts
    d_shared: int = 0             # shared-expert hidden dim (0 => n_shared*d_expert)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    every: int = 1                # MoE every `every` layers (others dense)
    pad_to: int = 0               # pad expert stacks so E divides the mesh
                                  # (padded experts are never routed to)

    @property
    def shared_hidden(self) -> int:
        return self.d_shared or self.n_shared * self.d_expert

    @property
    def e_padded(self) -> int:
        return max(self.pad_to, self.n_experts)


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    ref: str                      # source paper / model card
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 => d_model // n_heads
    # layer pattern, repeated to n_layers. entries: "attn" | "mamba"
    pattern: tuple = ("attn",)
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    mlp: str = "swiglu"           # swiglu | geglu
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope_sections: tuple = ()    # qwen2-vl M-RoPE (t, h, w) head_dim split
    sliding_window: int = 0       # 0 = full causal; >0 = SWA window
    embed_source: str = "tokens"  # tokens | patches (vlm) | codec (audio)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    max_seq_len: int = 524288
    # numerics
    param_dtype: str = "float32"
    activ_dtype: str = "float32"
    moment_dtype: str = "float32"
    remat: bool = False

    # ---- derived -----------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def pattern_period(self) -> int:
        return len(self.pattern)

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.pattern_period == 0, \
            f"{self.name}: n_layers={self.n_layers} not divisible by " \
            f"pattern period {self.pattern_period}"
        return self.n_layers // self.pattern_period

    def layer_kind(self, pattern_idx: int) -> str:
        return self.pattern[pattern_idx]

    def layer_uses_moe(self, pattern_idx: int) -> bool:
        if self.moe is None:
            return False
        return pattern_idx % self.moe.every == (self.moe.every - 1)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.activ_dtype)

    @property
    def is_subquadratic(self) -> bool:
        """May run long_500k decode: SSM/hybrid or sliding-window attention."""
        return (self.family in ("ssm", "hybrid")) or self.sliding_window > 0

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # rough parameter counts for roofline MODEL_FLOPS = 6 N D --------------

    def param_counts(self) -> dict:
        d, hd = self.d_model, self.resolved_head_dim
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_pattern = []
        for pi, kind in enumerate(self.pattern):
            n = 0
            if kind == "attn":
                n += d * self.n_heads * hd * 2              # wq, wo
                n += d * self.n_kv_heads * hd * 2           # wk, wv
            else:  # mamba
                mc = self.mamba
                di = mc.d_inner(d)
                n += d * (2 * di + 2 * mc.n_groups * mc.d_state
                          + mc.n_heads(d))                   # in_proj
                n += di * d                                  # out_proj
                n += (di + 2 * mc.n_groups * mc.d_state) * mc.d_conv
            # MLP / MoE
            if self.layer_uses_moe(pi):
                m = self.moe
                n += m.n_experts * 3 * d * m.d_expert
                n += 3 * d * m.shared_hidden if m.n_shared else 0
                n += d * m.n_experts                         # router
            else:
                n += 3 * d * self.d_ff
            per_pattern.append(n)
        body = self.n_blocks * sum(per_pattern)
        # active params (MoE: top_k + shared experts only)
        active_pp = []
        for pi, kind in enumerate(self.pattern):
            n = per_pattern[pi]
            if self.layer_uses_moe(pi):
                m = self.moe
                n -= m.n_experts * 3 * d * m.d_expert
                n += m.top_k * 3 * d * m.d_expert
            active_pp.append(n)
        active = self.n_blocks * sum(active_pp)
        return {"total": body + embed, "body": body, "embed": embed,
                "active": active + embed}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "jamba-1.5-large-398b",
    "gemma-7b",
    "qwen2-moe-a2.7b",
    "llama4-maverick-400b-a17b",
    "mamba2-130m",
    "musicgen-large",
    "qwen3-32b",
    "granite-3-2b",
    "qwen2-vl-2b",
    "yi-6b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str, smoke: bool = False) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> dict:
    return {a: get_config(a, smoke=smoke) for a in ARCH_IDS}
