"""gemma-7b [dense] — GeGLU, head_dim=256, MHA (kv=16) [arXiv:2403.08295]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    ref="arXiv:2403.08295",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,              # gemma's oversized heads: 16*256 = 4096 > d
    d_ff=24576,
    vocab_size=256000,
    mlp="geglu",
    tie_embeddings=True,       # gemma ties input/output embeddings
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat=True,
)

SMOKE = ArchConfig(
    name="gemma-smoke",
    family="dense",
    ref=CONFIG.ref,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    mlp="geglu",
    tie_embeddings=True,
)
