"""granite-3-2b [dense] — GQA 32H/kv8 [hf:ibm-granite/granite-3.0-2b-base]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    ref="hf:ibm-granite/granite-3.0-2b-base",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    tie_embeddings=True,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat=True,
)

SMOKE = ArchConfig(
    name="granite-smoke",
    family="dense",
    ref=CONFIG.ref,
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    tie_embeddings=True,
)
