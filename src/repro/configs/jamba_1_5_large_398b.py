"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave with MoE
[arXiv:2403.19887, Jamba-1.5].

72 layers = 9 blocks of 8 (7 Mamba + 1 attention at position 3, matching
Jamba's one-attention-per-8 placement); MoE every other layer (16 experts,
top-2).  GQA: 64 query heads over 8 KV heads.
"""

from repro.configs.base import ArchConfig, MambaConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    ref="arXiv:2403.19887",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=("mamba", "mamba", "mamba", "attn",
             "mamba", "mamba", "mamba", "mamba"),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, every=2),
    mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
    mlp="swiglu",
    sliding_window=0,          # long_500k decode: attn layers get SWA variant
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    moment_dtype="bfloat16",
    remat=True,
)

SMOKE = ArchConfig(
    name="jamba-smoke",
    family="hybrid",
    ref=CONFIG.ref,
    n_layers=2,                # one pattern period, reduced
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    pattern=("mamba", "attn"),
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=512, every=2),
    mamba=MambaConfig(d_state=32, d_conv=4, expand=2, head_dim=32,
                      n_groups=1, chunk=64),
)
