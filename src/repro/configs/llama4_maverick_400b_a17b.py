"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + 1 shared,
MoE interleaved every other layer, early-fusion multimodal (text backbone
here; fusion enters via embeddings) [hf:meta-llama/Llama-4-Scout-17B-16E].
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    ref="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=("attn", "attn"),       # period 2: dense layer + MoE layer
    moe=MoEConfig(n_experts=128, top_k=1, d_expert=8192,
                  n_shared=1, d_shared=8192, every=2),
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    moment_dtype="bfloat16",
    remat=True,
)

SMOKE = ArchConfig(
    name="llama4-smoke",
    family="moe",
    ref=CONFIG.ref,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    pattern=("attn", "attn"),
    moe=MoEConfig(n_experts=4, top_k=1, d_expert=256,
                  n_shared=1, d_shared=256, every=2),
)
