"""mamba2-130m [ssm] — pure SSD (state-space duality), attention-free
[arXiv:2405.21060].  ssm_state=128, expand=2, head_dim=64.
"""

from repro.configs.base import ArchConfig, MambaConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    ref="arXiv:2405.21060",
    n_layers=24,
    d_model=768,
    n_heads=0,                  # attention-free
    n_kv_heads=0,
    d_ff=0,                     # mamba blocks have no separate FFN
    vocab_size=50280,
    pattern=("mamba",),
    mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
    tie_embeddings=True,
    param_dtype="float32",      # 130M fits easily; keep f32 like the release
    activ_dtype="float32",
)

SMOKE = ArchConfig(
    name="mamba2-smoke",
    family="ssm",
    ref=CONFIG.ref,
    n_layers=2,
    d_model=128,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    pattern=("mamba",),
    mamba=MambaConfig(d_state=32, d_conv=4, expand=2, head_dim=32,
                      n_groups=1, chunk=64),
    tie_embeddings=True,
)
