"""musicgen-large [audio] — decoder-only LM over EnCodec tokens
[arXiv:2306.05284].

Transformer backbone only (assignment carve-out): the EnCodec conv codec is
a stub; ``input_specs()`` feeds codebook-token ids directly (MusicGen's
native interface is discrete EnCodec codes, vocab 2048).  MHA (kv=32).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    ref="arXiv:2306.05284",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    embed_source="codec",
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat=True,
)

SMOKE = ArchConfig(
    name="musicgen-smoke",
    family="audio",
    ref=CONFIG.ref,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    embed_source="codec",
)
