"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B].

Per-expert FFN hidden 1408; shared-expert hidden 5632 (= 4×1408).
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    ref="hf:Qwen/Qwen1.5-MoE-A2.7B",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408,
                  n_shared=4, d_shared=5632, every=1,
                  pad_to=64),   # 64 divides the 16-wide mesh axes
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat=True,
)

SMOKE = ArchConfig(
    name="qwen2-moe-smoke",
    family="moe",
    ref=CONFIG.ref,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=128,
                  n_shared=1, d_shared=256, every=1),
)
