"""qwen2-vl-2b [vlm] — M-RoPE, dynamic-resolution ViT frontend (stubbed)
[arXiv:2409.12191].

The vision encoder + projector is a stub per the assignment carve-out:
``input_specs()`` supplies pre-projected patch embeddings [B, S, d_model]
plus the 3-component (t, h, w) M-RoPE position ids.  The backbone decoder
(GQA 12H/kv2, M-RoPE sections 24/20/20 frequency pairs of head_dim 128)
is fully implemented.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    ref="arXiv:2409.12191",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mrope_sections=(24, 20, 20),   # t/h/w frequency-pair split of 128/2
    embed_source="patches",
    tie_embeddings=True,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat=True,
)

SMOKE = ArchConfig(
    name="qwen2-vl-smoke",
    family="vlm",
    ref=CONFIG.ref,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    mrope_sections=(6, 5, 5),
    embed_source="patches",
    tie_embeddings=True,
)
