"""qwen3-32b [dense] — qk-RMSNorm, GQA 64H/kv8, head_dim=128
[hf:Qwen/Qwen3-8B family card scaled per assignment]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    ref="hf:Qwen/Qwen3-8B",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,              # qwen3 uses decoupled head_dim (64*128 > d)
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat=True,
)

SMOKE = ArchConfig(
    name="qwen3-smoke",
    family="dense",
    ref=CONFIG.ref,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=512,
    vocab_size=512,
    qk_norm=True,
)
