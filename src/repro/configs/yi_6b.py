"""yi-6b [dense] — llama-architecture GQA 32H/kv4 [arXiv:2403.04652]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    ref="arXiv:2403.04652",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5e6,
    param_dtype="bfloat16",
    activ_dtype="bfloat16",
    remat=True,
)

SMOKE = ArchConfig(
    name="yi-smoke",
    family="dense",
    ref=CONFIG.ref,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
)
