"""Core VARCO library: Definition-1 compressors, schedulers, compressed
collectives and the communication policy/ledger (the paper's primary
contribution, as a composable JAX module)."""

from .compression import (Compressed, Compressor, available_compressors,
                          block_mask_compressor, get_compressor,
                          int8_compressor, random_mask_compressor,
                          topk_compressor)
from .schedulers import (Scheduler, constant, cosine, exponential, fixed_step,
                         linear)
from .varco import (FULL_COMM, NO_COMM, CommLedger, CommPolicy, fixed, varco)

__all__ = [
    "Compressed", "Compressor", "available_compressors",
    "block_mask_compressor", "get_compressor", "int8_compressor",
    "random_mask_compressor", "topk_compressor",
    "Scheduler", "constant", "cosine", "exponential", "fixed_step", "linear",
    "FULL_COMM", "NO_COMM", "CommLedger", "CommPolicy", "fixed", "varco",
]
