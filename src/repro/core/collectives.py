"""Compressed collectives for ``shard_map`` programs.

These are the on-wire primitives of the distributed runtime: every byte the
system communicates between workers flows through one of the wrappers below,
which (i) applies a Definition-1 compressor to the payload *before* the
collective and (ii) returns the exact number of wire bits charged, so the
trainer's ledger reproduces the paper's "floating points communicated" axis
(Fig. 5).

TPU adaptation: the paper's point-to-point sends between adjacent machines
become dense collectives over a mesh axis (see DESIGN.md §3).  Byte
accounting nevertheless charges only the *useful* traffic (compressed
payload × peers), matching how the paper counts communicated floats rather
than transport-level padding.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from .compression import Compressor

Array = jax.Array


def _axis_size(axis_name: str) -> int:
    if hasattr(lax, "axis_size"):              # jax >= 0.5
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)              # jax 0.4.x fallback


def _per_device_key(key: Array, axis_name: str) -> Array:
    """Distinct stream per worker, derived from a key shared a priori."""
    return jax.random.fold_in(key, lax.axis_index(axis_name))


def compressed_all_gather(x: Array, axis_name: str, *, compressor: Compressor,
                          rate: Array, key: Array, axis: int = 0,
                          tiled: bool = False) -> tuple[Array, Array]:
    """All-gather of compressed activations (halo / TP activation exchange).

    Each worker compresses its local block with a worker-specific stream of
    the shared key, then the blocks are gathered.  Every worker's payload
    crosses the wire to ``Q - 1`` peers.

    Returns ``(gathered, wire_bits)`` where ``wire_bits`` is the *global*
    bit count for this exchange (identical on all workers).
    """
    q = _axis_size(axis_name)
    x_tilde, bits = compressor(_per_device_key(key, axis_name), x, rate)
    gathered = lax.all_gather(x_tilde, axis_name, axis=axis, tiled=tiled)
    wire_bits = lax.psum(bits, axis_name) * (q - 1)
    return gathered, wire_bits


def compressed_psum(x, axis_name: str, *, compressor: Compressor,
                    rate: Array, key: Array) -> tuple[Array, Array]:
    """Compressed all-reduce (gradient aggregation over the data axis).

    Each worker compresses its local contribution, then the compressed
    contributions are summed.  With the unbiased mask compressor this is an
    unbiased gradient estimator whose variance anneals to zero under a VARCO
    scheduler.  Ring all-reduce traffic: 2 (Q-1)/Q of the payload per worker.

    ``x`` may be a pytree (e.g. a gradient pytree); a single key is split
    across leaves.
    """
    q = _axis_size(axis_name)
    leaves, treedef = jax.tree_util.tree_flatten(x)
    dev_key = _per_device_key(key, axis_name)
    keys = jax.random.split(dev_key, max(len(leaves), 1))
    out_leaves = []
    bits = jnp.zeros((), jnp.float32)
    for leaf, k in zip(leaves, keys):
        leaf_t, b = compressor(k, leaf, rate)
        out_leaves.append(lax.psum(leaf_t, axis_name))
        bits = bits + b
    ring_factor = 2.0 * (q - 1) / q
    wire_bits = lax.psum(bits, axis_name) * ring_factor
    return jax.tree_util.tree_unflatten(treedef, out_leaves), wire_bits


def compressed_pmean(x, axis_name: str, *, compressor: Compressor,
                     rate: Array, key: Array) -> tuple[Array, Array]:
    """FedAvg-style parameter/gradient averaging (Algorithm 1 'Server' step)."""
    q = _axis_size(axis_name)
    summed, wire_bits = compressed_psum(x, axis_name, compressor=compressor,
                                        rate=rate, key=key)
    return jax.tree_util.tree_map(lambda t: t / q, summed), wire_bits


def compressed_all_to_all(x: Array, axis_name: str, *, compressor: Compressor,
                          rate: Array, key: Array, split_axis: int = 0,
                          concat_axis: int = 0) -> tuple[Array, Array]:
    """Compressed all-to-all (per-peer halo buffers / MoE dispatch).

    ``x``'s ``split_axis`` must equal the axis size ``Q``; slice ``i`` is the
    buffer destined for peer ``i``.  The slice a worker keeps for itself is
    not charged to the wire.
    """
    q = _axis_size(axis_name)
    x_tilde, bits = compressor(_per_device_key(key, axis_name), x, rate)
    out = lax.all_to_all(x_tilde, axis_name, split_axis=split_axis,
                         concat_axis=concat_axis, tiled=False)
    wire_bits = lax.psum(bits, axis_name) * (q - 1) / q
    return out, wire_bits


def uncompressed_bits(x) -> Array:
    """Bits of a pytree at its native dtypes (full-communication baseline)."""
    leaves = jax.tree_util.tree_leaves(x)
    total = 0.0
    for leaf in leaves:
        leaf = jnp.asarray(leaf)
        total += leaf.size * jnp.finfo(leaf.dtype).bits \
            if jnp.issubdtype(leaf.dtype, jnp.floating) \
            else leaf.size * jnp.iinfo(leaf.dtype).bits
    return jnp.asarray(total, jnp.float32)
