"""Compressed collectives for ``shard_map`` programs.

These are the on-wire primitives of the distributed runtime: every byte the
system communicates between workers flows through one of the wrappers below,
which (i) applies a Definition-1 compressor to the payload *before* the
collective and (ii) returns the exact number of wire bits charged, so the
trainer's ledger reproduces the paper's "floating points communicated" axis
(Fig. 5).

TPU adaptation: the paper's point-to-point sends between adjacent machines
become dense collectives over a mesh axis (see DESIGN.md §3).  Byte
accounting nevertheless charges only the *useful* traffic (compressed
payload × peers), matching how the paper counts communicated floats rather
than transport-level padding.

:func:`packed_all_gather` is the exception that actually shrinks the bytes
on the wire: it gathers the ``[B, K·128]`` lane-block-packed payload instead
of the masked dense block, and its bit count is the *transport* charge — the
buffer physically shipped (DESIGN.md §3.3).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from .compression import Compressor

Array = jax.Array


def _axis_size(axis_name: str) -> int:
    if hasattr(lax, "axis_size"):              # jax >= 0.5
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)              # jax 0.4.x fallback


def _per_device_key(key: Array, axis_name: str) -> Array:
    """Distinct stream per worker, derived from a key shared a priori."""
    return jax.random.fold_in(key, lax.axis_index(axis_name))


def compressed_all_gather(x: Array, axis_name: str, *, compressor: Compressor,
                          rate: Array, key: Array, axis: int = 0,
                          tiled: bool = False) -> tuple[Array, Array]:
    """All-gather of compressed activations (halo / TP activation exchange).

    Each worker compresses its local block with a worker-specific stream of
    the shared key, then the blocks are gathered.  Every worker's payload
    crosses the wire to ``Q - 1`` peers.

    Returns ``(gathered, wire_bits)`` where ``wire_bits`` is the *global*
    bit count for this exchange (identical on all workers).
    """
    q = _axis_size(axis_name)
    x_tilde, bits = compressor(_per_device_key(key, axis_name), x, rate)
    gathered = lax.all_gather(x_tilde, axis_name, axis=axis, tiled=tiled)
    wire_bits = lax.psum(bits, axis_name) * (q - 1)
    return gathered, wire_bits


def packed_all_gather(x: Array, axis_name: str, *, key: Array,
                      rate: float | None = None,
                      n_keep: int | None = None) -> tuple[Array, Array]:
    """All-gather of *packed* boundary activations (DESIGN.md §3.3).

    The real reduced-volume wire path: where :func:`compressed_all_gather`
    ships the dense ``[B, F]`` block with dropped entries zeroed (compression
    is ledger accounting only), this packs the kept lane-blocks first so only
    the ``[B, K·128]`` payload crosses the wire, ``K = max(floor((F/128)/r),
    1)``.  Sender packs with :func:`repro.kernels.ops.wire_pack` (Pallas on
    TPU, the jnp ``ref`` oracle elsewhere); every receiver re-derives all
    workers' kept/inverse maps from the shared ``key`` — fold_in(worker)
    exactly as the dense path draws its masks — and unpacks, zero-filling
    dropped blocks.  No index metadata travels (paper App. A); the values
    equal the dense ``blockmask`` round trip bitwise.

    The kept-block count ``K`` shapes the wire buffer, so it must be static:
    pass either ``n_keep`` directly (how the runtime calls it — the rate may
    then stay a traced operand elsewhere in the step) or a static python
    ``rate``, which quantises to ``K = max(floor((F/128)/rate), 1)``.
    ``x.shape[-1]`` must be a multiple of 128.

    Returns ``(gathered [Q, B, F], collective_bits)``.  ``collective_bits``
    counts the buffer the collective physically moves — every worker's
    packed payload, halo-padding rows included, crossing to ``Q - 1`` peers
    (identical on all workers).  Note this is a *collective-level* count;
    the runtime ledger's ``transport_bits`` charge is the point-to-point
    equivalent ``halo_demand × K·128`` instead, so the two are comparable
    across wire formats (DESIGN.md §3.2–3.3).
    """
    from repro.kernels.ops import wire_pack, wire_unpack
    from repro.kernels.varco_pack import LANE, block_mask_indices_k

    f = x.shape[-1]
    if f % LANE:
        raise ValueError(f"packed wire needs F % {LANE} == 0, got F={f}")
    q = _axis_size(axis_name)
    n_blocks = f // LANE
    if n_keep is None:
        if rate is None:
            raise ValueError("pass n_keep or a static rate")
        n_keep = max(int(n_blocks / max(float(rate), 1.0)), 1)
    # every worker's (kept, inv) pair from the shared key — receivers need
    # all of them to decode the gathered buffer
    keys = jax.vmap(jax.random.fold_in, (None, 0))(key, jnp.arange(q))
    kept_all, inv_all = jax.vmap(
        lambda k: block_mask_indices_k(k, n_blocks, n_keep))(keys)
    idx = lax.axis_index(axis_name)
    packed = wire_pack(x, kept_all[idx], inv_all[idx])     # [B, K*128]
    gathered = lax.all_gather(packed, axis_name)           # [Q, B, K*128]
    halo = jax.vmap(wire_unpack)(gathered, kept_all, inv_all)
    payload = packed.size * jnp.finfo(packed.dtype).bits
    wire_bits = jnp.asarray(payload * q * (q - 1), jnp.float32)
    return halo, wire_bits


def compressed_psum(x, axis_name: str, *, compressor: Compressor,
                    rate: Array, key: Array) -> tuple[Array, Array]:
    """Compressed all-reduce (gradient aggregation over the data axis).

    Each worker compresses its local contribution, then the compressed
    contributions are summed.  With the unbiased mask compressor this is an
    unbiased gradient estimator whose variance anneals to zero under a VARCO
    scheduler.  Ring all-reduce traffic: 2 (Q-1)/Q of the payload per worker.

    ``x`` may be a pytree (e.g. a gradient pytree); a single key is split
    across leaves.
    """
    q = _axis_size(axis_name)
    leaves, treedef = jax.tree_util.tree_flatten(x)
    dev_key = _per_device_key(key, axis_name)
    keys = jax.random.split(dev_key, max(len(leaves), 1))
    out_leaves = []
    bits = jnp.zeros((), jnp.float32)
    for leaf, k in zip(leaves, keys):
        leaf_t, b = compressor(k, leaf, rate)
        out_leaves.append(lax.psum(leaf_t, axis_name))
        bits = bits + b
    ring_factor = 2.0 * (q - 1) / q
    wire_bits = lax.psum(bits, axis_name) * ring_factor
    return jax.tree_util.tree_unflatten(treedef, out_leaves), wire_bits


def compressed_pmean(x, axis_name: str, *, compressor: Compressor,
                     rate: Array, key: Array) -> tuple[Array, Array]:
    """FedAvg-style parameter/gradient averaging (Algorithm 1 'Server' step)."""
    q = _axis_size(axis_name)
    summed, wire_bits = compressed_psum(x, axis_name, compressor=compressor,
                                        rate=rate, key=key)
    return jax.tree_util.tree_map(lambda t: t / q, summed), wire_bits


def compressed_all_to_all(x: Array, axis_name: str, *, compressor: Compressor,
                          rate: Array, key: Array, split_axis: int = 0,
                          concat_axis: int = 0) -> tuple[Array, Array]:
    """Compressed all-to-all (per-peer halo buffers / MoE dispatch).

    ``x``'s ``split_axis`` must equal the axis size ``Q``; slice ``i`` is the
    buffer destined for peer ``i``.  The slice a worker keeps for itself is
    not charged to the wire.
    """
    q = _axis_size(axis_name)
    x_tilde, bits = compressor(_per_device_key(key, axis_name), x, rate)
    out = lax.all_to_all(x_tilde, axis_name, split_axis=split_axis,
                         concat_axis=concat_axis, tiled=False)
    wire_bits = lax.psum(bits, axis_name) * (q - 1) / q
    return out, wire_bits


def uncompressed_bits(x) -> Array:
    """Bits of a pytree at its native dtypes (full-communication baseline)."""
    leaves = jax.tree_util.tree_leaves(x)
    total = 0.0
    for leaf in leaves:
        leaf = jnp.asarray(leaf)
        total += leaf.size * jnp.finfo(leaf.dtype).bits \
            if jnp.issubdtype(leaf.dtype, jnp.floating) \
            else leaf.size * jnp.iinfo(leaf.dtype).bits
    return jnp.asarray(total, jnp.float32)
