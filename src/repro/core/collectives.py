"""Compressed collectives for ``shard_map`` programs.

These are the on-wire primitives of the distributed runtime: every byte the
system communicates between workers flows through one of the wrappers below,
which (i) applies a Definition-1 compressor to the payload *before* the
collective and (ii) returns the exact number of wire bits charged, so the
trainer's ledger reproduces the paper's "floating points communicated" axis
(Fig. 5).

TPU adaptation: the paper's point-to-point sends between adjacent machines
become dense collectives over a mesh axis (see DESIGN.md §3).  Byte
accounting nevertheless charges only the *useful* traffic (compressed
payload × peers), matching how the paper counts communicated floats rather
than transport-level padding.

:func:`packed_all_gather` is the exception that actually shrinks the bytes
on the wire: it gathers the ``[B, K·128]`` lane-block-packed payload instead
of the masked dense block, and its bit count is the *transport* charge — the
buffer physically shipped (DESIGN.md §3.3).  :func:`neighbor_exchange` goes
further (DESIGN.md §3.5): a ``ppermute`` ring that ships each peer only the
halo rows it actually references, so transport equals the paper's analytic
point-to-point edge-cut charge instead of ``O(Q·B)``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from .compression import Compressor

Array = jax.Array


def _axis_size(axis_name: str) -> int:
    if hasattr(lax, "axis_size"):              # jax >= 0.5
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)              # jax 0.4.x fallback


def _per_device_key(key: Array, axis_name: str) -> Array:
    """Distinct stream per worker, derived from a key shared a priori."""
    return jax.random.fold_in(key, lax.axis_index(axis_name))


def _ppermute_grad_carrier(x: Array, axis_name: str, perm) -> Array:
    """Zero-valued forward whose VJP is the inverse-ring ``ppermute``.

    The sub-byte wire ships integer bytes + scales, which carry no
    gradient; the receiver's value is rebuilt as ``stop_gradient(decode)
    + carrier(rows)``, so the cotangent still rides the ring backward
    into the sender's pre-quantisation rows — exactly the
    straight-through estimator the fp32 value path realises with
    ``ppermute(wire_quant(rows))``, at zero extra forward traffic.
    """
    @jax.custom_vjp
    def carrier(v):
        return jnp.zeros_like(v)

    def fwd(v):
        return jnp.zeros_like(v), None

    def bwd(_, g):
        inv = [(dst, src) for (src, dst) in perm]
        return (lax.ppermute(g, axis_name, inv),)

    carrier.defvjp(fwd, bwd)
    return carrier(x)


def _all_gather_grad_carrier(x: Array, axis_name: str) -> Array:
    """Zero-valued ``[Q, *x.shape]`` forward whose VJP is the all-gather
    transpose (each worker keeps the summed cotangent of its own slice)
    — the gradient half of the sub-byte all-gather wire."""
    q = _axis_size(axis_name)

    @jax.custom_vjp
    def carrier(v):
        return jnp.zeros((q,) + v.shape, v.dtype)

    def fwd(v):
        return jnp.zeros((q,) + v.shape, v.dtype), None

    def bwd(_, g):
        return (lax.psum(g, axis_name)[lax.axis_index(axis_name)],)

    carrier.defvjp(fwd, bwd)
    return carrier(x)


def compressed_all_gather(x: Array, axis_name: str, *, compressor: Compressor,
                          rate: Array, key: Array, axis: int = 0,
                          tiled: bool = False) -> tuple[Array, Array]:
    """All-gather of compressed activations (halo / TP activation exchange).

    Each worker compresses its local block with a worker-specific stream of
    the shared key, then the blocks are gathered.  Every worker's payload
    crosses the wire to ``Q - 1`` peers.

    Returns ``(gathered, wire_bits)`` where ``wire_bits`` is the *global*
    bit count for this exchange (identical on all workers).
    """
    q = _axis_size(axis_name)
    x_tilde, bits = compressor(_per_device_key(key, axis_name), x, rate)
    gathered = lax.all_gather(x_tilde, axis_name, axis=axis, tiled=tiled)
    wire_bits = lax.psum(bits, axis_name) * (q - 1)
    return gathered, wire_bits


def packed_all_gather(x: Array, axis_name: str, *, key: Array,
                      rate: float | None = None,
                      n_keep: int | None = None,
                      pair_k: Array | None = None,
                      pair_w: Array | None = None,
                      rounding: str = "rint",
                      store_w: int = 0,
                      wire_out: list | None = None) -> tuple[Array, Array]:
    """All-gather of *packed* boundary activations (DESIGN.md §3.3).

    The real reduced-volume wire path: where :func:`compressed_all_gather`
    ships the dense ``[B, F]`` block with dropped entries zeroed (compression
    is ledger accounting only), this packs the kept lane-blocks first so only
    the ``[B, K·128]`` payload crosses the wire, ``K = max(floor((F/128)/r),
    1)``.  Sender packs with :func:`repro.kernels.ops.wire_pack` (Pallas on
    TPU, the jnp ``ref`` oracle elsewhere); every receiver re-derives all
    workers' kept/inverse maps from the shared ``key`` — fold_in(worker)
    exactly as the dense path draws its masks — and unpacks, zero-filling
    dropped blocks.  No index metadata travels (paper App. A); the values
    equal the dense ``blockmask`` round trip bitwise.

    The kept-block count ``K`` shapes the wire buffer, so it must be static:
    pass either ``n_keep`` directly (how the runtime calls it — the rate may
    then stay a traced operand elsewhere in the step) or a static python
    ``rate``, which quantises to ``K = max(floor((F/128)/rate), 1)``.
    ``x.shape[-1]`` must be a multiple of 128.

    ``pair_k`` (traced ``[Q, Q]`` receiver × sender kept-block counts,
    DESIGN.md §3.6) realises a per-pair rate map at this wire's native
    granularity — per *sender*: one payload serves every receiver, so
    sender ``j`` keeps ``max_i pair_k[i, j]`` blocks (the most demanding
    receiver) by zeroing its packed columns whose block sits at permutation
    position ``>=`` that count (kept sets are nested under one key, so the
    zeroed round trip matches the dense ``blockmask`` at the realised rate
    bitwise).  ``n_keep`` must then be the map's static maximum.

    ``pair_w`` (traced ``[Q, Q]`` receiver × sender bit-widths,
    DESIGN.md §3.8; requires ``pair_k``) adds the second wire axis at the
    same per-*sender* granularity: one payload serves every receiver, so
    sender ``j`` quantises its surviving packed columns at ``max_i
    pair_w[i, j]`` bits (the most demanding receiver's width) via the
    straight-through codec, and the collective count charges the payload
    at that width plus the fp32 block scales
    (:func:`repro.kernels.ops.per_block_wire_bits`).

    ``store_w`` (static, requires ``pair_w``) switches the collective to
    **true sub-byte storage** (DESIGN.md §3.8): every off-diagonal
    snapped width is sub-32, so the sender ships bit-packed uint8 levels
    (``8/store_w`` lanes per byte at the step's static storage width —
    the max snapped width — plus the fp32 block scales) instead of the
    fp32 straight-through values, and each receiver rebuilds
    ``levels · scale`` from the bytes.  Gradients ride
    :func:`_all_gather_grad_carrier`.  ``store_w == 0`` keeps the exact
    fp32 value path (any pair at width ≥ 32 forces it).  ``wire_out``,
    when a list, captures the physically gathered ``(payload, scales)``
    buffers — the ledger-vs-buffer conservation hook.

    Returns ``(gathered [Q, B, F], collective_bits)``.  ``collective_bits``
    counts the buffer the collective physically moves — every worker's
    packed payload, halo-padding rows included, crossing to ``Q - 1`` peers
    (identical on all workers).  Note this is a *collective-level* count;
    the runtime ledger's ``transport_bits`` charge is the point-to-point
    equivalent ``halo_demand × K·128`` instead, so the two are comparable
    across wire formats (DESIGN.md §3.2–3.3).
    """
    from repro.kernels.ops import (dequant_bits, pack_bits,
                                   per_block_wire_bits, quant_levels,
                                   wire_pack, wire_quant, wire_unpack)
    from repro.kernels.varco_pack import (LANE, worker_block_maps,
                                          worker_block_maps_pos)

    if pair_w is not None and pair_k is None:
        raise ValueError("pair_w needs pair_k (widths ride the rate map)")
    if store_w and pair_w is None:
        raise ValueError("store_w (sub-byte storage) rides the width map; "
                         "pass pair_w alongside it")
    f = x.shape[-1]
    if f % LANE:
        raise ValueError(f"packed wire needs F % {LANE} == 0, got F={f}")
    q = _axis_size(axis_name)
    n_blocks = f // LANE
    if n_keep is None:
        if rate is None:
            raise ValueError("pass n_keep or a static rate")
        n_keep = max(int(n_blocks / max(float(rate), 1.0)), 1)
    # every worker's (kept, inv) pair from the shared key — receivers need
    # all of them to decode the gathered buffer
    idx = lax.axis_index(axis_name)
    if pair_k is None:
        kept_all, inv_all = worker_block_maps(key, q, n_blocks, n_keep)
        packed = wire_pack(x, kept_all[idx], inv_all[idx])   # [B, K*128]
    else:
        kept_all, inv_all, pos_all = worker_block_maps_pos(key, q, n_blocks,
                                                           n_keep)
        off = jnp.where(jnp.eye(q, dtype=bool), 0, pair_k)
        k_send = jnp.maximum(jnp.max(off, axis=0), 1)        # [Q]
        packed = wire_pack(x, kept_all[idx], inv_all[idx])
        pos_kept = pos_all[idx][kept_all[idx]]               # [K]
        cmask = (pos_kept < k_send[idx]).astype(x.dtype)
        packed = packed * jnp.repeat(cmask, LANE)[None, :]
        if pair_w is not None:
            from repro.kernels.ops import round_key
            off_w = jnp.where(jnp.eye(q, dtype=bool), 0.0, pair_w)
            w_send = jnp.max(off_w, axis=0)                  # [Q]
            w_send = jnp.where(w_send > 0.0, w_send, 32.0)   # Q==1: no wire
            rk = round_key(key, idx) if rounding == "stochastic" else None
            if store_w:
                # sub-byte wire: bit-packed levels + scales cross, the
                # value is rebuilt receiver-side from the bytes alone
                levels, scales = quant_levels(packed, w_send[idx], key=rk)
                payload = pack_bits(levels, store_w)
                g_payload = lax.all_gather(payload, axis_name)
                g_scales = lax.all_gather(scales, axis_name)
                if wire_out is not None:
                    wire_out.append((g_payload, g_scales))
                dq = dequant_bits(g_payload, g_scales, store_w)
                gathered = lax.stop_gradient(dq) + \
                    _all_gather_grad_carrier(packed, axis_name)
                halo = jax.vmap(wire_unpack)(gathered, kept_all, inv_all)
                bits = packed.shape[0] * n_keep * \
                    per_block_wire_bits(w_send[idx])
                return halo, lax.psum(bits, axis_name) * (q - 1)
            packed = wire_quant(packed, w_send[idx], key=rk)
    gathered = lax.all_gather(packed, axis_name)           # [Q, B, K*128]
    if wire_out is not None:
        wire_out.append((gathered, None))
    halo = jax.vmap(wire_unpack)(gathered, kept_all, inv_all)
    if pair_w is not None:
        payload = packed.shape[0] * n_keep * \
            per_block_wire_bits(w_send[idx])
        wire_bits = lax.psum(payload, axis_name) * (q - 1)
    else:
        payload = packed.size * jnp.finfo(packed.dtype).bits
        wire_bits = jnp.asarray(payload * q * (q - 1), jnp.float32)
    return halo, wire_bits


def neighbor_exchange(publish: Array, send_slot: Array, send_valid: Array,
                      axis_name: str, *, key: Array | None = None,
                      n_keep: int | None = None,
                      pair_k: Array | None = None,
                      pair_w: Array | None = None,
                      store_w: int = 0,
                      wire_out: list | None = None) -> tuple[Array, Array]:
    """Neighbor-only p2p halo exchange over a ``ppermute`` ring (§3.5).

    Where :func:`packed_all_gather` ships every worker's whole boundary
    block to all ``Q - 1`` peers, this runs ``Q - 1`` ring offsets: at
    offset ``d`` worker ``j`` sends *only* the rows worker ``(j+d) mod Q``
    actually references (the per-pair halo sets of
    ``repro.dist.halo.halo_arrays``) via ``lax.ppermute``.  Transport is
    the edge-cut rows — the paper's analytic point-to-point charge — not
    ``O(Q·B)``.  Each hop is an independent collective with no data
    dependence on the caller's local compute, so XLA overlaps the transfers
    with whatever runs alongside (the ELL local aggregation in
    ``repro.dist.gnn_parallel``).  Gradients flow: the VJP of ``ppermute``
    is the inverted-permutation ``ppermute``, so cotangents ride the same
    neighbor-only ring backward.

    ``publish [B, F]`` is the worker's boundary block (its ``send_idx``
    rows, invalid rows zeroed);  ``send_slot``/``send_valid [Q-1, H]``
    hold, per offset, the *boundary slots* to ship and their 0/1 padding
    mask.  With ``n_keep`` (static kept-lane-block count) the sender packs
    its boundary block **once** to ``[B, n_keep·128]`` via
    :func:`repro.kernels.ops.wire_pack` under its ``fold_in(key, sender)``
    mask — the same per-worker streams the all-gather wires draw — then
    slices every hop buffer out of the packed rows; receivers unpack with
    the sender's inverse map re-derived from the shared ``key`` (no index
    metadata on the wire).

    ``pair_k`` (traced ``[Q, Q]`` receiver × sender kept-block counts,
    DESIGN.md §3.6) realises a per-pair rate map *exactly* on this wire:
    hop ``d``'s buffer from sender ``j`` is masked down to receiver
    ``(j+d) mod Q``'s own kept count before the ``ppermute`` (the nested
    column masks of ``block_mask_indices_pos``), so every ordered pair
    travels at its own rate.  ``n_keep`` must then be the map's static
    maximum, and ``wire_bits`` charges each pair its own kept columns.

    ``pair_w`` (traced ``[Q, Q]`` receiver × sender bit-widths, requires
    ``pair_k``; DESIGN.md §3.8) quantises hop ``d``'s buffer at receiver
    ``(j+d) mod Q``'s own width before the ``ppermute`` — the p2p wire
    realises the full 2-D rate × width map *exactly* per ordered pair —
    and ``wire_bits`` charges each pair its kept blocks at
    :func:`repro.kernels.ops.per_block_wire_bits` (payload at width +
    fp32 scales; width 32 reproduces the fp32 charge bit-for-bit).

    Returns ``(compact, wire_bits)``: ``compact [(Q-1)·H, F]`` stacks the
    received hops (offset ``d`` at rows ``[(d-1)·H, d·H)``; ``[1, F]``
    zeros when ``Q == 1``), and ``wire_bits`` counts the genuine rows
    shipped ring-wide × on-wire columns — which equals
    ``halo_demand × width × 32`` (identical on all workers).
    """
    hops, wire_bits = neighbor_exchange_start(
        publish, send_slot, send_valid, axis_name, key=key, n_keep=n_keep,
        pair_k=pair_k, pair_w=pair_w, store_w=store_w, wire_out=wire_out)
    compact = neighbor_exchange_finish(hops, axis_name, key=key,
                                       n_keep=n_keep, f=publish.shape[-1])
    return compact, wire_bits


def neighbor_exchange_start(publish: Array, send_slot: Array,
                            send_valid: Array, axis_name: str, *,
                            key: Array | None = None,
                            n_keep: int | None = None,
                            pair_k: Array | None = None,
                            pair_w: Array | None = None,
                            resid: Array | None = None,
                            resid_out: list | None = None,
                            rounding: str = "rint",
                            store_w: int = 0,
                            wire_out: list | None = None
                            ) -> tuple[Array, Array]:
    """Issue half of :func:`neighbor_exchange`: pack the boundary block
    once, mask each hop to its pair's kept columns, and run all ``Q - 1``
    ``ppermute`` hops — but do **not** unpack.  Returns ``(hops [D, H,
    width], wire_bits)`` where the hop rows are still in the on-wire
    (packed) format.

    This is the prefetch entry point of the pipelined forward
    (DESIGN.md §3.7): the caller issues the exchange, schedules its local
    compute, and only then calls :func:`neighbor_exchange_finish` — the
    sole consumer of the received buffers — so XLA's latency-hiding
    scheduler can keep the hops in flight behind the local work, and the
    explicit data dependence on the wire is confined to the unpack.

    ``resid`` (``[D, H, F]``, requires ``pair_w``) is this worker's
    error-feedback residual state (DESIGN.md §3.8): hop ``d``'s residual
    rows are packed onto the sender's kept set, masked to the pair's live
    columns/rows, and added to the pre-quantisation payload under
    ``stop_gradient``; the fresh per-hop quantisation error is unpacked
    back to ``[D, H, F]`` and appended to ``resid_out`` — the same
    sender-major state layout as the emulated backend, so the two
    backends' EF caches stay ≤ 1e-6 apart under the parity suite.

    ``rounding`` selects the quantiser's rounding mode: deterministic
    ``"rint"`` (default) or ``"stochastic"``, which draws each hop's
    uniforms from :func:`repro.kernels.ops.round_key` ``(key, me, d-1)``
    — the same per-(sender, hop) streams the emulated backend vmaps
    over, so both backends round identically.

    ``store_w`` (static, requires ``pair_w``) switches every hop to
    **true sub-byte storage**: the buffer that rides the ``ppermute`` is
    the bit-packed uint8 levels (``8/store_w`` lanes per byte at the
    step's static storage width — the max snapped sub-32 width; pairs
    quantised *below* it store exactly since their levels fit the wider
    field) plus the fp32 block scales — ``ceil(k·128·w/8)`` bytes per
    kept block per row instead of ``k·128`` fp32 lanes.  The receiver
    rebuilds ``levels · scale`` from the bytes; gradients ride
    :func:`_ppermute_grad_carrier`.  ``store_w == 0`` keeps the exact
    fp32 value path (any pair at width ≥ 32 forces it).  ``wire_out``,
    when a list, captures each hop's physically received ``(payload,
    scales)`` — the ledger-vs-buffer conservation hook (fp32 hops append
    ``(rows, None)``).
    """
    if pair_k is not None and n_keep is None:
        raise ValueError("pair_k needs n_keep (the map's static maximum)")
    if pair_w is not None and pair_k is None:
        raise ValueError("pair_w needs pair_k (widths ride the rate map)")
    if store_w and pair_w is None:
        raise ValueError("store_w (sub-byte storage) rides the width map; "
                         "pass pair_w alongside it")
    if resid is not None and pair_w is None:
        raise ValueError("error-feedback residuals ride the quantised "
                         "wire; pass pair_w alongside resid")
    q = _axis_size(axis_name)
    f = publish.shape[-1]
    if q == 1:
        if resid is not None and resid_out is not None:
            resid_out.append(resid)     # no wire at Q == 1: state carries
        return (jnp.zeros((1, 1, f), publish.dtype),
                jnp.zeros((), jnp.float32))
    width = f
    kept_all = inv_all = pos_kept_me = None
    if n_keep is not None:
        from repro.kernels.ops import wire_pack
        from repro.kernels.varco_pack import (LANE, worker_block_maps,
                                              worker_block_maps_pos)
        if f % LANE:
            raise ValueError(f"packed p2p hops need F % {LANE} == 0, "
                             f"got F={f}")
        if key is None:
            raise ValueError("n_keep needs the shared exchange key")
        width = n_keep * LANE
        if pair_k is None:
            kept_all, inv_all = worker_block_maps(key, q, f // LANE, n_keep)
        else:
            kept_all, inv_all, pos_all = worker_block_maps_pos(
                key, q, f // LANE, n_keep)
    me = lax.axis_index(axis_name)
    if n_keep is not None:
        publish = wire_pack(publish, kept_all[me], inv_all[me])
        if pair_k is not None:
            pos_kept_me = pos_all[me][kept_all[me]]          # [K]

    hops = []
    errs = []
    bits = jnp.zeros((), jnp.float32)
    for d in range(1, q):
        perm = [(j, (j + d) % q) for j in range(q)]
        rows = publish[send_slot[d - 1]] * send_valid[d - 1][:, None]
        if pair_k is not None:
            recv = (me + d) % q
            k_pair = pair_k[recv, me]
            cmask = (pos_kept_me < k_pair).astype(rows.dtype)
            rows = rows * jnp.repeat(cmask, LANE)[None, :]
            if pair_w is not None:
                from repro.kernels.ops import (dequant_bits, pack_bits,
                                               per_block_wire_bits,
                                               quant_levels, round_key,
                                               wire_quant, wire_unpack)
                if resid is not None:
                    # error feedback: last step's residual packed onto
                    # this call's kept set, masked to the pair's live
                    # columns/rows, injected before quantising
                    r_rows = wire_pack(resid[d - 1], kept_all[me],
                                       inv_all[me])
                    r_rows = r_rows * jnp.repeat(cmask, LANE)[None, :] * \
                        send_valid[d - 1][:, None]
                    rows = rows + lax.stop_gradient(r_rows)
                rk = round_key(key, me, d - 1) \
                    if rounding == "stochastic" else None
                blk_bits = per_block_wire_bits(pair_w[recv, me])
                bits = bits + jnp.sum(send_valid[d - 1]) * \
                    k_pair.astype(jnp.float32) * blk_bits
                if store_w:
                    # sub-byte wire: the ppermute carries bit-packed
                    # levels + fp32 scales; the receiver rebuilds
                    # levels · scale from the bytes alone
                    levels, scales = quant_levels(rows, pair_w[recv, me],
                                                  key=rk)
                    payload = pack_bits(levels, store_w)
                    if resid is not None:
                        dq_send = dequant_bits(payload, scales, store_w)
                        err = lax.stop_gradient(rows - dq_send)
                        errs.append(wire_unpack(err, kept_all[me],
                                                inv_all[me]))
                    p_payload = lax.ppermute(payload, axis_name, perm)
                    p_scales = lax.ppermute(scales, axis_name, perm)
                    if wire_out is not None:
                        wire_out.append((p_payload, p_scales))
                    dq = dequant_bits(p_payload, p_scales, store_w)
                    hops.append(lax.stop_gradient(dq) +
                                _ppermute_grad_carrier(rows, axis_name,
                                                       perm))
                    continue
                rows_q = wire_quant(rows, pair_w[recv, me], key=rk)
                if resid is not None:
                    err = lax.stop_gradient(rows - rows_q)
                    errs.append(wire_unpack(err, kept_all[me],
                                            inv_all[me]))
                rows = rows_q
            else:
                bits = bits + jnp.sum(send_valid[d - 1]) * \
                    k_pair.astype(jnp.float32) * (LANE * 32.0)
        rows = lax.ppermute(rows, axis_name, perm)
        if wire_out is not None:
            wire_out.append((rows, None))
        hops.append(rows)
    if errs and resid_out is not None:
        resid_out.append(jnp.stack(errs))          # [D, H, F] sender-major
    if pair_k is not None:
        wire_bits = lax.psum(bits, axis_name)
    else:
        wire_bits = lax.psum(jnp.sum(send_valid), axis_name) * width * 32.0
    return jnp.stack(hops), wire_bits


def neighbor_exchange_finish(hops: Array, axis_name: str, *,
                             key: Array | None = None,
                             n_keep: int | None = None,
                             f: int | None = None) -> Array:
    """Completion half of :func:`neighbor_exchange`: unpack each received
    hop with its sender's inverse map (re-derived from the shared ``key``
    — hop ``d``'s rows came from worker ``me - d``) and stack the hops
    into the compact ``[(Q-1)·H, F]`` halo buffer.  ``f`` is the original
    feature width (required when ``n_keep`` packed the hops)."""
    q = _axis_size(axis_name)
    if q == 1:
        return jnp.zeros((1, hops.shape[-1]), hops.dtype)
    if n_keep is None:
        return hops.reshape(-1, hops.shape[-1])
    from repro.kernels.ops import wire_unpack
    from repro.kernels.varco_pack import LANE, worker_block_maps
    if f is None:
        raise ValueError("packed hops need f (the unpacked feature width)")
    me = lax.axis_index(axis_name)
    kept_all, inv_all = worker_block_maps(key, q, f // LANE, n_keep)
    out = []
    for d in range(1, q):
        src = (me - d) % q          # hop d's rows came from worker me - d
        out.append(wire_unpack(hops[d - 1], kept_all[src], inv_all[src]))
    return jnp.concatenate(out, axis=0)


def compressed_psum(x, axis_name: str, *, compressor: Compressor,
                    rate: Array, key: Array) -> tuple[Array, Array]:
    """Compressed all-reduce (gradient aggregation over the data axis).

    Each worker compresses its local contribution, then the compressed
    contributions are summed.  With the unbiased mask compressor this is an
    unbiased gradient estimator whose variance anneals to zero under a VARCO
    scheduler.  Ring all-reduce traffic: 2 (Q-1)/Q of the payload per worker.

    ``x`` may be a pytree (e.g. a gradient pytree); a single key is split
    across leaves.
    """
    q = _axis_size(axis_name)
    leaves, treedef = jax.tree_util.tree_flatten(x)
    dev_key = _per_device_key(key, axis_name)
    keys = jax.random.split(dev_key, max(len(leaves), 1))
    out_leaves = []
    bits = jnp.zeros((), jnp.float32)
    for leaf, k in zip(leaves, keys):
        leaf_t, b = compressor(k, leaf, rate)
        out_leaves.append(lax.psum(leaf_t, axis_name))
        bits = bits + b
    ring_factor = 2.0 * (q - 1) / q
    wire_bits = lax.psum(bits, axis_name) * ring_factor
    return jax.tree_util.tree_unflatten(treedef, out_leaves), wire_bits


def compressed_pmean(x, axis_name: str, *, compressor: Compressor,
                     rate: Array, key: Array) -> tuple[Array, Array]:
    """FedAvg-style parameter/gradient averaging (Algorithm 1 'Server' step)."""
    q = _axis_size(axis_name)
    summed, wire_bits = compressed_psum(x, axis_name, compressor=compressor,
                                        rate=rate, key=key)
    return jax.tree_util.tree_map(lambda t: t / q, summed), wire_bits


def compressed_all_to_all(x: Array, axis_name: str, *, compressor: Compressor,
                          rate: Array, key: Array, split_axis: int = 0,
                          concat_axis: int = 0) -> tuple[Array, Array]:
    """Compressed all-to-all (per-peer halo buffers / MoE dispatch).

    ``x``'s ``split_axis`` must equal the axis size ``Q``; slice ``i`` is the
    buffer destined for peer ``i``.  The slice a worker keeps for itself is
    not charged to the wire.
    """
    q = _axis_size(axis_name)
    x_tilde, bits = compressor(_per_device_key(key, axis_name), x, rate)
    out = lax.all_to_all(x_tilde, axis_name, split_axis=split_axis,
                         concat_axis=concat_axis, tiled=False)
    wire_bits = lax.psum(bits, axis_name) * (q - 1) / q
    return out, wire_bits


def uncompressed_bits(x) -> Array:
    """Bits of a pytree at its native dtypes (full-communication baseline)."""
    leaves = jax.tree_util.tree_leaves(x)
    total = 0.0
    for leaf in leaves:
        leaf = jnp.asarray(leaf)
        total += leaf.size * jnp.finfo(leaf.dtype).bits \
            if jnp.issubdtype(leaf.dtype, jnp.floating) \
            else leaf.size * jnp.iinfo(leaf.dtype).bits
    return jnp.asarray(total, jnp.float32)
