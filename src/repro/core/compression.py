"""Compression / decompression mechanisms (paper Definition 1).

A compressor is a pair ``(g, g_inv)`` parameterised by a compression *ratio*
``r >= 1``: ``g`` maps a tensor ``x`` to a compressed representation ``z``
carrying ``size(x) / r`` payload elements, ``g_inv`` reconstructs ``x_tilde``
with ``E[
|x_tilde - x|] <= delta`` and ``E[|x_tilde - x|^2] <= eps(r)^2``
(Definition 1).  ``eps`` is monotone increasing in ``r`` and ``eps(1) = 0``.

The paper's concrete mechanism (Appendix): communicate a uniformly random
subset of ``n/r`` elements; the decoder — which shares the random key a
priori — scatters them back and zero-fills the rest.  On TPU we realise the
identical semantics as a shared-PRNG Bernoulli(1/r) element mask followed by
a dense pack of kept lanes (see kernels/varco_pack.py for the packing
kernel).  Because encoder and decoder derive the mask from the same
``jax.random`` key, no index metadata travels on the wire — exactly the
paper's "random key generator is shared a priori".

Beyond-paper compressors implementing the same interface:

* ``blockmask`` — the TPU-native lane-block variant of the paper mask: the
  shared PRNG keeps ``K = (F/128)/r`` whole 128-lane feature blocks.  Its
  kept set is bitwise identical to
  :func:`repro.kernels.varco_pack.block_mask_indices`, so the dense
  round-trip here equals the **packed wire path** (pack → all-gather →
  unpack, DESIGN.md §3.3) value-for-value — this compressor is the dense
  reference the packed transport is tested against.
* ``topk``      — magnitude top-k per row (needs index metadata: accounted).
* ``int8``      — per-row affine int8 quantisation (r = 4 for f32 payloads).
* ``randmask_unbiased`` — paper mask rescaled by ``r`` so that
  ``E[x_tilde] = x`` (delta = 0, first-order lossless).

All compressors are differentiable in ``x`` (straight-through for the index
selection, exact for the mask multiply), so gradients back-propagate
"across machines and through the differentiable compression routine"
(Algorithm 1).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Compressed representation
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Compressed:
    """Wire representation of a compressed tensor.

    ``payload`` is what actually crosses the network.  ``meta`` holds
    side-band tensors (e.g. top-k indices, quantisation scales) that also
    cross the wire and are charged to the byte ledger.  ``aux`` holds
    *free* decoder state shared a priori (PRNG-derived masks), charged zero
    bytes per the paper's shared-key protocol.
    """

    payload: Array
    meta: dict
    aux: dict

    def tree_flatten(self):
        meta_keys = tuple(sorted(self.meta))
        aux_keys = tuple(sorted(self.aux))
        children = (self.payload, tuple(self.meta[k] for k in meta_keys),
                    tuple(self.aux[k] for k in aux_keys))
        return children, (meta_keys, aux_keys)

    @classmethod
    def tree_unflatten(cls, static, children):
        meta_keys, aux_keys = static
        payload, meta_vals, aux_vals = children
        return cls(payload, dict(zip(meta_keys, meta_vals)),
                   dict(zip(aux_keys, aux_vals)))

    def wire_bits(self) -> Array:
        """Number of bits that cross the network for this message."""
        bits = jnp.asarray(0, jnp.float32)
        for t in (self.payload, *self.meta.values()):
            t = jnp.asarray(t)
            bits = bits + jnp.asarray(t.size * jnp.finfo(t.dtype).bits
                                      if jnp.issubdtype(t.dtype, jnp.floating)
                                      else t.size * jnp.iinfo(t.dtype).bits,
                                      jnp.float32)
        return bits


def _nbits(dtype) -> int:
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.finfo(dtype).bits
    return jnp.iinfo(dtype).bits


# ---------------------------------------------------------------------------
# Compressor interface
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Definition-1 compression mechanism.

    ``compress(key, x, rate)`` -> ``(x_tilde, wire_bits)`` where ``x_tilde``
    is the compress->decompress round trip (what the receiving machine sees)
    and ``wire_bits`` the traffic charged for it.  ``rate`` is a traced
    scalar so VARCO can anneal it without recompilation.
    """

    name: str
    fn: Callable[[Array, Array, Array], tuple[Array, Array]]
    # expected squared relative error  E||x~ - x||^2 / ||x||^2  as fn of rate
    eps2: Callable[[Array], Array]

    def __call__(self, key: Array, x: Array, rate: Array) -> tuple[Array, Array]:
        return self.fn(key, x, rate)


# -- paper mechanism: shared-PRNG random element subset ---------------------


def _random_mask(key: Array, x: Array, rate: Array, unbiased: bool
                 ) -> tuple[Array, Array]:
    """Keep each element independently w.p. 1/rate (paper Appendix).

    ``rate`` may be a traced float >= 1.  rate == 1 keeps everything
    (lossless, zero compression).  The decoder shares ``key`` a priori, so
    only the kept payload elements are charged to the wire.
    """
    rate = jnp.maximum(jnp.asarray(rate, jnp.float32), 1.0)
    keep_p = 1.0 / rate
    mask = jax.random.bernoulli(key, keep_p, x.shape)
    scale = jnp.where(jnp.asarray(unbiased), rate, 1.0).astype(x.dtype)
    x_tilde = jnp.where(mask, x * scale, jnp.zeros((), x.dtype))
    bits = jnp.sum(mask) * _nbits(x.dtype)
    return x_tilde, jnp.asarray(bits, jnp.float32)


def random_mask_compressor(unbiased: bool = False) -> Compressor:
    name = "randmask_unbiased" if unbiased else "randmask"
    if unbiased:
        eps2 = lambda r: jnp.maximum(r - 1.0, 0.0)          # Var of 1/p scaling
    else:
        eps2 = lambda r: 1.0 - 1.0 / jnp.maximum(r, 1.0)     # E mask miss
    return Compressor(name, partial(_random_mask, unbiased=unbiased), eps2)


# -- lane-block mask (the packed-wire mechanism, dense round-trip form) ------


LANE = 128


def _block_mask(key: Array, x: Array, rate: Array) -> tuple[Array, Array]:
    """Keep ``K = max(floor((F/128)/rate), 1)`` whole 128-lane blocks.

    The kept set derives from ``jax.random.permutation(key, F/128)`` exactly
    as :func:`repro.kernels.varco_pack.block_mask_indices` does, so for the
    same key this round trip is bitwise identical to the packed wire path
    (``wire_unpack(wire_pack(x))``).  ``rate`` may be traced: the block
    *count* is computed with jnp arithmetic, only shapes stay static.

    Requires ``x.shape[-1] % 128 == 0`` — this is an activation-wire
    compressor; feature widths off the lane grid cannot ride the packed
    wire either.
    """
    f = x.shape[-1]
    if f % LANE:
        raise ValueError(
            f"blockmask needs a feature width divisible by {LANE}, got {f}; "
            "use 'randmask' for off-lane-grid payloads")
    nb = f // LANE
    rate = jnp.maximum(jnp.asarray(rate, jnp.float32), 1.0)
    # floor matches block_mask_indices' int() truncation for positive values
    k = jnp.maximum(jnp.floor(nb / rate), 1.0)
    perm = jax.random.permutation(key, nb)
    pos = jnp.zeros((nb,), jnp.int32).at[perm].set(
        jnp.arange(nb, dtype=jnp.int32))
    keep = pos < k                                   # block b kept iff its
    xb = x.reshape(x.shape[:-1] + (nb, LANE))        # permutation slot < K
    x_tilde = jnp.where(keep[:, None], xb, jnp.zeros((), x.dtype))
    x_tilde = x_tilde.reshape(x.shape)
    rows = x.size // f
    bits = k * LANE * rows * _nbits(x.dtype)
    return x_tilde, jnp.asarray(bits, jnp.float32)


def block_mask_compressor() -> Compressor:
    # block-granular subsetting of exchangeable coordinates keeps the
    # element-mask error envelope: eps^2(r) = 1 - 1/r (DESIGN.md §3.3)
    return Compressor("blockmask", _block_mask,
                      lambda r: 1.0 - 1.0 / jnp.maximum(r, 1.0))


# -- magnitude top-k ---------------------------------------------------------


def _topk(key: Array, x: Array, rate: Array) -> tuple[Array, Array]:
    """Keep the k = ceil(size/rate) largest-magnitude elements (global).

    Index metadata (int32 per kept element) is charged to the wire.  ``rate``
    must be a *static* python number for top-k (k shapes the computation);
    VARCO's traced schedule therefore uses the mask compressor, while top-k
    serves fixed-rate runs.
    """
    del key
    flat = x.reshape(-1)
    r = float(rate)
    k = max(int(flat.size / max(r, 1.0)), 1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    x_tilde = jnp.zeros_like(flat).at[idx].set(vals).reshape(x.shape)
    bits = jnp.asarray(k * (_nbits(x.dtype) + 32), jnp.float32)
    return x_tilde, bits


def topk_compressor() -> Compressor:
    # per-element squared error of dropping the smallest (1 - 1/r) fraction;
    # for i.i.d. gaussian entries this is ~ (1 - 1/r)^2 of the energy — we
    # report the conservative mask bound.
    return Compressor("topk", _topk, lambda r: 1.0 - 1.0 / jnp.maximum(r, 1.0))


# -- int8 affine quantisation ------------------------------------------------


def _int8(key: Array, x: Array, rate: Array) -> tuple[Array, Array]:
    """Per-row symmetric int8 quantisation. Effective rate vs f32 is 4.

    ``rate`` > 4 additionally applies the random mask on top so the
    mechanism composes to arbitrary ratios (quantise-then-subsample).
    """
    orig_shape = x.shape
    rows = x.reshape(-1, x.shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    amax = jnp.max(jnp.abs(rows), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(rows / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(x.dtype) * scale.astype(x.dtype)).reshape(orig_shape)
    quant_gain = _nbits(x.dtype) / 8.0
    residual_rate = jnp.maximum(jnp.asarray(rate, jnp.float32) / quant_gain, 1.0)
    masked, mask_bits = _random_mask(key, deq, residual_rate, unbiased=False)
    # wire payload: surviving int8 elements (8 bits each; the mask itself is
    # free — shared-key protocol) + EVERY per-row f32 scale.  Scales are
    # side-band metadata that always crosses the wire; only the quantised
    # elements are subsampled, so the scales must not be divided by the
    # residual rate.
    kept = mask_bits / _nbits(deq.dtype)          # surviving element count
    bits = kept * 8.0 + jnp.asarray(scale.size * 32, jnp.float32)
    return masked, bits


def int8_compressor() -> Compressor:
    return Compressor(
        "int8", _int8,
        lambda r: 1e-4 + (1.0 - 4.0 / jnp.maximum(r, 4.0)))


# -- straight-through wrapper ------------------------------------------------


def straight_through(compress_fn):
    """Forward = compressed value, backward = identity.

    The paper back-propagates *through* the compression routine; the mask
    compressor is already differentiable (gradient masked identically to the
    forward).  For quantisers the straight-through estimator is standard.
    """

    def wrapped(key, x, rate):
        x_tilde, bits = compress_fn(key, x, rate)
        x_tilde = x + jax.lax.stop_gradient(x_tilde - x)
        return x_tilde, bits

    return wrapped


_REGISTRY: dict[str, Callable[[], Compressor]] = {
    "randmask": random_mask_compressor,
    "randmask_unbiased": partial(random_mask_compressor, unbiased=True),
    "blockmask": block_mask_compressor,
    "topk": topk_compressor,
    "int8": int8_compressor,
}


def get_compressor(name: str) -> Compressor:
    if name not in _REGISTRY:
        raise KeyError(f"unknown compressor {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def available_compressors() -> list[str]:
    return sorted(_REGISTRY)
