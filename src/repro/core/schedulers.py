"""Compression-rate schedulers (paper §IV + Appendix A, eq. (8)).

A scheduler maps a train step ``t`` to a compression ratio ``c(t) >= 1``.
Proposition 2 only requires the induced compression error to *strictly
decrease* every step, i.e. ``c`` monotone non-increasing (strictly until it
hits ``c_min``); no gradient information is needed.

Paper's experiment family (eq. 8, slopes a in {2..7}, c_max=128, c_min=1):

    c(t) = max(c_max - a * (c_max - c_min) * t / T, c_min)

(The paper's eq. (8) prints ``min``; the surrounding text — "strictly
decreasing", "128 and 1 maximum and minimum" — fixes the intended clamp to
``max`` toward the floor ``c_min``; we implement the corrected form and note
the erratum here.)

All schedulers return traced f32 scalars so the rate feeds jit'd train steps
without recompilation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Scheduler:
    name: str
    fn: Callable[[jnp.ndarray], jnp.ndarray]
    c_max: float
    c_min: float

    def __call__(self, step):
        # clamp BOTH ends: a mis-specified fn can neither dip below the
        # c_min floor nor request a rate above the configured c_max
        # ceiling (regression: tests/test_schedulers.py)
        c = jnp.asarray(self.fn(jnp.asarray(step, jnp.float32)), jnp.float32)
        return jnp.clip(c, self.c_min, self.c_max)


def constant(c: float) -> Scheduler:
    """Fixed compression ratio (paper's 'Fixed Comp Rate' baselines)."""
    return Scheduler(f"fixed:{c:g}", lambda t: jnp.full((), c, jnp.float32), c, c)


def linear(total_steps: int, slope: float = 5.0, c_max: float = 128.0,
           c_min: float = 1.0) -> Scheduler:
    """Paper eq. (8): linear decrease with slope multiplier ``a``."""

    def fn(t):
        c = c_max - slope * (c_max - c_min) * t / total_steps
        return jnp.clip(c, c_min, c_max)

    return Scheduler(f"linear:a={slope:g}", fn, c_max, c_min)


def fixed_step(total_steps: int, decrement: float, c_max: float = 128.0,
               c_min: float = 1.0) -> Scheduler:
    """Appendix A 'fixed rate' variant: c_{k+1} = c_k - R."""

    def fn(t):
        return jnp.clip(c_max - decrement * t, c_min, c_max)

    return Scheduler(f"step:R={decrement:g}", fn, c_max, c_min)


def exponential(total_steps: int, c_max: float = 128.0, c_min: float = 1.0
                ) -> Scheduler:
    """Appendix A exponential variant: geometric decay c_max -> c_min."""
    ratio = c_min / c_max

    def fn(t):
        frac = jnp.clip(t / total_steps, 0.0, 1.0)
        return c_max * jnp.power(ratio, frac)

    return Scheduler("exp", fn, c_max, c_min)


def cosine(total_steps: int, c_max: float = 128.0, c_min: float = 1.0
           ) -> Scheduler:
    """Beyond-paper: cosine anneal (smooth endpoints, still monotone)."""

    def fn(t):
        frac = jnp.clip(t / total_steps, 0.0, 1.0)
        return c_min + 0.5 * (c_max - c_min) * (1.0 + jnp.cos(math.pi * frac))

    return Scheduler("cosine", fn, c_max, c_min)


def parse(spec: str, total_steps: int) -> Scheduler:
    """Parse CLI specs: 'full' | 'none' | 'fixed:4' | 'linear:5' | 'exp' | 'cosine' | 'step:0.5'."""
    spec = spec.strip().lower()
    if spec in ("full", "off", "1"):
        return constant(1.0)
    if spec == "exp":
        return exponential(total_steps)
    if spec == "cosine":
        return cosine(total_steps)
    kind, _, arg = spec.partition(":")
    if kind == "fixed":
        return constant(float(arg))
    if kind == "linear":
        return linear(total_steps, slope=float(arg) if arg else 5.0)
    if kind == "step":
        return fixed_step(total_steps, decrement=float(arg))
    raise ValueError(f"unknown scheduler spec {spec!r}")
