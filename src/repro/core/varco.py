"""VARCO communication policy: compressor + scheduler + mode, plus ledger.

This is the user-facing object (``CommPolicy``) threaded through every
distributed train step.  It owns

* the communication *mode* — ``full`` (paper's Full Comm baseline), ``none``
  (No Comm baseline: workers never exchange halo activations), ``fixed:<r>``
  (Fixed Compression baseline) or ``varco:<sched>`` (the paper's method),
* the Definition-1 compressor realising the rate,
* a byte ledger accumulated across steps (Fig. 5's x-axis).

``CommPolicy`` is a static (hashable) config; per-step state is just the
integer step used to query the scheduler.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import schedulers
from .compression import Compressor, get_compressor
from .schedulers import Scheduler

MODES = ("full", "none", "fixed", "varco", "auto")

#: closed-loop controllers (``repro.dist.ratectl``) reachable via
#: ``auto:<controller>:<budget-bits>`` — kept in sync with
#: ``repro.dist.ratectl.base.CONTROLLERS`` (pinned by tests)
AUTO_CONTROLLERS = ("budget", "error", "stale", "qos")

#: supported wire storage bit-widths (``repro.kernels.ops.WIRE_WIDTHS``):
#: 2/4/8 quantised, 32 exact fp32 — kept literal here so the policy layer
#: stays import-light (pinned in sync by tests/test_ratectl.py)
WIRE_WIDTHS = (2, 4, 8, 32)


@dataclasses.dataclass(frozen=True)
class CommPolicy:
    """Static description of the communication scheme for a training run.

    ``auto`` mode names a closed-loop controller plus its total wire
    budget in bits: the rates are planned per step (and per worker pair)
    by ``repro.dist.ratectl`` from measured transport feedback, not by a
    step → rate schedule, so ``rate(step)`` is undefined for it.
    """

    mode: str = "full"
    scheduler: Scheduler | None = None
    compressor_name: str = "randmask"
    controller: str | None = None
    budget_bits: float = 0.0
    #: auto mode only: plan per-layer ``[L, Q, Q]`` rate tensors instead
    #: of one ``[Q, Q]`` map shared by every layer (DESIGN.md §3.7);
    #: spelled ``auto:<controller>:<bits>:per-layer``
    per_layer: bool = False
    #: auto mode only: lowest bit-width the controller may quantise a
    #: pair's wire payload to (DESIGN.md §3.8) — 32 keeps the wire exact
    #: fp32 (no quantised codec in the compiled step), 8/4/2 let the
    #: controller water-fill rate × width jointly down to that floor;
    #: spelled ``auto:<controller>:<bits>:w<max_width>``
    max_width: int = 32

    def __post_init__(self):
        if self.per_layer and self.mode != "auto":
            raise ValueError(
                f"per_layer rate planning is a closed-loop (auto) feature; "
                f"mode {self.mode!r} plans one scalar rate per step")
        if self.max_width not in WIRE_WIDTHS:
            raise ValueError(
                f"max_width must be one of {WIRE_WIDTHS} (supported wire "
                f"storage widths), got {self.max_width!r}")
        if self.max_width < 32 and self.mode != "auto":
            raise ValueError(
                f"quantised wire widths are planned closed-loop per pair; "
                f"max_width < 32 needs mode 'auto', got mode {self.mode!r}")
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.mode in ("fixed", "varco") and self.scheduler is None:
            raise ValueError(f"mode {self.mode!r} requires a scheduler")
        if self.mode == "auto":
            if self.controller not in AUTO_CONTROLLERS:
                raise ValueError(
                    f"auto mode needs a controller in {AUTO_CONTROLLERS}, "
                    f"got {self.controller!r}")
            if not self.budget_bits > 0:
                raise ValueError(f"auto mode needs a positive bit budget, "
                                 f"got {self.budget_bits!r}")
            if self.compressor_name != "blockmask":
                raise ValueError(
                    "auto mode rides the packed/p2p wires, which ship "
                    "PRNG-selected lane-blocks; the compressor must be "
                    f"'blockmask', got {self.compressor_name!r}")

    # -- construction --------------------------------------------------------

    @staticmethod
    def parse(spec: str, total_steps: int, compressor: str | None = None
              ) -> "CommPolicy":
        """Parse CLI specs.

        ``full`` | ``none`` | ``fixed:<r>`` | ``varco:linear:<a>`` |
        ``varco:exp`` | ``varco:cosine`` | ``varco:step:<R>`` |
        ``auto:<controller>:<budget-bits>[:w<width>][:per-layer]`` with
        controller in ``budget`` / ``error`` / ``stale`` / ``qos`` (e.g.
        ``auto:budget:2e9``; the ``per-layer`` suffix plans ``[L, Q, Q]``
        per-layer rate tensors, DESIGN.md §3.7; ``w<width>`` with width
        in ``2`` / ``4`` / ``8`` lets the controller quantise pair
        payloads down to that bit-width, DESIGN.md §3.8 — the two
        suffixes compose in either order).  ``str(policy)`` returns the
        canonical spec (``w`` before ``per-layer``) and round-trips
        through ``parse`` for every documented mode.
        """
        spec = spec.strip().lower()
        if spec == "full":
            return CommPolicy("full")
        if spec == "none":
            return CommPolicy("none")
        kind, _, rest = spec.partition(":")
        if kind == "fixed":
            return CommPolicy("fixed", schedulers.constant(float(rest)),
                              compressor or "randmask")
        if kind == "varco":
            return CommPolicy("varco",
                              schedulers.parse(rest or "linear:5", total_steps),
                              compressor or "randmask")
        if kind == "auto":
            parts = rest.split(":")
            if len(parts) < 2 or not parts[0] or not parts[1]:
                raise ValueError(
                    f"auto spec is auto:<controller>:<budget-bits>"
                    f"[:w<width>][:per-layer], got {spec!r}")
            ctl, budget = parts[0], parts[1]
            per_layer = False
            max_width = 32
            for suffix in parts[2:]:
                if suffix == "per-layer":
                    per_layer = True
                elif len(suffix) > 1 and suffix[0] == "w" \
                        and suffix[1:].isdigit():
                    w = int(suffix[1:])
                    if w not in WIRE_WIDTHS:
                        raise ValueError(
                            f"wire width must be one of {WIRE_WIDTHS}, "
                            f"got w{w} in {spec!r}")
                    max_width = w
                else:
                    raise ValueError(
                        f"unknown auto suffix {suffix!r} in {spec!r} "
                        f"('w<width>' and 'per-layer' are defined)")
            return CommPolicy("auto", compressor_name=compressor or
                              "blockmask", controller=ctl,
                              budget_bits=float(budget),
                              per_layer=per_layer, max_width=max_width)
        raise ValueError(f"unknown comm spec {spec!r}")

    def __str__(self) -> str:
        """Canonical parseable spec: ``CommPolicy.parse(str(p)) == p`` for
        every constructible policy, and ``str(CommPolicy.parse(s)) == s``
        for every canonical spec (``w`` suffix before ``per-layer``)."""
        if self.mode in ("full", "none"):
            return self.mode
        if self.mode == "auto":
            s = f"auto:{self.controller}:{self.budget_bits:g}"
            if self.max_width < 32:
                s += f":w{self.max_width}"
            if self.per_layer:
                s += ":per-layer"
            return s
        if self.mode == "fixed":
            return self.scheduler.name              # "fixed:<r>"
        name = self.scheduler.name                  # varco schedules
        for prefix, canon in (("linear:a=", "linear:"), ("step:R=", "step:")):
            if name.startswith(prefix):
                return f"varco:{canon}{name[len(prefix):]}"
        return f"varco:{name}"

    # -- queries -------------------------------------------------------------

    @property
    def communicates(self) -> bool:
        return self.mode != "none"

    @property
    def compresses(self) -> bool:
        return self.mode in ("fixed", "varco", "auto")

    def compressor(self) -> Compressor:
        return get_compressor(self.compressor_name)

    def rate(self, step) -> jnp.ndarray:
        """Compression ratio at ``step`` (1.0 for full communication)."""
        if self.mode == "auto":
            raise ValueError(
                "auto policies plan rates closed-loop per step — drive the "
                "run via repro.dist.ratectl (train_gnn does this) instead "
                "of querying a schedule")
        if not self.compresses:
            return jnp.ones((), jnp.float32)
        return self.scheduler(step)

    def describe(self) -> str:
        if self.mode in ("full", "none"):
            return self.mode
        if self.mode == "auto":
            pl = ",per-layer" if self.per_layer else ""
            w = f",w{self.max_width}" if self.max_width < 32 else ""
            return (f"auto({self.controller},{self.budget_bits:g}b,"
                    f"{self.compressor_name}{w}{pl})")
        return f"{self.mode}({self.scheduler.name},{self.compressor_name})"


FULL_COMM = CommPolicy("full")
NO_COMM = CommPolicy("none")


def fixed(rate: float, compressor: str = "randmask") -> CommPolicy:
    return CommPolicy("fixed", schedulers.constant(rate), compressor)


def varco(total_steps: int, slope: float = 5.0, c_max: float = 128.0,
          c_min: float = 1.0, compressor: str = "randmask") -> CommPolicy:
    return CommPolicy(
        "varco",
        schedulers.linear(total_steps, slope=slope, c_max=c_max, c_min=c_min),
        compressor)


# ---------------------------------------------------------------------------
# Ledger
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CommLedger:
    """Cumulative wire-traffic counter (floats & bits), a jit-safe pytree.

    Two parallel counters (DESIGN.md §3.3):

    * ``bits`` — the *analytic* point-to-point charge: the compressed
      payload a pairwise implementation would ship (``halo_demand × F × 32
      / rate`` per halo exchange).  This is the paper's Fig. 5 axis.
    * ``transport`` — the bits *actually shipped* by the wire format in use:
      the dense collective moves the full masked buffer regardless of rate,
      while the packed wire moves the ``K·128``-wide lane-block payload.
      ``transport == bits`` exactly for the packed wire at rate 1.

    Example::

        ledger = CommLedger.zero()
        ledger = ledger.add_bits(analytic, transport=shipped)
        print(float(ledger.floats), float(ledger.transport_gigabytes))
    """

    bits: jnp.ndarray
    transport: jnp.ndarray

    @staticmethod
    def zero() -> "CommLedger":
        return CommLedger(jnp.zeros((), jnp.float32),
                          jnp.zeros((), jnp.float32))

    def add_bits(self, bits, transport=None) -> "CommLedger":
        """Charge one exchange: analytic ``bits`` plus the transport-level
        count (defaults to ``bits`` — exact for uncompressed dense wires)."""
        t = bits if transport is None else transport
        return CommLedger(self.bits + bits, self.transport + t)

    @property
    def floats(self) -> jnp.ndarray:
        """Equivalent f32 floats communicated (paper Fig. 5 unit)."""
        return self.bits / 32.0

    @property
    def gigabytes(self) -> jnp.ndarray:
        return self.bits / 8.0 / 1e9

    @property
    def transport_gigabytes(self) -> jnp.ndarray:
        """GB physically shipped by the active wire format."""
        return self.transport / 8.0 / 1e9

    def tree_flatten(self):
        return (self.bits, self.transport), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)
