"""Distributed runtime: partition-parallel GNN training (the paper's
setting), mesh/sharding specs for the transformer workloads, and VARCO
gradient compression for data-parallel LM training.

Three modules, one per distribution style (DESIGN.md §2):

* ``gnn_parallel``  — the paper's Algorithm 1 over a ``workers`` mesh axis:
  each worker owns one graph partition and exchanges compressed halo
  activations every layer.  Three wire formats (``DistMeta.wire``): the
  dense masked all-gather, the packed ``[B, K·128]`` lane-block exchange
  backed by the varco_pack Pallas kernels (DESIGN.md §3.3), and the
  neighbor-only ``p2p`` ppermute ring with ELL-kernel local aggregation
  (DESIGN.md §3.5).
* ``halo``          — host-side construction of the p2p wire's static
  indices: per-pair halo sets, the compacted ``remote_src`` remap, and the
  degree-padded (forward + reversed) ELL neighbour lists.
* ``sharding``      — GSPMD mesh/sharding rules (param placement, activation
  constraints, KV-cache layout) for the transformer dry-run/serve stack,
  plus the worker-axis specs of the GNN graph pytree.
* ``grad_compress`` — VARCO applied to data-parallel gradient all-reduce,
  transplanting the paper's variable-rate scheme to LM training.
* ``ratectl``       — closed-loop rate control (DESIGN.md §3.6): the
  ``RateController`` API plus the ``budget`` / ``error`` / ``stale``
  controllers turning a byte budget into per-step, per-pair ``[Q, Q]``
  rate maps, and the per-pair-rate train step they drive.
"""

from repro.dist.gnn_parallel import (DistMeta, make_eval_step,
                                     make_train_step, make_worker_mesh,
                                     shard_graph)
from repro.dist.grad_compress import make_dp_mesh, make_varco_dp_train_step
from repro.dist.halo import (HaloSpec, attach_p2p, build_halo_spec,
                             build_reverse_ell, ell_arrays, halo_arrays)
from repro.dist.ratectl import (RateController, RatePlan, budget_controller,
                                error_controller, init_halo_cache,
                                init_wire_residuals, make_auto_train_step,
                                make_controller, make_pacing,
                                stale_controller)
from repro.dist.sharding import (activation_sharding, batch_spec, cache_spec,
                                 data_axes, dispatch_groups, maybe_shard,
                                 param_shardings, param_spec,
                                 worker_graph_shardings)

__all__ = [
    "DistMeta", "make_eval_step", "make_train_step", "make_worker_mesh",
    "shard_graph",
    "HaloSpec", "attach_p2p", "build_halo_spec", "build_reverse_ell",
    "ell_arrays", "halo_arrays",
    "RateController", "RatePlan", "budget_controller", "error_controller",
    "init_halo_cache", "init_wire_residuals", "make_auto_train_step",
    "make_controller", "make_pacing", "stale_controller",
    "make_dp_mesh", "make_varco_dp_train_step",
    "activation_sharding", "batch_spec", "cache_spec", "data_axes",
    "dispatch_groups", "maybe_shard", "param_shardings", "param_spec",
    "worker_graph_shardings",
]
