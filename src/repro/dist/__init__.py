"""Distributed runtime: partition-parallel GNN training (the paper's
setting), mesh/sharding specs for the transformer workloads, and VARCO
gradient compression for data-parallel LM training.

Three modules, one per distribution style (DESIGN.md §2):

* ``gnn_parallel``  — the paper's Algorithm 1 over a ``workers`` mesh axis:
  each worker owns one graph partition and exchanges compressed halo
  activations every layer.  Two wire formats (``DistMeta.wire``): the dense
  masked all-gather, and the packed ``[B, K·128]`` lane-block exchange
  backed by the varco_pack Pallas kernels (DESIGN.md §3.3).
* ``sharding``      — GSPMD mesh/sharding rules (param placement, activation
  constraints, KV-cache layout) for the transformer dry-run/serve stack.
* ``grad_compress`` — VARCO applied to data-parallel gradient all-reduce,
  transplanting the paper's variable-rate scheme to LM training.
"""

from repro.dist.gnn_parallel import (DistMeta, make_eval_step,
                                     make_train_step, make_worker_mesh,
                                     shard_graph)
from repro.dist.grad_compress import make_dp_mesh, make_varco_dp_train_step
from repro.dist.sharding import (activation_sharding, batch_spec, cache_spec,
                                 data_axes, dispatch_groups, maybe_shard,
                                 param_shardings, param_spec)

__all__ = [
    "DistMeta", "make_eval_step", "make_train_step", "make_worker_mesh",
    "shard_graph",
    "make_dp_mesh", "make_varco_dp_train_step",
    "activation_sharding", "batch_spec", "cache_spec", "data_axes",
    "dispatch_groups", "maybe_shard", "param_shardings", "param_spec",
]
