"""Deterministic fault injection + graceful degradation (DESIGN.md §3.10).

The variable-rate scheme treats the wire as unreliable-by-budget; this
module treats it as unreliable-by-nature and keeps the same training loop
running through three failure classes:

* **link drops / latency spikes** — :class:`FaultSchedule` derives a
  per-step ``[Q, Q]`` link-drop mask and per-link latency multipliers
  from a counter-based Philox stream keyed on ``(seed, step)``: the
  schedule is a pure function of its arguments, so every chaos run is
  replayable bit-for-bit (and survives worker shrinks — masks are always
  drawn at the *original* Q and the surviving rows/columns selected, so a
  crash never perturbs the remaining links' fault streams);
* **degraded halo service** — :func:`degrade_plan` runs the ladder
  *exchange → cached → backoff-probe → local-only*: a dropped pair serves
  the receiver's cached hop buffer (charging zero wire bits) while its
  ``age`` stays under ``max_stale``; past the cap the pair goes **dead**
  — its rows are zeroed, the local aggregation renormalises toward the
  isolated (No-Comm) weights (the paper's rate→0 limit), and the link is
  re-probed under capped exponential backoff until a probe lands;
* **worker crashes** — a ``crash_at`` event drops the run to ``Q - 1``:
  :func:`shrink_shards` renumbers a :class:`repro.graph.stream.ShardSet`
  around the dead partition (rebuilding the per-pair
  :class:`repro.dist.halo.HaloSpec` and p2p hop arrays),
  :func:`migrate_controller_state` carries the rate controller's pair
  state across, and the trainer resumes at the smaller Q.

The fault cache channel is *separate* from the ``stale`` controller's
(`cache`/`skip`) so degradation works under every policy — including
``auto:stale`` itself and the error-feedback residual channel.

Example::

    faults = FaultSchedule(q=8, seed=0, drop_rate=0.2,
                           crash_at=((15, 3),))
    res = train_gnn(g, q=8, policy=CommPolicy.parse("full", epochs),
                    faults=faults, wire="p2p")
"""

from __future__ import annotations

import dataclasses

import numpy as np

#: degradation-ladder serve modes per ordered pair (receiver × sender)
FRESH, CACHED, DEAD = 0, 1, 2


# ---------------------------------------------------------------------------
# Deterministic schedule
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Replayable fault plan: a pure function of ``(seed, step)``.

    ``q`` is the *original* worker count; ``alive`` the original indices
    still running (``None`` = all).  ``crash_at`` holds ``(step,
    original_worker)`` events.  ``drop_rate`` is the per-step per-ordered-
    pair Bernoulli drop probability; ``spike_rate``/``spike_factor`` model
    latency spikes (a link slower than ``spike_threshold``× is treated as
    dark for the step — the DistGNN-style "serve stale rather than
    stall" rule).
    """

    q: int
    seed: int = 0
    drop_rate: float = 0.0
    spike_rate: float = 0.0
    spike_factor: float = 8.0
    spike_threshold: float = 4.0
    crash_at: tuple = ()
    alive: tuple | None = None

    def __post_init__(self):
        if not 0.0 <= self.drop_rate <= 1.0:
            raise ValueError(f"drop_rate must be in [0, 1], "
                             f"got {self.drop_rate}")
        if not 0.0 <= self.spike_rate <= 1.0:
            raise ValueError(f"spike_rate must be in [0, 1], "
                             f"got {self.spike_rate}")
        if self.alive is not None:
            if sorted(set(self.alive)) != list(self.alive):
                raise ValueError("alive must be sorted unique indices")
            if any(not 0 <= a < self.q for a in self.alive):
                raise ValueError(f"alive indices must be in [0, {self.q})")

    @property
    def alive_workers(self) -> tuple:
        return tuple(range(self.q)) if self.alive is None else self.alive

    @property
    def cur_q(self) -> int:
        return len(self.alive_workers)

    def _gen(self, step: int) -> np.random.Generator:
        # counter-based: one independent, reconstructible stream per step
        return np.random.Generator(np.random.Philox(
            key=[int(self.seed) & 0xFFFFFFFFFFFFFFFF,
                 int(step) & 0xFFFFFFFFFFFFFFFF]))

    def _full_masks(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        """(drops, latency) at the ORIGINAL q — a fixed draw order keeps
        surviving links' streams invariant under :meth:`shrink`."""
        g = self._gen(step)
        drops = (g.random((self.q, self.q)) < self.drop_rate)
        spikes = (g.random((self.q, self.q)) < self.spike_rate)
        np.fill_diagonal(drops, False)
        np.fill_diagonal(spikes, False)
        lat = np.where(spikes, float(self.spike_factor), 1.0)
        return drops, lat

    def _select(self, m: np.ndarray) -> np.ndarray:
        a = np.asarray(self.alive_workers)
        return m[np.ix_(a, a)]

    def link_drops(self, step: int) -> np.ndarray:
        """``[q', q']`` 0/1 hard-drop mask (current numbering, diag 0)."""
        drops, _ = self._full_masks(step)
        return self._select(drops).astype(np.float32)

    def latency(self, step: int) -> np.ndarray:
        """``[q', q']`` per-link latency multipliers (≥ 1, diag 1)."""
        _, lat = self._full_masks(step)
        return self._select(lat).astype(np.float32)

    def effective_drops(self, step: int) -> np.ndarray:
        """Hard drops ∪ spikes past ``spike_threshold`` — the mask the
        degradation ladder consumes."""
        drops, lat = self._full_masks(step)
        eff = drops | (lat >= self.spike_threshold)
        return self._select(eff).astype(np.float32)

    def crash_at_step(self, step: int) -> int | None:
        """Index (CURRENT numbering) of a worker crashing at ``step``, or
        ``None``.  Events naming already-dead workers are ignored."""
        cur = self.alive_workers
        for s, w in self.crash_at:
            if int(s) == int(step) and int(w) in cur:
                return cur.index(int(w))
        return None

    def shrink(self, dead: int) -> "FaultSchedule":
        """The schedule after removing current-index ``dead`` — surviving
        pairs keep their exact fault streams."""
        cur = self.alive_workers
        if not 0 <= dead < len(cur):
            raise ValueError(f"dead index {dead} out of range for "
                             f"{len(cur)} live workers")
        alive = tuple(w for i, w in enumerate(cur) if i != dead)
        return dataclasses.replace(self, alive=alive)


# ---------------------------------------------------------------------------
# Degradation ladder: exchange → cached → backoff probe → local-only
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DegradeState:
    """Host-side per-pair ladder state (receiver × sender, all ``[Q,
    Q]`` int64): ``age`` counts consecutive steps without a fresh
    delivery, ``backoff`` the current probe backoff of dead pairs
    (0 = not in a dead episode), ``next_try`` the step of their next
    probe."""

    age: np.ndarray
    backoff: np.ndarray
    next_try: np.ndarray


def init_degrade(q: int) -> DegradeState:
    z = np.zeros((q, q), np.int64)
    return DegradeState(age=z.copy(), backoff=z.copy(), next_try=z.copy())


def degrade_plan(state: DegradeState, drops, step: int, *,
                 max_stale: int = 5, backoff_base: int = 1,
                 backoff_cap: int = 16
                 ) -> tuple[np.ndarray, DegradeState]:
    """One ladder transition: ``(serve [Q, Q] ∈ {FRESH, CACHED, DEAD},
    state')``.

    A pair with its link up serves FRESH (age resets) — unless it is in a
    dead episode, where the receiver only listens at probe steps (between
    probes even a recovered link stays DEAD; that is what gives the
    backoff real semantics).  A dropped pair serves the receiver's CACHED
    hop buffer while ``age < max_stale``; at the cap it goes DEAD: rows
    zeroed, local aggregation renormalised, and the link re-probed with
    exponential backoff ``backoff_base · 2^k`` capped at ``backoff_cap``.

    Pure in both arguments (the inputs are not mutated), so a crash-resume
    replays the exact ladder from a restored state.
    """
    if max_stale < 1:
        raise ValueError(f"max_stale must be >= 1, got {max_stale}")
    drops = np.asarray(drops) > 0.5
    np.fill_diagonal(drops, False)
    age, backoff, next_try = state.age, state.backoff, state.next_try
    in_dead = age >= max_stale
    # non-dead pairs always listen; dead pairs only when a probe is due
    # (backoff == 0 marks the first dead step of an episode)
    probe_due = ~in_dead | (backoff == 0) | (step >= next_try)
    fresh = ~drops & probe_due
    serve = np.where(fresh, FRESH, np.where(in_dead, DEAD, CACHED))
    new_age = np.where(fresh, 0, age + 1)
    probe_fail = in_dead & probe_due & drops
    new_backoff = np.where(
        fresh, 0,
        np.where(probe_fail,
                 np.clip(backoff * 2, backoff_base, backoff_cap), backoff))
    new_next = np.where(probe_fail, step + new_backoff, next_try)
    return serve.astype(np.int8), DegradeState(
        age=new_age.astype(np.int64), backoff=new_backoff.astype(np.int64),
        next_try=new_next.astype(np.int64))


def serve_masks(serve: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """``(fskip, dead)`` float32 0/1 masks of a serve plan — the fault
    channel operands of the aggregation oracles (``fskip`` substitutes
    the cached hop, ``dead`` zeroes it and triggers the local-only
    renormalisation; both charge zero wire bits in the ledger)."""
    return ((serve == CACHED).astype(np.float32),
            (serve == DEAD).astype(np.float32))


def migrate_degrade_state(state: DegradeState, dead: int) -> DegradeState:
    """Ladder state after worker ``dead`` leaves: delete its row/col."""
    def cut(m):
        return np.delete(np.delete(m, dead, axis=0), dead, axis=1)
    return DegradeState(age=cut(state.age), backoff=cut(state.backoff),
                        next_try=cut(state.next_try))


# ---------------------------------------------------------------------------
# Elastic shrink: ShardSet at Q - 1 + controller-state migration
# ---------------------------------------------------------------------------


def shrink_shards(shards, dead: int):
    """A :class:`repro.graph.stream.ShardSet` with partition ``dead``
    removed — the elastic-Q path of a worker crash.

    Survivor partitions are renumbered (``p - (p > dead)``); remote edges
    sourced at the dead partition lose their weight (their contribution
    falls to the dead-pair renormalisation, not to stale junk), the rest
    have their flat halo indices remapped; the per-pair
    :class:`~repro.dist.halo.HaloSpec` and p2p hop arrays are rebuilt for
    the smaller ring (ELL degrees — local edges — are untouched).
    Requires a fully-loaded set (every partition's remote table is needed
    to rebuild the pair sets).
    """
    from repro.dist.halo import HaloSpec, build_halo_spec, halo_arrays
    from repro.graph.stream import ShardSet

    if not isinstance(shards, ShardSet):
        raise TypeError("shrink_shards needs a loaded ShardSet (the "
                        "elastic path re-wires the halo around the dead "
                        "partition)")
    if tuple(shards.parts) != tuple(range(shards.q)):
        raise ValueError("shrink_shards needs all partitions loaded, got "
                         f"parts={shards.parts} of q={shards.q}")
    if not 0 <= dead < shards.q:
        raise ValueError(f"dead partition {dead} out of range [0, "
                         f"{shards.q})")
    if shards.q < 2:
        raise ValueError("cannot shrink below one worker")
    q_new = shards.q - 1
    keep = [p for p in range(shards.q) if p != dead]
    h_sz, p_sz = shards.halo_size, shards.part_size

    arrays = {k: np.array(v[keep]) for k, v in shards.arrays.items()}
    # remap remote edges: dead-sourced → weight 0 / dump row; survivors →
    # renumbered flat halo index (new_part * halo_size + slot)
    valid = arrays["remote_w"] > 0
    src_part = arrays["remote_src"] // h_sz
    slot = arrays["remote_src"] % h_sz
    from_dead = valid & (src_part == dead)
    new_part = src_part - (src_part > dead)
    alive = valid & ~from_dead
    arrays["remote_w"] = np.where(from_dead, 0.0,
                                  arrays["remote_w"]).astype(np.float32)
    arrays["remote_dst"] = np.where(from_dead, p_sz,
                                    arrays["remote_dst"]).astype(
        arrays["remote_dst"].dtype)
    arrays["remote_src"] = np.where(
        alive, new_part * h_sz + slot, 0).astype(arrays["remote_src"].dtype)

    new = ShardSet(
        path=shards.path, q=q_new, part_size=p_sz, halo_size=h_sz,
        num_nodes=shards.num_nodes, num_edges=shards.num_edges,
        feat_dim=shards.feat_dim, num_classes=shards.num_classes,
        halo_demand=0, cross_edges=int(alive.sum()),
        n_train=int(arrays["train_mask"].sum()),
        n_val=int(arrays["val_mask"].sum()),
        n_test=int(arrays["test_mask"].sum()),
        norm=shards.norm, name=f"{shards.name}-shrunk{dead}",
        halo_spec=None, parts=tuple(range(q_new)), arrays=arrays)
    # rebuild the per-pair halo layout for the smaller ring; local-edge
    # ELL arrays (and their padded degrees) are untouched by a crash
    spec = build_halo_spec(new)
    old = shards.halo_spec
    spec = HaloSpec(q=q_new, hop_width=spec.hop_width,
                    compact_rows=spec.compact_rows,
                    ell_degree=old.ell_degree, rev_degree=old.rev_degree,
                    pair_rows=spec.pair_rows)
    for k, v in halo_arrays(new, spec).items():
        arrays[k] = v
    object.__setattr__(new, "halo_spec", spec)
    object.__setattr__(new, "halo_demand",
                       int(np.asarray(spec.pair_rows).sum()))
    return new


def _cache_send_to_recv(c, q: int):
    """Sender-major hop cache ``[Q, D, H, F]`` (the emulated layout:
    row ``j``, hop ``d`` = what sender ``j`` ships at ring offset ``d``)
    → receiver-major (row ``i``, hop ``d`` = what receiver ``i`` got from
    ``(i - d) mod Q``) — the layout the shard backend can shard over the
    worker axis."""
    if q <= 1:
        return c
    i = np.arange(q)[:, None]
    d = np.arange(1, q)[None, :]
    return c[(i - d) % q, d - 1]


def _cache_recv_to_send(c, q: int):
    """Inverse of :func:`_cache_send_to_recv`."""
    if q <= 1:
        return c
    j = np.arange(q)[:, None]
    d = np.arange(1, q)[None, :]
    return c[(j + d) % q, d - 1]


def make_fault_train_step(cfg, policy, opt, meta, mesh=None, sync: str = "grad",
                          compiled_cache_size: int | None = None):
    """A train step with the fault channel threaded through — the
    degraded-mode analogue of ``make_auto_train_step`` that works under
    *every* communicating policy (full / fixed / varco / auto, scalar
    policies ride a uniform rate map).

    ``step(params, opt_state, graph, key, plan, fskip, dead, cache=(),
    fcache=()) -> (params, opt_state, metrics, cache', fcache')`` —
    ``fskip``/``dead`` are the ladder's concrete ``[Q, Q]`` 0/1 masks
    (:func:`serve_masks`), ``fcache`` the fault hop cache
    (``repro.dist.ratectl.init_halo_cache`` shapes, sender-major), and
    ``cache`` the stale-controller XOR error-feedback channel exactly as
    in the auto step.  Requires ``wire == 'p2p'``, ``Q >= 2``, and a
    communicating policy.
    """
    import functools

    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.gnn_parallel import (AXIS, COMPILED_CACHE_SIZE,
                                         _local_loss_fn,
                                         _make_aggregate_emulated,
                                         _make_aggregate_shard,
                                         _packed_pair_k_for,
                                         _packed_pair_w_for, _pmean_inexact,
                                         _snap_width)
    from repro.dist.ratectl.driver import _auto_metrics, exchange_widths
    from repro.kernels.varco_pack import LANE
    from repro.nn.gnn import gnn_forward, masked_loss_and_correct
    from repro.train.optim import apply_updates

    if meta.wire != "p2p":
        raise ValueError("fault-tolerant training serves dropped links "
                         "from per-pair hop caches; it needs wire='p2p', "
                         f"got {meta.wire!r}")
    if meta.q < 2:
        raise ValueError("fault injection needs Q >= 2 (a single worker "
                         "has no links to drop)")
    if not policy.communicates:
        raise ValueError("fault injection needs a communicating policy "
                         "(the No-Comm baseline has no wire to fail)")
    if sync not in ("grad", "fedavg"):
        raise ValueError(f"sync must be 'grad' or 'fedavg', got {sync!r}")
    for f_ in {meta.feat_dim, *meta.layer_dims}:
        if f_ % LANE:
            raise ValueError(
                f"the fault channel rides the rate-map wire; every "
                f"exchanged width must be divisible by {LANE}, got {f_}")
    q = meta.q
    n_ex = len(exchange_widths(cfg))
    stale_ch = policy.mode == "auto" and \
        getattr(policy, "controller", None) == "stale"
    if stale_ch and mesh is not None:
        raise ValueError("hop reuse is emulated-backend only; run the "
                         "stale controller with mesh=None")
    use_ef = policy.mode == "auto" and getattr(policy, "max_width", 32) < 32 \
        and mesh is None and not stale_ch
    cache_size = COMPILED_CACHE_SIZE if compiled_cache_size is None \
        else compiled_cache_size

    def _plan_widths(plan):
        if plan.widths is None:
            return None, ()
        wm = np.asarray(plan.widths, np.float32)
        wm = np.vectorize(_snap_width)(wm).astype(np.float32)
        ww = _packed_pair_w_for(meta, wm)
        return (wm, ww) if ww else (None, ())

    def _host_plan(plan, fskip, dead, fcache):
        rm = np.asarray(plan.rates, np.float32)
        kb = _packed_pair_k_for(meta, rm)
        wm, ww = _plan_widths(plan)
        rs = 1.0
        if policy.mode == "varco" and q > 1:
            rs = float(rm[~np.eye(q, dtype=bool)].mean())
        if len(fcache) != n_ex:
            raise ValueError(f"fcache must hold one buffer per exchange "
                             f"call ({n_ex}), got {len(fcache)} — pass "
                             f"init_halo_cache(meta, cfg)")
        return rm, kb, wm, ww, rs, \
            jnp.asarray(np.asarray(fskip), jnp.float32), \
            jnp.asarray(np.asarray(dead), jnp.float32)

    if mesh is None:
        @functools.partial(jax.jit,
                           static_argnames=("packed_k", "wire_w"))
        def _jit_step(params, opt_state, graph, key, rate_s, rate_map,
                      width_map, skip, cache, fskip, dead, fcache,
                      packed_k, wire_w):
            wm = width_map if wire_w else None
            ef = use_ef and bool(wire_w) and bool(cache)

            def loss_fn(p):
                cache_out: list = []
                fcache_out: list = []
                agg = _make_aggregate_emulated(
                    graph, meta, policy, None, rate_s, key,
                    packed_k=dict(packed_k), rate_map=rate_map,
                    skip=skip if stale_ch else None,
                    cache=cache if stale_ch else None,
                    cache_out=cache_out if stale_ch else None,
                    width_map=wm,
                    resid=cache if ef else None,
                    resid_out=cache_out if ef else None,
                    fskip=fskip, fcache=fcache,
                    fcache_out=fcache_out, dead=dead)
                logits, bits = gnn_forward(p, cfg, graph["features"], agg)
                loss_sum, _ = masked_loss_and_correct(
                    logits, graph["labels"], graph["train_mask"])
                return loss_sum / max(meta.n_train, 1), \
                    (bits, tuple(cache_out), tuple(fcache_out))

            (loss, (bits, cache_new, fcache_new)), grads = \
                jax.value_and_grad(loss_fn, has_aux=True)(params)
            updates, new_state = opt.update(grads, opt_state, params)
            new_params = apply_updates(params, updates)
            return (new_params, new_state,
                    _auto_metrics(loss, rate_map, bits, q, n_ex),
                    cache_new, fcache_new)

        def step(params, opt_state, graph, key, plan, fskip, dead,
                 cache=(), fcache=()):
            rm, kb, wm, ww, rs, fs, dd = _host_plan(plan, fskip, dead,
                                                    fcache)
            out = _jit_step(params, opt_state, graph, key,
                            jnp.asarray(rs, jnp.float32), jnp.asarray(rm),
                            jnp.zeros((), jnp.float32) if wm is None
                            else jnp.asarray(wm),
                            jnp.asarray(plan.skip, jnp.float32),
                            tuple(cache), fs, dd, tuple(fcache),
                            packed_k=kb, wire_w=ww)
            params, opt_state, m, cache_new, fcache_new = out
            if cache and not cache_new:
                cache_new = tuple(cache)   # exact step: carry EF unchanged
            return params, opt_state, m, cache_new, fcache_new

        step._jit_step = _jit_step
        return step

    def make_worker(packed_k: tuple, wire_w: tuple):
        def worker(params, opt_state, gblk, rate_s, rate_map, width_map,
                   fskip, dead, fcache, key):
            def loss_fn(p):
                fco: list = []
                agg = _make_aggregate_shard(
                    gblk, meta, policy, None, rate_s, key,
                    packed_k=dict(packed_k), rate_map=rate_map,
                    width_map=width_map if wire_w else None,
                    fskip=fskip, fcache=fcache, fcache_out=fco,
                    dead=dead)
                loss, bits = _local_loss_fn(p, cfg, gblk, agg, meta)
                return loss, (bits, tuple(fco))

            (loss, (bits, fcache_new)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            loss = lax.psum(loss, AXIS)
            if sync == "grad":
                grads = jax.tree_util.tree_map(lambda g: lax.psum(g, AXIS),
                                               grads)
                updates, new_state = opt.update(grads, opt_state, params)
                params = apply_updates(params, updates)
            else:  # fedavg
                updates, new_state = opt.update(grads, opt_state, params)
                params = apply_updates(params, updates)
                params = _pmean_inexact(params, AXIS)
                new_state = _pmean_inexact(new_state, AXIS)
            return params, new_state, \
                _auto_metrics(loss, rate_map, bits, q, n_ex), fcache_new

        return worker

    @functools.lru_cache(maxsize=cache_size)
    def _compiled_for(kblocks: tuple, wire_w: tuple = ()):
        return jax.jit(shard_map(
            make_worker(kblocks, wire_w), mesh=mesh,
            in_specs=(P(), P(), P(AXIS), P(), P(), P(), P(), P(),
                      P(AXIS), P()),
            out_specs=(P(), P(), P(), P(AXIS)), check_rep=False))

    def step(params, opt_state, graph, key, plan, fskip, dead,
             cache=(), fcache=()):
        rm, kb, wm, ww, rs, fs, dd = _host_plan(plan, fskip, dead, fcache)
        rcache = tuple(_cache_send_to_recv(c, q) for c in fcache)
        params, opt_state, m, rnew = _compiled_for(kb, ww)(
            params, opt_state, graph, jnp.asarray(rs, jnp.float32),
            jnp.asarray(rm),
            jnp.zeros((), jnp.float32) if wm is None else jnp.asarray(wm),
            fs, dd, rcache, key)
        fcache_new = tuple(_cache_recv_to_send(c, q) for c in rnew)
        return params, opt_state, m, tuple(cache), fcache_new

    step.cache_info = _compiled_for.cache_info
    step.cache_clear = _compiled_for.cache_clear
    return step


def migrate_controller_state(state: dict, dead: int, q: int) -> dict:
    """Controller state after worker ``dead`` (of ``q``) leaves.

    Pair-shaped leaves (trailing ``[Q, Q]``: the error controller's
    ``ema``/``y``, the stale controller's ``age``/``skip``) lose the dead
    row/column; scalar and per-layer leaves (budget ``spent``/``integ``,
    ``[L]`` EMAs) carry over unchanged — the PI loop then re-spends the
    dead link's bits on the surviving pairs automatically.
    """
    import jax.numpy as jnp

    out = {}
    for k, v in state.items():
        a = np.asarray(v)
        if a.ndim >= 2 and a.shape[-2:] == (q, q):
            a = np.delete(np.delete(a, dead, axis=-2), dead, axis=-1)
            out[k] = jnp.asarray(a)
        else:
            out[k] = v
    return out
