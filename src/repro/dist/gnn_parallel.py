"""Partition-parallel GNN runtime (paper Algorithm 1, DESIGN.md §3).

Each of the ``Q`` workers owns one graph partition in the padded ``[Q, ...]``
layout produced by :class:`repro.graph.partition.PartitionedGraph`.  A layer's
aggregation ``S x`` decomposes into

* a **local** scatter over edges whose endpoints are both owned, plus
* a **remote** scatter over cross edges whose source activations arrive via
  the *halo exchange*: every worker publishes its boundary nodes, the blocks
  are (optionally compressed, then) all-gathered, and the flattened
  ``[Q·B, F]`` halo buffer supplies the remote neighbour terms.

The same aggregation oracle (``nn.gnn.AggregateFn``) is built two ways:

* ``_make_aggregate_emulated`` — single-device emulation over the stacked
  ``[Q, ...]`` arrays (vmap over partitions, the all-gather is a reshape).
  This is the default test/CPU path.
* ``_make_aggregate_shard`` — the real collective path for ``shard_map``
  over a ``workers`` mesh axis, using
  :func:`repro.core.collectives.compressed_all_gather`.

Both draw per-worker compression masks from ``fold_in(key, worker_index)``
of a per-exchange key, so the emulated and shard_map runs are *bitwise
identical* (tests/test_multidevice.py pins this).

Wire formats (``DistMeta.wire``, DESIGN.md §3.3/§3.5): ``"dense"``
all-gathers the masked ``[B, F]`` boundary block — compression shrinks the
ledger, not the buffer; ``"packed"`` ships only the kept lane-blocks
(``[B, K·128]``, via :func:`repro.core.collectives.packed_all_gather` / the
varco_pack kernels), so the wire volume itself drops with the rate;
``"p2p"`` replaces the all-gather entirely with a neighbor-only
``ppermute`` ring (:func:`repro.core.collectives.neighbor_exchange`) that
ships each peer only the per-pair halo rows it references
(``repro.dist.halo``), and runs the local-edge aggregation through the
``ell_spmm`` kernel path (:func:`repro.kernels.ops.ell_aggregate`) while
the hops are in flight — transport equals the analytic point-to-point
charge at every rate.  All formats draw the same per-worker masks, so
packed / p2p and dense-``blockmask`` runs deliver identical remote values;
wire buffer shapes are set by the static kept-block counts, which each
step quantises from the schedule outside jit (bounded recompiles — see
:func:`make_train_step`).  The p2p wire needs the halo/ELL index arrays of
:func:`repro.dist.halo.attach_p2p` merged into the graph pytree.

Both non-dense wires additionally accept a per-pair ``[Q, Q]`` **rate
map** (DESIGN.md §3.6) in place of the scalar rate — the operand the
closed-loop controllers of ``repro.dist.ratectl`` plan each step: one
static kept-block count per width (the map's maximum) keeps recompiles
bounded, nested permutation masks carve out each pair's own kept set, and
the ledger grows per-pair transport / compression-error / staleness
columns (see :func:`_make_aggregate_emulated`).

Ledger accounting (paper Fig. 5 axis): every exchange charges two numbers,
``[analytic, transport]``.  Analytic is ``halo_demand × F × 32 / rate``
bits — the activations a point-to-point implementation would ship.
Transport is what the active wire format actually ships per needed boundary
row: the full ``F`` columns on the dense wire (zeros travel too), the
``K·128`` packed columns on the packed wire (DESIGN.md §3.2–3.3).  A train
step charges twice the forward traffic (activations forward + their
cotangents backward).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.collectives import (compressed_all_gather,
                                    neighbor_exchange_finish,
                                    neighbor_exchange_start,
                                    packed_all_gather)
from repro.core.compression import Compressor
from repro.core.varco import FULL_COMM, CommPolicy
from repro.dist.sharding import worker_graph_shardings
from repro.graph.partition import PartitionedGraph
from repro.kernels.ops import (WIRE_WIDTHS, dequant_bits, ell_aggregate,
                               pack_bits, per_block_wire_bits, quant_levels,
                               round_key, wire_pack, wire_quant, wire_unpack)
from repro.kernels.varco_pack import (LANE, worker_block_maps,
                                      worker_block_maps_pos)
from repro.nn.gnn import GNNConfig, gnn_forward, masked_loss_and_correct
from repro.train.optim import Optimizer, apply_updates

AXIS = "workers"
WIRES = ("dense", "packed", "p2p")

# shard_map executables kept per kept-block map before LRU eviction (an
# annealing schedule revisits maps; see make_train_step)
COMPILED_CACHE_SIZE = 8


# ---------------------------------------------------------------------------
# Static partition metadata
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DistMeta:
    """Static (hashable) facts about a partitioning, shared by every step.

    ``halo_demand`` is the paper's communication unit: the number of distinct
    (requesting partition, remote node) pairs whose activations must cross
    the wire each exchange.  Split sizes are *global* so per-worker losses
    normalise identically (``psum(local grads) == full gradient``).

    ``wire`` selects the halo-exchange transport (DESIGN.md §3.3/§3.5):
    ``"dense"`` ships the masked ``[B, F]`` block, ``"packed"`` ships only
    the kept ``[B, K·128]`` lane-blocks via the varco_pack kernels, and
    ``"p2p"`` ships each peer only its per-pair halo rows over the
    ``neighbor_exchange`` ppermute ring (graph pytree must carry the
    ``repro.dist.halo.attach_p2p`` arrays).  ``p2p_hop_width`` /
    ``p2p_compact`` are the p2p wire's static buffer facts (``H`` rows per
    ring hop, receiver-side compact-buffer height).

    Example::

        pg = partition_graph(g, q=8, scheme="random")
        meta = DistMeta.build(pg, params, wire="p2p")
        step = make_train_step(cfg, policy, opt, meta)
    """

    q: int
    part_size: int
    halo_size: int
    num_nodes: int
    feat_dim: int
    num_classes: int
    halo_demand: int
    cross_edges: int
    n_train: int
    n_val: int
    n_test: int
    layer_dims: tuple[int, ...]
    wire: str = "dense"
    p2p_hop_width: int = 0
    p2p_compact: int = 0
    # flattened [Q*Q] per-pair halo row counts (receiver-major; diagonal 0),
    # summing to halo_demand — the unit of per-pair rate-map accounting and
    # of the ratectl controllers' water-filling (DESIGN.md §3.6)
    pair_rows: tuple = ()

    def __post_init__(self):
        if self.wire not in WIRES:
            raise ValueError(f"wire must be one of {WIRES}, got {self.wire!r}")
        if self.wire == "packed":
            # halo exchanges happen at each layer's *input* width, which is
            # what layer_dims records (sage exchanges once per layer, poly
            # once per extra tap — same widths)
            for f in {self.feat_dim, *self.layer_dims}:
                if f % LANE:
                    raise ValueError(
                        f"packed wire needs every exchanged feature width "
                        f"divisible by {LANE}, got {f} (exchanged widths: "
                        f"{sorted({self.feat_dim, *self.layer_dims})}); "
                        f"use wire='dense' for off-lane-grid models")

    @staticmethod
    def build(pg: PartitionedGraph, params: dict,
              wire: str = "dense") -> "DistMeta":
        dims = []
        for layer in params["layers"]:
            if "self" in layer:                       # sage
                dims.append(int(layer["self"]["w"].shape[0]))
            else:                                     # poly taps
                dims.append(int(layer["taps"][0]["w"].shape[0]))
        # the per-pair facts cost an O(Q² + edges) host sweep — only the
        # rate-map-capable wires consume them, the dense wire stays free.
        # Shard-backed graphs (repro.graph.stream.ShardSet) carry the spec
        # precomputed in their manifest, so no sweep (and no global graph)
        # is needed at all.
        hop_w = compact = 0
        pair_rows: tuple = ()
        if wire != "dense":
            spec = getattr(pg, "halo_spec", None)
            if spec is None:
                from repro.dist.halo import build_halo_spec
                spec = build_halo_spec(pg)
            pair_rows = spec.pair_rows
            if wire == "p2p":
                hop_w, compact = spec.hop_width, spec.compact_rows
        n_train = getattr(pg, "n_train", None)
        return DistMeta(
            q=pg.q, part_size=pg.part_size, halo_size=pg.halo_size,
            num_nodes=pg.num_nodes, feat_dim=pg.feat_dim,
            num_classes=pg.num_classes, halo_demand=pg.halo_demand,
            cross_edges=pg.cross_edges,
            n_train=int(pg.train_mask.sum()) if n_train is None
            else int(n_train),
            n_val=int(pg.val_mask.sum()) if n_train is None
            else int(pg.n_val),
            n_test=int(pg.test_mask.sum()) if n_train is None
            else int(pg.n_test),
            layer_dims=tuple(dims), wire=wire,
            p2p_hop_width=hop_w, p2p_compact=compact,
            pair_rows=pair_rows)

    def pair_table(self) -> np.ndarray:
        """``[Q, Q]`` per-pair halo row counts (receiver × sender, diagonal
        0; entries sum to ``halo_demand``).  The unit of the per-pair
        rate-map ledger and of ``repro.dist.ratectl``'s allocations.
        Populated by :meth:`build` for the packed and p2p wires;
        hand-constructed or dense-wire metas must fill ``pair_rows``
        before using a ``[Q, Q]`` rate map."""
        if not self.pair_rows:
            raise ValueError(
                "DistMeta.pair_rows is empty — per-pair rate maps need the "
                "pair table; construct the meta via DistMeta.build(...) "
                "with wire='packed' or 'p2p' (dense metas don't carry it)")
        return np.asarray(self.pair_rows, np.int64).reshape(self.q, self.q)

    def ledger_bits(self, feat: int, rate=1.0) -> jnp.ndarray:
        """Analytic wire bits of one halo exchange at feature width ``feat``."""
        return jnp.asarray(self.halo_demand * feat * 32.0, jnp.float32) / \
            jnp.asarray(rate, jnp.float32)

    def packed_width(self, feat: int, rate: float = 1.0) -> int:
        """Columns of the packed wire payload: ``K·128`` with ``K =
        max(floor((feat/128)/rate), 1)`` (matches ``block_mask_indices``).
        ``rate`` must be static; ``feat % 128 == 0``."""
        if feat % LANE:
            raise ValueError(f"packed wire needs feat % {LANE} == 0, "
                             f"got {feat}")
        n_blocks = feat // LANE
        return max(int(n_blocks / max(float(rate), 1.0)), 1) * LANE

    def _wire_width(self, feat: int, rate: float) -> int:
        """On-wire column count of the active format at ``rate``."""
        if self.wire == "packed":
            return self.packed_width(feat, rate)
        if self.wire == "p2p":
            # uncompressed hops ship dense rows (any width); compressing
            # policies pack lane-blocks exactly like the packed wire
            return feat if float(rate) <= 1.0 \
                else self.packed_width(feat, rate)
        return feat

    def transport_bits(self, feat: int, rate: float = 1.0) -> jnp.ndarray:
        """Bits the active wire format actually ships per halo exchange,
        charged per needed boundary row (same point-to-point ``halo_demand``
        unit as :meth:`ledger_bits`): the full ``feat`` columns on the dense
        wire — dropped entries travel as zeros — vs the ``K·128`` packed
        columns.  Equals ``ledger_bits`` at rate 1 on the packed and p2p
        wires; on the p2p wire the charge *is* the physically shipped
        volume (padding aside) — the analytic edge-cut rows — equal to
        ``ledger_bits`` whenever the rate divides the lane-block count."""
        width = self._wire_width(feat, rate)
        return jnp.asarray(self.halo_demand * width * 32.0, jnp.float32)

    def transport_bits_quant(self, feat: int, rate: float = 1.0,
                             width: int = 32) -> jnp.ndarray:
        """:meth:`transport_bits` on a quantised wire (DESIGN.md §3.8):
        per needed boundary row, each of the ``K`` kept lane-blocks
        charges ``128·width`` payload bits plus one fp32 scale.
        ``width >= 32`` reproduces :meth:`transport_bits` exactly (fp32
        ships no scales) — the analytic counterpart the quant smoke pins
        the measured ledger against."""
        if width >= 32:
            return self.transport_bits(feat, rate)
        k = self.packed_width(feat, rate) // LANE
        return jnp.asarray(
            self.halo_demand * k * (LANE * width + 32.0), jnp.float32)

    def collective_bits(self, feat: int, rate: float = 1.0) -> float:
        """Bits the wire format physically moves per exchange, padding
        included — the honest buffer-level volume the benchmarks compare.
        All-gather wires ship every worker's padded ``[B, width]`` block to
        ``Q - 1`` peers; the p2p ring ships ``Q - 1`` padded ``[H, width]``
        hop buffers per worker, each crossing to exactly one peer."""
        width = self._wire_width(feat, rate)
        if self.wire == "p2p":
            return float(self.q * max(self.q - 1, 0) *
                         self.p2p_hop_width * width * 32.0)
        return float(self.q * (self.q - 1) * self.halo_size * width * 32.0)


# ---------------------------------------------------------------------------
# Mesh / placement
# ---------------------------------------------------------------------------


def make_worker_mesh(q: int) -> Mesh:
    """1-D ``workers`` mesh over the first ``q`` local devices.

    Example (8 virtual CPU devices)::

        # XLA_FLAGS=--xla_force_host_platform_device_count=8
        mesh = make_worker_mesh(8)
        step = make_train_step(cfg, policy, opt, meta, mesh=mesh)
    """
    devs = jax.devices()
    if len(devs) < q:
        raise ValueError(f"need {q} devices for a worker mesh, have "
                         f"{len(devs)} (set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count={q})")
    return Mesh(np.asarray(devs[:q]), (AXIS,))


def shard_graph(graph: dict, mesh: Mesh) -> dict:
    """Place the ``[Q, ...]`` graph pytree over the ``workers`` axis.

    Handles every leaf the runtime knows — including the p2p per-pair halo
    specs and ELL lists merged in by ``repro.dist.halo.attach_p2p`` (all
    stacked ``[Q, ...]``, specs from
    :func:`repro.dist.sharding.worker_graph_shardings`).

    Example::

        graph = shard_graph(attach_p2p(pg.device_arrays(), pg),
                            make_worker_mesh(pg.q))
    """
    shardings = worker_graph_shardings(graph, mesh, AXIS)
    return {k: jax.device_put(v, shardings[k]) for k, v in graph.items()}


# ---------------------------------------------------------------------------
# Aggregation oracles
# ---------------------------------------------------------------------------


def _local_w_for(graph: dict, policy: CommPolicy, rate):
    """Local edge weights for a communicating exchange at rate ``r``.

    VARCO mode blends toward the isolated-subgraph renormalisation: the
    biased mask delivers remote halo mass attenuated by ``1/r`` in
    expectation, so the aggregation realises ``(1/r)·S_full + (1-1/r)·S_iso``
    — local weights interpolate from the global-degree normalisation
    (``r=1``, bitwise the centralized operator) toward the No-Comm operator
    (``r→∞``).  Without the blend, heavy early compression under-scales
    every aggregation instead of degrading gracefully to the
    (well-conditioned) local-only training that the schedule then anneals
    away from.

    Fixed-compression and full-comm runs keep the paper's plain baseline
    semantics (no renormalisation), which the Definition-1 error-envelope
    tests pin down.
    """
    lw = graph["local_w"]
    if policy.mode != "varco":
        return lw
    mix = 1.0 - 1.0 / jnp.maximum(jnp.asarray(rate, jnp.float32), 1.0)
    return lw + mix * (graph["local_w_iso"] - lw)


def _ell_w_for(graph: dict, policy: CommPolicy, rate):
    """:func:`_local_w_for` in ELL layout (the p2p wire's local weights):
    the same VARCO blend toward the isolated-subgraph renormalisation,
    applied elementwise to the degree-padded ``[Q, P, K]`` weight lists
    (pad entries are 0 in both operands, so they stay 0)."""
    w = graph["ell_w"]
    if policy.mode != "varco":
        return w
    mix = 1.0 - 1.0 / jnp.maximum(jnp.asarray(rate, jnp.float32), 1.0)
    return w + mix * (graph["ell_w_iso"] - w)


def _exchange_bits(meta: DistMeta, f: int, rate,
                   wire_width: int | None = None) -> jnp.ndarray:
    """Per-exchange ledger charge ``[analytic, transport]`` (module docs).
    ``wire_width`` is the static on-wire column count — ``K·128`` on the
    packed wire, the full ``f`` (dense buffer) when ``None``."""
    transport = meta.halo_demand * (f if wire_width is None
                                    else wire_width) * 32.0
    return jnp.stack([meta.ledger_bits(f, rate),
                      jnp.asarray(transport, jnp.float32)])


def _keep_of(f: int, rate, packed_k: dict | None) -> int:
    """Static kept-block count for a packed exchange at width ``f``: from
    the quantised ``packed_k`` map when the rate is traced (train steps),
    else derived from a concrete rate directly (tests / eval call sites)."""
    n_blocks = f // LANE
    if packed_k is not None:
        return packed_k[n_blocks]
    return max(int(n_blocks / max(float(rate), 1.0)), 1)


def _exchanged_nbs(meta: DistMeta) -> tuple:
    """Sorted distinct lane-block counts of every exchanged feature width
    (``feat_dim`` plus each layer's input width) — THE shared domain of
    every bounded-recompile static-fact map (`_packed_k_for`,
    `_packed_pair_k_for`, `_packed_pair_w_for`): each quantises its traced
    operand to one static value per entry of this tuple, so the number of
    distinct compiled variants is bounded by the tuple's value ranges, not
    by the operand's."""
    return tuple(sorted({d // LANE for d in (meta.feat_dim,
                                             *meta.layer_dims)}))


def _packed_k_for(meta: DistMeta, rate_f: float) -> tuple:
    """Quantise a concrete rate to the kept-block count of every exchanged
    width (``layer_dims`` = each layer's input width) — the *only* static
    fact the packed wire needs per step, so an annealing schedule triggers
    at most ``Π n_blocks`` recompiles (a handful) instead of one per
    distinct rate value."""
    return tuple((nb, max(int(nb / max(rate_f, 1.0)), 1))
                 for nb in _exchanged_nbs(meta))


# ---------------------------------------------------------------------------
# Per-pair rate maps (DESIGN.md §3.6) — shared plumbing of both backends
# ---------------------------------------------------------------------------
#
# A closed-loop controller (``repro.dist.ratectl``) plans a ``[Q, Q]`` rate
# map (receiver × sender) instead of one scalar.  The wire realises it with
# ONE static kept-block count per exchanged width — the map's *maximum* —
# so recompiles stay bounded exactly like `_packed_k_for`: every sender
# packs once at that count, and each pair's smaller kept set is carved out
# by zeroing packed columns whose block sits at permutation position
# ``>= k_pair`` (kept sets at different counts are nested under one key —
# `block_mask_indices_pos`).  The dense wire keeps the scalar path.


def _pair_keep(nb: int, rate_map, k_max: int) -> jnp.ndarray:
    """Traced per-pair kept-block counts ``[Q, Q]`` at width ``nb·128``:
    the same ``max(floor(nb / r), 1)`` rule as the ``blockmask`` compressor
    and `_keep_of`, clamped to the step's static maximum ``k_max``."""
    r = jnp.maximum(jnp.asarray(rate_map, jnp.float32), 1.0)
    k = jnp.maximum(jnp.floor(nb / r), 1.0)
    return jnp.minimum(k, float(k_max)).astype(jnp.int32)


def _packed_pair_k_for(meta: DistMeta, rate_map) -> tuple:
    """Quantise a concrete rate map to the static max kept-block count of
    every exchanged width — `_packed_k_for`'s bounded-recompile contract
    for rate maps (at most ``Π (width/128)`` distinct tuples).

    Accepts the per-pair ``[Q, Q]`` map or the per-layer ``[L, Q, Q]``
    tensor (DESIGN.md §3.7): the static count is the maximum over every
    layer's off-diagonal entries, so one packed buffer per width serves
    all layers and each layer's smaller kept set is carved out by the
    nested column masks."""
    rm = np.maximum(np.asarray(rate_map, np.float64), 1.0)
    q = meta.q
    rm = rm.reshape(-1, q, q)          # [L, Q, Q] (L == 1 for pair maps)
    off = ~np.eye(q, dtype=bool) if q > 1 else np.zeros((1, 1), bool)
    out = []
    for nb in _exchanged_nbs(meta):
        k = np.maximum(np.floor(nb / rm), 1.0)
        kmax = int(k[:, off].max()) if q > 1 else 1
        out.append((nb, min(max(kmax, 1), nb)))
    return tuple(out)


def _snap_width(v) -> int:
    """Snap a planned bit-width to the nearest supported storage width from
    above: {2, 4, 8} quantised wire widths, else 32 (exact fp32).  Snapping
    *up* keeps realised error at or below the planner's estimate — the
    width analogue of `_pair_keep`'s floor-to-k rule."""
    v = float(v)
    for w in WIRE_WIDTHS[:-1]:
        if v <= w:
            return w
    return 32


def _packed_pair_w_for(meta: DistMeta, width_map) -> tuple:
    """Quantise a concrete width map to the sorted tuple of distinct
    sub-32 storage widths it realises off-diagonal — `_packed_pair_k_for`'s
    bounded-recompile contract for the width axis.

    The tuple is the jit-static fact the step function keys its compiled
    variants on: ``()`` (no pair quantises) compiles the exact pre-
    quantisation program — the quantise/dequantise code never enters the
    jaxpr — and at most ``2^|{2,4,8}|`` distinct tuples exist, so an
    annealing width schedule recompiles a bounded handful of times no
    matter how many distinct planned widths it visits.  Accepts ``[Q, Q]``
    or per-layer ``[L, Q, Q]`` maps (self-pairs never ship, so the
    diagonal is ignored)."""
    if width_map is None:
        return ()
    q = meta.q
    if q <= 1:
        return ()
    wm = np.asarray(width_map, np.float64).reshape(-1, q, q)
    off = ~np.eye(q, dtype=bool)
    ws = sorted({_snap_width(v) for v in wm[:, off].ravel()})
    return tuple(w for w in ws if w < 32)


def _packed_store_w(meta: DistMeta, width_map) -> int:
    """Static sub-byte **storage** width of a concrete width map
    (DESIGN.md §3.8): the maximum snapped off-diagonal width when every
    off-diagonal pair quantises (all snap below 32), else 0.

    Non-zero turns the quantised wires into true bit-packed byte buffers
    — ``8/store_w`` lanes per byte ride the collective instead of fp32
    lanes.  Pairs planned *below* the storage width store exactly (their
    levels fit the wider field; the ledger still charges the planned
    width).  Any pair at width ≥ 32 forces 0: fp32 lanes must travel for
    that pair, so the whole exchange stays on the exact straight-through
    value path.  Like `_packed_pair_w_for` this is a jit-static fact
    derived from `_snap_width`, so it adds no recompiles beyond the
    width-tuple's own variants."""
    if width_map is None:
        return 0
    q = meta.q
    if q <= 1:
        return 0
    wm = np.asarray(width_map, np.float64).reshape(-1, q, q)
    off = ~np.eye(q, dtype=bool)
    ws = {_snap_width(v) for v in wm[:, off].ravel()}
    if not ws or max(ws) >= 32:
        return 0
    return max(ws)


def _rate_tensor_layers(meta: DistMeta, rate_map) -> int:
    """Static layer count of a rate operand: 1 for ``None`` / ``[Q, Q]``
    pair maps, ``L`` for a per-layer ``[L, Q, Q]`` tensor — which must
    match the model's layer count (``len(meta.layer_dims)``), since layer
    ``li``'s exchanges index row ``li``."""
    if rate_map is None or jnp.ndim(rate_map) == 2:
        return 1
    if jnp.ndim(rate_map) != 3:
        raise ValueError(f"rate map must be [Q, Q] or [L, Q, Q], got "
                         f"ndim {jnp.ndim(rate_map)}")
    n_layers = int(jnp.shape(rate_map)[0])
    if n_layers != len(meta.layer_dims):
        raise ValueError(
            f"per-layer rate tensor has {n_layers} layer rows but the "
            f"model exchanges at {len(meta.layer_dims)} layers "
            f"(DistMeta.layer_dims {meta.layer_dims})")
    return n_layers


def _ring_targets(q: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``(senders [Q, 1], receivers [Q, D])`` of the hop layout: sender
    ``j``'s ring-offset-``d`` buffer goes to worker ``(j + d) mod Q``
    (``D = max(Q-1, 1)``, degenerate but well-formed at ``Q == 1``)."""
    jj = jnp.arange(q)[:, None]
    rv = (jj + jnp.arange(1, max(q, 2))[None, :]) % q
    return jj, rv


def _scatter_pairs(vals_jd: jnp.ndarray, q: int) -> jnp.ndarray:
    """Reshape sender-major per-hop values ``[Q, D]`` into the receiver ×
    sender ``[Q, Q]`` pair matrix (diagonal 0)."""
    if q == 1:
        return jnp.zeros((1, 1), vals_jd.dtype)
    jj, rv = _ring_targets(q)
    return jnp.zeros((q, q), vals_jd.dtype).at[rv, jj].set(vals_jd)


def _pair_hop_energy(publish: jnp.ndarray, slot: jnp.ndarray,
                     valid: jnp.ndarray) -> jnp.ndarray:
    """Per-hop, per-lane-block energy of the published boundary rows.

    ``publish [Q, B, F]`` (pre-compression), ``slot``/``valid [Q, D, H]``
    (the p2p per-pair halo sets) → ``[Q, D, nb]`` summed squared values of
    hop ``(j, d)``'s genuine rows per 128-lane block.  The blockmask
    round-trip error of a pair is *exactly* its dropped blocks' energy, so
    the ``error`` controller's observation is this tensor masked by the
    pair's dropped set — identical arithmetic on both backends."""
    q, _, f = publish.shape
    nb = f // LANE
    be = jnp.sum(publish.reshape(q, -1, nb, LANE).astype(jnp.float32) ** 2,
                 axis=-1)                              # [Q, B, nb]

    def per_worker(bej, slots, vals):                  # [B,nb],[D,H],[D,H]
        return jnp.sum(bej[slots] * vals[..., None], axis=1)

    return jax.vmap(per_worker)(be, slot, valid)       # [Q, D, nb]


def _pair_ledger(meta: DistMeta, f: int, rate_map, row_bits,
                 pair_err, pair_delta, live=None, li: int = 0,
                 n_layers: int = 1, width_map=None) -> jnp.ndarray:
    """Flat per-pair ledger vector of one exchange:
    ``[analytic, transport, layer_transport (L·Q²), layer_err (L·Q²),
    layer_delta (L·Q²)]`` (length ``2 + 3·L·Q²``).

    ``row_bits [Q, Q]`` is each pair's realised on-wire bits *per shipped
    row* — ``kept columns · 32`` on the fp32 wire, ``kept blocks ·
    per_block_wire_bits(w)`` (low-bit payload + one fp32 scale per block,
    the PR-1 accounting convention) when the pair quantises; ``live``
    (0/1, default all-1) zeroes skipped pairs (the ``stale`` controller's
    reused hops ship nothing, forward or backward).

    ``width_map [Q, Q]`` scales the *analytic* column by each pair's
    ``w/32`` payload factor (scale overhead excluded — analytic is the
    paper's idealised element count, transport the wire truth), making
    rate × width one joint 2-D allocation on both ledger columns.

    ``li``/``n_layers`` place this exchange's pair blocks on the per-layer
    ledger axis (DESIGN.md §3.7): each block lands in layer ``li``'s
    ``Q²`` slice, zeros elsewhere, so summing the per-call vectors across
    a forward pass composes the ``[L, Q, Q]`` tensors exchange-by-
    exchange.  ``n_layers == 1`` is the legacy per-pair layout (all
    exchanges accumulate into the single slice)."""
    rows = jnp.asarray(meta.pair_table(), jnp.float32)
    live = jnp.ones_like(rows) if live is None else live
    r = jnp.maximum(jnp.asarray(rate_map, jnp.float32), 1.0)
    w_factor = 1.0
    if width_map is not None:
        w = jnp.asarray(width_map, jnp.float32)
        w_factor = jnp.where(w >= 32.0, 1.0, w / 32.0)
    analytic = jnp.sum(rows * live * f * 32.0 / r * w_factor)
    pair_t = rows * live * row_bits

    def embed(block):
        if n_layers == 1:
            return block.ravel()
        out = jnp.zeros((n_layers, block.size), block.dtype)
        return out.at[li].set(block.ravel()).ravel()

    return jnp.concatenate([
        jnp.stack([analytic, jnp.sum(pair_t)]),
        embed(pair_t), embed(pair_err), embed(pair_delta)])


def _dead_mix(meta: DistMeta, dead) -> jnp.ndarray:
    """Per-receiver fraction of remote halo rows served by DEAD pairs
    (``[Q]``): the blend weight of the local-only renormalisation.  A
    fully dark receiver (every remote pair dead) lands exactly on the
    isolated (No-Comm) aggregation weights — the paper's rate→0 limit
    (DESIGN.md §3.10)."""
    rows = jnp.asarray(meta.pair_table(), jnp.float32)
    dark = jnp.sum(rows * jnp.asarray(dead, jnp.float32), axis=1)
    return dark / jnp.maximum(jnp.sum(rows, axis=1), 1.0)


def _fault_live(q: int, fskip, dead, live):
    """Fold the fault masks into the ledger's live matrix: CACHED
    (``fskip``) and DEAD pairs ship nothing, forward or backward — both
    their analytic and transport charges go to zero, and the budget/PI
    loop re-spends those bits on live pairs."""
    if fskip is None and dead is None:
        return live
    lv = jnp.ones((q, q), jnp.float32) if live is None else live
    if fskip is not None:
        lv = lv * (1.0 - jnp.asarray(fskip, jnp.float32))
    if dead is not None:
        lv = lv * (1.0 - jnp.asarray(dead, jnp.float32))
    return lv


def _make_aggregate_emulated(graph: dict, meta: DistMeta, policy: CommPolicy,
                             compressor: Compressor | None, rate, key,
                             packed_k: dict | None = None, rate_map=None,
                             skip=None, cache=None,
                             cache_out: list | None = None,
                             width_map=None, resid=None,
                             resid_out: list | None = None,
                             fskip=None, fcache=None,
                             fcache_out: list | None = None, dead=None,
                             rounding: str = "rint", store_w: int = 0,
                             wire_out: list | None = None):
    """AggregateFn over stacked ``[Q, P, F]`` tensors on one device.

    Numerically identical to the shard_map path: the all-gather becomes a
    reshape of the per-partition published blocks, and compression draws the
    worker-``i`` mask from ``fold_in(per-exchange key, i)`` exactly as
    ``compressed_all_gather`` does on device ``i``.  On the packed wire the
    same keys select the kept lane-blocks, and the wire payload is
    materialised through ``wire_pack``/``wire_unpack`` so the emulation
    exercises the real pack→ship→unpack round trip.  On the p2p wire each
    ``ppermute`` ring offset becomes a roll of the per-pair send buffers
    (same keys → same masks as ``neighbor_exchange``), and the local edges
    run through :func:`repro.kernels.ops.ell_aggregate`.

    ``rate_map`` (traced ``[Q, Q]``, receiver × sender) switches the packed
    and p2p wires to per-pair rates (DESIGN.md §3.6): every sender packs
    once at the static step maximum (``packed_k``), pairs below it are
    carved out by the nested-permutation column masks, and the returned
    ledger vector grows to ``2 + 3·Q²`` (per-pair transport, compression
    error, staleness delta).  A per-layer ``[L, Q, Q]`` tensor
    (DESIGN.md §3.7; ``L == len(meta.layer_dims)``) makes layer ``li``'s
    exchange draw its own ``[Q, Q]`` row, and the ledger vector grows a
    layer axis (``2 + 3·L·Q²``, exchange ``li``'s charges in slice
    ``li``).  ``skip``/``cache``/``cache_out`` are the ``stale``
    controller's hop reuse on the p2p wire: pair ``(i, j)`` with
    ``skip[i, j] == 1`` delivers ``cache[call]``'s rows instead of fresh
    ones and charges zero wire bits; the fresh buffers land in
    ``cache_out`` (one ``[Q, D, H, F]`` entry per exchange call).

    ``width_map`` (traced ``[Q, Q]`` or ``[L, Q, Q]``, same selection rule
    as ``rate_map``) quantises each pair's wire payload to its planned
    bit-width (DESIGN.md §3.8): the p2p wire quantises every hop at its
    *exact* per-pair width through the straight-through
    :func:`repro.kernels.ops.wire_quant`; the packed all-gather wire — one
    payload per sender — quantises at each sender's max width over its
    receivers (serve the most demanding, like ``k_send``).  The ledger
    charges the true ``per_block_wire_bits`` (payload at width + one fp32
    scale per kept block).  ``resid``/``resid_out`` are the error-feedback
    accumulators (p2p only): call ``i``'s residual ``[Q, D, H, F]`` is
    added to the pre-quantisation payload and the fresh quantisation error
    lands in ``resid_out``, so the compression error is re-shipped next
    step instead of lost (gradients see only the STE path — residual
    injection is ``stop_gradient``).

    ``fskip``/``fcache``/``fcache_out``/``dead`` are the FAULT channel
    (DESIGN.md §3.10) — deliberately separate from the ``stale``
    controller's ``skip``/``cache`` so degraded-mode halo service works
    under every policy: a pair with ``fskip[i, j] == 1`` (link dropped,
    cache still fresh enough) is served from ``fcache[call]`` and charges
    zero wire bits; ``dead[i, j] == 1`` (past ``max_stale``) zeroes the
    pair's rows and blends the receiver's local aggregation toward the
    isolated weights (:func:`_dead_mix`).  The served buffers land in
    ``fcache_out`` (one ``[Q, D, H, F]`` sender-major entry per exchange
    call) so the receiver's cache tracks the last content it actually
    aggregated.

    ``store_w`` (static, from :func:`_packed_store_w`) switches the
    quantised wires to **true sub-byte storage** (DESIGN.md §3.8): the
    materialised wire buffer is the bit-packed uint8 levels
    (``8/store_w`` lanes per byte) plus the fp32 block scales, and the
    delivered values are rebuilt as ``levels · scale`` from those bytes
    — elementwise identical to the shard backend's byte collectives, so
    mixed rate × width runs stay in the parity matrix.  ``wire_out``,
    when a list, captures each rate-map exchange's physically shipped
    buffers — ``(payload uint8, scales)`` under ``store_w``, ``(fp32
    buffer, None)`` otherwise (sender-major ``[Q, D, H, ·]`` hop stacks
    on the p2p wire, ``[Q, B, ·]`` payloads on the packed wire) — the
    ledger-vs-buffer conservation hook.

    The returned oracle carries the split-phase API of the pipelined
    forward (DESIGN.md §3.7): ``aggregate.start(li, x)`` issues the
    pack + exchange and returns ``(token, bits)``;
    ``aggregate.complete(li, x, token)`` runs the local aggregation and
    folds in the delivered halo.  ``aggregate(li, x)`` is exactly
    ``complete`` after ``start`` — one code path, so the fused and
    pipelined schedules are bitwise identical.
    """
    p_sz, b_sz, q = meta.part_size, meta.halo_size, meta.q
    packed_wire = meta.wire == "packed"
    p2p_wire = meta.wire == "p2p"
    if rate_map is not None and not (packed_wire or p2p_wire):
        raise ValueError("per-pair rate maps need wire='packed' or 'p2p'; "
                         "the dense wire keeps the scalar path")
    n_layers = _rate_tensor_layers(meta, rate_map)
    if width_map is not None:
        if rate_map is None:
            raise ValueError("per-pair width maps ride the rate-map wire; "
                             "pass rate_map alongside width_map")
        _rate_tensor_layers(meta, width_map)   # validate [L, Q, Q] shape
    if resid is not None and not p2p_wire:
        raise ValueError("error-feedback residuals are a p2p-wire feature")
    if store_w and width_map is None:
        raise ValueError("store_w (sub-byte storage) rides the width map; "
                         "pass width_map alongside it (DESIGN.md §3.8)")
    if (fskip is not None or fcache is not None or dead is not None) and \
            not (p2p_wire and rate_map is not None):
        raise ValueError("fault channels (fskip/fcache/dead) ride the "
                         "p2p rate-map wire; pass rate_map with "
                         "wire='p2p' (DESIGN.md §3.10)")
    calls = itertools.count()

    def pair_stats_p2p(publish, pos_all, k_used):
        """Per-pair dropped-block energy: ``k_used [Q, D]`` is the kept
        count governing hop ``(j, d)``, ``pos_all [Q, nb]`` each worker's
        permutation positions."""
        if "p2p_send_slot" not in graph:
            return jnp.zeros((q, q), jnp.float32)
        energy = _pair_hop_energy(publish, graph["p2p_send_slot"],
                                  graph["p2p_send_valid"])   # [Q, D, nb]
        dropped = pos_all[:, None, :] >= k_used[:, :, None]  # [Q, D, nb]
        return _scatter_pairs(jnp.sum(energy * dropped, -1), q)

    def start(li, x):                                  # x: [Q, P, F]
        """Issue layer ``li``'s exchange: pack, mask, ship.  Returns
        ``(halo token, bits)`` — the token is consumed by :func:`complete`
        (the only data dependence on the wire)."""
        call = next(calls)
        f = x.shape[-1]
        rm = wm = None
        lix = 0
        if rate_map is not None:
            # select by RANK, not by n_layers: a [1, Q, Q] tensor (1-layer
            # model under a per-layer controller) must still unsqueeze
            rm = rate_map if jnp.ndim(rate_map) == 2 else rate_map[li]
            lix = 0 if n_layers == 1 else li
        if width_map is not None:
            wm = width_map if jnp.ndim(width_map) == 2 else width_map[li]
        if not policy.communicates:                    # No-Comm baseline
            return None, jnp.zeros((2,), jnp.float32)

        if p2p_wire:
            # boundary block [Q, B, F]; a compressing policy packs it once
            # per worker (the real sender's move), the hop buffers are
            # sliced out of the (un)packed rows
            publish = jax.vmap(lambda xq, idx, v: xq[idx] * v[:, None])(
                x, graph["send_idx"], graph["send_valid"])
            bits = None
            if rm is not None:
                nb = f // LANE
                n_keep = _keep_of(f, rate, packed_k)
                k_call = jax.random.fold_in(key, call)
                kept, inv, pos_all = worker_block_maps_pos(k_call, q, nb,
                                                           n_keep)
                pos_kept = jax.vmap(lambda p, kk: p[kk])(pos_all, kept)
                k_pairs = _pair_keep(nb, rm, n_keep)              # [Q, Q]
                jj, rv = _ring_targets(q)
                k_jd = k_pairs[rv, jj]                            # [Q, D]
                packed = jax.vmap(wire_pack)(publish, kept, inv)
                hops = jax.vmap(lambda pk, slots, v:
                                pk[slots] * v[..., None])(
                    packed, graph["p2p_send_slot"],
                    graph["p2p_send_valid"])         # [Q, D, H, K·128]
                cmask = (pos_kept[:, None, :] <
                         k_jd[..., None]).astype(x.dtype)         # [Q, D, K]
                cmask_l = jnp.repeat(cmask, LANE, axis=-1)[:, :, None, :]
                hops = hops * cmask_l
                if wm is not None:
                    w_jd = wm[rv, jj]                             # [Q, D]
                    if resid is not None:
                        # error feedback: pack last step's residual onto
                        # this call's kept set, mask to the pair's live
                        # columns/rows, inject before quantising
                        r_pack = jax.vmap(lambda rq, kk, iv: jax.vmap(
                            lambda r_: wire_pack(r_, kk, iv))(rq))(
                            resid[call], kept, inv)   # [Q, D, H, K·128]
                        r_pack = r_pack * cmask_l * \
                            graph["p2p_send_valid"][..., None]
                        hops = hops + jax.lax.stop_gradient(r_pack)
                    rks = None
                    if rounding == "stochastic":
                        # per-(sender, hop) rounding keys — the exact
                        # streams the shard backend's workers draw from
                        # round_key(key, me, d-1), so both backends
                        # round identically (DESIGN.md §3.8)
                        rks = jax.vmap(lambda j_: jax.vmap(
                            lambda d_: round_key(k_call, j_, d_))(
                            jnp.arange(hops.shape[1])))(jnp.arange(q))
                    if store_w:
                        # sub-byte wire (DESIGN.md §3.8): the hop stack
                        # that would ride the ppermute is the bit-packed
                        # uint8 levels + fp32 scales; the delivered value
                        # is rebuilt from those bytes alone — elementwise
                        # the shard backend's byte hops, with the
                        # gradient passing straight through to the
                        # pre-quantisation rows (its grad carrier)
                        if rks is not None:
                            levels, scales = jax.vmap(jax.vmap(
                                lambda h_, w_, k_: quant_levels(
                                    h_, w_, key=k_)))(hops, w_jd, rks)
                        else:
                            levels, scales = quant_levels(
                                hops, w_jd[:, :, None, None])
                        payload = pack_bits(levels, store_w)
                        if wire_out is not None:
                            wire_out.append((payload, scales))
                        dq = dequant_bits(payload, scales, store_w)
                        hops_q = (hops - jax.lax.stop_gradient(hops)) + \
                            jax.lax.stop_gradient(dq)
                    elif rks is not None:
                        hops_q = jax.vmap(jax.vmap(
                            lambda h_, w_, k_: wire_quant(
                                h_, w_, key=k_)))(hops, w_jd, rks)
                    else:
                        hops_q = wire_quant(hops, w_jd[:, :, None, None])
                    if resid_out is not None:
                        err = jax.lax.stop_gradient(hops - hops_q)
                        resid_out.append(jax.vmap(
                            lambda eq, kk, iv: jax.vmap(
                                lambda e_: wire_unpack(e_, kk, iv))(eq))(
                            err, kept, inv))          # [Q, D, H, F]
                    hops = hops_q
                if wire_out is not None and not (wm is not None and store_w):
                    wire_out.append((hops, None))
                sent = jax.vmap(lambda hp, kk, iv: jax.vmap(
                    lambda h_: wire_unpack(h_, kk, iv))(hp))(
                    hops, kept, inv)                  # [Q, D, H, F]
                pair_err = pair_stats_p2p(publish, pos_all, k_jd)
                pair_delta = jnp.zeros((q, q), jnp.float32)
                live = None
                if cache is not None:
                    c = cache[call]
                    num = jnp.sum((sent - c) ** 2, axis=(-1, -2))
                    den = jnp.sum(sent ** 2, axis=(-1, -2)) + 1e-12
                    pair_delta = _scatter_pairs(num / den, q)
                    sk = skip[rv, jj]                             # [Q, D]
                    sent = jnp.where(sk[..., None, None] > 0.0, c, sent)
                    live = 1.0 - skip
                if fcache is not None:
                    # fault channel: dropped-but-fresh pairs serve the
                    # receiver's cached hop rows (zero wire bits)
                    fsk = fskip[rv, jj]                           # [Q, D]
                    sent = jnp.where(fsk[..., None, None] > 0.0,
                                     fcache[call], sent)
                if fcache_out is not None:
                    fcache_out.append(sent)
                if cache_out is not None:
                    cache_out.append(sent)
                if dead is not None:
                    # past max_stale: the pair ships nothing; its rows
                    # zero out and `complete` renormalises the receiver's
                    # local aggregation (_dead_mix)
                    dd = dead[rv, jj]                             # [Q, D]
                    sent = jnp.where(dd[..., None, None] > 0.0,
                                     jnp.zeros_like(sent), sent)
                live = _fault_live(q, fskip, dead, live)
                row_bits = k_pairs.astype(jnp.float32) * (
                    per_block_wire_bits(wm) if wm is not None
                    else LANE * 32.0)
                bits = _pair_ledger(meta, f, rm, row_bits,
                                    pair_err, pair_delta, live=live,
                                    li=lix, n_layers=n_layers,
                                    width_map=wm)
            else:
                wire_width = None
                if policy.compresses:
                    n_keep = _keep_of(f, rate, packed_k)
                    wire_width = n_keep * LANE
                    k_call = jax.random.fold_in(key, call)
                    kept, inv = worker_block_maps(k_call, q, f // LANE,
                                                  n_keep)
                    packed = jax.vmap(wire_pack)(publish, kept, inv)
                    publish = jax.vmap(wire_unpack)(packed, kept, inv)
                # per-pair hop buffers [Q, D, H, F]
                sent = jax.vmap(lambda pub, slots, v:
                                pub[slots] * v[..., None])(
                    publish, graph["p2p_send_slot"],
                    graph["p2p_send_valid"])
                bits = _exchange_bits(meta, f, rate, wire_width)
            # route: receiver i's hop-d rows come from worker (i - d) mod q
            if q > 1:
                src_w = (jnp.arange(q)[:, None] -
                         jnp.arange(1, q)[None, :]) % q         # [Q, D]
                compact = sent[src_w, jnp.arange(q - 1)[None, :]].reshape(
                    q, meta.p2p_compact, f)
            else:
                compact = jnp.zeros((q, meta.p2p_compact, f), x.dtype)
            return compact, bits

        sent = jax.vmap(lambda xq, idx, v: xq[idx] * v[:, None])(
            x, graph["send_idx"], graph["send_valid"])  # [Q, B, F]
        wire_width = None
        bits = None
        if packed_wire and rm is not None:
            # all-gather wire: one payload serves every receiver, so the
            # map degrades to per-SENDER rates — each sender keeps the max
            # over its receivers' kept counts (serve the most demanding)
            nb = f // LANE
            n_keep = _keep_of(f, rate, packed_k)
            k_call = jax.random.fold_in(key, call)
            kept, inv, pos_all = worker_block_maps_pos(k_call, q, nb, n_keep)
            pos_kept = jax.vmap(lambda p, kk: p[kk])(pos_all, kept)
            k_pairs = _pair_keep(nb, rm, n_keep)
            off = jnp.where(jnp.eye(q, dtype=bool), 0, k_pairs)
            k_send = jnp.maximum(jnp.max(off, axis=0), 1)         # [Q]
            pre = sent
            packed = jax.vmap(wire_pack)(sent, kept, inv)
            cmask = (pos_kept < k_send[:, None]).astype(x.dtype)  # [Q, K]
            packed = packed * jnp.repeat(cmask, LANE, axis=-1)[:, None, :]
            w_send = None
            if wm is not None:
                # one payload per sender: quantise at the max width over
                # its receivers (serve the most demanding), like k_send
                off_w = jnp.where(jnp.eye(q, dtype=bool), 0.0, wm)
                w_send = jnp.max(off_w, axis=0)                   # [Q]
                w_send = jnp.where(w_send > 0.0, w_send, 32.0)
                rks = None
                if rounding == "stochastic":
                    rks = jax.vmap(lambda j_: round_key(k_call, j_))(
                        jnp.arange(q))
                if store_w:
                    # sub-byte wire: the gathered buffer is bit-packed
                    # uint8 levels + fp32 scales (the shard backend's
                    # byte all-gather, elementwise)
                    if rks is not None:
                        levels, scales = jax.vmap(
                            lambda p_, w_, k_: quant_levels(
                                p_, w_, key=k_))(packed, w_send, rks)
                    else:
                        levels, scales = quant_levels(
                            packed, w_send[:, None, None])
                    payload = pack_bits(levels, store_w)
                    if wire_out is not None:
                        wire_out.append((payload, scales))
                    dq = dequant_bits(payload, scales, store_w)
                    packed = (packed - jax.lax.stop_gradient(packed)) + \
                        jax.lax.stop_gradient(dq)
                elif rks is not None:
                    packed = jax.vmap(lambda p_, w_, k_: wire_quant(
                        p_, w_, key=k_))(packed, w_send, rks)
                else:
                    packed = wire_quant(packed, w_send[:, None, None])
            if wire_out is not None and not (wm is not None and store_w):
                wire_out.append((packed, None))
            sent = jax.vmap(wire_unpack)(packed, kept, inv)
            k_jd = jnp.broadcast_to(k_send[:, None], (q, max(q - 1, 1)))
            pair_err = pair_stats_p2p(pre, pos_all, k_jd)
            row_bits = jnp.broadcast_to(
                (k_send.astype(jnp.float32) *
                 (per_block_wire_bits(w_send) if wm is not None
                  else LANE * 32.0))[None, :], (q, q))
            bits = _pair_ledger(meta, f, rm, row_bits, pair_err,
                                jnp.zeros((q, q), jnp.float32),
                                li=lix, n_layers=n_layers,
                                width_map=wm)
        elif packed_wire:
            n_keep = _keep_of(f, rate, packed_k)
            wire_width = n_keep * LANE
            k_call = jax.random.fold_in(key, call)
            kept, inv = worker_block_maps(k_call, q, f // LANE, n_keep)
            packed = jax.vmap(wire_pack)(sent, kept, inv)   # the wire buffer
            sent = jax.vmap(wire_unpack)(packed, kept, inv)
        elif compressor is not None:
            k_call = jax.random.fold_in(key, call)
            keys = jax.vmap(jax.random.fold_in, (None, 0))(
                k_call, jnp.arange(q))
            sent = jax.vmap(lambda k, blk: compressor(k, blk, rate)[0])(
                keys, sent)
        if bits is None:
            bits = _exchange_bits(meta, f, rate, wire_width)
        return sent.reshape(q * b_sz, f), bits

    def complete(li, x, token):
        """Consume layer ``li``'s delivered halo: local aggregation (ELL
        on the p2p wire — scheduled while the exchange is in flight) plus
        the remote scatter out of the token."""
        del li
        f = x.shape[-1]
        if not policy.communicates:                    # No-Comm baseline
            return jax.vmap(lambda xq, ld, ls, w:
                            jnp.zeros((p_sz + 1, f), x.dtype)
                            .at[ld].add(w[:, None] * xq[ls])[:p_sz])(
                x, graph["local_dst"], graph["local_src"],
                graph["local_w_iso"])

        if p2p_wire:
            ell_w = _ell_w_for(graph, policy, rate)
            if dead is not None:
                # local-only fallback: blend each receiver's aggregation
                # weights toward the isolated normalisation by its dark
                # remote-row fraction (all pairs dead → exact No-Comm)
                mix = _dead_mix(meta, dead)
                ell_w = ell_w + mix[:, None, None] * \
                    (graph["ell_w_iso"] - ell_w)

            def part_p2p(xq, nbr, w, rnbr, rslot, rd, rs, rw, halo_c):
                loc = ell_aggregate(xq, nbr, w, rnbr, rslot)
                rem = jnp.zeros((p_sz + 1, f), x.dtype)
                rem = rem.at[rd].add(rw[:, None] * halo_c[rs])
                return loc + rem[:p_sz]

            return jax.vmap(part_p2p)(
                x, graph["ell_nbr"], ell_w, graph["ell_rnbr"],
                graph["ell_rslot"], graph["remote_dst"],
                graph["remote_src_p2p"], graph["remote_w"], token)

        halo = token
        local_w = _local_w_for(graph, policy, rate)

        def part(xq, ld, ls, lw, rd, rs, rw):
            out = jnp.zeros((p_sz + 1, f), x.dtype)
            out = out.at[ld].add(lw[:, None] * xq[ls])
            out = out.at[rd].add(rw[:, None] * halo[rs])
            return out[:p_sz]

        return jax.vmap(part, (0, 0, 0, 0, 0, 0, 0))(
            x, graph["local_dst"], graph["local_src"], local_w,
            graph["remote_dst"], graph["remote_src"], graph["remote_w"])

    def aggregate(li, x):
        token, bits = start(li, x)
        return complete(li, x, token), bits

    aggregate.start = start
    aggregate.complete = complete
    return aggregate


def _make_aggregate_shard(graph: dict, meta: DistMeta, policy: CommPolicy,
                          compressor: Compressor | None, rate, key,
                          axis: str = AXIS, packed_k: dict | None = None,
                          rate_map=None, width_map=None,
                          resid=None, resid_out: list | None = None,
                          fskip=None, fcache=None,
                          fcache_out: list | None = None, dead=None,
                          rounding: str = "rint", store_w: int = 0,
                          wire_out: list | None = None):
    """AggregateFn for one worker inside ``shard_map`` (blocks ``[1, P, F]``).

    Dense wire: :func:`compressed_all_gather` (or a plain all-gather at full
    communication).  Packed wire: :func:`packed_all_gather`, which ships the
    ``[B, K·128]`` lane-block payload.  P2P wire:
    :func:`repro.core.collectives.neighbor_exchange` — ``Q - 1`` ppermute
    hops carrying only the per-pair halo rows, with the local edges on the
    :func:`repro.kernels.ops.ell_aggregate` kernel path so XLA can overlap
    the hops with the local compute.  The per-worker masks derive from the
    same ``fold_in`` streams as the emulated path, so both backends agree
    bitwise.

    ``rate_map`` (traced ``[Q, Q]``, replicated to every worker) switches
    the packed and p2p wires to per-pair rates exactly as in
    :func:`_make_aggregate_emulated`: the collectives mask their packed
    columns with the nested per-pair kept sets, the per-pair error stats
    are all-gathered from each sender, and the returned ledger vector is
    the same ``2 + 3·Q²`` layout (pair staleness deltas stay zero — hop
    reuse is an emulated-backend feature).  A per-layer ``[L, Q, Q]``
    tensor selects row ``li`` per exchange and grows the ledger to
    ``2 + 3·L·Q²``, mirroring the emulated backend bit for bit
    (DESIGN.md §3.7).

    ``width_map`` threads the per-pair bit-widths into the collectives'
    ``pair_w`` channel (DESIGN.md §3.8): ``neighbor_exchange_start``
    quantises each hop at its exact per-pair width,
    ``packed_all_gather`` at each sender's receiver-max — the same
    sender-side arithmetic as the emulated backend, so mixed rate × width
    maps stay bitwise-parity across backends.

    ``resid``/``resid_out`` thread the error-feedback residual channel
    (p2p rate-map wire with ``width_map``): ``resid`` is the tuple of
    per-exchange-call residual *blocks* ``[1, D, H, F]`` (the worker's
    slice of the sender-major ``[Q, D, H, F]`` state, sharded over the
    worker axis) and each call appends its fresh quantisation error —
    same layout — to ``resid_out``, mirroring the emulated backend's EF
    arithmetic step for step so the two backends' residual caches stay
    ≤ 1e-6 apart (tests/parity.py's EF train parity).  ``rounding``
    selects the quantiser's rounding mode ("rint" | "stochastic") with
    the per-(sender, hop) key schedule of
    :func:`repro.kernels.ops.round_key` on both backends.

    ``fskip``/``fcache``/``fcache_out``/``dead`` are the fault channel
    (DESIGN.md §3.10; p2p rate-map wire only), applied RECEIVER-side
    after ``neighbor_exchange_finish``: the SPMD ``ppermute`` still
    executes shape-uniformly (a fault means delivery failed, not that the
    hop was never scheduled), but the receiver discards the dropped
    pair's rows in favour of ``fcache[call][0]`` (its ``[1, D, H, F]``
    receiver-major cache block, sharded over the worker axis) or zeros
    (dead pairs), and the ledger's ``live`` mask zeroes both charges —
    the same pair arithmetic as the emulated backend, so fault runs stay
    in the parity matrix.

    ``store_w`` (static, from :func:`_packed_store_w`) forwards into the
    byte-storage channel of :func:`neighbor_exchange_start` /
    :func:`packed_all_gather`: the collective physically carries the
    bit-packed uint8 levels + fp32 scales instead of fp32 lanes
    (DESIGN.md §3.8).  ``wire_out``, when a list, captures this worker's
    shipped ``(payload, scales)`` buffers per rate-map exchange — the
    caller must return them out of ``shard_map`` to observe them (the
    conservation tests do).

    Carries the same ``start``/``complete`` split-phase attributes as the
    emulated oracle; on this backend ``start`` ends at the ``ppermute``
    (``neighbor_exchange_start``) and ``complete`` begins at the unpack
    (``neighbor_exchange_finish``), so the hops genuinely overlap the ELL
    local aggregation under XLA's async collective scheduling.
    """
    p_sz, b_sz, q = meta.part_size, meta.halo_size, meta.q
    packed_wire = meta.wire == "packed"
    p2p_wire = meta.wire == "p2p"
    if rate_map is not None and not (packed_wire or p2p_wire):
        raise ValueError("per-pair rate maps need wire='packed' or 'p2p'; "
                         "the dense wire keeps the scalar path")
    n_layers = _rate_tensor_layers(meta, rate_map)
    if width_map is not None:
        if rate_map is None:
            raise ValueError("per-pair width maps ride the rate-map wire; "
                             "pass rate_map alongside width_map")
        _rate_tensor_layers(meta, width_map)   # validate [L, Q, Q] shape
    if (fskip is not None or fcache is not None or dead is not None) and \
            not (p2p_wire and rate_map is not None):
        raise ValueError("fault channels (fskip/fcache/dead) ride the "
                         "p2p rate-map wire; pass rate_map with "
                         "wire='p2p' (DESIGN.md §3.10)")
    if resid is not None and not (p2p_wire and width_map is not None):
        raise ValueError("error-feedback residuals ride the quantised "
                         "p2p wire; pass width_map with wire='p2p' "
                         "(DESIGN.md §3.8)")
    if store_w and width_map is None:
        raise ValueError("store_w (sub-byte storage) rides the width map; "
                         "pass width_map alongside it (DESIGN.md §3.8)")
    calls = itertools.count()

    def pair_err_shard(publish_pre, pos_me, k_d):
        """Sender-side dropped-block energy per hop, all-gathered into the
        replicated ``[Q, Q]`` pair matrix (same arithmetic as the emulated
        ``pair_stats_p2p``)."""
        nb = publish_pre.shape[-1] // LANE
        be = jnp.sum(publish_pre.reshape(-1, nb, LANE).astype(jnp.float32)
                     ** 2, axis=-1)                        # [B, nb]
        slot = graph["p2p_send_slot"][0]
        val = graph["p2p_send_valid"][0]
        energy = jnp.sum(be[slot] * val[..., None], axis=1)    # [D, nb]
        dropped = pos_me[None, :] >= k_d[:, None]              # [D, nb]
        err_d = jnp.sum(energy * dropped, -1)                  # [D]
        return _scatter_pairs(lax.all_gather(err_d, axis), q)

    def start(li, x):                                  # x: [1, P, F]
        """Issue layer ``li``'s exchange on this worker.  P2P tokens stop
        at the ``ppermute`` (packed hop rows, no unpack); all-gather
        tokens carry the decoded halo buffer."""
        call = next(calls)
        f = x.shape[-1]
        rm = wm = None
        lix = 0
        if rate_map is not None:
            # select by RANK, not by n_layers (see the emulated backend)
            rm = rate_map if jnp.ndim(rate_map) == 2 else rate_map[li]
            lix = 0 if n_layers == 1 else li
        if width_map is not None:
            wm = width_map if jnp.ndim(width_map) == 2 else width_map[li]
        if not policy.communicates:
            return None, jnp.zeros((2,), jnp.float32)
        xq = x[0]

        if p2p_wire:
            publish = xq[graph["send_idx"][0]] * \
                graph["send_valid"][0][:, None]
            if rm is not None:
                nb = f // LANE
                n_keep = _keep_of(f, rate, packed_k)
                k_call = jax.random.fold_in(key, call)
                k_pairs = _pair_keep(nb, rm, n_keep)
                r_out: list = []
                hops, _ = neighbor_exchange_start(
                    publish, graph["p2p_send_slot"][0],
                    graph["p2p_send_valid"][0], axis, key=k_call,
                    n_keep=n_keep, pair_k=k_pairs, pair_w=wm,
                    resid=None if resid is None else resid[call][0],
                    resid_out=r_out if resid is not None else None,
                    rounding=rounding, store_w=store_w if wm is not None
                    else 0, wire_out=wire_out)
                if resid is not None and resid_out is not None:
                    # [1, D, H, F] block — P(AXIS) out_spec stacks the
                    # workers back into the sender-major [Q, D, H, F]
                    resid_out.append(r_out[0][None] if r_out
                                     else resid[call])
                me = lax.axis_index(axis)
                _, _, pos_all = worker_block_maps_pos(k_call, q, nb, n_keep)
                k_d = k_pairs[(me + jnp.arange(1, max(q, 2))) % q, me]
                pair_err = pair_err_shard(publish, pos_all[me], k_d)
                row_bits = k_pairs.astype(jnp.float32) * (
                    per_block_wire_bits(wm) if wm is not None
                    else LANE * 32.0)
                bits = _pair_ledger(meta, f, rm, row_bits,
                                    pair_err,
                                    jnp.zeros((q, q), jnp.float32),
                                    live=_fault_live(q, fskip, dead, None),
                                    li=lix, n_layers=n_layers,
                                    width_map=wm)
            else:
                n_keep = wire_width = k_call = None
                if policy.compresses:
                    n_keep = _keep_of(f, rate, packed_k)
                    wire_width = n_keep * LANE
                    k_call = jax.random.fold_in(key, call)
                hops, _ = neighbor_exchange_start(
                    publish, graph["p2p_send_slot"][0],
                    graph["p2p_send_valid"][0], axis, key=k_call,
                    n_keep=n_keep)
                bits = _exchange_bits(meta, f, rate, wire_width)
            return (hops, k_call, n_keep, call), bits

        sent = xq[graph["send_idx"][0]] * graph["send_valid"][0][:, None]
        wire_width = None
        bits = None
        if packed_wire and rm is not None:
            nb = f // LANE
            n_keep = _keep_of(f, rate, packed_k)
            k_call = jax.random.fold_in(key, call)
            k_pairs = _pair_keep(nb, rm, n_keep)
            halo, _ = packed_all_gather(sent, axis, n_keep=n_keep,
                                        key=k_call, pair_k=k_pairs,
                                        pair_w=wm, rounding=rounding,
                                        store_w=store_w if wm is not None
                                        else 0, wire_out=wire_out)
            off = jnp.where(jnp.eye(q, dtype=bool), 0, k_pairs)
            k_send = jnp.maximum(jnp.max(off, axis=0), 1)
            me = lax.axis_index(axis)
            _, _, pos_all = worker_block_maps_pos(k_call, q, nb, n_keep)
            pair_err = jnp.zeros((q, q), jnp.float32)
            if "p2p_send_slot" in graph:
                k_d = jnp.broadcast_to(k_send[me], (max(q - 1, 1),))
                pair_err = pair_err_shard(sent, pos_all[me], k_d)
            w_send = None
            if wm is not None:
                off_w = jnp.where(jnp.eye(q, dtype=bool), 0.0, wm)
                w_send = jnp.max(off_w, axis=0)
                w_send = jnp.where(w_send > 0.0, w_send, 32.0)
            row_bits = jnp.broadcast_to(
                (k_send.astype(jnp.float32) *
                 (per_block_wire_bits(w_send) if wm is not None
                  else LANE * 32.0))[None, :], (q, q))
            bits = _pair_ledger(meta, f, rm, row_bits, pair_err,
                                jnp.zeros((q, q), jnp.float32),
                                li=lix, n_layers=n_layers,
                                width_map=wm)
        elif packed_wire:
            n_keep = _keep_of(f, rate, packed_k)
            wire_width = n_keep * LANE
            k_call = jax.random.fold_in(key, call)
            halo, _ = packed_all_gather(sent, axis, n_keep=n_keep,
                                        key=k_call)
        elif compressor is not None:
            k_call = jax.random.fold_in(key, call)
            halo, _ = compressed_all_gather(sent, axis, compressor=compressor,
                                            rate=rate, key=k_call)
        else:
            halo = lax.all_gather(sent, axis)          # [Q, B, F]
        if bits is None:
            bits = _exchange_bits(meta, f, rate, wire_width)
        return halo.reshape(q * b_sz, f), bits

    def complete(li, x, token):
        del li
        f = x.shape[-1]
        xq = x[0]
        if not policy.communicates:
            out = jnp.zeros((p_sz + 1, f), x.dtype)
            out = out.at[graph["local_dst"][0]].add(
                graph["local_w_iso"][0][:, None] * xq[graph["local_src"][0]])
            return out[:p_sz][None]

        if p2p_wire:
            hops, k_call, n_keep, call = token
            ell_w = _ell_w_for(graph, policy, rate)[0]
            if dead is not None:
                me = lax.axis_index(axis)
                mix = _dead_mix(meta, dead)[me]
                ell_w = ell_w + mix * (graph["ell_w_iso"][0] - ell_w)
            loc = ell_aggregate(xq, graph["ell_nbr"][0], ell_w,
                                graph["ell_rnbr"][0], graph["ell_rslot"][0])
            halo = neighbor_exchange_finish(hops, axis, key=k_call,
                                            n_keep=n_keep, f=f)
            if q > 1 and (fcache is not None or dead is not None):
                # receiver-side fault service: hop d's rows came from
                # sender (me - d) mod q; substitute the cached block for
                # CACHED pairs, zeros for DEAD ones (emulated-identical)
                me = lax.axis_index(axis)
                src = (me - jnp.arange(1, q)) % q              # [D]
                hal3 = halo.reshape(q - 1, -1, f)
                if fcache is not None:
                    fsk = fskip[me, src]
                    hal3 = jnp.where(fsk[:, None, None] > 0.0,
                                     fcache[call][0], hal3)
                if fcache_out is not None:
                    fcache_out.append(hal3[None])
                if dead is not None:
                    dd = dead[me, src]
                    hal3 = jnp.where(dd[:, None, None] > 0.0,
                                     jnp.zeros_like(hal3), hal3)
                halo = hal3.reshape(-1, f)
            rem = jnp.zeros((p_sz + 1, f), x.dtype)
            rem = rem.at[graph["remote_dst"][0]].add(
                graph["remote_w"][0][:, None] *
                halo[graph["remote_src_p2p"][0]])
            return (loc + rem[:p_sz])[None]

        halo = token
        out = jnp.zeros((p_sz + 1, f), x.dtype)
        out = out.at[graph["local_dst"][0]].add(
            _local_w_for(graph, policy, rate)[0][:, None] *
            xq[graph["local_src"][0]])
        out = out.at[graph["remote_dst"][0]].add(
            graph["remote_w"][0][:, None] * halo[graph["remote_src"][0]])
        return out[:p_sz][None]

    def aggregate(li, x):
        token, bits = start(li, x)
        return complete(li, x, token), bits

    aggregate.start = start
    aggregate.complete = complete
    return aggregate


# ---------------------------------------------------------------------------
# Loss / steps
# ---------------------------------------------------------------------------


def _local_loss_fn(params, cfg: GNNConfig, graph: dict, aggregate,
                   meta: DistMeta, psum: bool = False):
    """Masked CE over owned train nodes, normalised by the GLOBAL count.

    With the global normalisation, ``psum(per-worker grads)`` equals the full
    centralized gradient — the identity the grad-sync mode relies on.
    Returns ``(loss, forward wire bits)``.
    """
    logits, bits = gnn_forward(params, cfg, graph["features"], aggregate)
    loss_sum, _ = masked_loss_and_correct(logits, graph["labels"],
                                          graph["train_mask"])
    if psum:
        loss_sum = lax.psum(loss_sum, AXIS)
    return loss_sum / max(meta.n_train, 1), bits


def _pmean_inexact(tree, axis: str):
    """FedAvg server step: average float state, keep integer state local."""
    return jax.tree_util.tree_map(
        lambda t: lax.pmean(t, axis)
        if jnp.issubdtype(t.dtype, jnp.inexact) else t, tree)


def _step_metrics(loss, rate, bits) -> dict:
    """Common step metrics: ``bits`` is the forward ``[analytic, transport]``
    pair; a train step ships it twice (activations + cotangents)."""
    return {"loss": loss, "rate": jnp.asarray(rate, jnp.float32),
            "halo_bits": 2.0 * bits[0], "transport_bits": 2.0 * bits[1]}


def make_train_step(cfg: GNNConfig, policy: CommPolicy, opt: Optimizer,
                    meta: DistMeta, mesh: Mesh | None = None,
                    sync: str = "grad",
                    compiled_cache_size: int = COMPILED_CACHE_SIZE):
    """One full-batch step of Algorithm 1.

    ``step(params, opt_state, graph, step_idx, key)`` ->
    ``(params, opt_state, {loss, rate, halo_bits, transport_bits})``.

    ``mesh=None`` runs the single-device emulation over ``[Q, ...]`` stacks;
    with a ``workers`` mesh the same program runs under ``shard_map`` with
    real collectives.  ``sync``: ``'grad'`` psums gradients (exact
    centralized step), ``'fedavg'`` applies local updates then averages
    parameters (Algorithm 1's server step).

    ``meta.wire == "packed"`` runs the reduced-volume packed halo exchange;
    ``"p2p"`` the neighbor-only ppermute ring with ELL local aggregation
    (DESIGN.md §3.5; the graph pytree must carry the
    ``repro.dist.halo.attach_p2p`` arrays).  On both, a compressed payload's
    shape depends only on the kept-block counts, so each call quantises the
    schedule's rate to that static map outside jit (:func:`_packed_k_for`)
    while the rate itself stays a traced operand — a continuously-annealing
    VARCO schedule recompiles once per distinct kept-block map (at most
    ``Π (width/128)`` times, a handful), not per rate value.  A compressing
    policy must then use the ``blockmask`` compressor (these wires realise
    exactly that mechanism).  On the shard_map path the compiled
    executables live in an LRU cache of ``compiled_cache_size`` entries
    (exposed as ``step.cache_info`` / ``step.cache_clear``), so annealing
    across many maps evicts old executables instead of pinning every one
    forever.

    Example::

        step = make_train_step(cfg, varco(300, compressor="blockmask"),
                               adamw(5e-3), meta, mesh=None)
        params, opt_state, m = step(params, opt_state, graph, 0,
                                    jax.random.key(0))
    """
    if sync not in ("grad", "fedavg"):
        raise ValueError(f"sync must be 'grad' or 'fedavg', got {sync!r}")
    if policy.mode == "auto":
        raise ValueError(
            "auto policies plan per-pair rate maps closed-loop; build the "
            "step with repro.dist.ratectl.make_auto_train_step (train_gnn "
            "routes there automatically)")
    packed_wire = meta.wire == "packed"
    p2p_wire = meta.wire == "p2p"
    if (packed_wire or p2p_wire) and policy.compresses and \
            policy.compressor_name != "blockmask":
        raise ValueError(
            f"the {meta.wire} wire ships PRNG-selected lane-blocks; a "
            f"compressing policy must use the 'blockmask' compressor, got "
            f"{policy.compressor_name!r}")
    if p2p_wire and policy.compresses:
        for f_ in {meta.feat_dim, *meta.layer_dims}:
            if f_ % LANE:
                raise ValueError(
                    f"the p2p wire packs lane-blocks under a compressing "
                    f"policy, so every exchanged feature width must be "
                    f"divisible by {LANE}; got {f_} (exchanged widths: "
                    f"{sorted({meta.feat_dim, *meta.layer_dims})})")
    # a static kept-block map is needed whenever the wire payload shape
    # follows the rate: always on the packed wire, under compression on p2p
    needs_kb = packed_wire or (p2p_wire and policy.compresses)
    compressor = policy.compressor() if policy.compresses else None

    if mesh is None:
        @functools.partial(jax.jit, static_argnames=("packed_k",))
        def _jit_step(params, opt_state, graph, step_idx, key,
                      packed_k=None):
            rate = policy.rate(step_idx)

            def loss_fn(p):
                agg = _make_aggregate_emulated(
                    graph, meta, policy, compressor, rate, key,
                    packed_k=dict(packed_k) if packed_k else None)
                return _local_loss_fn(p, cfg, graph, agg, meta)

            (loss, bits), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            updates, new_state = opt.update(grads, opt_state, params)
            new_params = apply_updates(params, updates)
            return new_params, new_state, _step_metrics(loss, rate, bits)

        if not needs_kb:
            return _jit_step

        def step(params, opt_state, graph, step_idx, key):
            kb = _packed_k_for(meta, float(policy.rate(int(step_idx))))
            return _jit_step(params, opt_state, graph, step_idx, key,
                             packed_k=kb)

        return step

    def make_worker(packed_k: dict | None):
        def worker(params, opt_state, gblk, rate, key):
            def loss_fn(p):
                agg = _make_aggregate_shard(gblk, meta, policy, compressor,
                                            rate, key, packed_k=packed_k)
                return _local_loss_fn(p, cfg, gblk, agg, meta)

            (loss, bits), grads = jax.value_and_grad(loss_fn,
                                                     has_aux=True)(params)
            loss = lax.psum(loss, AXIS)
            if sync == "grad":
                grads = jax.tree_util.tree_map(lambda g: lax.psum(g, AXIS),
                                               grads)
                updates, new_state = opt.update(grads, opt_state, params)
                params = apply_updates(params, updates)
            else:  # fedavg: local step, then parameter averaging
                updates, new_state = opt.update(grads, opt_state, params)
                params = apply_updates(params, updates)
                params = _pmean_inexact(params, AXIS)
                new_state = _pmean_inexact(new_state, AXIS)
            return params, new_state, _step_metrics(loss, rate, bits)

        return worker

    def make_sm(packed_k: dict | None):
        return jax.jit(shard_map(make_worker(packed_k), mesh=mesh,
                                 in_specs=(P(), P(), P(AXIS), P(), P()),
                                 out_specs=(P(), P(), P()), check_rep=False))

    if needs_kb:
        # bounded: an annealing schedule walks many kept-block maps; keep
        # the recent executables, evict the rest (regression-pinned by
        # tests/test_p2p_wire.py::test_compiled_cache_bounded)
        @functools.lru_cache(maxsize=compiled_cache_size)
        def _compiled_for(kblocks: tuple):
            return make_sm(dict(kblocks))

        def step(params, opt_state, graph, step_idx, key):
            kb = _packed_k_for(meta, float(policy.rate(int(step_idx))))
            return _compiled_for(kb)(params, opt_state, graph,
                                     policy.rate(step_idx), key)

        step.cache_info = _compiled_for.cache_info
        step.cache_clear = _compiled_for.cache_clear
        return step

    sm = make_sm(None)

    @jax.jit
    def step(params, opt_state, graph, step_idx, key):
        return sm(params, opt_state, graph, policy.rate(step_idx), key)

    return step


def make_eval_step(cfg: GNNConfig, meta: DistMeta, mesh: Mesh | None = None):
    """Full-communication accuracy over the train/val/test splits.

    ``evaluate(params, graph) -> {"train": acc, "val": acc, "test": acc}``.
    Always evaluates over the dense wire: at rate 1 the packed exchange
    keeps every lane-block, so the two formats are bitwise identical and
    the dense path avoids the packed wire's static-rate bookkeeping.

    Example::

        evaluate = make_eval_step(cfg, meta)
        accs = evaluate(params, graph)      # graph from pg.device_arrays()
    """
    meta = dataclasses.replace(meta, wire="dense")
    splits = (("train", "train_mask", meta.n_train),
              ("val", "val_mask", meta.n_val),
              ("test", "test_mask", meta.n_test))

    def _accs(logits, gblk, reduce_psum: bool):
        pred = jnp.argmax(logits, -1)
        out = {}
        for name, mask_key, n in splits:
            correct = jnp.sum((pred == gblk["labels"]) *
                              gblk[mask_key].astype(jnp.float32))
            if reduce_psum:
                correct = lax.psum(correct, AXIS)
            out[name] = correct / max(n, 1)
        return out

    if mesh is None:
        @jax.jit
        def evaluate(params, graph):
            agg = _make_aggregate_emulated(graph, meta, FULL_COMM, None,
                                           jnp.ones((), jnp.float32),
                                           jax.random.key(0))
            logits, _ = gnn_forward(params, cfg, graph["features"], agg)
            return _accs(logits, graph, reduce_psum=False)

        return evaluate

    def worker(params, gblk):
        agg = _make_aggregate_shard(gblk, meta, FULL_COMM, None,
                                    jnp.ones((), jnp.float32),
                                    jax.random.key(0))
        logits, _ = gnn_forward(params, cfg, gblk["features"], agg)
        return _accs(logits, gblk, reduce_psum=True)

    sm = shard_map(worker, mesh=mesh, in_specs=(P(), P(AXIS)),
                   out_specs=P(), check_rep=False)
    return jax.jit(sm)


def make_infer_step(cfg: GNNConfig, policy: CommPolicy, meta: DistMeta,
                    rounding: str = "rint"):
    """Inference-only distributed forward for the serving runtime
    (DESIGN.md §3.11; emulated backend, p2p wire).

    ``infer(params, graph, key, plan, cache=()) -> (logits, hiddens,
    metrics, cache')`` — the serving analogue of
    ``repro.dist.ratectl.driver.make_auto_train_step`` with the grad
    plumbing skipped: no ``value_and_grad``, no optimizer, no cotangent
    traffic, and the forward still runs the split-phase ``start`` /
    ``complete`` oracle so the halo hops overlap the self-term matmuls
    exactly as in training.

    * ``plan`` is a :class:`repro.dist.ratectl.base.RatePlan`; its
      ``skip`` mask and the ``cache`` tuple (``init_halo_cache`` shapes)
      are the drift-gated halo service: a skipped pair's hop is served
      from ``cache`` at zero wire bits, the fresh buffers land in
      ``cache'``, and ``metrics["pair_delta"]`` carries the measured
      relative drift the gate (``repro.dist.ratectl.stale.drift_skip``)
      consumes.
    * ``hiddens`` is the tuple of every layer's post-activation output
      ``[Q, P, F_l]`` (the last entry is ``logits``) — the payload the
      serving embedding cache stores per (layer, node-block).
    * ``metrics`` charges the wire ONE WAY (``halo_bits`` /
      ``transport_bits`` / ``pair_transport`` are forward-only — no
      backward cotangents at inference), unlike the train step's doubled
      charges.

    Example::

        infer = make_infer_step(cfg, policy, meta)
        logits, hid, m, cache = infer(params, graph, key, plan, cache)
    """
    if policy.mode != "auto":
        raise ValueError(f"make_infer_step needs an 'auto' policy, got "
                         f"mode {policy.mode!r}")
    if meta.wire != "p2p":
        raise ValueError("the serving forward reuses the stale "
                         "controller's hop caches; it needs wire='p2p', "
                         f"got {meta.wire!r}")
    for f_ in {meta.feat_dim, *meta.layer_dims}:
        if f_ % LANE:
            raise ValueError(
                f"per-pair rate maps pack lane-blocks; every exchanged "
                f"width must be divisible by {LANE}, got {f_}")
    reps = 1 if cfg.conv == "sage" else max(cfg.k_taps - 1, 1)
    n_ex = cfg.layers * reps
    q = meta.q

    @functools.partial(jax.jit,
                       static_argnames=("packed_k", "wire_w", "store_w"))
    def _jit_infer(params, graph, key, rate_map, width_map, skip, cache,
                   packed_k, wire_w, store_w=0):
        cache_out: list = []
        hidden: list = []
        agg = _make_aggregate_emulated(
            graph, meta, policy, None, jnp.ones((), jnp.float32), key,
            packed_k=dict(packed_k), rate_map=rate_map,
            skip=skip if cache else None,
            cache=cache if cache else None,
            cache_out=cache_out if cache else None,
            width_map=width_map if wire_w else None,
            rounding=rounding, store_w=store_w if wire_w else 0)
        logits, bits = gnn_forward(params, cfg, graph["features"], agg,
                                   hidden_out=hidden)
        return logits, tuple(hidden), bits, tuple(cache_out)

    def infer(params, graph, key, plan, cache=()):
        rm = np.asarray(plan.rates, np.float32)
        kb = _packed_pair_k_for(meta, rm)
        wm = ww = None
        if plan.widths is not None:
            wm = np.vectorize(_snap_width)(
                np.asarray(plan.widths, np.float32)).astype(np.float32)
            ww = _packed_pair_w_for(meta, wm)
        if not ww:
            wm, ww = None, ()
        logits, hidden, bits, cache_new = _jit_infer(
            params, graph, key, jnp.asarray(rm),
            jnp.zeros((), jnp.float32) if wm is None else jnp.asarray(wm),
            jnp.asarray(plan.skip, jnp.float32), tuple(cache),
            packed_k=kb, wire_w=ww, store_w=_packed_store_w(meta, wm))
        n_layers = 1 if rm.ndim == 2 else rm.shape[0]
        q2, lq2 = q * q, (1 if rm.ndim == 2 else rm.shape[0]) * q * q
        layer_t = bits[2:2 + lq2].reshape(n_layers, q, q)
        layer_e = bits[2 + lq2:2 + 2 * lq2].reshape(n_layers, q, q)
        layer_d = bits[2 + 2 * lq2:2 + 3 * lq2].reshape(n_layers, q, q)
        metrics = {"halo_bits": bits[0], "transport_bits": bits[1],
                   "pair_transport": jnp.sum(layer_t, axis=0),
                   "pair_err": jnp.sum(layer_e, axis=0),
                   "pair_delta": jnp.sum(layer_d, axis=0) / max(n_ex, 1)}
        return logits, hidden, metrics, cache_new

    infer._jit_infer = _jit_infer
    return infer
