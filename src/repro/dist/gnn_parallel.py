"""Partition-parallel GNN runtime (paper Algorithm 1, DESIGN.md §3).

Each of the ``Q`` workers owns one graph partition in the padded ``[Q, ...]``
layout produced by :class:`repro.graph.partition.PartitionedGraph`.  A layer's
aggregation ``S x`` decomposes into

* a **local** scatter over edges whose endpoints are both owned, plus
* a **remote** scatter over cross edges whose source activations arrive via
  the *halo exchange*: every worker publishes its boundary nodes, the blocks
  are (optionally compressed, then) all-gathered, and the flattened
  ``[Q·B, F]`` halo buffer supplies the remote neighbour terms.

The same aggregation oracle (``nn.gnn.AggregateFn``) is built two ways:

* ``_make_aggregate_emulated`` — single-device emulation over the stacked
  ``[Q, ...]`` arrays (vmap over partitions, the all-gather is a reshape).
  This is the default test/CPU path.
* ``_make_aggregate_shard`` — the real collective path for ``shard_map``
  over a ``workers`` mesh axis, using
  :func:`repro.core.collectives.compressed_all_gather`.

Both draw per-worker compression masks from ``fold_in(key, worker_index)``
of a per-exchange key, so the emulated and shard_map runs are *bitwise
identical* (tests/test_multidevice.py pins this).

Ledger accounting (paper Fig. 5 axis): every exchange charges the analytic
``halo_demand × F × 32 / rate`` bits — the activations a point-to-point
implementation would ship, not the transport-level padding of the dense
collective (DESIGN.md §3.2).  A train step charges twice the forward traffic
(activations forward + their cotangents backward).
"""

from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.collectives import compressed_all_gather
from repro.core.compression import Compressor
from repro.core.varco import FULL_COMM, CommPolicy
from repro.graph.partition import PartitionedGraph
from repro.nn.gnn import GNNConfig, gnn_forward, masked_loss_and_correct
from repro.train.optim import Optimizer, apply_updates

AXIS = "workers"


# ---------------------------------------------------------------------------
# Static partition metadata
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DistMeta:
    """Static (hashable) facts about a partitioning, shared by every step.

    ``halo_demand`` is the paper's communication unit: the number of distinct
    (requesting partition, remote node) pairs whose activations must cross
    the wire each exchange.  Split sizes are *global* so per-worker losses
    normalise identically (``psum(local grads) == full gradient``).
    """

    q: int
    part_size: int
    halo_size: int
    num_nodes: int
    feat_dim: int
    num_classes: int
    halo_demand: int
    cross_edges: int
    n_train: int
    n_val: int
    n_test: int
    layer_dims: tuple[int, ...]

    @staticmethod
    def build(pg: PartitionedGraph, params: dict) -> "DistMeta":
        dims = []
        for layer in params["layers"]:
            if "self" in layer:                       # sage
                dims.append(int(layer["self"]["w"].shape[0]))
            else:                                     # poly taps
                dims.append(int(layer["taps"][0]["w"].shape[0]))
        return DistMeta(
            q=pg.q, part_size=pg.part_size, halo_size=pg.halo_size,
            num_nodes=pg.num_nodes, feat_dim=pg.feat_dim,
            num_classes=pg.num_classes, halo_demand=pg.halo_demand,
            cross_edges=pg.cross_edges,
            n_train=int(pg.train_mask.sum()),
            n_val=int(pg.val_mask.sum()),
            n_test=int(pg.test_mask.sum()),
            layer_dims=tuple(dims))

    def ledger_bits(self, feat: int, rate=1.0) -> jnp.ndarray:
        """Analytic wire bits of one halo exchange at feature width ``feat``."""
        return jnp.asarray(self.halo_demand * feat * 32.0, jnp.float32) / \
            jnp.asarray(rate, jnp.float32)


# ---------------------------------------------------------------------------
# Mesh / placement
# ---------------------------------------------------------------------------


def make_worker_mesh(q: int) -> Mesh:
    """1-D ``workers`` mesh over the first ``q`` local devices."""
    devs = jax.devices()
    if len(devs) < q:
        raise ValueError(f"need {q} devices for a worker mesh, have "
                         f"{len(devs)} (set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count={q})")
    return Mesh(np.asarray(devs[:q]), (AXIS,))


def shard_graph(graph: dict, mesh: Mesh) -> dict:
    """Place the ``[Q, ...]`` graph pytree over the ``workers`` axis."""
    sharding = NamedSharding(mesh, P(AXIS))
    return {k: jax.device_put(v, sharding) for k, v in graph.items()}


# ---------------------------------------------------------------------------
# Aggregation oracles
# ---------------------------------------------------------------------------


def _local_w_for(graph: dict, policy: CommPolicy, rate):
    """Local edge weights for a communicating exchange at rate ``r``.

    VARCO mode blends toward the isolated-subgraph renormalisation: the
    biased mask delivers remote halo mass attenuated by ``1/r`` in
    expectation, so the aggregation realises ``(1/r)·S_full + (1-1/r)·S_iso``
    — local weights interpolate from the global-degree normalisation
    (``r=1``, bitwise the centralized operator) toward the No-Comm operator
    (``r→∞``).  Without the blend, heavy early compression under-scales
    every aggregation instead of degrading gracefully to the
    (well-conditioned) local-only training that the schedule then anneals
    away from.

    Fixed-compression and full-comm runs keep the paper's plain baseline
    semantics (no renormalisation), which the Definition-1 error-envelope
    tests pin down.
    """
    lw = graph["local_w"]
    if policy.mode != "varco":
        return lw
    mix = 1.0 - 1.0 / jnp.maximum(jnp.asarray(rate, jnp.float32), 1.0)
    return lw + mix * (graph["local_w_iso"] - lw)


def _make_aggregate_emulated(graph: dict, meta: DistMeta, policy: CommPolicy,
                             compressor: Compressor | None, rate, key):
    """AggregateFn over stacked ``[Q, P, F]`` tensors on one device.

    Numerically identical to the shard_map path: the all-gather becomes a
    reshape of the per-partition published blocks, and compression draws the
    worker-``i`` mask from ``fold_in(per-exchange key, i)`` exactly as
    ``compressed_all_gather`` does on device ``i``.
    """
    p_sz, b_sz, q = meta.part_size, meta.halo_size, meta.q
    calls = itertools.count()

    def aggregate(li, x):                              # x: [Q, P, F]
        del li
        call = next(calls)
        f = x.shape[-1]
        if not policy.communicates:                    # No-Comm baseline
            agg = jax.vmap(lambda xq, ld, ls, w:
                           jnp.zeros((p_sz + 1, f), x.dtype)
                           .at[ld].add(w[:, None] * xq[ls])[:p_sz])(
                x, graph["local_dst"], graph["local_src"],
                graph["local_w_iso"])
            return agg, jnp.zeros((), jnp.float32)

        sent = jax.vmap(lambda xq, idx, v: xq[idx] * v[:, None])(
            x, graph["send_idx"], graph["send_valid"])  # [Q, B, F]
        if compressor is not None:
            k_call = jax.random.fold_in(key, call)
            keys = jax.vmap(jax.random.fold_in, (None, 0))(
                k_call, jnp.arange(q))
            sent = jax.vmap(lambda k, blk: compressor(k, blk, rate)[0])(
                keys, sent)
        halo = sent.reshape(q * b_sz, f)
        local_w = _local_w_for(graph, policy, rate)

        def part(xq, ld, ls, lw, rd, rs, rw):
            out = jnp.zeros((p_sz + 1, f), x.dtype)
            out = out.at[ld].add(lw[:, None] * xq[ls])
            out = out.at[rd].add(rw[:, None] * halo[rs])
            return out[:p_sz]

        agg = jax.vmap(part, (0, 0, 0, 0, 0, 0, 0))(
            x, graph["local_dst"], graph["local_src"], local_w,
            graph["remote_dst"], graph["remote_src"], graph["remote_w"])
        return agg, meta.ledger_bits(f, rate)

    return aggregate


def _make_aggregate_shard(graph: dict, meta: DistMeta, policy: CommPolicy,
                          compressor: Compressor | None, rate, key,
                          axis: str = AXIS):
    """AggregateFn for one worker inside ``shard_map`` (blocks ``[1, P, F]``)."""
    p_sz, b_sz, q = meta.part_size, meta.halo_size, meta.q
    calls = itertools.count()

    def aggregate(li, x):                              # x: [1, P, F]
        del li
        call = next(calls)
        f = x.shape[-1]
        xq = x[0]
        if not policy.communicates:
            out = jnp.zeros((p_sz + 1, f), x.dtype)
            out = out.at[graph["local_dst"][0]].add(
                graph["local_w_iso"][0][:, None] * xq[graph["local_src"][0]])
            return out[:p_sz][None], jnp.zeros((), jnp.float32)

        sent = xq[graph["send_idx"][0]] * graph["send_valid"][0][:, None]
        if compressor is not None:
            k_call = jax.random.fold_in(key, call)
            halo, _ = compressed_all_gather(sent, axis, compressor=compressor,
                                            rate=rate, key=k_call)
        else:
            halo = lax.all_gather(sent, axis)          # [Q, B, F]
        halo = halo.reshape(q * b_sz, f)

        out = jnp.zeros((p_sz + 1, f), x.dtype)
        out = out.at[graph["local_dst"][0]].add(
            _local_w_for(graph, policy, rate)[0][:, None] *
            xq[graph["local_src"][0]])
        out = out.at[graph["remote_dst"][0]].add(
            graph["remote_w"][0][:, None] * halo[graph["remote_src"][0]])
        return out[:p_sz][None], meta.ledger_bits(f, rate)

    return aggregate


# ---------------------------------------------------------------------------
# Loss / steps
# ---------------------------------------------------------------------------


def _local_loss_fn(params, cfg: GNNConfig, graph: dict, aggregate,
                   meta: DistMeta, psum: bool = False):
    """Masked CE over owned train nodes, normalised by the GLOBAL count.

    With the global normalisation, ``psum(per-worker grads)`` equals the full
    centralized gradient — the identity the grad-sync mode relies on.
    Returns ``(loss, forward wire bits)``.
    """
    logits, bits = gnn_forward(params, cfg, graph["features"], aggregate)
    loss_sum, _ = masked_loss_and_correct(logits, graph["labels"],
                                          graph["train_mask"])
    if psum:
        loss_sum = lax.psum(loss_sum, AXIS)
    return loss_sum / max(meta.n_train, 1), bits


def _pmean_inexact(tree, axis: str):
    """FedAvg server step: average float state, keep integer state local."""
    return jax.tree_util.tree_map(
        lambda t: lax.pmean(t, axis)
        if jnp.issubdtype(t.dtype, jnp.inexact) else t, tree)


def make_train_step(cfg: GNNConfig, policy: CommPolicy, opt: Optimizer,
                    meta: DistMeta, mesh: Mesh | None = None,
                    sync: str = "grad"):
    """One full-batch step of Algorithm 1.

    ``step(params, opt_state, graph, step_idx, key)`` ->
    ``(params, opt_state, {loss, rate, halo_bits})``.

    ``mesh=None`` runs the single-device emulation over ``[Q, ...]`` stacks;
    with a ``workers`` mesh the same program runs under ``shard_map`` with
    real collectives.  ``sync``: ``'grad'`` psums gradients (exact
    centralized step), ``'fedavg'`` applies local updates then averages
    parameters (Algorithm 1's server step).
    """
    if sync not in ("grad", "fedavg"):
        raise ValueError(f"sync must be 'grad' or 'fedavg', got {sync!r}")
    compressor = policy.compressor() if policy.compresses else None

    if mesh is None:
        @jax.jit
        def step(params, opt_state, graph, step_idx, key):
            rate = policy.rate(step_idx)

            def loss_fn(p):
                agg = _make_aggregate_emulated(graph, meta, policy,
                                               compressor, rate, key)
                return _local_loss_fn(p, cfg, graph, agg, meta)

            (loss, bits), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            updates, new_state = opt.update(grads, opt_state, params)
            new_params = apply_updates(params, updates)
            return new_params, new_state, {"loss": loss, "rate": rate,
                                           "halo_bits": 2.0 * bits}

        return step

    def worker(params, opt_state, gblk, rate, key):
        def loss_fn(p):
            agg = _make_aggregate_shard(gblk, meta, policy, compressor,
                                        rate, key)
            return _local_loss_fn(p, cfg, gblk, agg, meta)

        (loss, bits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        loss = lax.psum(loss, AXIS)
        if sync == "grad":
            grads = jax.tree_util.tree_map(lambda g: lax.psum(g, AXIS), grads)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
        else:  # fedavg: local step, then parameter averaging
            updates, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            params = _pmean_inexact(params, AXIS)
            opt_state = _pmean_inexact(opt_state, AXIS)
        return params, opt_state, {"loss": loss, "rate": rate,
                                   "halo_bits": 2.0 * bits}

    sm = shard_map(worker, mesh=mesh,
                   in_specs=(P(), P(), P(AXIS), P(), P()),
                   out_specs=(P(), P(), P()), check_rep=False)

    @jax.jit
    def step(params, opt_state, graph, step_idx, key):
        return sm(params, opt_state, graph, policy.rate(step_idx), key)

    return step


def make_eval_step(cfg: GNNConfig, meta: DistMeta, mesh: Mesh | None = None):
    """Full-communication accuracy over the train/val/test splits."""
    splits = (("train", "train_mask", meta.n_train),
              ("val", "val_mask", meta.n_val),
              ("test", "test_mask", meta.n_test))

    def _accs(logits, gblk, reduce_psum: bool):
        pred = jnp.argmax(logits, -1)
        out = {}
        for name, mask_key, n in splits:
            correct = jnp.sum((pred == gblk["labels"]) *
                              gblk[mask_key].astype(jnp.float32))
            if reduce_psum:
                correct = lax.psum(correct, AXIS)
            out[name] = correct / max(n, 1)
        return out

    if mesh is None:
        @jax.jit
        def evaluate(params, graph):
            agg = _make_aggregate_emulated(graph, meta, FULL_COMM, None,
                                           jnp.ones((), jnp.float32),
                                           jax.random.key(0))
            logits, _ = gnn_forward(params, cfg, graph["features"], agg)
            return _accs(logits, graph, reduce_psum=False)

        return evaluate

    def worker(params, gblk):
        agg = _make_aggregate_shard(gblk, meta, FULL_COMM, None,
                                    jnp.ones((), jnp.float32),
                                    jax.random.key(0))
        logits, _ = gnn_forward(params, cfg, gblk["features"], agg)
        return _accs(logits, gblk, reduce_psum=True)

    sm = shard_map(worker, mesh=mesh, in_specs=(P(), P(AXIS)),
                   out_specs=P(), check_rep=False)
    return jax.jit(sm)
