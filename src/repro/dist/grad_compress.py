"""VARCO gradient compression for data-parallel LM training (DESIGN.md §4).

The paper's variable-rate scheme transplanted from halo activations to the
data-parallel gradient all-reduce: each worker compresses its local gradient
with a Definition-1 compressor (per-worker mask streams derived from a
shared key), the compressed contributions are summed
(:func:`repro.core.collectives.compressed_psum`), and the rate anneals under
the policy's scheduler — early steps ship a fraction of the gradient bits,
converging to exact synchronous SGD as ``rate -> 1``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.collectives import compressed_psum, uncompressed_bits
from repro.core.varco import CommPolicy
from repro.train.optim import (Optimizer, apply_updates,
                               clip_by_global_norm)

AXIS = "data"


def make_dp_mesh(n_devices: int | None = None) -> Mesh:
    """1-D data-parallel mesh over the first ``n_devices`` local devices
    (all of them by default).

    Example::

        mesh = make_dp_mesh()               # axis name: "data"
        step = make_varco_dp_train_step(cfg, opt, policy, mesh)
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if len(devs) < n:
        raise ValueError(f"need {n} devices, have {len(devs)}")
    return Mesh(np.asarray(devs[:n]), (AXIS,))


def make_varco_dp_train_step(cfg: ArchConfig, optimizer: Optimizer,
                             policy: CommPolicy, mesh: Mesh,
                             clip: float = 1.0):
    """Data-parallel LM train step with VARCO-compressed gradient psum.

    ``step(params, opt_state, batch, step_idx, key)`` ->
    ``(params, opt_state, {loss, ce, moe_aux, grad_norm, grad_bits, rate})``.

    The batch pytree is split over ``data`` on its leading dim; parameters
    and optimizer state are replicated.  ``grad_bits`` charges the ring
    all-reduce traffic of the (compressed) payload; the full-communication
    baseline charges the uncompressed equivalent so accuracy-per-byte curves
    share an axis.

    Example::

        cfg = get_config("granite-3-2b", smoke=True)
        policy = CommPolicy.parse("varco:linear:5", total_steps=200)
        step = make_varco_dp_train_step(cfg, make_optimizer(cfg), policy,
                                        make_dp_mesh())
        params, opt_state, m = step(params, opt_state,
                                    {"tokens": tokens}, 0,
                                    jax.random.key(0))
    """
    # deferred: models.transformer imports repro.dist.sharding at module
    # scope, so a top-level import here would be circular
    from repro.models.transformer import lm_loss

    compressor = policy.compressor() if policy.compresses else None
    q = mesh.shape[AXIS]

    def worker(params, opt_state, batch, rate, key):
        (loss, parts), grads = jax.value_and_grad(
            lm_loss, has_aux=True)(params, cfg, batch)
        if compressor is not None:
            grads, grad_bits = compressed_psum(
                grads, AXIS, compressor=compressor, rate=rate, key=key)
            grads = jax.tree_util.tree_map(lambda g: g / q, grads)
        else:
            grad_bits = uncompressed_bits(grads) * 2.0 * (q - 1)
            grads = lax.pmean(grads, AXIS)
        loss = lax.pmean(loss, AXIS)
        grads, gnorm = clip_by_global_norm(grads, clip)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "ce": lax.pmean(parts["ce"], AXIS),
                   "moe_aux": lax.pmean(parts["moe_aux"], AXIS),
                   "grad_norm": gnorm, "grad_bits": grad_bits}
        return params, opt_state, metrics

    sm = shard_map(worker, mesh=mesh,
                   in_specs=(P(), P(), P(AXIS), P(), P()),
                   out_specs=(P(), P(), P()), check_rep=False)

    @jax.jit
    def step(params, opt_state, batch, step_idx, key):
        rate = policy.rate(step_idx)
        params, opt_state, metrics = sm(params, opt_state, batch, rate, key)
        metrics["rate"] = rate
        return params, opt_state, metrics

    return step
