"""Per-pair halo specs + ELL neighbour lists for the p2p wire (DESIGN.md §3.5).

The all-gather wires ship every partition's whole padded boundary block to
every peer; the paper's bit accounting (eq. (8)) charges only the
*edge-cut* rows each pair actually exchanges.  This module builds, on the
host at partition time, the static indices that let the runtime ship
exactly that:

* **per-pair halo index sets** — for each ordered pair ``(i ← j)``, the
  sorted set of ``j``'s boundary slots that partition ``i``'s remote edges
  reference, laid out per ring offset ``d = (i - j) mod Q`` so a
  ``lax.ppermute`` hop ``d`` carries every ``j → (j+d) mod Q`` buffer at
  once (``repro.core.collectives.neighbor_exchange``);
* **the compacted ``remote_src`` remap** — each remote edge re-indexed
  into the receiver's concatenated per-hop buffer ``[(Q-1)·H, F]``
  (hop ``d`` occupies rows ``[(d-1)·H, d·H)``), replacing the flattened
  ``[Q·B]`` all-gather buffer;
* **degree-padded ELL neighbour lists** for the local edges — forward
  lists ``(nbr, w, w_iso)`` for the ``ell_spmm`` kernel plus the
  *reversed* lists ``(rnbr, rslot)`` whose ELL SpMM is the forward's
  transpose (the custom VJP of :func:`repro.kernels.ops.ell_aggregate`).

Everything here is plain numpy; :func:`attach_p2p` merges the device
arrays into the graph pytree consumed by ``repro.dist.gnn_parallel`` (all
leaves keep the stacked ``[Q, ...]`` layout, so ``shard_graph`` places
them over the ``workers`` axis unchanged).

The per-pair slot sets serve every consumer of the p2p wire the same
way: the fused aggregation oracles, the split-phase pipelined prefetch
(``neighbor_exchange_start`` slices each hop's rows out of the packed
boundary block while the previous layer's unpack is still pending —
DESIGN.md §3.7), the per-pair/per-layer rate-map ledgers
(``pair_rows``), and the ``stale`` controller's hop caches (hop ``d``'s
``[H, F]`` slot layout is what makes a cached buffer reusable in
place).

Example::

    pg = partition_graph(g, q=8, scheme="metis-like")
    graph = attach_p2p(pg.device_arrays(), pg)
    meta = DistMeta.build(pg, params, wire="p2p")
    step = make_train_step(cfg, policy, opt, meta)
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Static facts of a per-pair halo layout (all hashable).

    ``hop_width`` (``H``) is the padded row count of one ring hop — the max
    over ordered pairs of distinct boundary rows shipped; ``compact_rows``
    is the receiver-side concatenated buffer height ``max((Q-1)·H, 1)``.
    ``pair_rows[i*Q + j]`` counts the distinct rows ``j`` ships to ``i``
    (zero on the diagonal); their sum equals ``halo_demand``.
    """

    q: int
    hop_width: int
    compact_rows: int
    ell_degree: int
    rev_degree: int
    pair_rows: tuple

    def pair_table(self) -> np.ndarray:
        """``[Q, Q]`` per-pair row counts (receiver × sender)."""
        return np.asarray(self.pair_rows, np.int64).reshape(self.q, self.q)

    def to_dict(self) -> dict:
        """JSON-ready form — the shard manifests (``repro.graph.stream``)
        persist the spec so shard-backed runs never rebuild it from the
        global graph."""
        return {"q": self.q, "hop_width": self.hop_width,
                "compact_rows": self.compact_rows,
                "ell_degree": self.ell_degree,
                "rev_degree": self.rev_degree,
                "pair_rows": list(self.pair_rows)}

    @staticmethod
    def from_dict(d: dict) -> "HaloSpec":
        """Inverse of :meth:`to_dict` (round-trips exactly)."""
        return HaloSpec(q=int(d["q"]), hop_width=int(d["hop_width"]),
                        compact_rows=int(d["compact_rows"]),
                        ell_degree=int(d["ell_degree"]),
                        rev_degree=int(d["rev_degree"]),
                        pair_rows=tuple(int(v) for v in d["pair_rows"]))


def _pair_slot_sets(pg) -> list[list[np.ndarray]]:
    """``sets[i][j]``: sorted unique boundary slots of ``j`` that ``i``'s
    remote edges reference (``None`` on the diagonal).

    Memoised on the (mutable) ``PartitionedGraph`` instance: the O(Q²)
    unique-sweep would otherwise run three times per setup
    (``attach_p2p``'s spec + arrays, then ``DistMeta.build``).
    """
    cached = getattr(pg, "_pair_slot_cache", None)
    if cached is not None:
        return cached
    valid, src_part, slot = pg.remote_pair_table()
    sets: list[list[np.ndarray]] = []
    for i in range(pg.q):
        row = []
        for j in range(pg.q):
            if j == i:
                row.append(None)
                continue
            sel = valid[i] & (src_part[i] == j)
            row.append(np.unique(slot[i][sel]))
        sets.append(row)
    pg._pair_slot_cache = sets
    return sets


def build_halo_spec(pg) -> HaloSpec:
    """Static halo/ELL facts for :class:`repro.dist.gnn_parallel.DistMeta`."""
    sets = _pair_slot_sets(pg)
    pair_rows = np.zeros((pg.q, pg.q), np.int64)
    for i in range(pg.q):
        for j in range(pg.q):
            if j != i:
                pair_rows[i, j] = len(sets[i][j])
    hop_w = max(int(pair_rows.max()), 1)
    ell_k, rev_k = _ell_degrees(pg)
    return HaloSpec(q=pg.q, hop_width=hop_w,
                    compact_rows=max((pg.q - 1) * hop_w, 1),
                    ell_degree=ell_k, rev_degree=rev_k,
                    pair_rows=tuple(int(v) for v in pair_rows.ravel()))


def halo_arrays(pg, spec: HaloSpec | None = None) -> dict[str, np.ndarray]:
    """The p2p exchange indices (stacked ``[Q, ...]`` numpy arrays).

    * ``p2p_send_slot [Q, D, H]`` — *boundary slots* (rows of the worker's
      published ``[B, ·]`` block, i.e. indices into ``send_idx``) worker
      ``j`` ships at ring offset ``d`` (row ``d-1``) to worker
      ``(j+d) mod Q``.  Slot indexing lets a sender pack its boundary
      block **once** and slice every per-pair hop buffer out of the packed
      rows;
    * ``p2p_send_valid [Q, D, H]`` — 1 for genuine rows, 0 for padding;
    * ``remote_src_p2p [Q, Er]`` — each remote edge's row in the
      receiver's compact buffer (pad edges → 0, their weight is 0).

    ``D = max(Q-1, 1)`` so the arrays stay well-formed for ``Q == 1``
    (no hops ever run).
    """
    spec = spec or build_halo_spec(pg)
    q, hop_w = pg.q, spec.hop_width
    d_hops = max(q - 1, 1)
    sets = _pair_slot_sets(pg)

    send_slot = np.zeros((q, d_hops, hop_w), np.int32)
    send_valid = np.zeros((q, d_hops, hop_w), np.float32)
    for j in range(q):
        for d in range(1, q):
            slots = sets[(j + d) % q][j]
            send_slot[j, d - 1, :len(slots)] = slots
            send_valid[j, d - 1, :len(slots)] = 1.0

    valid, src_part, slot = pg.remote_pair_table()
    remote_src_p2p = np.zeros_like(pg.remote_src)
    for i in range(q):
        for j in range(q):
            if j == i:
                continue
            sel = valid[i] & (src_part[i] == j)
            if not sel.any():
                continue
            pos = np.searchsorted(sets[i][j], slot[i][sel])
            d = (i - j) % q
            remote_src_p2p[i][sel] = (d - 1) * hop_w + pos

    return {"p2p_send_slot": send_slot, "p2p_send_valid": send_valid,
            "remote_src_p2p": remote_src_p2p.astype(np.int32)}


# ---------------------------------------------------------------------------
# ELL construction (local edges)
# ---------------------------------------------------------------------------


def _ell_degrees(pg) -> tuple[int, int]:
    """(max local in-degree, max local out-degree) across partitions."""
    p_sz = pg.part_size
    ell_k = rev_k = 1
    for p in range(pg.q):
        ok = pg.local_dst[p] < p_sz
        if ok.any():
            ell_k = max(ell_k, int(np.bincount(
                pg.local_dst[p][ok], minlength=p_sz).max()))
            rev_k = max(rev_k, int(np.bincount(
                pg.local_src[p][ok], minlength=p_sz).max()))
    return ell_k, rev_k


def _group_slots(ids: np.ndarray, minlength: int
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stable grouping by id: returns ``(order, slot_in, counts)`` with
    ``ids[order]`` group-sorted and ``slot_in`` each element's index within
    its group — the ELL slot-assignment rule shared by the forward and
    reversed list builders."""
    order = np.argsort(ids, kind="stable")
    sorted_ids = ids[order]
    counts = np.bincount(sorted_ids, minlength=minlength)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot_in = np.arange(len(sorted_ids)) - starts[sorted_ids]
    return order, slot_in, counts


def build_reverse_ell(nbr: np.ndarray, valid: np.ndarray, n_src: int,
                      rev_k: int | None = None
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Reversed ELL lists: the transpose layout for the SpMM VJP.

    ``nbr [N, K]`` holds source ids per destination row, ``valid [N, K]``
    marks genuine entries.  Returns ``(rnbr [n_src, RK], rslot [n_src,
    RK])``: ``rnbr[s]`` lists the destination rows fed by source ``s`` and
    ``rslot[s]`` the flat ``i·K + k`` position of the matching forward
    weight (``-1`` pad) — so ``ell_spmm(g, rnbr, w.ravel()[rslot])`` is the
    exact transpose of ``ell_spmm(x, nbr, w)``.
    """
    n, k = nbr.shape
    d_idx, k_idx = np.nonzero(valid)
    src = nbr[d_idx, k_idx]
    order, pos, counts = _group_slots(src, n_src)
    src_o, d_o = src[order], d_idx[order]
    flat_o = (d_idx * k + k_idx)[order]
    rk = rev_k or max(int(counts.max(initial=0)), 1)
    if counts.max(initial=0) > rk:
        raise ValueError(f"rev_k={rk} below the max reverse degree "
                         f"{int(counts.max())}")
    rnbr = np.zeros((n_src, rk), np.int32)
    rslot = np.full((n_src, rk), -1, np.int32)
    rnbr[src_o, pos] = d_o
    rslot[src_o, pos] = flat_o
    return rnbr, rslot


def ell_arrays(pg, spec: HaloSpec | None = None) -> dict[str, np.ndarray]:
    """Degree-padded ELL lists of every partition's local edges.

    ``ell_nbr/ell_w/ell_w_iso [Q, P, K]`` feed the forward ``ell_spmm``
    (pad entries carry weight 0); ``ell_rnbr/ell_rslot [Q, P, RK]`` are the
    reversed lists for the VJP transpose (:func:`build_reverse_ell`).
    """
    spec = spec or build_halo_spec(pg)
    q, p_sz = pg.q, pg.part_size
    k, rk = spec.ell_degree, spec.rev_degree
    nbr = np.zeros((q, p_sz, k), np.int32)
    w = np.zeros((q, p_sz, k), np.float32)
    w_iso = np.zeros((q, p_sz, k), np.float32)
    rnbr = np.zeros((q, p_sz, rk), np.int32)
    rslot = np.full((q, p_sz, rk), -1, np.int32)
    for p in range(q):
        ok = pg.local_dst[p] < p_sz
        d_ = pg.local_dst[p][ok]
        order, slot_in, _ = _group_slots(d_, p_sz)
        d_o = d_[order]
        nbr[p, d_o, slot_in] = pg.local_src[p][ok][order]
        w[p, d_o, slot_in] = pg.local_w[p][ok][order]
        w_iso[p, d_o, slot_in] = pg.local_w_iso[p][ok][order]
        valid = np.zeros((p_sz, k), bool)
        valid[d_o, slot_in] = True
        rnbr[p], rslot[p] = build_reverse_ell(nbr[p], valid, p_sz, rev_k=rk)
    return {"ell_nbr": nbr, "ell_w": w, "ell_w_iso": w_iso,
            "ell_rnbr": rnbr, "ell_rslot": rslot}


def attach_p2p(graph: dict, pg, spec: HaloSpec | None = None) -> dict:
    """Merge the p2p halo + ELL device arrays into a graph pytree.

    Returns a new dict; the input is not mutated.  Idempotent on the keys
    it owns.

    Example::

        graph = attach_p2p(pg.device_arrays(), pg)
    """
    import jax.numpy as jnp

    spec = spec or build_halo_spec(pg)
    out = dict(graph)
    for k, v in {**halo_arrays(pg, spec), **ell_arrays(pg, spec)}.items():
        out[k] = jnp.asarray(v)
    return out


def pair_query_mass(pair_rows: np.ndarray,
                    queries_per_part: np.ndarray) -> np.ndarray:
    """``[Q, Q]`` query mass for the ``qos`` controller (DESIGN.md §3.11).

    ``pair_rows[r, s]`` is the static halo row-count table
    (``DistMeta.pair_table()``); ``queries_per_part[r]`` counts the
    serving queries that landed on partition ``r`` in the last window.
    Each ordered pair's mass is the receiver's query count times the
    pair's halo rows — every query against partition ``r`` re-reads all
    of ``r``'s inbound halo rows, so a pair's refresh urgency scales
    with both.  Feeds ``observe({"query_mass": ...})`` of
    :func:`repro.dist.ratectl.qos.qos_controller`.

    Example::

        mass = pair_query_mass(meta.pair_table(), frontend.query_counts())
    """
    rows = np.asarray(pair_rows, np.float32)
    qc = np.asarray(queries_per_part, np.float32)
    if qc.shape != (rows.shape[0],):
        raise ValueError(f"queries_per_part must be [Q]={rows.shape[0]}, "
                         f"got {qc.shape}")
    return qc[:, None] * rows
