"""Closed-loop rate control for the distributed GNN wire (DESIGN.md §3.6).

A control-plane layer over the per-pair data plane: turn a user-supplied
byte budget into per-step, per-pair ``[Q, Q]`` compression rates from
measured wire feedback, instead of the open-loop step → scalar schedules
of ``repro.core.schedulers``.

* ``base``    — the :class:`RateController` ``init/observe/plan`` API,
  :class:`RatePlan`, and the shared eq.-(8)-referenced budget pacing
  (:func:`make_pacing` / :func:`allowance`).
* ``budget``  — PI controller tracking ``CommLedger.transport`` against
  a total-bits budget (open-loop limit = the paper's eq. (8)).
* ``error``   — AdaQP-style water-filling of each step's bit allowance
  over the measured per-pair compression-error EMA, monotone
  non-increasing per pair (Proposition 2 still applies).
* ``stale``   — skip pairs whose boundary activations barely changed,
  reusing the receiver's cached halo rows under a staleness cap.
* ``qos``     — water-filling of the bit allowance over the measured
  per-pair serving **query mass** (``repro.serve``, DESIGN.md §3.11):
  hot partitions' halos refresh at the lowest rates / widest widths.
* ``driver``  — :func:`make_controller` from a ``CommPolicy``
  ``auto:<controller>:<budget>`` spec and :func:`make_auto_train_step`,
  the per-pair-rate Algorithm-1 step (emulated + shard_map backends).

Every controller additionally supports **per-layer** planning
(``auto:<controller>:<budget>:per-layer``, DESIGN.md §3.7): the plan
becomes an ``[L, Q, Q]`` tensor whose layer rows are water-filled from
the measured per-layer dropped energy, monotone per layer so Prop. 2
applies layer by layer.

Example::

    policy = CommPolicy.parse("auto:error:2e9", epochs)
    res = train_gnn(g, q=8, policy=policy, wire="p2p", epochs=epochs)
"""

from repro.dist.ratectl.base import (CONTROLLERS, Pacing, RateController,
                                     RatePlan, allowance, make_pacing,
                                     rate_of_allowance, refine_widths,
                                     sustainable_cap, uniform_layer_plan,
                                     uniform_plan, waterfill,
                                     width_candidates, width_cost,
                                     width_eps, widths_map)
from repro.dist.ratectl.budget import budget_controller
from repro.dist.ratectl.driver import (exchange_widths, init_halo_cache,
                                       init_wire_residuals,
                                       layer_exchange_widths,
                                       make_auto_train_step, make_controller)
from repro.dist.ratectl.error import error_controller
from repro.dist.ratectl.qos import qos_controller
from repro.dist.ratectl.stale import drift_skip, stale_controller

__all__ = [
    "CONTROLLERS", "Pacing", "RateController", "RatePlan", "allowance",
    "make_pacing", "rate_of_allowance", "refine_widths", "sustainable_cap",
    "uniform_layer_plan", "uniform_plan",
    "width_candidates", "width_cost", "width_eps", "widths_map",
    "budget_controller", "drift_skip", "error_controller", "qos_controller",
    "stale_controller", "waterfill",
    "exchange_widths", "init_halo_cache", "init_wire_residuals",
    "layer_exchange_widths", "make_auto_train_step", "make_controller",
]
