"""Closed-loop rate control: the controller API + shared pacing machinery.

The open-loop schedulers of ``repro.core.schedulers`` map a step to ONE
global compression rate, blind to training dynamics and to the per-pair
structure the p2p wire exposes.  A :class:`RateController` closes the
loop: it turns a user-supplied **byte budget** into per-step, per-pair
``[Q, Q]`` compression rates from *measured* wire feedback (DESIGN.md
§3.6), following AdaQP's observation that per-boundary-set precision
assignment beats any uniform one.

The contract is three pure functions over a pytree ``state`` (every leaf
a jnp array, so the whole loop is jit-compatible; the trainer happens to
run it on host because the rate map also quantises the step's static
kept-block counts):

* ``init() -> state`` — the carried state at step 0;
* ``plan(state, step) -> (RatePlan, state)`` — the ``[Q, Q]`` rate map
  (receiver × sender, diagonal 1) and per-pair skip mask for this step;
* ``observe(state, obs) -> state`` — fold in the step's measurements:
  ``obs["transport_bits"]`` (scalar bits actually shipped),
  ``obs["pair_err"]`` (``[Q, Q]`` compression squared error — the dropped
  blocks' energy), ``obs["pair_delta"]`` (``[Q, Q]`` relative change of
  each pair's hop buffer vs its cached copy).

Controllers ship in sibling modules: ``budget`` (PI tracking of
``CommLedger.transport`` against the total budget, reducing to the
paper's eq. (8) open-loop schedule at zero gains), ``error`` (AdaQP-style
water-filling of the step's bit allowance over the measured per-pair
error EMA, monotone non-increasing per pair so Proposition 2's
convergence argument still applies), ``stale`` (skip a pair's hop and
reuse its cached halo rows while the boundary block barely changed,
bounded by a staleness cap).

Example::

    ctl = budget_controller(meta, widths, total_steps=300,
                            budget_bits=2e9)
    state = ctl.init()
    plan, state = ctl.plan(state, step)      # plan.rates: [Q, Q]
    ...run the step at plan.rates...
    state = ctl.observe(state, {"transport_bits": shipped, ...})
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.varco import WIRE_WIDTHS

#: controller names accepted by ``CommPolicy.parse("auto:<name>:<bits>")``
CONTROLLERS = ("budget", "error", "stale", "qos")

#: VPU lane width — one fp32 scale travels per kept lane-block of a
#: quantised pair (``repro.kernels.ops.per_block_wire_bits``)
LANE = 128


class RatePlan(NamedTuple):
    """One step's control decision: per-pair rates + hop skips + widths.

    ``rates [Q, Q]`` (receiver × sender, f32, diagonal 1) are compression
    ratios ``>= 1``; ``skip [Q, Q]`` (0/1 f32) marks pairs whose hop is
    served from the receiver's cached halo buffer instead of the wire
    (``stale`` controller; all-zero for the others); ``widths`` (``None``
    or ``[Q, Q]`` / ``[L, Q, Q]`` f32, diagonal 32) are per-pair wire
    bit-widths — ``None`` (every non-quantising plan) keeps the exact
    fp32 wire and compiles the pre-quantisation step program
    (DESIGN.md §3.8).
    """

    rates: jnp.ndarray
    skip: jnp.ndarray
    widths: jnp.ndarray | None = None


@dataclasses.dataclass(frozen=True)
class RateController:
    """A closed-loop rate controller (module docs for the contract).

    Example::

        state = ctl.init()
        for t in range(T):
            plan, state = ctl.plan(state, t)
            metrics = run_step(plan)
            state = ctl.observe(state, metrics)
    """

    name: str
    init_fn: Callable[[], dict]
    observe_fn: Callable[[dict, dict], dict]
    plan_fn: Callable[[dict, Any], tuple[RatePlan, dict]]

    def init(self) -> dict:
        """Carried state at step 0 (a pytree of jnp arrays)."""
        return self.init_fn()

    def observe(self, state: dict, obs: dict) -> dict:
        """Fold one step's measurements into the carried state."""
        return self.observe_fn(state, obs)

    def plan(self, state: dict, step) -> tuple[RatePlan, dict]:
        """The ``[Q, Q]`` rate map (+ skip mask) for ``step``."""
        return self.plan_fn(state, step)


def uniform_plan(q: int, rate) -> RatePlan:
    """A scalar rate as a (diagonal-1) rate map with no skips."""
    eye = jnp.eye(q, dtype=bool)
    rates = jnp.where(eye, 1.0, jnp.asarray(rate, jnp.float32))
    return RatePlan(rates, jnp.zeros((q, q), jnp.float32))


def uniform_layer_plan(q: int, rates_l) -> RatePlan:
    """Per-layer uniform rates ``rates_l [L]`` as a ``[L, Q, Q]`` tensor
    (diagonal 1 per layer) with no skips — the per-layer controllers'
    plan shape (DESIGN.md §3.7)."""
    r = jnp.asarray(rates_l, jnp.float32)
    n_layers = r.shape[0]
    eye = jnp.broadcast_to(jnp.eye(q, dtype=bool)[None],
                           (n_layers, q, q))
    rates = jnp.where(eye, 1.0,
                      jnp.broadcast_to(r[:, None, None], (n_layers, q, q)))
    return RatePlan(rates, jnp.zeros((q, q), jnp.float32))


def waterfill(density, rows, cap, y_floor, y_max: float = 1.0,
              iters: int = 60) -> jnp.ndarray:
    """Proportional (log-utility) water-filling of keep fractions.

    Solve ``y = clip(λ · density, y_floor, y_max)`` for the water level
    ``λ`` such that ``Σ rows · y == cap`` (bisection, ``iters`` fixed
    halvings — pure jnp, runs under jit).  This is the exact maximiser of
    ``Σ rows · density · log(y)`` under the bit constraint: entries with
    higher measured error density keep proportionally more blocks, equal
    densities degrade gracefully to the uniform allocation (never starving
    an arbitrary subset of tied entries, which the LP-greedy fill would).
    ``y_floor`` (scalar or ``rows``-shaped) carries the monotone-rate
    commitments: the fill only ever *adds* on top of it, so a floor
    already exceeding ``cap`` returns the floor unchanged.  Works over
    any index set — per-pair ``[Q, Q]`` maps, per-layer ``[L]`` vectors,
    or the joint ``[L, Q, Q]`` tensor (DESIGN.md §3.6–3.7).
    """
    y_floor = jnp.broadcast_to(jnp.asarray(y_floor, jnp.float32), rows.shape)
    d = jnp.where(rows > 0, jnp.maximum(density, 0.0), 0.0)
    dn = d / jnp.maximum(jnp.max(d), 1e-30)      # normalised to [0, 1]
    cap = jnp.maximum(cap, jnp.sum(rows * y_floor))

    def fill(lam):
        return jnp.clip(lam * dn, y_floor, y_max)

    lo = jnp.zeros(())
    hi = jnp.full((), 1e12)
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        under = jnp.sum(rows * fill(mid)) <= cap
        lo = jnp.where(under, mid, lo)
        hi = jnp.where(under, hi, mid)
    return fill(lo)


# ---------------------------------------------------------------------------
# Pacing: open-loop reference trajectory + PI feedback on the spend
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Pacing:
    """Budget pacing shared by every controller.

    The *reference trajectory* is the paper's eq. (8) linear schedule
    (slope ``a``, ``c_max → c_min`` over ``total_steps``): ``phi[t] =
    1/c(t)`` is its keep fraction and ``cum`` its cumulative sum, so the
    target cumulative spend before step ``t`` is ``budget_bits · cum[t] /
    cum[T]``.  :func:`allowance` turns the measured spend into this
    step's bit allowance with PI feedback on the pace error — at zero
    gains the allowance is exactly the open-loop profile, i.e. the
    controller *reduces to eq. (8)* when the budget equals that
    schedule's own total transport.

    ``d_full`` is the analytic full-communication transport of one train
    step (forward + backward over every exchange width): the model that
    converts a bit allowance into a uniform rate and back.

    ``layer_bits`` (``[L]`` jnp array, or ``None`` for pair-level pacing)
    splits ``d_full`` per model layer — ``layer_bits[l] = 2 · 32 ·
    halo_demand · Σ(widths of layer l's exchanges)`` — the cost model the
    per-layer controllers water-fill against (DESIGN.md §3.7).  Always
    sums to ``d_full``.
    """

    total_steps: int
    budget_bits: float
    d_full: float
    c_max: float
    c_min: float
    kp: float
    ki: float
    phi: Any
    cum: Any
    layer_bits: Any = None


def make_pacing(meta, widths, total_steps: int, budget_bits: float,
                c_max: float = 128.0, c_min: float = 1.0,
                slope: float = 5.0, kp: float = 4.0,
                ki: float = 0.25, layer_widths=None) -> Pacing:
    """Build the shared pacing state for ``meta`` (needs ``halo_demand``)
    and the per-step exchange ``widths`` (see ``driver.exchange_widths``).

    ``layer_widths`` (optional ``[L]`` tuple — each layer's summed
    exchange width, see ``driver.layer_exchange_widths``) additionally
    populates :attr:`Pacing.layer_bits` for the per-layer controllers;
    its sum must equal ``sum(widths)``."""
    from repro.core import schedulers

    if budget_bits <= 0:
        raise ValueError(f"budget_bits must be positive, got {budget_bits}")
    total = max(total_steps, 1)
    sched = schedulers.linear(total, slope=slope, c_max=c_max, c_min=c_min)
    phi = 1.0 / np.asarray([float(sched(t)) for t in range(total)])
    cum = np.concatenate([[0.0], np.cumsum(phi)])
    d_full = 2.0 * 32.0 * float(meta.halo_demand) * float(sum(widths))
    layer_bits = None
    if layer_widths is not None:
        if sum(layer_widths) != sum(widths):
            raise ValueError(
                f"layer_widths {tuple(layer_widths)} must sum to the "
                f"exchange widths' total {sum(widths)}")
        layer_bits = jnp.asarray(
            [2.0 * 32.0 * float(meta.halo_demand) * float(w)
             for w in layer_widths], jnp.float32)
    return Pacing(total_steps=int(max(total_steps, 1)),
                  budget_bits=float(budget_bits), d_full=d_full,
                  c_max=float(c_max), c_min=float(c_min), kp=float(kp),
                  ki=float(ki), phi=jnp.asarray(phi, jnp.float32),
                  cum=jnp.asarray(cum, jnp.float32), layer_bits=layer_bits)


def allowance(p: Pacing, spent, integ, step):
    """This step's bit allowance: receding-horizon replanning + PI.

    The *remaining* budget is spent proportionally to the *remaining*
    open-loop profile — ``(B − spent) · phi[t] / Σ_{s>=t} phi[s]`` — so a
    deficit or surplus redistributes over the steps left instead of being
    lost at the horizon.  When the measured spend tracks the profile
    exactly this telescopes to the open-loop allowance ``B · phi[t] /
    Σphi`` identically (the eq.-(8) reduction).  A PI term
    ``exp(kp·e + ki·Σe)`` on the pace error ``e`` (underspent → ``e > 0``
    → spend more) corrects the systematic bias of lane-block quantisation;
    the integral is clamped (anti-windup: a rate pinned at ``c_max`` /
    ``c_min`` must not accumulate unbounded correction).

    Returns ``(bits, integ')``."""
    ti = jnp.clip(jnp.asarray(step, jnp.int32), 0, p.total_steps - 1)
    frac = p.cum[ti] / p.cum[-1]
    e = frac - jnp.asarray(spent, jnp.float32) / p.budget_bits
    integ = jnp.clip(integ + e, -10.0, 10.0)
    gain = jnp.exp(p.kp * e + p.ki * integ)
    share = p.phi[ti] / jnp.maximum(p.cum[-1] - p.cum[ti], 1e-12)
    left = jnp.maximum(p.budget_bits - jnp.asarray(spent, jnp.float32), 0.0)
    return left * share * gain, integ


def rate_of_allowance(p: Pacing, bits) -> jnp.ndarray:
    """Uniform rate realising a per-step bit allowance: ``d_full / bits``
    clamped to ``[c_min_rate, c_max]`` (a rate is never below 1)."""
    r = p.d_full / jnp.maximum(jnp.asarray(bits, jnp.float32), 1.0)
    return jnp.clip(r, jnp.maximum(p.c_min, 1.0), p.c_max)


# ---------------------------------------------------------------------------
# Bit-width selection: the second wire axis (DESIGN.md §3.8)
# ---------------------------------------------------------------------------


def width_candidates(max_width: int) -> tuple[int, ...]:
    """Widths a controller may assign, most precise first: every supported
    storage width from 32 (exact fp32) down to the policy floor
    ``CommPolicy.max_width``.  ``(32,)`` — the default — means the width
    axis is off and plans carry ``widths=None``."""
    return tuple(w for w in sorted(WIRE_WIDTHS, reverse=True)
                 if w >= max_width)


def width_cost(w) -> float:
    """Wire cost of width ``w`` relative to fp32: ``(w + 32/LANE) / 32``
    for ``w < 32`` — the payload at ``w`` bits plus one fp32 scale per
    kept lane-block (``per_block_wire_bits`` over ``LANE·32``) — and
    exactly 1 at 32 (no scales ship on the fp32 wire)."""
    return 1.0 if w >= 32 else (w + 32.0 / LANE) / 32.0


def width_eps(w) -> float:
    """Relative quantisation error proxy of width ``w``: the uniform-
    quantiser MSE bound ``1 / (4·qmax²)`` of a per-block-scaled symmetric
    rounder (error ≤ scale/2 per element, ``scale = amax/qmax``), 0 at 32.
    Only *relative ordering* matters here — it breaks the rate-vs-width
    tie toward precision when a wider wire buys no extra kept blocks."""
    return 0.0 if w >= 32 else 1.0 / (4.0 * float(2 ** (w - 1) - 1) ** 2)


def refine_widths(y, candidates, live):
    """Per-coordinate rate × width refinement: given fp32-cost keep
    fractions ``y`` (any shape) from a water-fill, spend each
    coordinate's bits at the width that retains the most signal —
    ``argmax_w  min(y / cost_w, 1) · (1 − eps_w)`` (``candidates``
    descending, so exact ties keep the more precise width).  Returns
    ``(y_real, widths)``: the realised keep fraction at the chosen width
    (``>= y`` wherever quantisation is chosen — cheaper bits buy more
    blocks) and the per-coordinate width map (32 on dead coordinates).
    This is THE joint 2-D allocation rule: the water level moves bits
    *across* coordinates, this refinement moves them *along* the
    rate-vs-width frontier within each coordinate (DESIGN.md §3.8)."""
    y = jnp.asarray(y, jnp.float32)
    exp = (1,) * y.ndim
    costs = jnp.asarray([width_cost(w) for w in candidates],
                        jnp.float32).reshape(-1, *exp)
    eps = jnp.asarray([width_eps(w) for w in candidates],
                      jnp.float32).reshape(-1, *exp)
    cands = jnp.asarray(candidates, jnp.float32).reshape(-1, *exp)
    y_w = jnp.minimum(y[None] / costs, 1.0)
    util = y_w * (1.0 - eps)
    idx = jnp.argmax(util, axis=0)[None]
    y_real = jnp.take_along_axis(y_w, idx, axis=0)[0]
    widths = jnp.take_along_axis(jnp.broadcast_to(cands, y_w.shape),
                                 idx, axis=0)[0]
    return jnp.where(live, y_real, y), jnp.where(live, widths, 32.0)


def best_uniform_width(bits, d_full: float, candidates):
    """The uniform controllers' width pick: run this step's whole
    allowance at the single width maximising the retained fraction
    (:func:`refine_widths` over one coordinate).  Returns ``(width,
    cost)`` as traced f32 scalars."""
    cands = jnp.asarray(candidates, jnp.float32)
    costs = jnp.asarray([width_cost(w) for w in candidates], jnp.float32)
    eps = jnp.asarray([width_eps(w) for w in candidates], jnp.float32)
    y_w = jnp.minimum(jnp.asarray(bits, jnp.float32) /
                      jnp.maximum(d_full * costs, 1e-30), 1.0)
    idx = jnp.argmax(y_w * (1.0 - eps))
    return cands[idx], costs[idx]


def widths_map(q: int, width) -> jnp.ndarray:
    """A scalar width as a (diagonal-32) ``[Q, Q]`` width map — the wire
    never quantises a worker's own rows (they never ship)."""
    eye = jnp.eye(q, dtype=bool)
    return jnp.where(eye, 32.0, jnp.asarray(width, jnp.float32))


def init_layer_fill(p: Pacing) -> dict:
    """Per-layer fill state shared by the ``budget`` and ``stale``
    controllers: the dropped-energy EMA (initialised to ``layer_bits`` —
    uniform density, so the first fills reproduce the uniform-layer
    allocation) and the monotone keep-fraction floors."""
    return {"ema": jnp.asarray(p.layer_bits, jnp.float32),
            "y": jnp.full(p.layer_bits.shape, 1.0 / p.c_max, jnp.float32)}


def plan_layer_fill(p: Pacing, state: dict, step, cost_factor=1.0):
    """One per-layer planning step (DESIGN.md §3.7): PI allowance →
    sustainable cap → water-fill over ``Pacing.layer_bits`` weighted by
    the dropped-energy EMA, floored at the prior commitments.  Returns
    ``(rates_l [L], integ', y')``.

    ``cost_factor`` (:func:`width_cost` of the step's chosen wire width)
    deflates the wire-bit cap into fp32-equivalent keep units — shipping
    at ``w`` bits costs ``c_w ×`` the fp32 wire per kept block, so the
    same cap buys ``1/c_w ×`` the keep mass (DESIGN.md §3.8)."""
    bits, integ = allowance(p, state["spent"], state["integ"], step)
    cap = sustainable_cap(p, state["spent"], step, bits) / cost_factor
    density = state["ema"] / jnp.maximum(p.layer_bits, 1e-30)
    y = waterfill(density, p.layer_bits, cap, state["y"], 1.0)
    # same rate clamp as the scalar rate_of_allowance — a configured
    # c_min > 1 floors the per-layer rates too (the L=1 telescoping
    # equivalence holds for every pacing, not just the default c_min=1)
    rates_l = jnp.clip(1.0 / jnp.clip(y, 1.0 / p.c_max, 1.0),
                       jnp.maximum(p.c_min, 1.0), p.c_max)
    return rates_l, integ, y


def fold_layer_err(state: dict, obs: dict, ema_decay: float) -> dict:
    """The per-layer observe update: fold ``obs["layer_err"]`` (summed
    over pairs) into the dropped-energy EMA.  The key is required — a
    per-layer controller observing metrics without its layer feedback is
    a plumbing bug that must fail loudly, not freeze the EMA silently
    (every per-layer plan makes ``_auto_metrics`` emit it)."""
    err_l = jnp.sum(jnp.asarray(obs["layer_err"], jnp.float32),
                    axis=(1, 2))
    return {"ema": ema_decay * state["ema"] + (1.0 - ema_decay) * err_l}


def sustainable_cap(p: Pacing, spent, step, bits) -> jnp.ndarray:
    """Clamp one step's allowance to what the remaining budget can
    sustain for the steps left.  Monotone (committed) allocations — the
    ``error`` controller's per-pair keep fractions, every per-layer
    controller's layer fractions — hold for the rest of the run, so a
    transient PI spike must not ratchet them to a level whose sustained
    cost exceeds the budget."""
    remaining = jnp.maximum(p.budget_bits - jnp.asarray(spent, jnp.float32),
                            0.0)
    steps_left = jnp.maximum(
        p.total_steps - jnp.asarray(step, jnp.float32), 1.0)
    return jnp.minimum(jnp.asarray(bits, jnp.float32),
                       remaining / steps_left)
