"""Closed-loop rate control: the controller API + shared pacing machinery.

The open-loop schedulers of ``repro.core.schedulers`` map a step to ONE
global compression rate, blind to training dynamics and to the per-pair
structure the p2p wire exposes.  A :class:`RateController` closes the
loop: it turns a user-supplied **byte budget** into per-step, per-pair
``[Q, Q]`` compression rates from *measured* wire feedback (DESIGN.md
§3.6), following AdaQP's observation that per-boundary-set precision
assignment beats any uniform one.

The contract is three pure functions over a pytree ``state`` (every leaf
a jnp array, so the whole loop is jit-compatible; the trainer happens to
run it on host because the rate map also quantises the step's static
kept-block counts):

* ``init() -> state`` — the carried state at step 0;
* ``plan(state, step) -> (RatePlan, state)`` — the ``[Q, Q]`` rate map
  (receiver × sender, diagonal 1) and per-pair skip mask for this step;
* ``observe(state, obs) -> state`` — fold in the step's measurements:
  ``obs["transport_bits"]`` (scalar bits actually shipped),
  ``obs["pair_err"]`` (``[Q, Q]`` compression squared error — the dropped
  blocks' energy), ``obs["pair_delta"]`` (``[Q, Q]`` relative change of
  each pair's hop buffer vs its cached copy).

Controllers ship in sibling modules: ``budget`` (PI tracking of
``CommLedger.transport`` against the total budget, reducing to the
paper's eq. (8) open-loop schedule at zero gains), ``error`` (AdaQP-style
water-filling of the step's bit allowance over the measured per-pair
error EMA, monotone non-increasing per pair so Proposition 2's
convergence argument still applies), ``stale`` (skip a pair's hop and
reuse its cached halo rows while the boundary block barely changed,
bounded by a staleness cap).

Example::

    ctl = budget_controller(meta, widths, total_steps=300,
                            budget_bits=2e9)
    state = ctl.init()
    plan, state = ctl.plan(state, step)      # plan.rates: [Q, Q]
    ...run the step at plan.rates...
    state = ctl.observe(state, {"transport_bits": shipped, ...})
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax.numpy as jnp
import numpy as np

#: controller names accepted by ``CommPolicy.parse("auto:<name>:<bits>")``
CONTROLLERS = ("budget", "error", "stale")


class RatePlan(NamedTuple):
    """One step's control decision: per-pair rates + per-pair hop skips.

    ``rates [Q, Q]`` (receiver × sender, f32, diagonal 1) are compression
    ratios ``>= 1``; ``skip [Q, Q]`` (0/1 f32) marks pairs whose hop is
    served from the receiver's cached halo buffer instead of the wire
    (``stale`` controller; all-zero for the others).
    """

    rates: jnp.ndarray
    skip: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class RateController:
    """A closed-loop rate controller (module docs for the contract).

    Example::

        state = ctl.init()
        for t in range(T):
            plan, state = ctl.plan(state, t)
            metrics = run_step(plan)
            state = ctl.observe(state, metrics)
    """

    name: str
    init_fn: Callable[[], dict]
    observe_fn: Callable[[dict, dict], dict]
    plan_fn: Callable[[dict, Any], tuple[RatePlan, dict]]

    def init(self) -> dict:
        """Carried state at step 0 (a pytree of jnp arrays)."""
        return self.init_fn()

    def observe(self, state: dict, obs: dict) -> dict:
        """Fold one step's measurements into the carried state."""
        return self.observe_fn(state, obs)

    def plan(self, state: dict, step) -> tuple[RatePlan, dict]:
        """The ``[Q, Q]`` rate map (+ skip mask) for ``step``."""
        return self.plan_fn(state, step)


def uniform_plan(q: int, rate) -> RatePlan:
    """A scalar rate as a (diagonal-1) rate map with no skips."""
    eye = jnp.eye(q, dtype=bool)
    rates = jnp.where(eye, 1.0, jnp.asarray(rate, jnp.float32))
    return RatePlan(rates, jnp.zeros((q, q), jnp.float32))


# ---------------------------------------------------------------------------
# Pacing: open-loop reference trajectory + PI feedback on the spend
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Pacing:
    """Budget pacing shared by every controller.

    The *reference trajectory* is the paper's eq. (8) linear schedule
    (slope ``a``, ``c_max → c_min`` over ``total_steps``): ``phi[t] =
    1/c(t)`` is its keep fraction and ``cum`` its cumulative sum, so the
    target cumulative spend before step ``t`` is ``budget_bits · cum[t] /
    cum[T]``.  :func:`allowance` turns the measured spend into this
    step's bit allowance with PI feedback on the pace error — at zero
    gains the allowance is exactly the open-loop profile, i.e. the
    controller *reduces to eq. (8)* when the budget equals that
    schedule's own total transport.

    ``d_full`` is the analytic full-communication transport of one train
    step (forward + backward over every exchange width): the model that
    converts a bit allowance into a uniform rate and back.
    """

    total_steps: int
    budget_bits: float
    d_full: float
    c_max: float
    c_min: float
    kp: float
    ki: float
    phi: Any
    cum: Any


def make_pacing(meta, widths, total_steps: int, budget_bits: float,
                c_max: float = 128.0, c_min: float = 1.0,
                slope: float = 5.0, kp: float = 4.0,
                ki: float = 0.25) -> Pacing:
    """Build the shared pacing state for ``meta`` (needs ``halo_demand``)
    and the per-step exchange ``widths`` (see ``driver.exchange_widths``)."""
    from repro.core import schedulers

    if budget_bits <= 0:
        raise ValueError(f"budget_bits must be positive, got {budget_bits}")
    total = max(total_steps, 1)
    sched = schedulers.linear(total, slope=slope, c_max=c_max, c_min=c_min)
    phi = 1.0 / np.asarray([float(sched(t)) for t in range(total)])
    cum = np.concatenate([[0.0], np.cumsum(phi)])
    d_full = 2.0 * 32.0 * float(meta.halo_demand) * float(sum(widths))
    return Pacing(total_steps=int(max(total_steps, 1)),
                  budget_bits=float(budget_bits), d_full=d_full,
                  c_max=float(c_max), c_min=float(c_min), kp=float(kp),
                  ki=float(ki), phi=jnp.asarray(phi, jnp.float32),
                  cum=jnp.asarray(cum, jnp.float32))


def allowance(p: Pacing, spent, integ, step):
    """This step's bit allowance: receding-horizon replanning + PI.

    The *remaining* budget is spent proportionally to the *remaining*
    open-loop profile — ``(B − spent) · phi[t] / Σ_{s>=t} phi[s]`` — so a
    deficit or surplus redistributes over the steps left instead of being
    lost at the horizon.  When the measured spend tracks the profile
    exactly this telescopes to the open-loop allowance ``B · phi[t] /
    Σphi`` identically (the eq.-(8) reduction).  A PI term
    ``exp(kp·e + ki·Σe)`` on the pace error ``e`` (underspent → ``e > 0``
    → spend more) corrects the systematic bias of lane-block quantisation;
    the integral is clamped (anti-windup: a rate pinned at ``c_max`` /
    ``c_min`` must not accumulate unbounded correction).

    Returns ``(bits, integ')``."""
    ti = jnp.clip(jnp.asarray(step, jnp.int32), 0, p.total_steps - 1)
    frac = p.cum[ti] / p.cum[-1]
    e = frac - jnp.asarray(spent, jnp.float32) / p.budget_bits
    integ = jnp.clip(integ + e, -10.0, 10.0)
    gain = jnp.exp(p.kp * e + p.ki * integ)
    share = p.phi[ti] / jnp.maximum(p.cum[-1] - p.cum[ti], 1e-12)
    left = jnp.maximum(p.budget_bits - jnp.asarray(spent, jnp.float32), 0.0)
    return left * share * gain, integ


def rate_of_allowance(p: Pacing, bits) -> jnp.ndarray:
    """Uniform rate realising a per-step bit allowance: ``d_full / bits``
    clamped to ``[c_min_rate, c_max]`` (a rate is never below 1)."""
    r = p.d_full / jnp.maximum(jnp.asarray(bits, jnp.float32), 1.0)
    return jnp.clip(r, jnp.maximum(p.c_min, 1.0), p.c_max)
