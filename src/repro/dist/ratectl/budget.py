"""``budget`` controller: PI tracking of transport bits against a budget.

The user names a total wire budget ``B`` (bits over the whole run); the
controller plans, each step, ONE uniform rate whose predicted transport
follows the paper's eq.-(8) reference trajectory scaled to ``B``, and
closes the loop with PI feedback on the *measured* cumulative
``CommLedger.transport`` — so lane-block quantisation error (the realised
kept count is ``max(floor(nb/r), 1)``, a staircase in ``r``) dithers the
planned rate between adjacent counts instead of accumulating drift.

At zero gains (``kp = ki = 0``) and ``B`` equal to the eq.-(8) schedule's
own total, the plan IS the open-loop schedule — the closed loop strictly
generalises the paper's scheme (DESIGN.md §3.6).

``per_layer=True`` (DESIGN.md §3.7) splits each step's bit allowance
across the model's ``L`` layers by **water-filling over the measured
per-layer dropped-energy EMA** (AdaQP's bit-allocation observation,
lifted from pairs to layers): layers whose exchanges lose the most
activation energy to compression keep proportionally more lane-blocks,
uniform within the layer's ``[Q, Q]`` pairs.  Each layer's keep fraction
is **monotone non-decreasing** (its rate never rises again), so every
layer's compression-error sequence is non-increasing and Proposition 2's
convergence argument applies per layer.  With ``L = 1`` the fill
degenerates to ``y = allowance / d_full`` — exactly the scalar plan, so
the per-layer controller still telescopes to eq. (8) at zero gains.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.dist.ratectl.base import (Pacing, RateController, allowance,
                                     best_uniform_width, fold_layer_err,
                                     init_layer_fill, plan_layer_fill,
                                     rate_of_allowance, uniform_layer_plan,
                                     uniform_plan, width_candidates,
                                     widths_map)


def budget_controller(q: int, pacing: Pacing, name: str = "budget",
                      per_layer: bool = False,
                      ema_decay: float = 0.8,
                      max_width: int = 32) -> RateController:
    """Budget-tracking PI controller over a ``workers`` axis of size ``q``.

    State: ``{"spent": bits shipped so far, "integ": PI integral}``; the
    per-layer mode adds ``{"ema": [L] dropped-energy EMA, "y": [L]
    monotone keep fractions}`` and needs ``pacing.layer_bits``
    (``make_pacing(..., layer_widths=...)``).

    ``max_width < 32`` (DESIGN.md §3.8) turns the allowance → rate map
    into a joint rate × width choice: each step the controller picks the
    single wire width (from 32 down to ``max_width``) whose cheaper bits
    retain the most boundary signal — ``argmax_w  min(allowance /
    (d_full · cost_w), 1) · (1 − eps_w)`` — then converts the allowance
    at that width's cost into the uniform rate.  A generous allowance
    picks 32 (exact wire, ``widths=None``); a squeezed one trades
    precision for kept blocks.

    Example::

        pacing = make_pacing(meta, widths, total_steps=300,
                             budget_bits=2e9)
        ctl = budget_controller(meta.q, pacing)
    """
    if per_layer and pacing.layer_bits is None:
        raise ValueError(
            "per_layer needs pacing.layer_bits — build the pacing with "
            "make_pacing(..., layer_widths=layer_exchange_widths(cfg))")
    candidates = width_candidates(max_width)

    def init():
        state = {"spent": jnp.zeros((), jnp.float32),
                 "integ": jnp.zeros((), jnp.float32)}
        if per_layer:
            state.update(init_layer_fill(pacing))
        return state

    def pick_width(state, step):
        """The step's uniform width from the PI allowance (32 ↔ exact)."""
        if len(candidates) == 1:               # width axis off
            return None, 1.0
        bits, _ = allowance(pacing, state["spent"], state["integ"], step)
        return best_uniform_width(bits, pacing.d_full, candidates)

    def plan(state, step):
        w_star, cost = pick_width(state, step)
        wmap = None if w_star is None else widths_map(q, w_star)
        if not per_layer:
            bits, integ = allowance(pacing, state["spent"], state["integ"],
                                    step)
            rate = rate_of_allowance(pacing, bits / cost)
            plan_ = uniform_plan(q, rate)
            return plan_._replace(widths=wmap), {**state, "integ": integ}
        rates_l, integ, y = plan_layer_fill(pacing, state, step,
                                            cost_factor=cost)
        plan_ = uniform_layer_plan(q, rates_l)
        return plan_._replace(widths=wmap), \
            {**state, "integ": integ, "y": y}

    def observe(state, obs):
        out = {**state,
               "spent": state["spent"] +
               jnp.asarray(obs["transport_bits"], jnp.float32)}
        if per_layer:
            out.update(fold_layer_err(state, obs, ema_decay))
        return out

    return RateController(name, init, observe, plan)
