"""``budget`` controller: PI tracking of transport bits against a budget.

The user names a total wire budget ``B`` (bits over the whole run); the
controller plans, each step, ONE uniform rate whose predicted transport
follows the paper's eq.-(8) reference trajectory scaled to ``B``, and
closes the loop with PI feedback on the *measured* cumulative
``CommLedger.transport`` — so lane-block quantisation error (the realised
kept count is ``max(floor(nb/r), 1)``, a staircase in ``r``) dithers the
planned rate between adjacent counts instead of accumulating drift.

At zero gains (``kp = ki = 0``) and ``B`` equal to the eq.-(8) schedule's
own total, the plan IS the open-loop schedule — the closed loop strictly
generalises the paper's scheme (DESIGN.md §3.6).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.dist.ratectl.base import (Pacing, RateController, allowance,
                                     rate_of_allowance, uniform_plan)


def budget_controller(q: int, pacing: Pacing,
                      name: str = "budget") -> RateController:
    """Budget-tracking PI controller over a ``workers`` axis of size ``q``.

    State: ``{"spent": bits shipped so far, "integ": PI integral}``.

    Example::

        pacing = make_pacing(meta, widths, total_steps=300,
                             budget_bits=2e9)
        ctl = budget_controller(meta.q, pacing)
    """

    def init():
        return {"spent": jnp.zeros((), jnp.float32),
                "integ": jnp.zeros((), jnp.float32)}

    def plan(state, step):
        bits, integ = allowance(pacing, state["spent"], state["integ"], step)
        rate = rate_of_allowance(pacing, bits)
        return uniform_plan(q, rate), {**state, "integ": integ}

    def observe(state, obs):
        return {**state,
                "spent": state["spent"] +
                jnp.asarray(obs["transport_bits"], jnp.float32)}

    return RateController(name, init, observe, plan)
