"""Trainer integration: auto policies → controller + per-pair train step.

``CommPolicy.parse("auto:<controller>:<budget-bits>")`` names a closed
loop; this module turns it into running machinery:

* :func:`make_controller` — instantiate the named controller with the
  shared budget pacing built from the partition facts;
* :func:`make_auto_train_step` — the per-pair-rate analogue of
  ``repro.dist.gnn_parallel.make_train_step``: same Algorithm-1 step, but
  the compression operand is a traced ``[Q, Q]`` rate map (+ skip mask
  and halo cache for the ``stale`` controller) planned by the controller
  each step.  The step quantises the concrete map to its static
  kept-block maximum per width (`_packed_pair_k_for`) outside jit —
  bounded recompiles, exactly the scalar wires' contract — and the
  shard_map executables sit behind the same LRU cache.

The loop a trainer runs (``repro.train.trainer.train_gnn`` does this):

    ctl = make_controller(policy, meta, cfg, total_steps)
    state, cache = ctl.init(), init_halo_cache(meta, cfg)
    step = make_auto_train_step(cfg, policy, opt, meta, mesh=mesh)
    for t in range(total_steps):
        plan, state = ctl.plan(state, t)
        params, opt_state, m, cache = step(params, opt_state, graph,
                                           key_t, plan, cache)
        state = ctl.observe(state, m)

``observe`` reads the metrics directly: the step returns
``pair_transport`` / ``pair_err`` / ``pair_delta`` ``[Q, Q]`` matrices
next to the usual scalars (History's per-pair transport columns come from
the same place).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.varco import CommPolicy
from repro.dist.gnn_parallel import (AXIS, COMPILED_CACHE_SIZE, DistMeta,
                                     _local_loss_fn, _make_aggregate_emulated,
                                     _make_aggregate_shard, _packed_pair_k_for,
                                     _packed_pair_w_for, _packed_store_w,
                                     _pmean_inexact, _snap_width)
from repro.dist.ratectl.base import RateController, RatePlan, make_pacing
from repro.dist.ratectl.budget import budget_controller
from repro.dist.ratectl.error import error_controller
from repro.dist.ratectl.qos import qos_controller
from repro.dist.ratectl.stale import stale_controller
from repro.kernels.ops import default_wire_rounding
from repro.kernels.varco_pack import LANE
from repro.nn.gnn import GNNConfig, gnn_forward, masked_loss_and_correct
from repro.train.optim import Optimizer, apply_updates


def exchange_widths(cfg: GNNConfig) -> tuple[int, ...]:
    """Feature width of every halo exchange in one forward pass: each
    layer's input width, once per exchange call (sage: one per layer;
    poly: ``k_taps - 1`` per layer) — the controllers' transport model and
    the ``stale`` cache's buffer widths."""
    dims = [cfg.in_dim] + [cfg.hidden] * (cfg.layers - 1)
    reps = 1 if cfg.conv == "sage" else max(cfg.k_taps - 1, 1)
    return tuple(d for d in dims for _ in range(reps))


def layer_exchange_widths(cfg: GNNConfig) -> tuple[int, ...]:
    """Summed exchange width of each model *layer* (``[L]``): layer
    ``l``'s input width times its exchange count (sage 1, poly
    ``k_taps - 1``).  Sums to ``sum(exchange_widths(cfg))`` — the
    per-layer split of the controllers' transport model
    (``Pacing.layer_bits``, DESIGN.md §3.7)."""
    dims = [cfg.in_dim] + [cfg.hidden] * (cfg.layers - 1)
    reps = 1 if cfg.conv == "sage" else max(cfg.k_taps - 1, 1)
    return tuple(d * reps for d in dims)


def make_controller(policy: CommPolicy, meta: DistMeta, cfg: GNNConfig,
                    total_steps: int, **overrides) -> RateController:
    """Instantiate ``policy.controller`` with pacing scaled to
    ``policy.budget_bits`` over ``total_steps``.

    ``overrides`` pass through to :func:`repro.dist.ratectl.base.
    make_pacing` (``c_max``, ``slope``, ``kp``, ``ki``, ...) and to the
    controller factory (``threshold``/``max_stale`` for ``stale``,
    ``ema_decay`` for ``error``).

    Example::

        policy = CommPolicy.parse("auto:budget:2e9", epochs)
        ctl = make_controller(policy, meta, cfg, epochs)
    """
    if policy.mode != "auto":
        raise ValueError(f"policy mode must be 'auto', got {policy.mode!r}")
    ctl_kw = {k: overrides.pop(k) for k in ("threshold", "max_stale",
                                            "ema_decay")
              if k in overrides}
    per_layer = policy.per_layer
    pacing = make_pacing(meta, exchange_widths(cfg), total_steps,
                         policy.budget_bits,
                         layer_widths=layer_exchange_widths(cfg)
                         if per_layer else None,
                         **overrides)
    if policy.controller != "stale":
        bad = sorted(k for k in ("threshold", "max_stale") if k in ctl_kw)
        if bad:
            raise ValueError(
                f"{'/'.join(bad)} are stale-controller knobs; the "
                f"{policy.controller!r} controller does not accept them")
    if "ema_decay" in ctl_kw and policy.controller not in ("error", "qos") \
            and not per_layer:
        raise ValueError(
            f"ema_decay drives the error/qos EMAs; the scalar "
            f"{policy.controller!r} controller keeps none — use the "
            f"error or qos controller or a :per-layer policy")
    if policy.controller == "budget":
        return budget_controller(meta.q, pacing, per_layer=per_layer,
                                 max_width=policy.max_width, **ctl_kw)
    if policy.controller == "error":
        return error_controller(meta.q, pacing, meta.pair_table(),
                                per_layer=per_layer,
                                max_width=policy.max_width, **ctl_kw)
    if policy.controller == "qos":
        return qos_controller(meta.q, pacing, meta.pair_table(),
                              per_layer=per_layer,
                              max_width=policy.max_width, **ctl_kw)
    if policy.controller == "stale":
        return stale_controller(meta.q, pacing, per_layer=per_layer,
                                max_width=policy.max_width, **ctl_kw)
    raise ValueError(f"unknown controller {policy.controller!r}")


def init_halo_cache(meta: DistMeta, cfg: GNNConfig) -> tuple:
    """Zero-initialised per-exchange hop-buffer caches for the ``stale``
    controller (``[Q, D, H, width]`` per exchange call; p2p wire only).
    The controller never skips at step 0, so the zeros are never read."""
    d = max(meta.q - 1, 1)
    return tuple(jnp.zeros((meta.q, d, meta.p2p_hop_width, w), jnp.float32)
                 for w in exchange_widths(cfg))


def init_wire_residuals(meta: DistMeta, cfg: GNNConfig) -> tuple:
    """Zero-initialised per-exchange error-feedback residual accumulators
    for quantising policies (``max_width < 32``, p2p wire, emulated
    backend): one full-width ``[Q, D, H, width]`` buffer per exchange
    call — the same shapes as :func:`init_halo_cache`, because both ride
    the train step's ``cache`` channel (stale XOR error-feedback,
    DESIGN.md §3.8).  Each step the residual is packed onto the fresh
    kept set, added to the pre-quantisation payload, and replaced by the
    new quantisation error — so the wire's rounding error is re-shipped
    instead of lost and the compressed-gradient bias stays bounded."""
    return init_halo_cache(meta, cfg)


def _auto_metrics(loss, rate_map, bits, q: int, n_exchanges: int) -> dict:
    """Step metrics of the per-pair ledger vector (``2 + 3·L·Q²`` layout
    of ``gnn_parallel._pair_ledger``; ``L == 1`` for ``[Q, Q]`` pair
    maps); transports double for the backward cotangents exactly like the
    scalar `_step_metrics`.  The staleness delta accumulates one
    relative-change ratio per exchange call, so it is averaged over
    ``n_exchanges`` here — the controller-facing ``pair_delta`` is the
    mean per-buffer change, depth-independent (the ``stale`` threshold
    must not shrink with network depth).

    A per-layer ``[L, Q, Q]`` rate tensor additionally yields
    ``layer_transport`` / ``layer_err`` ``[L, Q, Q]`` tensors (the
    ``pair_*`` matrices are their sums over ``L``, so downstream
    consumers are layout-independent)."""
    n_layers = 1 if rate_map.ndim == 2 else rate_map.shape[0]
    eye = jnp.eye(q, dtype=bool)
    off = ~eye if rate_map.ndim == 2 else ~eye[None]
    mean_rate = jnp.sum(jnp.where(off, rate_map, 0.0)) / \
        max((q * q - q) * n_layers, 1)
    q2 = q * q
    lq2 = n_layers * q2
    layer_t = bits[2:2 + lq2].reshape(n_layers, q, q)
    layer_e = bits[2 + lq2:2 + 2 * lq2].reshape(n_layers, q, q)
    layer_d = bits[2 + 2 * lq2:2 + 3 * lq2].reshape(n_layers, q, q)
    out = {"loss": loss, "rate": mean_rate,
           "halo_bits": 2.0 * bits[0], "transport_bits": 2.0 * bits[1],
           "pair_transport": 2.0 * jnp.sum(layer_t, axis=0),
           "pair_err": jnp.sum(layer_e, axis=0),
           "pair_delta": jnp.sum(layer_d, axis=0) / max(n_exchanges, 1)}
    if rate_map.ndim == 3:
        # keyed on the PLAN's rank, not on L > 1: a per-layer controller
        # on a 1-layer model still needs its layer_err feedback (and its
        # History columns), even though the ledger kept the legacy layout
        out["layer_transport"] = 2.0 * layer_t
        out["layer_err"] = layer_e
    return out


def make_auto_train_step(cfg: GNNConfig, policy: CommPolicy, opt: Optimizer,
                         meta: DistMeta, mesh: Mesh | None = None,
                         sync: str = "grad", stale: bool | None = None,
                         compiled_cache_size: int = COMPILED_CACHE_SIZE,
                         rounding: str | None = None):
    """One Algorithm-1 step driven by a :class:`RatePlan`.

    ``step(params, opt_state, graph, key, plan, cache=()) ->
    (params, opt_state, metrics, cache')`` — ``plan.rates`` must be a
    concrete ``[Q, Q]`` map or per-layer ``[L, Q, Q]`` tensor with
    ``L == cfg.layers`` (the step quantises it to the static kept-block
    maximum per width; passing it traced would defeat the
    bounded-recompile contract).  ``metrics`` adds ``pair_transport`` /
    ``pair_err`` / ``pair_delta`` ``[Q, Q]`` matrices to the usual
    scalars — plus ``layer_transport`` / ``layer_err`` ``[L, Q, Q]``
    tensors for per-layer plans (DESIGN.md §3.7).  ``cache`` is the
    ``stale`` controller's halo-cache tuple (:func:`init_halo_cache`) —
    or, for a quantising policy (``max_width < 32``) on the emulated p2p
    wire, the error-feedback residual tuple
    (:func:`init_wire_residuals`); the two uses are exclusive
    (stale XOR EF).  Other configurations pass ``()`` and get ``()``
    back.

    ``plan.widths`` (``None`` or a concrete ``[Q, Q]`` / ``[L, Q, Q]``
    map) quantises each pair's wire payload (DESIGN.md §3.8): the step
    snaps the widths to the storage grid and keys its compiled variants
    on the distinct sub-32 widths (`_packed_pair_w_for`) exactly like the
    kept-block maps — ``widths=None`` or an all-32 map compiles the
    pre-quantisation program bit-for-bit.

    Requirements: ``policy.mode == "auto"``, ``meta.wire`` in
    ``("packed", "p2p")``, every exchanged width on the 128-lane grid,
    and the graph pytree carrying the ``attach_p2p`` arrays (the per-pair
    ledger and error stats read the per-pair halo sets on every wire).
    Hop reuse (``stale``) additionally needs ``wire == "p2p"`` and the
    emulated backend; error feedback runs on both backends with
    bitwise-identical residual state (tests/test_ratectl.py pins it).

    ``rounding`` picks the quantiser's rounding mode — ``"rint"``
    (deterministic nearest-even) or ``"stochastic"`` (unbiased, per-step
    ``(seed, step, pair)`` key schedule, DESIGN.md §3.8).  ``None``
    defers to :func:`repro.kernels.ops.default_wire_rounding`:
    stochastic on TPU, ``rint`` elsewhere, so CPU golden traces are
    unchanged and TPU wires are unbiased by default.

    Example::

        step = make_auto_train_step(cfg, policy, adamw(5e-3), meta)
        plan, state = ctl.plan(state, t)
        params, opt_state, m, cache = step(params, opt_state, graph,
                                           jax.random.key(t), plan, cache)
    """
    if policy.mode != "auto":
        raise ValueError(f"make_auto_train_step needs an 'auto' policy, "
                         f"got mode {policy.mode!r}")
    if meta.wire not in ("packed", "p2p"):
        raise ValueError(f"per-pair rate maps need wire='packed' or 'p2p', "
                         f"got {meta.wire!r} (the dense wire is scalar-only)")
    if sync not in ("grad", "fedavg"):
        raise ValueError(f"sync must be 'grad' or 'fedavg', got {sync!r}")
    for f_ in {meta.feat_dim, *meta.layer_dims}:
        if f_ % LANE:
            raise ValueError(
                f"per-pair rate maps pack lane-blocks; every exchanged "
                f"width must be divisible by {LANE}, got {f_}")
    n_ex = len(exchange_widths(cfg))
    stale = (policy.controller == "stale") if stale is None else stale
    if stale and meta.wire != "p2p":
        raise ValueError("the stale controller reuses per-pair hop buffers; "
                         "it needs wire='p2p'")
    if stale and mesh is not None:
        raise ValueError(
            "hop reuse is emulated-backend only: a shape-uniform SPMD "
            "ppermute cannot drop individual pairs' buffers (DESIGN.md "
            "§3.6); run the stale controller with mesh=None")
    if rounding is None:
        rounding = default_wire_rounding()
    if rounding not in ("rint", "stochastic"):
        raise ValueError(f"rounding must be 'rint' or 'stochastic', "
                         f"got {rounding!r}")
    # error feedback accumulates per-exchange residual state through the
    # same cache channel hop reuse owns — stale XOR error-feedback; a
    # stale run at max_width < 32 quantises without EF (DESIGN.md §3.8)
    use_ef = policy.max_width < 32 and meta.wire == "p2p" and not stale

    def _plan_widths(plan: RatePlan):
        """Host-side width quantisation: snap the planned widths to the
        supported storage grid (`_snap_width`, mirroring the kept-block
        floor), and derive the jit-static distinct-width tuple
        (`_packed_pair_w_for`) — ``()`` compiles the exact pre-
        quantisation program.  Returns ``(wm | None, wire_w)``."""
        if plan.widths is None:
            return None, ()
        wm = np.asarray(plan.widths, np.float32)
        wm = np.vectorize(_snap_width)(wm).astype(np.float32)
        ww = _packed_pair_w_for(meta, wm)
        return (wm, ww) if ww else (None, ())

    if mesh is None:
        @functools.partial(jax.jit,
                           static_argnames=("packed_k", "wire_w", "store_w"))
        def _jit_step(params, opt_state, graph, key, rate_map, width_map,
                      skip, cache, packed_k, wire_w, store_w=0):
            wm = width_map if wire_w else None
            ef = use_ef and bool(wire_w) and bool(cache)

            def loss_fn(p):
                cache_out: list = []
                agg = _make_aggregate_emulated(
                    graph, meta, policy, None, jnp.ones((), jnp.float32),
                    key, packed_k=dict(packed_k), rate_map=rate_map,
                    skip=skip if stale else None,
                    cache=cache if stale else None,
                    cache_out=cache_out if stale else None,
                    width_map=wm,
                    resid=cache if ef else None,
                    resid_out=cache_out if ef else None,
                    rounding=rounding,
                    store_w=store_w if wire_w else 0)
                logits, bits = gnn_forward(p, cfg, graph["features"], agg)
                loss_sum, _ = masked_loss_and_correct(
                    logits, graph["labels"], graph["train_mask"])
                return loss_sum / max(meta.n_train, 1), \
                    (bits, tuple(cache_out))

            (loss, (bits, cache_new)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            updates, new_state = opt.update(grads, opt_state, params)
            new_params = apply_updates(params, updates)
            return (new_params, new_state,
                    _auto_metrics(loss, rate_map, bits, meta.q, n_ex),
                    cache_new)

        def step(params, opt_state, graph, key, plan: RatePlan, cache=()):
            rm = np.asarray(plan.rates, np.float32)
            kb = _packed_pair_k_for(meta, rm)
            wm, ww = _plan_widths(plan)
            out = _jit_step(params, opt_state, graph, key,
                            jnp.asarray(rm),
                            jnp.zeros((), jnp.float32) if wm is None
                            else jnp.asarray(wm),
                            jnp.asarray(plan.skip, jnp.float32),
                            tuple(cache), packed_k=kb, wire_w=ww,
                            store_w=_packed_store_w(meta, wm))
            # an exact (unquantised) step neither reads nor rewrites EF
            # residuals — carry them unchanged instead of dropping them
            return out if out[3] or not cache else (*out[:3], tuple(cache))

        step._jit_step = _jit_step
        return step

    def make_worker(packed_k: tuple, wire_w: tuple, ef: bool,
                    store_w: int = 0):
        def worker(params, opt_state, gblk, rate_map, width_map, key,
                   cache):
            # `cache` is the EF residual tuple sharded along its leading
            # [Q] axis: this worker sees [1, D, H, F] blocks and passes
            # its own sender-major slab into the exchange
            def loss_fn(p):
                cache_out: list = []
                agg = _make_aggregate_shard(
                    gblk, meta, policy, None, jnp.ones((), jnp.float32),
                    key, packed_k=dict(packed_k), rate_map=rate_map,
                    width_map=width_map if wire_w else None,
                    resid=cache if ef else None,
                    resid_out=cache_out if ef else None,
                    rounding=rounding,
                    store_w=store_w if wire_w else 0)
                loss, bits = _local_loss_fn(p, cfg, gblk, agg, meta)
                return loss, (bits, tuple(cache_out))

            (loss, (bits, cache_new)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            loss = lax.psum(loss, AXIS)
            if sync == "grad":
                grads = jax.tree_util.tree_map(lambda g: lax.psum(g, AXIS),
                                               grads)
                updates, new_state = opt.update(grads, opt_state, params)
                params = apply_updates(params, updates)
            else:  # fedavg
                updates, new_state = opt.update(grads, opt_state, params)
                params = apply_updates(params, updates)
                params = _pmean_inexact(params, AXIS)
                new_state = _pmean_inexact(new_state, AXIS)
            return (params, new_state,
                    _auto_metrics(loss, rate_map, bits, meta.q, n_ex),
                    cache_new)

        return worker

    @functools.lru_cache(maxsize=compiled_cache_size)
    def _compiled_for(kblocks: tuple, wire_w: tuple = (), ef: bool = False,
                      store_w: int = 0):
        return jax.jit(shard_map(
            make_worker(kblocks, wire_w, ef, store_w), mesh=mesh,
            in_specs=(P(), P(), P(AXIS), P(), P(), P(), P(AXIS)),
            out_specs=(P(), P(), P(), P(AXIS)), check_rep=False))

    def step(params, opt_state, graph, key, plan: RatePlan, cache=()):
        rm = np.asarray(plan.rates, np.float32)
        kb = _packed_pair_k_for(meta, rm)
        wm, ww = _plan_widths(plan)
        ef = use_ef and bool(ww) and bool(cache)
        params, opt_state, m, cache_new = _compiled_for(
            kb, ww, ef, _packed_store_w(meta, wm))(
            params, opt_state, graph, jnp.asarray(rm),
            jnp.zeros((), jnp.float32) if wm is None else jnp.asarray(wm),
            key, tuple(cache))
        # an exact (unquantised) step neither reads nor rewrites EF
        # residuals — carry them unchanged instead of dropping them
        return params, opt_state, m, \
            tuple(cache_new) if ef else tuple(cache)

    step.cache_info = _compiled_for.cache_info
    step.cache_clear = _compiled_for.cache_clear
    return step
