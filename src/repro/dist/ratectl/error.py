"""``error`` controller: per-pair rates water-filled from measured error.

AdaQP's observation, transplanted to the VARCO wire: assigning message
precision per boundary set from measured statistics beats any uniform
assignment under the same bit budget.  Here the "precision" is each
ordered pair's kept-lane-block fraction ``y = 1/rate``: every step the
controller takes the budget pacing's bit allowance (same PI machinery as
the ``budget`` controller) and **water-fills** it over the pairs by
descending measured compression-error density — the EMA of each pair's
dropped-block energy per boundary row — so pairs whose activations lose
the most energy to compression communicate at the lowest rates.

The per-pair rates are forced **monotone non-increasing** over steps
(``y`` only ever grows), so the induced compression error still decreases
step-to-step and Proposition 2's convergence argument applies unchanged
(DESIGN.md §3.6).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.dist.ratectl.base import (Pacing, RateController, RatePlan,
                                     allowance)


def waterfill(density, rows, cap, y_floor, y_max: float = 1.0,
              iters: int = 60) -> jnp.ndarray:
    """Proportional (log-utility) water-filling of keep fractions.

    Solve ``y = clip(λ · density, y_floor, y_max)`` for the water level
    ``λ`` such that ``Σ rows · y == cap`` (bisection, ``iters`` fixed
    halvings — pure jnp, runs under jit).  This is the exact maximiser of
    ``Σ rows · density · log(y)`` under the bit constraint: pairs with
    higher measured error density keep proportionally more blocks, equal
    densities degrade gracefully to the uniform allocation (never starving
    an arbitrary subset of tied pairs, which the LP-greedy fill would).
    ``y_floor`` (scalar or ``[Q, Q]``) carries the monotone-rate
    commitments: the fill only ever *adds* on top of it, so a floor
    already exceeding ``cap`` returns the floor unchanged.
    """
    y_floor = jnp.broadcast_to(jnp.asarray(y_floor, jnp.float32), rows.shape)
    d = jnp.where(rows > 0, jnp.maximum(density, 0.0), 0.0)
    dn = d / jnp.maximum(jnp.max(d), 1e-30)      # normalised to [0, 1]
    cap = jnp.maximum(cap, jnp.sum(rows * y_floor))

    def fill(lam):
        return jnp.clip(lam * dn, y_floor, y_max)

    lo = jnp.zeros(())
    hi = jnp.full((), 1e12)
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        under = jnp.sum(rows * fill(mid)) <= cap
        lo = jnp.where(under, mid, lo)
        hi = jnp.where(under, hi, mid)
    return fill(lo)


def error_controller(q: int, pacing: Pacing, pair_rows,
                     ema_decay: float = 0.8,
                     name: str = "error") -> RateController:
    """Error-weighted per-pair controller (module docs).

    ``pair_rows`` is the static ``[Q, Q]`` halo row-count table
    (``DistMeta.pair_table()``): the water-filling's cost unit, and the
    error EMA's initial value (uniform density until measurements arrive).

    State: ``{"spent", "integ", "ema" [Q, Q], "y" [Q, Q]}`` with ``y``
    the monotone keep fractions.

    Example::

        ctl = error_controller(meta.q, pacing, meta.pair_table())
    """
    rows = jnp.asarray(pair_rows, jnp.float32)
    eye = jnp.eye(q, dtype=bool)
    live = (rows > 0) & ~eye
    y_min = 1.0 / pacing.c_max
    # bits of one train step per unit of Σ rows·y (fwd + bwd, all widths)
    bits_per_rowkeep = pacing.d_full / max(float(jnp.sum(rows)), 1.0)

    def init():
        return {"spent": jnp.zeros((), jnp.float32),
                "integ": jnp.zeros((), jnp.float32),
                "ema": rows,
                "y": jnp.full((q, q), y_min, jnp.float32)}

    def plan(state, step):
        bits, integ = allowance(pacing, state["spent"], state["integ"], step)
        # the monotone y makes every allocation a COMMITMENT for the rest
        # of the run, so cap this step by what the remaining budget can
        # sustain for the steps left — a transient PI spike must not ratchet
        # y to a level whose sustained cost exceeds the budget
        remaining = jnp.maximum(pacing.budget_bits - state["spent"], 0.0)
        steps_left = jnp.maximum(
            pacing.total_steps - jnp.asarray(step, jnp.float32), 1.0)
        cap = jnp.minimum(bits, remaining / steps_left) / bits_per_rowkeep
        density = jnp.where(live, state["ema"] / jnp.maximum(rows, 1.0),
                            -jnp.inf)
        # prior commitments are the fill's floor → monotone by construction
        y = waterfill(density, rows, cap, state["y"], 1.0)
        rates = jnp.where(live, 1.0 / jnp.clip(y, y_min, 1.0), 1.0)
        plan_ = RatePlan(rates, jnp.zeros((q, q), jnp.float32))
        return plan_, {**state, "integ": integ, "y": y}

    def observe(state, obs):
        err = jnp.asarray(obs["pair_err"], jnp.float32)
        return {**state,
                "spent": state["spent"] +
                jnp.asarray(obs["transport_bits"], jnp.float32),
                "ema": ema_decay * state["ema"] + (1.0 - ema_decay) * err}

    return RateController(name, init, observe, plan)
