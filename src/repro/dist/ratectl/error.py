"""``error`` controller: per-pair rates water-filled from measured error.

AdaQP's observation, transplanted to the VARCO wire: assigning message
precision per boundary set from measured statistics beats any uniform
assignment under the same bit budget.  Here the "precision" is each
ordered pair's kept-lane-block fraction ``y = 1/rate``: every step the
controller takes the budget pacing's bit allowance (same PI machinery as
the ``budget`` controller) and **water-fills** it over the pairs by
descending measured compression-error density — the EMA of each pair's
dropped-block energy per boundary row — so pairs whose activations lose
the most energy to compression communicate at the lowest rates.

The per-pair rates are forced **monotone non-increasing** over steps
(``y`` only ever grows), so the induced compression error still decreases
step-to-step and Proposition 2's convergence argument applies unchanged
(DESIGN.md §3.6).

``per_layer=True`` (DESIGN.md §3.7) lifts the fill to the joint
``[L, Q, Q]`` index set: the cost of keep fraction ``y[l, i, j]`` is
``rows[i, j] · layer_width[l]`` wire bits, the density is layer ``l``'s
measured per-pair dropped energy per unit cost, and one water level
clears the whole tensor — so bits flow to whichever (layer, pair)
coordinates lose the most energy, at every granularity at once.
Monotonicity is enforced per coordinate, so each pair's per-layer rate
sequence is non-increasing and Proposition 2 applies layer by layer.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.dist.ratectl.base import (Pacing, RateController, RatePlan,
                                     allowance, refine_widths,
                                     sustainable_cap, waterfill,
                                     width_candidates)

__all__ = ["error_controller", "waterfill"]


def error_controller(q: int, pacing: Pacing, pair_rows,
                     ema_decay: float = 0.8,
                     name: str = "error",
                     per_layer: bool = False,
                     max_width: int = 32) -> RateController:
    """Error-weighted per-pair controller (module docs).

    ``pair_rows`` is the static ``[Q, Q]`` halo row-count table
    (``DistMeta.pair_table()``): the water-filling's cost unit, and the
    error EMA's initial value (uniform density until measurements arrive).

    State: ``{"spent", "integ", "ema", "y"}`` with ``y`` the monotone
    keep fractions — ``[Q, Q]`` matrices, or ``[L, Q, Q]`` tensors in
    ``per_layer`` mode (which needs ``pacing.layer_bits``).

    ``max_width < 32`` (DESIGN.md §3.8) refines each coordinate's filled
    allocation along the rate × width frontier
    (:func:`repro.dist.ratectl.base.refine_widths`): the committed ``y``
    stays in fp32-cost units (monotonicity and Proposition 2 are
    untouched), but each (layer,) pair *spends* its bits at the width
    retaining the most signal — low-density pairs drop to 2–4-bit wires
    and keep proportionally more blocks.

    Example::

        ctl = error_controller(meta.q, pacing, meta.pair_table())
    """
    rows = jnp.asarray(pair_rows, jnp.float32)
    eye = jnp.eye(q, dtype=bool)
    live = (rows > 0) & ~eye
    y_min = 1.0 / pacing.c_max
    candidates = width_candidates(max_width)
    if per_layer:
        if pacing.layer_bits is None:
            raise ValueError(
                "per_layer needs pacing.layer_bits — build the pacing "
                "with make_pacing(..., layer_widths=...)")
        # cost[l, i, j] in bits per unit keep fraction: layer l's total
        # bits split over its pairs by halo rows (Σ cost == d_full)
        total_rows = jnp.maximum(jnp.sum(rows), 1.0)
        cost = pacing.layer_bits[:, None, None] * rows[None] / total_rows
        live = jnp.broadcast_to(live[None], cost.shape)
        rows_fill, shape = cost, cost.shape
    else:
        # bits of one train step per unit of Σ rows·y (fwd + bwd, widths)
        bits_per_rowkeep = pacing.d_full / \
            max(float(jnp.sum(rows)), 1.0)
        rows_fill, shape = rows, (q, q)

    def init():
        return {"spent": jnp.zeros((), jnp.float32),
                "integ": jnp.zeros((), jnp.float32),
                "ema": rows_fill,
                "y": jnp.full(shape, y_min, jnp.float32)}

    def plan(state, step):
        bits, integ = allowance(pacing, state["spent"], state["integ"], step)
        # the monotone y makes every allocation a COMMITMENT for the rest
        # of the run, so cap this step by what the remaining budget can
        # sustain for the steps left — a transient PI spike must not ratchet
        # y to a level whose sustained cost exceeds the budget
        cap_bits = sustainable_cap(pacing, state["spent"], step, bits)
        cap = cap_bits if per_layer else cap_bits / bits_per_rowkeep
        density = jnp.where(live,
                            state["ema"] / jnp.maximum(rows_fill, 1e-30)
                            if per_layer else
                            state["ema"] / jnp.maximum(rows_fill, 1.0),
                            -jnp.inf)
        # prior commitments are the fill's floor → monotone by construction
        y = waterfill(density, rows_fill, cap, state["y"], 1.0)
        widths = None
        y_real = y
        if len(candidates) > 1:
            y_real, widths = refine_widths(y, candidates, live)
        rates = jnp.where(live, 1.0 / jnp.clip(y_real, y_min, 1.0), 1.0)
        skip = jnp.zeros((q, q), jnp.float32)
        plan_ = RatePlan(rates, skip, widths)
        return plan_, {**state, "integ": integ, "y": y}

    def observe(state, obs):
        # the measurement is this controller's whole reason to exist —
        # a missing key must fail loudly, not freeze the EMA silently
        key = "layer_err" if per_layer else "pair_err"
        err = jnp.asarray(obs[key], jnp.float32)
        return {**state,
                "spent": state["spent"] +
                jnp.asarray(obs["transport_bits"], jnp.float32),
                "ema": ema_decay * state["ema"] + (1.0 - ema_decay) * err}

    return RateController(name, init, observe, plan)
