"""``qos`` controller: per-pair rates water-filled from serving query mass.

The serving-side allocation mode (DESIGN.md §3.11): where the ``error``
controller spends the bit allowance where *training* loses the most
signal (measured dropped-block energy), this one spends it where
*queries* concentrate — each ordered pair's fill density is the EMA of
its observed **query mass** (queries landing on the receiving partition,
weighted by the pair's halo row count), so hot partitions' halos refresh
at the lowest rates / widest widths and cold pairs drop toward the floor.

Same budget machinery as the other controllers: the PI-paced allowance
(:func:`repro.dist.ratectl.base.allowance`) is water-filled over the
live pairs by query-mass density, and ``max_width < 32`` refines each
pair's allocation along the rate × width frontier exactly as the
``error`` controller does.  Unlike ``error``, the fill floor is NOT
monotone — query traffic moves, and serving has no Proposition-2
convergence argument to protect — so rates track the load both ways.

The measurement arrives through ``observe``'s optional ``query_mass``
key (``[Q, Q]``; :func:`repro.dist.halo.pair_query_mass` builds it from
the frontend's per-partition query counts).  A missing key leaves the
EMA untouched — at the halo-row prior the controller degenerates to the
``budget`` controller's uniform fill, so a *training* loop can run an
``auto:qos:<bits>`` policy unchanged.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.dist.ratectl.base import (Pacing, RateController, RatePlan,
                                     allowance, refine_widths, waterfill,
                                     width_candidates)

__all__ = ["qos_controller"]


def qos_controller(q: int, pacing: Pacing, pair_rows,
                   ema_decay: float = 0.8,
                   name: str = "qos",
                   per_layer: bool = False,
                   max_width: int = 32) -> RateController:
    """Query-mass-weighted per-pair controller (module docs).

    ``pair_rows`` is the static ``[Q, Q]`` halo row-count table
    (``DistMeta.pair_table()``): the water-filling's cost unit and the
    mass EMA's prior (uniform per-row density until queries arrive).

    State: ``{"spent", "integ", "mass"}`` — ``mass`` the ``[Q, Q]``
    query-mass EMA.

    Example::

        ctl = qos_controller(meta.q, pacing, meta.pair_table())
    """
    if per_layer:
        raise ValueError(
            "per-layer qos planning is not supported: query mass has no "
            "layer axis — use auto:qos:<bits> without :per-layer")
    rows = jnp.asarray(pair_rows, jnp.float32)
    eye = jnp.eye(q, dtype=bool)
    live = (rows > 0) & ~eye
    y_min = 1.0 / pacing.c_max
    candidates = width_candidates(max_width)
    # bits of one serve/train step per unit of Σ rows·y (see error ctl)
    bits_per_rowkeep = pacing.d_full / max(float(jnp.sum(rows)), 1.0)

    def init():
        return {"spent": jnp.zeros((), jnp.float32),
                "integ": jnp.zeros((), jnp.float32),
                "mass": rows}

    def plan(state, step):
        bits, integ = allowance(pacing, state["spent"], state["integ"],
                                step)
        cap = bits / bits_per_rowkeep
        density = jnp.where(live,
                            state["mass"] / jnp.maximum(rows, 1.0),
                            -jnp.inf)
        # non-monotone fill: traffic moves, the floor stays at y_min
        y = waterfill(density, rows, cap, y_min, 1.0)
        widths = None
        y_real = y
        if len(candidates) > 1:
            y_real, widths = refine_widths(y, candidates, live)
        rates = jnp.where(live, 1.0 / jnp.clip(y_real, y_min, 1.0), 1.0)
        skip = jnp.zeros((q, q), jnp.float32)
        return RatePlan(rates, skip, widths), {**state, "integ": integ}

    def observe(state, obs):
        # the isinstance guard used to shield only query_mass, so a
        # non-dict observation crashed one line earlier on
        # obs["transport_bits"] with a bare TypeError — fail with the
        # contract instead
        if not isinstance(obs, dict):
            raise TypeError(
                "qos observe() needs the step metrics dict "
                "(keys 'transport_bits' and optionally 'query_mass'); "
                f"got {type(obs).__name__}")
        out = {**state,
               "spent": state["spent"] +
               jnp.asarray(obs["transport_bits"], jnp.float32)}
        mass = obs.get("query_mass")
        if mass is not None:
            out["mass"] = ema_decay * state["mass"] + \
                (1.0 - ema_decay) * jnp.asarray(mass, jnp.float32)
        return out

    return RateController(name, init, observe, plan)
