"""``stale`` controller: skip unchanged pairs' hops, reuse cached halos.

DistGNN's delayed-aggregation result is the second lever on the wire
budget: when a pair's boundary activations barely moved since its last
exchange, *shipping nothing and reusing the receiver's cached halo rows*
costs far less accuracy than compressing fresh rows ever could.  This
controller runs the ``budget`` controller's PI-paced uniform rate for the
pairs that do communicate, and additionally skips pair ``(i, j)``'s hop
whenever its measured relative change — ``‖fresh − cached‖² / ‖fresh‖²``
from the step metrics — stayed below ``threshold``, bounded by a
**staleness cap**: after ``max_stale`` consecutive reuses the pair is
forced to refresh regardless, so no halo row is ever older than
``max_stale`` steps (the bounded-staleness condition delayed-aggregation
convergence analyses rely on).

Skipped pairs charge zero wire bits (forward and backward — the cached
rows are constants, no cotangent travels), and the PI loop automatically
re-spends the saved bits on lower rates for the refreshing pairs.  Hop
reuse is an emulated-backend feature of the p2p wire (a shape-uniform
SPMD ``ppermute`` cannot drop individual pairs' buffers; DESIGN.md §3.6).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.dist.ratectl.base import (Pacing, RateController, RatePlan,
                                     allowance, rate_of_allowance)


def stale_controller(q: int, pacing: Pacing, threshold: float = 0.05,
                     max_stale: int = 5,
                     name: str = "stale") -> RateController:
    """Staleness-reuse controller (module docs).

    State: ``{"spent", "integ", "age" [Q, Q] consecutive reuses,
    "skip" [Q, Q] next step's skip mask}``.

    Example::

        ctl = stale_controller(meta.q, pacing, threshold=0.05, max_stale=5)
    """
    eye = jnp.eye(q, dtype=bool)

    def init():
        return {"spent": jnp.zeros((), jnp.float32),
                "integ": jnp.zeros((), jnp.float32),
                "age": jnp.zeros((q, q), jnp.float32),
                "skip": jnp.zeros((q, q), jnp.float32)}

    def plan(state, step):
        bits, integ = allowance(pacing, state["spent"], state["integ"], step)
        rate = rate_of_allowance(pacing, bits)
        rates = jnp.where(eye, 1.0, rate)
        return RatePlan(rates, state["skip"]), {**state, "integ": integ}

    def observe(state, obs):
        delta = jnp.asarray(obs["pair_delta"], jnp.float32)
        # pairs served stale this step aged by one; refreshed pairs reset
        age = jnp.where(state["skip"] > 0.0, state["age"] + 1.0, 0.0)
        skip = ((delta <= threshold) & (age < max_stale) &
                ~eye).astype(jnp.float32)
        return {**state, "age": age, "skip": skip,
                "spent": state["spent"] +
                jnp.asarray(obs["transport_bits"], jnp.float32)}

    return RateController(name, init, observe, plan)
