"""``stale`` controller: skip unchanged pairs' hops, reuse cached halos.

DistGNN's delayed-aggregation result is the second lever on the wire
budget: when a pair's boundary activations barely moved since its last
exchange, *shipping nothing and reusing the receiver's cached halo rows*
costs far less accuracy than compressing fresh rows ever could.  This
controller runs the ``budget`` controller's PI-paced uniform rate for the
pairs that do communicate, and additionally skips pair ``(i, j)``'s hop
whenever its measured relative change — ``‖fresh − cached‖² / ‖fresh‖²``
from the step metrics — stayed below ``threshold``, bounded by a
**staleness cap**: after ``max_stale`` consecutive reuses the pair is
forced to refresh regardless, so no halo row is ever older than
``max_stale`` steps (the bounded-staleness condition delayed-aggregation
convergence analyses rely on).

Skipped pairs charge zero wire bits (forward and backward — the cached
rows are constants, no cotangent travels), and the PI loop automatically
re-spends the saved bits on lower rates for the refreshing pairs.  Hop
reuse is an emulated-backend feature of the p2p wire (a shape-uniform
SPMD ``ppermute`` cannot drop individual pairs' buffers; DESIGN.md §3.6).

``per_layer=True`` (DESIGN.md §3.7) runs the communicating pairs at
per-layer rates — the ``budget`` controller's dropped-energy water-fill
over layers, monotone per layer — while the skip logic stays per pair: a
skipped pair's hop is served from cache at *every* layer's exchange.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.dist.ratectl.base import (Pacing, RateController, RatePlan,
                                     allowance, fold_layer_err,
                                     init_layer_fill, plan_layer_fill,
                                     rate_of_allowance, uniform_layer_plan,
                                     width_cost, widths_map)


def drift_skip(delta, age, threshold: float, max_stale: int):
    """The halo-drift gating predicate, shared between training and
    serving: pair ``(i, j)`` may be served from cache (skip == 1) iff its
    measured relative drift ``delta[i, j] = ‖fresh − cached‖² / ‖fresh‖²``
    stayed at or below ``threshold`` AND the pair has been reused fewer
    than ``max_stale`` consecutive times (``age``).  The diagonal never
    skips (local rows never hit the wire).

    This is the exact predicate :func:`stale_controller`'s ``observe``
    applies between train steps; ``repro.serve.cache`` reuses it verbatim
    for drift-gated cache invalidation (DESIGN.md §3.11) — the
    shared-predicate property test in tests/test_serve.py pins the two
    call sites to this one function.

    Returns the ``[Q, Q]`` float32 0/1 skip mask.
    """
    delta = jnp.asarray(delta, jnp.float32)
    age = jnp.asarray(age, jnp.float32)
    eye = jnp.eye(delta.shape[-1], dtype=bool)
    return ((delta <= threshold) & (age < max_stale) &
            ~eye).astype(jnp.float32)


def stale_controller(q: int, pacing: Pacing, threshold: float = 0.05,
                     max_stale: int = 5, name: str = "stale",
                     per_layer: bool = False,
                     ema_decay: float = 0.8,
                     max_width: int = 32) -> RateController:
    """Staleness-reuse controller (module docs).

    State: ``{"spent", "integ", "age" [Q, Q] consecutive reuses,
    "skip" [Q, Q] next step's skip mask}``; ``per_layer=True`` adds the
    ``budget`` controller's per-layer machinery (``{"ema", "y"}`` over
    ``[L]``; needs ``pacing.layer_bits``).

    ``max_width < 32`` runs every *communicating* pair's wire at that
    width flat (skipped pairs ship nothing either way): hop reuse and
    error feedback both key residual state off the exchange cache, so
    the stale controller keeps the width axis static rather than joining
    the water-fill (stale-XOR-error-feedback, DESIGN.md §3.8); the
    cheaper wire simply lets the PI pacing afford lower rates.

    Example::

        ctl = stale_controller(meta.q, pacing, threshold=0.05, max_stale=5)
    """
    if per_layer and pacing.layer_bits is None:
        raise ValueError(
            "per_layer needs pacing.layer_bits — build the pacing with "
            "make_pacing(..., layer_widths=layer_exchange_widths(cfg))")
    eye = jnp.eye(q, dtype=bool)
    wmap = None if max_width >= 32 else widths_map(q, float(max_width))
    w_cost = width_cost(max_width)

    def init():
        state = {"spent": jnp.zeros((), jnp.float32),
                 "integ": jnp.zeros((), jnp.float32),
                 "age": jnp.zeros((q, q), jnp.float32),
                 "skip": jnp.zeros((q, q), jnp.float32)}
        if per_layer:
            state.update(init_layer_fill(pacing))
        return state

    def plan(state, step):
        if not per_layer:
            bits, integ = allowance(pacing, state["spent"], state["integ"],
                                    step)
            rate = rate_of_allowance(pacing, bits / w_cost)
            rates = jnp.where(eye, 1.0, rate)
            return RatePlan(rates, state["skip"], wmap), \
                {**state, "integ": integ}
        rates_l, integ, y = plan_layer_fill(pacing, state, step,
                                            cost_factor=w_cost)
        plan_ = uniform_layer_plan(q, rates_l)
        return RatePlan(plan_.rates, state["skip"], wmap), \
            {**state, "integ": integ, "y": y}

    def observe(state, obs):
        delta = jnp.asarray(obs["pair_delta"], jnp.float32)
        # pairs served stale this step aged by one; refreshed pairs reset
        age = jnp.where(state["skip"] > 0.0, state["age"] + 1.0, 0.0)
        skip = drift_skip(delta, age, threshold, max_stale)
        out = {**state, "age": age, "skip": skip,
               "spent": state["spent"] +
               jnp.asarray(obs["transport_bits"], jnp.float32)}
        if per_layer:
            out.update(fold_layer_err(state, obs, ema_decay))
        return out

    return RateController(name, init, observe, plan)
