"""Mesh/sharding specs for the transformer workloads (DESIGN.md §5).

Two surfaces:

* **Rules** — :func:`param_spec` / :func:`cache_spec` map a parameter path +
  shape (or a cache layout) to a :class:`PartitionSpec` under the production
  ``(data, model)`` or multi-pod ``(pod, data, model)`` meshes.  Every
  assignment is divisibility-guarded: a dim that doesn't divide its mesh
  axis group is replicated rather than unevenly split (e.g. 8 KV heads on a
  16-way model axis).
* **Activation constraints** — :func:`maybe_shard` applies
  ``with_sharding_constraint`` hints *only* inside an
  :func:`activation_sharding` context, so the same model code runs
  unannotated on a bare CPU device and fully constrained under the dry-run
  meshes.  Axis names absent from the active mesh (e.g. ``pod`` on a
  single-pod mesh) are silently dropped.

The rule choices encode the experiments' hard-won layout decisions
(EXPERIMENTS.md §Perf iterations): vocab tables shard over ``model`` only
(2-D-sharded tables defeat GSPMD sparse lookup), MoE expert parallelism
lives on the ``data`` axis (single-axis dispatch all-to-all), and the
``pod`` axis joins ``data`` for parameter/batch sharding.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_ctx = threading.local()


def _current_mesh():
    return getattr(_ctx, "mesh", None)


@contextlib.contextmanager
def activation_sharding(mesh):
    """Enable :func:`maybe_shard` constraints against ``mesh`` while tracing.

    Example::

        with activation_sharding(mesh):
            compiled = jax.jit(step).lower(params, opt_state, batch).compile()
    """
    prev = getattr(_ctx, "mesh", None)
    _ctx.mesh = mesh
    try:
        yield mesh
    finally:
        _ctx.mesh = prev


# ---------------------------------------------------------------------------
# Axis helpers
# ---------------------------------------------------------------------------


def data_axes(mesh) -> tuple:
    """The batch-parallel axis group: ``(pod, data)`` filtered to the mesh.

    Example: ``data_axes(make_multipod_mesh()) == ("pod", "data")`` while a
    single-pod ``(data, model)`` mesh gives ``("data",)``.
    """
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= int(mesh.shape[a])
    return n


def dispatch_groups() -> int:
    """Token groups for MoE dispatch = active data-parallel degree (or 1).

    Only meaningful inside :func:`activation_sharding`; model code calls it
    to pick the all-to-all group count, e.g.
    ``tokens.reshape(dispatch_groups(), -1, d)``.
    """
    mesh = _current_mesh()
    if mesh is None:
        return 1
    return max(_size(mesh, data_axes(mesh)), 1)


def batch_spec(mesh) -> P:
    """Leading-dim batch sharding over the data axis group.

    Example::

        tokens = jax.device_put(tokens, NamedSharding(mesh, batch_spec(mesh)))
    """
    d = data_axes(mesh)
    return P(d) if d else P()


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------


def maybe_shard(x, *dims):
    """Constrain ``x``'s layout, one entry per dim (name, tuple, or None).

    No-op outside an :func:`activation_sharding` context.  Entries naming
    axes absent from the active mesh, or groups that don't divide the dim,
    degrade to replicated.

    Example (activations ``[batch, seq, d_model]``)::

        h = maybe_shard(h, ("pod", "data"), None, "model")
    """
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = []
    for i, d in enumerate(dims):
        if d is None:
            spec.append(None)
            continue
        axes = (d,) if isinstance(d, str) else tuple(d)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes or _size(mesh, axes) <= 1 or \
                x.shape[i] % _size(mesh, axes) != 0:
            spec.append(None)
        else:
            spec.append(axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------


def param_spec(path: str, shape, mesh) -> P:
    """PartitionSpec for the parameter at ``path`` (``/``-joined pytree keys).

    Rules (megatron-style TP over ``model``, FSDP-style weight sharding over
    the ``(pod, data)`` group; every assignment divisibility-guarded):

    * norms / 1-D / unrecognised 2-D      -> replicated
    * ``embed [V, d]``, ``lm_head [d, V]`` -> vocab over ``model`` only
    * attention ``wq/wk/wv [L, d, h, dh]`` -> d over data, heads over model
    * attention ``wo [L, h, dh, d]``       -> heads over model, d over data
    * MoE experts ``[L, E, a, b]``         -> E over data, d_expert over model
    * generic 3-D ``[L, d_in, d_out]``     -> column-parallel (down
      projections named ``w_down`` are row-parallel)

    Example: ``param_spec("blocks/attn/wq", (32, 4096, 32, 128), mesh)``
    returns ``P(None, ("pod", "data"), "model", None)`` on a multi-pod mesh.
    """
    name = path.split("/")[-1]
    rank = len(shape)
    data = data_axes(mesh)
    model = ("model",) if "model" in mesh.axis_names else ()
    spec = [None] * rank

    def assign(dim, axes):
        axes = tuple(axes)
        while axes and (_size(mesh, axes) <= 1
                        or shape[dim] % _size(mesh, axes) != 0):
            axes = axes[1:]                    # shrink the group, keep inner
        if axes and _size(mesh, axes) > 1:
            spec[dim] = axes

    if rank == 0 or "norm" in name or rank == 1:
        return P(*spec)
    if name == "embed":
        assign(0, model)                       # vocab over model ONLY
        return P(*spec)
    if name == "lm_head":
        assign(1, model)
        return P(*spec)
    if name == "router":
        return P(*spec)                        # tiny; replicate
    if rank == 4 and name in ("wq", "wk", "wv"):
        assign(1, data)
        assign(2, model)                       # query/kv heads
        return P(*spec)
    if rank == 4 and name == "wo":
        assign(1, model)
        assign(3, data)
        return P(*spec)
    if rank == 4:                              # stacked MoE experts [L,E,a,b]
        assign(1, data)                        # expert parallel on data axis
        assign(2 if name == "w_down" else 3, model)
        return P(*spec)
    if rank == 3:
        if name == "w_down":                   # row-parallel [L, f, d]
            assign(1, model)
            assign(2, data)
        else:                                  # column-parallel [L, d, f]
            assign(1, data)
            assign(2, model)
        return P(*spec)
    return P(*spec)                            # unknown 2-D: replicate


def param_shardings(params, mesh):
    """NamedSharding pytree for a parameter (or optimizer-state) pytree.

    Example::

        p_sh = param_shardings(jax.eval_shape(init_fn), mesh)
        fn = jax.jit(step, in_shardings=(p_sh, ...), out_shardings=(p_sh, ...))
    """

    def _path_str(path) -> str:
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            elif hasattr(k, "name"):
                parts.append(str(k.name))
            else:
                parts.append(str(k))
        return "/".join(parts)

    return jax.tree_util.tree_map_with_path(
        lambda path, x: NamedSharding(mesh,
                                      param_spec(_path_str(path), x.shape,
                                                 mesh)),
        params)


# ---------------------------------------------------------------------------
# Partition-parallel graph rules
# ---------------------------------------------------------------------------


def worker_graph_shardings(graph: dict, mesh, axis: str = "workers") -> dict:
    """NamedSharding per graph-pytree leaf for the GNN runtime.

    Every array in the padded graph layout — node features/labels/masks,
    edge lists, halo send lists, and the p2p per-pair index sets of
    ``repro.dist.halo`` — is stacked ``[Q, ...]``, so each leaf splits its
    leading partition dim over ``axis`` and is otherwise replicated.
    Validates that contract: a leaf whose leading dim doesn't match the
    mesh's ``axis`` size (e.g. an un-stacked host array slipped into the
    pytree) is rejected here with its key named, instead of surfacing as
    an opaque GSPMD shape error inside ``shard_map``.

    Example::

        shardings = worker_graph_shardings(graph, mesh)
        graph = {k: jax.device_put(v, shardings[k]) for k, v in graph.items()}
    """
    q = int(mesh.shape[axis])
    for k, v in graph.items():
        shape = getattr(v, "shape", ())
        if len(shape) == 0 or shape[0] != q:
            raise ValueError(
                f"graph leaf {k!r} has shape {tuple(shape)}; expected a "
                f"stacked [Q, ...] array with Q == mesh {axis!r} size {q}")
    sh = NamedSharding(mesh, P(axis))
    return {k: sh for k in graph}


# ---------------------------------------------------------------------------
# KV / SSM cache rules
# ---------------------------------------------------------------------------


def cache_spec(shape, mesh, batch_dim: int | None = None,
               seq_dim: int | None = None,
               head_dim: int | None = None) -> P:
    """Cache layout: heads over ``model`` when they divide, else the
    sequence dim absorbs ``model``; batch over ``data`` when it divides,
    else (batch=1 long-context) the sequence dim takes the data group too.

    Example (KV cache ``[batch, seq, kv_heads, head_dim]``)::

        spec = cache_spec(kv.shape, mesh, batch_dim=0, seq_dim=1, head_dim=2)
    """
    spec = [None] * len(shape)
    data = data_axes(mesh)
    dsize = _size(mesh, data)
    model = ("model",) if "model" in mesh.axis_names else ()
    msize = _size(mesh, model) if model else 1
    model_free = bool(model) and msize > 1

    if head_dim is not None and model_free and shape[head_dim] % msize == 0:
        spec[head_dim] = model
        model_free = False
    if batch_dim is not None and dsize > 1 and shape[batch_dim] % dsize == 0:
        spec[batch_dim] = data
        if seq_dim is not None and model_free and shape[seq_dim] % msize == 0:
            spec[seq_dim] = model
    elif seq_dim is not None:
        group = data + (model if model_free else ())
        while group and (_size(mesh, group) <= 1
                         or shape[seq_dim] % _size(mesh, group) != 0):
            group = group[1:]
        if group and _size(mesh, group) > 1:
            spec[seq_dim] = group
    return P(*spec)
