"""Graph substrate: CSR containers, synthetic datasets, partitioning —
in-memory (``partition``) and out-of-core streaming (``stream``)."""

from .data import GraphData, from_edge_list, normalized_edge_weights
from .partition import (PartitionedGraph, build_partitioned, edge_cut_stats,
                        greedy_partition, partition_graph, random_partition,
                        refine_partition)
from .stream import (GraphStore, ShardSet, load_graph_store, load_shards,
                     open_store, stream_edge_cut, stream_partition,
                     write_graph_store, write_shards)
from .synthetic import (citation_graph, copurchase_graph, load,
                        stream_powerlaw_graph, stream_sbm_graph, tiny_graph)

__all__ = [
    "GraphData", "from_edge_list", "normalized_edge_weights",
    "PartitionedGraph", "build_partitioned", "edge_cut_stats",
    "greedy_partition", "partition_graph", "random_partition",
    "refine_partition",
    "GraphStore", "ShardSet", "load_graph_store", "load_shards",
    "open_store", "stream_edge_cut", "stream_partition",
    "write_graph_store", "write_shards",
    "citation_graph", "copurchase_graph", "load", "stream_powerlaw_graph",
    "stream_sbm_graph", "tiny_graph",
]
