"""Graph substrate: CSR containers, synthetic datasets, partitioning."""

from .data import GraphData, from_edge_list, normalized_edge_weights
from .partition import (PartitionedGraph, build_partitioned, edge_cut_stats,
                        greedy_partition, partition_graph, random_partition)
from .synthetic import citation_graph, copurchase_graph, load, tiny_graph

__all__ = [
    "GraphData", "from_edge_list", "normalized_edge_weights",
    "PartitionedGraph", "build_partitioned", "edge_cut_stats",
    "greedy_partition", "partition_graph", "random_partition",
    "citation_graph", "copurchase_graph", "load", "tiny_graph",
]
