"""Graph containers: CSR graphs with node features/labels/splits.

Plain numpy on the host (graphs are preprocessing-side data); device arrays
are produced by the partitioner (`repro.graph.partition`) in padded,
shard_map-ready layouts.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class GraphData:
    """An undirected graph in CSR form with node features and labels.

    ``indptr``/``indices`` encode, for each destination node ``i``, the
    source neighbours ``indices[indptr[i]:indptr[i+1]]`` (symmetric for
    undirected graphs).  Self-loops are not stored; convolutions add the
    self term explicitly.
    """

    indptr: np.ndarray      # [n+1] int64
    indices: np.ndarray     # [num_edges] int32 (directed edge count)
    features: np.ndarray    # [n, F] float32
    labels: np.ndarray      # [n] int32
    train_mask: np.ndarray  # [n] bool
    val_mask: np.ndarray    # [n] bool
    test_mask: np.ndarray   # [n] bool
    name: str = "graph"

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        """Directed edge count (2x undirected)."""
        return len(self.indices)

    @property
    def feat_dim(self) -> int:
        return self.features.shape[1]

    @property
    def num_classes(self) -> int:
        return int(self.labels.max()) + 1

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    def edge_list(self) -> tuple[np.ndarray, np.ndarray]:
        """(dst, src) arrays of all directed edges."""
        dst = np.repeat(np.arange(self.num_nodes, dtype=np.int32),
                        np.diff(self.indptr))
        return dst, self.indices.astype(np.int32)

    def validate(self) -> None:
        n = self.num_nodes
        assert self.indptr[0] == 0 and self.indptr[-1] == len(self.indices)
        assert np.all(np.diff(self.indptr) >= 0)
        assert self.indices.min(initial=0) >= 0
        assert self.indices.max(initial=-1) < n
        assert self.features.shape[0] == n
        assert self.labels.shape == (n,)
        for m in (self.train_mask, self.val_mask, self.test_mask):
            assert m.shape == (n,) and m.dtype == bool
        # splits disjoint
        assert not np.any(self.train_mask & self.val_mask)
        assert not np.any(self.train_mask & self.test_mask)
        assert not np.any(self.val_mask & self.test_mask)


def from_edge_list(n: int, dst: np.ndarray, src: np.ndarray,
                   features: np.ndarray, labels: np.ndarray,
                   splits=(0.6, 0.2, 0.2), seed: int = 0,
                   name: str = "graph") -> GraphData:
    """Build a symmetric CSR GraphData from a directed edge list.

    The edge list is symmetrised and deduplicated; self-loops dropped.
    """
    dst = np.asarray(dst, np.int64)
    src = np.asarray(src, np.int64)
    keep = dst != src
    dst, src = dst[keep], src[keep]
    # symmetrise + dedup via a packed key
    a = np.concatenate([dst, src])
    b = np.concatenate([src, dst])
    key = a * n + b
    key = np.unique(key)
    a = (key // n).astype(np.int64)
    b = (key % n).astype(np.int32)
    order = np.argsort(a, kind="stable")
    a, b = a[order], b[order]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, a + 1, 1)
    indptr = np.cumsum(indptr)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_train = int(splits[0] * n)
    n_val = int(splits[1] * n)
    train_mask = np.zeros(n, bool)
    val_mask = np.zeros(n, bool)
    test_mask = np.zeros(n, bool)
    train_mask[perm[:n_train]] = True
    val_mask[perm[n_train:n_train + n_val]] = True
    test_mask[perm[n_train + n_val:]] = True
    g = GraphData(indptr, b, np.asarray(features, np.float32),
                  np.asarray(labels, np.int32), train_mask, val_mask,
                  test_mask, name=name)
    g.validate()
    return g


def normalized_edge_weights(g: GraphData, kind: str = "mean") -> np.ndarray:
    """Per-directed-edge weights for the aggregation.

    ``mean``: 1/deg(dst)  (GraphSAGE mean aggregator)
    ``sym``:  1/sqrt(deg(dst) deg(src))  (GCN normalisation; eq. (2) with
              S = D^-1/2 A D^-1/2)
    """
    deg = np.maximum(g.degrees(), 1).astype(np.float32)
    dst, src = g.edge_list()
    if kind == "mean":
        return 1.0 / deg[dst]
    if kind == "sym":
        return 1.0 / np.sqrt(deg[dst] * deg[src])
    raise ValueError(f"unknown normalisation {kind!r}")
