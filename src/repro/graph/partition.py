"""Graph partitioning + halo construction for partition-parallel training.

Partitioners
------------
* ``random_partition`` — the paper's random scheme (method must work here).
* ``greedy_partition`` — METIS-like min-cut: linear deterministic greedy
  (LDG) streaming over a BFS order with capacity constraints.  METIS itself
  is unavailable offline; LDG reproduces the property Table I measures —
  far fewer cross edges than random — which is all the experiments need.

``PartitionedGraph`` lowers a partitioned graph into padded, stacked
``[Q, ...]`` numpy arrays ready to be sharded over the ``workers`` mesh axis
by ``repro.dist.gnn_parallel``:

* per-partition local edges (both endpoints owned),
* per-partition remote edges whose source indexes a *halo buffer* — the
  all-gathered boundary activations ``[Q, B, F]`` flattened to ``[Q*B, F]``,
* the send list: which local nodes each worker publishes per layer.

Byte accounting: ``halo_demand`` counts distinct (requesting partition,
remote node) pairs — the activations a P2P implementation would ship each
layer; the ledger charges ``demand × F × bits / rate`` per exchange, which
is the paper's "floats communicated ∝ cross edges / compression" axis.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from .data import GraphData, normalized_edge_weights


# ---------------------------------------------------------------------------
# Partitioners
# ---------------------------------------------------------------------------


def random_partition(g: GraphData, q: int, seed: int = 0) -> np.ndarray:
    """Equal-size random assignment (paper's random partitioning)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.num_nodes)
    owner = np.empty(g.num_nodes, np.int32)
    for i in range(q):
        owner[perm[i::q]] = i
    return owner


def _canonical_rows(g: GraphData, weight: np.ndarray | None = None):
    """Within-row ascending copy of the CSR (weights permuted alongside).

    The streaming pipeline (``repro.graph.stream``) presents each row's
    neighbours in whatever order its chunks arrived; sorting rows first
    makes the BFS order of :func:`greedy_partition` and the weighted
    neighbour sums of :func:`refine_partition` invariant to edge
    presentation order.  A no-op (bitwise) for :func:`from_edge_list`
    graphs, whose rows are already ascending.
    """
    indptr, indices = g.indptr, g.indices
    rows = np.repeat(np.arange(g.num_nodes, dtype=np.int64),
                     np.diff(indptr))
    order = np.lexsort((indices, rows))
    return indptr, indices[order], None if weight is None else weight[order]


def greedy_partition(g: GraphData, q: int, seed: int = 0,
                     slack: float = 1.03) -> np.ndarray:
    """METIS-like streaming min-cut (LDG) over a BFS node order."""
    n = g.num_nodes
    rng = np.random.default_rng(seed)
    capacity = slack * n / q
    owner = np.full(n, -1, np.int32)
    sizes = np.zeros(q, np.float64)
    indptr, indices, _ = _canonical_rows(g)

    order = np.empty(n, np.int64)
    pos = 0
    visited = np.zeros(n, bool)
    for start in rng.permutation(n):
        if visited[start]:
            continue
        dq = deque([start])
        visited[start] = True
        while dq:
            u = dq.popleft()
            order[pos] = u
            pos += 1
            for v in indices[indptr[u]:indptr[u + 1]]:
                if not visited[v]:
                    visited[v] = True
                    dq.append(v)
    assert pos == n

    counts = np.zeros(q, np.float64)
    for u in order:
        counts[:] = 0.0
        neigh = indices[indptr[u]:indptr[u + 1]]
        if len(neigh):
            owned = owner[neigh]
            owned = owned[owned >= 0]
            if len(owned):
                np.add.at(counts, owned, 1.0)
        score = counts * np.maximum(1.0 - sizes / capacity, 0.0)
        best = int(np.argmax(score))
        if score[best] <= 0.0:  # no placed neighbours / all parts look full
            best = int(np.argmin(sizes))
        owner[u] = best
        sizes[best] += 1.0
    return owner


def refine_partition(g: GraphData, owner: np.ndarray, q: int,
                     passes: int = 4, slack: float = 1.05, seed: int = 0,
                     node_weight: np.ndarray | None = None,
                     edge_weight: np.ndarray | None = None) -> np.ndarray:
    """Kernighan-Lin-style local refinement: greedily move nodes to the
    partition holding most of their neighbours, subject to balance.

    ``node_weight``/``edge_weight`` (per node / per directed edge, in
    ``g.edge_list()`` order) weight the balance constraint and the
    neighbour affinity — the coarse levels of the multilevel streaming
    partitioner (``repro.graph.stream``), where each node is a cluster
    and each edge a multi-edge bundle.  ``None`` (the default) reproduces
    the unweighted behaviour exactly.  Rows are sorted before refining
    (:func:`_canonical_rows`), so the result is invariant to the order
    edges were presented in.
    """
    n = g.num_nodes
    rng = np.random.default_rng(seed)
    owner = owner.copy()
    indptr, indices, ew = _canonical_rows(g, edge_weight)
    if node_weight is None:
        capacity = slack * n / q
        sizes = np.bincount(owner, minlength=q).astype(np.float64)
    else:
        node_weight = np.asarray(node_weight, np.float64)
        capacity = slack * float(node_weight.sum()) / q
        sizes = np.bincount(owner, weights=node_weight, minlength=q)
    counts = np.zeros(q, np.float64)
    for _ in range(passes):
        moved = 0
        for u in rng.permutation(n):
            row = slice(indptr[u], indptr[u + 1])
            neigh = indices[row]
            if len(neigh) == 0:
                continue
            counts[:] = 0.0
            np.add.at(counts, owner[neigh],
                      1.0 if ew is None else ew[row])
            cur = owner[u]
            cur_count = counts[cur]
            counts[sizes >= capacity] = -np.inf
            # staying put is always feasible: restore the true neighbour
            # count of the current partition so a move happens only when it
            # is strictly better (keep-current tie-breaking).  Moving to the
            # argmax then strictly reduces u's cut edges, so a refinement
            # pass can never increase the total edge cut.
            counts[cur] = cur_count
            best = int(np.argmax(counts))
            if best != cur and counts[best] > counts[cur]:
                w_u = 1.0 if node_weight is None else node_weight[u]
                owner[u] = best
                sizes[cur] -= w_u
                sizes[best] += w_u
                moved += 1
        if moved == 0:
            break
    return owner


def metis_like_partition(g: GraphData, q: int, seed: int = 0,
                         slack: float = 1.03) -> np.ndarray:
    """LDG streaming + KL refinement — our offline METIS stand-in."""
    owner = greedy_partition(g, q, seed=seed, slack=slack)
    return refine_partition(g, owner, q, seed=seed)


PARTITIONERS = {"random": random_partition, "metis-like": metis_like_partition}


def edge_cut_stats(g: GraphData, owner: np.ndarray) -> dict:
    """Table-I statistics: self vs cross directed edge counts."""
    dst, src = g.edge_list()
    cross = owner[dst] != owner[src]
    n_cross = int(cross.sum())
    n_self = len(dst) - n_cross
    return {
        "self_edges": n_self,
        "cross_edges": n_cross,
        "self_frac": n_self / max(len(dst), 1),
        "cross_frac": n_cross / max(len(dst), 1),
    }


# ---------------------------------------------------------------------------
# Partitioned, padded device layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PartitionedGraph:
    """Padded ``[Q, ...]`` arrays for shard_map partition-parallel training."""

    q: int
    part_size: int            # P: padded nodes per partition
    halo_size: int            # B: padded boundary (published) nodes per part
    num_nodes: int
    feat_dim: int
    num_classes: int
    halo_demand: int          # distinct (partition, remote node) pairs
    cross_edges: int

    owner: np.ndarray         # [n] partition of each global node
    local_index: np.ndarray   # [n] index of each global node in its partition

    features: np.ndarray      # [Q, P, F]
    labels: np.ndarray        # [Q, P] int32 (pad 0)
    train_mask: np.ndarray    # [Q, P] bool (pad False)
    val_mask: np.ndarray      # [Q, P] bool
    test_mask: np.ndarray     # [Q, P] bool
    node_valid: np.ndarray    # [Q, P] bool

    # local edges: dst/src are partition-local; pad dst -> P (dropped row)
    local_dst: np.ndarray     # [Q, El] int32
    local_src: np.ndarray     # [Q, El] int32
    local_w: np.ndarray       # [Q, El] f32 (global-degree normalisation)
    local_w_iso: np.ndarray   # [Q, El] f32 (local-degree norm; No-Comm mode)

    # remote edges: src indexes flattened halo buffer [Q*B]
    remote_dst: np.ndarray    # [Q, Er] int32 (pad -> P)
    remote_src: np.ndarray    # [Q, Er] int32 (pad -> 0)
    remote_w: np.ndarray      # [Q, Er] f32

    # publish list: local node indices each worker sends every layer
    send_idx: np.ndarray      # [Q, B] int32 (pad 0)
    send_valid: np.ndarray    # [Q, B] f32 (1 valid / 0 pad)

    def remote_pair_table(self):
        """Decode the flat ``remote_src`` halo indices per remote edge.

        Returns ``(valid [Q, Er] bool, src_part [Q, Er] int32, slot
        [Q, Er] int32)`` — which peer partition and boundary slot each
        remote edge reads (padding rows have ``remote_w == 0`` and are
        masked out of ``valid``).  This is the raw material for the
        per-pair p2p halo specs (``repro.dist.halo``).
        """
        valid = self.remote_w > 0
        src_part = (self.remote_src // self.halo_size).astype(np.int32)
        slot = (self.remote_src % self.halo_size).astype(np.int32)
        return valid, src_part, slot

    def device_arrays(self):
        """The pytree handed to the distributed train step."""
        import jax.numpy as jnp
        return {
            "features": jnp.asarray(self.features),
            "labels": jnp.asarray(self.labels),
            "train_mask": jnp.asarray(self.train_mask),
            "val_mask": jnp.asarray(self.val_mask),
            "test_mask": jnp.asarray(self.test_mask),
            "node_valid": jnp.asarray(self.node_valid),
            "local_dst": jnp.asarray(self.local_dst),
            "local_src": jnp.asarray(self.local_src),
            "local_w": jnp.asarray(self.local_w),
            "local_w_iso": jnp.asarray(self.local_w_iso),
            "remote_dst": jnp.asarray(self.remote_dst),
            "remote_src": jnp.asarray(self.remote_src),
            "remote_w": jnp.asarray(self.remote_w),
            "send_idx": jnp.asarray(self.send_idx),
            "send_valid": jnp.asarray(self.send_valid),
        }


def _pad_rows(rows: list[np.ndarray], pad_value, width: int | None = None,
              dtype=None) -> np.ndarray:
    width = max((len(r) for r in rows), default=1) if width is None else width
    width = max(width, 1)
    out = np.full((len(rows), width), pad_value,
                  dtype or np.asarray(rows[0]).dtype)
    for i, r in enumerate(rows):
        out[i, :len(r)] = r
    return out


def partition_graph(g: GraphData, q: int, scheme: str = "random",
                    norm: str = "mean", seed: int = 0) -> PartitionedGraph:
    """Partition ``g`` into ``q`` workers and build the padded halo layout."""
    owner = PARTITIONERS[scheme](g, q, seed=seed)
    return build_partitioned(g, owner, q, norm=norm)


def build_partitioned(g: GraphData, owner: np.ndarray, q: int,
                      norm: str = "mean") -> PartitionedGraph:
    n = g.num_nodes
    weights = normalized_edge_weights(g, kind=norm)
    dst, src = g.edge_list()
    e_owner_dst = owner[dst]
    e_owner_src = owner[src]
    is_local = e_owner_dst == e_owner_src

    # partition-local node numbering
    local_index = np.zeros(n, np.int32)
    part_nodes: list[np.ndarray] = []
    for p in range(q):
        nodes = np.flatnonzero(owner == p)
        local_index[nodes] = np.arange(len(nodes), dtype=np.int32)
        part_nodes.append(nodes)
    part_size = max(len(nodes) for nodes in part_nodes)

    # boundary (publish) sets: nodes with at least one cross out-edge.
    # undirected graph => a node needed remotely == has a cross edge.
    is_boundary = np.zeros(n, bool)
    cross_mask = ~is_local
    is_boundary[src[cross_mask]] = True
    send_rows, send_slot = [], np.full(n, -1, np.int32)
    for p in range(q):
        b_nodes = part_nodes[p][is_boundary[part_nodes[p]]]
        send_slot[b_nodes] = np.arange(len(b_nodes), dtype=np.int32)
        send_rows.append(local_index[b_nodes])
    halo_size = max((len(r) for r in send_rows), default=1)
    halo_size = max(halo_size, 1)

    # local-degree (isolated-subgraph) renormalisation for the No-Comm mode
    local_deg = np.zeros(n, np.int64)
    np.add.at(local_deg, dst[is_local], 1)
    if norm == "mean":
        w_iso_all = 1.0 / np.maximum(local_deg, 1).astype(np.float32)
        w_iso = w_iso_all[dst]
    else:  # sym
        d = np.maximum(local_deg, 1).astype(np.float32)
        w_iso = 1.0 / np.sqrt(d[dst] * d[src])

    local_dst_rows, local_src_rows, local_w_rows, local_wiso_rows = [], [], [], []
    remote_dst_rows, remote_src_rows, remote_w_rows = [], [], []
    demand = 0
    for p in range(q):
        mine = e_owner_dst == p
        loc = mine & is_local
        rem = mine & ~is_local
        local_dst_rows.append(local_index[dst[loc]])
        local_src_rows.append(local_index[src[loc]])
        local_w_rows.append(weights[loc].astype(np.float32))
        local_wiso_rows.append(w_iso[loc].astype(np.float32))
        r_src = src[rem]
        slot = send_slot[r_src]
        assert np.all(slot >= 0)
        flat = e_owner_src[rem].astype(np.int64) * halo_size + slot
        remote_dst_rows.append(local_index[dst[rem]])
        remote_src_rows.append(flat.astype(np.int32))
        remote_w_rows.append(weights[rem].astype(np.float32))
        demand += len(np.unique(r_src))

    def stack_nodes(values: np.ndarray, pad):
        out = np.full((q, part_size) + values.shape[1:], pad, values.dtype)
        for p in range(q):
            out[p, :len(part_nodes[p])] = values[part_nodes[p]]
        return out

    node_valid = np.zeros((q, part_size), bool)
    for p in range(q):
        node_valid[p, :len(part_nodes[p])] = True

    send_valid = np.zeros((q, halo_size), np.float32)
    for p in range(q):
        send_valid[p, :len(send_rows[p])] = 1.0

    cross_edges = int((~is_local).sum())
    return PartitionedGraph(
        q=q, part_size=part_size, halo_size=halo_size, num_nodes=n,
        feat_dim=g.feat_dim, num_classes=g.num_classes,
        halo_demand=demand, cross_edges=cross_edges,
        owner=owner, local_index=local_index,
        features=stack_nodes(g.features, 0.0),
        labels=stack_nodes(g.labels, 0),
        train_mask=stack_nodes(g.train_mask, False),
        val_mask=stack_nodes(g.val_mask, False),
        test_mask=stack_nodes(g.test_mask, False),
        node_valid=node_valid,
        local_dst=_pad_rows(local_dst_rows, part_size, dtype=np.int32),
        local_src=_pad_rows(local_src_rows, 0, dtype=np.int32),
        local_w=_pad_rows(local_w_rows, 0.0, dtype=np.float32),
        local_w_iso=_pad_rows(local_wiso_rows, 0.0, dtype=np.float32),
        remote_dst=_pad_rows(remote_dst_rows, part_size, dtype=np.int32),
        remote_src=_pad_rows(remote_src_rows, 0, dtype=np.int32),
        remote_w=_pad_rows(remote_w_rows, 0.0, dtype=np.float32),
        send_idx=_pad_rows(send_rows, 0, width=halo_size, dtype=np.int32),
        send_valid=send_valid,
    )
