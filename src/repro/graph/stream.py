"""Out-of-core streaming partition pipeline (DESIGN.md §3.9).

Everything upstream of this module assumes the whole graph fits in one
host's memory: ``partition_graph`` wants a materialised ``GraphData`` and
``build_partitioned`` stacks every partition's padded arrays at once.  The
paper's own experiments partition ogbn-papers100M (10⁸ nodes) into 16
parts *before* training ever starts — this module is that ingestion path:

* **GraphStore** — an on-disk chunked-CSR graph: row-range edge chunks
  (``edges_*.npz``: rebased ``indptr`` + ``indices`` [+ ``wgt``]) and
  node-range payload chunks (``nodes_*.npz``: features / labels / split
  masks), with a ``store.json`` manifest.  Reading is a bounded-memory
  iterator; nothing ever holds the full edge or feature set.
* **spill_to_store** — the external bucket sort that turns arbitrary
  streamed ``(dst, src[, wgt])`` pairs into a canonical chunked CSR
  (symmetrised by the emitter, deduplicated / self-loop-dropped /
  row-sorted per bucket) — the construction path of the streaming
  synthetic generators (``repro.graph.synthetic.stream_sbm_graph`` /
  ``stream_powerlaw_graph``) and of each coarsening level's contraction.
* **stream_partition** — the multilevel METIS-quality partitioner:
  chunked heavy-edge matching coarsens level by level (each coarse level
  is itself a weighted ``GraphStore``, spilled to disk until it fits),
  a weighted LDG + weighted ``refine_partition`` seeds the coarsest
  level, and uncoarsening projects owners down, re-running the existing
  :func:`repro.graph.partition.refine_partition` at every level small
  enough to load.  Graphs that fit in core (``in_core_nodes``) take the
  exact reduction: the assembled CSR is bit-identical to the in-memory
  graph, so the owner vector equals ``partition_graph``'s for any chunk
  size (property-pinned in tests/test_properties.py).
* **write_shards / load_shards** — the on-disk per-worker shard format:
  one ``part_*.npz`` per partition holding that worker's rows of every
  runtime array (feature/label slabs, local + remote edge lists, publish
  lists, and the precomputed p2p halo / ELL indices of
  ``repro.dist.halo``), plus a ``shards.json`` manifest carrying the
  serialised :class:`repro.dist.halo.HaloSpec` and the global ``DistMeta``
  facts — so a Q ≥ 16 worker loads only its own partition and
  ``repro.dist.gnn_parallel`` never touches the global graph.  Shard
  construction is itself streaming: two passes over the edge chunks into
  per-partition spill files, one pass over the node chunks into
  per-partition slabs, then one partition assembled (and released) at a
  time.

Memory contract: O(num_nodes) *per-node* scalar arrays (owner, degrees,
local index — the same arrays any distributed partition tool keeps) plus
O(chunk) buffers and O(max partition) assembly slabs are resident; the
O(num_edges) structure and the O(n·F) features never are.

Everything here is plain numpy — no jax at import time, so the RSS-probed
benchmark (benchmarks/partition_pipeline.py) measures the pipeline, not
an accelerator runtime.

Example::

    stream_sbm_graph(store_dir, n=1_000_000, feat_dim=64)
    store = open_store(store_dir)
    owner = stream_partition(store, q=16, scheme="metis-like")
    write_shards(store, owner, shard_dir)
    res = train_gnn(shard_dir, policy=CommPolicy.parse("fixed:4", 1),
                    wire="p2p")          # loads shards, never the graph
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile

import numpy as np

from .data import GraphData
from .partition import PARTITIONERS, refine_partition

# default chunk granularity: ~64k rows / ~1M directed edges per chunk keeps
# per-chunk buffers in the tens of MB at any feature width
CHUNK_NODES = 65536
CHUNK_EDGES = 1 << 20

_STORE_MANIFEST = "store.json"
_SHARD_MANIFEST = "shards.json"


# ---------------------------------------------------------------------------
# GraphStore: chunked CSR + node payload on disk
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GraphStore:
    """Manifest view of an on-disk chunked graph (see module docs).

    ``edge_rows[k] = (lo, hi)`` is edge chunk ``k``'s dst row range;
    ``indptr`` inside the chunk is rebased to 0.  ``has_nodes`` is False
    for coarse levels (contraction keeps structure only); ``weighted``
    marks per-edge ``wgt`` arrays (coarse multi-edge counts).
    """

    path: str
    num_nodes: int
    num_edges: int          # directed
    feat_dim: int
    num_classes: int
    name: str
    edge_rows: list
    node_rows: list
    has_nodes: bool = True
    weighted: bool = False

    def save_manifest(self) -> None:
        with open(os.path.join(self.path, _STORE_MANIFEST), "w") as fh:
            json.dump({k: getattr(self, k) for k in
                       ("num_nodes", "num_edges", "feat_dim", "num_classes",
                        "name", "edge_rows", "node_rows", "has_nodes",
                        "weighted")}, fh)

    def edge_chunks(self):
        """Yield ``(lo, hi, indptr, indices, wgt)`` per chunk; ``indptr``
        is rebased (``indptr[0] == 0``), ``wgt`` is None when unweighted."""
        for k, (lo, hi) in enumerate(self.edge_rows):
            with np.load(os.path.join(self.path, f"edges_{k:05d}.npz")) as z:
                yield (lo, hi, z["indptr"], z["indices"],
                       z["wgt"] if self.weighted else None)

    def node_chunks(self):
        """Yield ``(lo, hi, payload-dict)`` per node chunk."""
        for k, (lo, hi) in enumerate(self.node_rows):
            with np.load(os.path.join(self.path, f"nodes_{k:05d}.npz")) as z:
                yield lo, hi, {key: z[key] for key in z.files}

    def degrees(self) -> np.ndarray:
        """Streaming per-node degree (one pass over the indptr chunks)."""
        deg = np.zeros(self.num_nodes, np.int64)
        for lo, hi, indptr, _, _ in self.edge_chunks():
            deg[lo:hi] = np.diff(indptr)
        return deg


def open_store(path: str | os.PathLike) -> GraphStore:
    with open(os.path.join(path, _STORE_MANIFEST)) as fh:
        m = json.load(fh)
    return GraphStore(path=str(path),
                      edge_rows=[tuple(r) for r in m.pop("edge_rows")],
                      node_rows=[tuple(r) for r in m.pop("node_rows")], **m)


def is_store(path) -> bool:
    return isinstance(path, (str, os.PathLike)) and \
        os.path.exists(os.path.join(path, _STORE_MANIFEST))


def _row_chunks(n: int, indptr: np.ndarray | None, chunk_nodes: int,
                chunk_edges: int) -> list[tuple[int, int]]:
    """Row ranges capped at ``chunk_nodes`` rows / ``chunk_edges`` edges
    (rows never split; a single huge row gets its own chunk)."""
    rows = []
    lo = 0
    while lo < n:
        hi = min(lo + chunk_nodes, n)
        if indptr is not None:
            # largest hi with indptr[hi] - indptr[lo] <= chunk_edges
            cap = int(np.searchsorted(indptr, indptr[lo] + chunk_edges,
                                      side="right")) - 1
            hi = max(min(hi, cap), lo + 1)
        rows.append((lo, hi))
        lo = hi
    return rows or [(0, 0)]


def write_graph_store(g: GraphData, path: str | os.PathLike,
                      chunk_nodes: int = CHUNK_NODES,
                      chunk_edges: int = CHUNK_EDGES) -> GraphStore:
    """Chunk an in-memory ``GraphData`` to disk — the exact inverse of
    :func:`load_graph_store` (CSR round-trips bitwise for any chunk size,
    property-pinned)."""
    path = str(path)
    os.makedirs(path, exist_ok=True)
    store = GraphStore(
        path=path, num_nodes=g.num_nodes, num_edges=g.num_edges,
        feat_dim=g.feat_dim, num_classes=g.num_classes, name=g.name,
        edge_rows=_row_chunks(g.num_nodes, g.indptr, chunk_nodes,
                              chunk_edges),
        node_rows=_row_chunks(g.num_nodes, None, chunk_nodes, chunk_edges))
    for k, (lo, hi) in enumerate(store.edge_rows):
        e0, e1 = int(g.indptr[lo]), int(g.indptr[hi])
        np.savez(os.path.join(path, f"edges_{k:05d}.npz"),
                 indptr=(g.indptr[lo:hi + 1] - e0).astype(np.int64),
                 indices=g.indices[e0:e1].astype(np.int32))
    for k, (lo, hi) in enumerate(store.node_rows):
        np.savez(os.path.join(path, f"nodes_{k:05d}.npz"),
                 features=g.features[lo:hi], labels=g.labels[lo:hi],
                 train_mask=g.train_mask[lo:hi],
                 val_mask=g.val_mask[lo:hi], test_mask=g.test_mask[lo:hi])
    store.save_manifest()
    return store


def load_graph_store(store: GraphStore) -> GraphData:
    """Assemble the full ``GraphData`` — the in-core escape hatch for
    graphs that fit (the exact-reduction path of :func:`stream_partition`
    and the equivalence tests).  O(num_edges) memory by construction."""
    if not store.has_nodes:
        raise ValueError("store has no node payload (coarse level?)")
    indptr = np.zeros(store.num_nodes + 1, np.int64)
    idx_parts, base = [], 0
    for lo, hi, iptr, idx, _ in store.edge_chunks():
        indptr[lo + 1:hi + 1] = iptr[1:] + base
        base += int(iptr[-1])
        idx_parts.append(idx)
    payload = {k: [] for k in ("features", "labels", "train_mask",
                               "val_mask", "test_mask")}
    for _, _, chunk in store.node_chunks():
        for k in payload:
            payload[k].append(chunk[k])
    return GraphData(indptr=indptr,
                     indices=np.concatenate(idx_parts) if idx_parts
                     else np.zeros(0, np.int32),
                     **{k: np.concatenate(v) for k, v in payload.items()},
                     name=store.name)


# ---------------------------------------------------------------------------
# External bucket sort: streamed (dst, src[, wgt]) pairs -> chunked CSR
# ---------------------------------------------------------------------------


class EdgeSpill:
    """Bounded-memory edge accumulator: ``add`` buckets incoming directed
    pairs by dst row range onto disk; ``to_store`` sorts each bucket into
    canonical CSR rows (dedup + self-loop drop + ascending neighbours —
    the :func:`repro.graph.data.from_edge_list` convention, applied one
    bucket at a time).  The emitter must send both directions of every
    undirected edge (symmetry is its contract, dedup is ours).
    """

    def __init__(self, n: int, workdir: str, bucket_nodes: int = CHUNK_NODES,
                 weighted: bool = False, drop_nonpositive: bool = False):
        self.n = n
        self.bucket_nodes = max(int(bucket_nodes), 1)
        self.n_buckets = max(-(-n // self.bucket_nodes), 1)
        self.weighted = weighted
        # signed-weight mode (streaming graph updates, repro.serve.update):
        # inserts spill +1, deletes -1; duplicate summing nets them out and
        # edges whose total lands ≤ 0 are dropped from the canonical rows
        self.drop_nonpositive = drop_nonpositive
        if drop_nonpositive and not weighted:
            raise ValueError("drop_nonpositive sums signed weights; "
                             "it needs weighted=True")
        self.dir = workdir
        os.makedirs(workdir, exist_ok=True)
        self._piece = [0] * self.n_buckets

    def add(self, dst: np.ndarray, src: np.ndarray,
            wgt: np.ndarray | None = None) -> None:
        dst = np.asarray(dst, np.int64)
        src = np.asarray(src, np.int64)
        b = dst // self.bucket_nodes
        order = np.argsort(b, kind="stable")
        b_sorted = b[order]
        bounds = np.searchsorted(b_sorted, np.arange(self.n_buckets + 1))
        for bk in np.unique(b_sorted):
            sel = order[bounds[bk]:bounds[bk + 1]]
            cols = [dst[sel].astype(np.int32), src[sel].astype(np.int32)]
            if self.weighted:
                w = np.ones(len(sel), np.float64) if wgt is None \
                    else np.asarray(wgt, np.float64)[sel]
                cols.append(w)
            np.savez(os.path.join(
                self.dir, f"b{bk:05d}_{self._piece[bk]:05d}.npz"),
                dst=cols[0], src=cols[1],
                **({"wgt": cols[2]} if self.weighted else {}))
            self._piece[bk] += 1

    def _bucket_rows(self, bk: int):
        """Load + canonicalise one bucket: unique (dst, src) ascending,
        self-loops dropped, weights summed over duplicates."""
        ds, ss, ws = [], [], []
        for p in range(self._piece[bk]):
            with np.load(os.path.join(self.dir,
                                      f"b{bk:05d}_{p:05d}.npz")) as z:
                ds.append(z["dst"])
                ss.append(z["src"])
                if self.weighted:
                    ws.append(z["wgt"])
        if not ds:
            return (np.zeros(0, np.int64), np.zeros(0, np.int32),
                    np.zeros(0, np.float64) if self.weighted else None)
        dst = np.concatenate(ds).astype(np.int64)
        src = np.concatenate(ss).astype(np.int64)
        keep = dst != src
        dst, src = dst[keep], src[keep]
        key = dst * self.n + src
        if self.weighted:
            w = np.concatenate(ws)[keep]
            ukey, inv = np.unique(key, return_inverse=True)
            wsum = np.zeros(len(ukey), np.float64)
            np.add.at(wsum, inv, w)
            if self.drop_nonpositive:
                alive = wsum > 0.0
                ukey, wsum = ukey[alive], wsum[alive]
        else:
            ukey, wsum = np.unique(key), None
        return (ukey // self.n, (ukey % self.n).astype(np.int32), wsum)

    def canonical_edges(self) -> tuple[np.ndarray, np.ndarray,
                                       np.ndarray | None]:
        """Concatenated canonical directed rows over all buckets:
        ``(dst, src, wsum | None)``, dst-major ascending, self-loops
        dropped, duplicates summed (and, under ``drop_nonpositive``,
        netted-out edges removed).  The in-memory counterpart of
        :meth:`to_store` for graphs that fit — ``repro.serve.update``
        rebuilds a :class:`repro.graph.data.GraphData` from these rows
        after an edge-update batch."""
        ds, ss, ws = [], [], []
        for bk in range(self.n_buckets):
            dst, src, wsum = self._bucket_rows(bk)
            ds.append(dst)
            ss.append(src)
            if self.weighted:
                ws.append(wsum)
        return (np.concatenate(ds), np.concatenate(ss),
                np.concatenate(ws) if self.weighted else None)

    def to_store(self, path: str | os.PathLike, *, name: str,
                 node_writer=None, feat_dim: int = 0, num_classes: int = 1,
                 chunk_nodes: int = CHUNK_NODES,
                 chunk_edges: int = CHUNK_EDGES) -> GraphStore:
        """Materialise the chunked-CSR store.  ``node_writer(lo, hi)``
        returns the payload dict for node rows ``[lo, hi)`` (None → a
        structure-only store, e.g. a coarse level)."""
        path = str(path)
        os.makedirs(path, exist_ok=True)
        edge_rows, num_edges, k_out = [], 0, 0
        for bk in range(self.n_buckets):
            b_lo = bk * self.bucket_nodes
            b_hi = min(b_lo + self.bucket_nodes, self.n)
            dst, src, wgt = self._bucket_rows(bk)
            w32 = wgt.astype(np.float32) if wgt is not None else None
            counts = np.bincount((dst - b_lo).astype(np.int64),
                                 minlength=b_hi - b_lo)
            iptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
            # a bucket is at most bucket_nodes rows; split only on edges
            lo = b_lo
            while lo < b_hi:
                cap = int(np.searchsorted(iptr, iptr[lo - b_lo] + chunk_edges,
                                          side="right")) - 1
                hi = max(min(b_hi, b_lo + cap), lo + 1)
                e0, e1 = int(iptr[lo - b_lo]), int(iptr[hi - b_lo])
                np.savez(os.path.join(path, f"edges_{k_out:05d}.npz"),
                         indptr=(iptr[lo - b_lo:hi - b_lo + 1]
                                 - e0).astype(np.int64),
                         indices=src[e0:e1],
                         **({"wgt": w32[e0:e1]} if w32 is not None else {}))
                edge_rows.append((int(lo), int(hi)))
                num_edges += e1 - e0
                k_out += 1
                lo = hi
        node_rows = []
        if node_writer is not None:
            node_rows = _row_chunks(self.n, None, chunk_nodes, chunk_edges)
            for k, (lo, hi) in enumerate(node_rows):
                np.savez(os.path.join(path, f"nodes_{k:05d}.npz"),
                         **node_writer(lo, hi))
        store = GraphStore(path=path, num_nodes=self.n, num_edges=num_edges,
                           feat_dim=feat_dim, num_classes=num_classes,
                           name=name, edge_rows=edge_rows,
                           node_rows=node_rows,
                           has_nodes=node_writer is not None,
                           weighted=self.weighted)
        store.save_manifest()
        return store

    def cleanup(self) -> None:
        shutil.rmtree(self.dir, ignore_errors=True)


def spill_to_store(n: int, emit, path: str | os.PathLike, *, name: str,
                   node_writer=None, feat_dim: int = 0,
                   num_classes: int = 1, weighted: bool = False,
                   chunk_nodes: int = CHUNK_NODES,
                   chunk_edges: int = CHUNK_EDGES,
                   bucket_nodes: int | None = None) -> GraphStore:
    """Drive an edge emitter through the external sort into a store.

    ``emit(spill)`` calls ``spill.add(dst, src[, wgt])`` any number of
    times (both directions of every undirected edge); the result is the
    canonical chunked CSR.  The spill directory is temporary and removed.
    ``bucket_nodes`` sizes the sort buckets (default ``chunk_nodes``) —
    shrink it when the expected edges-per-node is high so the per-bucket
    dedup arrays stay bounded.
    """
    tmp = tempfile.mkdtemp(prefix="edge_spill_",
                           dir=os.path.dirname(str(path)) or ".")
    spill = EdgeSpill(n, tmp, bucket_nodes=bucket_nodes or chunk_nodes,
                      weighted=weighted)
    try:
        emit(spill)
        return spill.to_store(path, name=name, node_writer=node_writer,
                              feat_dim=feat_dim, num_classes=num_classes,
                              chunk_nodes=chunk_nodes,
                              chunk_edges=chunk_edges)
    finally:
        spill.cleanup()


# ---------------------------------------------------------------------------
# Multilevel streaming partitioner
# ---------------------------------------------------------------------------


def _hash_bit(ids: np.ndarray, salt: int) -> np.ndarray:
    """Deterministic splitmix64-style bit per id (chunk-invariant)."""
    z = ids.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15) \
        + np.uint64(2 * salt + 1)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return ((z ^ (z >> np.uint64(31))) & np.uint64(1)).astype(bool)


def _chunked_match(store: GraphStore, node_w: np.ndarray, max_w: float,
                   salt: int = 0) -> tuple[np.ndarray, int]:
    """One chunked leader/follower clustering round.

    A salted hash bit splits nodes into leaders and followers; each
    follower nominates its heaviest leader neighbour (ties → smallest
    id), and every leader accepts its nominees in ascending follower id
    while the merged weight stays under ``max_w``.  Unlike mutual-pair
    heavy-edge matching (which stalls once nominations stop being
    symmetric — a few % of nodes per round), roughly a third of the
    nodes collapse every round, so coarsening is geometric.  Returns
    ``(cluster [n] int64, n_coarse)`` with cluster ids compacted in
    ascending-representative order (deterministic, chunk-invariant:
    rows never split across chunks and acceptance is a global pass).
    """
    n = store.num_nodes
    leader = _hash_bit(np.arange(n, dtype=np.int64), salt)
    cand = np.full(n, -1, np.int64)
    for lo, hi, iptr, idx, wgt in store.edge_chunks():
        if len(idx) == 0:
            continue
        rows = np.repeat(np.arange(lo, hi, dtype=np.int64), np.diff(iptr))
        idx = idx.astype(np.int64)
        w = np.ones(len(idx), np.float64) if wgt is None \
            else wgt.astype(np.float64)
        sel = ~leader[rows] & leader[idx]     # follower -> leader edges
        rows, idx, w = rows[sel], idx[sel], w[sel]
        if not len(rows):
            continue
        # heaviest leader per follower, smallest id on ties: lexsort
        # keys (last key is primary) — row asc, weight desc, id asc
        order = np.lexsort((idx, -w, rows))
        first = np.unique(rows[order], return_index=True)[1]
        cand[rows[order][first]] = idx[order][first]
    rep = np.arange(n, dtype=np.int64)
    f = np.flatnonzero(cand >= 0)             # nominating followers
    if len(f):
        ld = cand[f]
        order = np.lexsort((f, ld))           # by leader, then follower
        f, ld = f[order], ld[order]
        wf = node_w[f]
        cum = np.cumsum(wf)
        starts = np.flatnonzero(np.concatenate([[True], ld[1:] != ld[:-1]]))
        run = np.repeat(starts, np.diff(np.concatenate([starts, [len(ld)]])))
        within = cum - (cum[run] - wf[run])   # cumulative within group
        ok = node_w[ld] + within <= max_w
        rep[f[ok]] = ld[ok]
    uniq, cluster = np.unique(rep, return_inverse=True)
    return cluster.astype(np.int64), len(uniq)


def _contract(store: GraphStore, cluster: np.ndarray, n_coarse: int,
              out_path: str) -> GraphStore:
    """Contract a level along ``cluster``: map both endpoints, drop
    intra-cluster edges, sum parallel edge weights (external sort)."""
    def emit(spill):
        for lo, hi, iptr, idx, wgt in store.edge_chunks():
            if len(idx) == 0:
                continue
            rows = np.repeat(np.arange(lo, hi, dtype=np.int64),
                             np.diff(iptr))
            cd, cs = cluster[rows], cluster[idx.astype(np.int64)]
            keep = cd != cs
            w = np.ones(len(idx), np.float64) if wgt is None \
                else wgt.astype(np.float64)
            spill.add(cd[keep], cs[keep], w[keep])

    # coarse levels have high edges-per-node: size buckets so each holds
    # ~chunk_edges pre-dedup pairs, keeping the sort transients bounded
    per_node = max(store.num_edges // max(n_coarse, 1), 1)
    bucket = min(CHUNK_NODES, max(CHUNK_EDGES // (2 * per_node), 4096))
    return spill_to_store(n_coarse, emit, out_path,
                          name=f"{store.name}-c", weighted=True,
                          bucket_nodes=bucket)


def _weighted_ldg(indptr, indices, ewgt, node_w, q: int, seed: int,
                  slack: float) -> np.ndarray:
    """Weighted linear deterministic greedy over a BFS order — the
    coarsest-level seeding of the multilevel partitioner (the weighted
    analogue of :func:`repro.graph.partition.greedy_partition`)."""
    from collections import deque

    n = len(node_w)
    rng = np.random.default_rng(seed)
    capacity = slack * float(node_w.sum()) / q
    owner = np.full(n, -1, np.int32)
    sizes = np.zeros(q, np.float64)
    order = np.empty(n, np.int64)
    pos = 0
    visited = np.zeros(n, bool)
    for start in rng.permutation(n):
        if visited[start]:
            continue
        dq = deque([start])
        visited[start] = True
        while dq:
            u = dq.popleft()
            order[pos] = u
            pos += 1
            for v in indices[indptr[u]:indptr[u + 1]]:
                if not visited[v]:
                    visited[v] = True
                    dq.append(v)
    counts = np.zeros(q, np.float64)
    for u in order:
        counts[:] = 0.0
        sl = slice(indptr[u], indptr[u + 1])
        neigh = indices[sl]
        if len(neigh):
            owned = owner[neigh]
            ok = owned >= 0
            if ok.any():
                np.add.at(counts, owned[ok], ewgt[sl][ok])
        # strict feasibility: never place into a part the node overfills
        # (the argmin fallback fires only when every part is full, so
        # final imbalance is bounded by one node weight, not by drift)
        fits = sizes + node_w[u] <= capacity
        score = counts * np.maximum(1.0 - sizes / capacity, 0.0)
        if fits.any():
            score = np.where(fits, score, -1.0)
            best = int(np.argmax(score))
            if score[best] <= 0.0:
                masked = np.where(fits, sizes, np.inf)
                best = int(np.argmin(masked))
        else:
            best = int(np.argmin(sizes))
        owner[u] = best
        sizes[best] += node_w[u]
    return owner


def _rebalance(owner: np.ndarray, node_w: np.ndarray, q: int,
               slack: float) -> np.ndarray:
    """Move lightest nodes out of overfull parts until every part fits
    the weighted capacity (LDG's all-parts-full fallback can overshoot
    it).  Refinement never re-breaks the bound — its moves are
    capacity-gated — and uncoarsening projects weights exactly, so this
    single pass makes the final node balance ≤ slack."""
    owner = owner.copy()
    capacity = slack * float(node_w.sum()) / q
    sizes = np.bincount(owner, weights=node_w, minlength=q)
    for p in np.flatnonzero(sizes > capacity):
        nodes = np.flatnonzero(owner == p)
        nodes = nodes[np.argsort(node_w[nodes], kind="stable")]
        for u in nodes:
            if sizes[p] <= capacity:
                break
            t = int(np.argmin(sizes))
            if sizes[t] + node_w[u] > capacity:
                break               # nowhere to put it without overfilling
            owner[u] = t
            sizes[p] -= node_w[u]
            sizes[t] += node_w[u]
    return owner


def _level_graph(store: GraphStore) -> tuple:
    """Load one (small) level fully: ``(GraphData, edge weights | None)``.
    Coarse levels carry no node payload, so the GraphData gets dummy
    features/labels — the partitioners only read the CSR."""
    n = store.num_nodes
    indptr = np.zeros(n + 1, np.int64)
    idx_parts, w_parts, base = [], [], 0
    for lo, hi, iptr, idx, wgt in store.edge_chunks():
        indptr[lo + 1:hi + 1] = iptr[1:] + base
        base += int(iptr[-1])
        idx_parts.append(idx)
        if wgt is not None:
            w_parts.append(wgt)
    indices = np.concatenate(idx_parts) if idx_parts \
        else np.zeros(0, np.int32)
    ew = np.concatenate(w_parts).astype(np.float64) if w_parts else None
    dummy = np.zeros(n, np.int32)
    g = GraphData(indptr=indptr, indices=indices,
                  features=np.zeros((n, 1), np.float32), labels=dummy,
                  train_mask=np.zeros(n, bool), val_mask=np.zeros(n, bool),
                  test_mask=np.zeros(n, bool), name=store.name)
    return g, ew


def stream_partition(store: GraphStore, q: int, scheme: str = "metis-like",
                     seed: int = 0, slack: float = 1.05,
                     in_core_nodes: int = 200_000,
                     coarsen_target: int = 20_000,
                     refine_max_nodes: int = 150_000,
                     max_rounds: int = 20) -> np.ndarray:
    """Partition a :class:`GraphStore` into ``q`` parts without ever
    materialising the full graph.

    * ``scheme="random"`` — the paper's random assignment, O(n) memory.
    * graphs with ``num_nodes <= in_core_nodes`` — **exact reduction**:
      the chunked CSR is assembled (it is bit-identical to the source
      graph for any chunk size) and handed to the in-memory partitioner,
      so the owner vector equals ``partition_graph``'s exactly.
    * larger graphs — **multilevel**: chunked heavy-edge matching
      coarsens until ``coarsen_target`` nodes (every level an on-disk
      weighted store), weighted LDG + weighted
      :func:`repro.graph.partition.refine_partition` seed the coarsest
      level, and uncoarsening projects owners down, re-refining with the
      same ``refine_partition`` at each level with at most
      ``refine_max_nodes`` nodes (levels above that project only — the
      coarse structure already carries the cut quality).

    Returns the ``[num_nodes]`` int32 owner vector.
    """
    n = store.num_nodes
    if scheme == "random":
        rng = np.random.default_rng(seed)
        perm = rng.permutation(n)
        owner = np.empty(n, np.int32)
        for i in range(q):
            owner[perm[i::q]] = i
        return owner
    if scheme != "metis-like":
        raise ValueError(f"unknown scheme {scheme!r}; "
                         f"have ('random', 'metis-like')")

    if n <= in_core_nodes:
        g = load_graph_store(store) if store.has_nodes \
            else _level_graph(store)[0]
        return PARTITIONERS[scheme](g, q, seed=seed)

    # --- coarsen ---------------------------------------------------------
    tmp = tempfile.mkdtemp(prefix="mlevel_", dir=store.path)
    try:
        levels = [store]
        clusters = []
        node_w = np.ones(n, np.float64)
        weights = [node_w]
        # start the cluster-weight cap at ~3% of a part (placement stays
        # granular → balance), doubling it whenever matching stalls
        # (<10% reduction) up to a hard 12.5%-of-part cap — the METIS
        # adaptive-cap trick, so coarsening reaches the target at every
        # scale without giving up balance granularity early
        max_w = max(float(n) / (q * 32.0), 2.0)
        max_w_cap = max(float(n) / (q * 8.0), 2.0)
        cur = store
        for r in range(max_rounds):
            if cur.num_nodes <= coarsen_target:
                break
            cluster, n_coarse = _chunked_match(cur, weights[-1], max_w,
                                               salt=r)
            if n_coarse >= 0.90 * cur.num_nodes:
                if max_w >= max_w_cap:
                    break               # stalled at the hard cap
                max_w = min(2.0 * max_w, max_w_cap)
                continue
            cur = _contract(cur, cluster, n_coarse,
                            os.path.join(tmp, f"level_{r:02d}"))
            w_next = np.zeros(n_coarse, np.float64)
            np.add.at(w_next, cluster, weights[-1])
            clusters.append(cluster)
            weights.append(w_next)
            levels.append(cur)

        # --- initial partition at the coarsest level ---------------------
        g_c, ew_c = _level_graph(levels[-1])
        ew_c = ew_c if ew_c is not None else \
            np.ones(g_c.num_edges, np.float64)
        owner = _weighted_ldg(g_c.indptr, g_c.indices, ew_c, weights[-1],
                              q, seed, slack)
        owner = _rebalance(owner, weights[-1], q, slack)
        owner = refine_partition(g_c, owner, q, seed=seed, slack=slack,
                                 node_weight=weights[-1], edge_weight=ew_c)

        # --- uncoarsen + refine ------------------------------------------
        for li in range(len(clusters) - 1, -1, -1):
            owner = owner[clusters[li]]
            lvl = levels[li]
            if lvl.num_nodes <= refine_max_nodes:
                # finer levels carry smaller node weights, so the repair
                # that was infeasible around coarse boulder clusters
                # converges here; refine then only improves the cut
                # within the same capacity
                owner = _rebalance(owner, weights[li], q, slack)
                g_l, ew_l = _level_graph(lvl)
                owner = refine_partition(g_l, owner, q, seed=seed,
                                         slack=slack,
                                         node_weight=weights[li],
                                         edge_weight=ew_l)
        return owner.astype(np.int32)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def stream_edge_cut(store: GraphStore, owner: np.ndarray) -> dict:
    """Streaming :func:`repro.graph.partition.edge_cut_stats`: one pass
    over the edge chunks, O(chunk) memory."""
    n_cross = n_total = 0
    for lo, hi, iptr, idx, _ in store.edge_chunks():
        if len(idx) == 0:
            continue
        rows = np.repeat(np.arange(lo, hi, dtype=np.int64), np.diff(iptr))
        n_cross += int((owner[rows] != owner[idx]).sum())
        n_total += len(idx)
    return {"self_edges": n_total - n_cross, "cross_edges": n_cross,
            "self_frac": (n_total - n_cross) / max(n_total, 1),
            "cross_frac": n_cross / max(n_total, 1)}


# ---------------------------------------------------------------------------
# On-disk per-worker shards
# ---------------------------------------------------------------------------


def _local_index_of(store: GraphStore, owner: np.ndarray,
                    q: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-node index within its partition, ascending by global id —
    the same numbering ``build_partitioned`` assigns.  Chunked."""
    from repro.dist.halo import _group_slots

    local_index = np.zeros(store.num_nodes, np.int32)
    base = np.zeros(q, np.int64)
    for lo, hi in store.node_rows or [(0, store.num_nodes)]:
        o = owner[lo:hi].astype(np.int64)
        order, slot_in, counts = _group_slots(o, q)
        li = np.empty(hi - lo, np.int64)
        li[order] = base[o[order]] + slot_in
        local_index[lo:hi] = li.astype(np.int32)
        base += counts[:q]
    return local_index, base            # base == per-partition sizes


def write_shards(store: GraphStore, owner: np.ndarray,
                 out_dir: str | os.PathLike, norm: str = "mean") -> str:
    """Write the per-worker shard set of ``store`` under ``owner``.

    Layout (all padding widths global, recorded in ``shards.json``):

    * ``part_{p:05d}.npz`` — partition ``p``'s rows of every runtime
      array: ``features [P, F]``, ``labels``/``*_mask``/``node_valid``
      ``[P]``, local + remote edge lists (``local_dst/src/w/w_iso
      [El]``, ``remote_dst/src/w [Er]``), publish lists (``send_idx/
      send_valid [B]``), and the precomputed p2p halo + ELL arrays of
      ``repro.dist.halo`` (``p2p_send_slot/p2p_send_valid [D, H]``,
      ``remote_src_p2p [Er]``, ``ell_* [P, K]``).
    * ``shards.json`` — global facts (``part_size``, ``halo_size``,
      ``halo_demand``, split counts, …) plus the serialised
      :class:`repro.dist.halo.HaloSpec`, so ``DistMeta`` builds without
      touching any shard, let alone the graph.
    * ``owner.npy`` — the global owner vector (provenance; loaders
      never read it).

    The arrays are bitwise-identical to
    ``build_partitioned(g, owner) → attach_p2p`` on the assembled graph
    (property-pinned), but construction is streaming: two edge-chunk
    passes into per-partition spill files, one node-chunk pass into
    per-partition slabs, then one partition assembled at a time.
    """
    from repro.dist.halo import (HaloSpec, _group_slots, build_reverse_ell)

    out_dir = str(out_dir)
    os.makedirs(out_dir, exist_ok=True)
    n = store.num_nodes
    q = int(owner.max()) + 1 if len(owner) else 1
    owner = np.asarray(owner, np.int32)
    deg = np.maximum(store.degrees(), 1).astype(np.float32)
    local_index, part_counts = _local_index_of(store, owner, q)
    part_size = max(int(part_counts.max()), 1)

    # ---- edge pass 1: boundary flags + local degrees + per-part spills --
    tmp = tempfile.mkdtemp(prefix="shard_spill_", dir=out_dir)
    piece = [0] * q
    is_boundary = np.zeros(n, bool)
    local_deg = np.zeros(n, np.int64)
    cross_edges = 0
    try:
        for lo, hi, iptr, idx, _ in store.edge_chunks():
            if len(idx) == 0:
                continue
            rows = np.repeat(np.arange(lo, hi, dtype=np.int64),
                             np.diff(iptr))
            src = idx.astype(np.int64)
            is_local = owner[rows] == owner[src]
            is_boundary[src[~is_local]] = True
            np.add.at(local_deg, rows[is_local], 1)
            cross_edges += int((~is_local).sum())
            p_of = owner[rows]
            order = np.argsort(p_of, kind="stable")  # preserves CSR order
            po = p_of[order]
            bounds = np.searchsorted(po, np.arange(q + 1))
            for p in np.unique(po):
                sel = order[bounds[p]:bounds[p + 1]]
                np.savez(os.path.join(tmp, f"p{p:05d}_{piece[p]:05d}.npz"),
                         dstg=rows[sel].astype(np.int32),
                         srcg=src[sel].astype(np.int32),
                         loc=is_local[sel])
                piece[p] += 1

        # ---- publish (boundary) slots, ascending per partition ----------
        send_slot = np.full(n, -1, np.int32)
        send_counts = np.zeros(q, np.int64)
        for lo, hi in store.node_rows or [(0, n)]:
            b_sel = np.flatnonzero(is_boundary[lo:hi]) + lo
            o = owner[b_sel].astype(np.int64)
            order, slot_in, counts = _group_slots(o, q)
            send_slot[b_sel[order]] = \
                (send_counts[o[order]] + slot_in).astype(np.int32)
            send_counts += counts[:q]
        halo_size = max(int(send_counts.max()), 1)

        def _load_part_edges(p: int):
            cols = {"dstg": [], "srcg": [], "loc": []}
            for k in range(piece[p]):
                with np.load(os.path.join(tmp,
                                          f"p{p:05d}_{k:05d}.npz")) as z:
                    for c in cols:
                        cols[c].append(z[c])
            if not cols["dstg"]:
                return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                        np.zeros(0, bool))
            return (np.concatenate(cols["dstg"]).astype(np.int64),
                    np.concatenate(cols["srcg"]).astype(np.int64),
                    np.concatenate(cols["loc"]))

        # ---- pass A over partitions: global padding widths + pair sets --
        el = er = ell_k = rev_k = 1
        halo_demand = 0
        pair_sets: list[list] = [[None] * q for _ in range(q)]
        for p in range(q):
            dstg, srcg, loc = _load_part_edges(p)
            el = max(el, int(loc.sum()))
            er = max(er, int((~loc).sum()))
            if loc.any():
                dl = local_index[dstg[loc]].astype(np.int64)
                sl = local_index[srcg[loc]].astype(np.int64)
                ell_k = max(ell_k, int(np.bincount(dl).max()))
                rev_k = max(rev_k, int(np.bincount(sl).max()))
            r_src = srcg[~loc]
            halo_demand += len(np.unique(r_src))
            so = owner[r_src].astype(np.int64)
            for j in np.unique(so):
                pair_sets[p][j] = np.unique(send_slot[r_src[so == j]])
        pair_rows = np.zeros((q, q), np.int64)
        for i in range(q):
            for j in range(q):
                if j != i and pair_sets[i][j] is not None:
                    pair_rows[i, j] = len(pair_sets[i][j])
        hop_w = max(int(pair_rows.max()), 1)
        d_hops = max(q - 1, 1)
        spec = HaloSpec(q=q, hop_width=hop_w,
                        compact_rows=max((q - 1) * hop_w, 1),
                        ell_degree=ell_k, rev_degree=rev_k,
                        pair_rows=tuple(int(v) for v in pair_rows.ravel()))

        # ---- node pass: per-partition payload slabs ---------------------
        mask_keys = ("train_mask", "val_mask", "test_mask", "node_valid")
        slab_dir = os.path.join(tmp, "slabs")
        os.makedirs(slab_dir, exist_ok=True)
        slab_info = {"features": (np.float32, (part_size, store.feat_dim)),
                     "labels": (np.int32, (part_size,)),
                     **{k: (bool, (part_size,)) for k in mask_keys}}

        def _slab(key, p, mode):
            path = os.path.join(slab_dir, f"{key}{p}.npy")
            if mode == "w+":
                dt, shape = slab_info[key]
                return np.lib.format.open_memmap(path, mode="w+",
                                                 dtype=dt, shape=shape)
            return np.lib.format.open_memmap(path, mode=mode)

        for p in range(q):
            for key in slab_info:          # sparse zero-filled files
                _slab(key, p, "w+")
        n_train = n_val = n_test = 0
        for lo, hi, chunk in store.node_chunks():
            o = owner[lo:hi]
            li = local_index[lo:hi]
            n_train += int(chunk["train_mask"].sum())
            n_val += int(chunk["val_mask"].sum())
            n_test += int(chunk["test_mask"].sum())
            for p in np.unique(o):
                sel = o == p
                # open → write → flush → unmap per chunk: dirty slab
                # pages never accumulate across the whole node pass, so
                # peak RSS stays O(chunk), not O(n·F)
                for key in slab_info:
                    m = _slab(key, p, "r+")
                    m[li[sel]] = True if key == "node_valid" \
                        else chunk[key][sel]
                    m.flush()
                    del m

        # ---- pass B: assemble + write one shard at a time ---------------
        for p in range(q):
            dstg, srcg, loc = _load_part_edges(p)
            w_all = _edge_w(deg, dstg, srcg, norm)
            wiso_all = _edge_w(np.maximum(local_deg, 1).astype(np.float32),
                               dstg, srcg, norm)
            d_loc = local_index[dstg[loc]]
            s_loc = local_index[srcg[loc]]
            shard = {
                "local_dst": _pad1(d_loc, el, part_size, np.int32),
                "local_src": _pad1(s_loc, el, 0, np.int32),
                "local_w": _pad1(w_all[loc], el, 0.0, np.float32),
                "local_w_iso": _pad1(wiso_all[loc], el, 0.0, np.float32),
            }
            r_dst = local_index[dstg[~loc]]
            r_src = srcg[~loc]
            flat = owner[r_src].astype(np.int64) * halo_size + \
                send_slot[r_src]
            shard["remote_dst"] = _pad1(r_dst, er, part_size, np.int32)
            shard["remote_src"] = _pad1(flat, er, 0, np.int32)
            shard["remote_w"] = _pad1(w_all[~loc], er, 0.0, np.float32)

            # publish list: this partition's boundary nodes, ascending
            mine_b = np.zeros(0, np.int64)
            for lo, hi in store.node_rows or [(0, n)]:
                sel = np.flatnonzero((owner[lo:hi] == p) &
                                     is_boundary[lo:hi]) + lo
                mine_b = np.concatenate([mine_b, sel])
            shard["send_idx"] = _pad1(local_index[mine_b], halo_size, 0,
                                      np.int32)
            shard["send_valid"] = _pad1(np.ones(len(mine_b)), halo_size,
                                        0.0, np.float32)

            # p2p halo rows (sender p: hop d -> receiver (p + d) mod q)
            p2p_slot = np.zeros((d_hops, hop_w), np.int32)
            p2p_valid = np.zeros((d_hops, hop_w), np.float32)
            for d in range(1, q):
                slots = pair_sets[(p + d) % q][p]
                if slots is not None and len(slots):
                    p2p_slot[d - 1, :len(slots)] = slots
                    p2p_valid[d - 1, :len(slots)] = 1.0
            shard["p2p_send_slot"] = p2p_slot
            shard["p2p_send_valid"] = p2p_valid
            rsp = np.zeros(er, np.int32)
            so = owner[r_src].astype(np.int64)
            for j in range(q):
                if j == p or pair_sets[p][j] is None:
                    continue
                sel = so == j
                if not sel.any():
                    continue
                pos = np.searchsorted(pair_sets[p][j],
                                      send_slot[r_src[sel]])
                rsp[:len(r_dst)][sel] = ((p - j) % q - 1) * hop_w + pos
            shard["remote_src_p2p"] = rsp

            # ELL lists (forward + reversed) for the local edges
            nbr = np.zeros((part_size, ell_k), np.int32)
            wf = np.zeros((part_size, ell_k), np.float32)
            wfi = np.zeros((part_size, ell_k), np.float32)
            valid = np.zeros((part_size, ell_k), bool)
            if loc.any():
                order, slot_in, _ = _group_slots(
                    d_loc.astype(np.int64), part_size)
                d_o = d_loc[order]
                nbr[d_o, slot_in] = s_loc[order]
                wf[d_o, slot_in] = w_all[loc][order]
                wfi[d_o, slot_in] = wiso_all[loc][order]
                valid[d_o, slot_in] = True
            rnbr, rslot = build_reverse_ell(nbr, valid, part_size,
                                            rev_k=rev_k)
            shard.update(ell_nbr=nbr, ell_w=wf, ell_w_iso=wfi,
                         ell_rnbr=rnbr, ell_rslot=rslot)

            for key in slab_info:
                m = _slab(key, p, "r")
                shard[key] = np.array(m)
                del m
            np.savez(os.path.join(out_dir, f"part_{p:05d}.npz"), **shard)

        np.save(os.path.join(out_dir, "owner.npy"), owner)
        meta = {"q": q, "part_size": part_size, "halo_size": halo_size,
                "num_nodes": n, "num_edges": store.num_edges,
                "feat_dim": store.feat_dim,
                "num_classes": store.num_classes,
                "halo_demand": int(halo_demand),
                "cross_edges": int(cross_edges),
                "n_train": n_train, "n_val": n_val, "n_test": n_test,
                "norm": norm, "name": store.name,
                "el": el, "er": er,
                "halo_spec": spec.to_dict()}
        with open(os.path.join(out_dir, _SHARD_MANIFEST), "w") as fh:
            json.dump(meta, fh)
        return out_dir
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def _edge_w(deg: np.ndarray, dst: np.ndarray, src: np.ndarray,
            norm: str) -> np.ndarray:
    if norm == "mean":
        return (1.0 / deg[dst]).astype(np.float32)
    if norm == "sym":
        return (1.0 / np.sqrt(deg[dst] * deg[src])).astype(np.float32)
    raise ValueError(f"unknown normalisation {norm!r}")


def _pad1(vals, width: int, pad, dtype) -> np.ndarray:
    out = np.full(max(width, 1), pad, dtype)
    out[:len(vals)] = vals
    return out


# ---------------------------------------------------------------------------
# Shard loading
# ---------------------------------------------------------------------------

#: stacked-array keys every shard carries, in device_arrays() +
#: attach_p2p() order
_SHARD_KEYS = ("features", "labels", "train_mask", "val_mask", "test_mask",
               "node_valid", "local_dst", "local_src", "local_w",
               "local_w_iso", "remote_dst", "remote_src", "remote_w",
               "send_idx", "send_valid", "p2p_send_slot", "p2p_send_valid",
               "remote_src_p2p", "ell_nbr", "ell_w", "ell_w_iso",
               "ell_rnbr", "ell_rslot")


@dataclasses.dataclass
class ShardSet:
    """Loaded shard arrays + global facts — duck-types
    :class:`repro.graph.partition.PartitionedGraph` for ``DistMeta.build``
    and the aggregation oracles, with the :class:`repro.dist.halo.HaloSpec`
    precomputed (``halo_spec``) so nothing recomputes the per-pair sets.

    ``parts`` records which partitions are loaded; a worker passes its own
    index to :func:`load_shards` and gets a ``[1, ...]`` stack holding
    only its slice (the shard_map per-worker block layout).
    """

    path: str
    q: int
    part_size: int
    halo_size: int
    num_nodes: int
    num_edges: int
    feat_dim: int
    num_classes: int
    halo_demand: int
    cross_edges: int
    n_train: int
    n_val: int
    n_test: int
    norm: str
    name: str
    halo_spec: object               # repro.dist.halo.HaloSpec
    parts: tuple
    arrays: dict                    # key -> [len(parts), ...] numpy stack

    def __getattr__(self, key):
        arrays = object.__getattribute__(self, "arrays")
        if key in arrays:
            return arrays[key]
        raise AttributeError(key)

    def remote_pair_table(self):
        """Decode the flat halo indices per remote edge (the
        ``PartitionedGraph`` contract) — lets ``repro.dist.halo`` rebuild
        the :class:`HaloSpec` from loaded shards, which the round-trip
        property pins bitwise against the manifest copy."""
        valid = self.remote_w > 0
        src_part = (self.remote_src // self.halo_size).astype(np.int32)
        slot = (self.remote_src % self.halo_size).astype(np.int32)
        return valid, src_part, slot

    def device_arrays(self) -> dict:
        """The jnp pytree for the train step — the union of
        ``PartitionedGraph.device_arrays()`` and ``attach_p2p`` keys
        (shards precompute the halo/ELL indices, so no attach step)."""
        import jax.numpy as jnp
        return {k: jnp.asarray(v) for k, v in self.arrays.items()}


def shard_meta(path: str | os.PathLike) -> dict:
    """The global shard facts without loading any shard (what a
    ``DistMeta`` needs — the 'never touch the global graph' contract)."""
    from repro.dist.halo import HaloSpec

    with open(os.path.join(path, _SHARD_MANIFEST)) as fh:
        meta = json.load(fh)
    meta["halo_spec"] = HaloSpec.from_dict(meta["halo_spec"])
    return meta


def is_shard_dir(path) -> bool:
    return isinstance(path, (str, os.PathLike)) and \
        os.path.exists(os.path.join(path, _SHARD_MANIFEST))


def load_shards(path: str | os.PathLike,
                parts: list[int] | None = None) -> ShardSet:
    """Load shard arrays for ``parts`` (default: all) as ``[len(parts),
    ...]`` stacks.  A single-partition load reads exactly one
    ``part_*.npz`` — the per-worker ingestion path (and what the
    elastic-Q recovery uses to boot a replacement worker).  ``parts``
    must be unique, in-range partition ids; they are loaded in the
    given order."""
    meta = shard_meta(path)
    q = meta["q"]
    parts = list(range(q)) if parts is None else [int(p) for p in parts]
    if not parts:
        raise ValueError("parts must name at least one partition")
    if len(set(parts)) != len(parts):
        raise ValueError(f"duplicate partition ids in parts: {parts}")
    bad = [p for p in parts if not 0 <= p < q]
    if bad:
        raise ValueError(f"partition ids {bad} out of range for q={q}")
    stacks: dict[str, list] = {k: [] for k in _SHARD_KEYS}
    for p in parts:
        fname = os.path.join(path, f"part_{p:05d}.npz")
        if not os.path.exists(fname):
            raise FileNotFoundError(
                f"shard dir {path!s} is missing partition file "
                f"part_{p:05d}.npz (manifest says q={q})")
        with np.load(fname) as z:
            for k in _SHARD_KEYS:
                stacks[k].append(z[k])
    arrays = {k: np.stack(v) for k, v in stacks.items()}
    return ShardSet(path=str(path), parts=tuple(parts), arrays=arrays,
                    **{k: meta[k] for k in
                       ("q", "part_size", "halo_size", "num_nodes",
                        "num_edges", "feat_dim", "num_classes",
                        "halo_demand", "cross_edges", "n_train", "n_val",
                        "n_test", "norm", "name", "halo_spec")})
