"""Synthetic analogues of the paper's datasets (container is offline).

* ``citation_graph``   — OGBN-Arxiv analogue: SBM community structure
  correlated with the 40 labels, 128-dim noisy class-centroid features.
* ``copurchase_graph`` — OGBN-Products analogue: heavier, hub-dominated
  degree profile (power-law overlay on an SBM), 47 classes, 100-dim feats.

Both tasks are built so that *neighbourhood information matters*: node
features alone are weakly informative (large noise), while neighbours share
labels with high probability — so a model that ignores cross-partition edges
(the paper's No-Comm baseline) measurably under-performs, reproducing the
qualitative gap in Tables II/III.
"""

from __future__ import annotations

import numpy as np

from .data import GraphData, from_edge_list


def _sbm_edges(rng: np.random.Generator, labels: np.ndarray, n_classes: int,
               avg_deg_in: float, avg_deg_out: float
               ) -> tuple[np.ndarray, np.ndarray]:
    """Sample SBM edges block-pair-wise in O(E)."""
    n = len(labels)
    class_nodes = [np.flatnonzero(labels == c) for c in range(n_classes)]
    sizes = np.array([len(c) for c in class_nodes], np.float64)
    dsts, srcs = [], []
    # expected intra edges per node ~ avg_deg_in, inter ~ avg_deg_out spread
    for ci in range(n_classes):
        ni = sizes[ci]
        if ni < 2:
            continue
        # intra-block
        m_in = rng.poisson(ni * avg_deg_in / 2.0)
        if m_in:
            dsts.append(rng.choice(class_nodes[ci], m_in))
            srcs.append(rng.choice(class_nodes[ci], m_in))
        # inter-block: connect to a few random other blocks
        m_out = rng.poisson(ni * avg_deg_out / 2.0)
        if m_out:
            dsts.append(rng.choice(class_nodes[ci], m_out))
            srcs.append(rng.integers(0, n, m_out))
    return np.concatenate(dsts), np.concatenate(srcs)


def _features(rng: np.random.Generator, labels: np.ndarray, n_classes: int,
              dim: int, signal: float) -> np.ndarray:
    """Noisy class-centroid features; ``signal`` sets feature informativeness."""
    centroids = rng.normal(0.0, 1.0, (n_classes, dim)).astype(np.float32)
    noise = rng.normal(0.0, 1.0, (len(labels), dim)).astype(np.float32)
    feats = signal * centroids[labels] + noise
    # row-normalise (paper assumes normalised signals, AS2/AS4)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True) + 1e-6
    return feats


def citation_graph(n: int = 20000, n_classes: int = 40, feat_dim: int = 128,
                   avg_degree: float = 13.8, homophily: float = 0.82,
                   feature_signal: float = 0.06, seed: int = 0) -> GraphData:
    """OGBN-Arxiv analogue (169k nodes / 1.17M edges scaled to ``n``).

    ``avg_degree`` matches Arxiv's 2|E|/n ≈ 13.8; ``homophily`` is the
    fraction of edge mass that stays intra-class.
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n).astype(np.int32)
    deg_in = avg_degree * homophily
    deg_out = avg_degree * (1.0 - homophily)
    dst, src = _sbm_edges(rng, labels, n_classes, deg_in, deg_out)
    feats = _features(rng, labels, n_classes, feat_dim, feature_signal)
    return from_edge_list(n, dst, src, feats, labels,
                          splits=(0.54, 0.18, 0.28), seed=seed,
                          name=f"synth-arxiv-{n}")


def copurchase_graph(n: int = 50000, n_classes: int = 47, feat_dim: int = 100,
                     avg_degree: float = 25.0, homophily: float = 0.88,
                     hub_fraction: float = 0.01, hub_degree: float = 200.0,
                     feature_signal: float = 0.08, seed: int = 1) -> GraphData:
    """OGBN-Products analogue: SBM + power-law hub overlay.

    Products has avg degree ≈ 50 and extreme hubs; we scale degree down with
    node count but keep the hub-heavy profile that stresses partition cuts.
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n).astype(np.int32)
    dst, src = _sbm_edges(rng, labels, n_classes,
                          avg_degree * homophily,
                          avg_degree * (1.0 - homophily))
    # hub overlay: a few nodes attach to many random nodes (co-purchase hubs)
    n_hubs = max(int(hub_fraction * n), 1)
    hubs = rng.choice(n, n_hubs, replace=False)
    m_hub = rng.poisson(hub_degree, n_hubs)
    hub_dst = np.repeat(hubs, m_hub)
    hub_src = rng.integers(0, n, int(m_hub.sum()))
    dst = np.concatenate([dst, hub_dst])
    src = np.concatenate([src, hub_src])
    feats = _features(rng, labels, n_classes, feat_dim, feature_signal)
    return from_edge_list(n, dst, src, feats, labels,
                          splits=(0.08, 0.02, 0.90), seed=seed,
                          name=f"synth-products-{n}")


def tiny_graph(n: int = 256, n_classes: int = 4, feat_dim: int = 16,
               seed: int = 0) -> GraphData:
    """Small deterministic graph for unit tests."""
    return citation_graph(n=n, n_classes=n_classes, feat_dim=feat_dim,
                          avg_degree=8.0, homophily=0.85,
                          feature_signal=0.3, seed=seed)


DATASETS = {
    "synth-arxiv": citation_graph,
    "synth-products": copurchase_graph,
    "tiny": tiny_graph,
}


def load(name: str, **kw) -> GraphData:
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(DATASETS)}")
    return DATASETS[name](**kw)
