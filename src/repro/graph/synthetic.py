"""Synthetic analogues of the paper's datasets (container is offline).

* ``citation_graph``   — OGBN-Arxiv analogue: SBM community structure
  correlated with the 40 labels, 128-dim noisy class-centroid features.
* ``copurchase_graph`` — OGBN-Products analogue: heavier, hub-dominated
  degree profile (power-law overlay on an SBM), 47 classes, 100-dim feats.

Both tasks are built so that *neighbourhood information matters*: node
features alone are weakly informative (large noise), while neighbours share
labels with high probability — so a model that ignores cross-partition edges
(the paper's No-Comm baseline) measurably under-performs, reproducing the
qualitative gap in Tables II/III.
"""

from __future__ import annotations

import numpy as np

from .data import GraphData, from_edge_list


def _sbm_edges(rng: np.random.Generator, labels: np.ndarray, n_classes: int,
               avg_deg_in: float, avg_deg_out: float
               ) -> tuple[np.ndarray, np.ndarray]:
    """Sample SBM edges block-pair-wise in O(E)."""
    n = len(labels)
    class_nodes = [np.flatnonzero(labels == c) for c in range(n_classes)]
    sizes = np.array([len(c) for c in class_nodes], np.float64)
    dsts, srcs = [], []
    # expected intra edges per node ~ avg_deg_in, inter ~ avg_deg_out spread
    for ci in range(n_classes):
        ni = sizes[ci]
        if ni < 2:
            continue
        # intra-block
        m_in = rng.poisson(ni * avg_deg_in / 2.0)
        if m_in:
            dsts.append(rng.choice(class_nodes[ci], m_in))
            srcs.append(rng.choice(class_nodes[ci], m_in))
        # inter-block: connect to a few random other blocks
        m_out = rng.poisson(ni * avg_deg_out / 2.0)
        if m_out:
            dsts.append(rng.choice(class_nodes[ci], m_out))
            srcs.append(rng.integers(0, n, m_out))
    return np.concatenate(dsts), np.concatenate(srcs)


def _features(rng: np.random.Generator, labels: np.ndarray, n_classes: int,
              dim: int, signal: float) -> np.ndarray:
    """Noisy class-centroid features; ``signal`` sets feature informativeness."""
    centroids = rng.normal(0.0, 1.0, (n_classes, dim)).astype(np.float32)
    noise = rng.normal(0.0, 1.0, (len(labels), dim)).astype(np.float32)
    feats = signal * centroids[labels] + noise
    # row-normalise (paper assumes normalised signals, AS2/AS4)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True) + 1e-6
    return feats


def citation_graph(n: int = 20000, n_classes: int = 40, feat_dim: int = 128,
                   avg_degree: float = 13.8, homophily: float = 0.82,
                   feature_signal: float = 0.06, seed: int = 0) -> GraphData:
    """OGBN-Arxiv analogue (169k nodes / 1.17M edges scaled to ``n``).

    ``avg_degree`` matches Arxiv's 2|E|/n ≈ 13.8; ``homophily`` is the
    fraction of edge mass that stays intra-class.
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n).astype(np.int32)
    deg_in = avg_degree * homophily
    deg_out = avg_degree * (1.0 - homophily)
    dst, src = _sbm_edges(rng, labels, n_classes, deg_in, deg_out)
    feats = _features(rng, labels, n_classes, feat_dim, feature_signal)
    return from_edge_list(n, dst, src, feats, labels,
                          splits=(0.54, 0.18, 0.28), seed=seed,
                          name=f"synth-arxiv-{n}")


def copurchase_graph(n: int = 50000, n_classes: int = 47, feat_dim: int = 100,
                     avg_degree: float = 25.0, homophily: float = 0.88,
                     hub_fraction: float = 0.01, hub_degree: float = 200.0,
                     feature_signal: float = 0.08, seed: int = 1) -> GraphData:
    """OGBN-Products analogue: SBM + power-law hub overlay.

    Products has avg degree ≈ 50 and extreme hubs; we scale degree down with
    node count but keep the hub-heavy profile that stresses partition cuts.
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n).astype(np.int32)
    dst, src = _sbm_edges(rng, labels, n_classes,
                          avg_degree * homophily,
                          avg_degree * (1.0 - homophily))
    # hub overlay: a few nodes attach to many random nodes (co-purchase hubs)
    n_hubs = max(int(hub_fraction * n), 1)
    hubs = rng.choice(n, n_hubs, replace=False)
    m_hub = rng.poisson(hub_degree, n_hubs)
    hub_dst = np.repeat(hubs, m_hub)
    hub_src = rng.integers(0, n, int(m_hub.sum()))
    dst = np.concatenate([dst, hub_dst])
    src = np.concatenate([src, hub_src])
    feats = _features(rng, labels, n_classes, feat_dim, feature_signal)
    return from_edge_list(n, dst, src, feats, labels,
                          splits=(0.08, 0.02, 0.90), seed=seed,
                          name=f"synth-products-{n}")


def tiny_graph(n: int = 256, n_classes: int = 4, feat_dim: int = 16,
               seed: int = 0) -> GraphData:
    """Small deterministic graph for unit tests."""
    return citation_graph(n=n, n_classes=n_classes, feat_dim=feat_dim,
                          avg_degree=8.0, homophily=0.85,
                          feature_signal=0.3, seed=seed)


# ---------------------------------------------------------------------------
# Streaming generators (10⁷–10⁸ nodes): emit straight to a GraphStore
# ---------------------------------------------------------------------------
#
# The in-memory generators above materialise every edge and feature at
# once; these stream both to disk through the external sort of
# ``repro.graph.stream`` in fixed 65536-node generation chunks, so peak
# memory is O(chunk) regardless of ``n`` — and the result is
# bit-identical for any io chunking (the generation chunk is an internal
# constant, and the chunked-CSR content is canonical under dedup).
#
# Class labels come from an affine permutation ``π(i) = (a·i+b) mod n``
# (gcd(a, n) = 1): ``label(i) = π(i) mod C`` scatters classes uniformly,
# yet the k-th member of class ``c`` is recoverable in O(1) as
# ``π⁻¹(c + C·k)`` — which is what lets a generation chunk sample
# *same-class* SBM partners without a per-class node index (the
# ``class_nodes`` lists above are O(n) pointers we can't afford).

_GEN_CHUNK = 65536


def _affine(n: int, salt: int):
    """A fixed-point-free-ish affine permutation of [0, n) and its
    inverse multiplier (``a`` odd and coprime with ``n``)."""
    import math

    a = (2 * salt + 1) % n or 1
    while math.gcd(a, n) != 1:
        a = (a + 2) % n or 1
    return a, pow(a, -1, n), (salt * 2654435761 + 12345) % n


class _StreamLabels:
    """Label / split / feature oracle shared by the streaming generators."""

    def __init__(self, n, n_classes, feat_dim, signal, splits, seed):
        self.n, self.c, self.f = n, n_classes, feat_dim
        self.signal, self.splits, self.seed = signal, splits, seed
        self.a, self.a_inv, self.b = _affine(n, seed + 7)
        self.a2, _, self.b2 = _affine(n, seed + 101)
        # members of class c are y ≡ c (mod C), y ∈ [0, n)
        self.class_count = np.array(
            [(n - c - 1) // n_classes + 1 if c < n else 0
             for c in range(n_classes)], np.int64)
        rng = np.random.default_rng([seed, 29])
        self.centroids = rng.normal(
            0.0, 1.0, (n_classes, feat_dim)).astype(np.float32)

    def label(self, u: np.ndarray) -> np.ndarray:
        return (((self.a * u.astype(np.int64) + self.b) % self.n)
                % self.c).astype(np.int32)

    def member(self, c: np.ndarray, k: np.ndarray) -> np.ndarray:
        """The k-th node of class c (π⁻¹ of the class lattice)."""
        y = c.astype(np.int64) + self.c * k.astype(np.int64)
        return (self.a_inv * (y - self.b)) % self.n

    def node_writer(self, lo: int, hi: int) -> dict:
        """Payload for node rows [lo, hi): generated per aligned
        _GEN_CHUNK block so the content is io-chunking-independent."""
        feats = np.empty((hi - lo, self.f), np.float32)
        for g0 in range(lo - lo % _GEN_CHUNK, hi, _GEN_CHUNK):
            g1 = min(g0 + _GEN_CHUNK, self.n)
            rng = np.random.default_rng([self.seed, 23, g0 // _GEN_CHUNK])
            noise = rng.normal(0.0, 1.0,
                               (g1 - g0, self.f)).astype(np.float32)
            s0, s1 = max(lo, g0), min(hi, g1)
            lab = self.label(np.arange(s0, s1))
            block = self.signal * self.centroids[lab] + \
                noise[s0 - g0:s1 - g0]
            block /= np.linalg.norm(block, axis=1, keepdims=True) + 1e-6
            feats[s0 - lo:s1 - lo] = block
        u = np.arange(lo, hi)
        r = ((self.a2 * u.astype(np.int64) + self.b2) % self.n) / self.n
        s_tr, s_va = self.splits[0], self.splits[0] + self.splits[1]
        return {"features": feats, "labels": self.label(u),
                "train_mask": r < s_tr,
                "val_mask": (r >= s_tr) & (r < s_va),
                "test_mask": r >= s_va}


def stream_sbm_graph(path, n: int = 1_000_000, n_classes: int = 40,
                     feat_dim: int = 64, avg_degree: float = 8.0,
                     homophily: float = 0.85, feature_signal: float = 0.1,
                     splits=(0.6, 0.2, 0.2), seed: int = 0,
                     chunk_nodes: int | None = None,
                     chunk_edges: int | None = None):
    """SBM streamed to disk: the ``citation_graph`` structure at scales
    that never fit in memory.  Returns the :class:`GraphStore`."""
    from . import stream as st

    ora = _StreamLabels(n, n_classes, feat_dim, feature_signal, splits,
                        seed)
    p_in = avg_degree * homophily / 2.0        # undirected stubs per node
    p_out = avg_degree * (1.0 - homophily) / 2.0

    def emit(spill):
        for g0 in range(0, n, _GEN_CHUNK):
            g1 = min(g0 + _GEN_CHUNK, n)
            rng = np.random.default_rng([seed, 17, g0 // _GEN_CHUNK])
            u = np.arange(g0, g1, dtype=np.int64)
            # intra-class: partner is a uniform member of u's class
            ui = np.repeat(u, rng.poisson(p_in, len(u)))
            ci = ora.label(ui)
            vi = ora.member(ci, rng.integers(
                0, ora.class_count[ci], len(ui)))
            # inter-class: uniform partner anywhere
            uo = np.repeat(u, rng.poisson(p_out, len(u)))
            vo = rng.integers(0, n, len(uo))
            dst = np.concatenate([ui, vi, uo, vo])
            src = np.concatenate([vi, ui, vo, uo])   # both directions
            spill.add(dst, src)

    return st.spill_to_store(
        n, emit, path, name=f"stream-sbm-{n}", node_writer=ora.node_writer,
        feat_dim=feat_dim, num_classes=n_classes,
        chunk_nodes=chunk_nodes or st.CHUNK_NODES,
        chunk_edges=chunk_edges or st.CHUNK_EDGES)


def stream_powerlaw_graph(path, n: int = 1_000_000, n_classes: int = 47,
                          feat_dim: int = 64, avg_degree: float = 8.0,
                          alpha: float = 2.3, feature_signal: float = 0.1,
                          splits=(0.6, 0.2, 0.2), seed: int = 1,
                          chunk_nodes: int | None = None,
                          chunk_edges: int | None = None):
    """Chung-Lu power-law graph streamed to disk (``p(deg) ∝ deg^-alpha``
    — the hub-dominated profile of ``copurchase_graph`` at scale).

    Each node draws stubs proportional to its weight ``w(r) ∝ (r+1)^-γ``
    (``γ = 1/(alpha-1)``, rank ``r = π(i)`` so hubs scatter across the id
    space) and partners are sampled by inverse-CDF of the same weight
    law, giving the heavy-tailed joint degree profile that stresses
    partition cuts.  Returns the :class:`GraphStore`.
    """
    from . import stream as st

    ora = _StreamLabels(n, n_classes, feat_dim, feature_signal, splits,
                        seed)
    gamma = 1.0 / (alpha - 1.0)
    # mean weight over ranks, streamed (no O(n) resident vector)
    mean_w = 0.0
    for g0 in range(0, n, _GEN_CHUNK):
        r = np.arange(g0, min(g0 + _GEN_CHUNK, n), dtype=np.float64)
        mean_w += float(((r + 1.0) ** -gamma).sum())
    mean_w /= n
    a, a_inv, b = _affine(n, seed + 51)
    top = float(n) ** (1.0 - gamma)

    def emit(spill):
        for g0 in range(0, n, _GEN_CHUNK):
            g1 = min(g0 + _GEN_CHUNK, n)
            rng = np.random.default_rng([seed, 19, g0 // _GEN_CHUNK])
            u = np.arange(g0, g1, dtype=np.int64)
            rank = (a * u + b) % n
            w = (rank.astype(np.float64) + 1.0) ** -gamma
            stubs = rng.poisson(avg_degree * w / (2.0 * mean_w))
            us = np.repeat(u, stubs)
            # partner rank by inverse CDF of x^-γ on [1, n]
            x = (rng.random(len(us)) * (top - 1.0) + 1.0) \
                ** (1.0 / (1.0 - gamma))
            pr = np.minimum(x.astype(np.int64), n - 1)
            vs = (a_inv * (pr - b)) % n
            spill.add(np.concatenate([us, vs]), np.concatenate([vs, us]))

    return st.spill_to_store(
        n, emit, path, name=f"stream-powerlaw-{n}",
        node_writer=ora.node_writer, feat_dim=feat_dim,
        num_classes=n_classes,
        chunk_nodes=chunk_nodes or st.CHUNK_NODES,
        chunk_edges=chunk_edges or st.CHUNK_EDGES)


DATASETS = {
    "synth-arxiv": citation_graph,
    "synth-products": copurchase_graph,
    "tiny": tiny_graph,
}

STREAM_DATASETS = {
    "stream-sbm": stream_sbm_graph,
    "stream-powerlaw": stream_powerlaw_graph,
}


def load(name: str, **kw) -> GraphData:
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(DATASETS)}")
    return DATASETS[name](**kw)
