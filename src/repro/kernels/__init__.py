"""Pallas TPU kernels for the compute hot-spots, each with a pure-jnp
oracle in ref.py and a jit'd wrapper in ops.py (interpret mode off-TPU):

* varco_pack       — the paper's compression pack/unpack (lane-block
                     gather/scatter steered from SMEM scalar prefetch)
* flash_attention  — causal / sliding-window online-softmax attention (GQA)
* ell_spmm         — ELLPACK neighbour aggregation (GNN eq. 2 hot spot)
* ssd_chunk        — Mamba2 SSD intra-chunk quadratic form
"""
