"""ELLPACK SpMM Pallas TPU kernel — GNN neighbour aggregation.

The GNN hot spot is ``out[i] = Σ_k w[i,k] · x[nbr[i,k]]`` (eq. (2)'s ``S x``
with degree-padded ELL neighbour lists).  CSR row-gather is replaced by a
**source-chunked** formulation so arbitrary-size node sets stream through
VMEM:

  grid (node_tiles, feature_blocks, source_chunks); the kernel holds a
  ``[sc, bf]`` source-chunk slab of ``x`` in VMEM, gathers the neighbour
  rows that fall inside the chunk (others masked), and accumulates the
  weighted sum in a VMEM scratch, writing out on the last chunk.

Every neighbour gather is VMEM-local; HBM traffic is one pass over ``x``
per node tile.  Validated against ``ref.ell_spmm_reference`` in interpret
mode over shape sweeps incl. ragged/padded degrees.

The kernel itself is forward-only; the runtime's differentiable entry
point is :func:`repro.kernels.ops.ell_aggregate`, whose custom VJP runs
the *transpose* — the same SpMM over the reversed neighbour lists
(``repro.dist.halo.build_reverse_ell``) — so both directions of the p2p
wire's local aggregation stay on this kernel on TPU and on the jnp oracle
elsewhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ell_kernel(nbr_ref, w_ref, x_ref, out_ref, acc_scr, *,
                sc: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    nbr = nbr_ref[...]                       # [tn, K] int32 (global ids)
    w = w_ref[...]                           # [tn, K] f32
    x = x_ref[...]                           # [sc, bf] source chunk slab

    lo = ci * sc
    local = nbr - lo
    in_chunk = (local >= 0) & (local < sc)
    safe = jnp.where(in_chunk, local, 0)
    gathered = x[safe]                       # [tn, K, bf] VMEM gather
    wm = jnp.where(in_chunk, w, 0.0).astype(jnp.float32)
    acc_scr[...] += jnp.einsum("tk,tkf->tf", wm,
                               gathered.astype(jnp.float32))

    @pl.when(ci == n_chunks - 1)
    def _finalize():
        out_ref[...] = acc_scr[...].astype(out_ref.dtype)


def ell_spmm(x: jax.Array, nbr: jax.Array, w: jax.Array, *,
             tile_n: int = 128, block_f: int = 128, src_chunk: int = 1024,
             interpret: bool = False) -> jax.Array:
    """out[i] = Σ_k w[i,k] x[nbr[i,k]].

    x: [N_src, F]; nbr: [N_dst, K] int32 (pad entries may point anywhere
    with w == 0); w: [N_dst, K].  Returns [N_dst, F].
    """
    n_src, f = x.shape
    n_dst, k = nbr.shape
    tn = min(tile_n, n_dst)
    bf = min(block_f, f)
    sc = min(src_chunk, n_src)
    assert n_dst % tn == 0 and f % bf == 0 and n_src % sc == 0
    n_chunks = n_src // sc

    kernel = functools.partial(_ell_kernel, sc=sc, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(n_dst // tn, f // bf, n_chunks),
        in_specs=[
            pl.BlockSpec((tn, k), lambda i, j, c: (i, 0)),
            pl.BlockSpec((tn, k), lambda i, j, c: (i, 0)),
            pl.BlockSpec((sc, bf), lambda i, j, c: (c, j)),
        ],
        out_specs=pl.BlockSpec((tn, bf), lambda i, j, c: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_dst, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((tn, bf), jnp.float32)],
        interpret=interpret,
    )(nbr, w, x)
