"""Flash attention Pallas TPU kernel (causal / sliding-window, GQA).

TPU design: grid ``(B, H, S/bq, S/bk)`` with the KV index innermost so the
online-softmax accumulators (max ``m``, sum ``l``, output acc) live in VMEM
scratch across the KV sweep of one query tile.  Query/KV tiles are
``(bq, D)``/``(bk, D)`` VMEM blocks — MXU-aligned for D ∈ {64, 128, 256}.
GQA maps query head ``h`` to KV head ``h // group`` in the BlockSpec index
maps, so KV tiles are fetched once per group, not per query head.

Causal masking skips fully-masked KV tiles via ``@pl.when`` (the tile still
iterates — Pallas TPU grids are static — but does no compute, which is how
the production Splash kernels handle it too).

Validated in interpret mode against ``ref.mha_reference`` over
shape/dtype/window sweeps (tests/test_kernels_flash.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, n_kv: int, causal: bool, window: int,
                  scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk

    # tile-level skip: entirely above the diagonal / outside the window
    tile_live = True
    if causal:
        tile_live = k_start <= q_start + bq - 1
    if window > 0:
        tile_live = jnp.logical_and(
            tile_live, k_start + bk - 1 > q_start - window)

    @pl.when(tile_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)               # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)               # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B, H, S, D]; k/v: [B, KV, S, D] (KV divides H). Returns [B,H,S,D].
    """
    b, h, s, d = q.shape
    kvh = k.shape[1]
    assert h % kvh == 0, (h, kvh)
    group = h // kvh
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    n_q, n_kv = s // bq, s // bk
    scale = 1.0 / (d ** 0.5)

    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, n_kv=n_kv,
                               causal=causal, window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
