"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites run on this
CPU container (kernel bodies execute in Python) and compile to real Mosaic
kernels on TPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .ell_spmm import ell_spmm
from .flash_attention import flash_attention
from .varco_pack import block_mask_indices, varco_pack, varco_unpack


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def mha(q, k, v, *, causal: bool = True, window: int = 0,
        interpret: bool | None = None):
    """Flash attention. q [B,H,S,D], k/v [B,KV,S,D]."""
    it = _default_interpret() if interpret is None else interpret
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=it)


@partial(jax.jit, static_argnames=("interpret",))
def compress_pack(x, block_idx, *, interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return varco_pack(x, block_idx, interpret=it)


@partial(jax.jit, static_argnames=("interpret",))
def compress_unpack(packed, inv_idx, *, interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return varco_unpack(packed, inv_idx, interpret=it)


@partial(jax.jit, static_argnames=("rate", "n_blocks"))
def compression_indices(key, n_blocks: int, rate: float):
    return block_mask_indices(key, n_blocks, rate)


def compress_roundtrip(key, x, rate: float, *, interpret: bool | None = None):
    """Full VARCO compress→wire→decompress round trip via the kernels."""
    n_blocks = x.shape[-1] // 128
    kept, inv = block_mask_indices(key, n_blocks, rate)
    packed = compress_pack(x, kept, interpret=interpret)
    wire_bits = packed.size * jnp.finfo(packed.dtype).bits
    return compress_unpack(packed, inv, interpret=interpret), wire_bits


@partial(jax.jit, static_argnames=("interpret",))
def aggregate(x, nbr, w, *, interpret: bool | None = None):
    """ELL neighbour aggregation. x [N_src,F], nbr/w [N_dst,K]."""
    it = _default_interpret() if interpret is None else interpret
    return ell_spmm(x, nbr, w, interpret=it)


# re-exported oracles (benchmarks compare against these)
mha_reference = ref.mha_reference
pack_reference = ref.pack_reference
unpack_reference = ref.unpack_reference
ell_spmm_reference = ref.ell_spmm_reference
ssd_reference = ref.ssd_reference
