"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites run on this
CPU container (kernel bodies execute in Python) and compile to real Mosaic
kernels on TPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .ell_spmm import ell_spmm
from .flash_attention import flash_attention
from .varco_pack import (LANE, block_mask_indices, varco_pack,
                         varco_pack_quant, varco_unpack, varco_unpack_quant)

#: wire bit-widths the quantised codecs speak — 32 is the fp32
#: passthrough, the rest are symmetric per-lane-block int formats
#: (qmax = 2^(w-1) - 1) bit-packed to true sub-byte storage: 8/w lanes
#: per byte, so the buffers carry exactly the ledger's w bits per lane.
WIRE_WIDTHS = (2, 4, 8, 32)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def mha(q, k, v, *, causal: bool = True, window: int = 0,
        interpret: bool | None = None):
    """Flash attention. q [B,H,S,D], k/v [B,KV,S,D]."""
    it = _default_interpret() if interpret is None else interpret
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=it)


@partial(jax.jit, static_argnames=("interpret",))
def compress_pack(x, block_idx, *, interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return varco_pack(x, block_idx, interpret=it)


@partial(jax.jit, static_argnames=("interpret",))
def compress_unpack(packed, inv_idx, *, interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return varco_unpack(packed, inv_idx, interpret=it)


@partial(jax.jit, static_argnames=("rate", "n_blocks"))
def compression_indices(key, n_blocks: int, rate: float):
    return block_mask_indices(key, n_blocks, rate)


def compress_roundtrip(key, x, rate: float, *, interpret: bool | None = None):
    """Full VARCO compress→wire→decompress round trip via the kernels."""
    n_blocks = x.shape[-1] // 128
    kept, inv = block_mask_indices(key, n_blocks, rate)
    packed = compress_pack(x, kept, interpret=interpret)
    wire_bits = packed.size * jnp.finfo(packed.dtype).bits
    return compress_unpack(packed, inv, interpret=interpret), wire_bits


# ---------------------------------------------------------------------------
# Differentiable wire ops (the packed halo-exchange payload path)
# ---------------------------------------------------------------------------
#
# ``wire_pack`` / ``wire_unpack`` are what the distributed runtime puts on
# the wire (DESIGN.md §3.3): Pallas kernels on TPU, the jnp ``ref`` oracles
# on every other backend — interpret-mode Pallas executes kernel bodies in
# Python, far too slow for a train loop, while the oracles are ordinary XLA
# gathers.  Gradients flow through the wire (Algorithm 1 back-propagates
# across machines), so both ops carry custom VJPs: pack and unpack are each
# other's transpose under the same (kept, inv) index pair.


def _padded_rows(n: int, tile: int = 256) -> int:
    """Row count the Pallas kernels accept: their ``tile_n`` grid needs
    ``n % min(tile, n) == 0``, but the runtime feeds arbitrary boundary
    counts (B = halo_size).  Pad small inputs to the f32 sublane (8), large
    ones to a whole tile."""
    if n <= tile:
        return -(-n // 8) * 8
    return -(-n // tile) * tile


def _pad_call(kernel, x, idx):
    n = x.shape[0]
    pad = _padded_rows(n) - n
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = kernel(x, idx)
    return out[:n] if pad else out


def _pack_impl(x, kept):
    if jax.default_backend() == "tpu":
        return _pad_call(varco_pack, x, kept)
    return ref.pack_reference(x, kept)


def _unpack_impl(packed, inv):
    if jax.default_backend() == "tpu":
        return _pad_call(varco_unpack, packed, inv)
    return ref.unpack_reference(packed, inv)


@jax.custom_vjp
def wire_pack(x, kept, inv):
    """Gather kept lane-blocks for the wire: ``[N, F] -> [N, K*128]``.

    ``kept``/``inv`` must be the matched pair from
    :func:`repro.kernels.varco_pack.block_mask_indices`; ``inv`` is carried
    for the backward scatter.
    """
    del inv
    return _pack_impl(x, kept)


def _wire_pack_fwd(x, kept, inv):
    return _pack_impl(x, kept), (kept, inv)


def _wire_pack_bwd(res, g):
    _, inv = res
    return _unpack_impl(g, inv), None, None


wire_pack.defvjp(_wire_pack_fwd, _wire_pack_bwd)


@jax.custom_vjp
def wire_unpack(packed, kept, inv):
    """Scatter a received wire payload back: ``[N, K*128] -> [N, F]``,
    zero-filling dropped blocks (the paper's decoder)."""
    del kept
    return _unpack_impl(packed, inv)


def _wire_unpack_fwd(packed, kept, inv):
    return _unpack_impl(packed, inv), (kept, inv)


def _wire_unpack_bwd(res, g):
    kept, _ = res
    return _pack_impl(g, kept), None, None


wire_unpack.defvjp(_wire_unpack_fwd, _wire_unpack_bwd)


# ---------------------------------------------------------------------------
# Quantised wire codecs (DESIGN.md §3.8)
# ---------------------------------------------------------------------------
#
# ``quant_dequant`` is the value-level model of the low-bit wire: what a
# receiver reconstructs from an int-``width`` payload plus the per-block
# fp32 scales.  The *width* operand may be a traced array (per-pair
# widths change every step under the controllers), which works because
# the arithmetic — qmax = 2^(w-1)-1, scale = amax/qmax, round, clip —
# is ordinary elementwise math; only the storage dtype needs a static
# width, and that lives in the fused Pallas kernel
# (``varco_pack_quant``) / ``pack_quant`` below.


def quant_levels(x, width, *, key=None):
    """Per-lane-block symmetric quantisation *levels* plus scales.

    ``x [..., nb*LANE]`` -> ``(levels int8 [..., nb*LANE], scales f32
    [..., nb])``.  ``width`` may be a traced scalar or array
    broadcastable against the per-block scale array (per-pair widths
    change every step under the controllers); the *storage* stays int8
    here — :func:`pack_bits` squeezes the levels to true sub-byte bytes
    at the step's static storage width.  Deterministic round-to-nearest
    by default; pass ``key`` for stochastic rounding ``floor(v + u)``
    (same uniform stream :func:`quant_dequant` draws, so the two agree
    bitwise).  ``width >= 32`` yields wrapped garbage levels — callers
    on the fp32 passthrough discard them (as :func:`quant_dequant`'s
    ``where`` does).
    """
    lead = x.shape[:-1]
    nb = x.shape[-1] // LANE
    xb = x.reshape(*lead, nb, LANE)
    w = jnp.asarray(width, jnp.float32)
    qmax = 2.0 ** (w - 1.0) - 1.0
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    v = xb / scale[..., None]
    if key is None:
        qv = jnp.rint(v)
    else:
        qv = jnp.floor(v + jax.random.uniform(key, xb.shape))
    qv = jnp.clip(qv, -qmax[..., None], qmax[..., None])
    return qv.astype(jnp.int8).reshape(x.shape), scale


def quant_dequant(x, width, *, key=None):
    """Symmetric per-lane-block quantise→dequantise at ``width`` bits.

    ``x [..., nb*LANE]``; ``width`` — scalar or array broadcastable
    against the per-block scale array ``[..., nb]`` (e.g. per-pair
    widths ``w[:, :, None, None]`` against hops ``[Q, D, H, nb]``).
    ``width >= 32`` is an exact fp32 passthrough.  Deterministic
    round-to-nearest by default (the parity-checked wire behaviour,
    identical on both backends); pass ``key`` for stochastic rounding
    ``floor(v + u)``, ``u ~ U[0, 1)`` — unbiased in expectation.
    Per-element error ≤ ``amax_block / (2^(width-1) - 1)``.

    Built on :func:`quant_levels` — for sub-32 widths the int8 levels
    round-trip exactly (|level| ≤ 127), so the decode here is bitwise
    what a receiver reconstructs from the bit-packed wire bytes.
    """
    lead = x.shape[:-1]
    nb = x.shape[-1] // LANE
    xb = x.reshape(*lead, nb, LANE)
    w = jnp.asarray(width, jnp.float32)
    levels, scale = quant_levels(x, width, key=key)
    lb = levels.astype(jnp.float32).reshape(*lead, nb, LANE)
    dq = lb * scale[..., None]
    out = jnp.where(jnp.broadcast_to(w >= 32.0, scale.shape)[..., None],
                    xb, dq)
    return out.reshape(x.shape)


def pack_bits(levels, width: int):
    """Bit-pack int-``width`` levels to bytes: ``[..., M] -> [...,
    ceil(M·width/8)]`` uint8, ``8/width`` lanes per byte little-endian
    (``width == 8`` is the identity reinterpret).  jnp on every backend
    — the fused Pallas kernels pack in-register (``varco_pack_quant``);
    this is the standalone codec the transport layers and tests use."""
    return ref.pack_bits_reference(levels, width)


def unpack_bits(packed, width: int, m: int | None = None):
    """Inverse of :func:`pack_bits`: sign-extend each ``width``-bit
    field back to int8 levels (``m`` trims tail-byte zero-pad lanes)."""
    return ref.unpack_bits_reference(packed, width, m)


def dequant_bits(payload, scales, width: int):
    """Value-level decode of a sub-byte wire buffer: payload uint8
    ``[..., K·LANE·width/8]`` × scales f32 ``[..., K]`` -> f32
    ``[..., K·LANE]``.  Bitwise the ``levels · scale`` dequantise of
    :func:`quant_dequant` — what every receiver reconstructs from the
    bytes that actually crossed the wire."""
    k = scales.shape[-1]
    levels = ref.unpack_bits_reference(payload, width, k * LANE)
    lb = levels.astype(jnp.float32).reshape(*scales.shape, LANE)
    return (lb * scales[..., None]).reshape(*payload.shape[:-1], k * LANE)


def wire_quant(x, width, *, key=None):
    """Straight-through :func:`quant_dequant`: the forward sees the
    quantised wire values, the backward passes gradients through
    unchanged (the STE the ratectl error-feedback loop assumes)."""
    return x + jax.lax.stop_gradient(quant_dequant(x, width, key=key) - x)


#: fold_in salt separating the stochastic-rounding key stream from the
#: mask-selection streams that share the per-exchange key (DESIGN.md §3.8)
ROUND_SALT = 0x5EED


def default_wire_rounding() -> str:
    """Default rounding mode of the quantised wire on this backend:
    ``"stochastic"`` on TPU (unbiased ``floor(v + u)`` — the hardware
    target, where the paper's convergence argument wants an unbiased
    codec), ``"rint"`` elsewhere (deterministic round-to-nearest — the
    parity-checked CPU behaviour every golden trace is pinned under).
    Callers may always opt into either mode explicitly; this is only the
    ``rounding=None`` resolution used by ``make_auto_train_step``."""
    return "stochastic" if jax.default_backend() == "tpu" else "rint"


def round_key(key, sender, hop=None):
    """Per-(pair) stochastic-rounding key schedule: the shared
    per-exchange key (already ``fold_in(step key, call)``) is salted away
    from the mask streams, then folded with the *sender* index and — on
    the p2p wire — the ring-hop index, so every ordered pair draws its
    own uniforms and the emulated backend (vmapping over senders) and the
    shard_map backend (each worker its own ``sender``) consume identical
    streams.  The (seed, step, pair) derivation: seed and step live in
    the exchange key, the pair in the folds here."""
    k = jax.random.fold_in(jax.random.fold_in(key, ROUND_SALT), sender)
    return k if hop is None else jax.random.fold_in(k, hop)


def per_block_wire_bits(width):
    """On-wire bits of ONE kept lane-block per row at ``width``: the
    ``LANE·width`` payload plus the fp32 scale — the accounting
    convention the int8 dense compressor established (scales charged
    fully).  ``width >= 32`` means fp32 on the wire: no scale travels,
    so the charge stays exactly ``LANE·32`` and pre-quantisation ledgers
    are reproduced bit-for-bit.  ``width`` may be a traced array."""
    w = jnp.asarray(width, jnp.float32)
    return jnp.where(w >= 32.0, LANE * 32.0, LANE * w + 32.0)


def _pack_quant_impl(x, kept, width: int):
    if jax.default_backend() == "tpu":
        n = x.shape[0]
        pad = _padded_rows(n) - n
        if pad:
            x = jnp.pad(x, ((0, pad), (0, 0)))
        packed, scales = varco_pack_quant(x, kept, width=width)
        return (packed[:n], scales[:n]) if pad else (packed, scales)
    return ref.pack_quant_reference(x, kept, width)


@partial(jax.jit, static_argnames=("width", "interpret"))
def pack_quant(x, kept, *, width: int, interpret: bool | None = None):
    """Fused pack+quantise+bit-pack entry point: ``[N, F] -> (payload
    uint8 [N, K*128*width/8], scales f32 [N, K])`` in one kernel launch
    (Pallas on TPU, the ``ref`` oracle elsewhere).  The payload carries
    the ledger's exact ``LANE·width`` bits per kept block — ``8/width``
    lanes per byte, ``width == 8`` bitwise the former int8 storage.
    Decode with :func:`unpack_quant` (fused) or
    :func:`dequant_bits` (+ ``wire_unpack`` for the scatter)."""
    if interpret is not None and interpret:
        n = x.shape[0]
        pad = _padded_rows(n) - n
        if pad:
            x = jnp.pad(x, ((0, pad), (0, 0)))
        packed, scales = varco_pack_quant(x, kept, width=width,
                                          interpret=True)
        return (packed[:n], scales[:n]) if pad else (packed, scales)
    return _pack_quant_impl(x, kept, width)


def _unpack_quant_impl(payload, scales, inv, width: int):
    if jax.default_backend() == "tpu":
        n = payload.shape[0]
        pad = _padded_rows(n) - n
        if pad:
            payload = jnp.pad(payload, ((0, pad), (0, 0)))
            scales = jnp.pad(scales, ((0, pad), (0, 0)))
        out = varco_unpack_quant(payload, scales, inv, width=width)
        return out[:n] if pad else out
    return ref.unpack_reference(
        ref.unpack_quant_reference(payload, scales, width), inv)


@partial(jax.jit, static_argnames=("width", "interpret"))
def unpack_quant(payload, scales, inv, *, width: int,
                 interpret: bool | None = None):
    """Fused receive-side decode: bit-unpack + dequantise + scatter in
    one launch — ``(payload uint8 [N, K*128*width/8], scales f32
    [N, K], inv [F/128]) -> f32 [N, F]`` with dropped blocks
    zero-filled (Pallas ``varco_unpack_quant`` on TPU, the ``ref``
    oracles elsewhere)."""
    if interpret is not None and interpret:
        n = payload.shape[0]
        pad = _padded_rows(n) - n
        if pad:
            payload = jnp.pad(payload, ((0, pad), (0, 0)))
            scales = jnp.pad(scales, ((0, pad), (0, 0)))
        out = varco_unpack_quant(payload, scales, inv, width=width,
                                 interpret=True)
        return out[:n] if pad else out
    return _unpack_quant_impl(payload, scales, inv, width)


@partial(jax.jit, static_argnames=("interpret",))
def aggregate(x, nbr, w, *, interpret: bool | None = None):
    """Forward-only ELL neighbour aggregation (kernel correctness surface).
    The runtime's differentiable entry point is :func:`ell_aggregate`."""
    it = _default_interpret() if interpret is None else interpret
    return ell_spmm(x, nbr, w, interpret=it)


# ---------------------------------------------------------------------------
# Differentiable ELL aggregation (the p2p wire's local-edge hot path)
# ---------------------------------------------------------------------------
#
# ``ell_aggregate`` is what ``repro.dist.gnn_parallel`` runs over each
# partition's local edges on the p2p wire: the Pallas ``ell_spmm`` kernel on
# TPU (rows padded to its grid), the ``ref.ell_spmm_reference`` jnp oracle
# elsewhere (interpret-mode Pallas is far too slow for a train loop).  The
# custom VJP keeps gradients on the same kernel path: the transpose of an
# ELL SpMM is the ELL SpMM over the *reversed* neighbour lists
# (``repro.dist.halo.build_reverse_ell``), whose weights are gathered from
# the forward weights via the ``rslot`` flat map.


def _ell_cpu(x, nbr, w):
    """Oracle-equivalent ELL SpMM for XLA:CPU/GPU: K-sliced fused
    accumulation (k-ascending, like the kernel's einsum) instead of the
    ``ref`` oracle's ``[N, K, F]`` gather materialisation, which dominates
    the emulated train loop at realistic degrees."""
    def body(k, acc):
        return acc + w[:, k].astype(jnp.float32)[:, None] * \
            x[nbr[:, k]].astype(jnp.float32)

    acc = jnp.zeros((nbr.shape[0], x.shape[1]), jnp.float32)
    return jax.lax.fori_loop(0, nbr.shape[1], body, acc).astype(x.dtype)


def _ell_impl(x, nbr, w):
    if jax.default_backend() != "tpu":
        return _ell_cpu(x, nbr, w)
    n_dst, _ = nbr.shape
    n_src, f = x.shape
    tn = 128 if n_dst >= 128 else -(-n_dst // 8) * 8
    sc = 1024 if n_src >= 1024 else -(-n_src // 8) * 8
    bf = 128 if f % 128 == 0 else f
    nd_p = -(-n_dst // tn) * tn
    ns_p = -(-n_src // sc) * sc
    xp = jnp.pad(x, ((0, ns_p - n_src), (0, 0))) if ns_p > n_src else x
    nbr_p = jnp.pad(nbr, ((0, nd_p - n_dst), (0, 0))) if nd_p > n_dst else nbr
    w_p = jnp.pad(w, ((0, nd_p - n_dst), (0, 0))) if nd_p > n_dst else w
    out = ell_spmm(xp, nbr_p, w_p, tile_n=tn, block_f=bf, src_chunk=sc)
    return out[:n_dst] if nd_p > n_dst else out


@jax.custom_vjp
def ell_aggregate(x, nbr, w, rnbr, rslot):
    """Differentiable ELL aggregation: ``out[i] = Σ_k w[i,k] x[nbr[i,k]]``.

    ``x [N_src, F]``; ``nbr``/``w [N_dst, K]`` (pad entries carry ``w ==
    0``); ``rnbr``/``rslot [N_src, RK]`` are the static reversed lists from
    :func:`repro.dist.halo.build_reverse_ell` — ``rslot`` gathers the
    matching forward weight (``-1`` pad), so the x-cotangent is the
    reversed-list ELL SpMM (the exact transpose of the forward).
    """
    del rnbr, rslot
    return _ell_impl(x, nbr, w)


def _ell_aggregate_fwd(x, nbr, w, rnbr, rslot):
    return _ell_impl(x, nbr, w), (x, nbr, w, rnbr, rslot)


def _ell_aggregate_bwd(res, g):
    x, nbr, w, rnbr, rslot = res
    rw = jnp.where(rslot >= 0, w.reshape(-1)[jnp.maximum(rslot, 0)], 0.0)
    dx = _ell_impl(g, rnbr, rw).astype(x.dtype)

    # dw[i, k] = <g[i], x[nbr[i, k]]> — K-sliced like _ell_cpu, never the
    # [N, K, F] gather.  (In the train loop graph weights are not
    # differentiated, so XLA DCEs this branch entirely.)
    gf = g.astype(jnp.float32)

    def body(k, acc):
        return acc.at[:, k].set(
            jnp.sum(gf * x[nbr[:, k]].astype(jnp.float32), axis=-1))

    dw = jax.lax.fori_loop(0, nbr.shape[1], body,
                           jnp.zeros(nbr.shape, jnp.float32)).astype(w.dtype)
    return dx, None, dw, None, None


ell_aggregate.defvjp(_ell_aggregate_fwd, _ell_aggregate_bwd)


# re-exported oracles (benchmarks compare against these)
mha_reference = ref.mha_reference
pack_reference = ref.pack_reference
unpack_reference = ref.unpack_reference
pack_quant_reference = ref.pack_quant_reference
quant_dequant_reference = ref.quant_dequant_reference
pack_bits_reference = ref.pack_bits_reference
unpack_bits_reference = ref.unpack_bits_reference
unpack_quant_reference = ref.unpack_quant_reference
ell_spmm_reference = ref.ell_spmm_reference
ssd_reference = ref.ssd_reference
