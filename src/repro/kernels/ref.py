"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128


def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q: [B,H,S,D]; k/v: [B,KV,S,D]. Dense masked softmax attention."""
    b, h, s, d = q.shape
    kvh = k.shape[1]
    k = jnp.repeat(k, h // kvh, axis=1)
    v = jnp.repeat(v, h // kvh, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def pack_reference(x: jax.Array, block_idx: jax.Array) -> jax.Array:
    """Gather kept lane-blocks. x [N, F] -> [N, K*LANE]."""
    n, f = x.shape
    xb = x.reshape(n, f // LANE, LANE)
    return xb[:, block_idx].reshape(n, -1)


def unpack_reference(packed: jax.Array, inv_idx: jax.Array) -> jax.Array:
    """Scatter kept blocks; zero dropped. packed [N, K*LANE] -> [N, F]."""
    n = packed.shape[0]
    k = packed.shape[1] // LANE
    nf = inv_idx.shape[0]
    pb = packed.reshape(n, k, LANE)
    safe = jnp.maximum(inv_idx, 0)
    out = pb[:, safe]                              # [N, F/LANE, LANE]
    out = jnp.where((inv_idx >= 0)[None, :, None], out, 0)
    return out.reshape(n, nf * LANE)


def pack_quant_reference(x: jax.Array, block_idx: jax.Array, width: int
                         ) -> tuple[jax.Array, jax.Array]:
    """Fused pack+quantise oracle: gather kept lane-blocks and quantise
    each to ``width`` bits with one symmetric per-(row, block) scale.

    x [N, F], block_idx [K] -> (packed int8 [N, K*LANE], scales f32
    [N, K]).  ``qmax = 2^(width-1) - 1``; zero blocks get scale 1 so the
    dequantise is exact there too.  This is the jnp reference for the
    Pallas ``varco_pack_quant`` kernel (one VMEM pass; the amax, the
    scale and the rounded int8 block come out of the same tile visit).
    """
    packed = pack_reference(x, block_idx)
    n, kf = packed.shape
    k = kf // LANE
    qmax = float(2 ** (width - 1) - 1)
    pb = packed.reshape(n, k, LANE)
    amax = jnp.max(jnp.abs(pb), axis=-1)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.rint(pb / scale[..., None]), -qmax, qmax)
    return q.astype(jnp.int8).reshape(n, kf), scale


def quant_dequant_reference(packed_q: jax.Array, scales: jax.Array
                            ) -> jax.Array:
    """Decode a quantised wire payload: int8 [N, K*LANE] × scales [N, K]
    -> f32 [N, K*LANE] (the receiver's side of ``pack_quant_reference``)."""
    n, kf = packed_q.shape
    k = kf // LANE
    pb = packed_q.astype(jnp.float32).reshape(n, k, LANE)
    return (pb * scales[..., None]).reshape(n, kf)


def ell_spmm_reference(x: jax.Array, nbr: jax.Array, w: jax.Array
                       ) -> jax.Array:
    """out[i] = sum_k w[i,k] x[nbr[i,k]]."""
    gathered = x[nbr]                              # [N_dst, K, F]
    return jnp.einsum("tk,tkf->tf", w.astype(jnp.float32),
                      gathered.astype(jnp.float32)).astype(x.dtype)


def ssd_reference(x, dt, a_log, b, c, d_skip):
    """Sequential (non-chunked) SSD recurrence — oracle for ssd_chunked.

    x: [B,T,H,P]  dt: [B,T,H]  a_log: [H]  b,c: [B,T,G,N]  d_skip: [H]
    """
    bsz, t, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    bg = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    cg = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp                     # [B,H,P],[B,H],[B,H,N]x2
        da = jnp.exp(dtt * a)                     # [B,H]
        state = state * da[..., None, None] + jnp.einsum(
            "bhp,bhk->bhpk", dtt[..., None] * xt, bt)
        y = jnp.einsum("bhpk,bhk->bhp", state, ct)
        return state, y

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, init,
                         (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
                          jnp.moveaxis(bg, 1, 0), jnp.moveaxis(cg, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)                    # [B,T,H,P]
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype)
