"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128


def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q: [B,H,S,D]; k/v: [B,KV,S,D]. Dense masked softmax attention."""
    b, h, s, d = q.shape
    kvh = k.shape[1]
    k = jnp.repeat(k, h // kvh, axis=1)
    v = jnp.repeat(v, h // kvh, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def pack_reference(x: jax.Array, block_idx: jax.Array) -> jax.Array:
    """Gather kept lane-blocks. x [N, F] -> [N, K*LANE]."""
    n, f = x.shape
    xb = x.reshape(n, f // LANE, LANE)
    return xb[:, block_idx].reshape(n, -1)


def unpack_reference(packed: jax.Array, inv_idx: jax.Array) -> jax.Array:
    """Scatter kept blocks; zero dropped. packed [N, K*LANE] -> [N, F]."""
    n = packed.shape[0]
    k = packed.shape[1] // LANE
    nf = inv_idx.shape[0]
    pb = packed.reshape(n, k, LANE)
    safe = jnp.maximum(inv_idx, 0)
    out = pb[:, safe]                              # [N, F/LANE, LANE]
    out = jnp.where((inv_idx >= 0)[None, :, None], out, 0)
    return out.reshape(n, nf * LANE)


def pack_bits_reference(levels: jax.Array, width: int) -> jax.Array:
    """Bit-pack int-``width`` levels into bytes (the sub-byte wire layout).

    ``levels [..., M]`` int8 values in ``[-qmax, qmax]`` -> uint8
    ``[..., ceil(M / (8/width))]``.  Each byte holds ``8/width``
    consecutive lanes, little-endian within the byte: lane ``i`` lives in
    byte ``i // (8/w)`` at bit offset ``(i % (8/w)) * w``, stored as the
    low ``w`` bits of its two's complement.  ``width == 8`` is the
    identity reinterpret (one lane per byte — bitwise the int8 storage
    the pre-packing wire shipped).  Tail lanes (``M`` not a multiple of
    ``8/w``) are zero-padded into the last byte.
    """
    assert width in (2, 4, 8), width
    lv = levels.astype(jnp.int8)
    if width == 8:
        return jax.lax.bitcast_convert_type(lv, jnp.uint8)
    vpb = 8 // width
    m = lv.shape[-1]
    pad = (-m) % vpb
    if pad:
        lv = jnp.pad(lv, [(0, 0)] * (lv.ndim - 1) + [(0, pad)])
    u = jax.lax.bitcast_convert_type(lv, jnp.uint8) & jnp.uint8(2 ** width - 1)
    u = u.reshape(*lv.shape[:-1], -1, vpb)
    out = u[..., 0]
    for j in range(1, vpb):
        out = out | (u[..., j] << jnp.uint8(j * width))
    return out


def unpack_bits_reference(packed: jax.Array, width: int,
                          m: int | None = None) -> jax.Array:
    """Inverse of :func:`pack_bits_reference`: uint8 bytes -> int8 levels.

    ``m`` trims the trailing zero-pad lanes of a tail byte (defaults to
    every stored lane, ``bytes · 8/width``).  Sign-extends each ``width``-
    bit field (values ``>= 2^(w-1)`` wrap negative).
    """
    assert width in (2, 4, 8), width
    if width == 8:
        out = jax.lax.bitcast_convert_type(packed, jnp.int8)
        return out if m is None else out[..., :m]
    vpb = 8 // width
    mask = jnp.uint8(2 ** width - 1)
    shifts = jnp.arange(vpb, dtype=jnp.uint8) * jnp.uint8(width)
    fields = (packed[..., None] >> shifts) & mask       # [..., B, vpb]
    v = fields.astype(jnp.int32)
    v = jnp.where(v >= 2 ** (width - 1), v - 2 ** width, v)
    out = v.astype(jnp.int8).reshape(*packed.shape[:-1], -1)
    return out[..., : (m if m is not None else out.shape[-1])]


def quant_levels_reference(packed: jax.Array, width: int
                           ) -> tuple[jax.Array, jax.Array]:
    """Per-(row, block) symmetric quantisation of a packed fp32 payload:
    [N, K*LANE] -> (int8 levels [N, K*LANE], scales f32 [N, K]).
    ``qmax = 2^(width-1) - 1``; zero blocks get scale 1 so the dequantise
    is exact there too."""
    n, kf = packed.shape
    k = kf // LANE
    qmax = float(2 ** (width - 1) - 1)
    pb = packed.reshape(n, k, LANE)
    amax = jnp.max(jnp.abs(pb), axis=-1)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.rint(pb / scale[..., None]), -qmax, qmax)
    return q.astype(jnp.int8).reshape(n, kf), scale


def pack_quant_reference(x: jax.Array, block_idx: jax.Array, width: int
                         ) -> tuple[jax.Array, jax.Array]:
    """Fused pack+quantise oracle: gather kept lane-blocks, quantise each
    to ``width`` bits with one symmetric per-(row, block) scale, and
    bit-pack the levels into true sub-byte storage.

    x [N, F], block_idx [K] -> (payload uint8 [N, K*LANE*width/8],
    scales f32 [N, K]).  ``qmax = 2^(width-1) - 1``; byte layout per
    :func:`pack_bits_reference` (``8/width`` lanes per byte, little-
    endian; ``width == 8`` stores bitwise the int8 lanes of the
    pre-packing wire).  This is the jnp reference for the Pallas
    ``varco_pack_quant`` kernel (one VMEM pass; amax, scale, rounded
    levels and the packed bytes come out of the same tile visit).
    Decode with :func:`unpack_quant_reference`.
    """
    levels, scale = quant_levels_reference(pack_reference(x, block_idx),
                                           width)
    return pack_bits_reference(levels, width), scale


def quant_dequant_reference(levels: jax.Array, scales: jax.Array
                            ) -> jax.Array:
    """Decode *unpacked* quantisation levels: int8 [N, K*LANE] × scales
    [N, K] -> f32 [N, K*LANE]."""
    n, kf = levels.shape
    k = kf // LANE
    pb = levels.astype(jnp.float32).reshape(n, k, LANE)
    return (pb * scales[..., None]).reshape(n, kf)


def unpack_quant_reference(payload: jax.Array, scales: jax.Array,
                           width: int) -> jax.Array:
    """Receiver's side of :func:`pack_quant_reference`: sub-byte payload
    uint8 [N, K*LANE*width/8] × scales [N, K] -> f32 [N, K*LANE]."""
    k = scales.shape[-1]
    levels = unpack_bits_reference(payload, width, k * LANE)
    return quant_dequant_reference(levels, scales)


def ell_spmm_reference(x: jax.Array, nbr: jax.Array, w: jax.Array
                       ) -> jax.Array:
    """out[i] = sum_k w[i,k] x[nbr[i,k]]."""
    gathered = x[nbr]                              # [N_dst, K, F]
    return jnp.einsum("tk,tkf->tf", w.astype(jnp.float32),
                      gathered.astype(jnp.float32)).astype(x.dtype)


def ssd_reference(x, dt, a_log, b, c, d_skip):
    """Sequential (non-chunked) SSD recurrence — oracle for ssd_chunked.

    x: [B,T,H,P]  dt: [B,T,H]  a_log: [H]  b,c: [B,T,G,N]  d_skip: [H]
    """
    bsz, t, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    bg = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    cg = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp                     # [B,H,P],[B,H],[B,H,N]x2
        da = jnp.exp(dtt * a)                     # [B,H]
        state = state * da[..., None, None] + jnp.einsum(
            "bhp,bhk->bhpk", dtt[..., None] * xt, bt)
        y = jnp.einsum("bhpk,bhk->bhp", state, ct)
        return state, y

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, init,
                         (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
                          jnp.moveaxis(bg, 1, 0), jnp.moveaxis(cg, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)                    # [B,T,H,P]
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype)
