"""Mamba2 SSD intra-chunk Pallas TPU kernel.

The SSD hot spot (arXiv:2405.21060 §6) is the *intra-chunk quadratic form*:
for each (batch, chunk, head),

    M[t, s]   = (C_t · B_s) · exp(cum_t − cum_s) · dt_s · 1[s ≤ t]
    Y_intra   = M @ X                          ([Q, Q] @ [Q, P] — MXU)
    S_contrib = (exp(cum_end − cum) · dt · B)ᵀ @ X    ([N, Q] @ [Q, P])

This kernel fuses both matmuls and the decay/mask elementwise work over a
``(B, NC, H)`` grid with ``[Q, N]`` / ``[Q, P]`` VMEM tiles (Q=chunk ≤ 256,
N=d_state 128, P=head_dim 64 — all MXU-aligned).  The inter-chunk
recurrence stays a ``lax.scan`` over the per-chunk ``S_contrib`` outputs
(tiny [H, P, N] state), exactly the split the paper's decomposition calls
for on TPU.

Validated against the pure-jnp chunk math derived from
``kernels.ref.ssd_reference`` in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref, s_ref):
    x = x_ref[0, :, 0, :].astype(jnp.float32)        # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # [Q]
    cum = cum_ref[0, :, 0].astype(jnp.float32)       # [Q]
    b = b_ref[0, :, 0, :].astype(jnp.float32)        # [Q, N]
    c = c_ref[0, :, 0, :].astype(jnp.float32)        # [Q, N]
    q = x.shape[0]

    # intra-chunk scores with segment decay + causal mask + dt weighting
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    seg = cum[:, None] - cum[None, :]                # cum_t - cum_s
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(s_idx <= t_idx, jnp.exp(seg), 0.0)
    m = scores * decay * dt[None, :]
    y_ref[0, :, 0, :] = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    # chunk state contribution: [P, Q] @ [Q, N] (stored as [P, N])
    decay_to_end = jnp.exp(cum[-1] - cum) * dt       # [Q]
    bw = b * decay_to_end[:, None]                   # [Q, N]
    s_ref[0, :, 0, :] = jax.lax.dot_general(
        x, bw, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(s_ref.dtype)  # [P, N]


def ssd_chunk(x: jax.Array, dt: jax.Array, cum: jax.Array, b: jax.Array,
              c: jax.Array, *, interpret: bool = False
              ) -> tuple[jax.Array, jax.Array]:
    """Intra-chunk SSD for all (batch, chunk, head) tiles.

    x:   [B, NC, Q, H, P]   (chunked inputs, already dt-free)
    dt:  [B, NC, Q, H]
    cum: [B, NC, Q, H]      (within-chunk inclusive cumsum of dt*A)
    b,c: [B, NC, Q, H, N]   (group-expanded)
    Returns (y_intra [B,NC,Q,H,P], state_contrib [B,NC,H,P,N]).
    """
    bsz, nc, q, h, p = x.shape
    n = b.shape[-1]

    grid = (bsz * nc, h)
    xr = x.reshape(bsz * nc, q, h, p)
    dtr = dt.reshape(bsz * nc, q, h)
    cumr = cum.reshape(bsz * nc, q, h)
    br = b.reshape(bsz * nc, q, h, n)
    cr = c.reshape(bsz * nc, q, h, n)

    y, s = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda i, hi: (i, 0, hi, 0)),
            pl.BlockSpec((1, q, 1), lambda i, hi: (i, 0, hi)),
            pl.BlockSpec((1, q, 1), lambda i, hi: (i, 0, hi)),
            pl.BlockSpec((1, q, 1, n), lambda i, hi: (i, 0, hi, 0)),
            pl.BlockSpec((1, q, 1, n), lambda i, hi: (i, 0, hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda i, hi: (i, 0, hi, 0)),
            pl.BlockSpec((1, p, 1, n), lambda i, hi: (i, 0, hi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz * nc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz * nc, p, h, n), jnp.float32),
        ],
        interpret=interpret,
    )(xr, dtr, cumr, br, cr)
    y = y.reshape(bsz, nc, q, h, p)
    s = s.reshape(bsz, nc, p, h, n).transpose(0, 1, 3, 2, 4)  # [B,NC,H,P,N]
    return y, s
