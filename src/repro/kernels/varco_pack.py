"""VARCO compression pack/unpack Pallas TPU kernels.

The paper's compression (Definition 1 + Appendix) communicates a random
subset of activation elements chosen by a shared PRNG.  Element-granular
gather/scatter is hostile to the TPU vector unit, so the TPU-native
realisation subsamples **128-lane feature blocks** (the VPU lane width):
the shared key selects ``K = (F/128)/r`` blocks; ``pack`` gathers them into
a dense ``[N, K·128]`` wire buffer and ``unpack`` scatters them back,
zero-filling dropped blocks (exactly the paper's decoder).  Block-granular
random subsetting satisfies Definition 1 with the same ε(r) for
exchangeable coordinates; see DESIGN.md §3.

Mechanics: the kept-block indices ride in scalar-prefetch memory (SMEM) so
the BlockSpec ``index_map`` can route HBM→VMEM DMAs directly — the gather
costs zero VPU work; it is pure DMA steering.  ``unpack`` iterates all
output blocks, copying from the packed buffer where the inverse map is
valid and zeroing otherwise (``inv`` also in SMEM).

These kernels are the TPU realisation of the runtime's **packed wire
format** (DESIGN.md §3.3): :func:`repro.core.collectives.packed_all_gather`
packs each worker's boundary block before the all-gather so only the
``[B, K·128]`` payload crosses the wire, and unpacks on receipt.  The
differentiable entry points are :func:`repro.kernels.ops.wire_pack` /
:func:`repro.kernels.ops.wire_unpack`, which dispatch to these Pallas
kernels on TPU and to the ``ref.py`` jnp oracles elsewhere (the CPU
fallback rule), with custom VJPs (pack and unpack are each other's
transpose).  Correctness vs ``ref.pack_reference`` / ``ref.unpack_reference``
is pinned by ``tests/test_kernels.py``; the runtime integration — packed vs
dense parity at every rate — by ``tests/test_packed_wire.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _pack_kernel(idx_ref, x_ref, out_ref):
    del idx_ref  # consumed by the index_map
    out_ref[...] = x_ref[...]


def varco_pack(x: jax.Array, block_idx: jax.Array, *, tile_n: int = 256,
               interpret: bool = False) -> jax.Array:
    """Gather kept lane-blocks: x [N, F], block_idx [K] -> [N, K*128]."""
    n, f = x.shape
    assert f % LANE == 0, f
    k = block_idx.shape[0]
    tn = min(tile_n, n)
    assert n % tn == 0, (n, tn)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // tn, k),
        in_specs=[
            pl.BlockSpec((tn, LANE), lambda i, j, idx: (i, idx[j])),
        ],
        out_specs=pl.BlockSpec((tn, LANE), lambda i, j, idx: (i, j)),
    )
    return pl.pallas_call(
        _pack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, k * LANE), x.dtype),
        interpret=interpret,
    )(block_idx, x)


def _bitpack_block(levels: jax.Array, width: int) -> jax.Array:
    """int8 levels [tn, LANE] -> packed uint8 [tn, LANE*width/8].

    One byte holds ``vpb = 8/width`` consecutive lanes, little-endian
    within the byte (lane ``c*vpb + j`` at bit offset ``j*width``).  The
    strided slice ``levels[:, j::vpb]`` is exactly the offset-``j`` lane
    of every group, so the combine is ``vpb`` shifted ORs — no in-kernel
    reshape.  Shared by the pack and the oracle-checked byte layout
    (:func:`repro.kernels.ref.pack_bits_reference`).
    """
    lv = levels.astype(jnp.int8)
    if width == 8:
        return jax.lax.bitcast_convert_type(lv, jnp.uint8)
    vpb = 8 // width
    u = jax.lax.bitcast_convert_type(lv, jnp.uint8) & jnp.uint8(2 ** width - 1)
    out = u[:, 0::vpb]
    for j in range(1, vpb):
        out = out | (u[:, j::vpb] << jnp.uint8(j * width))
    return out


def _bitunpack_block(packed: jax.Array, width: int) -> jax.Array:
    """Inverse of :func:`_bitpack_block`: uint8 [tn, LANE*width/8] ->
    sign-extended int8 levels [tn, LANE] via interleaved strided sets."""
    if width == 8:
        return jax.lax.bitcast_convert_type(packed, jnp.int8)
    vpb = 8 // width
    mask = jnp.uint8(2 ** width - 1)
    out = jnp.zeros((packed.shape[0], packed.shape[1] * vpb), jnp.int32)
    for j in range(vpb):
        v = ((packed >> jnp.uint8(j * width)) & mask).astype(jnp.int32)
        v = jnp.where(v >= 2 ** (width - 1), v - 2 ** width, v)
        out = out.at[:, j::vpb].set(v)
    return out.astype(jnp.int8)


def _pack_quant_kernel(idx_ref, x_ref, out_ref, scale_ref, *, qmax, width):
    del idx_ref  # consumed by the index_map
    xb = x_ref[...]
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    levels = jnp.clip(jnp.rint(xb / scale), -qmax, qmax).astype(jnp.int8)
    out_ref[...] = _bitpack_block(levels, width)
    scale_ref[...] = scale


def varco_pack_quant(x: jax.Array, block_idx: jax.Array, *, width: int,
                     tile_n: int = 256, interpret: bool = False
                     ) -> tuple[jax.Array, jax.Array]:
    """Fused gather + low-bit quantise + bit-pack: one kernel launch.

    x [N, F], block_idx [K] -> (payload uint8 [N, K*128*width/8],
    scales f32 [N, K]).  Each kept lane-block is DMA-routed into VMEM
    exactly as in :func:`varco_pack`, and *in the same tile visit* the
    kernel computes the per-row block amax, the symmetric scale
    ``amax / qmax`` with ``qmax = 2^(width-1) - 1``, the rounded-clipped
    int8 levels, AND the sub-byte bit-pack (``8/width`` lanes per byte,
    little-endian — ``width == 8`` stores bitwise the int8 lanes the
    pre-packing wire shipped) — the fp32 intermediate and the one-lane-
    per-byte int8 buffer never exist.  ``width`` ∈ {2, 4, 8}; storage
    now matches the ledger's ``LANE·width`` payload charge exactly.
    Oracle: :func:`repro.kernels.ref.pack_quant_reference`; decode with
    :func:`varco_unpack_quant` / ``ref.unpack_quant_reference``.
    """
    n, f = x.shape
    assert f % LANE == 0, f
    assert width in (2, 4, 8), width
    k = block_idx.shape[0]
    tn = min(tile_n, n)
    assert n % tn == 0, (n, tn)
    qmax = float(2 ** (width - 1) - 1)
    bpb = LANE * width // 8                 # payload bytes per lane-block

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // tn, k),
        in_specs=[
            pl.BlockSpec((tn, LANE), lambda i, j, idx: (i, idx[j])),
        ],
        out_specs=[
            pl.BlockSpec((tn, bpb), lambda i, j, idx: (i, j)),
            pl.BlockSpec((tn, 1), lambda i, j, idx: (i, j)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_pack_quant_kernel, qmax=qmax, width=width),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n, k * bpb), jnp.uint8),
                   jax.ShapeDtypeStruct((n, k), jnp.float32)],
        interpret=interpret,
    )(block_idx, x)


def _unpack_quant_kernel(inv_ref, packed_ref, scale_ref, out_ref, *, width):
    j = pl.program_id(1)
    live = inv_ref[j] >= 0

    @pl.when(live)
    def _decode():
        levels = _bitunpack_block(packed_ref[...], width)
        out_ref[...] = levels.astype(jnp.float32) * scale_ref[...]

    @pl.when(jnp.logical_not(live))
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)


def varco_unpack_quant(payload: jax.Array, scales: jax.Array,
                       inv_idx: jax.Array, *, width: int, tile_n: int = 256,
                       interpret: bool = False) -> jax.Array:
    """Fused receive-side decode: bit-unpack + dequantise + scatter.

    payload uint8 [N, K*128*width/8], scales f32 [N, K], inv_idx [F/128]
    (packed block column of each output block, -1 if dropped) -> f32
    [N, F].  One launch does what unpack-then-dequant did in two: each
    live output block DMA-routes its payload bytes and scale column into
    VMEM, sign-extends the ``width``-bit fields and multiplies by the
    block scale; dropped blocks are zero-filled (the paper's decoder).
    Oracle: ``ref.unpack_quant_reference`` + ``ref.unpack_reference``.
    """
    n, kb = payload.shape
    assert width in (2, 4, 8), width
    bpb = LANE * width // 8
    assert kb % bpb == 0, (kb, bpb)
    nf = inv_idx.shape[0]
    tn = min(tile_n, n)
    assert n % tn == 0, (n, tn)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // tn, nf),
        in_specs=[
            pl.BlockSpec((tn, bpb),
                         lambda i, j, inv: (i, jnp.maximum(inv[j], 0))),
            pl.BlockSpec((tn, 1),
                         lambda i, j, inv: (i, jnp.maximum(inv[j], 0))),
        ],
        out_specs=pl.BlockSpec((tn, LANE), lambda i, j, inv: (i, j)),
    )
    return pl.pallas_call(
        functools.partial(_unpack_quant_kernel, width=width),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, nf * LANE), jnp.float32),
        interpret=interpret,
    )(inv_idx, payload, scales)


def _unpack_kernel(inv_ref, packed_ref, out_ref):
    j = pl.program_id(1)
    live = inv_ref[j] >= 0

    @pl.when(live)
    def _copy():
        out_ref[...] = packed_ref[...]

    @pl.when(jnp.logical_not(live))
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)


def varco_unpack(packed: jax.Array, inv_idx: jax.Array, *, tile_n: int = 256,
                 interpret: bool = False) -> jax.Array:
    """Scatter kept blocks back, zero-filling dropped ones.

    packed: [N, K*128]; inv_idx: [F/128] with inv_idx[b] = packed block
    column of output block b, or -1 if dropped.  Returns [N, F].
    """
    n, kf = packed.shape
    k = kf // LANE
    nf = inv_idx.shape[0]
    tn = min(tile_n, n)
    assert n % tn == 0, (n, tn)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // tn, nf),
        in_specs=[
            pl.BlockSpec((tn, LANE),
                         lambda i, j, inv: (i, jnp.maximum(inv[j], 0))),
        ],
        out_specs=pl.BlockSpec((tn, LANE), lambda i, j, inv: (i, j)),
    )
    return pl.pallas_call(
        _unpack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, nf * LANE), packed.dtype),
        interpret=interpret,
    )(inv_idx, packed)


def block_mask_indices(key: jax.Array, n_blocks: int, rate: float
                       ) -> tuple[jax.Array, jax.Array]:
    """Shared-PRNG selection of ``K = max(floor(n_blocks/rate), 1)`` kept
    lane-blocks (floor, clamped to one block — never zero payload).

    Returns (block_idx [K] sorted, inv_idx [n_blocks]).  Both ends derive
    these from the same key — no index metadata on the wire (paper App. A).
    """
    k = max(int(n_blocks / max(rate, 1.0)), 1)
    return block_mask_indices_k(key, n_blocks, k)


def block_mask_indices_k(key: jax.Array, n_blocks: int, k: int
                         ) -> tuple[jax.Array, jax.Array]:
    """:func:`block_mask_indices` with the kept-block count ``k`` given
    directly — the runtime quantises the (possibly annealing) rate to ``k``
    outside jit so the rate itself can stay a traced operand."""
    perm = jax.random.permutation(key, n_blocks)
    kept = jnp.sort(perm[:k])
    inv = jnp.full((n_blocks,), -1, jnp.int32)
    inv = inv.at[kept].set(jnp.arange(k, dtype=jnp.int32))
    return kept.astype(jnp.int32), inv


def block_mask_indices_pos(key: jax.Array, n_blocks: int, k: int
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """:func:`block_mask_indices_k` plus the permutation *positions*.

    Returns ``(kept [k], inv [n_blocks], pos [n_blocks])`` where ``pos[b]``
    is block ``b``'s slot in the shared permutation.  The kept sets at two
    counts ``k' <= k`` are *nested* under one key (both are "permutation
    slot < count"), so a buffer packed at ``k`` realises any smaller
    per-pair count ``k'`` by zeroing the packed columns whose block has
    ``pos >= k'`` — the per-pair rate-map mechanism of
    ``repro.dist.ratectl`` (DESIGN.md §3.6).  ``pos`` matches the dense
    ``blockmask`` compressor's keep rule bitwise.
    """
    perm = jax.random.permutation(key, n_blocks)
    pos = jnp.zeros((n_blocks,), jnp.int32).at[perm].set(
        jnp.arange(n_blocks, dtype=jnp.int32))
    kept = jnp.sort(perm[:k])
    inv = jnp.full((n_blocks,), -1, jnp.int32)
    inv = inv.at[kept].set(jnp.arange(k, dtype=jnp.int32))
    return kept.astype(jnp.int32), inv, pos


def worker_block_maps(key: jax.Array, q: int, n_blocks: int, k: int
                      ) -> tuple[jax.Array, jax.Array]:
    """Every worker's ``(kept, inv)`` pair for one exchange: worker ``i``
    draws from ``fold_in(key, i)``.  This is THE key-stream rule all wire
    paths share — emulated and shard_map, packed and p2p — so the
    bitwise-parity guarantees are structural, not four copies that must be
    kept in sync.  Returns ``(kept_all [Q, k], inv_all [Q, n_blocks])``.
    """
    keys = jax.vmap(jax.random.fold_in, (None, 0))(key, jnp.arange(q))
    return jax.vmap(lambda kk: block_mask_indices_k(kk, n_blocks, k))(keys)


def worker_block_maps_pos(key: jax.Array, q: int, n_blocks: int, k: int
                          ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """:func:`worker_block_maps` plus every worker's permutation positions
    (:func:`block_mask_indices_pos`): ``(kept_all [Q, k], inv_all
    [Q, n_blocks], pos_all [Q, n_blocks])``.  Same ``fold_in(key, worker)``
    streams, so the scalar-rate wires and the per-pair rate-map wires draw
    identical kept sets for identical keys."""
    keys = jax.vmap(jax.random.fold_in, (None, 0))(key, jnp.arange(q))
    return jax.vmap(lambda kk: block_mask_indices_pos(kk, n_blocks, k))(keys)
