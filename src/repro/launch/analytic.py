"""Analytic FLOPs / HBM-bytes model per (arch × shape).

XLA's ``cost_analysis()`` counts ``while`` bodies once (verified on this
jax build), so scanned-layer models under-report by ~n_blocks×.  The
roofline therefore uses this analytic model for the compute and memory
terms — standard napkin-math formulas over the configs we control — and
the trip-count-aware HLO parser (``hlo_analysis``) for the collective term.
``cost_analysis`` output is still recorded for cross-checking: for a
1-block model the two agree within a few % (tests/test_roofline.py).

Conventions (per *global* step; divide by chip count for per-device):
* matmul x@W: 2·m·k·n FLOPs.
* train: fwd + backward (2×fwd) + remat re-forward if enabled.
* attention: 4·B·S²·H·hd fwd (QKᵀ + PV), halved for causal.
* memory bytes/device: parameters touched (fwd + bwd + optimizer r/w) +
  activation traffic ≈ 2·(act writes + reads) + KV-cache traffic (decode).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.launch.shapes import InputShape


@dataclasses.dataclass
class CostEstimate:
    flops_global: float           # per step, all chips
    hbm_bytes_per_dev: float      # per step, per chip
    param_bytes_per_dev: float
    act_bytes_per_dev: float
    detail: dict


def _layer_matmul_flops_per_tok(cfg: ArchConfig, pi: int) -> float:
    """Forward matmul FLOPs per token for pattern position ``pi``."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kind = cfg.pattern[pi]
    f = 0.0
    if kind == "attn":
        f += 2.0 * d * cfg.n_heads * hd * 2          # wq, wo
        f += 2.0 * d * cfg.n_kv_heads * hd * 2       # wk, wv
    else:
        mc = cfg.mamba
        di = mc.d_inner(d)
        proj = 2 * di + 2 * mc.n_groups * mc.d_state + mc.n_heads(d)
        f += 2.0 * d * proj                          # in_proj
        f += 2.0 * di * d                            # out_proj
    if cfg.layer_uses_moe(pi):
        m = cfg.moe
        # top_k experts at capacity_factor occupancy + shared experts
        f += 2.0 * 3 * d * m.d_expert * m.top_k * m.capacity_factor
        if m.n_shared:
            f += 2.0 * 3 * d * m.shared_hidden
        f += 2.0 * d * m.n_experts                   # router
    elif cfg.d_ff > 0:
        f += 2.0 * 3 * d * cfg.d_ff
    return f


def _attn_seq_flops(cfg: ArchConfig, b: int, s: int, kv_len: int) -> float:
    """Per-layer attention score+value FLOPs (fwd) for q-len s vs kv_len."""
    hd = cfg.resolved_head_dim
    eff_kv = min(kv_len, cfg.sliding_window) if cfg.sliding_window else kv_len
    f = 4.0 * b * s * eff_kv * cfg.n_heads * hd
    if s == kv_len and not cfg.sliding_window:
        f *= 0.5                                     # causal half
    return f


def _mamba_seq_flops(cfg: ArchConfig, b: int, s: int) -> float:
    """SSD chunked-scan FLOPs (fwd) per layer."""
    mc = cfg.mamba
    h = mc.n_heads(cfg.d_model)
    p, n, q = mc.head_dim, mc.d_state, min(mc.chunk, s)
    # intra-chunk quadratic: scores 2·s·q·h·n + apply 2·s·q·h·p
    f = 2.0 * b * s * q * h * (n + p)
    # state build + inter-chunk apply: 2 × 2·s·h·p·n
    f += 4.0 * b * s * h * p * n
    return f


def _n_attn_mamba(cfg: ArchConfig) -> tuple[int, int]:
    na = sum(1 for k in cfg.pattern if k == "attn") * cfg.n_blocks
    nm = cfg.n_layers - na
    return na, nm


def _param_bytes(cfg: ArchConfig) -> float:
    return cfg.param_counts()["total"] * cfg.pdtype.itemsize


def estimate(cfg: ArchConfig, shape: InputShape, n_chips: int,
             moment_bytes: int | None = None) -> CostEstimate:
    b = shape.global_batch
    s = 1 if shape.kind == "decode" else shape.seq_len
    kv_len = shape.seq_len
    tokens = b * s
    na, nm = _n_attn_mamba(cfg)
    dt = cfg.adtype.itemsize

    # ---- FLOPs --------------------------------------------------------------
    matmul_tok = sum(_layer_matmul_flops_per_tok(cfg, pi)
                     for pi in range(cfg.pattern_period)) * cfg.n_blocks
    fwd = matmul_tok * tokens
    if na:
        fwd += na * _attn_seq_flops(cfg, b, s, kv_len if shape.kind ==
                                    "decode" else s)
    if nm:
        fwd += nm * (_mamba_seq_flops(cfg, b, s) if shape.kind != "decode"
                     else 4.0 * b * cfg.mamba.n_heads(cfg.d_model) *
                     cfg.mamba.head_dim * cfg.mamba.d_state)
    fwd += 2.0 * tokens * cfg.d_model * cfg.vocab_size  # lm head
    if shape.kind == "train":
        total = fwd * (3.0 + (1.0 if cfg.remat else 0.0))
    else:
        total = fwd

    # ---- HBM bytes per device ----------------------------------------------
    p_bytes_dev = _param_bytes(cfg) / n_chips
    mdt = moment_bytes if moment_bytes is not None else \
        2 * cfg.pdtype.itemsize  # 2 adam moments at param dtype by default
    if shape.kind == "train":
        # params: read fwd + read bwd (+ remat re-read) + grad write/read
        # + 2 moments read+write + param write
        reads = 2.0 + (1.0 if cfg.remat else 0.0)
        opt_traffic = p_bytes_dev * (2.0            # grad w+r
                                     + 1.0          # param write
                                     ) + \
            (cfg.param_counts()["total"] / n_chips) * mdt * 2.0
        param_traffic = p_bytes_dev * reads + opt_traffic
        act_per_layer = tokens * cfg.d_model * dt / n_chips
        # save + re-read block inputs, plus ~6 intermediate r/w per layer
        act_traffic = act_per_layer * cfg.n_layers * 8.0
    else:
        param_traffic = p_bytes_dev                  # read once per step
        act_per_layer = tokens * cfg.d_model * dt / n_chips
        act_traffic = act_per_layer * cfg.n_layers * 6.0
        if shape.kind == "decode" and na:
            w = min(kv_len, cfg.sliding_window) if cfg.sliding_window \
                else kv_len
            kv_bytes = (na * b * w * cfg.n_kv_heads *
                        cfg.resolved_head_dim * 2 * dt) / n_chips
            act_traffic += kv_bytes                  # read the KV cache
        if shape.kind == "prefill" and na:
            act_traffic += (na * tokens * cfg.n_kv_heads *
                            cfg.resolved_head_dim * 2 * dt * 2) / n_chips

    return CostEstimate(
        flops_global=total,
        hbm_bytes_per_dev=param_traffic + act_traffic,
        param_bytes_per_dev=p_bytes_dev,
        act_bytes_per_dev=act_traffic,
        detail={
            "fwd_flops": fwd,
            "matmul_flops_per_tok": matmul_tok,
            "attn_layers": na, "mamba_layers": nm,
            "param_traffic_dev": param_traffic,
        })
