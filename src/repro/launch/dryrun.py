import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

For each combination this builds the full-size model *abstractly*
(ShapeDtypeStructs only — no allocation), jits the appropriate step
(train / prefill / decode) with production in/out shardings, compiles it,
and records:

* ``memory_analysis()``  — per-device HBM: argument/output/temp/peak bytes,
* ``cost_analysis()``    — HLO FLOPs + bytes accessed,
* collective traffic    — parsed from the post-SPMD optimized HLO
  (all-reduce / all-gather / reduce-scatter / all-to-all /
  collective-permute result sizes × ring factors),

into ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` — the §Roofline
inputs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
        --shape train_4k [--multi-pod] [--out DIR]
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, ArchConfig, get_config
from repro.dist.sharding import (activation_sharding, batch_spec, cache_spec,
                                 data_axes, param_shardings)
from repro.launch import analytic
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.shapes import (SHAPES, InputShape, batch_specs,
                                 long_context_variant)
from repro.launch.steps import make_decode_step, make_optimizer, \
    make_train_step

# ---------------------------------------------------------------------------
# Abstract model construction
# ---------------------------------------------------------------------------


def _eval_shapes(fn, *args):
    return jax.eval_shape(fn, *args)


def build_dryrun(cfg: ArchConfig, shape: InputShape, mesh,
                 lr: float = 3e-4):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs w/ shardings)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.transformer import init_lm

    if shape.name == "long_500k":
        cfg = long_context_variant(cfg)

    params_s = _eval_shapes(lambda: init_lm(jax.random.key(0), cfg))
    p_shard = param_shardings(params_s, mesh)
    params_in = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params_s, p_shard)

    bspec = NamedSharding(mesh, batch_spec(mesh))
    batch_s = batch_specs(cfg, shape)

    def shard_batch(s):
        daxes = data_axes(mesh)
        dsize = 1
        for a in daxes:
            dsize *= mesh.shape[a]
        # batch dim is dim 0 except positions3 (dim 1)
        bdim = 1 if s.shape[:1] == (3,) and len(s.shape) == 3 else 0
        spec = [None] * len(s.shape)
        if s.shape[bdim] % dsize == 0 and dsize > 1:
            spec[bdim] = daxes
        elif len(s.shape) > bdim + 1 and s.shape[bdim + 1] % dsize == 0:
            spec[bdim + 1] = daxes          # batch=1: shard seq (context par.)
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, P(*spec)))

    batch_in = jax.tree_util.tree_map(shard_batch, batch_s)

    if shape.kind == "train":
        opt = make_optimizer(cfg, lr)
        opt_s = _eval_shapes(opt.init, params_s)
        o_shard = param_shardings(opt_s, mesh)
        opt_in = jax.tree_util.tree_map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            opt_s, o_shard)
        step = make_train_step(cfg, opt)

        def wrapped(params, opt_state, batch):
            with activation_sharding(mesh):
                return step(params, opt_state, batch)

        fn = jax.jit(wrapped,
                     in_shardings=(p_shard, o_shard,
                                   jax.tree_util.tree_map(
                                       lambda s: s.sharding, batch_in)),
                     out_shardings=(p_shard, o_shard, None),
                     donate_argnums=(0, 1))
        return fn, (params_in, opt_in, batch_in), cfg

    def shard_cache_tree(cache_s):
        def leaf(s):
            if len(s.shape) >= 4:   # [nb, B, W, KV, D] attn k/v
                spec = cache_spec(s.shape, mesh, batch_dim=1,
                                  seq_dim=2 if len(s.shape) == 5 else None,
                                  head_dim=3 if len(s.shape) == 5 else None)
            elif len(s.shape) == 3:  # pos [nb, B, W]
                spec = cache_spec(s.shape, mesh, batch_dim=1, seq_dim=2)
            elif len(s.shape) == 0:
                spec = P()
            else:
                spec = cache_spec(s.shape, mesh, batch_dim=1)
            return NamedSharding(mesh, spec)
        return jax.tree_util.tree_map(leaf, cache_s)

    if shape.kind == "prefill":
        from repro.models.transformer import prefill

        def pre_fn(params, batch):
            with activation_sharding(mesh):
                return prefill(params, cfg, batch)

        cache_out_s = jax.eval_shape(pre_fn, params_s, batch_s)[1]
        fn = jax.jit(pre_fn,
                     in_shardings=(p_shard,
                                   jax.tree_util.tree_map(
                                       lambda s: s.sharding, batch_in)),
                     out_shardings=(None, shard_cache_tree(cache_out_s)))
        return fn, (params_in, batch_in), cfg

    # decode
    from repro.models.transformer import init_cache
    dec = make_decode_step(cfg)
    cache_s = _eval_shapes(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))

    cache_in = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        cache_s, shard_cache_tree(cache_s))
    # decode batch: 1 token per sequence
    dec_batch = batch_specs(cfg, shape)
    dec_batch_in = jax.tree_util.tree_map(shard_batch, dec_batch)

    def dec_fn(params, batch, cache):
        with activation_sharding(mesh):
            return dec(params, batch, cache)

    fn = jax.jit(dec_fn,
                 in_shardings=(p_shard,
                               jax.tree_util.tree_map(
                                   lambda s: s.sharding, dec_batch_in),
                               jax.tree_util.tree_map(
                                   lambda s: s.sharding, cache_in)),
                 out_shardings=(None, None,
                                jax.tree_util.tree_map(
                                    lambda s: s.sharding, cache_in)),
                 donate_argnums=(2,))
    return fn, (params_in, dec_batch_in, cache_in), cfg


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: str = "experiments/dryrun") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    n_chips = 512 if multi_pod else 256

    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "chips": n_chips, "kind": shape.kind}
    t0 = time.time()
    try:
        fn, args, cfg_used = build_dryrun(cfg, shape, mesh)
        lowered = fn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        rec["cost"] = {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed",
                                       cost.get("bytes_accessed")),
            "transcendentals": cost.get("transcendentals"),
        }
        hlo = compiled.as_text()
        rec["hlo_chars"] = len(hlo)
        # trip-count-aware collective accounting (per device, per step)
        rec["collectives"] = collective_bytes(hlo)
        del hlo

        # analytic compute/memory model (XLA cost_analysis counts while
        # bodies once — see launch/analytic.py; raw values kept above for
        # cross-checks)
        est = analytic.estimate(cfg_used, shape, n_chips)
        rec["analytic"] = {
            "flops_global": est.flops_global,
            "hbm_bytes_per_dev": est.hbm_bytes_per_dev,
            "param_bytes_per_dev": est.param_bytes_per_dev,
            **est.detail,
        }
        coll = rec["collectives"]["bytes"]
        if cfg_used.activ_dtype == "bfloat16":
            # CPU-backend f32-upcast artifact; see hlo_analysis
            coll = rec["collectives"]["bf16_normalized_bytes"]
        rec["roofline"] = {
            "compute_s": est.flops_global / n_chips / HW["peak_flops_bf16"],
            "memory_s": est.hbm_bytes_per_dev / HW["hbm_bw"],
            "collective_s": coll / HW["ici_bw"],
        }
        dom = max(rec["roofline"], key=rec["roofline"].get)
        rec["roofline"]["dominant"] = dom

        counts = cfg_used.param_counts()
        tokens = shape.global_batch * (1 if shape.kind == "decode"
                                       else shape.seq_len)
        model_flops = (6.0 if shape.kind == "train" else 2.0) \
            * counts["active"] * tokens
        rec["model_flops_global"] = model_flops
        rec["useful_flop_ratio"] = model_flops / est.flops_global
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    for arch in archs:
        for shp in shapes:
            rec = run_one(arch, shp, args.multi_pod, args.out)
            status = "OK " if rec.get("ok") else "FAIL"
            ro = rec.get("roofline", {})
            print(f"[{status}] {arch:28s} {shp:12s} {rec['mesh']:10s} "
                  f"lower={rec.get('lower_s', '-')}s "
                  f"compile={rec.get('compile_s', '-')}s "
                  f"peakGB={(rec.get('memory') or {}).get('peak_bytes', 0) and round(rec['memory']['peak_bytes'] / 1e9, 2)} "
                  f"dom={ro.get('dominant', rec.get('error', ''))}",
                  flush=True)


if __name__ == "__main__":
    main()
