"""Trip-count-aware collective accounting from post-SPMD optimized HLO.

``XLA``'s ``cost_analysis()`` (and a naive text scan) counts a ``while``
body **once**, but ``lax.scan``-over-blocks models execute it
``n_blocks`` times.  This module parses the optimized HLO into
computations, resolves each while loop's trip count from its condition
computation (the loop bound constant), and attributes every collective op
to its computation's *execution multiplier* (nested loops multiply).

Verified against hand-built HLO in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->",
                       re.M)
_WHILE_RE = re.compile(
    r"while\([^)]*\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLL_RE = re.compile(
    r"^\s*%?[\w\.\-]+\s*=\s*(.*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ALT = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def split_computations(hlo: str) -> dict[str, str]:
    """Split module text into {computation_name: body_text}."""
    comps: dict[str, str] = {}
    starts = [(m.start(), m.group(1)) for m in _COMP_HDR.finditer(hlo)]
    for i, (pos, name) in enumerate(starts):
        end = starts[i + 1][0] if i + 1 < len(starts) else len(hlo)
        comps[name] = hlo[pos:end]
    return comps


def _entry_name(hlo: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    return m.group(1) if m else None


def while_trip_count(cond_text: str) -> int:
    """Largest integer constant in the loop condition ≈ the trip bound."""
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else 1


_CALL_RE = re.compile(
    r"(?:calls=|to_apply=|called_computations=\{)%?([\w\.\-]+)")


def computation_multipliers(hlo: str) -> dict[str, float]:
    """Execution count of each computation (entry = 1, loop bodies = trips,
    nested loops multiply through; plain calls / async-wrapped collectives
    inherit the caller's multiplier)."""
    comps = split_computations(hlo)
    entry = _entry_name(hlo)
    mult: dict[str, float] = {name: 0.0 for name in comps}
    if entry is None:
        return {name: 1.0 for name in comps}
    mult[entry] = 1.0

    # iterate to fixpoint (loop nesting depth is small)
    for _ in range(12):
        changed = False
        for name, text in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for w in _WHILE_RE.finditer(text):
                cond, body = w.group(1), w.group(2)
                trips = while_trip_count(comps.get(cond, ""))
                want = m * trips
                if mult.get(body, 0.0) < want:
                    mult[body] = want
                    changed = True
                if mult.get(cond, 0.0) < want:
                    mult[cond] = want
            for c in _CALL_RE.finditer(text):
                callee = c.group(1)
                if callee in mult and mult[callee] < m:
                    mult[callee] = m
                    changed = True
        if not changed:
            break
    # computations never reached (fusions etc. referenced inline) run with
    # their caller; give them multiplier 1 so their collectives still count.
    for name in comps:
        if mult[name] == 0.0:
            mult[name] = 1.0
    return mult


def _group_size(text: str, pos: int) -> int:
    g = _GROUPS_RE.search(text, pos, pos + 4000)
    if g:
        return max(len(g.group(1).split(",")), 2)
    g2 = _GROUPS_ALT.search(text, pos, pos + 4000)
    if g2:                     # replica_groups=[ngroups,group_size]
        return max(int(g2.group(2)), 2)
    return 2


def collective_bytes(hlo: str) -> dict:
    """Per-device wire bytes per executed step, ring-model factors:

      all-reduce:         2 (g-1)/g · r
      all-gather:         (g-1)/g · r      (r = gathered result)
      reduce-scatter:     (g-1) · r        (r = scattered result)
      all-to-all:         (g-1)/g · r
      collective-permute: r
    """
    comps = split_computations(hlo)
    mults = computation_multipliers(hlo)
    per_kind: dict[str, float] = {}
    per_dtype: dict[str, float] = {}
    total = 0.0
    ops = 0
    for name, text in comps.items():
        mult = mults.get(name, 1.0)
        for m in _COLL_RE.finditer(text):
            result_types, kind = m.group(1), m.group(2)
            shapes = _SHAPE_RE.findall(result_types)
            if not shapes:
                continue
            # async starts carry (operand, result) tuples: take the largest
            dt, dims = max(shapes, key=lambda s: _shape_bytes(*s))
            r = _shape_bytes(dt, dims)
            g = _group_size(text, m.end())
            if kind == "all-reduce":
                b = 2.0 * (g - 1) / g * r
            elif kind == "all-gather":
                b = (g - 1) / g * r
            elif kind == "reduce-scatter":
                b = (g - 1.0) * r
            elif kind == "all-to-all":
                b = (g - 1) / g * r
            else:
                b = r
            per_kind[kind] = per_kind.get(kind, 0.0) + b * mult
            per_dtype[dt] = per_dtype.get(dt, 0.0) + b * mult
            total += b * mult
            ops += 1
    # XLA:CPU upcasts bf16 dot operands to f32 (convert + replicated f32
    # collectives); on TPU those payloads stay bf16.  The normalized figure
    # halves f32 traffic — use it for bf16-configured models.
    normalized = total - 0.5 * per_dtype.get("f32", 0.0)
    return {"per_kind": per_kind, "per_dtype": per_dtype, "bytes": total,
            "bf16_normalized_bytes": normalized, "ops": ops}
