"""Production meshes (TPU v5e target).

Functions, not module-level constants, so importing never touches jax
device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; ordinary processes (tests, benches) see the 1 real CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_small_mesh(data: int = 2, model: int = 4):
    """Reduced mesh for in-CI dry-run tests (8 virtual CPU devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16e9,           # capacity
}
