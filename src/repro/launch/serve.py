"""Batched serving launcher: prefill a request batch, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
        --batch 8 --prompt-len 64 --new-tokens 32

On real hardware this runs under the production mesh with the decode-shape
shardings exercised by the dry-run; on this container it serves the reduced
configs end to end.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.transformer import init_lm
from repro.nn.modules import param_count


def build_parser() -> argparse.ArgumentParser:
    """CLI surface (separate from :func:`main` so tests can pin it).

    ``--smoke`` is a real opt-in flag: ``store_true`` with
    ``default=False`` — the earlier ``default=True`` spelling made the
    flag a no-op (there was no way to run the full config).
    """
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=False)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--greedy", action="store_true", default=True)
    return ap


def main() -> None:
    args = build_parser().parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_lm(jax.random.key(0), cfg)
    print(f"serving {cfg.name}: {param_count(params):,} params")
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    max_len = args.prompt_len + args.new_tokens
    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(next_tok)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time() - t0:.2f}s")

    t0 = time.time()
    for i in range(args.new_tokens - 1):
        next_tok, logits, cache = decode(params, {"tokens": next_tok[:, None]},
                                         cache)
    jax.block_until_ready(next_tok)
    total = args.batch * (args.new_tokens - 1)
    dt = time.time() - t0
    print(f"decode {total} tokens: {dt:.2f}s ({total / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
