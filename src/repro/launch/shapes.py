"""Assigned input shapes + ``input_specs()`` stand-ins.

The four assigned shapes:

    train_4k     seq 4,096    global_batch 256   (training)
    prefill_32k  seq 32,768   global_batch 32    (inference prefill)
    decode_32k   seq 32,768   global_batch 128   (decode: 1 new token, KV=32k)
    long_500k    seq 524,288  global_batch 1     (long-context decode)

``input_specs(cfg, shape)`` returns ShapeDtypeStructs for every model input
(weak-type-correct, shardable, zero device allocation) — tokens for LM
archs, precomputed patch embeddings + M-RoPE ids for the VLM (frontend
stub), codec token ids for the audio arch.  ``concrete=True`` materialises
small random arrays instead (smoke tests).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def batch_specs(cfg: ArchConfig, shape: InputShape, concrete: bool = False,
                batch: int | None = None, seq: int | None = None) -> dict:
    """Model-input pytree for (cfg, shape): ShapeDtypeStructs or arrays."""
    b = batch or shape.global_batch
    s = 1 if shape.kind == "decode" else (seq or shape.seq_len)

    def mk(shp, dtype, maxval=None):
        if concrete:
            if jnp.issubdtype(dtype, jnp.integer):
                return jnp.asarray(
                    np.random.default_rng(0).integers(0, maxval or 2, shp),
                    dtype)
            return jnp.asarray(
                np.random.default_rng(0).normal(0, 0.02, shp), dtype)
        return jax.ShapeDtypeStruct(shp, dtype)

    specs: dict = {}
    if cfg.embed_source == "patches":
        # VLM stub frontend: pre-projected patch embeddings + M-RoPE ids
        specs["embeds"] = mk((b, s, cfg.d_model), cfg.adtype)
        specs["labels"] = mk((b, s), jnp.int32, cfg.vocab_size)
        specs["positions3"] = mk((3, b, s), jnp.int32, max(s, 2))
        specs["positions"] = mk((b, s), jnp.int32, max(s, 2))
    else:
        specs["tokens"] = mk((b, s), jnp.int32, cfg.vocab_size)
    return specs


def cache_specs(cfg: ArchConfig, shape: InputShape, concrete: bool = False,
                batch: int | None = None, cache_len: int | None = None):
    """Cache pytree (ShapeDtypeStructs by default) for decode shapes."""
    from repro.models.transformer import init_cache
    b = batch or shape.global_batch
    n = cache_len or shape.seq_len
    if concrete:
        return init_cache(cfg, b, n)
    shapes = jax.eval_shape(lambda: init_cache(cfg, b, n))
    return shapes


def long_context_variant(cfg: ArchConfig, window: int = 8192) -> ArchConfig:
    """SWA variant used for ``long_500k`` on attention-bearing archs.

    SSM archs pass through unchanged (already O(1) decode); archs with
    attention layers get a sliding window so the KV cache is bounded —
    the carve-out that lets dense archs run 524k decode.
    """
    if cfg.family == "ssm" or cfg.sliding_window:
        return cfg
    return cfg.with_(sliding_window=window)
