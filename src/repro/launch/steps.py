"""Jit-able train / prefill / decode step functions for the assigned archs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import decode_step, lm_loss, prefill
from repro.train.optim import (Optimizer, adamw, apply_updates,
                               clip_by_global_norm)


def make_optimizer(cfg: ArchConfig, lr: float = 3e-4) -> Optimizer:
    return adamw(lr, weight_decay=0.1,
                 moment_dtype=jnp.dtype(cfg.moment_dtype))


def make_train_step(cfg: ArchConfig, optimizer: Optimizer,
                    clip: float = 1.0):
    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lm_loss, has_aux=True)(params, cfg, batch)
        grads, gnorm = clip_by_global_norm(grads, clip)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "ce": parts["ce"],
                   "moe_aux": parts["moe_aux"], "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, max_len: int | None = None):
    def prefill_step(params, batch):
        return prefill(params, cfg, batch, max_len=max_len)
    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def serve_step(params, batch, cache):
        logits, new_cache = decode_step(params, cfg, batch, cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache
    return serve_step
