"""LM training launcher for the assigned architectures.

On real hardware this runs under the production mesh; on this container it
trains the reduced (smoke) variants end to end, exercising the identical
code path: config -> sharded params -> jit train step -> checkpoint.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 50 [--comm varco:linear:5] [--batch 8 --seq 128]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.core.varco import CommPolicy
from repro.launch.steps import make_optimizer, make_train_step
from repro.models.transformer import init_lm
from repro.nn.modules import param_count
from repro.train.checkpoint import save
from repro.train.data import TokenPipeline


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--comm", default="full",
                    help="full | fixed:<r> | varco:linear:<a> — gradient "
                         "all-reduce compression (needs >1 device)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_lm(jax.random.key(0), cfg)
    print(f"arch={cfg.name} params={param_count(params):,} "
          f"layers={cfg.n_layers} d={cfg.d_model}")

    opt = make_optimizer(cfg, lr=args.lr)
    opt_state = opt.init(params)
    policy = CommPolicy.parse(args.comm, args.steps)

    n_dev = len(jax.devices())
    if policy.mode != "full" or n_dev > 1:
        from repro.dist.grad_compress import make_dp_mesh, \
            make_varco_dp_train_step
        mesh = make_dp_mesh(n_dev)
        step = make_varco_dp_train_step(cfg, opt, policy, mesh)
        dp = True
    else:
        base = make_train_step(cfg, opt)
        step = jax.jit(lambda p, o, b, *_: base(p, o, b))
        dp = False

    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq)
    t0 = time.time()
    for i, batch in zip(range(args.steps), pipe):
        out = step(params, opt_state, batch, jnp.asarray(i),
                   jax.random.key(i)) if dp else step(params, opt_state,
                                                      batch)
        params, opt_state, m = out
        if i % 10 == 0 or i == args.steps - 1:
            extra = f" rate {float(m['rate']):6.1f}" if "rate" in m else ""
            print(f"step {i:4d}  loss {float(m['loss']):.4f}"
                  f"  grad_norm {float(m['grad_norm']):.3f}{extra}"
                  f"  ({(time.time() - t0) / (i + 1):.2f}s/step)",
                  flush=True)

    if args.ckpt:
        save(args.ckpt, {"params": params, "opt": opt_state},
             extra={"arch": cfg.name, "steps": args.steps})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
