"""Transformer layer primitives shared across the 10 assigned archs.

Attention supports GQA/MQA (n_kv_heads < n_heads), explicit head_dim
(gemma's 256), qk-RMSNorm (qwen3), RoPE and M-RoPE (qwen2-vl's 3-section
multimodal rotary), full-causal and sliding-window masks, and a KV cache for
prefill/decode serving.

Everything is written mask-based over full [S, S] score tiles for the XLA
path; the Pallas flash-attention kernel (repro.kernels.flash_attention) is
the TPU hot-spot replacement with identical semantics (validated against
ref.py in interpret mode).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn.modules import rms_norm

Array = jax.Array


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [B, S, H, D]; positions: [B, S] int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions3: Array, theta: float,
                sections: tuple) -> Array:
    """Qwen2-VL multimodal RoPE [arXiv:2409.12191].

    ``positions3``: [3, B, S] (temporal, height, width) position ids.
    ``sections``: how many rotary *frequency pairs* each component owns;
    sums to head_dim // 2.  Text tokens use t == h == w, reducing to RoPE.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                                # [D/2]
    # pick which position component drives each frequency pair
    comp = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                      total_repeat_length=d // 2)               # [D/2]
    pos = positions3.astype(jnp.float32)[comp]                  # [D/2, B, S]
    angles = jnp.moveaxis(pos, 0, -1) * freqs                   # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Per-attention-layer cache: keys/values [B, S_cache, KV, D]."""
    k: Array
    v: Array


def init_attn(key: Array, cfg: ArchConfig) -> dict:
    d, h, kv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = cfg.pdtype
    scale = lambda fan_in: 1.0 / jnp.sqrt(fan_in)
    p = {
        "wq": (jax.random.normal(k1, (d, h, hd), dt) * scale(d)),
        "wk": (jax.random.normal(k2, (d, kv, hd), dt) * scale(d)),
        "wv": (jax.random.normal(k3, (d, kv, hd), dt) * scale(d)),
        "wo": (jax.random.normal(k4, (h, hd, d), dt) * scale(h * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def _attn_mask(q_pos: Array, k_pos: Array, window: int) -> Array:
    """[.., Sq, Sk] boolean mask: causal, optionally sliding-window."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def sdpa(q: Array, k: Array, v: Array, mask: Array) -> Array:
    """Masked scaled-dot-product attention; q [B,Sq,H,D], k/v [B,Sk,KV,D]."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    group = h // kvh
    qg = q.reshape(b, sq, kvh, group, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / jnp.sqrt(d).astype(q.dtype)
    scores = scores.astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask[:, None, None, :, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, d)


def attention(params: dict, cfg: ArchConfig, x: Array, positions: Array,
              cache: Optional[KVCache] = None,
              cache_index: Optional[Array] = None,
              positions3: Optional[Array] = None
              ) -> tuple[Array, Optional[KVCache]]:
    """Full attention sublayer (projections + rope + sdpa + output).

    Train/prefill: ``cache=None`` → causal over the sequence, returns the
    fresh KVCache.  Decode: ``cache`` holds S_cache slots, ``cache_index``
    is the write position; x has S=1.
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if cfg.mrope_sections:
        p3 = positions3 if positions3 is not None else \
            jnp.broadcast_to(positions[None], (3, *positions.shape))
        q = apply_mrope(q, p3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, p3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        mask = _attn_mask(positions, positions, cfg.sliding_window)
        out = sdpa(q, k, v, mask)
        new_cache = KVCache(k, v)
    else:
        # decode: write the new kv at cache_index, attend over the cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), cache_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), cache_index, axis=1)
        s_cache = k_cache.shape[1]
        k_pos = jnp.arange(s_cache, dtype=jnp.int32)[None, :]
        valid = k_pos <= cache_index
        mask = _attn_mask(positions, jnp.broadcast_to(k_pos, (b, s_cache)),
                          cfg.sliding_window) & valid[:, None, :]
        out = sdpa(q, k_cache, v_cache, mask)
        new_cache = KVCache(k_cache, v_cache)

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key: Array, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / jnp.sqrt(d)
    s_out = 1.0 / jnp.sqrt(d_ff)
    return {
        "w_gate": jax.random.normal(k1, (d, d_ff), dtype) * s_in,
        "w_up": jax.random.normal(k2, (d, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d), dtype) * s_out,
    }


def mlp(params: dict, x: Array, kind: str) -> Array:
    gate = x @ params["w_gate"]
    act = jax.nn.gelu(gate, approximate=True) if kind == "geglu" \
        else jax.nn.silu(gate)
    return (act * (x @ params["w_up"])) @ params["w_down"]
