"""Mamba2 / SSD (state-space duality) layer [arXiv:2405.21060].

TPU adaptation of the chunked SSD algorithm: the sequence is split into
chunks of ``Q`` tokens; intra-chunk terms use the quadratic (attention-like)
form — MXU-friendly [Q, Q] tiles — while inter-chunk terms carry a recurrent
state [H, P, N] across chunks via ``lax.scan``.  This is exactly the
decomposition the paper derives; on TPU the chunk matmuls map to the MXU and
the scan stays in VMEM-resident registers.

Decode maintains (conv_state, ssm_state) and costs O(1) per token — the
reason mamba2-130m (and jamba's mamba layers) run the ``long_500k`` shape.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn.modules import rms_norm

Array = jax.Array


class MambaCache(NamedTuple):
    conv: Array   # [B, d_conv-1, di + 2*G*N]
    ssm: Array    # [B, H, P, N]


def init_mamba(key: Array, cfg: ArchConfig) -> dict:
    mc = cfg.mamba
    d, dt_ = cfg.d_model, cfg.pdtype
    di = mc.d_inner(d)
    h = mc.n_heads(d)
    conv_dim = di + 2 * mc.n_groups * mc.d_state
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / jnp.sqrt(d)
    return {
        # fused input projection: [z, xBC, dt]
        "in_proj": jax.random.normal(
            k1, (d, 2 * di + 2 * mc.n_groups * mc.d_state + h), dt_) * s_in,
        "conv_w": jax.random.normal(k2, (mc.d_conv, conv_dim), dt_) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dt_),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32))
                   .astype(dt_),
        "D": jnp.ones((h,), dt_),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k3, (h,), jnp.float32) *
                    (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
        )).astype(dt_),
        "norm": jnp.zeros((di,), dt_),
        "out_proj": jax.random.normal(k4, (di, d), dt_) / jnp.sqrt(di),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: Array):
    mc = cfg.mamba
    di = mc.d_inner(cfg.d_model)
    gn = mc.n_groups * mc.d_state
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * gn], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over time. xbc: [B, T, C], w: [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def ssd_chunked(x: Array, dt: Array, a_log: Array, b: Array, c: Array,
                d_skip: Array, chunk: int,
                initial_state: Array | None = None
                ) -> tuple[Array, Array]:
    """Chunked SSD scan.

    x: [B, T, H, P]  dt: [B, T, H]  a_log: [H]
    b, c: [B, T, G, N]  d_skip: [H]
    Returns (y [B,T,H,P], final_state [B,H,P,N]).
    """
    bsz, t, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    rep = h // g

    a = -jnp.exp(a_log.astype(jnp.float32))                     # [H] negative
    dt_f = dt.astype(jnp.float32)
    dta = dt_f * a                                              # [B, T, H]

    # reshape to chunks
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt_f.reshape(bsz, nc, chunk, h)
    dtac = dta.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, g, n)
    cc = c.reshape(bsz, nc, chunk, g, n)

    cum = jnp.cumsum(dtac, axis=2)                              # [B,NC,Q,H]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]         # [B,NC,Q,Q,H]
    q_idx = jnp.arange(chunk)
    causal = q_idx[:, None] >= q_idx[None, :]
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk (quadratic form): scores [B,NC,H,Q,Q]
    bg = jnp.repeat(bc, rep, axis=3)                            # [B,NC,Q,H,N]
    cg = jnp.repeat(cc, rep, axis=3)
    scores = jnp.einsum("bnqhk,bnshk->bnqsh", cg.astype(jnp.float32),
                        bg.astype(jnp.float32))
    m = scores * decay * dtc[:, :, None, :, :]                  # [B,NC,Q,S,H]
    y_intra = jnp.einsum("bnqsh,bnshp->bnqhp", m,
                         xc.astype(jnp.float32))

    # chunk-final states: S_c = sum_s exp(cum_end - cum_s) dt_s B_s x_s
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)             # [B,NC,Q,H]
    state_contrib = jnp.einsum(
        "bnqh,bnqhk,bnqhp->bnhpk",
        decay_to_end * dtc, bg.astype(jnp.float32),
        xc.astype(jnp.float32))                                 # [B,NC,H,P,N]

    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # [B,NC,H]

    def scan_fn(state, inp):
        contrib, cdecay = inp                                   # [B,H,P,N],[B,H]
        new_state = state * cdecay[:, :, None, None] + contrib
        return new_state, state                                  # emit PREV

    init = initial_state.astype(jnp.float32) if initial_state is not None \
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(state_contrib, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)               # [B,NC,H,P,N]

    # inter-chunk: y_inter[t] = exp(cum_t) * C_t · state_prev
    y_inter = jnp.einsum("bnqhk,bnhpk->bnqhp",
                         cg.astype(jnp.float32) *
                         jnp.exp(cum)[..., None],
                         prev_states)

    y = (y_intra + y_inter).reshape(bsz, t, h, p)
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * \
        x.astype(jnp.float32)
    return y.astype(x.dtype), final_state


def mamba_layer(params: dict, cfg: ArchConfig, x: Array,
                cache: MambaCache | None = None
                ) -> tuple[Array, MambaCache]:
    """Full mamba2 block. Train/prefill: cache=None. Decode: S==1."""
    mc = cfg.mamba
    bsz, t, _ = x.shape
    di = mc.d_inner(cfg.d_model)
    h = mc.n_heads(cfg.d_model)
    g, n, p = mc.n_groups, mc.d_state, mc.head_dim

    zxbcdt = x @ params["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)

    if cache is None:
        xbc_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        conv_state = xbc[:, -(mc.d_conv - 1):, :] if t >= mc.d_conv - 1 else \
            jnp.pad(xbc, ((0, 0), (mc.d_conv - 1 - t, 0), (0, 0)))
        xs, bs, cs = jnp.split(xbc_conv, [di, di + g * n], axis=-1)
        dt_act = jax.nn.softplus(dt.astype(jnp.float32) +
                                 params["dt_bias"].astype(jnp.float32))
        y, final_state = ssd_chunked(
            xs.reshape(bsz, t, h, p), dt_act, params["A_log"],
            bs.reshape(bsz, t, g, n), cs.reshape(bsz, t, g, n),
            params["D"], min(mc.chunk, t))
        new_cache = MambaCache(conv_state.astype(x.dtype),
                               final_state.astype(jnp.float32))
    else:
        # O(1) decode step
        conv_in = jnp.concatenate([cache.conv, xbc], axis=1)    # [B, K, C]
        conv_out = jnp.einsum("bkc,kc->bc", conv_in,
                              params["conv_w"]) + params["conv_b"]
        xbc_conv = jax.nn.silu(conv_out)[:, None, :]
        xs, bs, cs = jnp.split(xbc_conv, [di, di + g * n], axis=-1)
        dt_act = jax.nn.softplus(dt.astype(jnp.float32) +
                                 params["dt_bias"].astype(jnp.float32))
        da = jnp.exp(dt_act[:, 0, :] *
                     -jnp.exp(params["A_log"].astype(jnp.float32)))  # [B,H]
        xh = xs.reshape(bsz, h, p).astype(jnp.float32)
        bh = jnp.repeat(bs.reshape(bsz, g, n), h // g, axis=1)
        ch = jnp.repeat(cs.reshape(bsz, g, n), h // g, axis=1)
        dtx = dt_act[:, 0, :, None] * xh                        # [B,H,P]
        new_ssm = cache.ssm * da[:, :, None, None] + \
            jnp.einsum("bhp,bhk->bhpk", dtx, bh.astype(jnp.float32))
        yh = jnp.einsum("bhpk,bhk->bhp", new_ssm,
                        ch.astype(jnp.float32))
        yh = yh + params["D"].astype(jnp.float32)[None, :, None] * xh
        y = yh.reshape(bsz, 1, h, p).astype(x.dtype)
        new_cache = MambaCache(conv_in[:, 1:, :].astype(cache.conv.dtype),
                               new_ssm)

    # gated RMSNorm + output projection
    y = y.reshape(bsz, t, di)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return (y @ params["out_proj"]).astype(x.dtype), new_cache


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> MambaCache:
    mc = cfg.mamba
    di = mc.d_inner(cfg.d_model)
    h = mc.n_heads(cfg.d_model)
    conv_dim = di + 2 * mc.n_groups * mc.d_state
    return MambaCache(
        conv=jnp.zeros((batch, mc.d_conv - 1, conv_dim), dtype),
        ssm=jnp.zeros((batch, h, mc.head_dim, mc.d_state), jnp.float32))
