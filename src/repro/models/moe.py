"""Mixture-of-Experts FFN with GShard-style grouped token dispatch.

Covers the three assigned MoE shapes:

* qwen2-moe-a2.7b  — 60 routed experts top-4 + 4 shared experts
  [hf:Qwen/Qwen1.5-MoE-A2.7B]
* llama4-maverick  — 128 routed experts top-1 + 1 shared expert
  [hf:meta-llama/Llama-4-Scout-17B-16E]
* jamba-1.5-large  — 16 routed experts top-2 [arXiv:2403.19887]

Dispatch layout (the part that decides the collective schedule on TPU):
tokens are split into ``G`` *groups* — one per data shard — and each group
owns its own per-expert capacity ``C = S·K·cf/E``.  Slot assignment
(a cumsum over the group's token-choices) and the dispatch scatter /
combine gather are then **group-local**: with the group dim sharded over
``data`` they lower to shard-local ops.  The only cross-device movement is
the resharding of the ``[G, E, C, d]`` buffers from group-sharded to
expert-sharded around the expert matmuls — exactly the MoE all-to-all.

A global-capacity formulation (slot = global cumsum) makes every token's
slot depend on all other shards' tokens: GSPMD must replicate the
dispatch (observed: 68 GB f32 all-reduces *per MoE layer* on the 398B
config). The grouped layout removes them — EXPERIMENTS.md §Perf
iterations 1-3 document the progression.

Per-expert overflow beyond capacity is dropped (the residual stream
carries dropped tokens unchanged), giving the roofline's expected
``top_k × capacity_factor`` dense-MLP-equivalents of compute.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.dist.sharding import dispatch_groups, maybe_shard
from repro.models.layers import init_mlp, mlp

Array = jax.Array


def init_moe(key: Array, cfg: ArchConfig) -> dict:
    m = cfg.moe
    d, dt = cfg.d_model, cfg.pdtype
    k_router, k_experts, k_shared = jax.random.split(key, 3)
    s_in = 1.0 / jnp.sqrt(d)
    s_out = 1.0 / jnp.sqrt(m.d_expert)
    ke = jax.random.split(k_experts, 3)
    p = {
        "router": jax.random.normal(k_router, (d, m.n_experts), dt) * s_in,
        # stacked expert weights [E, d, f] / [E, f, d]
        "w_gate": jax.random.normal(ke[0], (m.e_padded, d, m.d_expert), dt) * s_in,
        "w_up": jax.random.normal(ke[1], (m.e_padded, d, m.d_expert), dt) * s_in,
        "w_down": jax.random.normal(ke[2], (m.e_padded, m.d_expert, d), dt) * s_out,
    }
    if m.n_shared:
        p["shared"] = init_mlp(k_shared, d, m.shared_hidden, dt)
    return p


def _group_capacity(m: MoEConfig, group_tokens: int) -> int:
    cap = int(group_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(cap, 4)


def moe_ffn(params: dict, cfg: ArchConfig, x: Array
            ) -> tuple[Array, Array]:
    """MoE FFN over x: [B, S, d].  Returns (out, router aux loss)."""
    m = cfg.moe
    b, s, d = x.shape
    n_tok = b * s
    g = dispatch_groups()
    if n_tok % g:
        g = 1
    sg = n_tok // g                                             # tokens/group
    xt = x.reshape(n_tok, d)
    # un-shard d at MoE entry: dispatch buffers carrying d/model force
    # partial-sum all-reduces through every expert einsum (§Perf it. 4)
    xt = maybe_shard(xt, ("pod", "data"), None)

    logits = (xt @ params["router"]).astype(jnp.float32)        # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)       # [T, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                 # renormalise

    # load-balancing auxiliary loss (Switch/GShard) — global statistics
    me = probs.mean(0)                                          # [E]
    ce_frac = jnp.zeros((m.n_experts,), jnp.float32).at[
        expert_idx.reshape(-1)].add(1.0) / (n_tok * m.top_k)
    aux = m.n_experts * jnp.sum(me * ce_frac) * m.router_aux_weight

    cap = _group_capacity(m, sg)

    # ---- group-local slot assignment [G, S*K] -----------------------------
    # buffers use the padded expert count so the E dim divides the mesh
    # (padded experts receive no tokens; see MoEConfig.pad_to)
    e_pad = m.e_padded
    fe = expert_idx.reshape(g, sg * m.top_k)                    # flat experts
    onehot = jax.nn.one_hot(fe, e_pad, dtype=jnp.int32)         # [G, SK, E]
    pos_all = jnp.cumsum(onehot, axis=1) - onehot               # exclusive
    pos = jnp.take_along_axis(pos_all, fe[..., None],
                              axis=2)[..., 0]                   # [G, SK]
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap - 1)

    # ---- group-local dispatch scatter: [G, E, C, d] ------------------------
    x_rep = jnp.broadcast_to(
        xt.reshape(g, sg, 1, d),
        (g, sg, m.top_k, d)).reshape(g, sg * m.top_k, d)
    updates = jnp.where(keep[..., None], x_rep, 0).astype(x.dtype)

    def dispatch_one(fe_g, sp_g, upd_g):
        return jnp.zeros((e_pad, cap, d), x.dtype) \
            .at[fe_g, sp_g].add(upd_g)

    buf = jax.vmap(dispatch_one)(fe, safe_pos, updates)          # [G,E,C,d]
    # group-sharded [G/data, E, C, d] -> expert-sharded [G, E/data, C, d]:
    # the MoE dispatch all-to-all, within the data axis only (single-axis
    # reshards are the pattern GSPMD lowers to a real all-to-all)
    buf = maybe_shard(buf, None, ("pod", "data"), None, None)

    # ---- expert MLPs: [G,E/data,C,d] @ [E/data,d,f/model] ------------------
    # bf16 accumulation on the row-parallel down projection keeps the
    # (canonical, unavoidable) TP partial-sum all-reduce at half width
    pet = x.dtype if x.dtype == jnp.bfloat16 else None
    gate = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    hid = maybe_shard(jax.nn.silu(gate) * up,
                      None, ("pod", "data"), None, "model")
    out_buf = jnp.einsum("gecf,efd->gecd", hid, params["w_down"],
                         preferred_element_type=pet)
    # reshard back expert-sharded -> group-sharded (combine all-to-all)
    out_buf = maybe_shard(out_buf, ("pod", "data"), None, None, None)

    # ---- group-local combine gather + structured top-k sum -----------------
    def combine_one(ob_g, fe_g, sp_g):
        return ob_g[fe_g, sp_g]                                  # [SK, d]

    gathered = jax.vmap(combine_one)(out_buf, fe, safe_pos)
    w = jnp.where(keep, gate_vals.reshape(g, sg * m.top_k), 0.0) \
        .astype(x.dtype)
    contrib = gathered * w[..., None]
    out = contrib.reshape(g, sg, m.top_k, d).sum(axis=2) \
        .reshape(n_tok, d)
    out = maybe_shard(out, ("pod", "data"), None)

    if m.n_shared:
        out = out + mlp(params["shared"], xt, "swiglu")
    return out.reshape(b, s, d), aux
