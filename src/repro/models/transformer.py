"""Unified decoder covering all 10 assigned architectures.

A model is ``n_blocks`` repetitions of a *pattern* (a tuple of layer kinds,
e.g. ``("attn",)`` for dense LMs or ``7×mamba + 1×attn`` for jamba), scanned
with ``lax.scan`` so the HLO stays block-sized regardless of depth, with
optional per-block remat (only block-boundary activations live across the
backward pass).

Three entry points:

* ``lm_loss``     — training forward + next-token CE (+ MoE aux loss).
* ``prefill``     — forward returning logits + a populated ``Cache``.
* ``decode_step`` — one-token serve step against a Cache (O(1) for SSM
  layers; ring-buffer sliding-window or full causal for attention).

Attention uses memory-bounded chunked (flash-style, online-softmax) SDPA
for long sequences — see ``chunked_sdpa`` — so the 32k prefill lowers
without materialising [S, S] score matrices.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.dist.sharding import maybe_shard
from repro.models import layers as L
from repro.models.mamba2 import (MambaCache, init_mamba, init_mamba_cache,
                                 mamba_layer)
from repro.models.moe import init_moe, moe_ffn
from repro.nn.modules import rms_norm, softmax_cross_entropy

Array = jax.Array


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention for long sequences
# ---------------------------------------------------------------------------


def chunked_sdpa(q: Array, k: Array, v: Array, window: int,
                 q_chunk: int = 512, kv_chunk: int = 1024) -> Array:
    """Online-softmax causal attention; peak memory O(q_chunk × kv_chunk).

    q: [B, S, H, D], k/v: [B, S, KV, D] (same length, causal, optional
    sliding window).  Equivalent to ``L.sdpa`` with a causal/window mask.

    Sharding: all tensors keep a *flat* query-head axis constrained to
    ``model`` — splitting H into (kv, group) axes defeats GSPMD head
    sharding and made it all-gather every score tile (EXPERIMENTS.md §Perf
    iteration 6).  GQA is realised by repeating the per-chunk KV slab to H
    inside the scan body (67 MB-scale, shard-local).
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    group = h // kvh
    nq = s // q_chunk
    nk = s // kv_chunk
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    q = maybe_shard(q, ("pod", "data"), None, "model", None)
    qc = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, d), 3, 2)
    qc = maybe_shard(qc, ("pod", "data"), None, "model", None, None)
    kc = k.reshape(b, nk, kv_chunk, kvh, d)
    vc = v.reshape(b, nk, kv_chunk, kvh, d)

    def q_block(qi, q_blk):                           # q_blk [B, H, Qc, D]
        q_pos = qi * q_chunk + jnp.arange(q_chunk)
        # re-assert head sharding inside the map body — constraints outside
        # lax.map/scan don't reach the body computation
        q_blk = maybe_shard(q_blk, ("pod", "data"), "model", None, None)

        def kv_step(carry, inp):
            m_run, l_run, acc = carry
            kj, k_blk, v_blk = inp                    # [B, Kc, KV, D]
            k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
            # per-chunk GQA expansion; H/model-sharded via the scores hint
            krep = jnp.repeat(k_blk, group, axis=2)   # [B, Kc, H, D]
            vrep = jnp.repeat(v_blk, group, axis=2)
            krep = maybe_shard(krep, ("pod", "data"), None, "model", None)
            vrep = maybe_shard(vrep, ("pod", "data"), None, "model", None)
            scores = jnp.einsum("bhqd,bshd->bhqs", q_blk,
                                krep).astype(jnp.float32) * scale
            scores = maybe_shard(scores, ("pod", "data"), "model", None,
                                 None)
            mask = k_pos[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= k_pos[None, :] > (q_pos[:, None] - window)
            scores = jnp.where(mask[None, None], scores,
                               jnp.finfo(jnp.float32).min)
            m_new = jnp.maximum(m_run, scores.max(-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(scores - m_new[..., None])
            l_new = l_run * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", p.astype(q.dtype), vrep
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, h, q_chunk), jnp.finfo(jnp.float32).min)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        (m_f, l_f, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
        out = acc / jnp.maximum(l_f, 1e-20)[..., None]
        return out.astype(q.dtype)                    # [B, H, Qc, D]

    outs = lax.map(lambda args: q_block(*args),
                   (jnp.arange(nq), jnp.moveaxis(qc, 1, 0)))
    # outs: [nq, B, H, Qc, D] -> [B, S, H, D]
    out = jnp.moveaxis(outs, 0, 2)                    # [B, H, nq, Qc, D]
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


class AttnCache(NamedTuple):
    """Ring-buffer KV cache: ``pos`` holds absolute positions (-1 empty)."""
    k: Array        # [B, W, KV, D]
    v: Array        # [B, W, KV, D]
    pos: Array      # [B, W] int32


class Cache(NamedTuple):
    """Per-pattern-position caches, each stacked over n_blocks."""
    layers: tuple   # tuple over pattern idx of AttnCache | MambaCache
    index: Array    # scalar int32: number of tokens already in cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=None) -> Cache:
    dtype = dtype or cfg.adtype
    nb = cfg.n_blocks
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    w = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    per = []
    for kind in cfg.pattern:
        if kind == "attn":
            per.append(AttnCache(
                k=jnp.zeros((nb, batch, w, kv, hd), dtype),
                v=jnp.zeros((nb, batch, w, kv, hd), dtype),
                pos=jnp.full((nb, batch, w), -1, jnp.int32)))
        else:
            mc = init_mamba_cache(cfg, batch, dtype)
            per.append(MambaCache(
                conv=jnp.broadcast_to(mc.conv, (nb,) + mc.conv.shape),
                ssm=jnp.broadcast_to(mc.ssm, (nb,) + mc.ssm.shape)))
    return Cache(layers=tuple(per), index=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _init_block(key: Array, cfg: ArchConfig) -> dict:
    """One pattern-period of layers."""
    block = {}
    for pi, kind in enumerate(cfg.pattern):
        key, k_mix, k_ffn = jax.random.split(key, 3)
        lp: dict = {"norm1": jnp.zeros((cfg.d_model,), cfg.pdtype),
                    "norm2": jnp.zeros((cfg.d_model,), cfg.pdtype)}
        if kind == "attn":
            lp["attn"] = L.init_attn(k_mix, cfg)
        else:
            lp["mamba"] = init_mamba(k_mix, cfg)
        if cfg.layer_uses_moe(pi):
            lp["moe"] = init_moe(k_ffn, cfg)
        elif cfg.d_ff > 0:
            lp["mlp"] = L.init_mlp(k_ffn, cfg.d_model, cfg.d_ff, cfg.pdtype)
        else:
            del lp["norm2"]     # mamba2-style blocks: mixer only, no FFN
        block[f"p{pi}_{kind}"] = lp
    return block


def init_lm(key: Array, cfg: ArchConfig) -> dict:
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    params = {
        "embed": jax.random.normal(
            k_embed, (cfg.vocab_size, cfg.d_model), cfg.pdtype) * 0.02,
        "final_norm": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg))(
            jax.random.split(k_blocks, cfg.n_blocks)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab_size), cfg.pdtype) * 0.02
    return params


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _mixer(lp: dict, cfg: ArchConfig, pi: int, kind: str, h: Array,
           positions: Array, cache_layer, cache_index,
           positions3) -> tuple[Array, object]:
    """Apply the token mixer (attention or mamba) for one layer."""
    if kind == "attn":
        if cache_layer is None:
            s = h.shape[1]
            use_chunked = s >= 2048 and s % 1024 == 0 and \
                positions3 is None and not cfg.mrope_sections
            if use_chunked:
                q, k, v, _ = _attn_qkv(lp["attn"], cfg, h, positions)
                # after sequence parallelism, k/v inherit S/model sharding;
                # left that way, every kv-chunk slice in the attention loop
                # re-gathers over model (observed 17 GB/layer on yi).
                # Materialise them ONCE per layer: S unsharded, heads on
                # model when they divide (else replicated — KV slabs are
                # ~67 MB).  EXPERIMENTS.md §Perf iteration 8.
                q = maybe_shard(q, ("pod", "data"), None, "model", None)
                k = maybe_shard(k, ("pod", "data"), None, "model", None)
                v = maybe_shard(v, ("pod", "data"), None, "model", None)
                out = chunked_sdpa(q, k, v, cfg.sliding_window)
                y = jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
                return y.astype(h.dtype), AttnCache(
                    k.astype(cfg.adtype), v.astype(cfg.adtype),
                    jnp.broadcast_to(positions, (h.shape[0], s)))
            y, kvc = L.attention(lp["attn"], cfg, h, positions,
                                 positions3=positions3)
            return y, AttnCache(kvc.k.astype(cfg.adtype),
                                kvc.v.astype(cfg.adtype),
                                jnp.broadcast_to(positions,
                                                 (h.shape[0], h.shape[1])))
        y, new = _attn_decode(lp["attn"], cfg, h, positions, cache_layer,
                              cache_index, positions3)
        return y, new
    # mamba
    y, new = mamba_layer(lp["mamba"], cfg, h,
                         cache=cache_layer)
    return y, new


def _attn_qkv(params, cfg: ArchConfig, x: Array, positions: Array):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v, None


def _attn_decode(params, cfg: ArchConfig, x: Array, positions: Array,
                 cache: AttnCache, cache_index: Array, positions3):
    """One-token decode against a (possibly ring-buffer) KV cache."""
    b, s, _ = x.shape
    assert s == 1
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if cfg.mrope_sections:
        p3 = positions3 if positions3 is not None else \
            jnp.broadcast_to(positions[None], (3, *positions.shape))
        q = L.apply_mrope(q, p3, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_mrope(k, p3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)

    w = cache.k.shape[1]
    slot = jnp.mod(cache_index, w)
    k_c = lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype),
                                          slot, axis=1)
    v_c = lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype),
                                          slot, axis=1)
    pos_c = lax.dynamic_update_slice_in_dim(
        cache.pos, jnp.broadcast_to(positions, (b, 1)).astype(jnp.int32),
        slot, axis=1)

    q_pos = positions[:, :1]                                   # [B, 1]
    valid = (pos_c >= 0) & (pos_c <= q_pos)
    if cfg.sliding_window:
        valid &= pos_c > (q_pos - cfg.sliding_window)
    out = L.sdpa(q, k_c, v_c, valid[:, None, :])
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y.astype(x.dtype), AttnCache(k_c, v_c, pos_c)


def _apply_block(block: dict, cfg: ArchConfig, h: Array, positions: Array,
                 block_cache: Optional[tuple], cache_index,
                 positions3) -> tuple[Array, tuple, Array]:
    """One pattern period: pre-norm mixer + pre-norm FFN per layer."""
    new_caches = []
    aux = jnp.zeros((), jnp.float32)
    decode = h.shape[1] == 1
    for pi, kind in enumerate(cfg.pattern):
        lp = block[f"p{pi}_{kind}"]
        cl = block_cache[pi] if block_cache is not None else None
        # sequence parallelism (Korthikanti et al.): between layers the
        # residual stream is sharded over `model` on the SEQUENCE dim, so
        # each TP layer costs all-gather(in) + reduce-scatter(out) instead
        # of all-gather + all-reduce (EXPERIMENTS.md §Perf iteration 5).
        # decode steps (S=1) keep the d-sharded layout.
        if decode:
            h = maybe_shard(h, ("pod", "data"), None, "model")
        else:
            h = maybe_shard(h, ("pod", "data"), "model", None)
        mixed, new_c = _mixer(lp, cfg, pi, kind, rms_norm(h, lp["norm1"]),
                              positions, cl, cache_index, positions3)
        h = h + mixed
        if cfg.layer_uses_moe(pi):
            ffn_out, a = moe_ffn(lp["moe"], cfg, rms_norm(h, lp["norm2"]))
            aux = aux + a
            h = h + ffn_out
        elif cfg.d_ff > 0:
            h = h + L.mlp(lp["mlp"], rms_norm(h, lp["norm2"]), cfg.mlp)
        new_caches.append(new_c)
    return h, tuple(new_caches), aux


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def _embed_in(params, cfg: ArchConfig, batch: dict) -> tuple[Array, Array]:
    if "embeds" in batch:                       # vlm / stubbed frontend
        x = batch["embeds"].astype(cfg.adtype)
    else:
        x = params["embed"][batch["tokens"]].astype(cfg.adtype)
    b, s = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions


def _lm_head(params, cfg: ArchConfig, h: Array) -> Array:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return h @ params["embed"].T.astype(h.dtype)
    return h @ params["lm_head"].astype(h.dtype)


def forward_train(params, cfg: ArchConfig, batch: dict
                  ) -> tuple[Array, Array]:
    """Training forward: scan over blocks, no cache emission.

    Returns (hidden [B,S,d], moe_aux).  With ``cfg.remat`` each block is
    checkpointed — only block-boundary activations survive the forward.
    """
    x, positions = _embed_in(params, cfg, batch)
    positions3 = batch.get("positions3")
    x = maybe_shard(x, ("pod", "data"), "model", None)   # sequence parallel

    def block_fn(block, h):
        h, _, aux = _apply_block(block, cfg, h, positions, None, None,
                                 positions3)
        return h, aux

    if cfg.remat:
        block_fn = jax.checkpoint(block_fn, prevent_cse=False)

    def scan_body(h, block):
        h, aux = block_fn(block, h)
        return h, aux

    h, auxs = lax.scan(scan_body, x, params["blocks"])
    return h, jnp.sum(auxs)


def lm_loss(params, cfg: ArchConfig, batch: dict) -> tuple[Array, dict]:
    """Next-token CE + MoE aux. ``batch``: tokens [B,S] (+ embeds/labels)."""
    h, aux = forward_train(params, cfg, batch)
    logits = _lm_head(params, cfg, h).astype(jnp.float32)
    labels = batch.get("labels", batch.get("tokens"))
    ce = softmax_cross_entropy(logits[:, :-1], labels[:, 1:])
    mask = batch.get("loss_mask")
    if mask is not None:
        m = mask[:, 1:].astype(jnp.float32)
        loss = jnp.sum(ce * m) / jnp.maximum(jnp.sum(m), 1.0)
    else:
        loss = jnp.mean(ce)
    return loss + aux, {"ce": loss, "moe_aux": aux}


def prefill(params, cfg: ArchConfig, batch: dict, max_len: int | None = None
            ) -> tuple[Array, Cache]:
    """Process a full prompt; returns last-position logits + a Cache with
    ``max_len`` slots (ring-truncated to the sliding window if set).

    Note: with a sliding window ``w``, prompt length must satisfy
    ``s % w == 0 or s <= w`` so the ring-buffer slot arithmetic stays
    aligned for subsequent decode steps.
    """
    x, positions = _embed_in(params, cfg, batch)
    b, s = x.shape[0], x.shape[1]
    max_len = max_len or s
    positions3 = batch.get("positions3")
    x = maybe_shard(x, ("pod", "data"), "model", None)   # sequence parallel

    def scan_body(h, block):
        h, new_c, aux = _apply_block(block, cfg, h, positions, None, None,
                                     positions3)
        return h, new_c

    h, layer_caches = lax.scan(scan_body, x, params["blocks"])
    logits = _lm_head(params, cfg, h[:, -1:])

    # size the per-layer KV caches to max_len (or the SWA window)
    w = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    if cfg.sliding_window and not (s <= w or s % w == 0):
        raise ValueError(f"prefill length {s} incompatible with window {w}")
    padded = []
    for pi, kind in enumerate(cfg.pattern):
        lc = layer_caches[pi]
        if kind == "attn":
            pad = w - lc.k.shape[2]
            if pad > 0:
                z = lambda a: jnp.pad(
                    a, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 3))
                padded.append(AttnCache(
                    z(lc.k), z(lc.v),
                    jnp.pad(lc.pos, ((0, 0), (0, 0), (0, pad)),
                            constant_values=-1)))
            else:  # keep the last w entries (SWA ring layout)
                padded.append(AttnCache(lc.k[:, :, -w:], lc.v[:, :, -w:],
                                        lc.pos[:, :, -w:]))
        else:
            padded.append(lc)
    return logits[:, 0], Cache(layers=tuple(padded),
                               index=jnp.full((), s, jnp.int32))


def decode_step(params, cfg: ArchConfig, batch: dict, cache: Cache
                ) -> tuple[Array, Cache]:
    """One-token serve step: batch['tokens'] [B,1] (or embeds [B,1,d])."""
    b = batch["tokens"].shape[0] if "tokens" in batch else \
        batch["embeds"].shape[0]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(cache.index[None, None],
                                     (b, 1)).astype(jnp.int32)
        batch = dict(batch, positions=positions)
    x, positions = _embed_in(params, cfg, batch)
    positions3 = batch.get("positions3")

    def scan_body(h, inp):
        block, bc = inp
        h, new_c, _aux = _apply_block(block, cfg, h, positions, bc,
                                      cache.index, positions3)
        return h, new_c

    h, new_layers = lax.scan(scan_body, x, (params["blocks"], cache.layers))
    logits = _lm_head(params, cfg, h)
    return logits[:, 0], Cache(layers=new_layers, index=cache.index + 1)
