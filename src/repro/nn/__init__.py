from .gnn import (GNNConfig, centralized_aggregate_fn, centralized_forward,
                  gnn_forward, init_gnn, masked_loss_and_correct)
from .modules import (dense, dense_init, layer_norm, param_count, rms_norm,
                      softmax_cross_entropy)

__all__ = [
    "GNNConfig", "centralized_aggregate_fn", "centralized_forward",
    "gnn_forward", "init_gnn", "masked_loss_and_correct",
    "dense", "dense_init", "layer_norm", "param_count", "rms_norm",
    "softmax_cross_entropy",
]
