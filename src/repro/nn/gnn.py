"""GNN models (paper §II eq. (2) + §V experimental setup).

The model is written against an abstract *aggregation oracle*
``aggregate(layer, x) -> (Sx, wire_bits)`` so the **same model code** runs

* centralised (single device, exact full-graph aggregation) — the reference
  the distributed runtime must match under full communication, and
* distributed (`repro.dist.gnn_parallel`) — per-partition aggregation with a
  compressed halo exchange supplying the remote neighbour terms.

Conv types
----------
``sage``  GraphSAGE mean aggregator (paper §V):
          ``h = ρ(x W_self + (S_mean x) W_neigh + b)``
``poly``  The paper's polynomial graph convolution (eq. 2) with K taps:
          ``h = ρ(Σ_k (S^k x) H_k)`` with S symmetric-normalised.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .modules import dense, dense_init, softmax_cross_entropy

Array = jax.Array

# aggregate(layer_idx, x) -> (aggregated, wire_bits)
AggregateFn = Callable[[int, Array], tuple[Array, Array]]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    conv: str = "sage"          # "sage" | "poly"
    in_dim: int = 128
    hidden: int = 256           # paper §V: 256 hidden units
    out_dim: int = 40
    layers: int = 3             # paper §V: 3 layers
    k_taps: int = 2             # poly conv: number of filter taps K
    residual: bool = False

    def dims(self) -> list[tuple[int, int]]:
        ds = [self.in_dim] + [self.hidden] * (self.layers - 1) + [self.out_dim]
        return list(zip(ds[:-1], ds[1:]))


def init_gnn(key: Array, cfg: GNNConfig) -> dict:
    params: dict = {"layers": []}
    for li, (d_in, d_out) in enumerate(cfg.dims()):
        key, *sub = jax.random.split(key, 4)
        if cfg.conv == "sage":
            layer = {
                "self": dense_init(sub[0], d_in, d_out, bias=True),
                "neigh": dense_init(sub[1], d_in, d_out, bias=False),
            }
        elif cfg.conv == "poly":
            layer = {"taps": [dense_init(k, d_in, d_out, bias=(t == 0))
                              for t, k in enumerate(
                                  jax.random.split(sub[0], cfg.k_taps))]}
        else:
            raise ValueError(f"unknown conv {cfg.conv!r}")
        params["layers"].append(layer)
    return params


def gnn_forward(params: dict, cfg: GNNConfig, x: Array,
                aggregate: AggregateFn,
                hidden_out: list | None = None) -> tuple[Array, Array]:
    """Run the GNN; returns (logits, total_wire_bits).

    ``hidden_out`` (optional list) collects every layer's post-activation
    output — one entry per layer, the last being the returned logits —
    without touching the compute graph; the serving embedding cache
    (``repro.serve``, DESIGN.md §3.11) stores these per (layer,
    node-block).

    ``aggregate`` is called once per (layer, tap>0): every call corresponds
    to one halo exchange in the distributed runtime (Fig. 2's
    compute → compress → communicate → decompress round).

    When the oracle carries the split-phase attributes ``start(li, x) ->
    (token, bits)`` / ``complete(li, x, token) -> agg`` (the distributed
    p2p/packed oracles of ``repro.dist.gnn_parallel`` do), the forward
    runs the **pipelined halo prefetch** schedule (DESIGN.md §3.7): each
    layer's pack + exchange is issued *first*, the exchange-independent
    local work (the self-term matmul here, the ELL local aggregation
    inside ``complete``) is scheduled while the hops are in flight, and
    the wire is consumed only at the unpack inside ``complete``.  The two
    phases are the fused oracle's own halves, so the pipelined and fused
    schedules are bitwise identical (pinned by tests/test_layer_rates.py)
    — at most two exchanges' hop buffers are ever live (double-buffered
    hop slots).
    """
    bits = jnp.zeros((), jnp.float32)
    h = x
    n_layers = len(params["layers"])
    start = getattr(aggregate, "start", None)
    complete = getattr(aggregate, "complete", None)
    pipelined = start is not None and complete is not None

    for li, layer in enumerate(params["layers"]):
        if cfg.conv == "sage":
            if pipelined:
                token, b = start(li, h)                # issue the exchange
                self_term = dense(layer["self"], h)    # overlaps the wire
                agg = complete(li, h, token)           # unpack + aggregate
                bits = bits + b
                h_new = self_term + dense(layer["neigh"], agg)
            else:
                agg, b = aggregate(li, h)
                bits = bits + b
                h_new = dense(layer["self"], h) + dense(layer["neigh"], agg)
        else:  # poly, eq. (2) — taps chain (tap t+1 consumes tap t), so
            # there is no exchange-independent work to interleave and the
            # fused call is the pipelined schedule already
            sk = h
            h_new = dense(layer["taps"][0], h)
            for t in range(1, cfg.k_taps):
                sk, b = aggregate(li, sk)
                bits = bits + b
                h_new = h_new + dense(layer["taps"][t], sk)
        if cfg.residual and h_new.shape == h.shape:
            h_new = h_new + h
        h = jax.nn.relu(h_new) if li < n_layers - 1 else h_new
        if hidden_out is not None:
            hidden_out.append(h)
    return h, bits


def masked_loss_and_correct(logits: Array, labels: Array, mask: Array
                            ) -> tuple[Array, Array]:
    """Sum of CE over masked nodes + count of correct predictions."""
    ce = softmax_cross_entropy(logits, labels)
    m = mask.astype(jnp.float32)
    loss_sum = jnp.sum(ce * m)
    correct = jnp.sum((jnp.argmax(logits, -1) == labels) * m)
    return loss_sum, correct


# ---------------------------------------------------------------------------
# Centralised aggregation oracle (reference semantics)
# ---------------------------------------------------------------------------


def centralized_aggregate_fn(n: int, dst: Array, src: Array, w: Array
                             ) -> AggregateFn:
    """Exact full-graph ``S x`` via segment-sum; zero wire bits."""
    dst = jnp.asarray(dst, jnp.int32)
    src = jnp.asarray(src, jnp.int32)
    w = jnp.asarray(w, jnp.float32)

    def aggregate(_li: int, x: Array) -> tuple[Array, Array]:
        contrib = x[src] * w[:, None]
        agg = jnp.zeros((n,) + x.shape[1:], x.dtype).at[dst].add(contrib)
        return agg, jnp.zeros((), jnp.float32)

    return aggregate


def centralized_forward(params: dict, cfg: GNNConfig, g, norm: str = "mean"
                        ) -> Array:
    """Full-graph forward on a host GraphData (test/eval reference)."""
    from repro.graph.data import normalized_edge_weights
    dst, src = g.edge_list()
    w = normalized_edge_weights(g, kind=norm)
    agg = centralized_aggregate_fn(g.num_nodes, dst, src, w)
    logits, _ = gnn_forward(params, cfg, jnp.asarray(g.features), agg)
    return logits
