"""Minimal functional NN building blocks (param pytrees + pure apply fns).

No flax/haiku on this box; every model in the framework is a pair of
``init(key, ...) -> params`` and ``apply(params, ...) -> out`` functions over
plain dict pytrees.  Initialisers follow the conventions of the respective
source papers (LeCun/He fan-in scaling).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array


def dense_init(key: Array, d_in: int, d_out: int, *, bias: bool = True,
               scale: float = 1.0, dtype=jnp.float32) -> dict:
    std = scale / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * std}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params: dict, x: Array) -> Array:
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


def layer_norm(x: Array, eps: float = 1e-5) -> Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    # compute in f32 for stability regardless of activation dtype
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * scale) * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def softmax_cross_entropy(logits: Array, labels: Array) -> Array:
    """Per-example CE; labels int. Stable log-softmax."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return logz - gold


def param_count(params) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))
