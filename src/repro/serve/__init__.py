"""Distributed GNN inference serving (DESIGN.md §3.11).

The serving runtime reuses the training data plane — the partitioned
graph, the p2p halo wire, the packed/quantised codecs and the rate
controllers — to answer node-embedding queries without the grad
plumbing:

* ``frontend`` — :class:`MicroBatcher` (deadline-aware multi-tenant
  micro-batching per owning partition) and :class:`ServingEngine`, the
  query-facing runtime over ``make_infer_step``'s inference-only
  distributed forward.
* ``cache``    — :class:`EmbeddingCache`, post-layer activations keyed
  by ``(layer, node-block)`` with drift-gated invalidation sharing the
  ``stale`` controller's halo-drift predicate
  (:func:`repro.dist.ratectl.stale.drift_skip`): cached halos serve at
  zero wire bits until measured drift crosses the threshold, then
  refresh through the packed/quantised wire at controller-chosen
  rate × width.
* ``update``   — incremental recompute on streaming edge-update batches
  (through ``repro.graph.stream.EdgeSpill``'s spill path): only the
  k-hop frontier of touched nodes is re-embedded.

Example::

    from repro.serve import ServingEngine
    eng = ServingEngine(g, params, cfg, q=4)
    eng.refresh(force=True)                    # cold start: exact halos
    emb, status = eng.serve([3, 17, 101])      # status == "FRESH"
"""

from repro.serve.cache import EmbeddingCache
from repro.serve.frontend import MicroBatcher, Query, ServingEngine
from repro.serve.update import apply_edge_updates, incremental_recompute

__all__ = ["EmbeddingCache", "MicroBatcher", "Query", "ServingEngine",
           "apply_edge_updates", "incremental_recompute"]
