"""Per-partition embedding cache keyed by ``(layer, node-block)``.

The serving runtime stores every layer's post-activation output (the
``hiddens`` tuple of ``make_infer_step``) in partition-local blocks of
``block_nodes`` rows, so a query gathers its answer with two integer
indirections (owner → block → offset) and an update batch invalidates
only the blocks its frontier touches.

Invalidation is **drift-gated** and shares the ``stale`` controller's
halo-drift predicate verbatim (:func:`repro.dist.ratectl.stale.
drift_skip` — one function, two call sites, pinned by
tests/test_serve.py): a pair whose measured halo drift is under the
threshold and whose staleness is under the cap keeps serving its cached
rows at **zero wire bits**; once either trips, the refresh ships through
the packed/quantised wire at the controller-chosen rate × width.
"""

from __future__ import annotations

import numpy as np

from repro.dist.ratectl.stale import drift_skip

__all__ = ["EmbeddingCache"]


class EmbeddingCache:
    """Blocked activation store over a fixed partition assignment.

    ``owner[n]`` / ``local_index[n]`` are the partitioner's maps
    (:class:`repro.graph.partition.PartitionedGraph`); ``put`` ingests a
    padded ``[Q, P, F]`` layer stack, ``gather`` answers global node ids.

    Example::

        cache = EmbeddingCache(pg.owner, pg.local_index, pg.part_size)
        cache.put(0, np.asarray(hiddens[0]))
        rows = cache.gather(0, [3, 17, 101])
    """

    def __init__(self, owner: np.ndarray, local_index: np.ndarray,
                 part_size: int, block_nodes: int = 128):
        self.owner = np.asarray(owner, np.int64)
        self.local = np.asarray(local_index, np.int64)
        self.part_size = int(part_size)
        self.block_nodes = max(int(block_nodes), 1)
        self.n_blocks = -(-self.part_size // self.block_nodes)
        self._store: dict[tuple[int, int, int], np.ndarray] = {}

    def put(self, layer: int, acts: np.ndarray) -> None:
        """Ingest one layer's ``[Q, P, F]`` padded activation stack,
        splitting each partition's rows into ``(layer, block)`` entries."""
        acts = np.asarray(acts)
        if acts.ndim != 3 or acts.shape[1] != self.part_size:
            raise ValueError(f"expected [Q, {self.part_size}, F] stack, "
                             f"got {acts.shape}")
        for qo in range(acts.shape[0]):
            for b in range(self.n_blocks):
                lo = b * self.block_nodes
                hi = min(lo + self.block_nodes, self.part_size)
                # copy: blocks are mutated in place by scatter_rows
                self._store[(layer, qo, b)] = np.array(acts[qo, lo:hi])

    def scatter_rows(self, layer: int, nodes: np.ndarray,
                     rows: np.ndarray) -> None:
        """Overwrite single cached rows (incremental recompute lands its
        re-embedded frontier here; blocks not yet ``put`` are skipped)."""
        nodes = np.asarray(nodes, np.int64)
        b, off = np.divmod(self.local[nodes], self.block_nodes)
        for i, node in enumerate(nodes):
            key = (layer, int(self.owner[node]), int(b[i]))
            if key in self._store:
                self._store[key][int(off[i])] = rows[i]

    def gather(self, layer: int, nodes) -> np.ndarray:
        """``[len(nodes), F]`` cached rows for global node ids."""
        nodes = np.asarray(nodes, np.int64)
        b, off = np.divmod(self.local[nodes], self.block_nodes)
        return np.stack([
            self._store[(layer, int(self.owner[node]), int(b[i]))][int(off[i])]
            for i, node in enumerate(nodes)])

    def __contains__(self, key: tuple[int, int, int]) -> bool:
        return key in self._store

    def __len__(self) -> int:
        return len(self._store)

    @staticmethod
    def plan_refresh(delta, age, threshold: float, max_stale: int):
        """The drift gate: ``[Q, Q]`` 0/1 skip mask — 1 keeps serving the
        cached halo at zero wire bits, 0 refreshes the pair through the
        wire.  This IS :func:`repro.dist.ratectl.stale.drift_skip` (the
        training-side hop-reuse predicate): the property test pins that
        serving invalidates exactly when training would stop skipping."""
        return drift_skip(delta, age, threshold, max_stale)
