"""Serving frontend: micro-batched queries over the distributed forward.

:class:`MicroBatcher` groups multi-tenant node/edge queries by owning
partition under a deadline-aware batching window (flush when the oldest
waiting query ages past the window OR any partition's batch fills), so
one cache gather answers a whole partition's batch.

:class:`ServingEngine` is the runtime behind it: the training data plane
— partitioned graph, p2p halo wire, packed/quantised codecs, the
``auto:qos`` rate controller — re-used for inference
(``repro.dist.gnn_parallel.make_infer_step``, no grad plumbing), with a
drift-gated :class:`repro.serve.cache.EmbeddingCache` in front.  Cross-
partition neighbourhoods route through the p2p halo wire only on
refresh; between refreshes every query is a cache gather at zero wire
bits (DESIGN.md §3.11).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.varco import CommLedger, CommPolicy
from repro.dist.gnn_parallel import DistMeta, make_infer_step
from repro.dist.halo import attach_p2p, pair_query_mass
from repro.dist.ratectl import (RatePlan, exchange_widths, init_halo_cache,
                                make_controller)
from repro.graph.partition import build_partitioned, partition_graph
from repro.nn.gnn import GNNConfig
from repro.serve.cache import EmbeddingCache
from repro.serve.update import apply_edge_updates, incremental_recompute

__all__ = ["MicroBatcher", "Query", "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class Query:
    """One frontend request: a node embedding (``nodes == (u,)``) or an
    edge embedding (``nodes == (u, v)``, endpoint concat)."""
    nodes: tuple[int, ...]
    tenant: str = "default"
    arrival: float = 0.0


class MicroBatcher:
    """Deadline-aware per-partition micro-batching.

    Queries queue under the partition owning their first node;
    :meth:`ready` trips when any partition batch reaches ``max_batch``
    or the oldest waiting query has aged past ``window_s``.

    Example::

        mb = MicroBatcher(pg.owner, window_s=2e-3, max_batch=64)
        mb.submit((3,), "tenant-a", now=0.0)
        if mb.ready(now=0.003):
            per_part = mb.drain()
    """

    def __init__(self, owner: np.ndarray, window_s: float = 2e-3,
                 max_batch: int = 64):
        self.owner = np.asarray(owner, np.int64)
        self.window_s = float(window_s)
        self.max_batch = max(int(max_batch), 1)
        self._queues: dict[int, deque[Query]] = {}
        self._oldest: float | None = None

    def submit(self, nodes, tenant: str = "default",
               now: float | None = None) -> Query:
        now = time.monotonic() if now is None else now
        nodes = tuple(int(v) for v in (nodes if hasattr(nodes, "__len__")
                                       else (nodes,)))
        if not 1 <= len(nodes) <= 2:
            raise ValueError(f"a query names 1 node or 2 edge endpoints, "
                             f"got {len(nodes)}")
        qy = Query(nodes, tenant, now)
        self._queues.setdefault(int(self.owner[nodes[0]]),
                                deque()).append(qy)
        # true minimum, not first-arrival: callers feed explicit `now`
        # stamps (replay, skewed tenant clocks), so a later submit may
        # carry an EARLIER timestamp — keeping the first stamp would
        # leave _oldest too new and ready() would trip late or never
        if self._oldest is None or now < self._oldest:
            self._oldest = now
        return qy

    @property
    def pending(self) -> int:
        return sum(len(dq) for dq in self._queues.values())

    def ready(self, now: float | None = None) -> bool:
        if not self.pending:
            return False
        if any(len(dq) >= self.max_batch for dq in self._queues.values()):
            return True
        now = time.monotonic() if now is None else now
        return now - self._oldest >= self.window_s

    def drain(self) -> dict[int, list[Query]]:
        """Pop everything as ``{partition: [Query, ...]}`` (arrival
        order preserved within a partition)."""
        out = {p: list(dq) for p, dq in self._queues.items() if dq}
        self._queues.clear()
        self._oldest = None
        return out


class ServingEngine:
    """Distributed GNN inference server over one partitioned graph.

    Lifecycle: ``refresh(force=True)`` cold-starts the cache with one
    exact (rate-1, fp32) distributed forward; ``serve`` answers queries
    from the cache; periodic ``refresh()`` re-ships only the pairs whose
    measured halo drift crossed the ``stale`` predicate, at the
    ``auto:qos`` controller's rate × width (query-mass weighted);
    ``apply_updates`` folds an edge batch in and re-embeds the touched
    k-hop frontier.

    ``status()`` is ``"FRESH"`` while the cache provably equals a full
    fresh fp32 forward (cold start, then as long as every live pair
    keeps drift-skipping — a skipped refresh recomputes from identical
    halos, so exactness survives it) and ``"CACHED"`` otherwise.

    Example::

        eng = ServingEngine(g, params, cfg, q=4)
        eng.refresh(force=True)
        emb, status = eng.serve([3, 17])       # status == "FRESH"
    """

    def __init__(self, g, params: dict, cfg: GNNConfig, q: int, *,
                 policy: CommPolicy | str | None = None,
                 scheme: str = "metis-like", seed: int = 0,
                 refresh_horizon: int = 64, threshold: float = 0.05,
                 max_stale: int = 8, block_nodes: int = 128,
                 window_s: float = 2e-3, max_batch: int = 64,
                 rounding: str = "rint"):
        if cfg.conv != "sage":
            raise ValueError("the serving engine is sage-only (incremental "
                             f"recompute), got conv={cfg.conv!r}")
        self.g, self.params, self.cfg, self.q = g, params, cfg, q
        self.threshold, self.max_stale = float(threshold), int(max_stale)
        self.block_nodes, self.rounding = block_nodes, rounding
        self.refresh_horizon = int(refresh_horizon)
        self.pg = partition_graph(g, q, scheme=scheme, seed=seed)
        self.owner = np.asarray(self.pg.owner, np.int64)
        self._key = jax.random.key(seed)   # FIXED across refreshes: the
        # kept lane-block sets are then identical refresh-to-refresh, so
        # pair_delta measures real activation drift, not sampling noise
        if policy is None:
            # default qos budget: half the full-rate refresh spend
            full = 32.0 * float(self._full_refresh_bits())
            policy = f"auto:qos:{0.5 * full * self.refresh_horizon:g}:w8"
        if isinstance(policy, str):
            policy = CommPolicy.parse(policy, self.refresh_horizon)
        self.policy = policy
        self.batcher = MicroBatcher(self.owner, window_s=window_s,
                                    max_batch=max_batch)
        self.ledger = CommLedger.zero()
        self._qcount = np.zeros(q, np.float64)
        self._step = 0
        self._exact = False
        self._rebuild(self.pg)

    def _full_refresh_bits(self) -> float:
        return float(self.pg.halo_demand) * sum(exchange_widths(self.cfg))

    def _rebuild(self, pg) -> None:
        """(Re)build everything hanging off the partitioned graph: the
        device pytree, DistMeta, the inference step, the controller and
        the drift-gate state.  Called at init and after apply_updates."""
        self.pg = pg
        self.graph = attach_p2p(pg.device_arrays(), pg)
        self.meta = DistMeta.build(pg, self.params, wire="p2p")
        self.infer = make_infer_step(self.cfg, self.policy, self.meta,
                                     rounding=self.rounding)
        self.ctl = make_controller(self.policy, self.meta, self.cfg,
                                   self.refresh_horizon)
        self._ctl_state = self.ctl.init()
        self._halo_cache = init_halo_cache(self.meta, self.cfg)
        self._age = np.zeros((self.q, self.q), np.float32)
        self._skip_next = np.zeros((self.q, self.q), np.float32)
        self.cache = EmbeddingCache(pg.owner, pg.local_index, pg.part_size,
                                    block_nodes=self.block_nodes)

    # -- refresh ----------------------------------------------------------

    def refresh(self, force: bool = False) -> dict:
        """One distributed forward refreshing the embedding cache.

        ``force=True`` is the cold-start / resync path: rate 1, fp32,
        no drift skips — the cache becomes exact.  Otherwise the qos
        controller plans the pair rate × width map and the drift gate
        (``EmbeddingCache.plan_refresh`` == the ``stale`` predicate)
        decides which pairs serve from the halo cache at zero wire bits.
        Returns the step metrics (``halo_bits``/``transport_bits``
        forward-only, plus the ``[Q, Q]`` pair matrices).
        """
        q = self.q
        if force:
            rates = np.ones((q, q), np.float32)
            plan = RatePlan(jnp.asarray(rates),
                            jnp.zeros((q, q), jnp.float32), None)
        else:
            plan, self._ctl_state = self.ctl.plan(self._ctl_state,
                                                  self._step)
            plan = plan._replace(skip=jnp.asarray(self._skip_next))
        skip = np.asarray(plan.skip, np.float32)
        logits, hidden, m, self._halo_cache = self.infer(
            self.params, self.graph, self._key, plan, self._halo_cache)
        for li, h in enumerate(hidden):
            self.cache.put(li, np.asarray(h))
        delta = np.asarray(m["pair_delta"], np.float32)
        self._age = np.where(skip > 0.0, self._age + 1.0, 0.0)
        self._skip_next = np.asarray(self.cache.plan_refresh(
            delta, self._age, self.threshold, self.max_stale))
        obs = {"transport_bits": m["transport_bits"],
               "pair_err": m["pair_err"], "pair_delta": m["pair_delta"],
               "query_mass": pair_query_mass(self.meta.pair_table(),
                                             self._qcount)}
        self._ctl_state = self.ctl.observe(self._ctl_state, obs)
        self._qcount[:] = 0.0
        self.ledger = self.ledger.add_bits(m["halo_bits"],
                                           m["transport_bits"])
        off = ~np.eye(q, dtype=bool)
        self._exact = True if force else \
            bool(self._exact and np.all(skip[off] >= 1.0))
        self._step += 1
        return m

    def status(self) -> str:
        return "FRESH" if self._exact else "CACHED"

    # -- queries ----------------------------------------------------------

    def serve(self, nodes) -> tuple[np.ndarray, str]:
        """Final-layer embeddings ``[len(nodes), out_dim]`` for global
        node ids, straight from the cache (zero wire bits)."""
        nodes = np.asarray(nodes, np.int64)
        np.add.at(self._qcount, self.owner[nodes], 1.0)
        emb = self.cache.gather(len(self.params["layers"]) - 1, nodes)
        return emb, self.status()

    def serve_edges(self, pairs) -> tuple[np.ndarray, str]:
        """Edge queries: ``[len(pairs), 2·out_dim]`` endpoint concat."""
        pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
        u, _ = self.serve(pairs[:, 0])
        v, status = self.serve(pairs[:, 1])
        return np.concatenate([u, v], axis=-1), status

    def submit(self, nodes, tenant: str = "default",
               now: float | None = None) -> Query:
        """Enqueue one query into the micro-batching window."""
        return self.batcher.submit(nodes, tenant, now=now)

    def flush(self, now: float | None = None,
              force: bool = False) -> list[tuple[Query, np.ndarray]]:
        """Answer every waiting query if the batching window tripped
        (``ready``) or ``force=True``; one cache gather per partition
        batch.  Returns ``(query, embedding)`` pairs."""
        if not force and not self.batcher.ready(now):
            return []
        out: list[tuple[Query, np.ndarray]] = []
        for _, batch in sorted(self.batcher.drain().items()):
            for qy in batch:
                if len(qy.nodes) == 1:
                    emb, _ = self.serve([qy.nodes[0]])
                    out.append((qy, emb[0]))
                else:
                    emb, _ = self.serve_edges([qy.nodes])
                    out.append((qy, emb[0]))
        return out

    # -- streaming updates -------------------------------------------------

    def apply_updates(self, inserts=None, deletes=None
                      ) -> tuple[np.ndarray, list[np.ndarray]]:
        """Fold an undirected edge insert/delete batch into the served
        graph: rebuild the CSR through the ``EdgeSpill`` path, re-embed
        only the k-hop frontier of the touched endpoints
        (:func:`repro.serve.update.incremental_recompute`), repartition
        on the UNCHANGED owner vector, and reset the drift gate (the
        halo caches refer to the old topology).  Returns
        ``(touched, per-layer frontiers)``."""
        n = self.g.num_nodes
        g2, touched = apply_edge_updates(self.g, inserts, deletes)
        hidden_old = [self.cache.gather(li, np.arange(n))
                      for li in range(len(self.params["layers"]))]
        hidden_new, frontiers = incremental_recompute(
            self.params, self.cfg, g2, hidden_old, touched)
        self.g = g2
        self._rebuild(build_partitioned(g2, self.owner, self.q))
        for li, h in enumerate(hidden_new):
            self.cache.put(li, self._to_blocks(h))
        self._exact = False   # ≤ 1e-5 vs fresh, not bitwise
        return touched, frontiers

    def _to_blocks(self, garr: np.ndarray) -> np.ndarray:
        """Global ``[n, F]`` rows → padded ``[Q, P, F]`` stack."""
        out = np.zeros((self.q, self.pg.part_size, garr.shape[1]),
                       np.float32)
        idx = np.arange(len(garr))
        out[self.owner[idx], np.asarray(self.pg.local_index, np.int64)[idx]] \
            = garr
        return out

    def query_counts(self) -> np.ndarray:
        """Per-partition query counts since the last refresh (the qos
        controller's raw mass signal)."""
        return self._qcount.copy()
