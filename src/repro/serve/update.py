"""Streaming graph updates: edge batches → k-hop frontier recompute.

Two halves (DESIGN.md §3.11):

* :func:`apply_edge_updates` — fold an insert/delete edge batch into a
  :class:`repro.graph.data.GraphData` through ``repro.graph.stream``'s
  :class:`EdgeSpill` spill path (signed weights: existing edges and
  inserts spill ``+1``, deletes ``-1``; the bucket sort's duplicate
  summing nets them out and ``drop_nonpositive`` removes cancelled
  edges), returning the rebuilt graph plus the **touched** node set.
* :func:`incremental_recompute` — re-embed only the k-hop frontier of
  the touched nodes: layer ``l``'s dirty set is
  ``S_l = T ∪ nbrs(S_{l-1})`` (a row's output changes iff its adjacency
  changed — it is an update endpoint — or it aggregates a neighbour
  whose previous-layer row changed), and only those rows are recomputed
  against the patched previous layer.  Everything outside the frontier
  keeps its cached activations, and the patched stack equals a full
  fresh forward on the new graph (tests pin ≤ 1e-5).
"""

from __future__ import annotations

import os
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.graph.data import GraphData, normalized_edge_weights
from repro.graph.stream import EdgeSpill
from repro.nn.gnn import GNNConfig
from repro.nn.modules import dense

__all__ = ["apply_edge_updates", "incremental_recompute"]


def apply_edge_updates(g: GraphData, inserts=None, deletes=None,
                       workdir: str | None = None,
                       bucket_nodes: int = 1 << 14
                       ) -> tuple[GraphData, np.ndarray]:
    """Rebuild ``g`` with an undirected edge batch applied.

    ``inserts`` / ``deletes`` are ``(dst, src)`` array pairs (undirected:
    both directions are spilled).  Inserting a present edge or deleting
    an absent one is a no-op after the signed-weight netting — the
    canonical rows keep an edge iff its summed weight is positive.
    Features, labels and split masks carry over unchanged; ``touched``
    is the sorted unique endpoint set of the batch (the frontier seed of
    :func:`incremental_recompute`).

    Example::

        g2, touched = apply_edge_updates(g, inserts=(dst_new, src_new),
                                         deletes=(dst_old, src_old))
    """
    n = g.num_nodes

    def _pair(batch):
        if batch is None:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        d, s = batch
        return np.asarray(d, np.int64), np.asarray(s, np.int64)

    ins_d, ins_s = _pair(inserts)
    del_d, del_s = _pair(deletes)
    with tempfile.TemporaryDirectory(dir=workdir) as td:
        spill = EdgeSpill(n, os.path.join(td, "spill"),
                          bucket_nodes=bucket_nodes, weighted=True,
                          drop_nonpositive=True)
        dst0, src0 = g.edge_list()
        if len(dst0):
            spill.add(dst0, src0)          # existing directed rows: +1
        for d, s, w in ((ins_d, ins_s, 1.0), (del_d, del_s, -1.0)):
            if len(d):
                both_d = np.concatenate([d, s])
                both_s = np.concatenate([s, d])
                spill.add(both_d, both_s,
                          np.full(len(both_d), w, np.float64))
        dst, src, _ = spill.canonical_edges()
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, dst.astype(np.int64) + 1, 1)
    g2 = GraphData(indptr=np.cumsum(indptr), indices=src.astype(np.int32),
                   features=g.features, labels=g.labels,
                   train_mask=g.train_mask, val_mask=g.val_mask,
                   test_mask=g.test_mask, name=g.name)
    g2.validate()
    touched = np.unique(np.concatenate([ins_d, ins_s, del_d, del_s]))
    return g2, touched.astype(np.int64)


def incremental_recompute(params: dict, cfg: GNNConfig, g: GraphData,
                          hidden_prev: list, touched: np.ndarray,
                          norm: str = "mean"
                          ) -> tuple[list[np.ndarray], list[np.ndarray]]:
    """Patch a cached per-layer activation stack after a graph update.

    ``hidden_prev`` is the full-graph ``[n, F_l]`` stack computed on the
    OLD graph (the serving cache's global gather); ``g`` is the NEW
    graph; ``touched`` the update batch's endpoint set.  Returns the
    patched stack plus the per-layer frontier sets actually recomputed
    — ``frontiers[l]`` grows one hop per layer, so the work is
    ``O(Σ_l |S_l| · d̄ · F)`` instead of a full ``O(n)`` forward.

    Only the ``sage`` conv is supported (the poly conv's tap chain hops
    ``k_taps - 1`` times *inside* a layer, so its frontier bookkeeping
    differs; the serving engine is sage-only for now).
    """
    if cfg.conv != "sage":
        raise ValueError(f"incremental recompute supports conv='sage', "
                         f"got {cfg.conv!r}")
    n = g.num_nodes
    dst, src = g.edge_list()
    w = normalized_edge_weights(g, kind=norm)
    layers = params["layers"]
    if len(hidden_prev) != len(layers):
        raise ValueError(f"hidden_prev has {len(hidden_prev)} layers, "
                         f"model has {len(layers)}")
    touched = np.unique(np.asarray(touched, np.int64))
    hidden = [np.array(h) for h in hidden_prev]
    frontiers: list[np.ndarray] = []
    x = np.asarray(g.features, np.float32)
    dirty = np.zeros(n, bool)
    dirty[touched] = True
    for li, layer in enumerate(layers):
        # rows reading a dirty previous-layer value join the frontier
        if li > 0:
            grow = np.zeros(n, bool)
            grow[dst[dirty[src]]] = True
            dirty = grow
            dirty[touched] = True
        s_nodes = np.flatnonzero(dirty)
        frontiers.append(s_nodes)
        if not len(s_nodes):
            continue
        h_in = x if li == 0 else hidden[li - 1]
        sel = dirty[dst]
        agg = np.zeros((n, h_in.shape[1]), np.float32)
        np.add.at(agg, dst[sel], h_in[src[sel]] * w[sel, None])
        h_new = np.asarray(
            dense(layer["self"], jnp.asarray(h_in[s_nodes])) +
            dense(layer["neigh"], jnp.asarray(agg[s_nodes])))
        if cfg.residual and h_new.shape[1] == h_in.shape[1]:
            h_new = h_new + h_in[s_nodes]
        if li < len(layers) - 1:
            h_new = np.maximum(h_new, 0.0)
        hidden[li][s_nodes] = h_new
    return hidden, frontiers
