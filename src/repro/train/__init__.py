from .optim import (adamw, apply_updates, clip_by_global_norm, constant_lr,
                    cosine_lr, global_norm, linear_decay_lr, sgd)

__all__ = [
    "adamw", "apply_updates", "clip_by_global_norm", "constant_lr",
    "cosine_lr", "global_norm", "linear_decay_lr", "sgd",
    "History", "TrainResult", "train_gnn",
]


def __getattr__(name):
    # lazy: trainer imports repro.dist.gnn_parallel which imports
    # repro.train.optim — eager import here would be circular.
    if name in ("History", "TrainResult", "train_gnn"):
        from . import trainer
        return getattr(trainer, name)
    raise AttributeError(name)
