"""Checkpointing: msgpack-serialised param/opt pytrees (no orbax offline).

Layout-stable: leaves are stored as (dtype, shape, raw bytes) in tree-flatten
order with the treedef structure recorded as a string for validation.

Crash consistency (DESIGN.md §3.10): ``save`` writes through a temp file,
fsyncs it, atomically renames it over the target, and fsyncs the
containing directory — a crash at any point leaves either the old
checkpoint or the new one, never a torn file.  ``restore`` validates the
treedef, leaf count, and every leaf's shape AND dtype, reporting the
offending tree path.  :func:`save_train_state` / :func:`restore_train_state`
round-trip the *full* train state (params, optimizer, controller state,
halo/fault caches, EF residuals, cumulative ledger counters, step) so
``train_gnn(resume=True)`` reproduces the uninterrupted run bitwise.
"""

from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

#: the single train-state file a checkpoint directory holds — the atomic
#: rename makes in-place overwrite crash-consistent, so no numbered files
TRAIN_STATE_FILE = "state.ckpt"


def _encode_leaf(x) -> dict:
    a = np.asarray(x)
    if a.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(a.shape),
                "data": a.view(np.uint16).tobytes()}
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": a.tobytes()}


def _decode_leaf(d) -> jnp.ndarray:
    shape = tuple(d["shape"])
    if d["dtype"] == "bfloat16":
        a = np.frombuffer(d["data"], np.uint16).reshape(shape)
        return jnp.asarray(a.view(jnp.bfloat16))
    return jnp.asarray(np.frombuffer(d["data"],
                                     np.dtype(d["dtype"])).reshape(shape))


def _leaf_dtype(x) -> str:
    a = np.asarray(x)
    return "bfloat16" if a.dtype == jnp.bfloat16 else str(a.dtype)


def _fsync_dir(d: str) -> None:
    """Durably record the rename itself (best-effort on platforms whose
    directories reject O_RDONLY opens)."""
    try:
        fd = os.open(d, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(path: str, tree, extra: dict | None = None) -> None:
    """Atomically write ``tree`` (any pytree of arrays) to ``path``:
    tmp file + fsync + rename + directory fsync."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [_encode_leaf(l) for l in leaves],
        "extra": extra or {},
    }
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".ckpt.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(d)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def peek(path: str) -> dict:
    """The ``extra`` metadata of a checkpoint without decoding its leaves
    — resume uses it to learn the checkpoint's world (q, alive workers)
    *before* it can build the like-tree to restore into."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    return payload["extra"]


def restore(path: str, like):
    """Restore into the structure of ``like`` (validates treedef + every
    leaf's shape and dtype, naming the offending tree path)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(like)
    if payload["treedef"] != str(treedef):
        raise ValueError("checkpoint treedef mismatch")
    if len(payload["leaves"]) != len(leaves_p):
        raise ValueError("checkpoint leaf count mismatch")
    out = []
    for stored, (kp, ref) in zip(payload["leaves"], leaves_p):
        arr = _decode_leaf(stored)
        where = jax.tree_util.keystr(kp) or "<root>"
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(
                f"shape mismatch at {where}: checkpoint "
                f"{tuple(arr.shape)} vs expected {tuple(np.shape(ref))}")
        want = _leaf_dtype(ref)
        if stored["dtype"] != want:
            raise ValueError(
                f"dtype mismatch at {where}: checkpoint "
                f"{stored['dtype']} vs expected {want}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), payload["extra"]


# ---------------------------------------------------------------------------
# Full-train-state API (crash-consistent resume)
# ---------------------------------------------------------------------------


def latest_checkpoint(ckpt_dir: str) -> str | None:
    """Path of the train-state checkpoint under ``ckpt_dir`` (or None)."""
    p = os.path.join(ckpt_dir, TRAIN_STATE_FILE)
    return p if os.path.exists(p) else None


def save_train_state(ckpt_dir: str, tree, step: int,
                     extra: dict | None = None) -> str:
    """Atomically persist the full train state after ``step`` completed
    steps.  ``tree`` must round-trip through :func:`restore` against the
    trainer's like-tree — every piece of carried state (controller,
    caches, residuals, cumulative counters) belongs in it, or the resume
    diverges from the uninterrupted run."""
    path = os.path.join(ckpt_dir, TRAIN_STATE_FILE)
    save(path, tree, extra={"step": int(step), **(extra or {})})
    return path


def restore_train_state(ckpt_dir: str, like):
    """``(tree, step, extra)`` from ``ckpt_dir`` — raises FileNotFoundError
    when no checkpoint exists (callers decide whether that is fatal)."""
    path = latest_checkpoint(ckpt_dir)
    if path is None:
        raise FileNotFoundError(
            f"no {TRAIN_STATE_FILE} under {ckpt_dir!r}")
    tree, extra = restore(path, like)
    return tree, int(extra["step"]), extra
