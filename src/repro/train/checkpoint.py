"""Checkpointing: msgpack-serialised param/opt pytrees (no orbax offline).

Layout-stable: leaves are stored as (dtype, shape, raw bytes) in tree-flatten
order with the treedef structure recorded as a string for validation.
"""

from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode_leaf(x) -> dict:
    a = np.asarray(x)
    if a.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(a.shape),
                "data": a.view(np.uint16).tobytes()}
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": a.tobytes()}


def _decode_leaf(d) -> jnp.ndarray:
    shape = tuple(d["shape"])
    if d["dtype"] == "bfloat16":
        a = np.frombuffer(d["data"], np.uint16).reshape(shape)
        return jnp.asarray(a.view(jnp.bfloat16))
    return jnp.asarray(np.frombuffer(d["data"],
                                     np.dtype(d["dtype"])).reshape(shape))


def save(path: str, tree, extra: dict | None = None) -> None:
    """Atomically write ``tree`` (any pytree of arrays) to ``path``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [_encode_leaf(l) for l in leaves],
        "extra": extra or {},
    }
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".ckpt.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore(path: str, like):
    """Restore into the structure of ``like`` (validates treedef + shapes)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    if payload["treedef"] != str(treedef):
        raise ValueError("checkpoint treedef mismatch")
    if len(payload["leaves"]) != len(leaves):
        raise ValueError("checkpoint leaf count mismatch")
    out = []
    for stored, ref in zip(payload["leaves"], leaves):
        arr = _decode_leaf(stored)
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(
                f"shape mismatch: {arr.shape} vs {np.shape(ref)}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), payload["extra"]
