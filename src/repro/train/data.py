"""Synthetic token pipeline for LM training (offline container).

Deterministic, seedable stream of batches with learnable structure: a
power-law unigram prior composed with a sparse bigram transition —
enough signal that CE falls well below ln(V) within a few steps, which the
examples and integration tests assert.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    branch: int = 8          # bigram fan-out

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse deterministic-ish bigram table: each token has `branch`
        # successors with dirichlet weights
        self._succ = rng.integers(0, self.vocab_size,
                                  (self.vocab_size, self.branch))
        w = rng.dirichlet(np.ones(self.branch) * 0.5, self.vocab_size)
        self._w = w.astype(np.float64)
        self._step = 0

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        rng = np.random.default_rng(self.seed * 100003 + self._step)
        self._step += 1
        toks = np.zeros((self.batch, self.seq_len), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab_size, self.batch)
        # vectorised bigram walk
        for t in range(1, self.seq_len):
            u = rng.random(self.batch)
            cum = np.cumsum(self._w[toks[:, t - 1]], axis=1)
            choice = (u[:, None] < cum).argmax(axis=1)
            toks[:, t] = self._succ[toks[:, t - 1], choice]
        import jax.numpy as jnp
        return {"tokens": jnp.asarray(toks, jnp.int32)}
