"""CSV logging helpers shared by benchmarks and examples."""

from __future__ import annotations

import csv
import os
import sys


def write_csv(path: str, rows: list[dict]) -> None:
    if not rows:
        return
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    keys = list(rows[0].keys())
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)


def print_csv(rows: list[dict], file=None) -> None:
    file = file or sys.stdout
    if not rows:
        return
    keys = list(rows[0].keys())
    print(",".join(keys), file=file)
    for r in rows:
        print(",".join(_fmt(r[k]) for k in keys), file=file)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
