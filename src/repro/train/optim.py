"""Optimizers + LR schedules (no optax on this box — built from scratch).

``Optimizer`` is the usual (init, update) pair over param pytrees.  AdamW
supports a *dtype policy* for its moments so the 398B-class dry-run configs
fit HBM (bf16 moments is a standard production trick; see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def global_norm(tree) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    return _tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# SGD with momentum
# ---------------------------------------------------------------------------


def sgd(lr: float | Callable, momentum: float = 0.0,
        weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        mom = _tree_map(jnp.zeros_like, params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mom": mom}

    def update(grads, state, params):
        step = state["step"] + 1
        eta = lr_fn(step)
        if weight_decay:
            grads = _tree_map(lambda g, p: g + weight_decay * p, grads, params)
        if momentum:
            mom = _tree_map(lambda m, g: momentum * m + g, state["mom"], grads)
            upd = _tree_map(lambda m: -eta * m, mom)
        else:
            mom = None
            upd = _tree_map(lambda g: -eta * g, grads)
        return upd, {"step": step, "mom": mom}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(lr: float | Callable, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0,
          moment_dtype=None) -> Optimizer:
    """AdamW; ``moment_dtype=jnp.bfloat16`` halves optimizer HBM."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def zeros(p):
            dt = moment_dtype or p.dtype
            return jnp.zeros(p.shape, dt)
        return {"step": jnp.zeros((), jnp.int32),
                "mu": _tree_map(zeros, params),
                "nu": _tree_map(zeros, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        eta = lr_fn(step)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd_moments(mu, nu, g):
            gf = g.astype(jnp.float32)
            mu_f = b1 * mu.astype(jnp.float32) + (1 - b1) * gf
            nu_f = b2 * nu.astype(jnp.float32) + (1 - b2) * gf * gf
            return mu_f, nu_f

        mus, nus, upds = [], [], []
        g_leaves, treedef = jax.tree_util.tree_flatten(grads)
        mu_leaves = treedef.flatten_up_to(state["mu"])
        nu_leaves = treedef.flatten_up_to(state["nu"])
        p_leaves = treedef.flatten_up_to(params)
        for g, mu, nu, p in zip(g_leaves, mu_leaves, nu_leaves, p_leaves):
            mu_f, nu_f = upd_moments(mu, nu, g)
            u = -eta * (mu_f / bc1) / (jnp.sqrt(nu_f / bc2) + eps)
            if weight_decay:
                u = u - eta * weight_decay * p.astype(jnp.float32)
            dt = moment_dtype or p.dtype
            mus.append(mu_f.astype(dt))
            nus.append(nu_f.astype(dt))
            upds.append(u.astype(p.dtype))
        unf = jax.tree_util.tree_unflatten
        return unf(treedef, upds), {"step": step,
                                    "mu": unf(treedef, mus),
                                    "nu": unf(treedef, nus)}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return _tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------


def constant_lr(v: float) -> Callable:
    return lambda _step: jnp.asarray(v, jnp.float32)


def cosine_lr(peak: float, total_steps: int, warmup: int = 0,
              floor: float = 0.0) -> Callable:
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak * s / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(math.pi * frac))
        return jnp.where(s < warmup, warm, cos)
    return fn


def linear_decay_lr(peak: float, total_steps: int, warmup: int = 0) -> Callable:
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak * s / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        return jnp.where(s < warmup, warm, peak * (1.0 - frac))
    return fn


OPTIMIZERS = {"adamw": adamw, "sgd": sgd}
