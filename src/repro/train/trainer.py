"""Full-batch distributed GNN trainer (the paper's experimental loop).

Runs Algorithm 1 for ``epochs`` steps (full-batch: one gradient step per
epoch, as the paper trains), tracking the communication ledger so accuracy
can be plotted against epochs (Fig. 3) or communicated floats (Fig. 5).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.varco import CommPolicy
from repro.dist import faults as faultlib
from repro.dist.gnn_parallel import (DistMeta, make_eval_step,
                                     make_train_step, make_worker_mesh,
                                     shard_graph)
from repro.graph.data import GraphData
from repro.graph.partition import partition_graph
from repro.graph.stream import ShardSet, is_shard_dir, load_shards
from repro.nn.gnn import GNNConfig, init_gnn
from repro.train import checkpoint as ckpt
from repro.train.optim import Optimizer, adamw


@dataclasses.dataclass
class History:
    """Per-epoch training record.

    ``pair_transport_gf`` is the cumulative per-pair transport split
    (flattened receiver-major ``[Q*Q]`` tuple of Gfloats per logged
    epoch) — populated by the closed-loop ``auto`` policies, whose
    controllers allocate the wire budget per worker pair; empty lists of
    tuples stay empty for scalar policies.  ``layer_transport_gf`` is the
    per-layer refinement (flattened layer-major ``[L*Q*Q]`` tuples,
    per-layer ``auto`` policies only — DESIGN.md §3.7) and ``comp_err``
    the cumulative measured compression error (dropped-block energy, auto
    policies).  ``row()`` serialises the tuples as ``|``-joined cells so
    the CSV stays one value per column.
    """
    epoch: list = dataclasses.field(default_factory=list)
    loss: list = dataclasses.field(default_factory=list)
    rate: list = dataclasses.field(default_factory=list)
    train_acc: list = dataclasses.field(default_factory=list)
    val_acc: list = dataclasses.field(default_factory=list)
    test_acc: list = dataclasses.field(default_factory=list)
    halo_gfloats: list = dataclasses.field(default_factory=list)  # cumulative
    transport_gfloats: list = dataclasses.field(default_factory=list)
    wall_s: list = dataclasses.field(default_factory=list)
    pair_transport_gf: list = dataclasses.field(default_factory=list)
    layer_transport_gf: list = dataclasses.field(default_factory=list)
    comp_err: list = dataclasses.field(default_factory=list)  # cumulative

    def row(self, i: int) -> dict:
        out = {k: getattr(self, k)[i] for k in
               ("epoch", "loss", "rate", "train_acc", "val_acc", "test_acc",
                "halo_gfloats", "transport_gfloats", "wall_s")}
        if self.pair_transport_gf:
            out["pair_transport_gf"] = "|".join(
                f"{v:.6g}" for v in self.pair_transport_gf[i])
        if self.layer_transport_gf:
            out["layer_transport_gf"] = "|".join(
                f"{v:.6g}" for v in self.layer_transport_gf[i])
        if self.comp_err:
            out["comp_err"] = self.comp_err[i]
        return out

    def rows(self):
        return [self.row(i) for i in range(len(self.epoch))]

    def layer_split(self, q: int) -> list:
        """Cumulative per-layer transport (Gfloats, ``[L]``) of the last
        logged epoch — the layer-major ``[L·Q²]`` flattening of
        ``layer_transport_gf`` summed per layer.  Empty for runs without
        per-layer plans.  The one place the flattening convention is
        decoded (example driver and benchmark both call this)."""
        if not self.layer_transport_gf:
            return []
        lt = self.layer_transport_gf[-1]
        n_pairs = q * q
        return [float(sum(lt[i * n_pairs:(i + 1) * n_pairs]))
                for i in range(len(lt) // n_pairs)]

    @property
    def final_test_acc(self) -> float:
        return self.test_acc[-1] if self.test_acc else float("nan")

    @property
    def best_test_acc(self) -> float:
        return max(self.test_acc) if self.test_acc else float("nan")

    @property
    def total_halo_gfloats(self) -> float:
        return self.halo_gfloats[-1] if self.halo_gfloats else 0.0

    @property
    def total_transport_gfloats(self) -> float:
        """Gfloats the wire format actually shipped (DESIGN.md §3.3)."""
        return self.transport_gfloats[-1] if self.transport_gfloats else 0.0


@dataclasses.dataclass
class TrainResult:
    history: History
    params: Any
    meta: DistMeta
    policy_desc: str


def train_gnn(g: "GraphData | ShardSet | str", *, q: int = 8,
              scheme: str = "random",
              policy: CommPolicy, epochs: int = 300, lr: float = 5e-3,
              weight_decay: float = 0.0, hidden: int = 256, layers: int = 3,
              conv: str = "sage", seed: int = 0, eval_every: int = 5,
              use_shard_map: bool = False, optimizer: Optimizer | None = None,
              sync: str = "grad", wire: str = "dense",
              faults: "faultlib.FaultSchedule | None" = None,
              fault_max_stale: int = 5, fault_backoff_cap: int = 16,
              checkpoint_dir: str | None = None, checkpoint_every: int = 0,
              resume: bool = False, stop_after: int | None = None,
              log_fn=None) -> TrainResult:
    """Partition ``g`` over ``q`` workers and train under ``policy``.

    ``g`` may also be an on-disk shard directory (written by
    ``repro.graph.stream.write_shards``) or a loaded ``ShardSet`` — the
    out-of-core path: partitioning happened offline, the per-pair halo /
    ELL arrays are already in the shards, and ``q``/``scheme``/the global
    graph are never consulted (Q ≥ 16 runs load only partition data).

    Mirrors the paper's §V setup by default: 3-layer SAGE, 256 hidden,
    full-batch, 300 epochs.  ``wire="packed"`` runs the reduced-volume
    packed halo exchange (DESIGN.md §3.3; feature widths must be multiples
    of 128, and compressing policies must use the ``blockmask`` compressor);
    ``wire="p2p"`` the neighbor-only ppermute ring with ELL local
    aggregation (DESIGN.md §3.5 — same constraints under compression, and
    the per-pair halo/ELL arrays are attached here automatically).

    An ``auto:<controller>:<budget-bits>`` policy (``CommPolicy.parse``)
    closes the loop: the named ``repro.dist.ratectl`` controller plans a
    per-pair ``[Q, Q]`` rate map each epoch from measured transport
    feedback, and its state threads through the epoch scan alongside the
    optimizer state (DESIGN.md §3.6).  Auto policies default the wire to
    ``"p2p"`` when the caller left ``"dense"`` (per-pair rates need a
    per-pair wire) and record the per-pair transport split in
    ``History.pair_transport_gf`` plus the cumulative measured
    compression error in ``History.comp_err``.  A trailing ``:per-layer``
    lifts the plan to per-layer ``[L, Q, Q]`` tensors — every layer's
    exchanges get their own water-filled share of each step's bit
    allowance — and fills ``History.layer_transport_gf`` (DESIGN.md
    §3.7).

    A ``faults`` :class:`repro.dist.faults.FaultSchedule` turns on the
    degraded-mode loop (DESIGN.md §3.10): each step the schedule's
    seeded link-drop mask feeds the *exchange → cached → backoff-probe →
    local-only* ladder (``fault_max_stale`` staleness cap,
    ``fault_backoff_cap`` probe backoff), every policy's step runs
    through the fault-channel oracle (scalar policies ride a uniform
    rate map), and a ``crash_at`` event drops the run elastically to
    Q − 1 — shard-backed inputs only — migrating controller and ladder
    state.  The fault channel defaults the wire to ``"p2p"`` like auto.

    ``checkpoint_dir`` + ``checkpoint_every`` persist the full train
    state atomically every N epochs (``stop_after`` additionally
    checkpoints and exits after that many epochs — the kill switch of
    the crash-consistency tests); ``resume=True`` restores it and
    continues at the saved epoch, bitwise-equal to the uninterrupted
    run.  Resume replays any recorded worker shrink but refuses a
    checkpoint whose world size cannot be reached from ``g``.
    """
    auto = policy.mode == "auto"
    fault = faults is not None
    if (auto or fault) and wire == "dense":
        wire = "p2p"                   # per-pair rates need a per-pair wire
    sched = faults
    if is_shard_dir(g):
        g = load_shards(g)
    cfg = GNNConfig(conv=conv, in_dim=g.feat_dim, hidden=hidden,
                    out_dim=g.num_classes, layers=layers)
    params = init_gnn(jax.random.key(seed), cfg)
    if isinstance(g, ShardSet):
        pg = g                         # partitioned offline; q comes with it
        q = pg.q
        graph = pg.device_arrays()     # halo/ELL arrays ship in the shards
    else:
        pg = partition_graph(g, q, scheme=scheme, seed=seed)
        graph = pg.device_arrays()
        if wire == "p2p" or auto:
            from repro.dist.halo import attach_p2p
            graph = attach_p2p(graph, pg)  # auto's per-pair stats need them
    if resume:
        if not checkpoint_dir:
            raise ValueError("resume=True needs checkpoint_dir")
        path = ckpt.latest_checkpoint(checkpoint_dir)
        if path is None:
            raise FileNotFoundError(
                f"resume=True but no checkpoint under {checkpoint_dir!r}")
        peeked = ckpt.peek(path)
        alive = peeked.get("alive")
        if alive is not None and len(alive) < q:
            # the checkpointed run had already shrunk: replay the shrinks
            # so the like-tree (and every step closure) matches its world
            if not isinstance(pg, ShardSet):
                raise ValueError("resuming a shrunk run needs shard-backed "
                                 "input (a ShardSet / shard dir)")
            cur = list(range(q))
            for w in sorted(set(cur) - set(int(a) for a in alive)):
                pg = faultlib.shrink_shards(pg, cur.index(w))
                cur.remove(w)
            q = pg.q
            graph = pg.device_arrays()
            if sched is not None:
                sched = dataclasses.replace(
                    sched, alive=tuple(int(a) for a in alive))
        if int(peeked.get("q", q)) != q:
            raise ValueError(f"checkpoint world size {peeked['q']} does "
                             f"not match this run's q={q}")
    meta = DistMeta.build(pg, params, wire=wire)
    opt = optimizer or adamw(lr, weight_decay=weight_decay)
    opt_state = opt.init(params)

    mesh = make_worker_mesh(q) if use_shard_map else None
    if mesh is not None:
        graph = shard_graph(graph, mesh)
    if auto or fault:
        from repro.dist.ratectl import (init_halo_cache, init_wire_residuals,
                                        make_auto_train_step, make_controller,
                                        uniform_plan)

    def _init_cache(meta_):
        if not auto:
            return ()
        if policy.controller == "stale":
            return init_halo_cache(meta_, cfg)
        if policy.max_width < 32 and meta_.wire == "p2p":
            # quantising wire: the cache channel carries the error-feedback
            # residuals instead (stale XOR EF, DESIGN.md §3.8)
            return init_wire_residuals(meta_, cfg)
        return ()

    def _make_step(meta_):
        if fault:
            return faultlib.make_fault_train_step(cfg, policy, opt, meta_,
                                                  mesh=mesh, sync=sync)
        if auto:
            return make_auto_train_step(cfg, policy, opt, meta_, mesh=mesh,
                                        sync=sync)
        return make_train_step(cfg, policy, opt, meta_, mesh=mesh, sync=sync)

    ctl = ctl_state = None
    if auto:
        ctl = make_controller(policy, meta, cfg, total_steps=epochs)
        ctl_state = ctl.init()
    cache = _init_cache(meta)
    fcache = init_halo_cache(meta, cfg) if fault else ()
    dstate = faultlib.init_degrade(q) if fault else None
    step = _make_step(meta)
    evaluate = make_eval_step(cfg, meta, mesh=mesh)

    hist = History()
    halo_bits_cum = 0.0
    transport_bits_cum = 0.0
    pair_bits_cum = None
    layer_bits_cum = None
    err_cum = 0.0
    start_epoch = 0

    def _state_tree():
        tree = {"params": params, "opt": opt_state}
        if auto:
            tree["ctl"] = ctl_state
        if cache:
            tree["cache"] = tuple(cache)
        if fault:
            tree["fcache"] = tuple(fcache)
        return tree

    def _ck_extra():
        return {
            "q": int(q),
            "alive": [int(w) for w in sched.alive_workers] if fault
            else None,
            "halo": float(halo_bits_cum),
            "transport": float(transport_bits_cum),
            "err": float(err_cum),
            "pair": None if pair_bits_cum is None else pair_bits_cum.tolist(),
            "layer": None if layer_bits_cum is None
            else layer_bits_cum.tolist(),
            "degrade": None if dstate is None else {
                "age": dstate.age.tolist(),
                "backoff": dstate.backoff.tolist(),
                "next_try": dstate.next_try.tolist()},
            "policy": policy.describe(),
        }

    if resume:
        tree, start_epoch, ext = ckpt.restore_train_state(checkpoint_dir,
                                                          _state_tree())
        params, opt_state = tree["params"], tree["opt"]
        if auto:
            ctl_state = tree["ctl"]
        if "cache" in tree:
            cache = tree["cache"]
        if fault:
            fcache = tree["fcache"]
            dg = ext.get("degrade")
            if dg is not None:
                dstate = faultlib.DegradeState(
                    age=np.asarray(dg["age"], np.int64),
                    backoff=np.asarray(dg["backoff"], np.int64),
                    next_try=np.asarray(dg["next_try"], np.int64))
        halo_bits_cum = float(ext.get("halo", 0.0))
        transport_bits_cum = float(ext.get("transport", 0.0))
        err_cum = float(ext.get("err", 0.0))
        if ext.get("pair") is not None:
            pair_bits_cum = np.asarray(ext["pair"], np.float64)
        if ext.get("layer") is not None:
            layer_bits_cum = np.asarray(ext["layer"], np.float64)

    t0 = time.time()
    for epoch in range(start_epoch, epochs):
        if fault:
            crash = sched.crash_at_step(epoch)
            if crash is not None:
                if not isinstance(pg, ShardSet):
                    raise ValueError(
                        "elastic worker-crash recovery needs shard-backed "
                        "input (a ShardSet / shard dir) — in-memory "
                        "partitions cannot be renumbered at Q - 1")
                if q <= 2:
                    raise ValueError("cannot shrink below Q = 2 — the "
                                     "fault plane needs at least one link")
                q_old = q
                pg = faultlib.shrink_shards(pg, crash)
                q = pg.q
                graph = pg.device_arrays()
                meta = DistMeta.build(pg, params, wire=wire)
                mesh = make_worker_mesh(q) if use_shard_map else None
                if mesh is not None:
                    graph = shard_graph(graph, mesh)
                sched = sched.shrink(crash)
                dstate = faultlib.migrate_degrade_state(dstate, crash)
                if auto:
                    ctl = make_controller(policy, meta, cfg,
                                          total_steps=epochs)
                    ctl_state = faultlib.migrate_controller_state(
                        ctl_state, crash, q_old)
                cache = _init_cache(meta)   # stale/EF buffers restart cold
                fcache = init_halo_cache(meta, cfg)
                step = _make_step(meta)
                evaluate = make_eval_step(cfg, meta, mesh=mesh)
                # keep cumulative pair splits shaped [..., Q, Q]: the dead
                # worker's history leaves the ledger with it
                if pair_bits_cum is not None:
                    pair_bits_cum = np.delete(
                        np.delete(pair_bits_cum, crash, 0), crash, 1)
                if layer_bits_cum is not None:
                    layer_bits_cum = np.delete(
                        np.delete(layer_bits_cum, crash, 1), crash, 2)
            serve, dstate = faultlib.degrade_plan(
                dstate, sched.effective_drops(epoch), epoch,
                max_stale=fault_max_stale, backoff_cap=fault_backoff_cap)
            fskip, dead = faultlib.serve_masks(serve)
        if fault:
            if auto:
                plan, ctl_state = ctl.plan(ctl_state, epoch)
            else:
                r = float(policy.rate(epoch)) if policy.compresses else 1.0
                plan = uniform_plan(q, r)
            params, opt_state, m, cache, fcache = step(
                params, opt_state, graph, jax.random.key(epoch), plan,
                fskip, dead, cache, fcache)
            if auto:
                ctl_state = ctl.observe(ctl_state, m)
        elif auto:
            plan, ctl_state = ctl.plan(ctl_state, epoch)
            params, opt_state, m, cache = step(params, opt_state, graph,
                                               jax.random.key(epoch), plan,
                                               cache)
            ctl_state = ctl.observe(ctl_state, m)
        else:
            params, opt_state, m = step(params, opt_state, graph,
                                        jnp.asarray(epoch),
                                        jax.random.key(epoch))
        if auto or fault:
            pair_t = np.asarray(m["pair_transport"], np.float64)
            pair_bits_cum = pair_t if pair_bits_cum is None \
                else pair_bits_cum + pair_t
            err_cum += float(np.asarray(m["pair_err"], np.float64).sum())
            if "layer_transport" in m:
                layer_t = np.asarray(m["layer_transport"], np.float64)
                layer_bits_cum = layer_t if layer_bits_cum is None \
                    else layer_bits_cum + layer_t
        halo_bits_cum += float(m["halo_bits"])
        transport_bits_cum += float(m["transport_bits"])
        if epoch % eval_every == 0 or epoch == epochs - 1:
            accs = evaluate(params, graph)
            hist.epoch.append(epoch)
            hist.loss.append(float(m["loss"]))
            hist.rate.append(float(m["rate"]))
            hist.train_acc.append(float(accs["train"]))
            hist.val_acc.append(float(accs["val"]))
            hist.test_acc.append(float(accs["test"]))
            hist.halo_gfloats.append(halo_bits_cum / 32.0 / 1e9)
            hist.transport_gfloats.append(transport_bits_cum / 32.0 / 1e9)
            hist.wall_s.append(time.time() - t0)
            if pair_bits_cum is not None:
                hist.pair_transport_gf.append(tuple(
                    pair_bits_cum.ravel() / 32.0 / 1e9))
                hist.comp_err.append(err_cum)
            if layer_bits_cum is not None:
                hist.layer_transport_gf.append(tuple(
                    layer_bits_cum.ravel() / 32.0 / 1e9))
            if log_fn:
                log_fn(hist.row(len(hist.epoch) - 1))
        done = epoch + 1
        if checkpoint_dir and (
                (checkpoint_every and done % checkpoint_every == 0)
                or done == stop_after):
            ckpt.save_train_state(checkpoint_dir, _state_tree(), done,
                                  extra=_ck_extra())
        if stop_after is not None and done >= stop_after:
            break
    return TrainResult(hist, params, meta, policy.describe())
