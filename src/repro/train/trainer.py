"""Full-batch distributed GNN trainer (the paper's experimental loop).

Runs Algorithm 1 for ``epochs`` steps (full-batch: one gradient step per
epoch, as the paper trains), tracking the communication ledger so accuracy
can be plotted against epochs (Fig. 3) or communicated floats (Fig. 5).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.varco import CommPolicy
from repro.dist.gnn_parallel import (DistMeta, make_eval_step,
                                     make_train_step, make_worker_mesh,
                                     shard_graph)
from repro.graph.data import GraphData
from repro.graph.partition import PartitionedGraph, partition_graph
from repro.nn.gnn import GNNConfig, init_gnn
from repro.train.optim import Optimizer, adamw


@dataclasses.dataclass
class History:
    """Per-epoch training record."""
    epoch: list = dataclasses.field(default_factory=list)
    loss: list = dataclasses.field(default_factory=list)
    rate: list = dataclasses.field(default_factory=list)
    train_acc: list = dataclasses.field(default_factory=list)
    val_acc: list = dataclasses.field(default_factory=list)
    test_acc: list = dataclasses.field(default_factory=list)
    halo_gfloats: list = dataclasses.field(default_factory=list)  # cumulative
    transport_gfloats: list = dataclasses.field(default_factory=list)
    wall_s: list = dataclasses.field(default_factory=list)

    def row(self, i: int) -> dict:
        return {k: getattr(self, k)[i] for k in
                ("epoch", "loss", "rate", "train_acc", "val_acc", "test_acc",
                 "halo_gfloats", "transport_gfloats", "wall_s")}

    def rows(self):
        return [self.row(i) for i in range(len(self.epoch))]

    @property
    def final_test_acc(self) -> float:
        return self.test_acc[-1] if self.test_acc else float("nan")

    @property
    def best_test_acc(self) -> float:
        return max(self.test_acc) if self.test_acc else float("nan")

    @property
    def total_halo_gfloats(self) -> float:
        return self.halo_gfloats[-1] if self.halo_gfloats else 0.0

    @property
    def total_transport_gfloats(self) -> float:
        """Gfloats the wire format actually shipped (DESIGN.md §3.3)."""
        return self.transport_gfloats[-1] if self.transport_gfloats else 0.0


@dataclasses.dataclass
class TrainResult:
    history: History
    params: Any
    meta: DistMeta
    policy_desc: str


def train_gnn(g: GraphData, *, q: int = 8, scheme: str = "random",
              policy: CommPolicy, epochs: int = 300, lr: float = 5e-3,
              weight_decay: float = 0.0, hidden: int = 256, layers: int = 3,
              conv: str = "sage", seed: int = 0, eval_every: int = 5,
              use_shard_map: bool = False, optimizer: Optimizer | None = None,
              sync: str = "grad", wire: str = "dense",
              log_fn=None) -> TrainResult:
    """Partition ``g`` over ``q`` workers and train under ``policy``.

    Mirrors the paper's §V setup by default: 3-layer SAGE, 256 hidden,
    full-batch, 300 epochs.  ``wire="packed"`` runs the reduced-volume
    packed halo exchange (DESIGN.md §3.3; feature widths must be multiples
    of 128, and compressing policies must use the ``blockmask`` compressor);
    ``wire="p2p"`` the neighbor-only ppermute ring with ELL local
    aggregation (DESIGN.md §3.5 — same constraints under compression, and
    the per-pair halo/ELL arrays are attached here automatically).
    """
    cfg = GNNConfig(conv=conv, in_dim=g.feat_dim, hidden=hidden,
                    out_dim=g.num_classes, layers=layers)
    params = init_gnn(jax.random.key(seed), cfg)
    pg: PartitionedGraph = partition_graph(g, q, scheme=scheme, seed=seed)
    graph = pg.device_arrays()
    if wire == "p2p":
        from repro.dist.halo import attach_p2p
        graph = attach_p2p(graph, pg)
    meta = DistMeta.build(pg, params, wire=wire)
    opt = optimizer or adamw(lr, weight_decay=weight_decay)
    opt_state = opt.init(params)

    mesh = make_worker_mesh(q) if use_shard_map else None
    if mesh is not None:
        graph = shard_graph(graph, mesh)
    step = make_train_step(cfg, policy, opt, meta, mesh=mesh, sync=sync)
    evaluate = make_eval_step(cfg, meta, mesh=mesh)

    hist = History()
    halo_bits_cum = 0.0
    transport_bits_cum = 0.0
    t0 = time.time()
    for epoch in range(epochs):
        params, opt_state, m = step(params, opt_state, graph,
                                    jnp.asarray(epoch), jax.random.key(epoch))
        halo_bits_cum += float(m["halo_bits"])
        transport_bits_cum += float(m["transport_bits"])
        if epoch % eval_every == 0 or epoch == epochs - 1:
            accs = evaluate(params, graph)
            hist.epoch.append(epoch)
            hist.loss.append(float(m["loss"]))
            hist.rate.append(float(m["rate"]))
            hist.train_acc.append(float(accs["train"]))
            hist.val_acc.append(float(accs["val"]))
            hist.test_acc.append(float(accs["test"]))
            hist.halo_gfloats.append(halo_bits_cum / 32.0 / 1e9)
            hist.transport_gfloats.append(transport_bits_cum / 32.0 / 1e9)
            hist.wall_s.append(time.time() - t0)
            if log_fn:
                log_fn(hist.row(len(hist.epoch) - 1))
    return TrainResult(hist, params, meta, policy.describe())
