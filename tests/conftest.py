import os
import sys

# src/ onto the path so `import repro` works without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: XLA_FLAGS / host-device-count is deliberately NOT set here — smoke
# tests and benches must see the single real CPU device.  Multi-device
# behaviour is exercised via subprocess tests (test_multidevice.py) which
# set the flag in a fresh interpreter.
