import os
import random
import sys
import types

# src/ onto the path so `import repro` works without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: XLA_FLAGS / host-device-count is deliberately NOT set here — smoke
# tests and benches must see the single real CPU device.  Multi-device
# behaviour is exercised via subprocess tests (test_multidevice.py) which
# set the flag in a fresh interpreter.


# ---------------------------------------------------------------------------
# Optional hypothesis (requirements-dev.txt): when absent, install a minimal
# deterministic stand-in so property-based tests still collect and run a few
# fixed examples instead of hard-failing the whole module at import.
# ---------------------------------------------------------------------------


def _install_hypothesis_stub() -> None:
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    st.integers = lambda min_value=0, max_value=100: _Strategy(
        lambda r: r.randint(int(min_value), int(max_value)))
    st.floats = lambda min_value=0.0, max_value=1.0, **_: _Strategy(
        lambda r: r.uniform(float(min_value), float(max_value)))
    st.sampled_from = lambda elements: _Strategy(
        lambda r: r.choice(list(elements)))
    st.booleans = lambda: _Strategy(lambda r: r.choice([False, True]))

    def given(**strategies):
        def deco(fn):
            # NOT functools.wraps: copying the signature would make pytest
            # look for fixtures named after the strategy parameters.
            def runner(*args, **kwargs):
                rng = random.Random(0)
                for _ in range(getattr(runner, "_stub_examples", 5)):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            runner.__name__ = fn.__name__
            runner.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            runner.__module__ = fn.__module__
            runner.__doc__ = fn.__doc__
            runner._stub_examples = 5
            return runner
        return deco

    def settings(max_examples=5, deadline=None, **_):
        del deadline

        def deco(fn):
            fn._stub_examples = min(int(max_examples), 5)
            return fn
        return deco

    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()
