import os
import random
import sys
import types
import zlib

# src/ onto the path so `import repro` works without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: XLA_FLAGS / host-device-count is deliberately NOT set here — smoke
# tests and benches must see the single real CPU device.  Multi-device
# behaviour is exercised via subprocess tests (test_multidevice.py) which
# set the flag in a fresh interpreter.


# ---------------------------------------------------------------------------
# Optional hypothesis (requirements-dev.txt): when absent, install a real —
# if minimal — property-based engine so the property suites
# (tests/test_properties.py and the @given tests across the tree) run
# genuine randomised draws, not a token handful of fixed examples.
#
# Contract matched to hypothesis where it matters:
#   * strategies: integers / floats / booleans / sampled_from / just /
#     tuples / lists / one_of
#   * @given draws DEFAULT_EXAMPLES examples per test (overridable via
#     @settings(max_examples=...), honoured up to MAX_EXAMPLES_CAP)
#   * deterministic but test-specific streams: the RNG seed derives from
#     the test's qualified name, so every property gets its own draws and
#     a failure reproduces exactly on re-run
#   * assume(cond) discards the current example without failing
# ---------------------------------------------------------------------------

DEFAULT_EXAMPLES = 20
MAX_EXAMPLES_CAP = 100


def _install_hypothesis_stub() -> None:
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")

    class _Discard(Exception):
        pass

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

        def map(self, fn):
            return _Strategy(lambda r: fn(self.draw(r)))

        def filter(self, pred):
            def draw(r):
                for _ in range(100):
                    v = self.draw(r)
                    if pred(v):
                        return v
                raise _Discard()
            return _Strategy(draw)

    st.integers = lambda min_value=0, max_value=100: _Strategy(
        lambda r: r.randint(int(min_value), int(max_value)))
    st.floats = lambda min_value=0.0, max_value=1.0, **_: _Strategy(
        lambda r: r.uniform(float(min_value), float(max_value)))
    st.sampled_from = lambda elements: _Strategy(
        lambda r: r.choice(list(elements)))
    st.booleans = lambda: _Strategy(lambda r: r.choice([False, True]))
    st.just = lambda value: _Strategy(lambda r: value)
    st.one_of = lambda *strategies: _Strategy(
        lambda r: r.choice(list(strategies)).draw(r))
    st.tuples = lambda *strategies: _Strategy(
        lambda r: tuple(s.draw(r) for s in strategies))

    def _lists(elements, min_size=0, max_size=10):
        return _Strategy(lambda r: [elements.draw(r) for _ in
                                    range(r.randint(int(min_size),
                                                    int(max_size)))])

    st.lists = _lists

    def assume(condition):
        if not condition:
            raise _Discard()
        return True

    def given(**strategies):
        def deco(fn):
            # NOT functools.wraps: copying the signature would make pytest
            # look for fixtures named after the strategy parameters.
            def runner(*args, **kwargs):
                name = f"{fn.__module__}.{getattr(fn, '__qualname__', fn.__name__)}"
                rng = random.Random(zlib.crc32(name.encode()))
                want = min(getattr(runner, "_stub_examples",
                                   DEFAULT_EXAMPLES), MAX_EXAMPLES_CAP)
                ran = 0
                attempts = 0
                while ran < want and attempts < 10 * want:
                    attempts += 1
                    try:
                        drawn = {k: s.draw(rng)
                                 for k, s in strategies.items()}
                        fn(*args, **kwargs, **drawn)
                    except _Discard:
                        continue
                    except AssertionError as e:
                        raise AssertionError(
                            f"property {name} falsified on example "
                            f"{drawn!r}: {e}") from e
                    ran += 1
                if ran < want:
                    # mirror hypothesis' filter_too_much health check: a
                    # property whose draws are mostly/entirely discarded
                    # verified less than it claims and must not silently
                    # pass at reduced coverage
                    raise AssertionError(
                        f"property {name} ran only {ran}/{want} examples "
                        f"after {attempts} attempts (assume()/filter "
                        f"discards too much)")
            runner.__name__ = fn.__name__
            runner.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            runner.__module__ = fn.__module__
            runner.__doc__ = fn.__doc__
            runner._stub_examples = getattr(fn, "_stub_examples",
                                            DEFAULT_EXAMPLES)
            return runner
        return deco

    def settings(max_examples=DEFAULT_EXAMPLES, deadline=None, **_):
        del deadline

        def deco(fn):
            fn._stub_examples = min(int(max_examples), MAX_EXAMPLES_CAP)
            return fn
        return deco

    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.strategies = st
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()
