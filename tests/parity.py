"""Shared emulated ≡ shard_map parity harness (satellite of ISSUE 5).

The same parity pattern used to be duplicated across test_p2p_wire.py,
test_pair_rates.py and test_gnn_distributed.py: build a tiny partitioned
graph + SAGE config, run the emulated ``[Q, ...]`` forward, re-run the
identical program under ``shard_map`` in a subprocess (the main test
process must keep the single real CPU device — see conftest), and pin
the outputs to ≤ 1e-6.  This module is the single home of that
machinery:

* :func:`build_setup` — the shared graph/config/params/partition
  construction (in-process fixtures);
* :func:`mixed_map` — the deterministic mixed-rate ``[Q, Q]`` /
  ``[L, Q, Q]`` draws every rate-map test uses;
* :func:`run_forward_parity` — one subprocess running a whole
  ``wire × policy × rate-map`` case list against a ``Q``-device mesh,
  asserting emulated ≡ shard_map on logits and ledger bits;
* :func:`run_train_parity` — the train-step variant (several optimizer
  steps, parameter + metric comparison).

tests/test_parity_matrix.py drives :func:`run_forward_parity` as one
parametrized matrix over ``wire × policy × Q ∈ {1, 2, 4}`` including the
per-layer ``[L, Q, Q]`` tensors (DESIGN.md §3.7).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MIXED_RATES = [1.0, 2.0, 4.0, 16.0]

MIXED_WIDTHS = [2.0, 4.0, 8.0, 32.0]


def build_setup(q: int, f: int = 256, layers: int = 2, n: int = 256,
                conv: str = "sage", seed: int = 0, p2p: bool = True,
                hidden: int | None = None, shards: bool = False):
    """The shared test scaffold: ``(g, cfg, params, pg, graph)`` with the
    p2p halo/ELL arrays attached (harmless on the all-gather wires).

    ``shards=True`` takes the out-of-core route instead: the same graph is
    written to a chunked :class:`repro.graph.stream.GraphStore`, sharded
    on disk with the same owner vector, and loaded back as a ``ShardSet``
    (bitwise-identical arrays, manifest-carried ``HaloSpec``) — so the
    same parity cases conform from disk-backed shards.
    """
    import jax

    from repro.graph import partition_graph, tiny_graph
    from repro.nn import GNNConfig, init_gnn

    g = tiny_graph(n=n, feat_dim=f)
    cfg = GNNConfig(conv=conv, in_dim=f, hidden=hidden or f,
                    out_dim=g.num_classes, layers=layers)
    params = init_gnn(jax.random.key(seed), cfg)
    if shards:
        import tempfile

        from repro.graph.partition import random_partition
        from repro.graph.stream import (load_shards, write_graph_store,
                                        write_shards)

        owner = random_partition(g, q, seed=seed)
        with tempfile.TemporaryDirectory() as td:
            store = write_graph_store(g, os.path.join(td, "store"))
            write_shards(store, owner, os.path.join(td, "shards"))
            pg = load_shards(os.path.join(td, "shards"))
        return g, cfg, params, pg, pg.device_arrays()
    pg = partition_graph(g, q, scheme="random", seed=seed)
    graph = pg.device_arrays()
    if p2p:
        from repro.dist.halo import attach_p2p
        graph = attach_p2p(graph, pg)
    return g, cfg, params, pg, graph


def mixed_map(q: int, seed: int = 0, layers: int | None = None) -> np.ndarray:
    """Deterministic mixed-rate map: ``[Q, Q]``, or ``[L, Q, Q]`` when
    ``layers`` is given (diagonal 1 everywhere)."""
    rng = np.random.default_rng(seed)
    shape = (q, q) if layers is None else (layers, q, q)
    rm = rng.choice(MIXED_RATES, size=shape).astype(np.float32)
    for sl in rm.reshape(-1, q, q):
        np.fill_diagonal(sl, 1.0)
    return rm


def mixed_width_map(q: int, seed: int = 0,
                    layers: int | None = None) -> np.ndarray:
    """Deterministic mixed wire-width map over ``WIRE_WIDTHS`` draws:
    ``[Q, Q]``, or ``[L, Q, Q]`` when ``layers`` is given (diagonal 32 —
    local rows never hit the wire; DESIGN.md §3.8)."""
    rng = np.random.default_rng(seed + 1000)
    shape = (q, q) if layers is None else (layers, q, q)
    wm = rng.choice(MIXED_WIDTHS, size=shape).astype(np.float32)
    for sl in wm.reshape(-1, q, q):
        np.fill_diagonal(sl, 32.0)
    return wm


def sub32_width_map(q: int, seed: int = 0,
                    layers: int | None = None) -> np.ndarray:
    """:func:`mixed_width_map` restricted to the sub-32 widths
    ``{2, 4, 8}`` off-diagonal: every pair quantises, so the step's
    static storage width is non-zero and the **bit-packed byte wire**
    carries the exchange (`_packed_store_w`; diagonal stays 32 — local
    rows never ship)."""
    rng = np.random.default_rng(seed + 2000)
    shape = (q, q) if layers is None else (layers, q, q)
    wm = rng.choice(MIXED_WIDTHS[:-1], size=shape).astype(np.float32)
    for sl in wm.reshape(-1, q, q):
        np.fill_diagonal(sl, 32.0)
    return wm


# ---------------------------------------------------------------------------
# Subprocess scripts.  One interpreter per Q (XLA fixes the device count at
# startup); each runs a whole case list so the graph build and mesh are paid
# once per matrix row, not once per case.
# ---------------------------------------------------------------------------

FORWARD_SCRIPT = r"""
import json, sys
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from parity import build_setup
from repro.core import CommPolicy
from repro.dist.gnn_parallel import (DistMeta, _make_aggregate_emulated,
                                     _make_aggregate_shard, _packed_k_for,
                                     _packed_pair_k_for, _packed_store_w,
                                     make_worker_mesh, shard_graph)
from repro.nn.gnn import gnn_forward

spec = json.loads(sys.argv[1])
q, f, layers, n = spec["q"], spec["f"], spec["layers"], spec["n"]
g, cfg, params, pg, graph = build_setup(q, f=f, layers=layers, n=n,
                                        hidden=spec.get("hidden"),
                                        shards=spec.get("shards", False))
mesh = make_worker_mesh(q)
gs = shard_graph(graph, mesh)

for case in spec["cases"]:
    wire, polspec, mode = case["wire"], case["policy"], case["map"]
    label = f"{wire}/{polspec}/{mode or 'scalar'}"
    meta = DistMeta.build(pg, params, wire=wire)
    pol = CommPolicy.parse(polspec, 1, compressor="blockmask")
    # rate maps arrive through the spec (mixed_map builds them host-side
    # — ONE construction shared with the in-process tests)
    rm = None if case.get("rates") is None \
        else np.asarray(case["rates"], np.float32)
    wm = None if case.get("widths") is None \
        else np.asarray(case["widths"], np.float32)
    key = jax.random.key(7)
    if rm is not None and case.get("fault") is not None:
        # fault-channel parity: seeded drop masks split into CACHED/DEAD,
        # a random fault hop cache (sender-major), identical on both
        # backends; the receiver-served buffers must round-trip too
        from repro.dist.faults import (FaultSchedule, _cache_recv_to_send,
                                       _cache_send_to_recv)
        fs = int(case["fault"])
        sched = FaultSchedule(q=q, seed=fs, drop_rate=0.3, spike_rate=0.1)
        drops = sched.effective_drops(0) > 0.0
        rng = np.random.default_rng(fs)
        dead_m = drops & (rng.random((q, q)) < 0.5)
        fskip = (drops & ~dead_m).astype(np.float32)
        dead = dead_m.astype(np.float32)
        np.fill_diagonal(fskip, 0.0)
        np.fill_diagonal(dead, 0.0)
        widths = [cfg.in_dim] + [cfg.hidden] * (cfg.layers - 1)
        d = max(q - 1, 1)
        fcache = tuple(
            jnp.asarray(rng.standard_normal(
                (q, d, meta.p2p_hop_width, w)).astype(np.float32))
            for w in widths)
        kb = dict(_packed_pair_k_for(meta, rm))
        fe = []
        agg_e = _make_aggregate_emulated(
            graph, meta, pol, None, jnp.ones(()), key, packed_k=kb,
            rate_map=jnp.asarray(rm),
            width_map=None if wm is None else jnp.asarray(wm),
            fskip=jnp.asarray(fskip), fcache=fcache, fcache_out=fe,
            dead=jnp.asarray(dead))
        le, be = gnn_forward(params, cfg, graph["features"], agg_e)

        def worker(p, gblk, rmap, wmap, fsk, dd, fc, k):
            fo = []
            agg = _make_aggregate_shard(
                gblk, meta, pol, None, jnp.ones(()), k, packed_k=kb,
                rate_map=rmap,
                width_map=wmap if wm is not None else None,
                fskip=fsk, fcache=fc, fcache_out=fo, dead=dd)
            l, b = gnn_forward(p, cfg, gblk["features"], agg)
            return l, b, tuple(fo)

        sm = jax.jit(shard_map(worker, mesh=mesh,
                               in_specs=(P(), P("workers"), P(), P(),
                                         P(), P(), P("workers"), P()),
                               out_specs=(P("workers"), P(),
                                          P("workers")),
                               check_rep=False))
        rcache = tuple(jnp.asarray(_cache_send_to_recv(np.asarray(c), q))
                       for c in fcache)
        ls, bs, fo_s = sm(params, gs, jnp.asarray(rm),
                          jnp.zeros(()) if wm is None else jnp.asarray(wm),
                          jnp.asarray(fskip), jnp.asarray(dead), rcache,
                          key)
        dl = float(jnp.abs(le - ls).max())
        db = float(jnp.abs(be - bs).max())
        dc = max(float(jnp.abs(a - jnp.asarray(
                     _cache_recv_to_send(np.asarray(b), q))).max())
                 for a, b in zip(fe, fo_s))
        assert dl <= spec["atol"], (label, "fault", dl)
        assert db <= 1e-6, (label, "fault", db)
        assert dc <= 1e-6, (label, "fault cache", dc)
        print(label, "fault OK", f"dl={dl:.2e} dc={dc:.2e}")
        continue
    if rm is not None:
        kb = dict(_packed_pair_k_for(meta, rm))
        # all-sub-32 width maps turn on the bit-packed byte wire on BOTH
        # backends (store_w > 0), so the parity matrix pins the sub-byte
        # storage path exactly like the fp32 one
        sw = 0 if wm is None else _packed_store_w(meta, wm)
        agg_e = _make_aggregate_emulated(
            graph, meta, pol, None, jnp.ones(()), key, packed_k=kb,
            rate_map=jnp.asarray(rm),
            width_map=None if wm is None else jnp.asarray(wm),
            store_w=sw)

        if wm is None:
            def worker(p, gblk, rmap, k):
                agg = _make_aggregate_shard(gblk, meta, pol, None,
                                            jnp.ones(()), k, packed_k=kb,
                                            rate_map=rmap)
                return gnn_forward(p, cfg, gblk["features"], agg)

            sm = jax.jit(shard_map(worker, mesh=mesh,
                                   in_specs=(P(), P("workers"), P(), P()),
                                   out_specs=(P("workers"), P()),
                                   check_rep=False))
            ls, bs = sm(params, gs, jnp.asarray(rm), key)
        else:
            def worker(p, gblk, rmap, wmap, k):
                agg = _make_aggregate_shard(gblk, meta, pol, None,
                                            jnp.ones(()), k, packed_k=kb,
                                            rate_map=rmap, width_map=wmap,
                                            store_w=sw)
                return gnn_forward(p, cfg, gblk["features"], agg)

            sm = jax.jit(shard_map(worker, mesh=mesh,
                                   in_specs=(P(), P("workers"), P(), P(),
                                             P()),
                                   out_specs=(P("workers"), P()),
                                   check_rep=False))
            ls, bs = sm(params, gs, jnp.asarray(rm), jnp.asarray(wm), key)
    else:
        rate = float(pol.rate(0)) if pol.compresses else 1.0
        comp = pol.compressor() if pol.compresses else None
        # static kept-block map whenever the wire payload shape follows
        # the rate: always on packed, under compression on p2p (the
        # `needs_kb` rule of make_train_step)
        kb = dict(_packed_k_for(meta, rate)) \
            if wire == "packed" or (wire == "p2p" and pol.compresses) \
            else None
        agg_e = _make_aggregate_emulated(graph, meta, pol, comp,
                                         jnp.asarray(rate), key,
                                         packed_k=kb)

        def worker(p, gblk, r, k):
            agg = _make_aggregate_shard(gblk, meta, pol, comp, r, k,
                                        packed_k=kb)
            return gnn_forward(p, cfg, gblk["features"], agg)

        sm = jax.jit(shard_map(worker, mesh=mesh,
                               in_specs=(P(), P("workers"), P(), P()),
                               out_specs=(P("workers"), P()),
                               check_rep=False))
        ls, bs = sm(params, gs, jnp.asarray(rate), key)
    le, be = gnn_forward(params, cfg, graph["features"], agg_e)
    dl = float(jnp.abs(le - ls).max())
    db = float(jnp.abs(be - bs).max())
    assert dl <= spec["atol"], (label, dl)
    assert db <= 1e-6, (label, db)
    print(label, "OK", f"dl={dl:.2e}")
print("PARITY_MATRIX_OK")
"""

CONSERVE_SCRIPT = r"""
import json, math, sys
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from parity import build_setup
from repro.core import CommPolicy
from repro.dist.gnn_parallel import (DistMeta, _make_aggregate_emulated,
                                     _make_aggregate_shard,
                                     _packed_pair_k_for, _packed_store_w,
                                     make_worker_mesh, shard_graph)
from repro.kernels.varco_pack import LANE
from repro.nn.gnn import gnn_forward

spec = json.loads(sys.argv[1])
q, f, layers, n = spec["q"], spec["f"], spec["layers"], spec["n"]
g, cfg, params, pg, graph = build_setup(q, f=f, layers=layers, n=n)
mesh = make_worker_mesh(q)
gs = shard_graph(graph, mesh)
pol = CommPolicy.parse("fixed:2", 1, compressor="blockmask")
rate = spec["rate"]
nb = f // LANE
k = max(int(nb // rate), 1)
rm = np.full((q, q), rate, np.float32)
np.fill_diagonal(rm, 1.0)
valid = np.asarray(graph["p2p_send_valid"])          # [Q, D, H]
D = q - 1
key = jax.random.key(7)


def hop_bytes(payload, scales, j, d):
    sel = valid[j, d] > 0
    m = np.asarray(payload)[sel].nbytes
    if scales is not None:
        m += np.asarray(scales)[sel].nbytes
    return m


for w in spec["widths"]:
    wm = np.full((q, q), float(w), np.float32)
    np.fill_diagonal(wm, 32.0)
    meta = DistMeta.build(pg, params, wire="p2p")
    sw = _packed_store_w(meta, wm)
    assert sw == (w if w < 32 else 0), (w, sw)
    kb = dict(_packed_pair_k_for(meta, rm))
    we = []
    agg_e = _make_aggregate_emulated(
        graph, meta, pol, None, jnp.ones(()), key, packed_k=kb,
        rate_map=jnp.asarray(rm), width_map=jnp.asarray(wm),
        store_w=sw, wire_out=we)
    le, be = gnn_forward(params, cfg, graph["features"], agg_e)
    assert len(we) == layers, (w, len(we))

    def worker(p, gblk, rmap, wmap, kk):
        wo = []
        agg = _make_aggregate_shard(gblk, meta, pol, None, jnp.ones(()),
                                    kk, packed_k=kb, rate_map=rmap,
                                    width_map=wmap, store_w=sw,
                                    wire_out=wo)
        l, b = gnn_forward(p, cfg, gblk["features"], agg)
        return l, b, tuple(wo)

    sm = jax.jit(shard_map(worker, mesh=mesh,
                           in_specs=(P(), P("workers"), P(), P(), P()),
                           out_specs=(P("workers"), P(), P("workers")),
                           check_rep=False))
    ls, bs, ws = sm(params, gs, jnp.asarray(rm), jnp.asarray(wm), key)
    assert len(ws) == layers * D, (w, len(ws))

    # per-pair ledger transport bits [recv, send], summed over exchanges
    for bvec, tag in ((be, "emulated"), (bs, "shard")):
        pt = np.asarray(bvec[2:2 + q * q], np.float64).reshape(q, q)
        assert not np.diagonal(pt).any(), (w, tag)
    pair_t = np.asarray(be[2:2 + q * q], np.float64).reshape(q, q)
    np.testing.assert_allclose(
        pair_t, np.asarray(bs[2:2 + q * q], np.float64).reshape(q, q))

    meas_e = np.zeros((q, q))
    meas_s = np.zeros((q, q))
    for e, (payload, scales) in enumerate(we):
        for j in range(q):
            for d in range(D):
                i = (j + d + 1) % q
                rows = int((valid[j, d] > 0).sum())
                m = hop_bytes(payload[j, d], None if scales is None
                              else scales[j, d], j, d)
                # every hop's transported bytes == ceil(its ledger
                # charge / 8): rows kept-blocks at LANE·w + 32 each
                blk = LANE * 32.0 if w >= 32 else LANE * w + 32.0
                assert m == math.ceil(rows * k * blk / 8.0), \
                    (w, "hop", e, j, d, m, rows, k)
                meas_e[i, j] += m
                # the shard backend's received buffer for this hop is
                # the SAME bytes (post-ppermute, receiver-major; the
                # out_spec concatenates workers along the row axis)
                sp, ss = ws[e * D + d]
                sp_i = np.asarray(sp).reshape(q, -1, sp.shape[-1])[i]
                np.testing.assert_array_equal(sp_i,
                                              np.asarray(payload[j, d]))
                ss_i = None
                if scales is not None:
                    ss_i = np.asarray(ss).reshape(q, -1, ss.shape[-1])[i]
                    np.testing.assert_array_equal(ss_i,
                                                  np.asarray(scales[j, d]))
                meas_s[i, j] += hop_bytes(sp_i, ss_i, j, d)
    np.testing.assert_array_equal(meas_e, np.ceil(pair_t / 8.0))
    np.testing.assert_array_equal(meas_s, np.ceil(pair_t / 8.0))
    print(f"w={w} OK pair_bytes_total={meas_e.sum():.0f}")

# packed wire: the all-gather ledger charges halo demand, not the padded
# buffer, so conservation is per transported ROW: k·(128·w + 32) bits
# land in k·16·w payload bytes + k fp32 scales exactly (byte-aligned)
w = spec["packed_width"]
wm = np.full((q, q), float(w), np.float32)
np.fill_diagonal(wm, 32.0)
meta = DistMeta.build(pg, params, wire="packed")
kb = dict(_packed_pair_k_for(meta, rm))
wp = []
agg_p = _make_aggregate_emulated(
    graph, meta, pol, None, jnp.ones(()), key, packed_k=kb,
    rate_map=jnp.asarray(rm), width_map=jnp.asarray(wm),
    store_w=_packed_store_w(meta, wm), wire_out=wp)
gnn_forward(params, cfg, graph["features"], agg_p)
assert len(wp) == layers
for payload, scales in wp:
    assert payload.dtype == jnp.uint8 and scales is not None
    per_row = payload[0, 0].nbytes + scales[0, 0].nbytes
    assert per_row == math.ceil(k * (LANE * w + 32.0) / 8.0), \
        (w, per_row, k)
print("CONSERVATION_OK")
"""


def run_wire_conservation(q: int, widths=(2, 4, 8, 32), f: int = 256,
                          layers: int = 2, n: int = 256, rate: float = 2.0,
                          packed_width: int = 4,
                          timeout: int = 1200) -> str:
    """Ledger-vs-buffer conservation (the tentpole's closing check): on
    BOTH backends, every p2p hop's physically transported array —
    bit-packed uint8 payload + fp32 scales under ``store_w``, fp32 rows
    at width 32 — has ``nbytes == ceil(per-pair ledger transport bits /
    8)``, hop by hop and per-pair in total, and the two backends ship
    byte-identical buffers.  The packed wire conforms per transported
    row (its ledger charges halo demand, not the padded all-gather)."""
    spec = {"q": q, "f": f, "layers": layers, "n": n, "rate": rate,
            "widths": list(widths), "packed_width": packed_width}
    return _run(CONSERVE_SCRIPT, spec, q, "CONSERVATION_OK",
                timeout=timeout)


TRAIN_SCRIPT = r"""
import json, sys
import jax, jax.numpy as jnp
from parity import build_setup
from repro.dist.gnn_parallel import (DistMeta, make_train_step,
                                     make_worker_mesh, shard_graph)
from repro.core import CommPolicy
from repro.train.optim import sgd

spec = json.loads(sys.argv[1])
q, f, layers, n = spec["q"], spec["f"], spec["layers"], spec["n"]
g, cfg, params, pg, graph = build_setup(q, f=f, layers=layers, n=n,
                                        hidden=spec["hidden"])
meta = DistMeta.build(pg, params, wire=spec["wire"])
opt = sgd(1e-2)
mesh = make_worker_mesh(q)
gs = shard_graph(graph, mesh)

for polspec in spec["policies"]:
    pol = CommPolicy.parse(polspec, 1, compressor="blockmask")
    p_e, s_e = params, opt.init(params)
    step_e = make_train_step(cfg, pol, opt, meta)
    p_s, s_s = params, opt.init(params)
    step_s = make_train_step(cfg, pol, opt, meta, mesh=mesh)
    for i in range(spec["steps"]):
        p_e, s_e, m_e = step_e(p_e, s_e, graph, jnp.asarray(i),
                               jax.random.key(i))
        p_s, s_s, m_s = step_s(p_s, s_s, gs, jnp.asarray(i),
                               jax.random.key(i))
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p_e), jax.tree.leaves(p_s)))
    assert d < 1e-6, (polspec, d)
    assert abs(float(m_e["loss"]) - float(m_s["loss"])) < 1e-5, polspec
    assert abs(float(m_e["transport_bits"]) -
               float(m_s["transport_bits"])) < 1.0, polspec
    print(polspec, "OK", f"dp={d:.2e}")
print("TRAIN_PARITY_OK")
"""


EF_SCRIPT = r"""
import json, sys
import jax, jax.numpy as jnp
import numpy as np
from parity import build_setup
from repro.core import CommPolicy
from repro.dist.gnn_parallel import (DistMeta, make_worker_mesh,
                                     shard_graph)
from repro.dist.ratectl import (RatePlan, init_wire_residuals,
                                make_auto_train_step)
from repro.train.optim import sgd

spec = json.loads(sys.argv[1])
q = spec["q"]
g, cfg, params, pg, graph = build_setup(q, f=spec["f"],
                                        layers=spec["layers"], n=spec["n"],
                                        hidden=spec["hidden"])
meta = DistMeta.build(pg, params, wire="p2p")
policy = CommPolicy.parse(spec["policy"], spec["steps"])
opt = sgd(1e-2)
mesh = make_worker_mesh(q)
gs = shard_graph(graph, mesh)
plan = RatePlan(jnp.asarray(np.asarray(spec["rates"], np.float32)),
                jnp.zeros((q, q), jnp.float32),
                jnp.asarray(np.asarray(spec["widths"], np.float32)))

def run(mesh_, gg, rounding):
    p, s = params, opt.init(params)
    cache = init_wire_residuals(meta, cfg)
    step = make_auto_train_step(cfg, policy, opt, meta, mesh=mesh_,
                                rounding=rounding)
    for t in range(spec["steps"]):
        p, s, m, cache = step(p, s, gg, jax.random.key(t), plan, cache)
    return p, cache, m

for rounding in spec["roundings"]:
    p_e, c_e, m_e = run(None, graph, rounding)
    p_s, c_s, m_s = run(mesh, gs, rounding)
    dp = max(float(jnp.abs(a - b).max())
             for a, b in zip(jax.tree.leaves(p_e), jax.tree.leaves(p_s)))
    assert len(c_e) == len(c_s) and c_e, (len(c_e), len(c_s))
    assert all(a.shape == b.shape for a, b in zip(c_e, c_s))
    dc = max(float(jnp.abs(a - b).max()) for a, b in zip(c_e, c_s))
    # EF must actually be live: residual state nonzero after a
    # quantised step
    nz = max(float(jnp.abs(a).max()) for a in c_e)
    db = abs(float(m_e["transport_bits"]) - float(m_s["transport_bits"]))
    assert dp <= 1e-6, (rounding, dp)
    assert dc <= 1e-6, (rounding, dc)
    assert db < 1.0, (rounding, db)
    assert nz > 0.0, rounding
    print(rounding, "OK", f"dp={dp:.2e} dc={dc:.2e} resid_max={nz:.2e}")
print("EF_PARITY_OK")
"""


def _run(script: str, spec: dict, q: int, sentinel: str,
         timeout: int = 1200) -> str:
    # tests/ on the path so the scripts import parity.build_setup — ONE
    # scaffold construction, in-process and in the subprocess
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={q}",
               PYTHONPATH=os.pathsep.join(
                   [SRC, os.path.dirname(os.path.abspath(__file__))]))
    out = subprocess.run([sys.executable, "-c", script, json.dumps(spec)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert sentinel in out.stdout, out.stdout
    return out.stdout


def run_forward_parity(q: int, cases: list[dict], f: int = 512,
                       layers: int = 2, n: int = 256, atol: float = 1e-6,
                       timeout: int = 1200, shards: bool = False) -> str:
    """Run ``cases`` (dicts of ``wire`` / ``policy`` / ``map`` ∈ {None,
    'pair', 'layer'} / optional ``width_map`` ∈ {None, 'pair', 'layer'} /
    optional ``seed`` / optional ``fault`` seed — a seeded
    ``FaultSchedule`` drop mask split into CACHED/DEAD plus a random
    fault hop cache, applied identically on both backends) on a
    ``q``-device mesh in one subprocess; asserts emulated ≡ shard_map
    ≤ ``atol`` per case.

    The mixed-rate (and mixed-width) operands are drawn host-side by
    :func:`mixed_map` / :func:`mixed_width_map` (so the subprocess
    exercises exactly the maps the in-process tests use) and shipped
    through the JSON spec.  ``shards=True`` builds the subprocess's graph
    from disk-backed shards (``build_setup(shards=True)``) instead of the
    in-memory partitioner — the Q ≥ 16 scale-conformance route."""
    def _widths(c):
        wmode = c.get("width_map")
        if wmode is None:
            return None
        if wmode.startswith("w"):
            # uniform off-diagonal width, e.g. "w2"/"w4": pins the byte
            # wire at exactly that static storage width on both backends
            wm = np.full((q, q), float(wmode[1:]), np.float32)
            np.fill_diagonal(wm, 32.0)
            return wm.tolist()
        draw = sub32_width_map if wmode.startswith("sub32") \
            else mixed_width_map
        return draw(q, c.get("seed", 0),
                    layers if wmode.endswith("layer") else None).tolist()

    cases = [dict(c,
                  rates=None if c["map"] is None else mixed_map(
                      q, c.get("seed", 0),
                      layers if c["map"] == "layer" else None).tolist(),
                  widths=_widths(c))
        for c in cases]
    spec = {"q": q, "f": f, "layers": layers, "n": n, "atol": atol,
            "cases": cases, "shards": shards}
    return _run(FORWARD_SCRIPT, spec, q, "PARITY_MATRIX_OK",
                timeout=timeout)


def run_ef_parity(q: int, policy: str = "auto:budget:2e8:w8",
                  roundings: tuple[str, ...] = ("rint",), f: int = 128,
                  hidden: int = 128, layers: int = 2, n: int = 128,
                  steps: int = 3, timeout: int = 900) -> str:
    """Error-feedback backend parity (DESIGN.md §3.8/§3.11 satellite):
    run ``steps`` quantised auto-policy train steps with a FIXED mixed
    rate × width plan (no controller in the loop, so both backends see
    identical operands) on the emulated and shard_map backends and pin
    parameters, the EF residual cache tuple, and transport ≤ 1e-6 —
    per requested rounding mode (``"stochastic"`` additionally pins the
    per-(sender, hop) key schedule across backends)."""
    spec = {"q": q, "f": f, "hidden": hidden, "layers": layers, "n": n,
            "steps": steps, "policy": policy,
            "roundings": list(roundings),
            "rates": mixed_map(q, 0).tolist(),
            "widths": mixed_width_map(q, 0).tolist()}
    return _run(EF_SCRIPT, spec, q, "EF_PARITY_OK", timeout=timeout)


def run_train_parity(q: int, policies: list[str], wire: str = "p2p",
                     f: int = 256, hidden: int = 128, layers: int = 3,
                     n: int = 256, steps: int = 4,
                     timeout: int = 900) -> str:
    """Train-step parity: run each policy ``steps`` optimizer steps on
    both backends and pin parameters, loss, and transport."""
    spec = {"q": q, "f": f, "hidden": hidden, "layers": layers, "n": n,
            "steps": steps, "wire": wire, "policies": policies}
    return _run(TRAIN_SCRIPT, spec, q, "TRAIN_PARITY_OK", timeout=timeout)
