"""Benchmark orchestrator exit code: a failed module must fail the run.

Regression: ``benchmarks/run.py`` counts failures; `main()` must return
that count (the process exit code) so CI can never silently pass a broken
benchmark.
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import run as bench_run  # noqa: E402


@pytest.fixture
def fake_modules(monkeypatch):
    import types

    good = types.ModuleType("benchmarks._fake_good")
    good.main = lambda quick=True: {"name": "fake_good", "us_per_call": 1.0,
                                    "derived": "ok"}
    bad = types.ModuleType("benchmarks._fake_bad")

    def boom(quick=True):
        raise RuntimeError("intentional benchmark failure")

    bad.main = boom
    monkeypatch.setitem(sys.modules, "benchmarks._fake_good", good)
    monkeypatch.setitem(sys.modules, "benchmarks._fake_bad", bad)
    monkeypatch.setattr(bench_run, "MODULES",
                        ["benchmarks._fake_good", "benchmarks._fake_bad"])


def test_failed_module_propagates_nonzero(fake_modules, capsys):
    rc = bench_run.main([])
    out = capsys.readouterr().out
    assert rc == 1
    assert "fake_good,1.0,ok" in out
    assert "benchmarks._fake_bad,NaN,ERROR" in out


def test_all_passing_returns_zero(fake_modules):
    rc = bench_run.main(["--only", "good"])
    assert rc == 0


def test_ratectl_budget_registered():
    assert "benchmarks.ratectl_budget" in bench_run.MODULES
