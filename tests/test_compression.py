"""Definition-1 compressor properties (paper §III-A)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import available_compressors, get_compressor


@pytest.mark.parametrize("name", available_compressors())
def test_rate_one_lossless_mask(name):
    """r = 1 must communicate everything (mask compressors exactly)."""
    c = get_compressor(name)
    x = jax.random.normal(jax.random.key(0), (64, 128))
    xt, bits = c(jax.random.key(1), x, 1.0)
    if name in ("randmask", "randmask_unbiased", "topk"):
        np.testing.assert_allclose(np.asarray(xt), np.asarray(x), rtol=1e-6)


@pytest.mark.parametrize("rate", [2.0, 4.0, 16.0, 128.0])
def test_mask_moment_bound(rate):
    """E||x~ - x||^2 <= eps(r)^2 ||x||^2 (Definition 1), statistically."""
    c = get_compressor("randmask")
    x = jax.random.normal(jax.random.key(0), (512, 128))
    errs, kept = [], []
    for i in range(8):
        xt, bits = c(jax.random.key(i), x, rate)
        errs.append(float(jnp.sum((xt - x) ** 2) / jnp.sum(x ** 2)))
        kept.append(float(bits) / (x.size * 32))
    mean_err = np.mean(errs)
    expect = float(c.eps2(rate))
    assert abs(mean_err - expect) < 0.05, (mean_err, expect)
    assert abs(np.mean(kept) - 1.0 / rate) < 0.05


def test_eps_monotone_in_rate():
    c = get_compressor("randmask")
    rates = jnp.array([1.0, 2.0, 4.0, 8.0, 64.0, 128.0])
    eps = np.asarray(c.eps2(rates))
    assert np.all(np.diff(eps) >= 0)


def test_unbiased_mask_is_unbiased():
    c = get_compressor("randmask_unbiased")
    x = jax.random.normal(jax.random.key(0), (256, 64))
    acc = jnp.zeros_like(x)
    n = 64
    for i in range(n):
        xt, _ = c(jax.random.key(i), x, 4.0)
        acc = acc + xt
    bias = float(jnp.abs(acc / n - x).mean() / jnp.abs(x).mean())
    assert bias < 0.2, bias


def test_topk_keeps_largest():
    c = get_compressor("topk")
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (32, 32)))
    xt, bits = c(jax.random.key(0), x, 4.0)
    kept = np.asarray(xt != 0)
    thresh = np.quantile(np.abs(np.asarray(x)), 0.75)
    assert np.all(np.abs(np.asarray(x))[kept] >= thresh - 1e-6)
    # index metadata charged: 32-bit value + 32-bit index per kept element
    assert float(bits) == kept.sum() * 64


def test_int8_error_small_at_rate4():
    c = get_compressor("int8")
    x = jax.random.normal(jax.random.key(0), (64, 128))
    xt, bits = c(jax.random.key(1), x, 4.0)
    rel = float(jnp.abs(xt - x).max() / jnp.abs(x).max())
    assert rel < 0.02, rel          # pure quantisation at r=4, no masking
    assert float(bits) <= x.size * 8 + x.shape[0] * 32


def test_compression_differentiable():
    c = get_compressor("randmask")

    def loss(x):
        xt, _ = c(jax.random.key(0), x, 4.0)
        return jnp.sum(xt ** 2)

    x = jax.random.normal(jax.random.key(2), (32, 32))
    g = jax.grad(loss)(x)
    xt, _ = c(jax.random.key(0), x, 4.0)
    # gradient flows exactly through kept entries
    np.testing.assert_allclose(np.asarray(g), np.asarray(2 * xt), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 64), cols=st.integers(1, 64),
       rate=st.floats(1.0, 64.0))
def test_mask_shape_preserving_property(rows, cols, rate):
    c = get_compressor("randmask")
    x = jnp.ones((rows, cols))
    xt, bits = c(jax.random.key(0), x, rate)
    assert xt.shape == x.shape
    kept = float((xt != 0).sum())
    assert float(bits) == kept * 32
    # masked output only contains 0 or the original value
    vals = np.unique(np.asarray(xt))
    assert set(vals.tolist()) <= {0.0, 1.0}


@pytest.mark.parametrize("rate", [4.0, 16.0, 64.0])
def test_int8_bits_match_payload_composition(rate):
    """Charged bits == surviving int8 elements × 8 + per-row f32 scales × 32.

    The scales are side-band metadata that always crosses the wire; only the
    quantised payload is subsampled past rate 4.
    """
    c = get_compressor("int8")
    x = jax.random.normal(jax.random.key(0), (32, 64))
    _, bits = c(jax.random.key(1), x, rate)
    residual = max(rate / 4.0, 1.0)
    mask = jax.random.bernoulli(jax.random.key(1), 1.0 / residual, x.shape)
    expect = float(mask.sum()) * 8 + x.shape[0] * 32
    np.testing.assert_allclose(float(bits), expect)
