"""Fault-injection plane (ISSUE 8): schedule determinism, the
degradation ladder, cached zero-bit serving, the rate→0 local-only
limit, and the elastic Q − 1 shrink."""

import dataclasses

import numpy as np
import pytest

from repro.dist.faults import (CACHED, DEAD, FRESH, DegradeState,
                               FaultSchedule, degrade_plan, init_degrade,
                               migrate_controller_state,
                               migrate_degrade_state, serve_masks,
                               shrink_shards)

Q = 4


# ---------------------------------------------------------------------------
# FaultSchedule: pure function of (seed, step)
# ---------------------------------------------------------------------------


def test_schedule_is_pure_and_replayable():
    a = FaultSchedule(q=Q, seed=7, drop_rate=0.3, spike_rate=0.2)
    b = FaultSchedule(q=Q, seed=7, drop_rate=0.3, spike_rate=0.2)
    for t in (0, 1, 5, 1000):
        np.testing.assert_array_equal(a.link_drops(t), b.link_drops(t))
        np.testing.assert_array_equal(a.latency(t), b.latency(t))
        np.testing.assert_array_equal(a.effective_drops(t),
                                      b.effective_drops(t))
    # different steps/seeds decorrelate
    assert not np.array_equal(a.effective_drops(0), a.effective_drops(1))
    c = FaultSchedule(q=Q, seed=8, drop_rate=0.3, spike_rate=0.2)
    assert not np.array_equal(a.effective_drops(0), c.effective_drops(0))
    # masks are off-diagonal, 0/1 float32
    d = a.link_drops(3)
    assert d.dtype == np.float32 and float(np.diag(d).sum()) == 0.0


def test_latency_spikes_count_as_effective_drops():
    s = FaultSchedule(q=Q, seed=3, drop_rate=0.0, spike_rate=0.5,
                      spike_factor=8.0, spike_threshold=4.0)
    eff = s.effective_drops(2) > 0
    lat = s.latency(2)
    np.testing.assert_array_equal(eff, lat >= 4.0)


def test_schedule_shrink_preserves_survivor_streams():
    s = FaultSchedule(q=Q, seed=5, drop_rate=0.4)
    shrunk = s.shrink(1)           # drop current index 1
    assert shrunk.alive_workers == (0, 2, 3) and shrunk.cur_q == Q - 1
    keep = np.ix_([0, 2, 3], [0, 2, 3])
    for t in range(6):
        np.testing.assert_array_equal(shrunk.effective_drops(t),
                                      s.effective_drops(t)[keep])


def test_crash_events_use_original_worker_ids():
    s = FaultSchedule(q=Q, seed=0, crash_at=((3, 2), (5, 3)))
    assert s.crash_at_step(0) is None
    assert s.crash_at_step(3) == 2
    s2 = s.shrink(s.crash_at_step(3))
    # original worker 3 is now current index 2
    assert s2.crash_at_step(5) == 2
    # events naming dead workers are ignored
    s3 = dataclasses.replace(s, crash_at=((4, 2),)).shrink(2)
    assert s3.crash_at_step(4) is None


# ---------------------------------------------------------------------------
# Degradation ladder: exchange → cached → backoff probe → local-only
# ---------------------------------------------------------------------------


def _dark_pair_trace(steps: int, max_stale: int = 2, cap: int = 4):
    """Serve modes of one permanently dark pair."""
    st = init_degrade(2)
    drops = np.array([[0.0, 1.0], [0.0, 0.0]], np.float32)
    out = []
    for t in range(steps):
        serve, st = degrade_plan(st, drops, t, max_stale=max_stale,
                                 backoff_base=1, backoff_cap=cap)
        out.append(int(serve[0, 1]))
    return out, st


def test_ladder_cached_then_dead():
    trace, st = _dark_pair_trace(8)
    assert trace[:2] == [CACHED, CACHED]      # under max_stale: cache
    assert all(v == DEAD for v in trace[2:])  # at the cap: local-only
    assert int(st.age[0, 1]) == 8
    assert 1 <= int(st.backoff[0, 1]) <= 4


def test_ladder_backoff_caps_and_recovery_waits_for_probe():
    st = init_degrade(2)
    cap = 4
    down = np.array([[0.0, 1.0], [0.0, 0.0]], np.float32)
    up = np.zeros((2, 2), np.float32)
    probes = []
    serve_at = {}
    for t in range(18):
        drops = up if t >= 14 else down     # link recovers at t=14
        pre = DegradeState(st.age.copy(), st.backoff.copy(),
                           st.next_try.copy())
        serve, st = degrade_plan(st, drops, t, max_stale=2,
                                 backoff_base=1, backoff_cap=cap)
        listened = (pre.age[0, 1] >= 2) and \
            (pre.backoff[0, 1] == 0 or t >= pre.next_try[0, 1])
        if pre.age[0, 1] >= 2 and listened:
            probes.append(t)
        serve_at[t] = int(serve[0, 1])
    # probe cadence: immediate, then 1, 2, 4, 4, 4 (capped)
    assert probes == [2, 3, 5, 9, 13, 17]
    # between probes even the recovered link stays DEAD ...
    assert serve_at[14] == DEAD and serve_at[16] == DEAD
    # ... until the next probe lands FRESH
    assert serve_at[17] == FRESH
    assert int(st.backoff[0, 1]) == 0 and int(st.age[0, 1]) == 0


def test_serve_masks_disjoint_and_migrate_shapes():
    serve = np.array([[FRESH, CACHED], [DEAD, FRESH]], np.int8)
    fskip, dead = serve_masks(serve)
    assert float((fskip * dead).sum()) == 0.0
    np.testing.assert_array_equal(fskip, [[0, 1], [0, 0]])
    np.testing.assert_array_equal(dead, [[0, 0], [1, 0]])
    st = migrate_degrade_state(init_degrade(Q), 2)
    assert st.age.shape == (Q - 1, Q - 1)


def test_degrade_plan_is_pure():
    st = init_degrade(2)
    drops = np.array([[0.0, 1.0], [1.0, 0.0]], np.float32)
    before = (st.age.copy(), st.backoff.copy(), st.next_try.copy())
    degrade_plan(st, drops, 0)
    np.testing.assert_array_equal(st.age, before[0])
    np.testing.assert_array_equal(st.backoff, before[1])
    np.testing.assert_array_equal(st.next_try, before[2])


# ---------------------------------------------------------------------------
# Aggregation fault channel (emulated backend; parity with shard_map is
# pinned by test_parity_matrix.py's fault cases)
# ---------------------------------------------------------------------------


def _forward_setup():
    import jax.numpy as jnp  # noqa: F401  (jax import gate)

    import parity
    from repro.dist.gnn_parallel import DistMeta

    g, cfg, params, pg, graph = parity.build_setup(Q, f=256, layers=2,
                                                   n=256)
    meta = DistMeta.build(pg, params, wire="p2p")
    return cfg, params, graph, meta


def _fault_forward(cfg, params, graph, meta, fskip, dead, fcache,
                   key_seed=3):
    import jax
    import jax.numpy as jnp

    from repro.core import CommPolicy
    from repro.dist.gnn_parallel import (_make_aggregate_emulated,
                                         _packed_pair_k_for)
    from repro.nn.gnn import gnn_forward

    pol = CommPolicy.parse("full", 1, compressor="blockmask")
    rm = np.ones((Q, Q), np.float32)
    fe: list = []
    agg = _make_aggregate_emulated(
        graph, meta, pol, None, jnp.ones(()), jax.random.key(key_seed),
        packed_k=dict(_packed_pair_k_for(meta, rm)),
        rate_map=jnp.asarray(rm), fskip=jnp.asarray(fskip),
        fcache=fcache, fcache_out=fe, dead=jnp.asarray(dead))
    logits, bits = gnn_forward(params, cfg, graph["features"], agg)
    return logits, np.asarray(bits, np.float64), tuple(fe)


def test_cached_serving_is_bitwise_and_charges_zero_bits():
    from repro.dist.ratectl import init_halo_cache
    from repro.nn import GNNConfig  # noqa: F401

    cfg, params, graph, meta = _forward_setup()
    zeros = np.zeros((Q, Q), np.float32)
    l0, b0, fresh = _fault_forward(cfg, params, graph, meta, zeros, zeros,
                                   init_halo_cache(meta, cfg))
    # serve pair (receiver 2 ← sender 0) from the captured fresh buffers
    fskip = zeros.copy()
    fskip[2, 0] = 1.0
    l1, b1, _ = _fault_forward(cfg, params, graph, meta, fskip, zeros,
                               fresh)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    # the cached pair ships nothing: its per-pair ledger entry zeroes and
    # both ledger columns shrink
    lq2 = 2 * Q * Q
    t0 = b0[2:2 + lq2].reshape(2, Q, Q)
    t1 = b1[2:2 + lq2].reshape(2, Q, Q)
    assert t0[:, 2, 0].sum() > 0 and t1[:, 2, 0].sum() == 0.0
    assert b1[0] < b0[0] and b1[1] < b0[1]
    np.testing.assert_allclose(b0[1] - b1[1], t0[:, 2, 0].sum())


def test_all_dark_matches_no_comm_limit():
    import jax
    import jax.numpy as jnp

    from repro.core import CommPolicy
    from repro.dist.gnn_parallel import _make_aggregate_emulated
    from repro.dist.ratectl import init_halo_cache
    from repro.nn.gnn import gnn_forward

    cfg, params, graph, meta = _forward_setup()
    zeros = np.zeros((Q, Q), np.float32)
    dead = 1.0 - np.eye(Q, dtype=np.float32)
    l1, b1, _ = _fault_forward(cfg, params, graph, meta, zeros, dead,
                               init_halo_cache(meta, cfg))
    assert b1[1] == 0.0, "dead pairs must charge zero transport"
    pol = CommPolicy.parse("none", 1)
    agg = _make_aggregate_emulated(graph, meta, pol, None, jnp.ones(()),
                                   jax.random.key(3))
    l_iso, _ = gnn_forward(params, cfg, graph["features"], agg)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l_iso),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Elastic shrink + state migration
# ---------------------------------------------------------------------------


def test_shrink_shards_renumbers_and_trains():
    import jax
    import jax.numpy as jnp

    import parity
    from repro.core import CommPolicy
    from repro.dist.gnn_parallel import (DistMeta, _make_aggregate_emulated,
                                         _packed_pair_k_for)
    from repro.nn.gnn import gnn_forward

    g, cfg, params, pg, graph = parity.build_setup(Q, f=256, layers=2,
                                                   n=256, shards=True)
    dead = 2
    new = shrink_shards(pg, dead)
    assert new.q == Q - 1 and new.halo_spec.q == Q - 1
    assert new.parts == tuple(range(Q - 1))
    # no surviving remote edge references the dead worker
    src_part = np.asarray(new.remote_src) // new.halo_size
    valid = np.asarray(new.remote_w) > 0
    assert valid.sum() > 0 and src_part[valid].max() < Q - 1
    assert new.cross_edges == int(valid.sum())
    # the shrunk set still runs a full-comm forward to finite logits
    meta = DistMeta.build(new, params, wire="p2p")
    rm = np.ones((Q - 1, Q - 1), np.float32)
    pol = CommPolicy.parse("full", 1, compressor="blockmask")
    agg = _make_aggregate_emulated(
        new.device_arrays(), meta, pol, None, jnp.ones(()),
        jax.random.key(0), packed_k=dict(_packed_pair_k_for(meta, rm)),
        rate_map=jnp.asarray(rm))
    logits, _ = gnn_forward(params, cfg, new.device_arrays()["features"],
                            agg)
    assert bool(jnp.isfinite(logits).all())


def test_shrink_shards_rejects_bad_input():
    import parity
    g, cfg, params, pg, graph = parity.build_setup(2, f=256, layers=2,
                                                   n=128, shards=True)
    with pytest.raises(ValueError):
        shrink_shards(pg, 5)
    with pytest.raises(TypeError):
        shrink_shards("not a shardset", 0)


def test_migrate_controller_state_cuts_pair_leaves():
    import jax.numpy as jnp

    state = {"spent": jnp.zeros(()), "integ": jnp.asarray(2.0),
             "ema": jnp.arange(2 * Q * Q, dtype=jnp.float32
                               ).reshape(2, Q, Q),
             "age": np.arange(Q * Q).reshape(Q, Q)}
    out = migrate_controller_state(state, 1, Q)
    assert out["ema"].shape == (2, Q - 1, Q - 1)
    exp = np.delete(np.delete(np.arange(Q * Q).reshape(Q, Q), 1, 0), 1, 1)
    np.testing.assert_array_equal(np.asarray(out["age"]), exp)
    assert float(out["integ"]) == 2.0   # scalars pass through untouched


# ---------------------------------------------------------------------------
# Trainer integration
# ---------------------------------------------------------------------------


def test_trainer_zero_drop_fault_plane_is_noop():
    """drop_rate=0 through the fault step lands bitwise on the plain
    trainer — the fault channel is free when no fault fires."""
    import parity
    from repro.core import CommPolicy
    from repro.train.trainer import train_gnn

    g, *_ = parity.build_setup(2, f=256, layers=2, n=128)
    ep = 6
    pol = CommPolicy.parse("full", ep)
    kw = dict(q=2, policy=pol, epochs=ep, hidden=128, layers=2,
              eval_every=2, wire="p2p", seed=0)
    plain = train_gnn(g, **kw)
    faulted = train_gnn(g, faults=FaultSchedule(q=2, seed=0,
                                                drop_rate=0.0), **kw)
    assert plain.history.loss == faulted.history.loss
    assert plain.history.transport_gfloats == \
        faulted.history.transport_gfloats


def test_trainer_crash_shrinks_elastically():
    import parity
    from repro.core import CommPolicy
    from repro.train.trainer import train_gnn

    g, cfg, params, pg, graph = parity.build_setup(Q, f=256, layers=2,
                                                   n=256, shards=True)
    ep = 6
    pol = CommPolicy.parse("full", ep)
    sched = FaultSchedule(q=Q, seed=1, drop_rate=0.1, crash_at=((3, 1),))
    res = train_gnn(pg, policy=pol, epochs=ep, hidden=128, layers=2,
                    eval_every=1, wire="p2p", seed=0, faults=sched)
    assert res.meta.q == Q - 1
    assert all(np.isfinite(res.history.loss))
    # in-memory partitions cannot take the elastic path
    with pytest.raises(ValueError, match="shard-backed"):
        train_gnn(g, q=Q, policy=pol, epochs=ep, hidden=128, layers=2,
                  eval_every=1, wire="p2p", seed=0, faults=sched)
