"""Distributed GNN runtime: exactness vs centralized + baseline semantics.

The graph/config/params scaffold comes from the shared parity harness
(tests/parity.py) so this file, test_p2p_wire.py and test_pair_rates.py
all exercise the same construction.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from parity import build_setup

from repro.core import FULL_COMM, NO_COMM, fixed, varco
from repro.dist.gnn_parallel import (DistMeta, _make_aggregate_emulated,
                                     make_eval_step, make_train_step)
from repro.graph import partition_graph, tiny_graph
from repro.nn import GNNConfig, centralized_forward, init_gnn
from repro.nn.gnn import gnn_forward
from repro.train.optim import adamw, sgd


@pytest.fixture(scope="module")
def setup():
    g, cfg, params, _, _ = build_setup(4, f=16, layers=3, n=256,
                                       hidden=32, p2p=False)
    return g, cfg, params


@pytest.mark.parametrize("scheme", ["random", "metis-like"])
@pytest.mark.parametrize("q", [2, 4, 8])
def test_full_comm_equals_centralized(setup, scheme, q):
    """The paper's premise: full communication == centralized training,
    for ANY partitioning (contribution 2)."""
    g, cfg, params = setup
    ref = np.asarray(centralized_forward(params, cfg, g))
    pg = partition_graph(g, q, scheme=scheme)
    graph = pg.device_arrays()
    meta = DistMeta.build(pg, params)
    agg = _make_aggregate_emulated(graph, meta, FULL_COMM, None,
                                   jnp.ones(()), jax.random.key(0))
    logits, bits = gnn_forward(params, cfg, graph["features"], agg)
    got = np.asarray(logits)[pg.owner, pg.local_index]
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-5)


def test_poly_conv_full_comm_equals_centralized(setup):
    g, _, _ = setup
    cfg = GNNConfig(conv="poly", in_dim=g.feat_dim, hidden=32,
                    out_dim=g.num_classes, layers=2, k_taps=3)
    params = init_gnn(jax.random.key(1), cfg)
    ref = np.asarray(centralized_forward(params, cfg, g, norm="sym"))
    pg = partition_graph(g, 4, scheme="random", norm="sym")
    graph = pg.device_arrays()
    meta = DistMeta.build(pg, params)
    agg = _make_aggregate_emulated(graph, meta, FULL_COMM, None,
                                   jnp.ones(()), jax.random.key(0))
    logits, _ = gnn_forward(params, cfg, graph["features"], agg)
    got = np.asarray(logits)[pg.owner, pg.local_index]
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-5)


def test_nocomm_ignores_remote_and_renormalises(setup):
    g, cfg, params = setup
    pg = partition_graph(g, 4, scheme="random")
    graph = pg.device_arrays()
    meta = DistMeta.build(pg, params)
    agg = _make_aggregate_emulated(graph, meta, NO_COMM, None,
                                   jnp.ones(()), jax.random.key(0))
    a, bits = agg(0, graph["features"])
    # [analytic, transport] ledger pair — No-Comm ships nothing either way
    assert float(jnp.sum(jnp.abs(bits))) == 0.0
    # isolated-subgraph reference on partition 0
    p = 0
    xq = np.asarray(graph["features"][p])
    out = np.zeros((pg.part_size + 1, xq.shape[1]), np.float32)
    np.add.at(out, np.asarray(pg.local_dst[p]),
              np.asarray(pg.local_w_iso[p])[:, None] *
              xq[np.asarray(pg.local_src[p])])
    np.testing.assert_allclose(np.asarray(a[p]), out[:-1], rtol=1e-5,
                               atol=1e-6)


def test_train_step_decreases_loss_and_charges_bits(setup):
    g, cfg, params = setup
    pg = partition_graph(g, 4, scheme="random")
    graph = pg.device_arrays()
    meta = DistMeta.build(pg, params)
    opt = adamw(5e-3)
    opt_state = opt.init(params)
    pol = varco(total_steps=20, slope=5)
    step = make_train_step(cfg, pol, opt, meta)
    losses, bits = [], []
    p, s = params, opt_state
    for i in range(12):
        p, s, m = step(p, s, graph, jnp.asarray(i), jax.random.key(i))
        losses.append(float(m["loss"]))
        bits.append(float(m["halo_bits"]))
    assert losses[-1] < losses[0]
    # bits grow as the rate anneals (more communication later)
    assert bits[-1] > bits[0]
    # exact accounting: 2 (fwd+bwd) * layers * demand * F * 32 / rate
    rate0 = float(pol.rate(0))
    expect0 = 2 * meta.halo_demand * 32.0 / rate0 * \
        (cfg.in_dim + cfg.hidden * (cfg.layers - 1))
    np.testing.assert_allclose(bits[0], expect0, rtol=1e-5)


def test_eval_step_reports_all_splits(setup):
    g, cfg, params = setup
    pg = partition_graph(g, 4, scheme="random")
    graph = pg.device_arrays()
    meta = DistMeta.build(pg, params)
    accs = make_eval_step(cfg, meta)(params, graph)
    for k in ("train", "val", "test"):
        assert 0.0 <= float(accs[k]) <= 1.0


def test_fixed_compression_noisy_but_bounded(setup):
    """Compressed aggregation stays within the Def.1 error envelope."""
    g, cfg, params = setup
    pg = partition_graph(g, 4, scheme="random")
    graph = pg.device_arrays()
    meta = DistMeta.build(pg, params)
    agg_full = _make_aggregate_emulated(graph, meta, FULL_COMM, None,
                                        jnp.ones(()), jax.random.key(0))
    ref, _ = agg_full(0, graph["features"])
    pol = fixed(4.0)
    agg_c = _make_aggregate_emulated(graph, meta, pol, pol.compressor(),
                                     jnp.asarray(4.0), jax.random.key(0))
    noisy, _ = agg_c(0, graph["features"])
    rel = float(jnp.linalg.norm(noisy - ref) / jnp.linalg.norm(ref))
    assert 0.0 < rel < 1.0
