"""Golden-trace convergence regression (ISSUE 5 satellite).

A seeded 30-step ``train_gnn`` run on ``tiny_graph`` whose loss curve is
pinned against ``tests/golden_traces.json`` (rtol 1e-4) for four policy
families — ``full``, ``fixed:4``, ``auto:budget``, and the quantised-wire
``auto:budget:…:w8`` (rate × width allocation + error feedback,
DESIGN.md §3.8) — all on the p2p wire.  Backend-parity tests catch *relative* drift between the
emulated and shard_map paths; this catches *absolute* numeric drift of
the whole training stack (a refactor that changes both backends in
lockstep still trips it).

Regenerate after an INTENTIONAL numeric change with::

    GOLDEN_REGEN=1 PYTHONPATH=src python -m pytest -q \
        tests/test_golden_trace.py

and commit the refreshed json alongside the change that explains it.
"""

import json
import os

import numpy as np
import pytest

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden_traces.json")

EPOCHS = 30
EVAL_EVERY = 5
N, FEAT, HIDDEN, LAYERS, SEED, QW = 128, 256, 256, 2, 0, 2


def _budget_bits() -> float:
    """Deterministic budget for the auto run: 3/4 of the full-comm
    transport of the run — deliberately OFF the kept-block quantisation
    grid (F=256 → 2 lane blocks → realisable fractions {1/2, 1}), so the
    controller has to dither between kept counts and the trace is
    distinct from every fixed-rate run.  Derived from the partition
    facts, so the spec string is reproducible without hand-maintained
    constants."""
    import jax

    from repro.dist.gnn_parallel import DistMeta
    from repro.graph import partition_graph, tiny_graph
    from repro.nn import GNNConfig, init_gnn

    g = tiny_graph(n=N, feat_dim=FEAT)
    cfg = GNNConfig(conv="sage", in_dim=FEAT, hidden=HIDDEN,
                    out_dim=g.num_classes, layers=LAYERS)
    pg = partition_graph(g, QW, scheme="random", seed=SEED)
    meta = DistMeta.build(pg, init_gnn(jax.random.key(SEED), cfg),
                          wire="p2p")
    d_full = 2.0 * 32.0 * meta.halo_demand * (FEAT + HIDDEN * (LAYERS - 1))
    return 0.75 * d_full * EPOCHS


def _policies() -> dict:
    return {"full": "full", "fixed4": "fixed:4",
            "auto_budget": f"auto:budget:{_budget_bits():g}",
            "auto_budget_w8": f"auto:budget:{_budget_bits():g}:w8"}


def _run(spec: str) -> list:
    from repro.core import CommPolicy
    from repro.graph import tiny_graph
    from repro.train.trainer import train_gnn

    g = tiny_graph(n=N, feat_dim=FEAT)
    policy = CommPolicy.parse(spec, EPOCHS, compressor="blockmask")
    res = train_gnn(g, q=QW, scheme="random", policy=policy, epochs=EPOCHS,
                    hidden=HIDDEN, layers=LAYERS, seed=SEED,
                    eval_every=EVAL_EVERY, wire="p2p")
    return [float(v) for v in res.history.loss]


@pytest.mark.parametrize("name", ["full", "fixed4", "auto_budget",
                                  "auto_budget_w8"])
def test_loss_curve_matches_golden(name):
    spec = _policies()[name]
    losses = _run(spec)
    if os.environ.get("GOLDEN_REGEN"):
        data = {}
        if os.path.exists(GOLDEN_PATH):
            with open(GOLDEN_PATH) as fh:
                data = json.load(fh)
        data[name] = {"policy": spec, "epochs": EPOCHS,
                      "eval_every": EVAL_EVERY, "loss": losses}
        with open(GOLDEN_PATH, "w") as fh:
            json.dump(data, fh, indent=1, sort_keys=True)
        pytest.skip(f"regenerated golden trace for {name}")
    assert os.path.exists(GOLDEN_PATH), \
        "golden_traces.json missing — run with GOLDEN_REGEN=1"
    with open(GOLDEN_PATH) as fh:
        golden = json.load(fh)[name]
    assert golden["policy"] == spec, \
        f"golden {name} was recorded for {golden['policy']!r}, now {spec!r}"
    # rtol pins the informative (early, O(1)) part of the curve; the atol
    # floor keeps near-zero late-epoch losses from demanding ~1e-9
    # absolute agreement across jax/XLA releases (CI installs unpinned
    # jax[cpu], and reduction-order changes perturb a 30-epoch run)
    np.testing.assert_allclose(np.asarray(losses),
                               np.asarray(golden["loss"]), rtol=1e-4,
                               atol=1e-6,
                               err_msg=f"{name} loss curve drifted "
                                       f"(regen only if intentional)")


def test_shard_backed_run_matches_golden_fixed4():
    """The out-of-core route lands on the in-memory golden curve: the
    same graph chunked to a GraphStore (awkward chunk sizes), partitioned
    by ``stream_partition`` (the random scheme reduces to the identical
    owner vector), sharded to disk, and trained via ``train_gnn(<shard
    dir>)`` — pinned against the same ``fixed4`` golden at rtol 1e-4
    (ISSUE 7 satellite).  No in-memory graph object touches the run."""
    import tempfile

    from repro.core import CommPolicy
    from repro.graph import (tiny_graph, stream_partition,
                             write_graph_store, write_shards)
    from repro.train.trainer import train_gnn

    if os.environ.get("GOLDEN_REGEN"):
        pytest.skip("golden refresh handled by the in-memory runs")
    assert os.path.exists(GOLDEN_PATH), \
        "golden_traces.json missing — run with GOLDEN_REGEN=1"
    with open(GOLDEN_PATH) as fh:
        golden = json.load(fh)["fixed4"]

    g = tiny_graph(n=N, feat_dim=FEAT)
    policy = CommPolicy.parse(golden["policy"], EPOCHS,
                              compressor="blockmask")
    with tempfile.TemporaryDirectory() as td:
        store = write_graph_store(g, os.path.join(td, "store"),
                                  chunk_nodes=29, chunk_edges=173)
        owner = stream_partition(store, QW, scheme="random", seed=SEED)
        shard_dir = write_shards(store, owner, os.path.join(td, "shards"))
        res = train_gnn(shard_dir, policy=policy, epochs=EPOCHS,
                        hidden=HIDDEN, layers=LAYERS, seed=SEED,
                        eval_every=EVAL_EVERY, wire="p2p")
    np.testing.assert_allclose(np.asarray(res.history.loss),
                               np.asarray(golden["loss"]), rtol=1e-4,
                               atol=1e-6,
                               err_msg="shard-backed run drifted off the "
                                       "in-memory golden trace")
