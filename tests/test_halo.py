"""Per-pair halo spec + ELL construction correctness (repro.dist.halo).

The p2p wire is only as good as its static indices: these tests pin the
compacted ``remote_src`` remap round trip (every remote edge must find its
exact source activation in the receiver's per-hop compact buffer) and the
ELL lists (forward == scatter aggregation, reversed == exact transpose) on
regular and adversarial partitionings — isolated partitions with an empty
cut, fully-connected cuts, singleton partitions, and Q == 1.
"""

import numpy as np
import pytest

from repro.dist.halo import (attach_p2p, build_halo_spec, build_reverse_ell,
                             ell_arrays, halo_arrays)
from repro.graph import partition_graph, tiny_graph
from repro.graph.data import from_edge_list
from repro.graph.partition import build_partitioned


def _numpy_compact(pg, arrays, spec, x):
    """Simulate the ring on the host: receiver ``i``'s compact buffer."""
    q, hop_w = pg.q, spec.hop_width
    xq = np.zeros((q, pg.part_size, x.shape[1]), np.float32)
    xq[pg.owner, pg.local_index] = x
    publish = np.stack([xq[p][pg.send_idx[p]] * pg.send_valid[p][:, None]
                        for p in range(q)])
    compact = np.zeros((q, spec.compact_rows, x.shape[1]), np.float32)
    for i in range(q):
        for d in range(1, q):
            j = (i - d) % q
            rows = publish[j][arrays["p2p_send_slot"][j, d - 1]] * \
                arrays["p2p_send_valid"][j, d - 1][:, None]
            compact[i, (d - 1) * hop_w:d * hop_w] = rows
    return compact


def _assert_remap_round_trips(g, pg):
    """Every valid remote edge reads its exact source row from the compact
    buffer — the remap must round-trip bitwise, not approximately."""
    spec = build_halo_spec(pg)
    arrays = halo_arrays(pg, spec)
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (g.num_nodes, 8)).astype(np.float32)
    compact = _numpy_compact(pg, arrays, spec, x)
    dst, src = g.edge_list()
    cross = pg.owner[dst] != pg.owner[src]
    for d_, s_ in zip(dst[cross], src[cross]):
        i = pg.owner[d_]
        # find this edge's row in partition i's remote arrays
        flat = pg.owner[s_] * pg.halo_size + \
            np.flatnonzero(pg.send_idx[pg.owner[s_]] ==
                           pg.local_index[s_])[0]
        e = np.flatnonzero((pg.remote_src[i] == flat) &
                           (pg.remote_w[i] > 0))[0]
        row = arrays["remote_src_p2p"][i][e]
        np.testing.assert_array_equal(compact[i, row], x[s_])


def _assert_spec_consistent(pg):
    spec = build_halo_spec(pg)
    table = spec.pair_table()
    assert table.shape == (pg.q, pg.q)
    assert (np.diag(table) == 0).all()
    assert table.sum() == pg.halo_demand
    assert spec.hop_width >= 1
    assert spec.compact_rows == max((pg.q - 1) * spec.hop_width, 1)
    arrays = halo_arrays(pg, spec)
    # per-pair genuine row counts mirror the table
    for j in range(pg.q):
        for d in range(1, pg.q):
            i = (j + d) % pg.q
            assert arrays["p2p_send_valid"][j, d - 1].sum() == table[i, j]
    return spec


@pytest.mark.parametrize("scheme", ["random", "metis-like"])
@pytest.mark.parametrize("q", [2, 4, 8])
def test_remap_round_trips_exactly(scheme, q):
    g = tiny_graph(n=200)
    pg = partition_graph(g, q, scheme=scheme)
    _assert_spec_consistent(pg)
    _assert_remap_round_trips(g, pg)


def test_isolated_partition_empty_cut():
    """A partition with no cross edges ships and receives nothing."""
    # two disjoint cliques; partition 0 = clique A, partitions 1/2 split B
    n_a, n_b = 8, 16
    edges = [(i, j) for i in range(n_a) for j in range(n_a) if i != j]
    edges += [(n_a + i, n_a + j) for i in range(n_b) for j in range(n_b)
              if i != j]
    dst, src = np.array([e[0] for e in edges]), np.array(
        [e[1] for e in edges])
    n = n_a + n_b
    rng = np.random.default_rng(0)
    g = from_edge_list(n, dst, src, rng.normal(0, 1, (n, 8)),
                       rng.integers(0, 3, n))
    owner = np.zeros(n, np.int32)
    owner[n_a:n_a + n_b // 2] = 1
    owner[n_a + n_b // 2:] = 2
    pg = build_partitioned(g, owner, 3)
    spec = _assert_spec_consistent(pg)
    table = spec.pair_table()
    assert (table[0] == 0).all() and (table[:, 0] == 0).all()
    arrays = halo_arrays(pg, spec)
    assert arrays["p2p_send_valid"][0].sum() == 0        # ships nothing
    _assert_remap_round_trips(g, pg)


def test_fully_connected_cut():
    """Complete graph: every ordered pair exchanges every boundary row."""
    n, q = 12, 4
    edges = [(i, j) for i in range(n) for j in range(n) if i != j]
    dst, src = np.array([e[0] for e in edges]), np.array(
        [e[1] for e in edges])
    rng = np.random.default_rng(1)
    g = from_edge_list(n, dst, src, rng.normal(0, 1, (n, 8)),
                       rng.integers(0, 3, n))
    pg = partition_graph(g, q, scheme="random")
    spec = _assert_spec_consistent(pg)
    table = spec.pair_table()
    off_diag = table[~np.eye(q, dtype=bool)]
    assert (off_diag == n // q).all()                    # all rows, all pairs
    # the p2p win vanishes by construction: demand == Q-1 × all boundary rows
    assert pg.halo_demand == q * (q - 1) * (n // q)
    _assert_remap_round_trips(g, pg)


def test_singleton_partition():
    """A partition holding exactly one node round-trips fine."""
    g = tiny_graph(n=65)
    owner = partition_graph(g, 4, scheme="random").owner.copy()
    owner[owner == 3] = 0
    owner[0] = 3                                         # partition 3 = {0}
    pg = build_partitioned(g, owner, 4)
    _assert_spec_consistent(pg)
    _assert_remap_round_trips(g, pg)


def test_single_partition_degenerate():
    """Q == 1: no pairs, no hops, arrays stay well-formed."""
    g = tiny_graph(n=64)
    pg = partition_graph(g, 1, scheme="random")
    spec = _assert_spec_consistent(pg)
    assert spec.hop_width == 1 and spec.compact_rows == 1
    arrays = halo_arrays(pg, spec)
    assert arrays["p2p_send_valid"].sum() == 0
    graph = attach_p2p(pg.device_arrays(), pg)
    assert graph["p2p_send_slot"].shape == (1, 1, 1)


# ---------------------------------------------------------------------------
# ELL lists
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q", [1, 2, 4])
def test_ell_equals_scatter_aggregation(q):
    """Forward ELL lists reproduce the padded scatter aggregation exactly
    (same edges, per-destination grouping)."""
    g = tiny_graph(n=128)
    pg = partition_graph(g, q, scheme="random")
    arrays = ell_arrays(pg)
    rng = np.random.default_rng(2)
    x = rng.normal(0, 1, (q, pg.part_size, 8)).astype(np.float32)
    for p in range(q):
        expect = np.zeros((pg.part_size + 1, 8), np.float32)
        np.add.at(expect, pg.local_dst[p],
                  pg.local_w[p][:, None] * x[p][pg.local_src[p]])
        got = np.einsum("tk,tkf->tf", arrays["ell_w"][p],
                        x[p][arrays["ell_nbr"][p]])
        np.testing.assert_allclose(got, expect[:-1], rtol=1e-5, atol=1e-6)


def test_reverse_ell_is_exact_transpose():
    """ell_spmm over the reversed lists with rslot-gathered weights equals
    the matrix transpose of the forward ELL SpMM."""
    rng = np.random.default_rng(3)
    n_dst, n_src, k = 20, 15, 4
    nbr = rng.integers(0, n_src, (n_dst, k)).astype(np.int32)
    valid = rng.random((n_dst, k)) < 0.7
    w = np.where(valid, rng.normal(0, 1, (n_dst, k)), 0.0).astype(np.float32)
    rnbr, rslot = build_reverse_ell(nbr, valid, n_src)
    # dense matrices of both operators
    a_fwd = np.zeros((n_dst, n_src))
    for i in range(n_dst):
        for kk in range(k):
            if valid[i, kk]:
                a_fwd[i, nbr[i, kk]] += w[i, kk]
    rw = np.where(rslot >= 0, w.reshape(-1)[np.maximum(rslot, 0)], 0.0)
    a_rev = np.zeros((n_src, n_dst))
    for s in range(n_src):
        for kk in range(rnbr.shape[1]):
            if rslot[s, kk] >= 0:
                a_rev[s, rnbr[s, kk]] += rw[s, kk]
    np.testing.assert_allclose(a_rev, a_fwd.T, rtol=0, atol=0)


def test_attach_p2p_is_pure_and_complete():
    g = tiny_graph(n=96)
    pg = partition_graph(g, 3, scheme="random")
    base = pg.device_arrays()
    n_before = len(base)
    graph = attach_p2p(base, pg)
    assert len(base) == n_before                         # input not mutated
    for k in ("p2p_send_slot", "p2p_send_valid", "remote_src_p2p",
              "ell_nbr", "ell_w", "ell_w_iso", "ell_rnbr", "ell_rslot"):
        assert k in graph, k
        assert graph[k].shape[0] == pg.q
