"""Trip-count-aware HLO collective accounting."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import (collective_bytes,
                                       computation_multipliers,
                                       split_computations, while_trip_count)

FAKE_HLO = """
HloModule jit_step

%body.1 (arg.1: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %p = (s32[], f32[64,128]) parameter(0)
  %x = f32[64,128]{1,0} get-tuple-element(%p), index=1
  %ar = f32[64,128]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %t = (s32[], f32[64,128]) tuple(%i, %ar)
}

%cond.1 (arg.2: (s32[], f32[64,128])) -> pred[] {
  %p2 = (s32[], f32[64,128]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(9)
  ROOT %lt = pred[] compare(%i2, %c), direction=LT
}

ENTRY %main (a: f32[64,128]) -> f32[64,128] {
  %a = f32[64,128]{1,0} parameter(0)
  %ag = f32[256,128]{1,0} all-gather(%a), replica_groups={{0,1,2,3}}, dimensions={0}
  %w = (s32[], f32[64,128]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[64,128]{1,0} get-tuple-element(%w), index=1
}
"""


def test_split_and_trip_count():
    comps = split_computations(FAKE_HLO)
    assert {"body.1", "cond.1", "main"} <= set(comps)
    assert while_trip_count(comps["cond.1"]) == 9


def test_multipliers_count_loop_trips():
    mult = computation_multipliers(FAKE_HLO)
    assert mult["main"] == 1.0
    assert mult["body.1"] == 9.0


def test_collective_bytes_trip_aware():
    out = collective_bytes(FAKE_HLO)
    # all-gather in entry: result 256*128*4 bytes, g=4 -> (3/4)*r, once
    ag = (3 / 4) * 256 * 128 * 4
    # all-reduce in body: r = 64*128*4, g=4 -> 2*(3/4)*r, nine times
    ar = 9 * 2 * (3 / 4) * 64 * 128 * 4
    assert abs(out["per_kind"]["all-gather"] - ag) < 1e-6
    assert abs(out["per_kind"]["all-reduce"] - ar) < 1e-6
    assert out["ops"] == 2


def test_real_hlo_scan_multiplier():
    """End-to-end: a jitted scan with a psum-like collective is scaled."""
    import functools
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    # single-device "mesh" won't emit collectives; instead check scan body
    # counting with a dot inside a loop via collective-free sanity: the
    # multiplier machinery must find trip count 7 for a length-7 scan.
    def f(x):
        def body(c, _):
            return c * 1.5 + 1.0, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    txt = jax.jit(f).lower(jnp.ones((8, 128))).compile().as_text()
    mult = computation_multipliers(txt)
    assert any(abs(v - 7.0) < 1e-6 for v in mult.values()), \
        sorted(set(mult.values()))
