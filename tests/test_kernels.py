"""Pallas kernel vs ref.py oracle allclose, interpret mode, shape/dtype
sweeps (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ell_spmm import ell_spmm
from repro.kernels.varco_pack import (block_mask_indices, varco_pack,
                                      varco_unpack)
from repro.kernels import ref

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 2, 2, 128, 64),      # MHA
    (2, 4, 2, 256, 64),      # GQA 2:1
    (1, 8, 1, 128, 128),     # MQA
    (1, 2, 2, 384, 256),     # gemma-sized heads, ragged seq/block
])
def test_flash_matches_reference(b, h, kv, s, d, dtype):
    q = jnp.asarray(RNG.normal(0, 1, (b, h, s, d)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, kv, s, d)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, kv, s, d)), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    expect = ref.mha_reference(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [64, 128, 200])
def test_flash_sliding_window(window):
    q = jnp.asarray(RNG.normal(0, 1, (1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (1, 2, 256, 64)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          interpret=True)
    expect = ref.mha_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_flash_noncausal():
    q = jnp.asarray(RNG.normal(0, 1, (1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (1, 2, 128, 64)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    expect = ref.mha_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_chunked_sdpa_matches_dense():
    """The model's jnp flash path equals dense sdpa (transformer internals)."""
    from repro.models.transformer import chunked_sdpa
    from repro.models.layers import sdpa, _attn_mask
    b, s, h, kv, d = 2, 2048, 4, 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, kv, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    out = chunked_sdpa(q, k, v, window=0)
    expect = sdpa(q, k, v, _attn_mask(pos, pos, 0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# varco pack / unpack
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,f,rate", [(256, 1024, 4.0), (512, 512, 2.0),
                                      (128, 2048, 16.0), (256, 256, 1.0)])
def test_pack_unpack_roundtrip(n, f, rate, dtype):
    x = jnp.asarray(RNG.normal(0, 1, (n, f)), dtype)
    kept, inv = block_mask_indices(jax.random.key(3), f // 128, rate)
    packed = varco_pack(x, kept, interpret=True)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(ref.pack_reference(x, kept)))
    xt = varco_unpack(packed, inv, interpret=True)
    np.testing.assert_array_equal(np.asarray(xt),
                                  np.asarray(ref.unpack_reference(packed,
                                                                  inv)))
    # round trip == block-mask multiply
    mask = np.zeros(f // 128, bool)
    mask[np.asarray(kept)] = True
    expect = np.asarray(x).reshape(n, f // 128, 128) * mask[None, :, None]
    np.testing.assert_array_equal(np.asarray(xt),
                                  expect.reshape(n, f).astype(expect.dtype))


@settings(max_examples=15, deadline=None)
@given(nb=st.integers(1, 32), rate=st.floats(1.0, 32.0),
       seed=st.integers(0, 100))
def test_block_mask_indices_properties(nb, rate, seed):
    kept, inv = jax.jit(block_mask_indices,
                        static_argnums=(1, 2))(jax.random.key(seed), nb, rate)
    kept = np.asarray(kept)
    inv = np.asarray(inv)
    k = max(int(nb / max(rate, 1.0)), 1)
    assert len(kept) == k
    assert len(np.unique(kept)) == k                     # no duplicates
    assert (np.sort(kept) == kept).all()
    # inverse map consistent
    for col, blk in enumerate(kept):
        assert inv[blk] == col
    assert (inv[np.setdiff1d(np.arange(nb), kept)] == -1).all()


def test_kernel_roundtrip_satisfies_definition1():
    """Kernel-path compression obeys the same Def.1 error bound."""
    x = jnp.asarray(RNG.normal(0, 1, (256, 1024)), jnp.float32)
    errs = []
    for i in range(8):
        xt, bits = ops.compress_roundtrip(jax.random.key(i), x, 4.0,
                                          interpret=True)
        errs.append(float(jnp.sum((xt - x) ** 2) / jnp.sum(x ** 2)))
        assert float(bits) == 256 * 256 * 32        # exactly 1/4 of blocks
    assert abs(np.mean(errs) - 0.75) < 0.15         # eps^2 = 1 - 1/r


# ---------------------------------------------------------------------------
# ell spmm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_src,n_dst,k,f,sc", [
    (2048, 256, 16, 256, 1024),
    (1024, 128, 8, 128, 256),     # multiple source chunks
    (512, 128, 32, 384, 512),
])
def test_ell_spmm_matches_reference(n_src, n_dst, k, f, sc, dtype):
    x = jnp.asarray(RNG.normal(0, 1, (n_src, f)), dtype)
    nbr = jnp.asarray(RNG.integers(0, n_src, (n_dst, k)), jnp.int32)
    w = jnp.asarray(RNG.normal(0, 1, (n_dst, k)), jnp.float32)
    out = ell_spmm(x, nbr, w, src_chunk=sc, interpret=True)
    expect = ref.ell_spmm_reference(x, nbr, w)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_ell_spmm_padded_degrees_zero_weight():
    """Pad entries (w == 0) contribute nothing wherever they point."""
    x = jnp.asarray(RNG.normal(0, 1, (256, 128)), jnp.float32)
    nbr = jnp.asarray(RNG.integers(0, 256, (128, 4)), jnp.int32)
    w = jnp.asarray(RNG.normal(0, 1, (128, 4)), jnp.float32)
    w = w.at[:, 2:].set(0.0)
    out = ell_spmm(x, nbr, w, interpret=True)
    expect = ref.ell_spmm_reference(x, nbr[:, :2], w[:, :2])
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# ell_aggregate: the differentiable runtime path (custom VJP)
# ---------------------------------------------------------------------------


def _random_ell(n_dst, n_src, k, seed, rev_k=None):
    from repro.dist.halo import build_reverse_ell
    rng = np.random.default_rng(seed)
    nbr = rng.integers(0, n_src, (n_dst, k)).astype(np.int32)
    valid = rng.random((n_dst, k)) < 0.7
    w = np.where(valid, rng.normal(0, 1, (n_dst, k)), 0.0).astype(np.float32)
    rnbr, rslot = build_reverse_ell(nbr, valid, n_src, rev_k=rev_k)
    return (jnp.asarray(nbr), jnp.asarray(w), jnp.asarray(rnbr),
            jnp.asarray(rslot))


@pytest.mark.parametrize("n_dst,n_src,k,f", [(64, 48, 6, 128),
                                             (128, 128, 3, 96),
                                             (33, 17, 9, 64)])
def test_ell_aggregate_forward_matches_reference(n_dst, n_src, k, f):
    nbr, w, rnbr, rslot = _random_ell(n_dst, n_src, k, seed=f)
    x = jnp.asarray(RNG.normal(0, 1, (n_src, f)), jnp.float32)
    out = ops.ell_aggregate(x, nbr, w, rnbr, rslot)
    expect = ref.ell_spmm_reference(x, nbr, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_dst,n_src,k,f", [(64, 48, 6, 128),
                                             (33, 17, 9, 64)])
def test_ell_aggregate_gradient_matches_reference(n_dst, n_src, k, f):
    """Custom VJP (reversed-list transpose) vs autodiff of the jnp oracle —
    both d/dx and d/dw, under an arbitrary downstream cotangent."""
    nbr, w, rnbr, rslot = _random_ell(n_dst, n_src, k, seed=7 * f)
    x = jnp.asarray(RNG.normal(0, 1, (n_src, f)), jnp.float32)
    cot = jnp.asarray(RNG.normal(0, 1, (n_dst, f)), jnp.float32)

    def loss_kernel(x_, w_):
        return jnp.sum(ops.ell_aggregate(x_, nbr, w_, rnbr, rslot) * cot)

    def loss_ref(x_, w_):
        return jnp.sum(ref.ell_spmm_reference(x_, nbr, w_) * cot)

    gx, gw = jax.grad(loss_kernel, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_r),
                               rtol=1e-5, atol=1e-5)


def test_ell_aggregate_gradient_under_vmap():
    """The runtime vmaps ell_aggregate over partitions — gradients must
    batch identically to the per-slice VJP."""
    q, n, k, f = 3, 32, 4, 64
    # common reverse width so the per-partition lists stack (as ell_arrays
    # pads in the runtime); n*k bounds any source's reverse degree
    packs = [_random_ell(n, n, k, seed=i, rev_k=n * k // 2) for i in range(q)]
    nbr = jnp.stack([p[0] for p in packs])
    w = jnp.stack([p[1] for p in packs])
    rnbr = jnp.stack([p[2] for p in packs])
    rslot = jnp.stack([p[3] for p in packs])
    x = jnp.asarray(RNG.normal(0, 1, (q, n, f)), jnp.float32)

    def loss_v(x_):
        out = jax.vmap(ops.ell_aggregate)(x_, nbr, w, rnbr, rslot)
        return jnp.sum(out ** 2)

    g_v = jax.grad(loss_v)(x)
    for p in range(q):
        def loss_1(x_):
            return jnp.sum(ops.ell_aggregate(x_, nbr[p], w[p], rnbr[p],
                                             rslot[p]) ** 2)
        g_1 = jax.grad(loss_1)(x[p])
        np.testing.assert_allclose(np.asarray(g_v[p]), np.asarray(g_1),
                                   rtol=1e-6, atol=1e-6)


def test_ell_aggregate_transpose_is_exact():
    """The VJP's x-cotangent is the reversed-list SpMM: applying forward
    then transpose equals the dense operator A^T A x."""
    nbr, w, rnbr, rslot = _random_ell(24, 18, 5, seed=11)
    n_src, f = 18, 32
    x = jnp.asarray(RNG.normal(0, 1, (n_src, f)), jnp.float32)
    y, vjp = jax.vjp(lambda x_: ops.ell_aggregate(x_, nbr, w, rnbr, rslot), x)
    (xt,) = vjp(y)
    a = np.zeros((24, n_src), np.float32)
    for i in range(24):
        for kk in range(5):
            a[i, int(nbr[i, kk])] += float(w[i, kk])
    np.testing.assert_allclose(np.asarray(xt), a.T @ a @ np.asarray(x),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ssd chunked scan vs sequential oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,chunk", [(64, 16), (128, 32), (96, 96)])
@pytest.mark.parametrize("g", [1, 2])
def test_ssd_chunked_matches_sequential(t, chunk, g):
    from repro.models.mamba2 import ssd_chunked
    b, h, p, n = 2, 4, 16, 8
    x = jnp.asarray(RNG.normal(0, 1, (b, t, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, t, h)), jnp.float32)
    a_log = jnp.asarray(RNG.uniform(-1, 1, (h,)), jnp.float32)
    bb = jnp.asarray(RNG.normal(0, 1, (b, t, g, n)), jnp.float32)
    cc = jnp.asarray(RNG.normal(0, 1, (b, t, g, n)), jnp.float32)
    d = jnp.asarray(RNG.normal(0, 1, (h,)), jnp.float32)
    y1, _ = ssd_chunked(x, dt, a_log, bb, cc, d, chunk=chunk)
    y2 = ref.ssd_reference(x, dt, a_log, bb, cc, d)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# ssd_chunk Pallas kernel (intra-chunk quadratic form) vs jnp oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q,h,p,n", [(64, 4, 32, 16), (128, 2, 64, 128),
                                     (32, 8, 16, 32)])
def test_ssd_chunk_kernel_matches_oracle(q, h, p, n):
    from repro.kernels.ssd_chunk import ssd_chunk
    b_, nc = 2, 3
    x = jnp.asarray(RNG.normal(0, 1, (b_, nc, q, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b_, nc, q, h)), jnp.float32)
    a = -jnp.exp(jnp.asarray(RNG.uniform(-1, 1, (h,)), jnp.float32))
    cum = jnp.cumsum(dt * a, axis=2)
    bb = jnp.asarray(RNG.normal(0, 1, (b_, nc, q, h, n)), jnp.float32)
    cc = jnp.asarray(RNG.normal(0, 1, (b_, nc, q, h, n)), jnp.float32)
    y, s = ssd_chunk(x, dt, cum, bb, cc, interpret=True)

    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    qi = np.arange(q)
    causal = jnp.asarray(qi[:, None] >= qi[None, :])
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bnqhk,bnshk->bnqsh", cc, bb)
    m = scores * decay * dt[:, :, None, :, :]
    y_ref = jnp.einsum("bnqsh,bnshp->bnqhp", m, x)
    d2e = jnp.exp(cum[:, :, -1:, :] - cum)
    s_ref = jnp.einsum("bnqh,bnqhk,bnqhp->bnhpk", d2e * dt, bb, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=2e-5,
                               atol=2e-5)
