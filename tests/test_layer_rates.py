"""Per-layer ``[L, Q, Q]`` rate tensors + pipelined prefetch (DESIGN.md §3.7).

The tentpole invariants of ISSUE 5:

* a ``[L, Q, Q]`` tensor with identical layer rows is the ``[Q, Q]``
  pair map bit-for-bit — and the per-layer transport/error/delta ledger
  summed over ``L`` reproduces the aggregate ledger exactly (the
  conservation satellite);
* each layer's exchange realises its OWN rate row (mixed tensors);
* the pipelined (start/complete) forward is bitwise the fused forward;
* the per-layer controllers water-fill the step allowance across layers,
  stay monotone per layer (Prop. 2), reduce to the scalar plan at
  ``L = 1``, and still land the bit budget;
* ``CommPolicy`` grows the ``auto:<ctl>:<bits>:per-layer`` spelling and
  ``History`` the per-layer transport columns.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from parity import build_setup, mixed_map

from repro.core import CommPolicy, fixed
from repro.dist.gnn_parallel import (DistMeta, _make_aggregate_emulated,
                                     _packed_pair_k_for, _rate_tensor_layers)
from repro.dist.ratectl import (budget_controller, error_controller,
                                exchange_widths, layer_exchange_widths,
                                make_controller, make_pacing,
                                stale_controller, uniform_layer_plan)
from repro.nn import GNNConfig
from repro.nn.gnn import gnn_forward

Q, F, L, T = 4, 512, 2, 40


@pytest.fixture(scope="module")
def setup():
    _, cfg, params, pg, graph = build_setup(Q, f=F, layers=L, n=256)
    return cfg, params, pg, graph


def _agg(graph, meta, rm, key, pol=None):
    pol = pol or fixed(4.0, compressor="blockmask")
    kb = dict(_packed_pair_k_for(meta, rm))
    return _make_aggregate_emulated(graph, meta, pol, None,
                                    jnp.ones((), jnp.float32), key,
                                    packed_k=kb, rate_map=jnp.asarray(rm))


# ---------------------------------------------------------------------------
# data plane: uniform-layer conservation + mixed-layer realisation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", ["packed", "p2p"])
def test_layer_ledger_conserves_pair_ledger(setup, wire):
    """Satellite: at uniform layer rates the per-layer ``[L, Q, Q]``
    transport (and error/delta) ledger summed over ``L`` reproduces the
    old aggregate per-pair ledger bit-for-bit, and the delivered values
    are identical."""
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire=wire)
    rm2 = mixed_map(Q, seed=3)
    rm3 = np.broadcast_to(rm2, (L, Q, Q)).copy()
    key = jax.random.key(11)
    l2, b2 = gnn_forward(params, cfg, graph["features"],
                         _agg(graph, meta, rm2, key))
    l3, b3 = gnn_forward(params, cfg, graph["features"],
                         _agg(graph, meta, rm3, key))
    assert b2.shape == (2 + 3 * Q * Q,)
    assert b3.shape == (2 + 3 * L * Q * Q,)
    assert float(jnp.abs(l2 - l3).max()) == 0.0
    np.testing.assert_array_equal(np.asarray(b2[:2]), np.asarray(b3[:2]))
    q2 = Q * Q
    for blk in range(3):                 # transport, err, delta blocks
        agg_ = np.asarray(b2[2 + blk * q2:2 + (blk + 1) * q2])
        per_layer = np.asarray(
            b3[2 + blk * L * q2:2 + (blk + 1) * L * q2]).reshape(L, q2)
        np.testing.assert_array_equal(agg_, per_layer.sum(0))


def test_mixed_layer_rows_realise_each_layers_rate(setup):
    """Layer ``l``'s exchange under ``[A, B]`` equals the same call
    sequence under the all-``A`` (resp. all-``B``) pair map — each
    exchange reads exactly its own row of the tensor."""
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="p2p")
    a = mixed_map(Q, seed=1)
    b = mixed_map(Q, seed=2)
    key = jax.random.key(5)
    agg_ab = _agg(graph, meta, np.stack([a, b]), key)
    agg_a = _agg(graph, meta, a, key)
    agg_b = _agg(graph, meta, b, key)
    x0 = graph["features"]
    o_ab0, _ = agg_ab(0, x0)
    o_a0, _ = agg_a(0, x0)
    np.testing.assert_array_equal(np.asarray(o_ab0), np.asarray(o_a0))
    x1 = jnp.tanh(o_ab0)
    o_ab1, _ = agg_ab(1, x1)
    agg_b(0, x0)                         # burn call 0 → same key stream
    o_b1, _ = agg_b(1, x1)
    np.testing.assert_array_equal(np.asarray(o_ab1), np.asarray(o_b1))


def test_single_layer_tensor_degenerates_to_pair_map():
    """Regression: a ``[1, Q, Q]`` tensor (1-layer model under a
    per-layer controller) must run and equal the ``[Q, Q]`` pair path —
    row selection keys on the operand's rank, not on ``L == 1``."""
    _, cfg1, params1, pg1, graph1 = build_setup(Q, f=F, layers=1, n=256)
    meta1 = DistMeta.build(pg1, params1, wire="p2p")
    rm2 = mixed_map(Q, seed=6)
    key = jax.random.key(3)
    l2, b2 = gnn_forward(params1, cfg1, graph1["features"],
                         _agg(graph1, meta1, rm2, key))
    l3, b3 = gnn_forward(params1, cfg1, graph1["features"],
                         _agg(graph1, meta1, rm2[None], key))
    np.testing.assert_array_equal(np.asarray(l2), np.asarray(l3))
    np.testing.assert_array_equal(np.asarray(b2), np.asarray(b3))
    # and end-to-end: per-layer policy on a 1-layer model trains, still
    # records the [1, Q, Q] History columns, and still feeds the
    # controller its layer_err (regression: metrics keyed on plan rank)
    from repro.graph import tiny_graph
    from repro.train.trainer import train_gnn
    res = train_gnn(tiny_graph(n=96, feat_dim=256), q=2,
                    policy=CommPolicy.parse("auto:budget:3e7:per-layer", 3),
                    epochs=3, hidden=256, layers=1, eval_every=3,
                    wire="p2p")
    assert res.history.total_transport_gfloats > 0.0
    assert res.history.layer_transport_gf
    lt = np.asarray(res.history.layer_transport_gf[-1]).reshape(1, 2, 2)
    np.testing.assert_allclose(
        lt.sum(0), np.asarray(res.history.pair_transport_gf[-1]).reshape(
            2, 2), rtol=1e-6)


def test_rate_tensor_layer_count_must_match(setup):
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="p2p")
    with pytest.raises(ValueError, match="layer rows"):
        _agg(graph, meta, mixed_map(Q, seed=0, layers=3), jax.random.key(0))
    with pytest.raises(ValueError, match="ndim"):
        _rate_tensor_layers(meta, jnp.ones((2, 2, Q, Q)))


# ---------------------------------------------------------------------------
# pipelined prefetch ≡ fused
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", ["packed", "p2p", "dense"])
def test_pipelined_forward_bitwise_equals_fused(setup, wire):
    """gnn_forward auto-detects the split-phase oracle; hiding the
    attributes forces the fused schedule — both must agree bit-for-bit
    (the phases are one code path by construction)."""
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire=wire)
    pol = fixed(4.0, compressor="blockmask")
    rm = None if wire == "dense" else mixed_map(Q, seed=7, layers=L)
    key = jax.random.key(9)

    def make(hide):
        if rm is None:
            comp = pol.compressor()
            agg = _make_aggregate_emulated(graph, meta, pol, comp,
                                           jnp.asarray(4.0), key)
        else:
            agg = _agg(graph, meta, rm, key)
        if hide:
            return lambda li, x: agg(li, x)      # no .start/.complete
        assert hasattr(agg, "start") and hasattr(agg, "complete")
        return agg

    l_pipe, b_pipe = gnn_forward(params, cfg, graph["features"], make(False))
    l_fuse, b_fuse = gnn_forward(params, cfg, graph["features"], make(True))
    np.testing.assert_array_equal(np.asarray(l_pipe), np.asarray(l_fuse))
    np.testing.assert_array_equal(np.asarray(b_pipe), np.asarray(b_fuse))


def test_pipelined_forward_poly_conv(setup):
    """The poly conv's chained taps run through the split phases too."""
    _, _, pg, graph = setup
    from repro.nn import init_gnn
    g_cfg = GNNConfig(conv="poly", in_dim=F, hidden=F, out_dim=4,
                      layers=2, k_taps=3)
    params = init_gnn(jax.random.key(1), g_cfg)
    meta = DistMeta.build(pg, params, wire="p2p")
    pol = fixed(2.0, compressor="blockmask")
    rm = mixed_map(Q, seed=4, layers=2)
    agg = _agg(graph, meta, rm, jax.random.key(2), pol=pol)
    hidden = lambda li, x: agg(li, x)
    agg2 = _agg(graph, meta, rm, jax.random.key(2), pol=pol)
    l_pipe, b_pipe = gnn_forward(params, g_cfg, graph["features"], agg2)
    l_fuse, b_fuse = gnn_forward(params, g_cfg, graph["features"], hidden)
    np.testing.assert_array_equal(np.asarray(l_pipe), np.asarray(l_fuse))
    np.testing.assert_array_equal(np.asarray(b_pipe), np.asarray(b_fuse))


# ---------------------------------------------------------------------------
# per-layer controllers
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def meta_cfg(setup):
    cfg, params, pg, _ = setup
    return DistMeta.build(pg, params, wire="p2p"), cfg


def _sim_per_layer(ctl, meta_, cfg, steps, budget):
    """Drive a per-layer controller against the quantised transport
    model; returns (spent, per-layer rate history [steps, L])."""
    rows = meta_.pair_table().astype(np.float64)
    nb = F // 128
    widths = layer_exchange_widths(cfg)
    state = ctl.init()
    spent = 0.0
    hist = []
    for t in range(steps):
        plan, state = ctl.plan(state, t)
        r = np.asarray(plan.rates, np.float64)
        assert r.shape == (L, Q, Q)
        for sl in r:
            assert (np.diag(sl) == 1.0).all()
        hist.append([float(sl[~np.eye(Q, dtype=bool)].mean()) for sl in r])
        k = np.clip(np.floor(nb / np.maximum(r, 1.0)), 1, nb)
        bits = 0.0
        err = np.zeros((L, Q, Q))
        for l, w in enumerate(widths):
            kl = k[l].copy()
            np.fill_diagonal(kl, 0.0)
            bits += 2.0 * 32.0 * (w / F) * float((rows * kl * 128).sum())
            err[l] = rows * (1.0 - k[l] / nb) * (l + 1.0)
        spent += bits
        state = ctl.observe(state, {
            "transport_bits": jnp.asarray(bits, jnp.float32),
            "pair_err": jnp.asarray(err.sum(0), jnp.float32),
            "layer_err": jnp.asarray(err, jnp.float32),
            "pair_delta": jnp.ones((Q, Q), jnp.float32)})
    return spent, np.asarray(hist)


@pytest.mark.parametrize("factory", ["budget", "error", "stale"])
def test_per_layer_controllers_monotone_and_budgeted(meta_cfg, factory):
    meta_, cfg = meta_cfg
    budget = 0.5 * 2.0 * 32.0 * meta_.halo_demand * \
        sum(exchange_widths(cfg)) * T
    pacing = make_pacing(meta_, exchange_widths(cfg), T, budget,
                         layer_widths=layer_exchange_widths(cfg))
    if factory == "budget":
        ctl = budget_controller(Q, pacing, per_layer=True)
    elif factory == "error":
        ctl = error_controller(Q, pacing, meta_.pair_table(),
                               per_layer=True)
    else:
        ctl = stale_controller(Q, pacing, per_layer=True, threshold=0.0)
    spent, hist = _sim_per_layer(ctl, meta_, cfg, T, budget)
    # monotone non-increasing mean rate per layer (Prop. 2 per layer)
    for l in range(L):
        assert (np.diff(hist[:, l]) <= 1e-5).all(), hist[:, l]
    assert abs(spent - budget) / budget <= 0.05, (spent, budget)


def test_per_layer_budget_reduces_to_scalar_at_single_layer(setup):
    """L = 1: the per-layer fill telescopes to the scalar budget plan
    (same uniform rate every step under on-model feedback)."""
    cfg, params, pg, _ = setup
    meta_ = DistMeta.build(pg, params, wire="p2p")
    cfg1 = dataclasses.replace(cfg, layers=1)
    budget = 0.4 * 2.0 * 32.0 * meta_.halo_demand * \
        sum(exchange_widths(cfg1)) * T
    pacing_s = make_pacing(meta_, exchange_widths(cfg1), T, budget)
    pacing_l = make_pacing(meta_, exchange_widths(cfg1), T, budget,
                           layer_widths=layer_exchange_widths(cfg1))
    ctl_s = budget_controller(Q, pacing_s)
    ctl_l = budget_controller(Q, pacing_l, per_layer=True)
    st_s, st_l = ctl_s.init(), ctl_l.init()
    off = ~np.eye(Q, dtype=bool)
    for t in range(T):
        plan_s, st_s = ctl_s.plan(st_s, t)
        plan_l, st_l = ctl_l.plan(st_l, t)
        r_s = float(np.asarray(plan_s.rates)[off].mean())
        r_l = float(np.asarray(plan_l.rates)[0][off].mean())
        np.testing.assert_allclose(r_l, r_s, rtol=1e-4)
        shipped = pacing_s.d_full / r_s
        obs = {"transport_bits": jnp.asarray(shipped, jnp.float32),
               "pair_err": jnp.zeros((Q, Q)),
               "layer_err": jnp.zeros((1, Q, Q)),
               "pair_delta": jnp.zeros((Q, Q))}
        st_s = ctl_s.observe(st_s, obs)
        st_l = ctl_l.observe(st_l, obs)


def test_per_layer_fill_prefers_lossier_layer(meta_cfg):
    """Given a persistent energy imbalance, the fill keeps more blocks
    (lower rate) on the lossier layer."""
    meta_, cfg = meta_cfg
    budget = 0.4 * 2.0 * 32.0 * meta_.halo_demand * \
        sum(exchange_widths(cfg)) * T
    pacing = make_pacing(meta_, exchange_widths(cfg), T, budget,
                         layer_widths=layer_exchange_widths(cfg))
    ctl = budget_controller(Q, pacing, per_layer=True, ema_decay=0.0)
    state = ctl.init()
    err = jnp.stack([jnp.ones((Q, Q)), 50.0 * jnp.ones((Q, Q))])
    for t in range(10):
        plan, state = ctl.plan(state, t)
        state = ctl.observe(state, {
            "transport_bits": jnp.asarray(pacing.d_full / 64.0),
            "pair_err": err.sum(0), "layer_err": err,
            "pair_delta": jnp.zeros((Q, Q))})
    off = ~np.eye(Q, dtype=bool)
    r = np.asarray(plan.rates)
    assert r[1][off].mean() < r[0][off].mean(), r


def test_uniform_layer_plan_shape():
    p = uniform_layer_plan(3, jnp.asarray([2.0, 8.0]))
    assert p.rates.shape == (2, 3, 3)
    assert p.skip.shape == (3, 3)
    for sl in np.asarray(p.rates):
        assert (np.diag(sl) == 1.0).all()
    assert (np.asarray(p.rates)[1][~np.eye(3, dtype=bool)] == 8.0).all()


def test_per_layer_needs_layer_bits(meta_cfg):
    meta_, cfg = meta_cfg
    pacing = make_pacing(meta_, exchange_widths(cfg), T, 1e9)
    for factory in (lambda: budget_controller(Q, pacing, per_layer=True),
                    lambda: error_controller(Q, pacing, meta_.pair_table(),
                                             per_layer=True),
                    lambda: stale_controller(Q, pacing, per_layer=True)):
        with pytest.raises(ValueError, match="layer_bits"):
            factory()
    with pytest.raises(ValueError, match="sum"):
        make_pacing(meta_, exchange_widths(cfg), T, 1e9,
                    layer_widths=(1, 2))


# ---------------------------------------------------------------------------
# policy spelling + trainer integration
# ---------------------------------------------------------------------------


def test_commpolicy_per_layer_parse_and_describe():
    p = CommPolicy.parse("auto:error:3e9:per-layer", T)
    assert p.per_layer and p.controller == "error"
    assert p.budget_bits == 3e9
    assert "per-layer" in p.describe()
    assert not CommPolicy.parse("auto:error:3e9", T).per_layer
    with pytest.raises(ValueError, match="per-layer"):
        CommPolicy.parse("auto:error:3e9:sideways", T)
    with pytest.raises(ValueError, match="per-layer"):
        CommPolicy.parse("auto:error:3e9:", T)    # truncated suffix
    with pytest.raises(ValueError, match="closed-loop"):
        CommPolicy(mode="full", per_layer=True)


def test_make_controller_per_layer_dispatch(meta_cfg):
    meta_, cfg = meta_cfg
    for name in ("budget", "error", "stale"):
        pol = CommPolicy.parse(f"auto:{name}:1e9:per-layer", T)
        ctl = make_controller(pol, meta_, cfg, T)
        plan, _ = ctl.plan(ctl.init(), 0)
        assert np.asarray(plan.rates).shape == (L, Q, Q), name
        # ema_decay reaches every per-layer controller...
        make_controller(pol, meta_, cfg, T, ema_decay=0.5)
    # ...but is rejected where no EMA exists (scalar budget/stale) —
    # misdirected knobs must fail loudly, not silently no-op
    for name in ("budget", "stale"):
        with pytest.raises(ValueError, match="ema_decay"):
            make_controller(CommPolicy.parse(f"auto:{name}:1e9", T),
                            meta_, cfg, T, ema_decay=0.5)
    make_controller(CommPolicy.parse("auto:error:1e9", T), meta_, cfg, T,
                    ema_decay=0.5)    # scalar error keeps its EMA knob


def test_train_gnn_per_layer_history_columns():
    from repro.graph import tiny_graph
    from repro.train.trainer import train_gnn

    g = tiny_graph(n=128, feat_dim=256)
    budget = 5e7
    res = train_gnn(g, q=2, policy=CommPolicy.parse(
        f"auto:budget:{budget:g}:per-layer", 4), epochs=4, hidden=256,
        layers=2, eval_every=2, wire="p2p")
    h = res.history
    assert h.layer_transport_gf and h.pair_transport_gf and h.comp_err
    lt = np.asarray(h.layer_transport_gf[-1]).reshape(2, 2, 2)
    pt = np.asarray(h.pair_transport_gf[-1]).reshape(2, 2)
    np.testing.assert_allclose(lt.sum(0), pt, rtol=1e-6)
    row = h.row(len(h.epoch) - 1)
    assert "layer_transport_gf" in row and "comp_err" in row
