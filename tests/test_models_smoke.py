"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
shape + finiteness assertions (deliverable f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.shapes import SHAPES, batch_specs, long_context_variant
from repro.launch.steps import make_optimizer, make_train_step
from repro.models.transformer import (decode_step, forward_train, init_cache,
                                      init_lm, lm_loss, prefill, _lm_head)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = init_lm(jax.random.key(0), cfg)
    batch = batch_specs(cfg, SHAPES["train_4k"], concrete=True, batch=2,
                        seq=64)
    # forward shapes
    h, aux = forward_train(params, cfg, batch)
    assert h.shape == (2, 64, cfg.d_model)
    logits = _lm_head(params, cfg, h)
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # one train step
    opt = make_optimizer(cfg, lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    params2, _, m = step(params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    deltas = [float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree.leaves(params), jax.tree.leaves(params2))]
    assert max(deltas) > 0


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen3-32b", "gemma-7b",
                                  "mamba2-130m", "musicgen-large", "yi-6b"])
def test_smoke_decode_consistency(arch):
    """prefill+decode logits == full-forward logits at the same position."""
    cfg = get_config(arch, smoke=True)
    params = init_lm(jax.random.key(1), cfg)
    b, s = 2, 16
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (b, s + 1)), jnp.int32)
    h, _ = forward_train(params, cfg, {"tokens": toks})
    ref = _lm_head(params, cfg, h)[:, s]
    _, cache = prefill(params, cfg, {"tokens": toks[:, :s]}, max_len=s + 8)
    got, cache2 = decode_step(params, cfg, {"tokens": toks[:, s:s + 1]},
                              cache)
    rel = float(jnp.abs(got - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 1e-3, rel
    assert int(cache2.index) == s + 1


def test_smoke_moe_decode_consistency_with_headroom():
    """MoE archs match once expert capacity can't differ between runs."""
    base = get_config("qwen2-moe-a2.7b", smoke=True)
    cfg = base.with_(moe=dataclasses.replace(base.moe, capacity_factor=8.0))
    params = init_lm(jax.random.key(1), cfg)
    b, s = 2, 12
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (b, s + 1)), jnp.int32)
    h, _ = forward_train(params, cfg, {"tokens": toks})
    ref = _lm_head(params, cfg, h)[:, s]
    _, cache = prefill(params, cfg, {"tokens": toks[:, :s]}, max_len=s + 4)
    got, _ = decode_step(params, cfg, {"tokens": toks[:, s:s + 1]}, cache)
    rel = float(jnp.abs(got - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 1e-3, rel


def test_sliding_window_variant_bounds_cache():
    cfg = long_context_variant(get_config("yi-6b", smoke=True), window=8)
    assert cfg.sliding_window == 8
    cache = init_cache(cfg, batch=2, max_len=1024)
    for pi, kind in enumerate(cfg.pattern):
        if kind == "attn":
            assert cache.layers[pi].k.shape[2] == 8   # ring window, not 1024


def test_swa_ring_decode_matches_full_attention_inside_window():
    """With window >= total length, SWA decode == full-cache decode."""
    base = get_config("granite-3-2b", smoke=True)
    swa = base.with_(sliding_window=32)
    params = init_lm(jax.random.key(2), base)
    b, s = 1, 8
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, base.vocab_size, (b, s + 2)), jnp.int32)
    _, c_full = prefill(params, base, {"tokens": toks[:, :s]}, max_len=32)
    _, c_swa = prefill(params, swa, {"tokens": toks[:, :s]}, max_len=32)
    g1, c_full = decode_step(params, base, {"tokens": toks[:, s:s + 1]},
                             c_full)
    g2, c_swa = decode_step(params, swa, {"tokens": toks[:, s:s + 1]}, c_swa)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4,
                               atol=1e-5)


def test_mrope_reduces_to_rope_for_text():
    """qwen2-vl M-RoPE with t==h==w positions equals standard RoPE."""
    from repro.models.layers import apply_mrope, apply_rope
    cfg = get_config("qwen2-vl-2b", smoke=True)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 8, 4, 32)),
                    jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
    p3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    a = apply_rope(x, pos, cfg.rope_theta)
    b = apply_mrope(x, p3, cfg.rope_theta, cfg.mrope_sections)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_sane(arch):
    cfg = get_config(arch)
    counts = cfg.param_counts()
    assert counts["total"] > 0 and counts["active"] <= counts["total"]
    # headline sizes within 2x of the model names where stated
    expected = {"jamba-1.5-large-398b": 398e9, "mamba2-130m": 130e6,
                "gemma-7b": 8.5e9, "yi-6b": 6e9,
                "llama4-maverick-400b-a17b": 400e9}
    if arch in expected:
        assert 0.5 < counts["total"] / expected[arch] < 2.0, counts
