"""Multi-device semantics via subprocess (8 virtual CPU devices).

The main test process must keep the single real device (smoke tests &
benches), so anything needing a mesh runs in a fresh interpreter with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, timeout: int = 600) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


SHARD_MAP_EQUIV = """
import jax, jax.numpy as jnp
from repro.graph import tiny_graph, partition_graph
from repro.nn import GNNConfig, init_gnn
from repro.dist.gnn_parallel import (DistMeta, make_train_step,
                                     make_worker_mesh, shard_graph,
                                     make_eval_step)
from repro.core import varco
from repro.train.optim import adamw

g = tiny_graph(n=256)
cfg = GNNConfig(conv='sage', in_dim=g.feat_dim, hidden=32,
                out_dim=g.num_classes, layers=3)
params = init_gnn(jax.random.key(0), cfg)
pg = partition_graph(g, 8, scheme='random')
graph = pg.device_arrays()
meta = DistMeta.build(pg, params)
opt = adamw(1e-2); opt_state = opt.init(params)
pol = varco(total_steps=20, slope=5)

p_e, s_e = params, opt_state
step_e = make_train_step(cfg, pol, opt, meta)
for i in range(6):
    p_e, s_e, m_e = step_e(p_e, s_e, graph, jnp.asarray(i), jax.random.key(i))

mesh = make_worker_mesh(8)
gs = shard_graph(graph, mesh)
step_s = make_train_step(cfg, pol, opt, meta, mesh=mesh)
p_s, s_s = params, opt_state
for i in range(6):
    p_s, s_s, m_s = step_s(p_s, s_s, gs, jnp.asarray(i), jax.random.key(i))

d = max(float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(p_e), jax.tree.leaves(p_s)))
assert d < 1e-5, d
assert abs(float(m_e['loss']) - float(m_s['loss'])) < 1e-5
ev = make_eval_step(cfg, meta, mesh=mesh)(p_s, gs)
assert 0 <= float(ev['test']) <= 1
print('SHARD_MAP_OK', d)
"""


FEDAVG_MODE = """
import jax, jax.numpy as jnp
from repro.graph import tiny_graph, partition_graph
from repro.nn import GNNConfig, init_gnn
from repro.dist.gnn_parallel import (DistMeta, make_train_step,
                                     make_worker_mesh, shard_graph)
from repro.core import FULL_COMM
from repro.train.optim import sgd

g = tiny_graph(n=256)
cfg = GNNConfig(conv='sage', in_dim=g.feat_dim, hidden=16,
                out_dim=g.num_classes, layers=2)
params = init_gnn(jax.random.key(0), cfg)
pg = partition_graph(g, 4, scheme='random')
graph = pg.device_arrays()
meta = DistMeta.build(pg, params)
opt = sgd(1e-2)

mesh = make_worker_mesh(4)
gs = shard_graph(graph, mesh)
# with plain SGD, fedavg (avg of local steps) == grad-psum (avg gradient)
pa, sa = params, opt.init(params)
step_a = make_train_step(cfg, FULL_COMM, opt, meta, mesh=mesh, sync='grad')
pb, sb = params, opt.init(params)
step_b = make_train_step(cfg, FULL_COMM, opt, meta, mesh=mesh, sync='fedavg')
for i in range(3):
    pa, sa, _ = step_a(pa, sa, gs, jnp.asarray(i), jax.random.key(i))
    pb, sb, _ = step_b(pb, sb, gs, jnp.asarray(i), jax.random.key(i))
# grad mode sums grads (then opt applies lr once); fedavg averages local
# SGD steps — identical iff update is linear in grad and grads are summed
# with the same normalisation. Our local loss divides by GLOBAL train count,
# so psum(grad) == sum of local grads == full gradient, while fedavg's
# parameter mean applies lr to each local grad then averages:
#   mean_q(p - lr g_q) = p - lr mean_q(g_q) = p - lr/Q * full_grad.
# So fedavg == grad mode with lr/Q. Verify that relationship instead.
import numpy as np
da = jax.tree.map(lambda a, b: np.asarray(a - b), pa, params)
db = jax.tree.map(lambda a, b: np.asarray(a - b), pb, params)
la = jax.tree.leaves(da); lb = jax.tree.leaves(db)
# after 1 step relationship is exact; after 3 it's approximate — test 1 step
pa1, _, _ = step_a(params, opt.init(params), gs, jnp.asarray(0),
                   jax.random.key(0))
pb1, _, _ = step_b(params, opt.init(params), gs, jnp.asarray(0),
                   jax.random.key(0))
for a, b in zip(jax.tree.leaves(jax.tree.map(lambda x, y: x - y, pa1, params)),
                jax.tree.leaves(jax.tree.map(lambda x, y: x - y, pb1, params))):
    a = np.asarray(a); b = np.asarray(b)
    scale = np.abs(a).max() + 1e-12
    np.testing.assert_allclose(a / scale, 4.0 * b / scale,
                               rtol=0, atol=2e-3)
print('FEDAVG_OK')
"""


COLLECTIVES = """
import functools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core.collectives import (compressed_all_gather, compressed_psum,
                                    compressed_all_to_all, uncompressed_bits)
from repro.core.compression import get_compressor

mesh = Mesh(np.array(jax.devices()[:4]), ('w',))
c = get_compressor('randmask')
x = jax.random.normal(jax.random.key(0), (4, 8, 16))

@functools.partial(shard_map, mesh=mesh, in_specs=P('w'), out_specs=(P('w'), P()),
                   check_rep=False)
def gather_rate1(xs):
    g, bits = compressed_all_gather(xs[0], 'w', compressor=c,
                                    rate=jnp.float32(1.0),
                                    key=jax.random.key(1))
    return g[None], bits

g, bits = gather_rate1(x)
np.testing.assert_allclose(np.asarray(g[0]), np.asarray(x), rtol=1e-6)
assert float(bits) == 3 * x.size / 4 * 32 * 4 / 4 * 4 / 4 or True
# exact: per-device bits = 8*16*32 ; psum -> 4x ; *(Q-1)=3
assert float(bits) == 4 * 8 * 16 * 32 * 3, float(bits)

@functools.partial(shard_map, mesh=mesh, in_specs=P('w'), out_specs=(P('w'), P()),
                   check_rep=False)
def psum_rate1(xs):
    s, bits = compressed_psum(xs[0], 'w', compressor=c,
                              rate=jnp.float32(1.0), key=jax.random.key(1))
    return s[None], bits

s, bits = psum_rate1(x)
np.testing.assert_allclose(np.asarray(s[0]), np.asarray(x.sum(0)), rtol=1e-5)

@functools.partial(shard_map, mesh=mesh, in_specs=P('w'), out_specs=(P('w'), P()),
                   check_rep=False)
def a2a_rate1(xs):
    o, bits = compressed_all_to_all(xs[0], 'w', compressor=c,
                                    rate=jnp.float32(1.0),
                                    key=jax.random.key(1))
    return o[None], bits

xa = jax.random.normal(jax.random.key(2), (4, 4, 16))
o, _ = a2a_rate1(xa)
np.testing.assert_allclose(np.asarray(o), np.asarray(xa.transpose(1, 0, 2)),
                           rtol=1e-6)
print('COLLECTIVES_OK')
"""


SMALL_DRYRUN = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.dist.sharding import activation_sharding, param_shardings
from repro.launch.mesh import make_small_mesh
from repro.launch.steps import make_train_step, make_optimizer
from repro.models.transformer import init_lm

cfg = get_config('granite-3-2b', smoke=True)
mesh = make_small_mesh(2, 4)
params_s = jax.eval_shape(lambda: init_lm(jax.random.key(0), cfg))
p_sh = param_shardings(params_s, mesh)
opt = make_optimizer(cfg)
opt_s = jax.eval_shape(opt.init, params_s)
o_sh = param_shardings(opt_s, mesh)
batch = {'tokens': jax.ShapeDtypeStruct((8, 128), jnp.int32,
         sharding=NamedSharding(mesh, P('data')))}
step = make_train_step(cfg, opt)

def wrapped(p, o, b):
    with activation_sharding(mesh):
        return step(p, o, b)

params_in = jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                         sharding=sh), params_s, p_sh)
opt_in = jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                      sharding=sh), opt_s, o_sh)
fn = jax.jit(wrapped, in_shardings=(p_sh, o_sh, None),
             out_shardings=(p_sh, o_sh, None))
compiled = fn.lower(params_in, opt_in, batch).compile()
mem = compiled.memory_analysis()
assert mem is not None
print('SMALL_DRYRUN_OK')
"""


@pytest.mark.slow
def test_shard_map_matches_emulated():
    assert "SHARD_MAP_OK" in run_py(SHARD_MAP_EQUIV)


@pytest.mark.slow
def test_fedavg_mode_relationship():
    assert "FEDAVG_OK" in run_py(FEDAVG_MODE)


@pytest.mark.slow
def test_compressed_collectives_rate1_exact():
    assert "COLLECTIVES_OK" in run_py(COLLECTIVES)


@pytest.mark.slow
def test_small_mesh_dryrun_compiles():
    assert "SMALL_DRYRUN_OK" in run_py(SMALL_DRYRUN)
