"""Optimizer math + checkpoint round trips."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint
from repro.train.optim import (adamw, apply_updates, clip_by_global_norm,
                               cosine_lr, linear_decay_lr, sgd)


def test_sgd_momentum_math():
    opt = sgd(0.1, momentum=0.9)
    p = {"w": jnp.ones((3,))}
    s = opt.init(p)
    g = {"w": jnp.full((3,), 2.0)}
    u1, s = opt.update(g, s, p)
    np.testing.assert_allclose(np.asarray(u1["w"]), -0.1 * 2.0)
    u2, s = opt.update(g, s, p)
    np.testing.assert_allclose(np.asarray(u2["w"]), -0.1 * (0.9 * 2 + 2))


def test_adamw_first_step_is_lr_signed():
    opt = adamw(1e-2, weight_decay=0.0)
    p = {"w": jnp.zeros((4,))}
    s = opt.init(p)
    g = {"w": jnp.asarray([1.0, -2.0, 0.5, 0.0])}
    u, s = opt.update(g, s, p)
    # bias-corrected first step: -lr * g / (|g| + eps) = -lr * sign(g)
    np.testing.assert_allclose(np.asarray(u["w"])[:3],
                               [-1e-2, 1e-2, -1e-2], rtol=1e-4)
    assert float(u["w"][3]) == 0.0


def test_adamw_bf16_moments_close_to_f32():
    kf = jax.random.key(0)
    p = {"w": jax.random.normal(kf, (64,))}
    g = {"w": jax.random.normal(jax.random.key(1), (64,)) * 0.1}
    o32 = adamw(1e-3)
    obf = adamw(1e-3, moment_dtype=jnp.bfloat16)
    s32, sbf = o32.init(p), obf.init(p)
    p32, pbf = p, p
    for _ in range(10):
        u, s32 = o32.update(g, s32, p32)
        p32 = apply_updates(p32, u)
        u, sbf = obf.update(g, sbf, pbf)
        pbf = apply_updates(pbf, u)
    rel = float(jnp.abs(p32["w"] - pbf["w"]).max() /
                jnp.abs(p32["w"]).max())
    assert rel < 0.05, rel
    assert sbf["mu"]["w"].dtype == jnp.bfloat16


def test_adamw_converges_quadratic():
    opt = adamw(0.1)
    p = {"w": jnp.full((8,), 5.0)}
    s = opt.init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        u, s = opt.update(g, s, p)
        p = apply_updates(p, u)
    assert float(jnp.abs(p["w"]).max()) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-3


def test_lr_schedules():
    f = cosine_lr(1.0, 100, warmup=10)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-6
    assert float(f(100)) < 1e-3
    g = linear_decay_lr(2.0, 100, warmup=0)
    assert abs(float(g(50)) - 1.0) < 1e-5


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}
    path = os.path.join(tmp_path, "ck", "state.msgpack")
    checkpoint.save(path, tree, extra={"step": 7})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, extra = checkpoint.restore(path, like)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_rejects_mismatch(tmp_path):
    import pytest
    path = os.path.join(tmp_path, "s.msgpack")
    checkpoint.save(path, {"a": jnp.ones((2,))})
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"a": jnp.ones((3,))})
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"b": jnp.ones((2,))})


def test_checkpoint_mismatch_names_tree_path(tmp_path):
    """dtype AND shape validation report the offending leaf's tree path
    (ISSUE 8 satellite)."""
    import pytest
    tree = {"a": jnp.ones((2, 3), jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    path = os.path.join(tmp_path, "s.msgpack")
    checkpoint.save(path, tree)
    bad_dtype = {"a": tree["a"], "b": {"c": jnp.ones((4,), jnp.float32)}}
    with pytest.raises(ValueError, match=r"dtype mismatch at .*c.*bfloat16"):
        checkpoint.restore(path, bad_dtype)
    bad_shape = {"a": jnp.ones((3, 2), jnp.float32), "b": tree["b"]}
    with pytest.raises(ValueError, match=r"shape mismatch at .*a"):
        checkpoint.restore(path, bad_shape)


def test_checkpoint_save_is_atomic(tmp_path, monkeypatch):
    """A failed overwrite leaves the previous checkpoint intact and no
    temp droppings behind (tmp + fsync + rename)."""
    import pytest
    path = os.path.join(tmp_path, "s.msgpack")
    checkpoint.save(path, {"a": jnp.ones((2,))})

    def _boom(leaf):
        raise RuntimeError("mid-write failure")

    # encoder dies mid-write: the crash lands before the rename
    monkeypatch.setattr(checkpoint, "_encode_leaf", _boom)
    with pytest.raises(RuntimeError, match="mid-write"):
        checkpoint.save(path, {"a": jnp.zeros((2,))})
    monkeypatch.undo()
    restored, _ = checkpoint.restore(path, {"a": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(restored["a"]), [1.0, 1.0])
    leftovers = [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    assert leftovers == []


def test_train_state_api_roundtrip(tmp_path):
    import pytest
    tree = {"params": {"w": jnp.arange(4.0)},
            "opt": {"mu": jnp.zeros((4,))}}
    d = os.path.join(tmp_path, "ck")
    assert checkpoint.latest_checkpoint(d) is None
    p = checkpoint.save_train_state(d, tree, 17, extra={"q": 4})
    assert checkpoint.latest_checkpoint(d) == p
    assert checkpoint.peek(p)["step"] == 17
    like = jax.tree.map(jnp.zeros_like, tree)
    out, step, extra = checkpoint.restore_train_state(d, like)
    assert step == 17 and extra["q"] == 4
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.arange(4.0))
    with pytest.raises(FileNotFoundError):
        checkpoint.restore_train_state(os.path.join(tmp_path, "nope"), like)
