"""P2P wire runtime: parity with the all-gather wires + p2p ledger.

The p2p wire (DESIGN.md §3.5) must be a pure transport change relative to
the dense ``blockmask`` semantics: same per-exchange keys → same kept
sets → the same remote values delivered, with only the local-edge
summation order differing (ELL vs scatter).  These tests pin that at
every acceptance rate on the emulated backend, pin emulated ≡ shard_map
on the real ``ppermute`` ring, and pin the headline ledger identity:
``CommLedger.transport == analytic point-to-point charge`` whenever the
rate divides the lane-block count — strictly below the all-gather
collective volume.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from parity import build_setup, run_train_parity

from repro.core import FULL_COMM, fixed, varco
from repro.dist.gnn_parallel import (DistMeta, _make_aggregate_emulated,
                                     _packed_k_for, make_eval_step,
                                     make_train_step, make_worker_mesh)
from repro.dist.halo import attach_p2p
from repro.graph import partition_graph, tiny_graph
from repro.nn import GNNConfig, init_gnn
from repro.nn.gnn import gnn_forward
from repro.train.optim import adamw, sgd

RATES = [1.0, 2.0, 4.0, 16.0]
F = 256


@pytest.fixture(scope="module")
def setup():
    _, cfg, params, pg, graph = build_setup(4, f=F, layers=3, n=256,
                                            hidden=128)
    return cfg, params, pg, graph


def _metas(pg, params):
    return (DistMeta.build(pg, params),
            DistMeta.build(pg, params, wire="p2p"))


def _policy(rate):
    return FULL_COMM if rate == 1.0 else fixed(rate, compressor="blockmask")


# ---------------------------------------------------------------------------
# emulated runtime parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rate", RATES)
def test_p2p_forward_matches_dense_blockmask(setup, rate):
    """Same keys → same delivered remote values; only the local summation
    order differs, so logits agree to float tolerance at every rate."""
    cfg, params, pg, graph = setup
    meta_d, meta_r = _metas(pg, params)
    pol = _policy(rate)
    comp = pol.compressor() if pol.compresses else None
    agg_d = _make_aggregate_emulated(graph, meta_d, pol, comp,
                                     jnp.asarray(rate), jax.random.key(2))
    agg_r = _make_aggregate_emulated(graph, meta_r, pol, comp, rate,
                                     jax.random.key(2))
    ld, bd = gnn_forward(params, cfg, graph["features"], agg_d)
    lr, br = gnn_forward(params, cfg, graph["features"], agg_r)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lr),
                               rtol=0, atol=1e-5)
    # identical analytic charge; p2p transport never above dense
    np.testing.assert_allclose(float(bd[0]), float(br[0]), rtol=1e-6)
    assert float(br[1]) <= float(bd[1]) + 1e-6


@pytest.mark.parametrize("rate", RATES)
def test_p2p_backward_matches_dense_blockmask(setup, rate):
    cfg, params, pg, graph = setup
    meta_d, meta_r = _metas(pg, params)
    pol = _policy(rate)
    comp = pol.compressor() if pol.compresses else None

    def loss(p, meta, r):
        agg = _make_aggregate_emulated(graph, meta, pol, comp, r,
                                       jax.random.key(4))
        logits, _ = gnn_forward(p, cfg, graph["features"], agg)
        return jnp.sum(logits ** 2)

    gd = jax.grad(loss)(params, meta_d, jnp.asarray(rate))
    gr = jax.grad(loss)(params, meta_r, rate)
    for a, b in zip(jax.tree_util.tree_leaves(gd),
                    jax.tree_util.tree_leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_p2p_rate1_training_matches_dense_full_comm(setup):
    """Acceptance: p2p rate-1 training ≡ dense full comm.  Plain SGD keeps
    the comparison proportional to the gradient diff (adaptive optimizers
    amplify summation-order noise on near-zero gradients to ±lr)."""
    cfg, params, pg, graph = setup
    meta_d, meta_r = _metas(pg, params)
    opt = sgd(1e-2)
    outs = []
    for meta in (meta_d, meta_r):
        p, s = params, opt.init(params)
        step = make_train_step(cfg, FULL_COMM, opt, meta)
        for i in range(5):
            p, s, m = step(p, s, graph, jnp.asarray(i), jax.random.key(i))
        outs.append((p, float(m["loss"])))
    (pd, lossd), (pr, lossr) = outs
    assert abs(lossd - lossr) < 1e-5
    for a, b in zip(jax.tree_util.tree_leaves(pd),
                    jax.tree_util.tree_leaves(pr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   rtol=0)


def test_p2p_varco_schedule_trains(setup):
    """A VARCO blockmask policy runs on the p2p wire; the transport charge
    tracks the packed hop width at every annealed rate."""
    cfg, params, pg, graph = setup
    _, meta_r = _metas(pg, params)
    pol = varco(total_steps=8, slope=5, compressor="blockmask")
    opt = adamw(5e-3)
    step = make_train_step(cfg, pol, opt, meta_r)
    p, s = params, opt.init(params)
    losses = []
    for i in range(6):
        p, s, m = step(p, s, graph, jnp.asarray(i), jax.random.key(i))
        losses.append(float(m["loss"]))
        rate = float(m["rate"])
        widths = [meta_r.packed_width(f, rate)
                  for f in (cfg.in_dim, cfg.hidden, cfg.hidden)]
        expect = 2 * meta_r.halo_demand * 32.0 * sum(widths)
        np.testing.assert_allclose(float(m["transport_bits"]), expect,
                                   rtol=1e-6)
    assert losses[-1] < losses[0]
    accs = make_eval_step(cfg, meta_r)(p, graph)
    assert 0.0 <= float(accs["test"]) <= 1.0


def test_p2p_nocomm_policy(setup):
    """The No-Comm baseline ships nothing on the p2p wire too."""
    from repro.core import NO_COMM
    cfg, params, pg, graph = setup
    _, meta_r = _metas(pg, params)
    agg = _make_aggregate_emulated(graph, meta_r, NO_COMM, None,
                                   jnp.ones(()), jax.random.key(0))
    _, bits = agg(0, graph["features"])
    assert float(jnp.sum(jnp.abs(bits))) == 0.0


def test_train_gnn_p2p_wire_end_to_end():
    """The high-level trainer attaches the halo/ELL arrays itself — the
    public entry point must work without the caller knowing about
    attach_p2p (regression: KeyError 'p2p_send_slot')."""
    from repro.train.trainer import train_gnn
    g = tiny_graph(n=128)
    res = train_gnn(g, q=2, policy=FULL_COMM, epochs=3, hidden=32,
                    layers=2, eval_every=2, wire="p2p")
    assert res.meta.wire == "p2p"
    assert 0.0 <= res.history.final_test_acc <= 1.0
    assert res.history.total_transport_gfloats > 0.0


# ---------------------------------------------------------------------------
# ledger: transport == analytic point-to-point charge
# ---------------------------------------------------------------------------


def test_p2p_transport_equals_analytic_when_rate_divides():
    """Acceptance headline: on the p2p wire ``transport == halo_demand ×
    F/rate × 32`` — exactly — whenever the rate divides the lane-block
    count, end-to-end through a train step's metrics."""
    g = tiny_graph(n=200, feat_dim=512)
    cfg = GNNConfig(conv="sage", in_dim=512, hidden=512,
                    out_dim=g.num_classes, layers=2)
    params = init_gnn(jax.random.key(0), cfg)
    pg = partition_graph(g, 4, scheme="random")
    graph = attach_p2p(pg.device_arrays(), pg)
    meta = DistMeta.build(pg, params, wire="p2p")
    opt = sgd(1e-2)
    for rate in (1.0, 2.0, 4.0):                   # 512/128 = 4 blocks
        np.testing.assert_allclose(float(meta.transport_bits(512, rate)),
                                   float(meta.ledger_bits(512, rate)),
                                   rtol=1e-7)
        step = make_train_step(cfg, _policy(rate), opt, meta)
        _, _, m = step(params, opt.init(params), graph, jnp.asarray(0),
                       jax.random.key(0))
        np.testing.assert_allclose(float(m["transport_bits"]),
                                   float(m["halo_bits"]), rtol=1e-6)


def test_p2p_transport_strictly_below_allgather(setup):
    """The p2p ring beats the all-gather collective volume whenever the
    partition graph isn't complete-with-full-overlap (random partitions
    here): halo_demand rows vs Q·(Q-1)·B rows."""
    cfg, params, pg, graph = setup
    meta_p = DistMeta.build(pg, params, wire="packed")
    _, meta_r = _metas(pg, params)
    for f in (256, 512):
        for rate in RATES:
            p2p = float(meta_r.transport_bits(f, rate))
            ag = meta_p.collective_bits(f, rate)
            assert p2p < ag, (f, rate, p2p, ag)
            # padded ring volume also never exceeds the all-gather's
            assert meta_r.collective_bits(f, rate) <= ag


def test_p2p_transport_quantises_like_packed(setup):
    """At a non-dividing rate the hop width floors to whole lane-blocks —
    the same quantisation the packed wire documents."""
    cfg, params, pg, graph = setup
    _, meta_r = _metas(pg, params)
    # F=256 → 2 blocks; rate 16 floors to 1 kept block of 128 cols
    assert float(meta_r.transport_bits(256, 16.0)) == \
        meta_r.halo_demand * 128 * 32.0


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------


def test_p2p_requires_blockmask_compressor(setup):
    cfg, params, pg, graph = setup
    _, meta_r = _metas(pg, params)
    with pytest.raises(ValueError, match="blockmask"):
        make_train_step(cfg, fixed(4.0), adamw(1e-3), meta_r)


def test_p2p_compressing_requires_lane_widths():
    g = tiny_graph(n=64, feat_dim=96)                  # 96 % 128 != 0
    cfg = GNNConfig(conv="sage", in_dim=96, hidden=128,
                    out_dim=g.num_classes, layers=2)
    params = init_gnn(jax.random.key(0), cfg)
    pg = partition_graph(g, 2, scheme="random")
    meta = DistMeta.build(pg, params, wire="p2p")      # build itself is fine
    with pytest.raises(ValueError, match="divisible"):
        make_train_step(cfg, fixed(2.0, compressor="blockmask"),
                        adamw(1e-3), meta)
    # an uncompressed policy runs off-lane-grid widths (dense hop rows)
    graph = attach_p2p(pg.device_arrays(), pg)
    step = make_train_step(cfg, FULL_COMM, adamw(1e-3), meta)
    opt = adamw(1e-3)
    step(params, opt.init(params), graph, jnp.asarray(0), jax.random.key(0))


# ---------------------------------------------------------------------------
# bounded shard_map executable cache (regression)
# ---------------------------------------------------------------------------


def test_compiled_cache_bounded():
    """Annealing across many kept-block maps must evict compiled
    executables rather than pin every one forever."""
    g = tiny_graph(n=64, feat_dim=1024)
    cfg = GNNConfig(conv="sage", in_dim=1024, hidden=128,
                    out_dim=g.num_classes, layers=2)
    params = init_gnn(jax.random.key(0), cfg)
    pg = partition_graph(g, 1, scheme="random")
    graph = pg.device_arrays()
    meta = DistMeta.build(pg, params, wire="packed")
    pol = varco(total_steps=12, slope=1, c_max=8.0, compressor="blockmask")
    maps = [_packed_k_for(meta, float(pol.rate(i))) for i in range(12)]
    distinct = list(dict.fromkeys(maps))
    assert len(distinct) >= 3                      # schedule walks ≥3 maps
    assert maps[0] not in distinct[-2:]            # first map gets evicted

    mesh = make_worker_mesh(1)                     # single real CPU device
    from repro.dist.gnn_parallel import shard_graph
    gs = shard_graph(graph, mesh)
    opt = sgd(1e-2)
    step = make_train_step(cfg, pol, opt, meta, mesh=mesh,
                           compiled_cache_size=2)
    p, s = params, opt.init(params)
    for i in (maps.index(m) for m in distinct):    # one step per map
        p, s, _ = step(p, s, gs, jnp.asarray(i), jax.random.key(i))
    info = step.cache_info()
    assert info.currsize <= 2, info
    assert info.misses == len(distinct), info
    # revisiting the evicted first map recompiles (evict ≠ break)
    p, s, _ = step(p, s, gs, jnp.asarray(0), jax.random.key(0))
    assert step.cache_info().misses == len(distinct) + 1


# ---------------------------------------------------------------------------
# shard_map backend (shared harness of tests/parity.py; subprocess: 8
# virtual devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_p2p_shard_map_matches_emulated():
    run_train_parity(8, ["full", "fixed:2", "fixed:4", "fixed:16"],
                     wire="p2p", f=256, hidden=128, layers=3, steps=4)
