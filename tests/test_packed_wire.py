"""Packed-wire halo exchange: packed vs dense parity + transport ledger.

The packed wire (DESIGN.md §3.3) must be a pure transport change: for the
same per-exchange key it delivers exactly the values of the dense
``blockmask`` round trip — forward and backward — while shipping only the
``[B, K·128]`` lane-block payload.  These tests pin that contract at every
rate the acceptance sweep uses ({1, 2, 4, 16}), on the emulated backend
here and on the real shard_map collectives in ``test_multidevice.py``
style subprocesses below.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FULL_COMM, fixed, get_compressor, varco
from repro.core.varco import CommLedger
from repro.dist.gnn_parallel import (DistMeta, _make_aggregate_emulated,
                                     make_eval_step, make_train_step)
from repro.graph import partition_graph, tiny_graph
from repro.kernels import ops, ref
from repro.kernels.varco_pack import block_mask_indices
from repro.nn import GNNConfig, init_gnn
from repro.nn.gnn import gnn_forward
from repro.train.optim import adamw

RATES = [1.0, 2.0, 4.0, 16.0]
F = 256                                  # 2 lane-blocks; rate 16 floors to 1


@pytest.fixture(scope="module")
def setup():
    g = tiny_graph(n=256, feat_dim=F)
    cfg = GNNConfig(conv="sage", in_dim=F, hidden=128,
                    out_dim=g.num_classes, layers=3)
    params = init_gnn(jax.random.key(0), cfg)
    pg = partition_graph(g, 4, scheme="random")
    graph = pg.device_arrays()
    return cfg, params, pg, graph


def _metas(pg, params):
    return (DistMeta.build(pg, params),
            DistMeta.build(pg, params, wire="packed"))


def _policy(rate):
    return FULL_COMM if rate == 1.0 else fixed(rate, compressor="blockmask")


# ---------------------------------------------------------------------------
# wire ops / compressor agreement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rate", RATES)
def test_blockmask_roundtrip_equals_wire_path(rate):
    """Dense blockmask compressor == wire_unpack(wire_pack(x)), bitwise."""
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (64, 512)),
                    jnp.float32)
    key = jax.random.key(7)
    dense, bits = get_compressor("blockmask")(key, x, jnp.asarray(rate))
    kept, inv = block_mask_indices(key, 512 // 128, rate)
    wired = ops.wire_unpack(ops.wire_pack(x, kept, inv), kept, inv)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(wired))
    # dense ledger charge counts exactly the packed payload elements
    assert float(bits) == kept.shape[0] * 128 * 64 * 32


@pytest.mark.parametrize("rate", RATES)
def test_wire_ops_gradient_is_block_mask(rate):
    """Custom VJPs: d/dx of the wire round trip is the kept-block mask."""
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (16, 512)),
                    jnp.float32)
    kept, inv = block_mask_indices(jax.random.key(3), 512 // 128, rate)

    def loss(x_):
        return jnp.sum(ops.wire_unpack(ops.wire_pack(x_, kept, inv),
                                       kept, inv) ** 2)

    g = jax.grad(loss)(x)
    mask = np.zeros(512 // 128, bool)
    mask[np.asarray(kept)] = True
    expect = 2 * np.asarray(x).reshape(16, -1, 128) * mask[None, :, None]
    np.testing.assert_allclose(np.asarray(g), expect.reshape(16, 512),
                               rtol=1e-6, atol=0)


def test_pallas_row_padding_matches_oracle():
    """The TPU dispatch pads arbitrary row counts (B = halo_size) to what
    the Pallas grid accepts; padded-kernel-then-slice must equal the oracle
    on the original rows.  Exercised here in interpret mode."""
    from repro.kernels.ops import _padded_rows
    from repro.kernels.varco_pack import varco_pack, varco_unpack

    for n in (3, 100, 300, 512):
        x = jnp.asarray(np.random.default_rng(n).normal(0, 1, (n, 256)),
                        jnp.float32)
        kept, inv = block_mask_indices(jax.random.key(0), 2, 2.0)
        pad = _padded_rows(n) - n
        assert _padded_rows(n) % min(256, _padded_rows(n)) == 0
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        packed = varco_pack(xp, kept, interpret=True)[:n]
        np.testing.assert_array_equal(np.asarray(packed),
                                      np.asarray(ref.pack_reference(x, kept)))
        up = varco_unpack(jnp.pad(packed, ((0, pad), (0, 0))), inv,
                          interpret=True)[:n]
        np.testing.assert_array_equal(
            np.asarray(up), np.asarray(ref.unpack_reference(packed, inv)))


def test_packed_k_quantisation_bounds_recompiles():
    """Annealing rates map to the static kept-block counts, so nearby rates
    share a compiled step (128.0 and 96.25 both keep 1 block of 2)."""
    from repro.dist.gnn_parallel import _packed_k_for

    meta = DistMeta(q=2, part_size=1, halo_size=1, num_nodes=2,
                    feat_dim=256, num_classes=2, halo_demand=1,
                    cross_edges=1, n_train=1, n_val=0, n_test=1,
                    layer_dims=(256, 128), wire="packed")
    # exchanged widths: 256 (nb=2) and 128 (nb=1)
    assert _packed_k_for(meta, 128.0) == _packed_k_for(meta, 96.25) \
        == ((1, 1), (2, 1))
    assert _packed_k_for(meta, 1.0) == ((1, 1), (2, 2))
    assert len({_packed_k_for(meta, r)
                for r in np.linspace(1.0, 128.0, 200)}) <= 2


def test_packed_rejects_off_lane_widths_at_build():
    g = tiny_graph(n=64, feat_dim=96)              # 96 % 128 != 0
    cfg = GNNConfig(conv="sage", in_dim=96, hidden=128,
                    out_dim=g.num_classes, layers=2)
    params = init_gnn(jax.random.key(0), cfg)
    pg = partition_graph(g, 2, scheme="random")
    with pytest.raises(ValueError, match="divisible"):
        DistMeta.build(pg, params, wire="packed")
    DistMeta.build(pg, params)                     # dense wire: fine


def test_packed_width_matches_kernel_selection():
    for rate in RATES + [3.0, 7.0, 100.0]:
        for f in (128, 256, 1024):
            meta_args = dict(q=2, part_size=1, halo_size=1, num_nodes=2,
                             feat_dim=f, num_classes=2, halo_demand=1,
                             cross_edges=1, n_train=1, n_val=0, n_test=1,
                             layer_dims=(f,), wire="packed")
            meta = DistMeta(**meta_args)
            kept, _ = block_mask_indices(jax.random.key(0), f // 128, rate)
            assert meta.packed_width(f, rate) == kept.shape[0] * 128


# ---------------------------------------------------------------------------
# emulated runtime parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rate", RATES)
def test_packed_forward_matches_dense_blockmask(setup, rate):
    cfg, params, pg, graph = setup
    meta_d, meta_p = _metas(pg, params)
    pol = _policy(rate)
    comp = pol.compressor() if pol.compresses else None
    agg_d = _make_aggregate_emulated(graph, meta_d, pol, comp,
                                     jnp.asarray(rate), jax.random.key(2))
    agg_p = _make_aggregate_emulated(graph, meta_p, pol, comp, rate,
                                     jax.random.key(2))
    ld, bd = gnn_forward(params, cfg, graph["features"], agg_d)
    lp, bp = gnn_forward(params, cfg, graph["features"], agg_p)
    np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))
    # same analytic charge; transports differ (dense ships full F always)
    np.testing.assert_allclose(float(bd[0]), float(bp[0]), rtol=1e-6)
    assert float(bp[1]) <= float(bd[1]) + 1e-6


@pytest.mark.parametrize("rate", RATES)
def test_packed_backward_matches_dense_blockmask(setup, rate):
    cfg, params, pg, graph = setup
    meta_d, meta_p = _metas(pg, params)
    pol = _policy(rate)
    comp = pol.compressor() if pol.compresses else None

    def loss(p, meta, r):
        agg = _make_aggregate_emulated(graph, meta, pol, comp, r,
                                       jax.random.key(4))
        logits, _ = gnn_forward(p, cfg, graph["features"], agg)
        return jnp.sum(logits ** 2)

    gd = jax.grad(loss)(params, meta_d, jnp.asarray(rate))
    gp = jax.grad(loss)(params, meta_p, rate)
    for a, b in zip(jax.tree_util.tree_leaves(gd),
                    jax.tree_util.tree_leaves(gp)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packed_rate1_training_matches_dense_full_comm(setup):
    """Acceptance: packed rate-1 training bitwise-close to dense full comm."""
    cfg, params, pg, graph = setup
    meta_d, meta_p = _metas(pg, params)
    opt = adamw(5e-3)
    outs = []
    for meta in (meta_d, meta_p):
        p, s = params, opt.init(params)
        step = make_train_step(cfg, FULL_COMM, opt, meta)
        for i in range(5):
            p, s, m = step(p, s, graph, jnp.asarray(i), jax.random.key(i))
        outs.append((p, float(m["loss"])))
    (pd, lossd), (pp, lossp) = outs
    assert abs(lossd - lossp) < 1e-5
    for a, b in zip(jax.tree_util.tree_leaves(pd),
                    jax.tree_util.tree_leaves(pp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=0)


def test_packed_varco_schedule_trains(setup):
    """A VARCO blockmask policy runs on the packed wire (recompiling only
    per kept-block map) and the transport charge tracks the wire width."""
    cfg, params, pg, graph = setup
    _, meta_p = _metas(pg, params)
    pol = varco(total_steps=8, slope=5, compressor="blockmask")
    opt = adamw(5e-3)
    step = make_train_step(cfg, pol, opt, meta_p)
    p, s = params, opt.init(params)
    losses = []
    for i in range(6):
        p, s, m = step(p, s, graph, jnp.asarray(i), jax.random.key(i))
        losses.append(float(m["loss"]))
        rate = float(m["rate"])
        widths = [meta_p.packed_width(f, rate)
                  for f in (cfg.in_dim, cfg.hidden, cfg.hidden)]
        expect = 2 * meta_p.halo_demand * 32.0 * sum(widths)
        np.testing.assert_allclose(float(m["transport_bits"]), expect,
                                   rtol=1e-6)
    assert losses[-1] < losses[0]
    accs = make_eval_step(cfg, meta_p)(p, graph)
    assert 0.0 <= float(accs["test"]) <= 1.0


# ---------------------------------------------------------------------------
# ledger: analytic vs transport
# ---------------------------------------------------------------------------


def test_transport_equals_analytic_at_rate1(setup):
    """Acceptance: transport_bits ≈ analytic_bits for packed at rate 1."""
    cfg, params, pg, graph = setup
    _, meta_p = _metas(pg, params)
    for f in (128, 256, 512):
        np.testing.assert_allclose(float(meta_p.transport_bits(f, 1.0)),
                                   float(meta_p.ledger_bits(f, 1.0)),
                                   rtol=1e-7)
    # and end-to-end through a train step's metrics
    opt = adamw(5e-3)
    step = make_train_step(cfg, FULL_COMM, opt, meta_p)
    _, _, m = step(params, opt.init(params), graph, jnp.asarray(0),
                   jax.random.key(0))
    np.testing.assert_allclose(float(m["transport_bits"]),
                               float(m["halo_bits"]), rtol=1e-6)


@pytest.mark.parametrize("rate", [2.0, 4.0, 16.0])
def test_packed_transport_within_block_quantised_bound(setup, rate):
    """Packed wire bytes ≤ (1/r + 1/(F/128)) × dense bytes (acceptance)."""
    cfg, params, pg, graph = setup
    meta_d, meta_p = _metas(pg, params)
    for f in (256, 512, 1024):
        dense = float(meta_d.transport_bits(f))        # ships full F always
        packed = float(meta_p.transport_bits(f, rate))
        bound = (1.0 / rate + 128.0 / f) * dense
        assert packed <= bound + 1e-6, (f, rate, packed, bound)
        assert packed < dense                          # strict shrink, F>128


def test_dense_wire_transport_is_rate_independent(setup):
    """The dense wire ships the masked buffer at full width — the honest
    transport number the packed wire exists to fix."""
    cfg, params, pg, graph = setup
    meta_d, _ = _metas(pg, params)
    assert float(meta_d.transport_bits(F, 1.0)) == \
        float(meta_d.transport_bits(F, 16.0))


def test_ledger_tracks_both_charges():
    led = CommLedger.zero().add_bits(jnp.float32(64.0),
                                     transport=jnp.float32(256.0))
    led = led.add_bits(jnp.float32(32.0))              # transport defaults
    assert float(led.bits) == 96.0
    assert float(led.transport) == 288.0
    assert float(led.floats) == 3.0


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------


def test_packed_requires_blockmask_compressor(setup):
    cfg, params, pg, graph = setup
    _, meta_p = _metas(pg, params)
    with pytest.raises(ValueError, match="blockmask"):
        make_train_step(cfg, fixed(4.0), adamw(1e-3), meta_p)


def test_unknown_wire_rejected(setup):
    cfg, params, pg, graph = setup
    with pytest.raises(ValueError, match="wire"):
        DistMeta.build(pg, params, wire="carrier-pigeon")


def test_blockmask_rejects_off_lane_width():
    with pytest.raises(ValueError, match="divisible"):
        get_compressor("blockmask")(jax.random.key(0),
                                    jnp.ones((4, 100)), jnp.asarray(2.0))


# ---------------------------------------------------------------------------
# shard_map backend (subprocess: needs 8 virtual devices)
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

PACKED_SHARD_EQUIV = """
import jax, jax.numpy as jnp
from repro.graph import tiny_graph, partition_graph
from repro.nn import GNNConfig, init_gnn
from repro.dist.gnn_parallel import (DistMeta, make_train_step,
                                     make_worker_mesh, shard_graph)
from repro.core import FULL_COMM, fixed
from repro.train.optim import adamw

g = tiny_graph(n=256, feat_dim=256)
cfg = GNNConfig(conv='sage', in_dim=256, hidden=128,
                out_dim=g.num_classes, layers=3)
params = init_gnn(jax.random.key(0), cfg)
pg = partition_graph(g, 8, scheme='random')
graph = pg.device_arrays()
meta = DistMeta.build(pg, params, wire='packed')
opt = adamw(1e-2)
mesh = make_worker_mesh(8)
gs = shard_graph(graph, mesh)

for rate in (1.0, 2.0, 4.0, 16.0):
    pol = FULL_COMM if rate == 1.0 else fixed(rate, compressor='blockmask')
    p_e, s_e = params, opt.init(params)
    step_e = make_train_step(cfg, pol, opt, meta)
    p_s, s_s = params, opt.init(params)
    step_s = make_train_step(cfg, pol, opt, meta, mesh=mesh)
    for i in range(4):
        p_e, s_e, m_e = step_e(p_e, s_e, graph, jnp.asarray(i),
                               jax.random.key(i))
        p_s, s_s, m_s = step_s(p_s, s_s, gs, jnp.asarray(i),
                               jax.random.key(i))
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p_e), jax.tree.leaves(p_s)))
    assert d < 1e-5, (rate, d)
    assert abs(float(m_e['loss']) - float(m_s['loss'])) < 1e-5, rate
    assert abs(float(m_e['transport_bits']) -
               float(m_s['transport_bits'])) < 1.0, rate
print('PACKED_SHARD_OK')
"""


@pytest.mark.slow
def test_packed_shard_map_matches_emulated():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", PACKED_SHARD_EQUIV], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "PACKED_SHARD_OK" in out.stdout
