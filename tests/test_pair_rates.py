"""Per-pair rate-map plumbing (DESIGN.md §3.6): wires + ledger + parity.

The ``[Q, Q]`` rate-map mechanism must be a pure refinement of the scalar
wires: a uniform map is bitwise the scalar path, a mixed map delivers
each ordered pair's rows at exactly the dense ``blockmask`` round trip of
that pair's own rate (nested kept sets under one shared permutation), the
ledger decomposes into per-pair charges that sum to the totals, and the
emulated and shard_map backends agree to 1e-6 at mixed rates drawn from
{1, 2, 4, 16} on both the packed and p2p wires.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from parity import build_setup, mixed_map, run_forward_parity

from repro.core import fixed
from repro.core.compression import get_compressor
from repro.dist.gnn_parallel import (DistMeta, _make_aggregate_emulated,
                                     _packed_k_for, _packed_pair_k_for,
                                     _pair_keep)
from repro.nn.gnn import gnn_forward

F = 512
Q = 4


@pytest.fixture(scope="module")
def setup():
    _, cfg, params, pg, graph = build_setup(Q, f=F, layers=2, n=256)
    return cfg, params, pg, graph


def _mixed_map(seed: int = 0) -> np.ndarray:
    return mixed_map(Q, seed)


def _agg(graph, meta, rm, key, pol=None):
    pol = pol or fixed(4.0, compressor="blockmask")
    kb = dict(_packed_pair_k_for(meta, rm))
    return _make_aggregate_emulated(graph, meta, pol, None,
                                    jnp.ones((), jnp.float32), key,
                                    packed_k=kb, rate_map=jnp.asarray(rm))


# ---------------------------------------------------------------------------
# scalar-path equivalence + per-pair blockmask semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", ["packed", "p2p"])
@pytest.mark.parametrize("rate", [1.0, 4.0])
def test_uniform_map_is_scalar_path(setup, wire, rate):
    """A constant rate map must reproduce the scalar wire bitwise (same
    keys, same kept sets, same ledger totals)."""
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire=wire)
    pol = fixed(rate, compressor="blockmask")
    kb = dict(_packed_k_for(meta, rate))
    agg_s = _make_aggregate_emulated(graph, meta, pol, None,
                                     jnp.asarray(rate), jax.random.key(3),
                                     packed_k=kb)
    rm = np.full((Q, Q), rate, np.float32)
    np.fill_diagonal(rm, 1.0)
    agg_p = _agg(graph, meta, rm, jax.random.key(3), pol=pol)
    ls, bs = gnn_forward(params, cfg, graph["features"], agg_s)
    lp, bp = gnn_forward(params, cfg, graph["features"], agg_p)
    assert float(jnp.abs(ls - lp).max()) == 0.0
    np.testing.assert_allclose(np.asarray(bs), np.asarray(bp[:2]), rtol=1e-6)


def test_p2p_pair_rows_match_per_pair_blockmask(setup):
    """Pair (i, j)'s delivered rows equal the dense ``blockmask`` round
    trip of sender j's boundary block at rate ``r[i, j]`` — the nested
    kept-set construction realises every pair's own rate exactly."""
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="p2p")
    rm = _mixed_map(1)
    key = jax.random.key(11)
    agg = _agg(graph, meta, rm, key)
    x = graph["features"]
    out_pair, _ = agg(0, x)

    # reference: dense-wire aggregation where receiver i's halo block from
    # sender j is blockmask-compressed at rate r[i, j] under j's key stream
    comp = get_compressor("blockmask")
    k_call = jax.random.fold_in(key, 0)
    publish = jax.vmap(lambda xq, idx, v: xq[idx] * v[:, None])(
        x, graph["send_idx"], graph["send_valid"])
    p_sz, b_sz = meta.part_size, meta.halo_size
    outs = []
    for i in range(Q):
        halo_i = jnp.concatenate([
            comp(jax.random.fold_in(k_call, j), publish[j],
                 jnp.asarray(rm[i, j]))[0]
            for j in range(Q)], axis=0)                    # [Q*B, F]
        out = jnp.zeros((p_sz + 1, F), x.dtype)
        out = out.at[graph["local_dst"][i]].add(
            graph["local_w"][i][:, None] * x[i][graph["local_src"][i]])
        out = out.at[graph["remote_dst"][i]].add(
            graph["remote_w"][i][:, None] * halo_i[graph["remote_src"][i]])
        outs.append(out[:p_sz])
    ref = jnp.stack(outs)
    np.testing.assert_allclose(np.asarray(out_pair), np.asarray(ref),
                               rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# ledger decomposition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", ["packed", "p2p"])
def test_pair_ledger_decomposes(setup, wire):
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire=wire)
    rm = _mixed_map(2)
    agg = _agg(graph, meta, rm, jax.random.key(5))
    _, bits = gnn_forward(params, cfg, graph["features"], agg)
    bits = np.asarray(bits)
    assert bits.shape == (2 + 3 * Q * Q,)
    pair_t = bits[2:2 + Q * Q].reshape(Q, Q)
    # per-pair transports sum to the transport total, diagonal never charged
    np.testing.assert_allclose(pair_t.sum(), bits[1], rtol=1e-6)
    assert np.all(np.diag(pair_t) == 0.0)
    # analytic charge is the requested-rate point-to-point sum over calls
    rows = meta.pair_table().astype(np.float64)
    expect = sum(float((rows * w * 32.0 / rm).sum())
                 for w in (F, F))                    # two exchanges at F
    np.testing.assert_allclose(bits[0], expect, rtol=1e-6)


def test_p2p_pair_transport_charges_own_rate(setup):
    """On the p2p wire each pair ships its OWN kept columns: transport of
    pair (i, j) is rows[i, j] × k(r[i, j]) × 128 × 32 per exchange."""
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="p2p")
    rm = _mixed_map(3)
    agg = _agg(graph, meta, rm, jax.random.key(5))
    _, bits = gnn_forward(params, cfg, graph["features"], agg)
    pair_t = np.asarray(bits[2:2 + Q * Q]).reshape(Q, Q)
    rows = meta.pair_table().astype(np.float64)
    nb = F // 128
    k = np.maximum(np.floor(nb / rm), 1.0)
    np.fill_diagonal(k, 0.0)
    expect = 2 * rows * k * 128 * 32.0              # two exchanges at F
    np.testing.assert_allclose(pair_t, expect, rtol=1e-6)


def test_packed_pair_transport_is_per_sender(setup):
    """The all-gather wire serves every receiver one payload, so sender
    j's realised kept count is max_i k[i, j] and every pair in column j
    is charged that width."""
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="packed")
    rm = _mixed_map(4)
    agg = _agg(graph, meta, rm, jax.random.key(5))
    _, bits = gnn_forward(params, cfg, graph["features"], agg)
    pair_t = np.asarray(bits[2:2 + Q * Q]).reshape(Q, Q)
    rows = meta.pair_table().astype(np.float64)
    nb = F // 128
    k = np.maximum(np.floor(nb / rm), 1.0)
    np.fill_diagonal(k, 0.0)
    k_send = np.maximum(k.max(axis=0), 1.0)
    expect = 2 * rows * k_send[None, :] * 128 * 32.0
    np.testing.assert_allclose(pair_t, expect, rtol=1e-6)


def test_pair_err_positive_only_when_dropping(setup):
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="p2p")
    # rate 1 everywhere → nothing dropped → zero per-pair error
    rm1 = np.ones((Q, Q), np.float32)
    agg = _agg(graph, meta, rm1, jax.random.key(5))
    _, bits = gnn_forward(params, cfg, graph["features"], agg)
    assert float(np.asarray(bits[2 + Q * Q:2 + 2 * Q * Q]).sum()) == 0.0
    rm = _mixed_map(5)
    agg = _agg(graph, meta, rm, jax.random.key(5))
    _, bits = gnn_forward(params, cfg, graph["features"], agg)
    err = np.asarray(bits[2 + Q * Q:2 + 2 * Q * Q]).reshape(Q, Q)
    assert err.sum() > 0.0
    # pairs at rate 1 drop nothing (nb/1 == nb kept)
    assert np.all(err[rm <= 1.0] == 0.0)


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------


def test_dense_wire_rejects_rate_map(setup):
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="dense")
    with pytest.raises(ValueError, match="scalar"):
        _make_aggregate_emulated(graph, meta, fixed(2.0, "blockmask"), None,
                                 jnp.ones(()), jax.random.key(0),
                                 rate_map=jnp.ones((Q, Q)))


def test_pair_table_requires_built_meta(setup):
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="p2p")
    import dataclasses
    bare = dataclasses.replace(meta, pair_rows=())
    with pytest.raises(ValueError, match="pair_rows"):
        bare.pair_table()
    assert meta.pair_table().sum() == meta.halo_demand


def test_pair_keep_matches_blockmask_floor():
    rm = np.asarray([[1.0, 2.0], [3.0, 16.0]], np.float32)
    k = np.asarray(_pair_keep(4, jnp.asarray(rm), 4))
    np.testing.assert_array_equal(k, [[4, 2], [1, 1]])
    # quantiser agrees with the static host-side maximum
    meta_nb = [(4, int(k.max()))]
    assert meta_nb[0][1] == 4


def test_neighbor_exchange_pair_k_needs_n_keep():
    from repro.core.collectives import neighbor_exchange

    def run():
        def worker(x):
            return neighbor_exchange(x, jnp.zeros((1, 2), jnp.int32),
                                     jnp.ones((1, 2)), "w",
                                     pair_k=jnp.ones((2, 2), jnp.int32))[0]
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("w",))
        return jax.jit(shard_map(worker, mesh=mesh, in_specs=P("w"),
                                 out_specs=P("w"), check_rep=False))(
            jnp.zeros((1, 4, 256)))

    with pytest.raises(ValueError, match="n_keep"):
        run()


# ---------------------------------------------------------------------------
# emulated ≡ shard_map at mixed per-pair AND per-layer rates (shared
# harness of tests/parity.py; subprocess: 4 devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_pair_rates_emulated_matches_shard_map():
    run_forward_parity(Q, [
        {"wire": wire, "policy": "fixed:4", "map": mode, "seed": 0}
        for wire in ("p2p", "packed") for mode in ("pair", "layer")])


@pytest.mark.slow
def test_single_layer_tensor_shard_parity():
    """[1, Q, Q] tensors (per-layer controller on a 1-layer model) on the
    real collectives — regression for the rank-vs-L selection bug."""
    run_forward_parity(2, [
        {"wire": wire, "policy": "fixed:4", "map": "layer", "seed": 1}
        for wire in ("p2p", "packed")], layers=1)
