"""The distributed conformance matrix (ISSUE 5 satellite).

One parametrized emulated ≡ shard_map sweep over ``wire × policy × Q ∈
{1, 2, 4}`` through the shared harness of tests/parity.py — full
communication, fixed blockmask compression, mixed per-pair ``[Q, Q]``
maps, and the per-layer ``[L, Q, Q]`` tensors (DESIGN.md §3.7) — so
backend conformance is pinned by construction for every transport, not
by hand-copied per-wire scripts.  Each Q runs as a single subprocess
(XLA fixes the device count at interpreter startup).
"""

import pytest

from parity import run_forward_parity


def _matrix(q: int) -> list[dict]:
    cases = [
        {"wire": "dense", "policy": "full", "map": None},
        {"wire": "dense", "policy": "fixed:4", "map": None},
    ]
    for wire in ("packed", "p2p"):
        cases += [
            {"wire": wire, "policy": "full", "map": None},
            {"wire": wire, "policy": "fixed:4", "map": None},
            {"wire": wire, "policy": "fixed:4", "map": "pair", "seed": q},
            {"wire": wire, "policy": "fixed:4", "map": "layer",
             "seed": 10 + q},
            # quantised wire (DESIGN.md §3.8): mixed rate × width maps
            {"wire": wire, "policy": "fixed:4", "map": "pair",
             "width_map": "pair", "seed": 20 + q},
            {"wire": wire, "policy": "fixed:4", "map": "layer",
             "width_map": "layer", "seed": 30 + q},
            # bit-packed byte wire (DESIGN.md §3.8): all-sub-32 width
            # maps flip store_w > 0 on both backends, so the uint8
            # payload + scales path conforms too — mixed {2, 4, 8}
            # draws (store_w 8) and uniform w=2 (4 lanes per byte)
            {"wire": wire, "policy": "fixed:4", "map": "pair",
             "width_map": "sub32", "seed": 60 + q},
            {"wire": wire, "policy": "fixed:4", "map": "layer",
             "width_map": "sub32_layer", "seed": 70 + q},
            {"wire": wire, "policy": "fixed:4", "map": "pair",
             "width_map": "w2", "seed": 80 + q},
        ]
    if q >= 2:
        # fault-channel conformance (ISSUE 8): seeded FaultSchedule drops
        # split CACHED/DEAD + random hop cache, identical on both
        # backends, at mixed [Q, Q] and [L, Q, Q] rate × width maps
        cases += [
            {"wire": "p2p", "policy": "fixed:4", "map": "pair",
             "seed": q, "fault": 40 + q},
            {"wire": "p2p", "policy": "fixed:4", "map": "layer",
             "width_map": "layer", "seed": 50 + q, "fault": 50 + q},
        ]
    return cases


@pytest.mark.slow
@pytest.mark.parametrize("q", [1, 2, 4])
def test_parity_matrix(q):
    out = run_forward_parity(q, _matrix(q))
    # every case must have reported, not just the sentinel
    assert out.count(" OK ") == len(_matrix(q)), out


# Q=16 scale conformance (ISSUE 7): the subprocess builds its graph from
# disk-backed shards (write_graph_store → write_shards → load_shards, the
# out-of-core ingestion path) rather than the in-memory partitioner, and
# the emulated ≡ shard_map matrix must still hold on a 16-device mesh —
# including one mixed per-layer [L, Q, Q] rate × width case.  Small F
# (LANE-divisible) keeps the 16-way host mesh affordable.
_Q16_CASES = [
    {"wire": "p2p", "policy": "full", "map": None},
    {"wire": "p2p", "policy": "fixed:4", "map": "pair", "seed": 16},
    {"wire": "p2p", "policy": "fixed:4", "map": "layer",
     "width_map": "layer", "seed": 46},
    {"wire": "packed", "policy": "fixed:4", "map": "pair",
     "width_map": "pair", "seed": 36},
    {"wire": "p2p", "policy": "fixed:4", "map": "pair",
     "width_map": "sub32", "seed": 56},
    {"wire": "p2p", "policy": "fixed:4", "map": "pair", "seed": 26,
     "fault": 99},
]


@pytest.mark.slow
def test_parity_matrix_q16_from_shards():
    out = run_forward_parity(16, _Q16_CASES, f=128, n=512, shards=True)
    assert out.count(" OK ") == len(_Q16_CASES), out
