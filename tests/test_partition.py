"""Partitioner + halo layout correctness."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import (citation_graph, edge_cut_stats, partition_graph,
                         tiny_graph)
from repro.graph.partition import PARTITIONERS


@pytest.mark.parametrize("scheme", list(PARTITIONERS))
@pytest.mark.parametrize("q", [2, 4, 8])
def test_partition_covers_disjoint(scheme, q):
    g = tiny_graph(n=256)
    pg = partition_graph(g, q, scheme=scheme)
    assert pg.owner.shape == (g.num_nodes,)
    assert pg.owner.min() >= 0 and pg.owner.max() < q
    sizes = np.bincount(pg.owner, minlength=q)
    assert sizes.sum() == g.num_nodes
    if scheme == "random":
        assert sizes.max() - sizes.min() <= 1
    else:
        assert sizes.max() <= 1.1 * g.num_nodes / q + 1


def test_edge_stats_sum_to_total():
    g = tiny_graph(n=256)
    pg = partition_graph(g, 4, scheme="random")
    st_ = edge_cut_stats(g, pg.owner)
    assert st_["self_edges"] + st_["cross_edges"] == g.num_edges
    assert abs(st_["self_frac"] + st_["cross_frac"] - 1.0) < 1e-9


def test_metis_like_cuts_fewer_edges_than_random():
    g = citation_graph(n=4000, seed=0)
    cut_r = edge_cut_stats(g, partition_graph(g, 8, "random").owner)
    cut_m = edge_cut_stats(g, partition_graph(g, 8, "metis-like").owner)
    assert cut_m["cross_frac"] < 0.75 * cut_r["cross_frac"], (cut_m, cut_r)


def test_halo_layout_reconstructs_full_aggregation():
    """local + remote edge arrays must reproduce the exact full-graph Sx."""
    g = tiny_graph(n=256)
    pg = partition_graph(g, 4, scheme="random", norm="mean")
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (g.num_nodes, 8)).astype(np.float32)

    # reference aggregation
    from repro.graph.data import normalized_edge_weights
    dst, src = g.edge_list()
    w = normalized_edge_weights(g, "mean")
    ref = np.zeros_like(x)
    np.add.at(ref, dst, w[:, None] * x[src])

    # partitioned aggregation using the padded halo layout
    xq = np.zeros((pg.q, pg.part_size, 8), np.float32)
    xq[pg.owner, pg.local_index] = x
    publish = np.stack([xq[p][pg.send_idx[p]] * pg.send_valid[p][:, None]
                        for p in range(pg.q)])          # [Q, B, F]
    halo_flat = publish.reshape(pg.q * pg.halo_size, 8)
    out = np.zeros((pg.q, pg.part_size + 1, 8), np.float32)
    for p in range(pg.q):
        np.add.at(out[p], pg.local_dst[p],
                  pg.local_w[p][:, None] * xq[p][pg.local_src[p]])
        np.add.at(out[p], pg.remote_dst[p],
                  pg.remote_w[p][:, None] * halo_flat[pg.remote_src[p]])
    got = out[pg.owner, pg.local_index]
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_halo_demand_counts_distinct_pairs():
    g = tiny_graph(n=128)
    pg = partition_graph(g, 4, scheme="random")
    dst, src = g.edge_list()
    demand = len({(pg.owner[d], s) for d, s in zip(dst, src)
                  if pg.owner[d] != pg.owner[s]})
    assert pg.halo_demand == demand


@settings(max_examples=10, deadline=None)
@given(n=st.integers(40, 200), q=st.sampled_from([2, 3, 4]),
       seed=st.integers(0, 5))
def test_partition_property_random_graphs(n, q, seed):
    g = tiny_graph(n=n, seed=seed)
    pg = partition_graph(g, q, scheme="random", seed=seed)
    # every node appears exactly once across partitions
    seen = np.zeros(g.num_nodes, bool)
    for p in range(q):
        nodes = np.flatnonzero(pg.owner == p)
        assert not seen[nodes].any()
        seen[nodes] = True
    assert seen.all()
    # every remote edge's halo slot points at a published boundary node
    for p in range(q):
        valid = pg.remote_w[p] > 0
        flat = pg.remote_src[p][valid]
        owners = flat // pg.halo_size
        slots = flat % pg.halo_size
        assert (pg.send_valid[owners, slots] == 1.0).all()


@pytest.mark.parametrize("scheme_seed", [0, 1, 2])
@pytest.mark.parametrize("q", [2, 4, 8])
def test_refine_never_increases_edge_cut(scheme_seed, q):
    """KL refinement moves only strictly-improving nodes (keep-current
    tie-breaking), so a pass can never increase the cut."""
    from repro.graph.partition import (greedy_partition, random_partition,
                                       refine_partition)
    g = tiny_graph(n=300, seed=scheme_seed)
    for base in (random_partition(g, q, seed=scheme_seed),
                 greedy_partition(g, q, seed=scheme_seed)):
        before = edge_cut_stats(g, base)["cross_edges"]
        refined = refine_partition(g, base, q, seed=scheme_seed)
        after = edge_cut_stats(g, refined)["cross_edges"]
        assert after <= before, (after, before)


def _scrambled_rows(g, seed):
    """The same graph with each CSR row's neighbours re-shuffled — the
    edge presentation order a chunked/streaming producer might emit.
    Returns the graph and the per-edge permutation (old → new position),
    so per-edge operands can be carried along."""
    import dataclasses as dc
    rng = np.random.default_rng(seed)
    perm = np.concatenate([
        g.indptr[u] + rng.permutation(int(g.indptr[u + 1] - g.indptr[u]))
        for u in range(g.num_nodes)]).astype(np.int64)
    return dc.replace(g, indices=g.indices[perm]), perm


@pytest.mark.parametrize("q", [2, 4])
@pytest.mark.parametrize("seed", [0, 3])
def test_partitioners_invariant_to_edge_presentation_order(q, seed):
    """Regression (ISSUE 7 satellite): refinement — and the whole
    metis-like pipeline — must produce the identical owner vector no
    matter what order each row's edges were presented in (the streaming
    pipeline's chunks make no ordering promises).  Pinned by the
    sort-before-refine canonicalisation in ``_canonical_rows``."""
    from repro.graph.partition import (metis_like_partition,
                                      random_partition, refine_partition)
    g = tiny_graph(n=300, seed=seed)
    g2, _ = _scrambled_rows(g, seed + 17)
    base = random_partition(g, q, seed=seed)
    np.testing.assert_array_equal(
        refine_partition(g, base, q, seed=seed),
        refine_partition(g2, base, q, seed=seed))
    np.testing.assert_array_equal(
        metis_like_partition(g, q, seed=seed),
        metis_like_partition(g2, q, seed=seed))


def test_weighted_refine_invariant_and_respects_balance():
    """The weighted extension (multilevel coarse levels): edge weights
    presented in any order give the same owners, and node-weight balance
    holds against the weighted capacity."""
    from repro.graph.data import normalized_edge_weights
    from repro.graph.partition import random_partition, refine_partition
    g = tiny_graph(n=240, seed=1)
    q, slack = 3, 1.05
    nw = 1.0 + (np.arange(g.num_nodes) % 5).astype(np.float64)
    ew = normalized_edge_weights(g, "mean").astype(np.float64)
    base = random_partition(g, q, seed=1)
    ref = refine_partition(g, base, q, seed=1, slack=slack,
                           node_weight=nw, edge_weight=ew)
    g2, perm = _scrambled_rows(g, 99)
    ref2 = refine_partition(g2, base, q, seed=1, slack=slack,
                            node_weight=nw, edge_weight=ew[perm])
    np.testing.assert_array_equal(ref, ref2)
    loads = np.bincount(ref, weights=nw, minlength=q)
    # capacity bound + one node's weight (a move may land just under it)
    assert loads.max() <= slack * nw.sum() / q + nw.max()
