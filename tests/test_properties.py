"""Property-based suites for the wire format and the rate-map masks.

Real randomised properties (hypothesis, or the conftest engine when
hypothesis is absent) over the invariants the runtime's bitwise-parity
guarantees rest on:

* pack/unpack round-trip at arbitrary ``(rate, Q, F)`` draws — the wire
  payload reconstructs exactly the dense ``blockmask`` round trip, kept
  blocks bit-for-bit, dropped blocks zero;
* pair-rate mask invariants — every pair's kept set is contained in the
  max-packed columns (`_packed_pair_k_for`'s static count), kept sets at
  different counts are nested under one key, and monotone rate maps give
  monotone kept counts (the mechanism behind the controllers' monotone
  non-increasing rates, Prop. 2);
* per-layer ``[L, Q, Q]`` tensors quantise to a static maximum that
  dominates every layer's every pair.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.compression import get_compressor
from repro.dist.gnn_parallel import _pair_keep, _packed_pair_k_for
from repro.kernels.ops import wire_pack, wire_unpack
from repro.kernels.varco_pack import (LANE, block_mask_indices,
                                      block_mask_indices_k,
                                      block_mask_indices_pos,
                                      worker_block_maps)

RATE_CHOICES = [1.0, 1.5, 2.0, 3.0, 4.0, 8.0, 16.0, 32.0]


# ---------------------------------------------------------------------------
# pack/unpack round trip at arbitrary (rate, Q, F)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(nb=st.integers(1, 8), rate=st.floats(1.0, 32.0),
       q=st.sampled_from([1, 2, 4]), n=st.integers(1, 12),
       seed=st.integers(0, 2 ** 16))
def test_pack_unpack_roundtrip(nb, rate, q, n, seed):
    """wire_pack → wire_unpack reconstructs the kept lane-blocks exactly
    and zero-fills the dropped ones, for every worker's key stream."""
    f = nb * LANE
    key = jax.random.key(seed)
    x = jax.random.normal(jax.random.fold_in(key, 999), (n, f), jnp.float32)
    k = max(int(nb / max(rate, 1.0)), 1)
    kept_all, inv_all = worker_block_maps(key, q, nb, k)
    for w in range(q):
        kept, inv = kept_all[w], inv_all[w]
        packed = wire_pack(x, kept, inv)
        assert packed.shape == (n, k * LANE)
        un = np.asarray(wire_unpack(packed, kept, inv))
        blocks = un.reshape(n, nb, LANE)
        x_blocks = np.asarray(x).reshape(n, nb, LANE)
        kept_set = set(np.asarray(kept).tolist())
        for b in range(nb):
            if b in kept_set:
                np.testing.assert_array_equal(blocks[:, b], x_blocks[:, b])
            else:
                assert not blocks[:, b].any()


@settings(max_examples=15, deadline=None)
@given(nb=st.integers(1, 8), rate=st.floats(1.0, 32.0),
       n=st.integers(1, 8), seed=st.integers(0, 2 ** 16))
def test_roundtrip_matches_blockmask_compressor(nb, rate, n, seed):
    """The packed wire's round trip equals the dense ``blockmask``
    compressor bitwise under the same key — the structural fact behind
    packed ≡ dense parity at every rate."""
    f = nb * LANE
    key = jax.random.key(seed)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, f), jnp.float32)
    kept, inv = block_mask_indices(key, nb, rate)
    rt = wire_unpack(wire_pack(x, kept, inv), kept, inv)
    dense, _ = get_compressor("blockmask")(key, x, jnp.asarray(rate))
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(dense))


# ---------------------------------------------------------------------------
# pair-rate mask invariants
# ---------------------------------------------------------------------------


def _rand_map(rng, shape):
    rm = rng.choice(RATE_CHOICES, size=shape).astype(np.float32)
    it = rm.reshape(-1, shape[-1], shape[-1])
    for sl in it:
        np.fill_diagonal(sl, 1.0)
    return rm


@settings(max_examples=20, deadline=None)
@given(nb=st.integers(1, 8), q=st.sampled_from([2, 3, 4]),
       seed=st.integers(0, 2 ** 16))
def test_pair_keep_within_max_packed_columns(nb, q, seed):
    """Every pair's kept count fits inside the static max-packed buffer:
    1 <= k_pair <= k_max, with k_max = the map's realised maximum."""
    rng = np.random.default_rng(seed)
    rm = _rand_map(rng, (q, q))
    k_true = np.maximum(np.floor(nb / rm), 1.0)
    off = ~np.eye(q, dtype=bool)
    k_max = int(k_true[off].max())
    k = np.asarray(_pair_keep(nb, jnp.asarray(rm), k_max))
    assert k.min() >= 1
    assert k[off].max() <= k_max
    np.testing.assert_array_equal(k[off], k_true[off].astype(np.int32))


@settings(max_examples=20, deadline=None)
@given(nb=st.integers(1, 8), q=st.sampled_from([2, 3, 4]),
       seed=st.integers(0, 2 ** 16), bump=st.sampled_from([1.5, 2.0, 4.0]))
def test_monotone_rate_maps_give_monotone_keep_counts(nb, q, seed, bump):
    """r1 <= r2 elementwise ⇒ kept counts k(r1) >= k(r2) elementwise —
    monotone non-increasing rates induce monotone non-decreasing kept
    sets, which is what keeps Prop. 2 applicable per pair and per layer."""
    rng = np.random.default_rng(seed)
    r1 = _rand_map(rng, (q, q))
    r2 = np.where(np.eye(q, dtype=bool), 1.0, r1 * bump).astype(np.float32)
    k_max = nb
    k1 = np.asarray(_pair_keep(nb, jnp.asarray(r1), k_max))
    k2 = np.asarray(_pair_keep(nb, jnp.asarray(r2), k_max))
    assert (k1 >= k2).all()


@settings(max_examples=20, deadline=None)
@given(nb=st.integers(1, 8), seed=st.integers(0, 2 ** 16))
def test_kept_sets_nested_under_one_key(nb, seed):
    """Kept sets at counts k' <= k are nested under one key (both are
    "permutation position < count"), and the positions match the kept
    selection — the carve-out mechanism of the per-pair/per-layer maps."""
    key = jax.random.key(seed)
    sets = []
    for k in range(1, nb + 1):
        kept, inv, pos = block_mask_indices_pos(key, nb, k)
        kept_k, _ = block_mask_indices_k(key, nb, k)
        np.testing.assert_array_equal(np.asarray(kept), np.asarray(kept_k))
        # pos-based rule reproduces the kept set exactly
        by_pos = np.nonzero(np.asarray(pos) < k)[0]
        np.testing.assert_array_equal(np.sort(np.asarray(kept)), by_pos)
        sets.append(set(np.asarray(kept).tolist()))
    for small, big in zip(sets[:-1], sets[1:]):
        assert small <= big


@settings(max_examples=15, deadline=None)
@given(q=st.sampled_from([2, 3, 4]), n_layers=st.sampled_from([1, 2, 3]),
       seed=st.integers(0, 2 ** 16))
def test_packed_pair_k_dominates_every_layer(q, n_layers, seed):
    """The static kept-block maximum of `_packed_pair_k_for` dominates
    every layer's every pair at every exchanged width — so one packed
    buffer per width serves the whole [L, Q, Q] tensor."""
    from repro.dist.gnn_parallel import DistMeta

    rng = np.random.default_rng(seed)
    shape = (n_layers, q, q) if n_layers > 1 else (q, q)
    rm = _rand_map(rng, shape)
    meta = DistMeta(q=q, part_size=8, halo_size=4, num_nodes=8 * q,
                    feat_dim=256, num_classes=4, halo_demand=q,
                    cross_edges=q, n_train=1, n_val=1, n_test=1,
                    layer_dims=(256, 512), wire="dense")
    kb = dict(_packed_pair_k_for(meta, rm))
    off = ~np.eye(q, dtype=bool)
    for nb, k_static in kb.items():
        k = np.maximum(np.floor(nb / rm.reshape(-1, q, q)), 1.0)
        assert k_static >= int(k[:, off].max())
        assert 1 <= k_static <= nb


# ---------------------------------------------------------------------------
# quantised wire codec (DESIGN.md §3.8)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(width=st.sampled_from([2, 4, 8]), nb=st.integers(1, 6),
       rate=st.floats(1.0, 32.0), n=st.integers(1, 8),
       seed=st.integers(0, 2 ** 16))
def test_quant_dequant_error_within_analytic_bound(width, nb, rate, n, seed):
    """Quantise→dequantise of a packed payload stays within the advertised
    per-element bound ``amax_block / (2^(w−1) − 1)`` for arbitrary
    ``(width, rate, Q, F)`` draws (deterministic rounding is tighter:
    half that), and ``width ≥ 32`` is an exact passthrough."""
    from repro.kernels.ops import quant_dequant
    from repro.kernels.varco_pack import block_mask_indices

    f = nb * LANE
    key = jax.random.key(seed)
    # scale-diverse rows (blocks spanning orders of magnitude) so the
    # per-block scales are genuinely heterogeneous
    mag = 10.0 ** jax.random.uniform(jax.random.fold_in(key, 1), (n, 1),
                                     minval=-2.0, maxval=2.0)
    x = jax.random.normal(jax.random.fold_in(key, 2), (n, f)) * mag
    kept, inv = block_mask_indices(key, nb, rate)
    packed = wire_pack(x, kept, inv)                 # [n, k*LANE]
    k = packed.shape[1] // LANE
    dq = np.asarray(quant_dequant(packed, width))
    pb = np.asarray(packed).reshape(n, k, LANE)
    qmax = 2.0 ** (width - 1) - 1.0
    bound = np.abs(pb).max(-1) / qmax                # [n, k]
    err = np.abs(dq.reshape(n, k, LANE) - pb)
    assert np.all(err <= 0.5 * bound[..., None] + 1e-6 * (1 + bound[..., None]))
    # fp32 "width" is bit-exact passthrough
    np.testing.assert_array_equal(
        np.asarray(quant_dequant(packed, 32)), np.asarray(packed))


# ---------------------------------------------------------------------------
# out-of-core streaming pipeline ≡ in-memory (ISSUE 7)
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(n=st.integers(60, 220), q=st.sampled_from([2, 3, 4]),
       scheme=st.sampled_from(["random", "metis-like"]),
       chunk_nodes=st.integers(7, 97), chunk_edges=st.integers(40, 900),
       seed=st.integers(0, 5))
def test_stream_partition_equals_in_memory_any_chunking(n, q, scheme,
                                                        chunk_nodes,
                                                        chunk_edges, seed):
    """The chunked partitioner is an exact reduction: for ANY chunk
    granularity (dividing the edge count or not), the owner vector and
    the edge-cut statistics equal ``partition_graph``'s in-memory
    results bitwise."""
    import tempfile

    from repro.graph import (edge_cut_stats, stream_edge_cut,
                             stream_partition, tiny_graph,
                             write_graph_store)
    from repro.graph.partition import PARTITIONERS

    g = tiny_graph(n=n, seed=seed)
    with tempfile.TemporaryDirectory() as td:
        store = write_graph_store(g, td + "/store",
                                  chunk_nodes=chunk_nodes,
                                  chunk_edges=chunk_edges)
        owner_s = stream_partition(store, q, scheme=scheme, seed=seed)
        owner_m = PARTITIONERS[scheme](g, q, seed=seed)
        np.testing.assert_array_equal(owner_s, owner_m)
        assert stream_edge_cut(store, owner_s) == \
            edge_cut_stats(g, owner_m)


@settings(max_examples=6, deadline=None)
@given(n=st.integers(60, 200), q=st.sampled_from([2, 3, 4]),
       chunk_nodes=st.integers(9, 77), seed=st.integers(0, 4),
       scheme=st.sampled_from(["random", "metis-like"]))
def test_shard_roundtrip_bitwise_vs_in_memory(n, q, chunk_nodes, seed,
                                              scheme):
    """write shards → load → rebuild ``HaloSpec``: bitwise-exact against
    ``build_halo_spec``/``build_partitioned``/``attach_p2p`` on the
    in-memory graph — every stacked array, every scalar fact, and both
    the manifest-carried and the rebuilt spec."""
    import json as _json
    import tempfile

    from repro.dist.halo import (HaloSpec, build_halo_spec, ell_arrays,
                                 halo_arrays)
    from repro.graph import (build_partitioned, load_shards, tiny_graph,
                             write_graph_store, write_shards)
    from repro.graph.partition import PARTITIONERS

    g = tiny_graph(n=n, seed=seed)
    owner = PARTITIONERS[scheme](g, q, seed=seed)
    pg = build_partitioned(g, owner, q)
    spec = build_halo_spec(pg)
    with tempfile.TemporaryDirectory() as td:
        store = write_graph_store(g, td + "/store",
                                  chunk_nodes=chunk_nodes)
        ss = load_shards(write_shards(store, owner, td + "/shards"))
    # spec: manifest copy, json round trip, and rebuild from loaded arrays
    assert ss.halo_spec == spec
    assert HaloSpec.from_dict(
        _json.loads(_json.dumps(spec.to_dict()))) == spec
    assert build_halo_spec(ss) == spec
    # scalar facts
    for k in ("q", "part_size", "halo_size", "num_nodes", "feat_dim",
              "num_classes", "halo_demand", "cross_edges"):
        assert getattr(ss, k) == getattr(pg, k), k
    assert (ss.n_train, ss.n_val, ss.n_test) == \
        (int(g.train_mask.sum()), int(g.val_mask.sum()),
         int(g.test_mask.sum()))
    # every stacked runtime array, bitwise
    ref = {k: getattr(pg, k) for k in
           ("features", "labels", "train_mask", "val_mask", "test_mask",
            "node_valid", "local_dst", "local_src", "local_w",
            "local_w_iso", "remote_dst", "remote_src", "remote_w",
            "send_idx", "send_valid")}
    ref.update(halo_arrays(pg, spec))
    ref.update(ell_arrays(pg, spec))
    for k, v in ref.items():
        np.testing.assert_array_equal(ss.arrays[k], v, err_msg=k)


@settings(max_examples=10, deadline=None)
@given(width=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2 ** 16))
def test_stochastic_rounding_unbiased(width, seed):
    """``floor(v + u)`` rounding is unbiased: the mean over M independent
    rounding keys approaches x — elementwise within 6σ of the rounding
    noise (std ≤ scale/2 per draw), and the pooled signed error within
    6σ of its own (much tighter) standard error."""
    from repro.kernels.ops import quant_dequant

    n, nb, m = 4, 2, 256
    f = nb * LANE
    key = jax.random.key(seed)
    x = jax.random.normal(jax.random.fold_in(key, 0), (n, f))
    keys = jax.random.split(jax.random.fold_in(key, 1), m)
    dq = jax.vmap(lambda k: quant_dequant(x, width, key=k))(keys)
    mean = np.asarray(jnp.mean(dq, axis=0)).reshape(n, nb, LANE)
    xb = np.asarray(x).reshape(n, nb, LANE)
    qmax = 2.0 ** (width - 1) - 1.0
    scale = np.maximum(np.abs(xb).max(-1), 1e-30) / qmax   # [n, nb]
    sigma = scale[..., None] * 0.5 / np.sqrt(m)
    assert np.all(np.abs(mean - xb) <= 6.0 * sigma + 1e-7)
    pooled = ((mean - xb) / scale[..., None]).mean()
    assert abs(pooled) <= 6.0 * 0.5 / np.sqrt(m * n * f)


# ---------------------------------------------------------------------------
# sub-byte bit-pack codec round trip (DESIGN.md §3.8 byte layout)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(width=st.sampled_from([2, 4, 8]), n=st.integers(1, 6),
       m=st.integers(1, 300), seed=st.integers(0, 2 ** 16))
def test_bitpack_roundtrip_reference(width, n, m, seed):
    """``unpack_bits(pack_bits(x, w), w, m) == x`` over the FULL signed
    field range for every width, including tail lane counts ``m`` that
    don't divide ``8/w`` (zero-padded last byte), and the byte layout is
    the documented little-endian grouping."""
    from repro.kernels.ops import pack_bits, unpack_bits

    rng = np.random.default_rng(seed)
    lo, hi = -(2 ** (width - 1)), 2 ** (width - 1) - 1
    x = rng.integers(lo, hi + 1, size=(n, m)).astype(np.int8)
    packed = np.asarray(pack_bits(jnp.asarray(x), width))
    vpb = 8 // width
    assert packed.dtype == np.uint8
    assert packed.shape == (n, -(-m // vpb))
    un = np.asarray(unpack_bits(jnp.asarray(packed), width, m))
    np.testing.assert_array_equal(un, x)
    # documented layout: lane i -> byte i//vpb, bit offset (i%vpb)*w,
    # low-w bits of the two's complement
    i = int(rng.integers(0, m))
    field = (int(packed[0, i // vpb]) >> ((i % vpb) * width)) \
        & (2 ** width - 1)
    assert field == int(x[0, i]) & (2 ** width - 1)
    # tail lanes beyond m decode to the zero pad
    full = np.asarray(unpack_bits(jnp.asarray(packed), width))
    assert full.shape[-1] == packed.shape[-1] * vpb
    assert not full[:, m:].any()


@settings(max_examples=20, deadline=None)
@given(width=st.sampled_from([2, 4, 8]), n=st.integers(1, 8),
       k=st.integers(1, 4), seed=st.integers(0, 2 ** 16))
def test_bitpack_roundtrip_kernel_helpers(width, n, k, seed):
    """The in-kernel strided-slice pack (`_bitpack_block`) is bitwise the
    reference grouping, and `_bitunpack_block` inverts it — the fused
    ``varco_pack_quant`` / ``varco_unpack_quant`` codec path."""
    from repro.kernels.ref import pack_bits_reference
    from repro.kernels.varco_pack import _bitpack_block, _bitunpack_block

    rng = np.random.default_rng(seed)
    lo, hi = -(2 ** (width - 1)), 2 ** (width - 1) - 1
    x = rng.integers(lo, hi + 1, size=(n, k * LANE)).astype(np.int8)
    packed = np.asarray(_bitpack_block(jnp.asarray(x), width))
    np.testing.assert_array_equal(
        packed, np.asarray(pack_bits_reference(jnp.asarray(x), width)))
    un = np.asarray(_bitunpack_block(jnp.asarray(packed), width))
    np.testing.assert_array_equal(un, x)
