"""Quantised wire codecs (DESIGN.md §3.8): the bit-width compression axis.

The fused pack+quantise kernel family, the ``[Q, Q]`` / ``[L, Q, Q]``
width-map plumbing through both aggregation oracles, the wire-bit
accounting (payload at width + fp32 scales), the error-feedback residual
loop, the controllers' rate × width allocation, and the bounded-recompile
contract of the width-keyed train step.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from parity import build_setup, mixed_map, mixed_width_map

from repro.core import CommPolicy, fixed
from repro.dist.gnn_parallel import (DistMeta, _make_aggregate_emulated,
                                     _packed_k_for, _packed_pair_k_for,
                                     _packed_pair_w_for, _snap_width)
from repro.dist.ratectl import (RatePlan, budget_controller, error_controller,
                                exchange_widths, init_wire_residuals,
                                make_auto_train_step, make_pacing,
                                stale_controller, width_candidates,
                                width_cost)
from repro.kernels import ref
from repro.kernels.ops import (LANE, pack_quant, per_block_wire_bits,
                               quant_dequant)
from repro.kernels.varco_pack import block_mask_indices
from repro.nn.gnn import gnn_forward
from repro.train.optim import sgd

F = 512
Q = 4
NB = F // LANE
WIDTHS = (2, 4, 8)


@pytest.fixture(scope="module")
def setup():
    _, cfg, params, pg, graph = build_setup(Q, f=F, layers=2, n=256)
    return cfg, params, pg, graph


def _uniform(rate: float) -> np.ndarray:
    rm = np.full((Q, Q), rate, np.float32)
    np.fill_diagonal(rm, 1.0)
    return rm


def _wmap(width: float) -> np.ndarray:
    wm = np.full((Q, Q), width, np.float32)
    np.fill_diagonal(wm, 32.0)
    return wm


def _agg(graph, meta, rm, key, wm=None, resid=None, resid_out=None):
    kb = dict(_packed_pair_k_for(meta, rm))
    return _make_aggregate_emulated(
        graph, meta, fixed(4.0, compressor="blockmask"), None,
        jnp.ones((), jnp.float32), key, packed_k=kb,
        rate_map=jnp.asarray(rm),
        width_map=None if wm is None else jnp.asarray(wm),
        resid=resid, resid_out=resid_out)


# ---------------------------------------------------------------------------
# fused pack+quantise kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("width", WIDTHS)
def test_pack_quant_matches_reference(width):
    """The fused Pallas kernel (interpret mode) and the jnp oracle agree
    bit-for-bit on both the bit-packed uint8 payload and the fp32
    scales, and the decode reproduces ``quant_dequant`` of the packed
    payload exactly."""
    key = jax.random.key(3)
    x = jax.random.normal(key, (24, F), jnp.float32) * \
        10.0 ** jax.random.uniform(jax.random.fold_in(key, 1), (24, 1),
                                   minval=-2.0, maxval=2.0)
    kept, inv = block_mask_indices(key, NB, 2.0)
    k = int(kept.shape[0])
    packed_k, scales_k = pack_quant(x, kept, width=width, interpret=True)
    packed_r, scales_r = ref.pack_quant_reference(x, kept, width)
    # true sub-byte storage: uint8 bytes, 8/width lanes per byte — the
    # buffer IS the ledger's LANE·width payload bits per kept block
    assert packed_k.dtype == jnp.uint8 and scales_k.dtype == jnp.float32
    assert packed_k.shape == (24, k * LANE * width // 8)
    np.testing.assert_array_equal(np.asarray(packed_k), np.asarray(packed_r))
    # the kernel folds 1/qmax into a multiply — scales match to fp32 ulp
    np.testing.assert_allclose(np.asarray(scales_k), np.asarray(scales_r),
                               rtol=1e-6)
    # w == 8 is bitwise the former int8-lane storage
    if width == 8:
        levels, _ = ref.quant_levels_reference(
            ref.pack_reference(x, kept), 8)
        np.testing.assert_array_equal(
            np.asarray(packed_k),
            np.asarray(jax.lax.bitcast_convert_type(levels, jnp.uint8)))
    # decode == quant_dequant of the packed fp32 payload (same scale rule)
    dq = ref.unpack_quant_reference(packed_r, scales_r, width)
    from repro.kernels.ops import wire_pack
    payload = wire_pack(x, kept, inv)
    np.testing.assert_allclose(np.asarray(dq),
                               np.asarray(quant_dequant(payload, width)),
                               rtol=0, atol=1e-6)


def test_per_block_wire_bits_values():
    assert float(per_block_wire_bits(32)) == LANE * 32.0
    for w in WIDTHS:
        assert float(per_block_wire_bits(w)) == LANE * w + 32.0


# ---------------------------------------------------------------------------
# width snapping and the static distinct-width key
# ---------------------------------------------------------------------------


def test_snap_width_grid():
    vals = [1.0, 2.0, 2.1, 4.0, 5.5, 8.0, 9.0, 31.0, 32.0, 40.0]
    assert [_snap_width(v) for v in vals] == \
        [2, 2, 4, 4, 8, 8, 32, 32, 32, 32]


def test_packed_pair_w_for_distinct_sub32(setup):
    _, params, pg, _ = setup
    meta = DistMeta.build(pg, params, wire="p2p")
    assert _packed_pair_w_for(meta, None) == ()
    assert _packed_pair_w_for(meta, _wmap(32.0)) == ()
    wm = _wmap(4.0)
    wm[0, 1] = 2.0
    wm[2, 3] = 7.5          # snaps up to 8
    assert _packed_pair_w_for(meta, wm) == (2, 4, 8)
    # the [L, Q, Q] tensor pools widths across layers
    wml = np.stack([_wmap(8.0), _wmap(32.0)])
    assert _packed_pair_w_for(meta, wml) == (8,)


def test_packed_k_shared_quantiser_consistency(setup):
    """Satellite: `_packed_k_for` and `_packed_pair_k_for` share one
    exchanged-width table — a uniform map must quantise identically to
    the scalar rate on every exchanged lane-block count."""
    _, params, pg, _ = setup
    for wire in ("packed", "p2p"):
        meta = DistMeta.build(pg, params, wire=wire)
        for rate in (1.0, 1.5, 2.0, 3.9, 16.0):
            assert dict(_packed_k_for(meta, rate)) == \
                dict(_packed_pair_k_for(meta, _uniform(rate))), (wire, rate)


# ---------------------------------------------------------------------------
# ledger: transport == analytic wire bits at every width
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("width", WIDTHS)
def test_p2p_transport_quant_matches_analytic(setup, width):
    """Per-pair transport at uniform (rate, width) is ``rows · K ·
    (128·w + 32)`` per exchange — and sums to the analytic
    ``DistMeta.transport_bits_quant`` at every width."""
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="p2p")
    rate = 2.0
    agg = _agg(graph, meta, _uniform(rate), jax.random.key(5),
               wm=_wmap(width))
    _, bits = gnn_forward(params, cfg, graph["features"], agg)
    pair_t = np.asarray(bits[2:2 + Q * Q]).reshape(Q, Q)
    rows = meta.pair_table().astype(np.float64)
    k = np.maximum(np.floor(NB / _uniform(rate)), 1.0)
    np.fill_diagonal(k, 0.0)
    expect = 2 * rows * k * (LANE * width + 32.0)   # two exchanges at F
    np.testing.assert_allclose(pair_t, expect, rtol=1e-6)
    analytic = 2 * float(meta.transport_bits_quant(F, rate, width))
    np.testing.assert_allclose(pair_t.sum(), analytic, rtol=1e-6)
    # fp32 "width" reproduces the unquantised ledger bit-for-bit
    agg32 = _agg(graph, meta, _uniform(rate), jax.random.key(5),
                 wm=_wmap(32.0))
    _, bits32 = gnn_forward(params, cfg, graph["features"], agg32)
    agg_none = _agg(graph, meta, _uniform(rate), jax.random.key(5))
    _, bits_none = gnn_forward(params, cfg, graph["features"], agg_none)
    np.testing.assert_array_equal(np.asarray(bits32), np.asarray(bits_none))


def test_packed_transport_quant_per_sender(setup):
    """The all-gather wire quantises each sender's payload once at the
    max width any receiver wants — transport charges the realised
    ``k_send · per_block_wire_bits(w_send)`` to every pair in the
    column."""
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="packed")
    rm = mixed_map(Q, 4)
    wm = mixed_width_map(Q, 4)
    agg = _agg(graph, meta, rm, jax.random.key(5), wm=wm)
    _, bits = gnn_forward(params, cfg, graph["features"], agg)
    pair_t = np.asarray(bits[2:2 + Q * Q]).reshape(Q, Q)
    rows = meta.pair_table().astype(np.float64)
    k = np.maximum(np.floor(NB / rm), 1.0)
    np.fill_diagonal(k, 0.0)
    k_send = np.maximum(k.max(axis=0), 1.0)
    off_w = np.where(np.eye(Q, dtype=bool), 0.0, wm)
    w_send = off_w.max(axis=0)
    blk = np.where(w_send >= 32.0, LANE * 32.0, LANE * w_send + 32.0)
    expect = 2 * rows * (k_send * blk)[None, :]
    np.testing.assert_allclose(pair_t, expect, rtol=1e-6)


def test_analytic_ledger_scales_with_width(setup):
    """The analytic (requested-rate) column charges payload at width —
    ``w/32`` of the fp32 charge, scale overhead excluded by
    convention."""
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="p2p")
    rm = _uniform(2.0)
    _, b32 = gnn_forward(params, cfg, graph["features"],
                         _agg(graph, meta, rm, jax.random.key(5)))
    _, b4 = gnn_forward(params, cfg, graph["features"],
                        _agg(graph, meta, rm, jax.random.key(5),
                             wm=_wmap(4.0)))
    np.testing.assert_allclose(float(b4[0]), float(b32[0]) * 4.0 / 32.0,
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# quantisation is actually applied (and bounded)
# ---------------------------------------------------------------------------


def test_width_map_quantises_hops_within_bound(setup):
    """A w-bit wire perturbs the logits (quantisation is real) but the
    perturbation shrinks as the width grows."""
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="p2p")
    rm = _uniform(1.0)
    exact, _ = gnn_forward(params, cfg, graph["features"],
                           _agg(graph, meta, rm, jax.random.key(5)))
    errs = []
    for w in WIDTHS:
        lq, _ = gnn_forward(params, cfg, graph["features"],
                            _agg(graph, meta, rm, jax.random.key(5),
                                 wm=_wmap(w)))
        errs.append(float(jnp.abs(lq - exact).max()))
    assert errs[0] > 0.0
    assert errs[0] > errs[1] > errs[2]            # 2 > 4 > 8 bit error


# ---------------------------------------------------------------------------
# error feedback through the cache channel
# ---------------------------------------------------------------------------


def test_error_feedback_residuals_and_carry(setup):
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="p2p")
    policy = CommPolicy.parse("auto:budget:1e9:w2", 10)
    opt = sgd(1e-2)
    step = make_auto_train_step(cfg, policy, opt, meta)
    cache = init_wire_residuals(meta, cfg)
    assert len(cache) == len(exchange_widths(cfg))
    p, s = params, opt.init(params)
    plan_q = RatePlan(jnp.asarray(_uniform(1.0)),
                      jnp.zeros((Q, Q), jnp.float32),
                      jnp.asarray(_wmap(2.0)))
    p, s, m, cache1 = step(p, s, graph, jax.random.key(0), plan_q, cache)
    assert len(cache1) == len(cache)
    for r0, r1 in zip(cache, cache1):
        assert r1.shape == r0.shape
    # residuals are the quantisation error — nonzero at w=2
    assert any(float(jnp.abs(r).max()) > 0.0 for r in cache1)
    # an exact step (widths=None, or an all-32 map) carries the EF state
    # unchanged instead of wiping it
    for widths in (None, jnp.asarray(_wmap(32.0))):
        plan_x = RatePlan(jnp.asarray(_uniform(1.0)),
                          jnp.zeros((Q, Q), jnp.float32), widths)
        _, _, _, cache2 = step(p, s, graph, jax.random.key(1), plan_x,
                               cache1)
        assert len(cache2) == len(cache1)
        for a, b in zip(cache1, cache2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_error_feedback_recurrence_time_average():
    """The EF recurrence the wire runs — ``q_t = Q(x + r_t)``,
    ``r_{t+1} = x + r_t − q_t`` — makes the time-averaged wire output
    converge to the exact payload at rate 1/T (the bounded-bias
    property residual shipping buys)."""
    key = jax.random.key(11)
    x = jax.random.normal(key, (8, 2 * LANE), jnp.float32)
    for width in (2, 4):
        r = jnp.zeros_like(x)
        outs = []
        for _ in range(16):
            q = quant_dequant(x + r, width)
            r = x + r - q
            outs.append(q)
        qmax = 2.0 ** (width - 1) - 1.0
        amax = np.abs(np.asarray(x)).max()
        # |mean_t q_t − x| = |r_T| / T ≤ scale_max / T
        bound = (amax * (1.0 + 1.0 / qmax)) / qmax / len(outs)
        err = np.abs(np.asarray(jnp.mean(jnp.stack(outs), 0) - x)).max()
        assert err <= bound + 1e-6, (width, err, bound)


def test_quantising_policy_routes_ef_cache_end_to_end():
    """train_gnn at a w<32 p2p policy initialises the EF residual cache
    and trains; a stale policy keeps hop-reuse ownership of the
    channel."""
    from repro.graph import tiny_graph
    from repro.train.trainer import train_gnn

    g = tiny_graph(n=128, feat_dim=256)
    res = train_gnn(g, q=2, scheme="random",
                    policy=CommPolicy.parse("auto:budget:5e6:w4", 4),
                    epochs=4, eval_every=4, hidden=128, seed=0, wire="p2p")
    assert np.isfinite(res.history.loss[-1])


# ---------------------------------------------------------------------------
# bounded recompiles across a mixed rate × width sweep (satellite)
# ---------------------------------------------------------------------------


def test_rate_width_sweep_bounds_recompiles(setup):
    """Rates quantise to kept-block counts and widths snap to the storage
    grid — a sweep of distinct (rate, width) plans whose static keys
    coincide must share compiled steps."""
    cfg, params, pg, graph = setup
    meta = DistMeta.build(pg, params, wire="p2p")
    policy = CommPolicy.parse("auto:budget:1e9:w4", 10)
    opt = sgd(1e-2)
    step = make_auto_train_step(cfg, policy, opt, meta)
    p, s = params, opt.init(params)
    cache = init_wire_residuals(meta, cfg)
    sweep = [(1.5, 4.0), (2.0, 3.7),     # same k (nb/r floors to 2), w→4
             (1.6, 4.0),                 # again
             (2.0, 32.0), (1.5, None)]   # exact wire: shares ONE variant
    for i, (rate, width) in enumerate(sweep):
        widths = None if width is None else jnp.asarray(_wmap(width))
        plan = RatePlan(jnp.asarray(_uniform(rate)),
                        jnp.zeros((Q, Q), jnp.float32), widths)
        p, s, m, cache = step(p, s, graph, jax.random.key(i), plan, cache)
    # two compiled variants: (k=2, w=(4,)) and (k=2, exact)
    assert step._jit_step._cache_size() == 2


# ---------------------------------------------------------------------------
# controllers allocate along the rate × width frontier
# ---------------------------------------------------------------------------


def _pacing(pg, params, budget, steps=20):
    meta = DistMeta.build(pg, params, wire="p2p")
    return meta, make_pacing(meta, (F, F), steps, budget)


def test_budget_controller_width_under_squeeze(setup):
    """A squeezed budget drops the uniform wire below 32 bits; a generous
    one stays exact (an all-32 pick, which the step collapses to the
    pre-quantisation compiled program)."""
    _, params, pg, _ = setup
    meta, pacing = _pacing(pg, params, budget=1e12)
    ctl = budget_controller(Q, pacing, max_width=2)
    plan, _ = ctl.plan(ctl.init(), 0)
    assert np.all(np.asarray(plan.widths) == 32.0)   # generous → fp32
    assert _packed_pair_w_for(meta, np.asarray(plan.widths)) == ()
    # the plan stays jit-compatible with the width axis on
    plan_j, _ = jax.jit(ctl.plan)(ctl.init(), jnp.asarray(0))
    assert plan_j.widths.shape == (Q, Q)
    meta, pacing = _pacing(pg, params, budget=0.02 * pacing.d_full * 20)
    ctl = budget_controller(Q, pacing, max_width=2)
    plan, _ = ctl.plan(ctl.init(), 0)
    assert plan.widths is not None
    wm = np.asarray(plan.widths)
    assert np.all(np.diag(wm) == 32.0)
    off = wm[~np.eye(Q, dtype=bool)]
    assert np.all(off < 32.0) and set(np.unique(off)) <= {2.0, 4.0, 8.0}
    # max_width=32 turns the axis off entirely
    ctl32 = budget_controller(Q, pacing, max_width=32)
    plan32, _ = ctl32.plan(ctl32.init(), 0)
    assert plan32.widths is None


def test_error_controller_refines_widths(setup):
    _, params, pg, _ = setup
    meta, pacing = _pacing(pg, params, budget=1.0)
    meta, pacing = _pacing(pg, params, budget=0.05 * pacing.d_full * 20)
    ctl = error_controller(Q, pacing, meta.pair_table(), max_width=2)
    state = ctl.init()
    plan, state = ctl.plan(state, 0)
    assert plan.widths is not None
    wm = np.asarray(plan.widths)
    live = meta.pair_table() > 0
    np.fill_diagonal(live, False)
    assert np.all(wm[~live] == 32.0)                 # dead pairs exact
    assert np.all(np.isin(wm[live], [2.0, 4.0, 8.0, 32.0]))
    # committed y stays monotone across steps (Prop. 2 untouched)
    y0 = np.asarray(state["y"])
    state = ctl.observe(state, {
        "transport_bits": jnp.zeros(()),
        "pair_err": jnp.asarray(meta.pair_table(), jnp.float32)})
    _, state = ctl.plan(state, 1)
    assert np.all(np.asarray(state["y"]) >= y0 - 1e-7)


def test_stale_controller_static_width(setup):
    _, params, pg, _ = setup
    meta, pacing = _pacing(pg, params, budget=1e9)
    ctl = stale_controller(Q, pacing, max_width=8)
    plan, _ = ctl.plan(ctl.init(), 0)
    wm = np.asarray(plan.widths)
    assert np.all(np.diag(wm) == 32.0)
    assert np.all(wm[~np.eye(Q, dtype=bool)] == 8.0)
    # the cheaper wire lets the same allowance afford a lower rate
    ctl32 = stale_controller(Q, pacing, max_width=32)
    plan32, _ = ctl32.plan(ctl32.init(), 0)
    off = ~np.eye(Q, dtype=bool)
    assert np.all(np.asarray(plan.rates)[off] <=
                  np.asarray(plan32.rates)[off] + 1e-6)


def test_width_candidates_and_cost():
    assert width_candidates(32) == (32,)
    assert width_candidates(8) == (32, 8)
    assert width_candidates(2) == (32, 8, 4, 2)
    assert width_cost(32) == 1.0
    assert width_cost(4) == pytest.approx((4 + 32.0 / LANE) / 32.0)


# ---------------------------------------------------------------------------
# backend parity at mixed rate × width (subprocess; the fast cases —
# the full sweep lives in test_parity_matrix)
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# ledger-vs-buffer conservation (the tentpole's closing check)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_wire_conservation_ledger_matches_buffers():
    """On BOTH backends, at w ∈ {2, 4, 8, 32}: every p2p hop's
    transported array (bit-packed uint8 payload + fp32 scales under
    ``store_w``; fp32 rows at 32) has ``nbytes == ceil(per-pair ledger
    transport bits / 8)`` — hop by hop, per-pair in total, and with
    byte-identical buffers across backends.  The packed wire conforms
    per transported row (its ledger charges halo demand, not the padded
    all-gather buffer)."""
    from parity import run_wire_conservation

    out = run_wire_conservation(4)
    assert out.count(" OK ") == 4, out


@pytest.mark.slow
def test_forward_parity_mixed_rate_width():
    from parity import run_forward_parity

    cases = [
        {"wire": "p2p", "policy": "fixed:4", "map": "pair",
         "width_map": "pair"},
        {"wire": "p2p", "policy": "fixed:4", "map": "layer",
         "width_map": "layer"},
        {"wire": "packed", "policy": "fixed:4", "map": "pair",
         "width_map": "pair"},
        {"wire": "packed", "policy": "fixed:4", "map": "layer",
         "width_map": "layer"},
    ]
    out = run_forward_parity(4, cases, f=256)
    assert out.count(" OK ") == len(cases), out
